"""Pallas TPU kernel for the Mamba2 SSD (state-space duality) operator.

TPU adaptation of the SSD chunked algorithm (Dao & Gu, 2024): the GPU
version leans on warp-level scans; on TPU we recast everything as
MXU matmuls inside a chunk plus a *sequential grid dimension* that carries
the (P x N) inter-chunk state in VMEM scratch — the TPU-idiomatic
replacement for a cross-block carry.

grid = (B, H, nChunks): chunks innermost ('arbitrary'), state scratch
persists across chunk steps for a fixed (batch, head).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _compiler_params(**kw):
    from repro.kernels.ops import tpu_compiler_params  # lazy: avoid cycle
    return tpu_compiler_params(**kw)


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, state_ref,
                *, chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)      # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)       # (Q,)
    bmat = b_ref[0, :, 0, :].astype(jnp.float32)   # (Q, N)
    cmat = c_ref[0, :, 0, :].astype(jnp.float32)   # (Q, N)
    a = a_ref[pl.program_id(1)]                    # scalar decay rate (<0)

    la = dt * a                                    # per-step log decay
    cum = jnp.cumsum(la)                           # L_i inclusive

    # intra-chunk (matmul form): M[i,j] = (C_i.B_j) dt_j exp(L_i - L_j), j<=i
    cb = jax.lax.dot(cmat, bmat.T, preferred_element_type=jnp.float32)
    dec = cum[:, None] - cum[None, :]
    idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jdx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = idx >= jdx
    dec = jnp.where(causal, dec, 0.0)   # clamp before exp (overflow hygiene)
    m = cb * jnp.where(causal, jnp.exp(dec), 0.0)
    y = jax.lax.dot(m, x * dt[:, None], preferred_element_type=jnp.float32)

    # inter-chunk: y_i += (C_i exp(L_i)) @ state^T   (state: (P, N))
    y += jax.lax.dot(cmat * jnp.exp(cum)[:, None], state_ref[...].T,
                     preferred_element_type=jnp.float32)

    # state update: h' = exp(L_Q) h + sum_j exp(L_Q - L_j) dt_j x_j B_j^T
    tot = cum[chunk - 1]
    w = jnp.exp(tot - cum) * dt                    # (Q,)
    upd = jax.lax.dot((x * w[:, None]).T, bmat,
                      preferred_element_type=jnp.float32)   # (P, N)
    state_ref[...] = state_ref[...] * jnp.exp(tot) + upd

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk=128, interpret=False):
    """x: (Bb,S,H,P); dt: (Bb,S,H); A: (H,); B,C: (Bb,S,G,N).

    Returns y: (Bb,S,H,P).  (D-skip and gating applied by the caller.)
    """
    bb, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    kv_map = lambda b_, h_, ci: (b_, ci, (h_ * g) // h, 0)
    out = pl.pallas_call(
        kernel,
        grid=(bb, h, nc),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),            # A (H,)
            pl.BlockSpec((1, chunk, 1, p),
                         lambda b_, h_, ci: (b_, ci, h_, 0)),  # x
            pl.BlockSpec((1, chunk, 1),
                         lambda b_, h_, ci: (b_, ci, h_)),     # dt
            pl.BlockSpec((1, chunk, 1, n), kv_map),            # B
            pl.BlockSpec((1, chunk, 1, n), kv_map),            # C
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, p),
                               lambda b_, h_, ci: (b_, ci, h_, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(A.astype(jnp.float32), x, dt, B, C)
    return out
