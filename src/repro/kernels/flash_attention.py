"""Pallas TPU flash-attention forward kernel.

Design (TPU-native, not a CUDA port):
  * grid = (B, Hq, nQ, nK); the k dimension is innermost/'arbitrary' so the
    fp32 accumulator lives in VMEM scratch across k steps (MXU-friendly
    128-aligned blocks, no HBM round-trips for the softmax state).
  * GQA is expressed in the k/v BlockSpec index_map (kv head = hq*Hkv//Hq)
    so no repeated K/V materialisation ever happens in HBM.
  * sliding-window size is a *dynamic* SMEM scalar: one compiled kernel
    serves local and global layers (gemma-style alternation inside a
    scanned layer stack); fully-masked k-blocks are skipped via pl.when.
  * optional logit soft-capping (gemma2) fused into the score computation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _compiler_params(**kw):
    from repro.kernels.ops import tpu_compiler_params  # lazy: avoid cycle
    return tpu_compiler_params(**kw)

NEG_INF = -1e30


def _fa_kernel(win_ref, q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref,
               l_ref, *, scale, softcap, causal, block_q, block_k, n_k):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    window = win_ref[0]

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k
    # any key in this block can be attended by any query in the q block?
    live = jnp.logical_and(
        jnp.logical_or(not causal, k_start <= q_start + block_q - 1),
        k_start + block_k - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = (rows - cols) < window
        if causal:
            mask = jnp.logical_and(mask, cols <= rows)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot(p.astype(v.dtype), v,
                                      preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = l_ref[...]
        lse_ref[0, 0, :] = (m_ref[...] + jnp.log(jnp.where(l == 0.0, 1.0, l)))
        l = jnp.where(l == 0.0, 1.0, l)   # fully-masked rows -> 0 output
        o_ref[0, 0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "softcap", "scale", "block_q", "block_k",
                     "interpret"))
def flash_attention_fwd(q, k, v, window=None, *, causal=True, softcap=0.0,
                        scale=None, block_q=128, block_k=128,
                        interpret=False):
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Sk, D); returns (B, Hq, Sq, D).

    ``window``: None (full), python int, or int32 scalar array (dynamic).
    Assumes Sq == Sk (training / prefill self-attention).
    """
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    assert sq == sk, "fwd kernel is for self-attention (train/prefill)"
    if scale is None:
        scale = d ** -0.5
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    n_q, n_k = sq // block_q, sk // block_k

    if window is None:
        window = sk + block_k  # never limits
    win = jnp.asarray(window, jnp.int32).reshape(1)

    kernel = functools.partial(
        _fa_kernel, scale=scale, softcap=softcap, causal=causal,
        block_q=block_q, block_k=block_k, n_k=n_k)

    kv_map = lambda b_, h_, qi, ki: (b_, (h_ * hkv) // hq, ki, 0)
    out = pl.pallas_call(
        kernel,
        grid=(b, hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d), kv_map),
            pl.BlockSpec((1, 1, block_k, d), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b_, h_, qi, ki: (b_, h_, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((b, hq, sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(win, q, k, v)
    return out  # (o, lse)
