"""Pallas TPU fused RMSNorm kernel (row-blocked, fp32 statistics)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _compiler_params(**kw):
    from repro.kernels.ops import tpu_compiler_params  # lazy: avoid cycle
    return tpu_compiler_params(**kw)


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps, weight_offset):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    w = weight_offset + w_ref[...].astype(jnp.float32)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "weight_offset",
                                             "block_rows", "interpret"))
def rmsnorm(x, w, *, eps=1e-6, weight_offset=0.0, block_rows=256,
            interpret=False):
    """x: (..., D); w: (D,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    # pad rows to a block multiple
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    n_r = x2.shape[0] // block_rows
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps,
                          weight_offset=weight_offset),
        grid=(n_r,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
            pl.BlockSpec((d,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        compiler_params=_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x2, w)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
