"""Public kernel API with backend dispatch and custom VJPs.

Backends:
  * ``pallas``  — the TPU kernels in this package (default on TPU).
  * ``xla``     — blockwise pure-jnp implementations (default elsewhere;
                  also what the CPU dry-run lowers, so HLO stays compact
                  and flash-style memory-efficient via lax.scan).

All train-path ops are differentiable: flash attention and SSD carry
manual/custom VJPs with flash-style recomputation (no O(S^2) residuals).
"""
from __future__ import annotations

import functools
import os
from math import gcd as math_gcd
from typing import Optional

import jax
import jax.numpy as jnp


def tpu_compiler_params(**kwargs):
    """Version-compat shim for the Pallas-TPU compiler-params class.

    jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``;
    resolve whichever this jax provides.  Kernels import this lazily
    (inside the kernel entry point) so the ops<->kernel module cycle
    stays one-directional at import time.
    """
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or pltpu.TPUCompilerParams
    return cls(**kwargs)


from repro.kernels import ref as _ref  # noqa: E402
from repro.kernels.flash_attention import flash_attention_fwd as _fa_pallas
from repro.kernels.moe_gmm import moe_gmm as _gmm_pallas
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm_pallas
from repro.kernels.ssd_scan import ssd_scan as _ssd_pallas

NEG_INF = -1e30


def default_backend() -> str:
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "xla"


# ===========================================================================
# Flash attention
# ===========================================================================
def _win_value(window, sk, block_k):
    if window is None:
        return jnp.int32(sk + block_k)
    return jnp.asarray(window, jnp.int32)


def _pick_block(s: int, want: int) -> int:
    """Largest divisor of s that is <= want (handles S like 1500)."""
    b = min(want, s)
    while s % b:
        b -= 1
    return b


def _fa_fwd_xla_blocked(q, k, v, window, causal, softcap, scale, block):
    """2D-blocked fwd with a PYTHON loop and STATIC block skipping.

    Skips (q-block, k-block) pairs that are fully masked (causal upper
    triangle, or beyond a static window) — the HLO contains only live
    blocks, so compiled FLOPs reflect the true sub-quadratic cost of
    windowed/causal attention.  Used when ``window`` is static.
    """
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    group = hq // hkv
    blkq = _pick_block(sq, block)
    blkk = _pick_block(sk, block)
    nq, nk = sq // blkq, sk // blkk
    f32 = jnp.float32
    qf = q.astype(f32)
    win = window if window is not None else sk + blkk

    o_blocks, lse_blocks = [], []
    for qi in range(nq):
        qb = qf[:, :, qi * blkq:(qi + 1) * blkq]
        rows = qi * blkq + jnp.arange(blkq)[:, None] + (sk - sq)
        acc = jnp.zeros((b, hq, blkq, d), f32)
        m = jnp.full((b, hq, blkq), NEG_INF, f32)
        l = jnp.zeros((b, hq, blkq), f32)
        for ki in range(nk):
            k_lo, k_hi = ki * blkk, (ki + 1) * blkk - 1
            q_lo, q_hi = (qi * blkq + (sk - sq),
                          qi * blkq + blkq - 1 + (sk - sq))
            if causal and k_lo > q_hi:
                continue                      # above the diagonal
            if k_hi <= q_lo - win:
                continue                      # beyond the window
            kb = jnp.repeat(k[:, :, k_lo:k_lo + blkk].astype(f32), group, 1)
            vb = jnp.repeat(v[:, :, k_lo:k_lo + blkk].astype(f32), group, 1)
            s = jnp.einsum("bhqd,bhkd->bhqk", qb, kb) * scale
            if softcap:
                s = softcap * jnp.tanh(s / softcap)
            cols = k_lo + jnp.arange(blkk)[None, :]
            mask = (rows - cols) < win
            if causal:
                mask &= cols <= rows
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l = l * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd",
                                                      p, vb)
            m = m_new
        lsafe = jnp.where(l == 0.0, 1.0, l)
        o_blocks.append((acc / lsafe[..., None]).astype(q.dtype))
        lse_blocks.append(m + jnp.log(lsafe))
    return jnp.concatenate(o_blocks, 2), jnp.concatenate(lse_blocks, 2)


def _fa_bwd_xla_blocked(q, k, v, o, lse, do, window, causal, softcap,
                        scale, block):
    """2D-blocked bwd (python loops, static skipping) — see fwd."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    group = hq // hkv
    blkq = _pick_block(sq, block)
    blkk = _pick_block(sk, block)
    nq, nk = sq // blkq, sk // blkk
    f32 = jnp.float32
    win = window if window is not None else sk + blkk
    delta = jnp.sum(do.astype(f32) * o.astype(f32), axis=-1)

    dq_blocks = []
    dk_acc = [None] * nk
    dv_acc = [None] * nk
    for qi in range(nq):
        qb = q[:, :, qi * blkq:(qi + 1) * blkq].astype(f32)
        dob = do[:, :, qi * blkq:(qi + 1) * blkq].astype(f32)
        lseb = lse[:, :, qi * blkq:(qi + 1) * blkq]
        db = delta[:, :, qi * blkq:(qi + 1) * blkq]
        rows = qi * blkq + jnp.arange(blkq)[:, None] + (sk - sq)
        dq_b = jnp.zeros((b, hq, blkq, d), f32)
        for ki in range(nk):
            k_lo, k_hi = ki * blkk, (ki + 1) * blkk - 1
            q_lo, q_hi = (qi * blkq + (sk - sq),
                          qi * blkq + blkq - 1 + (sk - sq))
            if causal and k_lo > q_hi:
                continue
            if k_hi <= q_lo - win:
                continue
            kb = jnp.repeat(k[:, :, k_lo:k_lo + blkk].astype(f32), group, 1)
            vb = jnp.repeat(v[:, :, k_lo:k_lo + blkk].astype(f32), group, 1)
            s_raw = jnp.einsum("bhqd,bhkd->bhqk", qb, kb) * scale
            if softcap:
                t = jnp.tanh(s_raw / softcap)
                s = softcap * t
                dcap = 1.0 - t * t
            else:
                s, dcap = s_raw, None
            cols = k_lo + jnp.arange(blkk)[None, :]
            mask = (rows - cols) < win
            if causal:
                mask &= cols <= rows
            s = jnp.where(mask[None, None], s, NEG_INF)
            p = jnp.exp(s - lseb[..., None])
            dv_q = jnp.einsum("bhqk,bhqd->bhkd", p, dob)
            dp = jnp.einsum("bhqd,bhkd->bhqk", dob, vb)
            ds = p * (dp - db[..., None])
            if dcap is not None:
                ds = ds * dcap
            ds = jnp.where(mask[None, None], ds, 0.0) * scale
            dq_b += jnp.einsum("bhqk,bhkd->bhqd", ds, kb)
            dk_q = jnp.einsum("bhqk,bhqd->bhkd", ds, qb)
            dk_q = dk_q.reshape(b, hkv, group, blkk, d).sum(2)
            dv_q = dv_q.reshape(b, hkv, group, blkk, d).sum(2)
            dk_acc[ki] = dk_q if dk_acc[ki] is None else dk_acc[ki] + dk_q
            dv_acc[ki] = dv_q if dv_acc[ki] is None else dv_acc[ki] + dv_q
        dq_blocks.append(dq_b)
    zero = jnp.zeros((b, hkv, blkk, d), f32)
    dk = jnp.concatenate([x if x is not None else zero for x in dk_acc], 2)
    dv = jnp.concatenate([x if x is not None else zero for x in dv_acc], 2)
    dq = jnp.concatenate(dq_blocks, 2)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def _fa_fwd_xla(q, k, v, window, causal, softcap, scale, block_k):
    """Blockwise fwd, lax.scan over k blocks.  Returns (o, lse) in fp32."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    group = hq // hkv
    bk = _pick_block(sk, block_k)
    nk = sk // bk
    win = _win_value(window, sk, bk)
    qf = q.astype(jnp.float32)
    rows = jnp.arange(sq)[:, None] + (sk - sq)

    kb = jnp.moveaxis(k.reshape(b, hkv, nk, bk, d), 2, 0)
    vb = jnp.moveaxis(v.reshape(b, hkv, nk, bk, d), 2, 0)

    def step(carry, inp):
        acc, m, l = carry
        ki, kblk, vblk = inp
        kblk = jnp.repeat(kblk.astype(jnp.float32), group, axis=1)
        vblk = jnp.repeat(vblk.astype(jnp.float32), group, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kblk) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        cols = ki * bk + jnp.arange(bk)[None, :]
        mask = (rows - cols) < win
        if causal:
            mask &= cols <= rows
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vblk)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((b, hq, sq, d), jnp.float32)
    m0 = jnp.full((b, hq, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0),
                                  (jnp.arange(nk), kb, vb))
    lsafe = jnp.where(l == 0.0, 1.0, l)
    o = (acc / lsafe[..., None]).astype(q.dtype)
    lse = m + jnp.log(lsafe)
    return o, lse


def _fa_bwd_xla(q, k, v, o, lse, do, window, causal, softcap, scale,
                block_q):
    """Blockwise bwd: single scan over q blocks; dk/dv accumulate in carry."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    group = hq // hkv
    bq = _pick_block(sq, block_q)
    nq = sq // bq
    win = _win_value(window, sk, bq)
    f32 = jnp.float32
    kf = jnp.repeat(k.astype(f32), group, axis=1)   # (b,hq,sk,d)
    vf = jnp.repeat(v.astype(f32), group, axis=1)
    cols = jnp.arange(sk)[None, :]

    qb = jnp.moveaxis(q.reshape(b, hq, nq, bq, d), 2, 0).astype(f32)
    dob = jnp.moveaxis(do.reshape(b, hq, nq, bq, d), 2, 0).astype(f32)
    lseb = jnp.moveaxis(lse.reshape(b, hq, nq, bq), 2, 0)
    # delta_i = rowsum(dO * O)
    delta = jnp.sum(do.astype(f32) * o.astype(f32), axis=-1)
    deltab = jnp.moveaxis(delta.reshape(b, hq, nq, bq), 2, 0)

    def step(carry, inp):
        dk, dv = carry
        qi, qblk, doblk, lseblk, dblk = inp
        s_raw = jnp.einsum("bhqd,bhkd->bhqk", qblk, kf) * scale
        if softcap:
            t = jnp.tanh(s_raw / softcap)
            s = softcap * t
            dcap = (1.0 - t * t)
        else:
            s = s_raw
            dcap = None
        rows = qi * bq + jnp.arange(bq)[:, None] + (sk - sq)
        mask = (rows - cols) < win
        if causal:
            mask &= cols <= rows
        s = jnp.where(mask[None, None], s, NEG_INF)
        p = jnp.exp(s - lseblk[..., None])                   # (b,hq,bq,sk)
        dv_q = jnp.einsum("bhqk,bhqd->bhkd", p, doblk)
        dp = jnp.einsum("bhqd,bhkd->bhqk", doblk, vf)
        ds = p * (dp - dblk[..., None])
        if dcap is not None:
            ds = ds * dcap
        ds = jnp.where(mask[None, None], ds, 0.0) * scale
        dq_b = jnp.einsum("bhqk,bhkd->bhqd", ds, kf)
        dk_q = jnp.einsum("bhqk,bhqd->bhkd", ds, qblk)
        # GQA: sum gradients over the head group
        dk_q = dk_q.reshape(b, hkv, group, sk, d).sum(2)
        dv_q = dv_q.reshape(b, hkv, group, sk, d).sum(2)
        return (dk + dk_q, dv + dv_q), dq_b

    dk0 = jnp.zeros((b, hkv, sk, d), f32)
    dv0 = jnp.zeros((b, hkv, sk, d), f32)
    (dk, dv), dqb = jax.lax.scan(step, (dk0, dv0),
                                 (jnp.arange(nq), qb, dob, lseb, deltab))
    dq = jnp.moveaxis(dqb, 0, 2).reshape(b, hq, sq, d)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _flash_attention(q, k, v, window, causal, softcap, scale, block,
                     backend):
    o, _ = _flash_attention_fwd_rule(q, k, v, window, causal, softcap,
                                     scale, block, backend)
    return o


def _static_window(window):
    return window is None or isinstance(window, int)


def _flash_attention_fwd_rule(q, k, v, window, causal, softcap, scale,
                              block, backend):
    if backend == "pallas":
        o, lse = _fa_pallas(q, k, v, window, causal=causal, softcap=softcap,
                            scale=scale, block_q=block, block_k=block)
    elif backend == "xla_blocked" and _static_window(window):
        o, lse = _fa_fwd_xla_blocked(q, k, v, window, causal, softcap,
                                     scale, block)
    else:
        o, lse = _fa_fwd_xla(q, k, v, window, causal, softcap, scale, block)
    return o, (q, k, v, o, lse, window)


def _flash_attention_bwd_rule(causal, softcap, scale, block, backend, res,
                              do):
    import numpy as np
    q, k, v, o, lse, window = res
    if backend == "xla_blocked" and _static_window(window):
        dq, dk, dv = _fa_bwd_xla_blocked(q, k, v, o, lse, do, window,
                                         causal, softcap, scale, block)
    else:
        dq, dk, dv = _fa_bwd_xla(q, k, v, o, lse, do, window, causal,
                                 softcap, scale, block)
    win_ct = (None if window is None or isinstance(window, int)
              else np.zeros(jnp.shape(window), jax.dtypes.float0))
    return dq, dk, dv, win_ct


def _fa_vjp_fwd(q, k, v, window, causal, softcap, scale, block, backend):
    o, res = _flash_attention_fwd_rule(q, k, v, window, causal, softcap,
                                       scale, block, backend)
    return o, res


_flash_attention.defvjp(_fa_vjp_fwd, _flash_attention_bwd_rule)


def flash_attention(q, k, v, *, window=None, causal=True, softcap=0.0,
                    scale=None, block=128, backend=None):
    """Memory-efficient attention.  q: (B,Hq,S,D); k/v: (B,Hkv,S,D).

    ``window`` may be None, an int, or a traced int32 scalar (dynamic
    local/global switching inside a scanned layer stack).
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    backend = backend or default_backend()
    return _flash_attention(q, k, v, window, causal, float(softcap),
                            float(scale), int(block), backend)


def decode_attention(q, k_cache, v_cache, pos, *, window=None, softcap=0.0,
                     scale=None):
    """Single-token decode attention.

    q: (B,Hq,1,D); caches: (B,Hkv,Smax,D); pos: () int32 current position
    (number of tokens already in cache, the new token attends to
    cache[0..pos]).  Window masks cache entries older than ``window``.
    Memory-bound: plain jnp is roofline-optimal here (one pass over KV).
    """
    b, hq, _, d = q.shape
    hkv, smax = k_cache.shape[1], k_cache.shape[2]
    if scale is None:
        scale = d ** -0.5
    group = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, group, d)
    kf = k_cache.astype(jnp.float32)
    s = jnp.einsum("bhgd,bhkd->bhgk", qf, kf) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    cols = jnp.arange(smax)[None, None, None, :]
    mask = cols <= pos
    if window is not None:
        mask &= cols > pos - jnp.asarray(window, jnp.int32)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, hq, 1, d).astype(q.dtype)


# ===========================================================================
# MoE dispatch / grouped matmul
# ===========================================================================
def moe_gmm(x, w, group_sizes_or_blockids, *, backend=None, block_t=128):
    """Grouped matmul over expert-sorted tokens.

    pallas: expects block ids per token-block.  xla: expects a dense batched
    form — used by the model layer (see models/moe.py which builds padded
    (E, cap, d) buckets and einsums); this wrapper handles the sorted-rows
    layout used by the kernel tests.
    """
    backend = backend or default_backend()
    if backend == "pallas":
        return _gmm_pallas(x, w, group_sizes_or_blockids, block_t=block_t)
    return _ref.moe_gmm_ref(x, w, group_sizes_or_blockids)


# ===========================================================================
# SSD (Mamba2)
# ===========================================================================
@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _ssd(x, dt, A, B, C, chunk, backend):
    if backend == "pallas":
        return _ssd_pallas(x, dt, A, B, C, chunk=chunk)
    unroll = backend == "xla_blocked"
    y, _ = _ref.ssd_chunked_ref(x, dt, A, B, C, chunk=chunk, unroll=unroll)
    return y


def _ssd_fwd(x, dt, A, B, C, chunk, backend):
    y = _ssd(x, dt, A, B, C, chunk, backend)
    return y, (x, dt, A, B, C)


def _ssd_bwd(chunk, backend, res, dy):
    x, dt, A, B, C = res
    # Flash-style recompute: differentiate the chunked jnp formulation.
    unroll = backend == "xla_blocked"
    def f(x_, dt_, A_, B_, C_):
        y, _ = _ref.ssd_chunked_ref(x_, dt_, A_, B_, C_, chunk=chunk,
                                    unroll=unroll)
        return y
    _, vjp = jax.vjp(f, x, dt, A, B, C)
    return vjp(dy)


_ssd.defvjp(_ssd_fwd, _ssd_bwd)


def ssd(x, dt, A, B, C, *, chunk=128, backend=None):
    """Mamba2 SSD operator.  See ssd_scan.py for shapes."""
    backend = backend or default_backend()
    return _ssd(x, dt, A, B, C, int(chunk), backend)


# ===========================================================================
# RMSNorm
# ===========================================================================
def rmsnorm(x, w, *, eps=1e-6, weight_offset=0.0, backend=None):
    backend = backend or default_backend()
    if backend == "pallas":
        # fwd-only pallas; bwd recomputes via the jnp formulation
        @jax.custom_vjp
        def _rn(x_, w_):
            return _rmsnorm_pallas(x_, w_, eps=eps,
                                   weight_offset=weight_offset)

        def _rn_fwd(x_, w_):
            return _rn(x_, w_), (x_, w_)

        def _rn_bwd(res, dy):
            x_, w_ = res
            _, vjp = jax.vjp(
                lambda a, b: _ref.rmsnorm_ref(a, b, eps=eps,
                                              weight_offset=weight_offset),
                x_, w_)
            return vjp(dy)

        _rn.defvjp(_rn_fwd, _rn_bwd)
        return _rn(x, w)
    return _ref.rmsnorm_ref(x, w, eps=eps, weight_offset=weight_offset)
