"""Pallas TPU grouped matmul for MoE expert FFNs (megablocks-style).

Tokens arrive sorted by expert and padded so every token block of size
``block_t`` belongs to exactly ONE expert; ``block_group_ids[t]`` names it.
The expert weight block is selected by a scalar-prefetch index_map, so the
kernel streams only the weights of experts that actually own tokens on this
core — the TPU-native analogue of megablocks' block-sparse matmul (no
(T, E, capacity) one-hot dispatch tensors ever touch HBM).

grid = (nT, nN, nK): fp32 accumulation over the K dimension in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _compiler_params(**kw):
    from repro.kernels.ops import tpu_compiler_params  # lazy: avoid cycle
    return tpu_compiler_params(**kw)


def _gmm_kernel(gid_ref, x_ref, w_ref, o_ref, acc_ref, *, n_k):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(
        x_ref[...].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "block_n", "block_k",
                                             "interpret"))
def moe_gmm(x, w, block_group_ids, *, block_t=128, block_n=128, block_k=128,
            interpret=False):
    """x: (T, K) sorted+padded tokens; w: (E, K, N);
    block_group_ids: (T//block_t,) int32 expert id per token block.
    Returns (T, N).
    """
    t, kdim = x.shape
    e, _, n = w.shape
    block_t = min(block_t, t)
    block_n = min(block_n, n)
    block_k = min(block_k, kdim)
    assert t % block_t == 0 and n % block_n == 0 and kdim % block_k == 0
    n_t, n_n, n_k = t // block_t, n // block_n, kdim // block_k
    assert block_group_ids.shape == (n_t,)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_t, n_n, n_k),
        in_specs=[
            pl.BlockSpec((block_t, block_k),
                         lambda ti, ni, ki, gid: (ti, ki)),
            pl.BlockSpec((1, block_k, block_n),
                         lambda ti, ni, ki, gid: (gid[ti], ki, ni)),
        ],
        out_specs=pl.BlockSpec((block_t, block_n),
                               lambda ti, ni, ki, gid: (ti, ni)),
        scratch_shapes=[pltpu.VMEM((block_t, block_n), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_gmm_kernel, n_k=n_k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, n), x.dtype),
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_group_ids.astype(jnp.int32), x, w)
    return out
