"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth for the kernel allclose sweeps and double as the
CPU execution path of the model zoo (the dry-run compiles the *blockwise*
variants in ops.py, which are numerically equivalent but memory-efficient).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
def attention_ref(q, k, v, *, causal=True, window=None, softcap=0.0,
                  scale=None):
    """Dense reference attention.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D).  GQA via head repetition.
    ``window``: sliding window size (keys with row-col >= window masked);
    may be a python int or a traced scalar.  Causal assumes Sq == Sk or
    q occupies the LAST Sq positions of the Sk key range.
    """
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    if scale is None:
        scale = d ** -0.5
    group = hq // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    rows = jnp.arange(sq)[:, None] + (sk - sq)   # absolute query positions
    cols = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= (rows - cols) < window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Grouped matmul (MoE expert FFN)
# ---------------------------------------------------------------------------
def moe_gmm_ref(x, w, group_sizes):
    """x: (T, K) tokens sorted by expert; w: (E, K, N); group_sizes: (E,).

    Returns (T, N).  Rows beyond sum(group_sizes) produce zeros.
    Python-loop oracle (group_sizes must be concrete).
    """
    import numpy as np
    sizes = np.asarray(group_sizes)
    out = jnp.zeros((x.shape[0], w.shape[-1]), dtype=x.dtype)
    start = 0
    for e, g in enumerate(sizes):
        g = int(g)
        if g == 0:
            continue
        seg = x[start:start + g].astype(jnp.float32) @ w[e].astype(jnp.float32)
        out = out.at[start:start + g].set(seg.astype(x.dtype))
        start += g
    return out


# ---------------------------------------------------------------------------
# Mamba2 SSD
# ---------------------------------------------------------------------------
def ssd_ref(x, dt, A, B, C, D=None, *, initial_state=None):
    """Naive per-step recurrence oracle for the SSD operator.

    x:  (Bb, S, H, P)     inputs (already gated/conv'd at the model level)
    dt: (Bb, S, H)        positive step sizes (softplus applied upstream)
    A:  (H,)              negative decay rates
    B:  (Bb, S, G, N)     input projections   (G groups, GQA-style)
    C:  (Bb, S, G, N)     output projections
    D:  (H,) or None      skip connection
    Returns y: (Bb, S, H, P) and final state (Bb, H, P, N).
    """
    bb, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2)  # (Bb,S,H,N)
    Ch = jnp.repeat(C, rep, axis=2)

    def step(state, inp):
        xt, dtt, bt, ct = inp          # (Bb,H,P),(Bb,H),(Bb,H,N),(Bb,H,N)
        decay = jnp.exp(dtt * A[None, :])[..., None, None]      # (Bb,H,1,1)
        upd = (dtt[..., None, None] * bt[:, :, None, :]
               * xt[..., :, None])                               # (Bb,H,P,N)
        state = state * decay + upd
        yt = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, yt

    state0 = (jnp.zeros((bb, h, p, n), dtype=jnp.float32)
              if initial_state is None else initial_state)
    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Bh, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Ch, 1, 0).astype(jnp.float32))
    state, ys = jax.lax.scan(step, state0, xs)
    y = jnp.moveaxis(ys, 0, 1)
    if D is not None:
        y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), state


def ssd_chunked_ref(x, dt, A, B, C, D=None, *, chunk=64, initial_state=None,
                    unroll=False):
    """Chunked (matmul-form) SSD — same math as ssd_ref, O(S*Q) memory.

    This is the algorithm the Pallas kernel implements; kept in jnp as the
    CPU/dry-run execution path.  ``unroll=True`` runs the chunk loop in
    python (HLO flop counts then reflect all chunks — used by the AOT
    roofline; lax.scan otherwise).
    """
    bb, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    f32 = jnp.float32

    xc = x.reshape(bb, nc, chunk, h, p).astype(f32)
    dtc = dt.reshape(bb, nc, chunk, h).astype(f32)
    Bc = jnp.repeat(B, rep, axis=2).reshape(bb, nc, chunk, h, n).astype(f32)
    Cc = jnp.repeat(C, rep, axis=2).reshape(bb, nc, chunk, h, n).astype(f32)

    def chunk_step(state, inp):
        xq, dtq, bq, cq = inp   # (Bb,Q,H,P),(Bb,Q,H),(Bb,Q,H,N),(Bb,Q,H,N)
        la = dtq * A[None, None, :]                    # log decay per step
        cum = jnp.cumsum(la, axis=1)                   # L_i (inclusive)
        # intra-chunk: M[i,j] = C_i.B_j * dt_j * exp(L_i - L_j) for j <= i
        cb = jnp.einsum("bqhn,bkhn->bhqk", cq, bq)
        dec = cum[:, :, None, :] - cum[:, None, :, :]   # (Bb,Q,K,H) L_i-L_j
        dec = jnp.moveaxis(dec, -1, 1)                  # (Bb,H,Q,K)
        iq = jnp.arange(xq.shape[1])
        causal = iq[:, None] >= iq[None, :]
        # clamp masked entries BEFORE exp: avoids 0*inf = NaN in the VJP
        dec = jnp.where(causal[None, None], dec, 0.0)
        m = cb * jnp.where(causal[None, None], jnp.exp(dec), 0.0)
        y_intra = jnp.einsum("bhqk,bkhp->bqhp", m, xq * dtq[..., None])
        # inter-chunk: y_i += C_i . (exp(L_i) * state)
        ci_dec = cq * jnp.exp(cum)[..., None]           # (Bb,Q,H,N)
        y_inter = jnp.einsum("bhpn,bqhn->bqhp", state, ci_dec)
        # state update: h' = exp(L_Q) h + sum_j exp(L_Q - L_j) dt_j B_j x_j
        tot = cum[:, -1:, :]                            # (Bb,1,H)
        w = jnp.exp(tot - cum) * dtq                    # (Bb,Q,H)
        upd = jnp.einsum("bqhn,bqhp->bhpn", bq * w[..., None], xq)
        state = state * jnp.exp(tot[:, 0, :])[..., None, None] + upd
        return state, y_intra + y_inter

    state0 = (jnp.zeros((bb, h, p, n), dtype=f32)
              if initial_state is None else initial_state)
    if unroll:
        state, ys = state0, []
        for ci in range(nc):
            state, yc = chunk_step(state, (xc[:, ci], dtc[:, ci],
                                           Bc[:, ci], Cc[:, ci]))
            ys.append(yc)
        y = jnp.stack(ys, 1).reshape(bb, s, h, p)
    else:
        xs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dtc, 1, 0),
              jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0))
        state, ys = jax.lax.scan(chunk_step, state0, xs)
        y = jnp.moveaxis(ys, 0, 1).reshape(bb, s, h, p)
    if D is not None:
        y = y + x.astype(f32) * D[None, None, :, None]
    return y.astype(x.dtype), state


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rmsnorm_ref(x, w, *, eps=1e-6, weight_offset=0.0):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * (weight_offset + w.astype(jnp.float32))
    return y.astype(x.dtype)
