from repro.checkpoint.manager import (CheckpointManager,  # noqa: F401
                                      save_pytree, restore_pytree)
