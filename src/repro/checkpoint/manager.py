"""Sharded checkpointing with restore-time resharding (elastic restart).

Layout per step:
  <dir>/step_<N>/manifest.json     — pytree structure + shapes + dtypes
  <dir>/step_<N>/arrays.npz        — flat leaves (single-host; per-host
                                     shard files on a real multi-host pod)
  <dir>/step_<N>/COMMITTED         — atomic-commit marker

Restore works onto ANY mesh: leaves are loaded as host arrays and
device_put with the target sharding — so a 256-chip checkpoint restarts
on 512 chips (elastic scale-up) or on 1 CPU (debugging).  Writes happen
on a background thread (async checkpointing) and are atomic via the
COMMITTED marker: a crash mid-write leaves the previous step intact.
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


def save_pytree(tree, path: Path) -> None:
    path = Path(path)
    tmp = path.with_name(path.name + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    names, leaves, _ = _flatten_with_names(tree)
    arrays, shapes, dtypes = {}, [], []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        shapes.append(list(arr.shape))
        dtypes.append(str(arr.dtype))
        # store raw bytes: npz cannot serialise ml_dtypes (bf16 etc.)
        arrays[f"a{i}"] = arr.reshape(-1).view(np.uint8)
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {"names": names, "shapes": shapes, "dtypes": dtypes}
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    (tmp / "COMMITTED").write_text("ok")
    if path.exists():
        shutil.rmtree(path)
    tmp.rename(path)


def _load_arrays(path: Path):
    data = np.load(path / "arrays.npz")
    manifest = json.loads((path / "manifest.json").read_text())
    out = []
    for i, (shape, dtype) in enumerate(zip(manifest["shapes"],
                                           manifest["dtypes"])):
        raw = data[f"a{i}"]
        out.append(raw.view(np.dtype(dtype)).reshape(shape))
    return manifest["names"], out


def restore_pytree(template, path: Path, shardings=None):
    """Load into the structure of ``template``; place with ``shardings``
    (a matching pytree of NamedSharding) for cross-mesh resharding."""
    path = Path(path)
    if not (path / "COMMITTED").exists():
        raise FileNotFoundError(f"checkpoint {path} not committed")
    names, t_leaves, treedef = _flatten_with_names(template)
    _, loaded = _load_arrays(path)
    for name, arr, tmpl in zip(names, loaded, t_leaves):
        if tuple(arr.shape) != tuple(np.shape(tmpl)):
            raise ValueError(f"shape mismatch at {name}: "
                             f"{arr.shape} vs {np.shape(tmpl)}")
    if shardings is not None:
        s_leaves = treedef.flatten_up_to(shardings)
        loaded = [jax.device_put(a.astype(np.asarray(t).dtype), s)
                  for a, t, s in zip(loaded, t_leaves, s_leaves)]
    else:
        loaded = [jax.numpy.asarray(a).astype(np.asarray(t).dtype)
                  for a, t in zip(loaded, t_leaves)]
    return treedef.unflatten(loaded)


class CheckpointManager:
    """Async, atomic, retention-managed checkpointing."""

    def __init__(self, directory, keep: int = 3, async_write: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> Path:
        return self.dir / f"step_{step:08d}"

    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "COMMITTED").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------
    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        """Snapshot to host memory NOW, write in the background."""
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        payload = {"state": host_tree, "extra": extra or {}}

        def _write():
            save_pytree(payload, self._step_dir(step))
            self._gc()

        self.wait()
        if self.async_write:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def restore(self, step: int, template: Any, shardings=None):
        path = self._step_dir(step)
        if not (path / "COMMITTED").exists():
            raise FileNotFoundError(f"checkpoint {path} not committed")
        names, loaded = _load_arrays(path)
        extra = {}
        state_arrays = []
        t_names, t_leaves, treedef = _flatten_with_names(template)
        for nm, arr in zip(names, loaded):
            if nm.startswith("['state']"):
                state_arrays.append(arr)
            else:
                extra[nm] = arr
        if shardings is not None:
            s_leaves = treedef.flatten_up_to(shardings)
            placed = [jax.device_put(a.astype(np.asarray(t).dtype), s)
                      for a, t, s in zip(state_arrays, t_leaves, s_leaves)]
        else:
            placed = [jax.numpy.asarray(a).astype(np.asarray(t).dtype)
                      for a, t in zip(state_arrays, t_leaves)]
        return treedef.unflatten(placed), extra

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
