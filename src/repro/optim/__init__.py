from repro.optim.adamw import (AdamWState, adamw_init, adamw_update,  # noqa
                               cosine_schedule, clip_by_global_norm)
from repro.optim.compression import (compress_int8, decompress_int8,  # noqa
                                     ef_compress_update)
