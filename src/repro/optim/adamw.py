"""Pure-JAX AdamW + schedules + clipping (no optax dependency)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gnorm


def adamw_update(params, grads, state: AdamWState, lr_fn,
                 *, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
                 max_grad_norm=1.0):
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    lr = lr_fn(step)
    b1t = 1.0 - b1 ** step.astype(jnp.float32)
    b2t = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        mh = m / b1t
        vh = v / b2t
        new_p = p.astype(jnp.float32) - lr * (
            mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    outs = [upd(p, g, m, v) for p, g, m, v in
            zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in outs])
    new_m = tdef.unflatten([o[1] for o in outs])
    new_v = tdef.unflatten([o[2] for o in outs])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), \
        {"lr": lr, "grad_norm": gnorm}
