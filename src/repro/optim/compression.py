"""Gradient compression with error feedback (int8 row-scaled).

Distributed-optimisation option for bandwidth-starved DP rings: gradients
are quantised to int8 with per-row fp32 scales before the all-reduce
(4x byte reduction — ChipLight's DP traffic term shrinks accordingly; see
benchmarks/fig8), and the quantisation residual is fed back into the next
step (error feedback keeps convergence).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(g):
    """-> (int8 values, fp32 scales) with per-last-dim-row scaling."""
    g32 = g.astype(jnp.float32)
    flat = g32.reshape(-1, g32.shape[-1]) if g32.ndim > 1 \
        else g32.reshape(1, -1)
    scale = jnp.max(jnp.abs(flat), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale, shape):
    return (q.astype(jnp.float32) * scale).reshape(shape)


def ef_compress_update(grads, error_state):
    """Apply error-feedback compression to a gradient pytree.

    Returns (decompressed grads as would exit the all-reduce,
    new error state).  error_state is a pytree like grads (fp32).
    """
    if error_state is None:
        error_state = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = compress_int8(corrected)
        deq = decompress_int8(q, s, corrected.shape)
        return deq.astype(g.dtype), corrected - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(error_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in outs]), \
        tdef.unflatten([o[1] for o in outs])
