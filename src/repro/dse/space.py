"""Design-space definition + grid enumeration in structure-of-arrays form.

The DSE engine operates on *batches* of design points.  A design point is
(parallelism strategy, MCM architecture, fabric); strategies are held as
``StrategyBatch`` — one int64 numpy array per degree — so the batched
simulator (``repro.dse.batched_sim``) can evaluate thousands of points
with a handful of vectorized array ops instead of one Python call each.

``enumerate_strategy_batch`` reproduces exactly the candidate set of
``core.optimizer.enumerate_strategies`` (same constraints, same order)
but builds it with a meshgrid + vectorized filters.  ``DesignSpace``
composes that with an MCM-variant and fabric grid for full cross-layer
sweeps (see DESIGN.md).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.hardware import HW, DEFAULT_HW
from repro.core.mcm import MCMArch, mcm_from_compute
from repro.core.traffic import PARALLELISMS, Strategy
from repro.core.workload import Workload

# canonical parallelism axis order for all (B, 5) arrays in repro.dse
P_ORDER = PARALLELISMS          # ("TP", "DP", "PP", "CP", "EP")
P_IDX = {p: i for i, p in enumerate(P_ORDER)}

FABRICS = ("oi", "ib", "nvlink")

# Pipeline-schedule search axis: interleave depths tried per schedule
# when the schedule is a search dimension (the event re-rank stage and
# the outer search's per-round replay).  Depths are requests — the
# compiler clamps per row to min(layers_per_stage, n_micro), and
# duplicate clamped candidates cost one extra vectorized pass, not a
# per-record walk.
SCHEDULE_V = {"gpipe": (1,), "1f1b": (1,), "interleaved": (2, 4)}


def schedule_axis(schedules: Sequence[str]
                  ) -> Tuple[Tuple[str, int], ...]:
    """Expand schedule names to (schedule, virtual_chunks) candidates."""
    return tuple((s, v) for s in schedules
                 for v in SCHEDULE_V.get(s, (1,)))


# ---------------------------------------------------------------------------
# Strategy batches (SoA)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class StrategyBatch:
    """Structure-of-arrays batch of parallelism strategies (int64, (B,))."""

    tp: np.ndarray
    dp: np.ndarray
    pp: np.ndarray
    cp: np.ndarray
    ep: np.ndarray
    n_micro: np.ndarray

    def __post_init__(self):
        for name in ("tp", "dp", "pp", "cp", "ep", "n_micro"):
            object.__setattr__(self, name,
                               np.asarray(getattr(self, name), np.int64))

    def __len__(self) -> int:
        return int(self.tp.shape[0])

    @property
    def n_devices(self) -> np.ndarray:
        return self.tp * self.dp * self.pp * self.cp * self.ep

    def degrees(self) -> np.ndarray:
        """(B, 5) degree matrix in ``P_ORDER``."""
        return np.stack([self.tp, self.dp, self.pp, self.cp, self.ep], 1)

    def take(self, idx) -> "StrategyBatch":
        idx = np.asarray(idx)
        return StrategyBatch(self.tp[idx], self.dp[idx], self.pp[idx],
                             self.cp[idx], self.ep[idx], self.n_micro[idx])

    def features(self) -> np.ndarray:
        """log2 feature matrix, matching the PRF surrogate's encoding."""
        cols = [self.tp, self.dp, self.pp, self.cp, self.ep, self.n_micro]
        return np.log2(np.maximum(np.stack(cols, 1), 1).astype(np.float64))

    def keys(self) -> List[Tuple[int, ...]]:
        """Hashable per-point strategy tuples (for the evaluation cache)."""
        cols = np.stack([self.tp, self.dp, self.pp, self.cp, self.ep,
                         self.n_micro], 1)
        return [tuple(row) for row in cols.tolist()]

    def to_strategies(self) -> List[Strategy]:
        return [Strategy(tp=int(t), dp=int(d), pp=int(p), cp=int(c),
                         ep=int(e), n_micro=int(m))
                for t, d, p, c, e, m in zip(self.tp, self.dp, self.pp,
                                            self.cp, self.ep, self.n_micro)]

    @classmethod
    def from_strategies(cls, strategies: Sequence[Strategy]
                        ) -> "StrategyBatch":
        if not strategies:
            return cls(*(np.zeros(0, np.int64) for _ in range(6)))
        return cls(np.array([s.tp for s in strategies], np.int64),
                   np.array([s.dp for s in strategies], np.int64),
                   np.array([s.pp for s in strategies], np.int64),
                   np.array([s.cp for s in strategies], np.int64),
                   np.array([s.ep for s in strategies], np.int64),
                   np.array([s.n_micro for s in strategies], np.int64))

    @classmethod
    def concat(cls, batches: Sequence["StrategyBatch"]) -> "StrategyBatch":
        return cls(*(np.concatenate([getattr(b, f) for b in batches])
                     for f in ("tp", "dp", "pp", "cp", "ep", "n_micro")))


# ---------------------------------------------------------------------------
# Strategy-grid enumeration (vectorized)
# ---------------------------------------------------------------------------
from repro.core.optimizer import _divisors  # noqa: E402  (shared helper)


# The candidate grid depends on the MCM only through (n_devices,
# dies_per_mcm) — across an MCM-variant grid at constant C, the m/cpo
# axes share one grid per die count.  The population outer search and
# the fused sweeps re-enumerate the same few grids constantly, so a
# content-keyed memo (Workload and its ModelConfig are frozen/hashable)
# turns enumeration into a dict hit.  Entries are treated as immutable.
_GRID_CACHE: Dict[tuple, StrategyBatch] = {}
_GRID_CACHE_MAX = 256


def enumerate_strategy_batch(w: Workload, mcm: MCMArch,
                             max_pp: int = 32,
                             min_layers_per_stage: int = 4,
                             mappable_only: bool = True) -> StrategyBatch:
    """SoA grid of valid strategies — same set (and nested-loop order) as
    ``core.optimizer.enumerate_strategies``, built vectorized and
    memoized per (workload, n_devices, dies_per_mcm)."""
    key = (w, mcm.n_devices, mcm.dies_per_mcm, max_pp,
           min_layers_per_stage, mappable_only)
    try:
        return _GRID_CACHE[key]
    except (KeyError, TypeError):       # TypeError: unhashable workload
        pass
    batch = _enumerate_strategy_batch(w, mcm, max_pp,
                                      min_layers_per_stage, mappable_only)
    try:
        if len(_GRID_CACHE) >= _GRID_CACHE_MAX:
            _GRID_CACHE.clear()
        _GRID_CACHE[key] = batch
    except TypeError:
        pass
    return batch


def _enumerate_strategy_batch(w: Workload, mcm: MCMArch,
                              max_pp: int = 32,
                              min_layers_per_stage: int = 4,
                              mappable_only: bool = True) -> StrategyBatch:
    n = mcm.n_devices
    dies = mcm.dies_per_mcm
    moe = w.model.moe
    divs = _divisors(n)

    tps = np.array([t for t in _divisors(dies) if w.d_model % t == 0],
                   np.int64)
    pps = np.array([p for p in divs
                    if p <= min(max_pp, w.n_layers // min_layers_per_stage)
                    or p == 1], np.int64)
    if moe is not None:
        eps = np.array([e for e in divs if moe.n_experts % e == 0], np.int64)
    else:
        eps = np.array([1], np.int64)
    cps = np.array([c for c in divs
                    if c <= 64 and w.seq_len % c == 0 and
                    (c == 1 or w.n_attn_layers > 0)], np.int64)
    if not (len(tps) and len(pps) and len(eps) and len(cps)):
        return StrategyBatch.from_strategies([])

    # meshgrid in (tp, pp, ep, cp) nested-loop order
    T, P, E, C = (g.reshape(-1) for g in
                  np.meshgrid(tps, pps, eps, cps, indexing="ij"))
    prod = T * P * E * C
    ok = n % prod == 0                       # pp|rest1, ep|rest2, cp|rest3
    T, P, E, C, prod = T[ok], P[ok], E[ok], C[ok], prod[ok]
    D = n // prod
    ok = (D <= 1) | (w.global_batch % D == 0)
    T, P, E, C, D = T[ok], P[ok], E[ok], C[ok], D[ok]

    # microbatch rule: pp>1 -> n_micro = min(4*pp, max(gb//max(dp,1),1))
    nm = np.minimum(4 * P, np.maximum(w.global_batch // np.maximum(D, 1), 1))
    nm = np.where(P > 1, nm, 1)
    ok = (P <= 1) | (nm >= P)
    batch = StrategyBatch(T[ok], D[ok], P[ok], C[ok], E[ok], nm[ok])

    if mappable_only and len(batch):
        from repro.dse.batched_sim import map_intra_batch  # lazy: no cycle
        mask, _, _ = map_intra_batch(batch, mcm)
        batch = batch.take(np.nonzero(mask)[0])
    return batch


def enumerate_space_batch(w: Workload, mcms: Sequence[MCMArch],
                          max_pp: int = 32, min_layers_per_stage: int = 4
                          ) -> Tuple[StrategyBatch, np.ndarray]:
    """Batched strategy enumeration ACROSS MCM variants: the concatenated
    grids of every variant plus a per-row variant index, for building
    custom fused ``MCMBatch`` evaluations outside ``DesignSpace`` (the
    sweep/outer paths enumerate per cell through the same memo).  Grids
    are memoized per (workload, n_devices, dies), so variants differing
    only in m/cpo share one enumeration."""
    grids = [enumerate_strategy_batch(w, m, max_pp=max_pp,
                                      min_layers_per_stage=min_layers_per_stage)
             for m in mcms]
    if not grids:
        return StrategyBatch.from_strategies([]), np.zeros(0, np.int64)
    idx = np.concatenate([np.full(len(g), i, np.int64)
                          for i, g in enumerate(grids)])
    return StrategyBatch.concat(grids), idx


# ---------------------------------------------------------------------------
# MCM-variant + fabric grid
# ---------------------------------------------------------------------------
def enumerate_mcm_grid(total_tflops: float,
                       dies_per_mcm: Sequence[int] = (8, 16, 32),
                       m: Sequence[int] = (2, 4, 6, 8, 12),
                       cpo_ratio: Sequence[float] = (0.3, 0.6, 0.9),
                       hw: HW = DEFAULT_HW) -> List[MCMArch]:
    """All feasible MCM variants at a fixed cluster-compute constant C."""
    out: List[MCMArch] = []
    seen = set()
    for d in dies_per_mcm:
        for mi in m:
            for r in cpo_ratio:
                mcm = mcm_from_compute(total_tflops, d, mi, cpo_ratio=r,
                                       hw=hw)
                key = (mcm.n_mcm, mcm.x, mcm.y, mcm.m, round(r, 6))
                if key in seen:
                    continue
                seen.add(key)
                if mcm.feasible() and mcm.total_links > 0:
                    out.append(mcm)
    return out


@dataclass(frozen=True)
class DesignSpace:
    """Full cross-layer grid: strategies x MCM variants x fabrics."""

    workload: Workload
    mcms: Tuple[MCMArch, ...]
    fabrics: Tuple[str, ...] = ("oi",)
    reuse: bool = True
    max_pp: int = 32
    min_layers_per_stage: int = 4
    # link-allocation policy on the OI fabric: "chiplight" is the
    # traffic-proportional allocator (+ dynamic reuse), "railx" the
    # uniform 50/50 two-rail-dimension baseline
    alloc_mode: str = "chiplight"

    @classmethod
    def from_compute(cls, w: Workload, total_tflops: float,
                     fabrics: Sequence[str] = ("oi",), reuse: bool = True,
                     hw: HW = DEFAULT_HW, alloc_mode: str = "chiplight",
                     **grid_kw) -> "DesignSpace":
        return cls(workload=w,
                   mcms=tuple(enumerate_mcm_grid(total_tflops, hw=hw,
                                                 **grid_kw)),
                   fabrics=tuple(fabrics), reuse=reuse,
                   alloc_mode=alloc_mode)

    def batches(self) -> Iterator[Tuple[MCMArch, str, StrategyBatch]]:
        """Yield one (mcm, fabric, StrategyBatch) slab per grid cell."""
        for mcm in self.mcms:
            batch = enumerate_strategy_batch(
                self.workload, mcm, max_pp=self.max_pp,
                min_layers_per_stage=self.min_layers_per_stage)
            if not len(batch):
                continue
            for fabric in self.fabrics:
                if fabric == "nvlink" and mcm.dies_per_mcm > 8:
                    continue        # NVLink domains cap at 8 GPUs
                yield mcm, fabric, batch

    def size(self) -> int:
        return sum(len(b) for _, _, b in self.batches())
