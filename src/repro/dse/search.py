"""Search drivers over the batched DSE engine.

All drivers share one cached batched-evaluate interface plus a
generator "stepper" core: a stepper yields arrays of candidate grid
indices and receives their metrics, so the SAME driver logic runs in
two harnesses —

  * per cell:  ``search_*`` drive one stepper against one
               ``BatchedEvaluator`` (one (workload, MCM, fabric) cell);
  * fused:     ``sweep_design_space`` drives every cell's stepper in
               lockstep and evaluates each round's candidates from ALL
               cells in one ``batched_simulate`` call per fabric
               (``MCMBatch``) — the way the exhaustive ``_sweep_fused``
               path always did, now for random/PRF/NSGA-II too.

Drivers:

  * ``search_exhaustive`` — the whole grid in one batched call;
  * ``search_random``     — uniform subsample (baseline);
  * ``search_prf_ucb``    — batched PRF surrogate + UCB acquisition
                            (the paper's black-box sampler, batched);
  * ``search_nsga2``      — NSGA-II-lite evolutionary loop (rank +
                            crowding selection, log2-space crossover /
                            mutation, nearest-valid-point repair).

``sweep_design_space`` returns the cross-layer Pareto surface over
(throughput, cost, power).  Costs there exclude the OCS component (it
needs the derived physical topology); ``refine_top_points`` re-derives
exact topologies and OCS-inclusive costs for the winners — vectorized
by default (one batched call + memoized ``derive_physical`` for all
top-K points), with the scalar oracle kept as the parity reference.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost import cluster_cost
from repro.core.hardware import HW
from repro.core.mcm import MCMArch
from repro.core.workload import Workload
from repro.dse.batched_sim import MCMBatch, batched_simulate
from repro.dse.pareto import (crowding_distance, nondominated_sort,
                              pareto_mask)
from repro.dse.space import (DesignSpace, P_IDX, P_ORDER, StrategyBatch,
                             enumerate_strategy_batch)
from repro.obs import metrics as obs_metrics
from repro.obs import span

Objective = Tuple[str, bool]          # (result field, maximize?)
DEFAULT_OBJECTIVES: Tuple[Objective, ...] = (("throughput", True),
                                             ("power", False))


# ---------------------------------------------------------------------------
# Cached batched evaluation
# ---------------------------------------------------------------------------
_RESULT_FIELDS = ("feasible", "step_time", "throughput", "mfu", "power")


class BatchedEvaluator:
    """Batched evaluate with a design-point cache for one (workload, MCM,
    fabric, reuse) cell.  ``cost`` is the topology-independent cluster
    cost of the cell (constant across strategies; OCS excluded).

    The cache is vectorized: each point's six strategy integers are
    bit-packed into one uint64 key (column widths adapt to the values
    seen, repacking when they grow), membership is one ``searchsorted``
    over the sorted cached keys, and values live in one (N, 5) float
    matrix — no per-row Python on the hit path.  If the packed widths
    ever exceed 64 bits (absurd degrees), it degrades to the exact
    dict-of-tuples path."""

    def __init__(self, w: Workload, mcm: MCMArch, fabric: str = "oi",
                 reuse: bool = True, hw: Optional[HW] = None,
                 backend: str = "numpy", alloc_mode: str = "chiplight"):
        self.w = w
        self.mcm = mcm
        self.fabric = fabric
        self.reuse = reuse
        self.hw = hw or mcm.hw
        self.backend = backend
        self.alloc_mode = alloc_mode
        self.cost = cluster_cost(mcm, None, fabric=fabric, hw=self.hw).total
        self.n_sim = 0
        self.n_hits = 0
        self.n_fallback = 0       # rows served by the exact dict path
        self._ccols = np.zeros((0, 6), np.int64)   # raw key columns
        self._ckeys = np.zeros(0, np.uint64)       # packed, insertion order
        self._cvals = np.zeros((0, len(_RESULT_FIELDS)))
        self._corder = np.zeros(0, np.int64)       # argsort of _ckeys
        self._cmax = np.zeros(6, np.int64)         # per-column max seen
        self._shifts: Optional[np.ndarray] = None
        self._fallback: Optional[Dict[Tuple[int, ...], np.ndarray]] = None

    def stats(self) -> Dict[str, int]:
        """Bit-packed cache counters (``repro.obs`` metric names):
        ``dse.cache.sim`` simulator rows spent, ``dse.cache.hits``
        rows served from cache, ``dse.cache.fallback_rows`` rows that
        took the exact dict path (packed widths > 64 bits)."""
        return {"dse.cache.sim": self.n_sim,
                "dse.cache.hits": self.n_hits,
                "dse.cache.fallback_rows": self.n_fallback}

    # -- uint64 key packing ------------------------------------------------
    def _ensure_widths(self, cols: np.ndarray) -> bool:
        """Adapt column bit widths to ``cols``; returns False when the
        values cannot be packed (switches to the dict fallback)."""
        if len(cols) and cols.min() < 0:   # uint64 cast would wrap and
            return False                   # could collide packed keys
        mx = np.maximum(self._cmax, cols.max(0)) if len(cols) else self._cmax
        if self._shifts is not None and (mx <= self._cmax).all():
            return True
        widths = np.array([max(int(v).bit_length(), 1) for v in mx],
                          np.int64)
        if int(widths.sum()) > 64:
            return False
        self._cmax = mx
        self._shifts = np.concatenate([[0], np.cumsum(widths)[:-1]]) \
            .astype(np.uint64)
        if len(self._ccols):                       # repack under new widths
            self._ckeys = self._pack(self._ccols)
            self._corder = np.argsort(self._ckeys, kind="stable")
        return True

    def _pack(self, cols: np.ndarray) -> np.ndarray:
        key = np.zeros(len(cols), np.uint64)
        u = cols.astype(np.uint64)
        for j in range(6):
            key |= u[:, j] << self._shifts[j]
        return key

    def _lookup(self, qkeys: np.ndarray
                ) -> Tuple[np.ndarray, np.ndarray]:
        """(hit mask, cache rows for the hits) for packed query keys."""
        nk = len(self._ckeys)
        if nk == 0:
            return np.zeros(len(qkeys), bool), np.zeros(0, np.int64)
        skeys = self._ckeys[self._corder]
        pos = np.minimum(np.searchsorted(skeys, qkeys), nk - 1)
        hit = skeys[pos] == qkeys
        return hit, self._corder[pos[hit]]

    # -- evaluation --------------------------------------------------------
    def evaluate(self, batch: StrategyBatch) -> Dict[str, np.ndarray]:
        B = len(batch)
        cols = np.stack([batch.tp, batch.dp, batch.pp, batch.cp,
                         batch.ep, batch.n_micro], 1) if B else \
            np.zeros((0, 6), np.int64)
        if self._fallback is None and not self._ensure_widths(cols):
            self._to_fallback()
        if self._fallback is not None:
            return self._evaluate_fallback(batch, cols)

        out = np.empty((B, len(_RESULT_FIELDS)))
        qkeys = self._pack(cols)
        hit, rows = self._lookup(qkeys)
        nh = int(hit.sum())
        self.n_hits += nh
        if nh:
            obs_metrics.inc("dse.cache.hits", nh)
        out[hit] = self._cvals[rows]
        miss = np.nonzero(~hit)[0]
        if len(miss):
            sub = batch.take(miss)
            res = batched_simulate(self.w, sub, self.mcm, self.fabric,
                                   self.reuse, self.hw, self.backend,
                                   alloc_mode=self.alloc_mode)
            self.n_sim += len(sub)
            obs_metrics.inc("dse.cache.sim", len(sub))
            vals = np.stack([np.asarray(getattr(res, f), np.float64)
                             for f in _RESULT_FIELDS], 1)
            out[miss] = vals
            # duplicate keys inside one batch agree — keep the first
            _, first = np.unique(qkeys[miss], return_index=True)
            self._ccols = np.concatenate([self._ccols, cols[miss][first]])
            self._ckeys = np.concatenate([self._ckeys, qkeys[miss][first]])
            self._cvals = np.concatenate([self._cvals, vals[first]])
            self._corder = np.argsort(self._ckeys, kind="stable")
        return self._metrics_from(out, B)

    def _metrics_from(self, out: np.ndarray, B: int
                      ) -> Dict[str, np.ndarray]:
        m = {f: out[:, j].copy() for j, f in enumerate(_RESULT_FIELDS)}
        m["feasible"] = out[:, 0] != 0.0
        m["cost"] = np.full(B, self.cost)
        return m

    # -- exact dict path for unpackable values -----------------------------
    def _to_fallback(self):
        self._fallback = {tuple(r): self._cvals[i]
                          for i, r in enumerate(self._ccols.tolist())}

    def _evaluate_fallback(self, batch: StrategyBatch, cols: np.ndarray
                           ) -> Dict[str, np.ndarray]:
        keys = [tuple(r) for r in cols.tolist()]
        miss = [i for i, k in enumerate(keys) if k not in self._fallback]
        self.n_hits += len(keys) - len(miss)
        self.n_fallback += len(keys)
        obs_metrics.inc("dse.cache.fallback_rows", len(keys))
        if len(keys) > len(miss):
            obs_metrics.inc("dse.cache.hits", len(keys) - len(miss))
        out = np.empty((len(keys), len(_RESULT_FIELDS)))
        if miss:
            sub = batch.take(np.array(miss, np.int64))
            res = batched_simulate(self.w, sub, self.mcm, self.fabric,
                                   self.reuse, self.hw, self.backend,
                                   alloc_mode=self.alloc_mode)
            self.n_sim += len(sub)
            obs_metrics.inc("dse.cache.sim", len(sub))
            vals = np.stack([np.asarray(getattr(res, f), np.float64)
                             for f in _RESULT_FIELDS], 1)
            for j, i in enumerate(miss):
                self._fallback[keys[i]] = vals[j]
        for i, k in enumerate(keys):
            out[i] = self._fallback[k]
        return self._metrics_from(out, len(keys))


@dataclass
class SearchResult:
    """Evaluated subset of one cell's strategy grid."""

    batch: StrategyBatch                  # evaluated points
    metrics: Dict[str, np.ndarray]        # feasible/step_time/... arrays
    grid_size: int                        # full candidate-grid size
    n_sim: int                            # simulator evaluations spent
    n_cache_hits: int

    @property
    def best(self) -> Optional[int]:
        t = self.metrics["throughput"]
        if not len(t) or not self.metrics["feasible"].any():
            return None
        return int(np.argmax(t))

    def pareto_indices(self,
                       objectives: Sequence[Objective] = DEFAULT_OBJECTIVES
                       ) -> np.ndarray:
        feas = self.metrics["feasible"]
        obj = np.stack([self.metrics[f] for f, _ in objectives], 1)
        obj = np.where(feas[:, None], obj, np.nan)
        return np.nonzero(pareto_mask(obj, [m for _, m in objectives]))[0]


def _result(ev: BatchedEvaluator, grid: StrategyBatch, idx: np.ndarray
            ) -> SearchResult:
    sub = grid.take(idx)
    return SearchResult(batch=sub, metrics=ev.evaluate(sub),
                        grid_size=len(grid), n_sim=ev.n_sim,
                        n_cache_hits=ev.n_hits)


# ---------------------------------------------------------------------------
# Driver steppers — the engine-agnostic driver cores
# ---------------------------------------------------------------------------
# A stepper is a generator over ONE cell grid: it yields int64 arrays of
# candidate grid indices, receives their metrics dict via .send(), and
# returns the final evaluated index set via StopIteration.value.

def _random_indices(n: int, budget: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.sort(rng.permutation(n)[: min(budget, n)])


def _stepper_random(grid: StrategyBatch, budget: int, seed: int = 0):
    idx = _random_indices(len(grid), budget, seed)
    if len(idx):
        yield idx
    return idx


def _stepper_prf(grid: StrategyBatch, budget: int, seed: int = 0,
                 batch_size: int = 16, kappa: float = 1.0):
    """Batched PRF-UCB: random init, then acquire top-UCB *batches*."""
    from repro.core.prf import PRF
    n = len(grid)
    budget = min(budget, n)
    rng = np.random.default_rng(seed)
    feats = grid.features()
    tried = list(rng.permutation(n)[: max(min(budget // 2, n), 1)])
    m = yield np.array(tried, np.int64)
    scores = list(m["throughput"])
    while len(tried) < budget:
        rest = np.setdiff1d(np.arange(n), np.array(tried))
        if len(scores) >= 4:
            model = PRF(seed=int(rng.integers(1 << 30))).fit(
                feats[np.array(tried)], np.array(scores))
            ucb = model.ucb(feats[rest], kappa=kappa)
            order = rest[np.argsort(-ucb)]
        else:
            order = rng.permutation(rest)
        pick = order[: min(batch_size, budget - len(tried))]
        got = (yield np.asarray(pick, np.int64))["throughput"]
        tried.extend(int(i) for i in pick)
        scores.extend(got)
    return np.array(tried, np.int64)


def _stepper_nsga2(grid: StrategyBatch, pop_size: int = 32,
                   generations: int = 12, seed: int = 0,
                   objectives: Sequence[Objective] = DEFAULT_OBJECTIVES,
                   mutation_p: float = 0.3):
    """NSGA-II-lite over the valid strategy grid.

    Genomes are grid indices; crossover/mutation act in log2-degree
    space and land back on the grid via nearest-valid-point repair, so
    every individual is a real (mappable) design point.  The cache makes
    revisits free."""
    n = len(grid)
    if n == 0:
        return np.zeros(0, np.int64)
    rng = np.random.default_rng(seed)
    feats = grid.features()                      # (n, 6) log2 coords
    pop = rng.permutation(n)[: min(pop_size, n)]
    seen = set(int(i) for i in pop)
    maximize = [mx for _, mx in objectives]

    def rank_crowd(m: Dict[str, np.ndarray], k: int):
        obj = np.stack([m[f] for f, _ in objectives], 1)
        obj = np.where(np.asarray(m["feasible"], bool)[:, None], obj,
                       np.nan)
        ranks = nondominated_sort(obj, maximize)
        crowd = np.zeros(k)
        for r in np.unique(ranks):
            sel = ranks == r
            if r >= k or sel.sum() == 0:
                continue
            sub = np.nan_to_num(obj[sel], nan=-np.inf)
            crowd[sel] = crowding_distance(sub, maximize)
        return ranks, crowd

    def repair(coords: np.ndarray) -> np.ndarray:
        """Nearest valid grid point (L1 in log2 space) per child row."""
        d = np.abs(feats[None, :, :] - coords[:, None, :]).sum(-1)
        return np.argmin(d, 1)

    for _ in range(generations):
        m = yield np.asarray(pop, np.int64)
        ranks, crowd = rank_crowd(m, len(pop))

        def tourney() -> int:
            a, b = rng.integers(len(pop), size=2)
            if (ranks[a], -crowd[a]) <= (ranks[b], -crowd[b]):
                return a
            return b

        children = []
        for _ in range(len(pop)):
            pa, pb = feats[pop[tourney()]], feats[pop[tourney()]]
            mask = rng.random(feats.shape[1]) < 0.5
            child = np.where(mask, pa, pb)
            if rng.random() < mutation_p:
                j = rng.integers(feats.shape[1])
                child[j] += rng.choice([-1.0, 1.0])
            children.append(child)
        kid_idx = repair(np.stack(children))
        union = np.unique(np.concatenate([pop, kid_idx]))
        seen.update(int(i) for i in kid_idx)
        mu = yield np.asarray(union, np.int64)
        ranks_u, crowd_u = rank_crowd(mu, len(union))
        order = np.lexsort((-crowd_u, ranks_u))
        pop = union[order[: min(pop_size, len(union))]]

    return np.array(sorted(seen), np.int64)


def _drive(ev: BatchedEvaluator, grid: StrategyBatch, gen) -> SearchResult:
    """Run one stepper against one cell evaluator."""
    try:
        req = next(gen)
        while True:
            m = ev.evaluate(grid.take(np.asarray(req, np.int64)))
            req = gen.send(m)
    except StopIteration as e:
        final = np.asarray(e.value, np.int64)
    return _result(ev, grid, final)


# ---------------------------------------------------------------------------
# Per-cell drivers (public API, unchanged signatures)
# ---------------------------------------------------------------------------
def search_exhaustive(ev: BatchedEvaluator,
                      grid: Optional[StrategyBatch] = None) -> SearchResult:
    grid = grid if grid is not None else enumerate_strategy_batch(
        ev.w, ev.mcm)
    return _result(ev, grid, np.arange(len(grid)))


def search_random(ev: BatchedEvaluator, budget: int, seed: int = 0,
                  grid: Optional[StrategyBatch] = None) -> SearchResult:
    grid = grid if grid is not None else enumerate_strategy_batch(
        ev.w, ev.mcm)
    return _result(ev, grid, _random_indices(len(grid), budget, seed))


def search_prf_ucb(ev: BatchedEvaluator, budget: int, seed: int = 0,
                   batch_size: int = 16, kappa: float = 1.0,
                   grid: Optional[StrategyBatch] = None) -> SearchResult:
    grid = grid if grid is not None else enumerate_strategy_batch(
        ev.w, ev.mcm)
    return _drive(ev, grid, _stepper_prf(grid, budget, seed=seed,
                                         batch_size=batch_size,
                                         kappa=kappa))


def search_nsga2(ev: BatchedEvaluator, pop_size: int = 32,
                 generations: int = 12, seed: int = 0,
                 objectives: Sequence[Objective] = DEFAULT_OBJECTIVES,
                 mutation_p: float = 0.3,
                 grid: Optional[StrategyBatch] = None) -> SearchResult:
    grid = grid if grid is not None else enumerate_strategy_batch(
        ev.w, ev.mcm)
    if len(grid) == 0:
        return _result(ev, grid, np.arange(0))
    return _drive(ev, grid, _stepper_nsga2(grid, pop_size=pop_size,
                                           generations=generations,
                                           seed=seed, objectives=objectives,
                                           mutation_p=mutation_p))


DRIVERS: Dict[str, Callable] = {
    "exhaustive": search_exhaustive,
    "random": search_random,
    "prf": search_prf_ucb,
    "nsga2": search_nsga2,
}

_STEPPERS: Dict[str, Callable] = {
    "random": _stepper_random,
    "prf": _stepper_prf,
    "nsga2": _stepper_nsga2,
}


# ---------------------------------------------------------------------------
# Cross-layer sweep over a DesignSpace
# ---------------------------------------------------------------------------
@dataclass
class SweepResult:
    """Concatenated evaluations across every (MCM, fabric) cell."""

    space: DesignSpace
    batch: StrategyBatch
    mcm_idx: np.ndarray            # (B,) index into space.mcms
    fabric: np.ndarray             # (B,) str
    metrics: Dict[str, np.ndarray]
    n_sim: int = 0
    n_cache_hits: int = 0
    elapsed_s: float = 0.0

    def __len__(self) -> int:
        return len(self.batch)

    @property
    def best(self) -> Optional[int]:
        if not len(self) or not self.metrics["feasible"].any():
            return None
        return int(np.argmax(self.metrics["throughput"]))

    def pareto_indices(self) -> np.ndarray:
        """Non-dominated set over (throughput max, cost min, power min)."""
        feas = self.metrics["feasible"]
        obj = np.stack([self.metrics["throughput"], self.metrics["cost"],
                        self.metrics["power"]], 1)
        obj = np.where(feas[:, None], obj, np.nan)
        mask = pareto_mask(obj, [True, False, False])
        idx = np.nonzero(mask)[0]
        return idx[np.argsort(-self.metrics["throughput"][idx])]

    def describe(self, i: int) -> Dict:
        b = self.batch
        mcm = self.space.mcms[int(self.mcm_idx[i])]
        return {
            "strategy": {"TP": int(b.tp[i]), "DP": int(b.dp[i]),
                         "PP": int(b.pp[i]), "CP": int(b.cp[i]),
                         "EP": int(b.ep[i]), "n_micro": int(b.n_micro[i])},
            "mcm": {"n_mcm": mcm.n_mcm, "x": mcm.x, "y": mcm.y, "m": mcm.m,
                    "cpo_ratio": mcm.cpo_ratio},
            "fabric": str(self.fabric[i]),
            "throughput_tok_s": float(self.metrics["throughput"][i]),
            "step_time_s": float(self.metrics["step_time"][i]),
            "mfu": float(self.metrics["mfu"][i]),
            "cost_usd": float(self.metrics["cost"][i]),
            "power_w": float(self.metrics["power"][i]),
        }


def _empty_sweep(space: DesignSpace, elapsed: float) -> SweepResult:
    empty = StrategyBatch.from_strategies([])
    return SweepResult(space, empty, np.zeros(0, np.int64),
                       np.zeros(0, "<U8"),
                       {f: np.zeros(0) for f in
                        (*_RESULT_FIELDS, "cost")}, 0, 0, elapsed)


def _sweep_fused(space: DesignSpace, backend: str) -> SweepResult:
    """Exhaustive sweep as ONE batched_simulate call per fabric: the
    strategy grids of every MCM variant are concatenated and evaluated
    against an ``MCMBatch`` of per-point parameters — no per-cell
    Python, which is what makes small-grid model configs fast too."""
    import time
    t0 = time.perf_counter()
    mcm_pos = {id(m): i for i, m in enumerate(space.mcms)}
    cells = list(space.batches())
    # one batched call per (fabric, hw): a hand-built DesignSpace may
    # mix HW configs across MCM variants
    by_group: Dict[Tuple[str, int], List] = {}
    for mcm, fabric, grid in cells:
        by_group.setdefault((fabric, id(mcm.hw)), []).append((mcm, grid))
    batches, mcm_idx, fabric_col, metric_parts, n_sim = [], [], [], [], 0
    for (fabric, _), sub in by_group.items():
        batch = StrategyBatch.concat([g for _, g in sub])
        local = np.concatenate([np.full(len(g), i, np.int64)
                                for i, (_, g) in enumerate(sub)])
        mcms = [m for m, _ in sub]
        res = batched_simulate(space.workload, batch,
                               MCMBatch.from_mcms(mcms, local),
                               fabric=fabric, reuse=space.reuse,
                               hw=mcms[0].hw, backend=backend,
                               alloc_mode=space.alloc_mode)
        costs = np.array([cluster_cost(m, None, fabric=fabric,
                                       hw=m.hw).total for m in mcms])[local]
        batches.append(batch)
        mcm_idx.append(np.array([mcm_pos[id(m)] for m in mcms],
                                np.int64)[local])
        fabric_col.append(np.full(len(batch), fabric))
        metric_parts.append({**{f: np.asarray(getattr(res, f))
                                for f in _RESULT_FIELDS}, "cost": costs})
        n_sim += len(batch)
    elapsed = time.perf_counter() - t0
    if not batches:
        return _empty_sweep(space, elapsed)
    metrics = {f: np.concatenate([p[f] for p in metric_parts])
               for f in (*_RESULT_FIELDS, "cost")}
    return SweepResult(space, StrategyBatch.concat(batches),
                       np.concatenate(mcm_idx),
                       np.concatenate(fabric_col), metrics,
                       n_sim=n_sim, n_cache_hits=0, elapsed_s=elapsed)


class _FusedEvaluator:
    """Cross-cell evaluator over the concatenated grids of every
    (MCM, fabric) cell: rows are GLOBAL indices, the cache is a
    row-indexed value matrix (exact — no hashing needed), and every
    evaluate round issues one ``batched_simulate`` per fabric spanning
    all touched cells via ``MCMBatch``."""

    def __init__(self, space: DesignSpace,
                 cells: List[Tuple[int, str, StrategyBatch]],
                 backend: str = "numpy"):
        self.space = space
        self.backend = backend
        grids = [g for _, _, g in cells]
        sizes = np.array([len(g) for g in grids], np.int64)
        self.batch = StrategyBatch.concat(grids)
        self.offsets = np.concatenate([[0], np.cumsum(sizes)[:-1]]) \
            .astype(np.int64)
        cell_of = np.repeat(np.arange(len(cells)), sizes)
        self.mcm_idx = np.array([mi for mi, _, _ in cells],
                                np.int64)[cell_of]
        self.fabric_names = sorted({fb for _, fb, _ in cells})
        fcode = {f: i for i, f in enumerate(self.fabric_names)}
        self.fabric_code = np.array([fcode[fb] for _, fb, _ in cells],
                                    np.int64)[cell_of]
        self.mb = MCMBatch.from_mcms(space.mcms, self.mcm_idx)
        cost_cell: Dict[Tuple[int, str], float] = {}
        for mi, fb, _ in cells:
            if (mi, fb) not in cost_cell:
                m = space.mcms[mi]
                cost_cell[(mi, fb)] = cluster_cost(m, None, fabric=fb,
                                                   hw=m.hw).total
        self.cost = np.array([cost_cell[(mi, fb)]
                              for mi, fb, _ in cells])[cell_of]
        n = len(self.batch)
        self._have = np.zeros(n, bool)
        self._vals = np.empty((n, len(_RESULT_FIELDS)))
        # a hand-built DesignSpace may mix HW configs across MCM
        # variants — simulate per (fabric, hw) group, not per fabric
        self.hw_objs: List[HW] = []
        code_cells = []
        for mi, _, _ in cells:
            h = space.mcms[mi].hw
            for j, ho in enumerate(self.hw_objs):
                if ho is h:
                    code_cells.append(j)
                    break
            else:
                code_cells.append(len(self.hw_objs))
                self.hw_objs.append(h)
        self.hw_code = np.array(code_cells, np.int64)[cell_of]
        self.n_sim = 0
        self.n_hits = 0

    def stats(self) -> Dict[str, int]:
        """Row-indexed cache counters, same names as
        ``BatchedEvaluator.stats`` (exact cache — no fallback path)."""
        return {"dse.cache.sim": self.n_sim,
                "dse.cache.hits": self.n_hits,
                "dse.cache.fallback_rows": 0}

    def evaluate_idx(self, idx: np.ndarray) -> Dict[str, np.ndarray]:
        idx = np.asarray(idx, np.int64)
        nh = int(self._have[idx].sum())
        self.n_hits += nh
        if nh:
            obs_metrics.inc("dse.cache.hits", nh)
        miss = np.unique(idx[~self._have[idx]])
        for fc, fabric in enumerate(self.fabric_names):
            for hc, hw in enumerate(self.hw_objs):
                rows = miss[(self.fabric_code[miss] == fc)
                            & (self.hw_code[miss] == hc)]
                if not len(rows):
                    continue
                self._simulate_rows(rows, fabric, hw)
        out = {f: self._vals[idx, j].copy()
               for j, f in enumerate(_RESULT_FIELDS)}
        out["feasible"] = self._vals[idx, 0] != 0.0
        out["cost"] = self.cost[idx]
        return out

    def _simulate_rows(self, rows: np.ndarray, fabric: str, hw: HW):
        res = batched_simulate(self.space.workload,
                               self.batch.take(rows),
                               self.mb.take(rows), fabric=fabric,
                               reuse=self.space.reuse, hw=hw,
                               backend=self.backend,
                               alloc_mode=self.space.alloc_mode)
        self._vals[rows] = np.stack(
            [np.asarray(getattr(res, f), np.float64)
             for f in _RESULT_FIELDS], 1)
        self._have[rows] = True
        self.n_sim += len(rows)
        obs_metrics.inc("dse.cache.sim", len(rows))


def _sweep_with_driver(space: DesignSpace, driver: str, backend: str,
                       seed: int, **driver_kw) -> SweepResult:
    """Drive every cell's stepper in lockstep rounds; each round's
    candidate batches from ALL cells are evaluated together (one
    batched_simulate per fabric)."""
    import time
    t0 = time.perf_counter()
    mcm_pos = {id(m): i for i, m in enumerate(space.mcms)}
    cells = [(mcm_pos[id(m)], fb, g) for m, fb, g in space.batches()]
    if not cells:
        return _empty_sweep(space, time.perf_counter() - t0)
    fev = _FusedEvaluator(space, cells, backend)
    stepper = _STEPPERS[driver]
    gens: List = []
    reqs: Dict[int, np.ndarray] = {}
    finals: Dict[int, np.ndarray] = {}
    for ci, (_, _, grid) in enumerate(cells):
        kw = dict(driver_kw)
        kw.setdefault("seed", seed + ci)
        gen = stepper(grid, **kw)
        gens.append(gen)
        try:
            reqs[ci] = np.asarray(next(gen), np.int64)
        except StopIteration as e:
            finals[ci] = np.asarray(e.value, np.int64)
    n_round = 0
    while reqs:
        order = sorted(reqs)
        glob = np.concatenate([fev.offsets[ci] + reqs[ci]
                               for ci in order])
        with span("sweep.round", driver=driver, round=n_round,
                  rows=len(glob), cells=len(order)):
            m = fev.evaluate_idx(glob)
            nxt: Dict[int, np.ndarray] = {}
            pos = 0
            for ci in order:
                ln = len(reqs[ci])
                sl = {k: v[pos:pos + ln] for k, v in m.items()}
                pos += ln
                try:
                    nxt[ci] = np.asarray(gens[ci].send(sl), np.int64)
                except StopIteration as e:
                    finals[ci] = np.asarray(e.value, np.int64)
        reqs = nxt
        n_round += 1
    glob_final = np.concatenate([fev.offsets[ci] + finals[ci]
                                 for ci in range(len(cells))])
    metrics = fev.evaluate_idx(glob_final)          # all cache hits
    fabric = np.array(fev.fabric_names)[fev.fabric_code[glob_final]]
    return SweepResult(space, fev.batch.take(glob_final),
                       fev.mcm_idx[glob_final], fabric, metrics,
                       n_sim=fev.n_sim, n_cache_hits=fev.n_hits,
                       elapsed_s=time.perf_counter() - t0)


def sweep_design_space(space: DesignSpace, driver: str = "exhaustive",
                       backend: str = "numpy", seed: int = 0,
                       **driver_kw) -> SweepResult:
    """Run one driver over every (MCM, fabric) cell and concatenate.
    Every driver takes a fused cross-variant path: the exhaustive case
    is one batched call per fabric, the budgeted drivers run their
    per-cell steppers in lockstep with fused per-round evaluation."""
    if driver == "exhaustive":
        with span("sweep", driver=driver):
            return _sweep_fused(space, backend)
    if driver not in _STEPPERS:
        raise KeyError(f"unknown driver {driver!r}; known: "
                       f"{['exhaustive', *sorted(_STEPPERS)]}")
    with span("sweep", driver=driver):
        return _sweep_with_driver(space, driver, backend, seed,
                                  **driver_kw)


# ---------------------------------------------------------------------------
# Refinement: exact topologies + OCS-inclusive costs for the winners
# ---------------------------------------------------------------------------
def refine_top_points(sweep: SweepResult, top_k: int = 8,
                      method: str = "batched"):
    """Re-evaluate the best sweep points with real OI topologies and
    exact (OCS-inclusive) costs.  Returns ``core.optimizer.DesignPoint``
    objects, best-first.

    ``method="batched"`` (default) derives everything vectorized: one
    ``batched_simulate`` over all top-K rows per fabric plus the
    memoized ``derive_physical`` front-end.  ``method="scalar"`` is the
    original per-point ``evaluate_point`` loop, kept as the parity
    reference (same points, same topologies, metrics to 1e-9).  A
    ``railx`` sweep refines through the RailX oracle
    (``railx_evaluate_point``) under either method."""
    feas = np.nonzero(sweep.metrics["feasible"])[0]
    order = feas[np.argsort(-sweep.metrics["throughput"][feas])][:top_k]
    out = refine_sweep_rows(sweep, order, method=method)
    out.sort(key=lambda p: -p.throughput)
    return out


def refine_sweep_rows(sweep: SweepResult, rows, method: str = "batched"
                      ) -> List:
    """Give the given sweep rows the full scalar treatment (derived
    topology, exact OCS-inclusive cost), preserving row order; rows that
    are infeasible or whose physical rails cannot be derived are skipped
    (not reordered).  The population outer search uses this to refine
    per-variant winners in one call."""
    rows = np.asarray(rows, np.int64)
    with span("refine", rows=len(rows), method=method):
        if sweep.space.alloc_mode == "railx":
            return _refine_railx(sweep, rows)
        if method == "scalar":
            return _refine_scalar(sweep, rows)
        if method == "batched":
            return _refine_batched(sweep, rows)
    raise ValueError(f"unknown refine method {method!r}; "
                     f"use 'batched' or 'scalar'")


def refine_cell_rows(w: Workload, mcm: MCMArch, batch: StrategyBatch,
                     rows, fabric: str = "oi", reuse: bool = True,
                     hw: Optional[HW] = None,
                     method: str = "batched") -> List:
    """Vectorized scalar-treatment of ``rows`` of ONE cell's strategy
    grid (the inner search's refinement step), row order preserved."""
    import dataclasses
    hw = hw or mcm.hw
    if hw is not mcm.hw:
        mcm = dataclasses.replace(mcm, hw=hw)
    space = DesignSpace(workload=w, mcms=(mcm,), fabrics=(fabric,),
                        reuse=reuse)
    n = len(batch)
    sweep = SweepResult(space, batch, np.zeros(n, np.int64),
                        np.full(n, fabric), metrics={})
    return refine_sweep_rows(sweep, rows, method=method)


def _refine_scalar(sweep: SweepResult, order: np.ndarray) -> List:
    from repro.core.optimizer import evaluate_point   # lazy: no cycle
    out = []
    for i in order:
        mcm = sweep.space.mcms[int(sweep.mcm_idx[i])]
        s = sweep.batch.take(np.array([i])).to_strategies()[0]
        pt = evaluate_point(sweep.space.workload, s, mcm,
                            fabric=str(sweep.fabric[i]),
                            reuse=sweep.space.reuse)
        if pt is not None:
            out.append(pt)
    return out


def _refine_railx(sweep: SweepResult, order: np.ndarray) -> List:
    """RailX refinement: the scalar RailX oracle per top row (the rail
    grouping search is combinatorial; top-K is small)."""
    from repro.core.optimizer import railx_evaluate_point  # lazy: no cycle
    out = []
    for i in order:
        mcm = sweep.space.mcms[int(sweep.mcm_idx[i])]
        s = sweep.batch.take(np.array([i])).to_strategies()[0]
        pt = railx_evaluate_point(sweep.space.workload, s, mcm,
                                  reuse=sweep.space.reuse, hw=mcm.hw)
        if pt is not None:
            out.append(pt)
    return out


# ---------------------------------------------------------------------------
# Event-replay re-rank: schedule as a search dimension
# ---------------------------------------------------------------------------
def event_rerank_rows(sweep: SweepResult, rows,
                      candidates: Sequence[Tuple[str, int]],
                      backend: str = "auto") -> Dict[str, np.ndarray]:
    """Re-rank the given sweep rows by event-replay step time.

    Compiles the rows ONCE per ``(schedule, virtual_chunks)`` candidate
    through ``events.compile_batch`` (vectorized — no per-record DAG
    walks) and replays them all; each row's winner is the candidate with
    the smallest event step time.  Returns per-row arrays —
    ``step_time`` (inf where no candidate is feasible), ``schedule``,
    ``v`` (the per-row CLAMPED interleave depth of the winner),
    ``candidate`` (index into ``candidates``) — plus ``order``: row
    POSITIONS (indices into ``rows``) sorted best-first by event step
    time, which is what ``Study.run``'s ``study.event_rerank`` stage
    feeds to ``refine_sweep_rows``."""
    from repro.events import compile_batch       # lazy: no cycle
    rows = np.asarray(rows, np.int64)
    N = len(rows)
    cands = tuple(candidates)
    if not cands:
        raise ValueError("event_rerank_rows needs at least one "
                         "(schedule, virtual_chunks) candidate")
    sub = sweep.batch.take(rows)
    midx = np.asarray(sweep.mcm_idx)[rows]
    mcms = [sweep.space.mcms[int(i)] for i in midx]
    fabs = [str(f) for f in np.asarray(sweep.fabric)[rows]]
    w = sweep.space.workload
    steps = np.full((len(cands), N), np.inf)
    vs = np.ones((len(cands), N), np.int64)
    for ci, (sched, v) in enumerate(cands):
        cb = compile_batch(w, sub, mcms, fabric=fabs,
                           reuse=sweep.space.reuse, schedule=sched,
                           virtual_chunks=v)
        steps[ci] = cb.replay(backend=backend)["step_time"]
        vs[ci] = cb.v
    win = np.argmin(steps, axis=0)
    pos = np.arange(N)
    step = steps[win, pos]
    return {
        "step_time": step,
        "candidate": win,
        "schedule": np.array([cands[int(c)][0] for c in win]),
        "v": vs[win, pos],
        "order": np.argsort(step, kind="stable"),
    }


_SIM_COLS = ("feasible", "step_time", "throughput", "mfu", "t_comp",
             "t_mem", "t_coll", "exposed", "dp_exposed", "bubble",
             "reuse_active")


def _refine_batched(sweep: SweepResult, order: np.ndarray) -> List:
    """Vectorized refinement of the given sweep rows.

    Mirrors ``core.optimizer.evaluate_point`` per row: traffic, reuse
    pair, link allocation and the simulator terms come from the batched
    engine (one call per fabric, heterogeneous MCMs via ``MCMBatch``);
    physical-rail derivation goes through the memoized
    ``derive_physical`` front-end; rows whose reuse-pair topology is
    underivable fall back to the no-reuse allocation (second batched
    call), and rows with no derivable topology at all are dropped —
    exactly the scalar semantics."""
    from repro.core.network import derive_physical_batch  # lazy: no cycle
    from repro.dse.batched_sim import (allocate_links_batch,
                                       map_intra_batch, pick_reuse_pairs,
                                       traffic_volumes_batch)
    w = sweep.space.workload
    out: List = []
    if not len(order):
        return out
    fabs = [str(f) for f in np.asarray(sweep.fabric)[order]]
    hws = [sweep.space.mcms[int(sweep.mcm_idx[i])].hw for i in order]
    groups: Dict[Tuple[str, int], List[int]] = {}
    for i, (f, h) in enumerate(zip(fabs, hws)):   # per (fabric, hw) —
        groups.setdefault((f, id(h)), []).append(i)   # hw may vary in a
    for (fabric, _), posns in groups.items():         # hand-built space
        rows = order[posns]
        K = len(rows)
        sub = sweep.batch.take(rows)
        midx = np.asarray(sweep.mcm_idx[rows], np.int64)
        mcms = [sweep.space.mcms[int(i)] for i in midx]
        hw = hws[posns[0]]
        mb = MCMBatch.from_mcms(sweep.space.mcms, midx)
        res = batched_simulate(w, sub, mb, fabric=fabric,
                               reuse=sweep.space.reuse, hw=hw)
        cols = {f: np.array(getattr(res, f), copy=True)
                for f in _SIM_COLS}

        _, intra, inter = map_intra_batch(sub, mb)
        vols = traffic_volumes_batch(w, sub)
        inter_mask = (inter > 1) & (vols > 0)
        topos: List = [None] * K
        degs: List[Dict[str, int]] = [{} for _ in range(K)]
        cands: List[Optional[Tuple[str, str]]] = [None] * K
        if fabric == "oi":
            if sweep.space.reuse:
                pa, pb = pick_reuse_pairs(vols, inter_mask)
            else:
                pa = pb = np.full(K, -1, np.int64)
            alloc = allocate_links_batch(vols, inter_mask, mb.total_links,
                                         pa, pb)
            degs, allocs, pairs = _topo_inputs(inter, inter_mask, alloc,
                                               pa, pb)
            cands = list(pairs)
            topos = derive_physical_batch(list(zip(degs, allocs, pairs)),
                                          mcms, hw)
            # reuse-pair derivation failures: no-reuse allocation + sim
            fb_rows = np.array([k for k in range(K)
                                if topos[k] is None
                                and pairs[k] is not None], np.int64)
            if len(fb_rows):
                mb_fb = mb.take(fb_rows)
                none_pair = np.full(len(fb_rows), -1, np.int64)
                alloc_nr = allocate_links_batch(
                    vols[fb_rows], inter_mask[fb_rows], mb_fb.total_links,
                    none_pair, none_pair)
                d_fb, a_fb, p_fb = _topo_inputs(
                    inter[fb_rows], inter_mask[fb_rows], alloc_nr,
                    none_pair, none_pair)
                t_fb = derive_physical_batch(
                    list(zip(d_fb, a_fb, p_fb)),
                    [mcms[int(k)] for k in fb_rows], hw)
                res_nr = batched_simulate(w, sub.take(fb_rows), mb_fb,
                                          fabric=fabric, reuse=False,
                                          hw=hw)
                for j, k in enumerate(fb_rows):
                    topos[int(k)] = t_fb[j]
                    # the scalar oracle re-simulates with the no-reuse
                    # topology, so its logs see no candidate either
                    cands[int(k)] = None
                for f in _SIM_COLS:
                    cols[f][fb_rows] = np.asarray(getattr(res_nr, f))

        out.extend(_assemble_points(w, sub, mb, cols, fabric, hw, mcms,
                                    topos, degs, intra, vols, inter_mask,
                                    cands))
    return out


def _topo_inputs(inter: np.ndarray, inter_mask: np.ndarray,
                 alloc: np.ndarray, pa: np.ndarray, pb: np.ndarray
                 ) -> Tuple[List[Dict[str, int]], List[Dict[str, int]],
                            List[Optional[Tuple[str, str]]]]:
    """Per-row (inter degrees, link alloc, reuse pair) dicts, with keys
    in the scalar path's insertion order (``map_intra``'s inter dict:
    DP, PP, CP, EP) so memoized derivation tie-breaks identically."""
    K = inter.shape[0]
    inter_l = inter.tolist()
    mask_l = inter_mask.tolist()
    alloc_l = alloc.tolist()
    degs, allocs, pairs = [], [], []
    cols = [(p, P_IDX[p]) for p in ("DP", "PP", "CP", "EP")]
    for k in range(K):
        degs.append({p: int(inter_l[k][j]) for p, j in cols
                     if inter_l[k][j] > 1})
        allocs.append({p: int(alloc_l[k][j]) for p, j in cols
                       if mask_l[k][j]})
        pairs.append((P_ORDER[pa[k]], P_ORDER[pb[k]])
                     if pa[k] >= 0 else None)
    return degs, allocs, pairs


def _assemble_points(w, sub, mb, cols, fabric, hw, mcms, topos, degs,
                     intra, vols, inter_mask, cands=None) -> List:
    """Build scalar ``DesignPoint``s from the batched refinement arrays
    (breakdown / bottleneck / logs mirror ``core.simulator.simulate``)."""
    from repro.core.optimizer import DesignPoint      # lazy: no cycle
    from repro.core.simulator import SimResult
    from repro.dse.batched_sim import gemm_eff_batch, hbm_demand_batch
    K = len(sub)
    step = cols["step_time"]
    t_comp, t_mem, t_coll = cols["t_comp"], cols["t_mem"], cols["t_coll"]
    exposed, dp_exposed = cols["exposed"], cols["dp_exposed"]
    with np.errstate(invalid="ignore"):
        util = np.where(cols["feasible"], t_comp / step, 0.0)
    eff = gemm_eff_batch(w, sub, hw) if hw.model_gemm_eff \
        else np.ones(K)
    demand, _ = hbm_demand_batch(w, sub)      # same exprs as the gate
    mem_pressure = demand / np.broadcast_to(
        np.asarray(mb.hbm_capacity, np.float64), (K,))

    strategies = sub.to_strategies()
    cands = cands if cands is not None else [None] * K
    pidx = lambda pr, j: float(P_IDX[pr[j]]) if pr else -1.0
    out = []
    for k in range(K):
        if not cols["feasible"][k]:
            continue
        if topos[k] is None and degs[k]:
            continue                       # no derivable physical rails
        # collective-term key order mirrors simulate(): intra dict
        # order (TP, the packed group, DP) then inter_vols (DP/PP/CP/EP)
        order_p = [p for p in ("TP", "CP", "EP", "PP", "DP")
                   if intra[k, P_IDX[p]] > 1 and vols[k, P_IDX[p]] > 0]
        order_p += [p for p in ("DP", "PP", "CP", "EP")
                    if inter_mask[k, P_IDX[p]] and p not in order_p]
        terms = {"compute": float(t_comp[k]), "memory": float(t_mem[k]),
                 **{f"coll_{p}": float(t_coll[k, P_IDX[p]])
                    for p in order_p}}
        nop_bound = any((p == "TP" or intra[k, P_IDX[p]] > 1)
                        and t_coll[k, P_IDX[p]] > t_comp[k]
                        for p in P_ORDER)
        active = bool(cols["reuse_active"][k])
        final = cands[k] if active else None
        logs = {
            "compute_util": float(util[k]),
            "gemm_eff": float(eff[k]),
            "mem_pressure": float(mem_pressure[k]),
            "exposed_comm": float(exposed[k] + dp_exposed[k]),
            "bubble": float(cols["bubble"][k]),
            "reuse_active": float(cols["reuse_active"][k]),
            "reuse_cand_a": pidx(cands[k], 0),
            "reuse_cand_b": pidx(cands[k], 1),
            "reuse_pair_a": pidx(final, 0),
            "reuse_pair_b": pidx(final, 1),
            "reuse_gated": float(cands[k] is not None and not active),
            "reuse_paper_mode": float(hw.ocs_reuse_mode == "paper"),
            "nop_bound": float(nop_bound),
            "oi_bound": float(fabric == "oi"
                              and exposed[k] + dp_exposed[k]
                              > 0.3 * step[k]),
            "hbm_bw_bound": float(t_mem[k] > t_comp[k]),
        }
        sim = SimResult(True, step_time=float(step[k]),
                        throughput=float(cols["throughput"][k]),
                        mfu=float(cols["mfu"][k]), breakdown=terms,
                        bottleneck=max(terms, key=terms.get), logs=logs)
        cost = cluster_cost(mcms[k], topos[k], fabric=fabric, hw=hw).total
        out.append(DesignPoint(strategy=strategies[k], mcm=mcms[k],
                               topo=topos[k], sim=sim, cost=cost,
                               fabric=fabric))
    return out
