"""Search drivers over the batched DSE engine.

All drivers share one ``BatchedEvaluator`` interface — evaluate a
``StrategyBatch``, get SoA results — plus an evaluation cache keyed by
design-point hash, so revisited points (evolutionary loops, repeated
sweeps) cost nothing.  Drivers:

  * ``search_exhaustive`` — the whole grid in one batched call;
  * ``search_random``     — uniform subsample (baseline);
  * ``search_prf_ucb``    — batched PRF surrogate + UCB acquisition
                            (the paper's black-box sampler, batched);
  * ``search_nsga2``      — NSGA-II-lite evolutionary loop (rank +
                            crowding selection, log2-space crossover /
                            mutation, nearest-valid-point repair).

``sweep_design_space`` runs a driver over every (MCM, fabric) cell of a
``DesignSpace`` and returns the cross-layer Pareto surface over
(throughput, cost, power).  Costs here exclude the OCS component (it
needs the derived physical topology); ``refine_top_points`` re-evaluates
winners through the scalar oracle for exact topologies and costs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost import cluster_cost
from repro.core.hardware import HW
from repro.core.mcm import MCMArch
from repro.core.workload import Workload
from repro.dse.batched_sim import batched_simulate
from repro.dse.pareto import (crowding_distance, nondominated_sort,
                              pareto_mask)
from repro.dse.space import (DesignSpace, StrategyBatch,
                             enumerate_strategy_batch)

Objective = Tuple[str, bool]          # (result field, maximize?)
DEFAULT_OBJECTIVES: Tuple[Objective, ...] = (("throughput", True),
                                             ("power", False))


# ---------------------------------------------------------------------------
# Cached batched evaluation
# ---------------------------------------------------------------------------
_RESULT_FIELDS = ("feasible", "step_time", "throughput", "mfu", "power")


class BatchedEvaluator:
    """Batched evaluate with a design-point cache for one (workload, MCM,
    fabric, reuse) cell.  ``cost`` is the topology-independent cluster
    cost of the cell (constant across strategies; OCS excluded)."""

    def __init__(self, w: Workload, mcm: MCMArch, fabric: str = "oi",
                 reuse: bool = True, hw: Optional[HW] = None,
                 backend: str = "numpy"):
        self.w = w
        self.mcm = mcm
        self.fabric = fabric
        self.reuse = reuse
        self.hw = hw or mcm.hw
        self.backend = backend
        self.cost = cluster_cost(mcm, None, fabric=fabric, hw=self.hw).total
        self._cache: Dict[Tuple[int, ...], Tuple] = {}
        self.n_sim = 0
        self.n_hits = 0

    def evaluate(self, batch: StrategyBatch) -> Dict[str, np.ndarray]:
        keys = batch.keys()
        miss = [i for i, k in enumerate(keys) if k not in self._cache]
        self.n_hits += len(keys) - len(miss)
        if miss:
            sub = batch.take(np.array(miss, np.int64))
            res = batched_simulate(self.w, sub, self.mcm, self.fabric,
                                   self.reuse, self.hw, self.backend)
            self.n_sim += len(sub)
            cols = [np.asarray(getattr(res, f)) for f in _RESULT_FIELDS]
            for j, i in enumerate(miss):
                self._cache[keys[i]] = tuple(c[j] for c in cols)
        rows = [self._cache[k] for k in keys]
        out = {f: np.array([r[j] for r in rows])
               for j, f in enumerate(_RESULT_FIELDS)}
        out["cost"] = np.full(len(batch), self.cost)
        return out


@dataclass
class SearchResult:
    """Evaluated subset of one cell's strategy grid."""

    batch: StrategyBatch                  # evaluated points
    metrics: Dict[str, np.ndarray]        # feasible/step_time/... arrays
    grid_size: int                        # full candidate-grid size
    n_sim: int                            # simulator evaluations spent
    n_cache_hits: int

    @property
    def best(self) -> Optional[int]:
        t = self.metrics["throughput"]
        if not len(t) or not self.metrics["feasible"].any():
            return None
        return int(np.argmax(t))

    def pareto_indices(self,
                       objectives: Sequence[Objective] = DEFAULT_OBJECTIVES
                       ) -> np.ndarray:
        feas = self.metrics["feasible"]
        obj = np.stack([self.metrics[f] for f, _ in objectives], 1)
        obj = np.where(feas[:, None], obj, np.nan)
        return np.nonzero(pareto_mask(obj, [m for _, m in objectives]))[0]


def _result(ev: BatchedEvaluator, grid: StrategyBatch, idx: np.ndarray
            ) -> SearchResult:
    sub = grid.take(idx)
    return SearchResult(batch=sub, metrics=ev.evaluate(sub),
                        grid_size=len(grid), n_sim=ev.n_sim,
                        n_cache_hits=ev.n_hits)


# ---------------------------------------------------------------------------
# Drivers
# ---------------------------------------------------------------------------
def search_exhaustive(ev: BatchedEvaluator,
                      grid: Optional[StrategyBatch] = None) -> SearchResult:
    grid = grid if grid is not None else enumerate_strategy_batch(
        ev.w, ev.mcm)
    return _result(ev, grid, np.arange(len(grid)))


def search_random(ev: BatchedEvaluator, budget: int, seed: int = 0,
                  grid: Optional[StrategyBatch] = None) -> SearchResult:
    grid = grid if grid is not None else enumerate_strategy_batch(
        ev.w, ev.mcm)
    rng = np.random.default_rng(seed)
    n = len(grid)
    idx = rng.permutation(n)[: min(budget, n)]
    return _result(ev, grid, np.sort(idx))


def search_prf_ucb(ev: BatchedEvaluator, budget: int, seed: int = 0,
                   batch_size: int = 16, kappa: float = 1.0,
                   grid: Optional[StrategyBatch] = None) -> SearchResult:
    """Batched PRF-UCB: random init, then acquire top-UCB *batches*."""
    from repro.core.prf import PRF
    grid = grid if grid is not None else enumerate_strategy_batch(
        ev.w, ev.mcm)
    n = len(grid)
    budget = min(budget, n)
    rng = np.random.default_rng(seed)
    feats = grid.features()
    tried = list(rng.permutation(n)[: max(min(budget // 2, n), 1)])
    thpt = ev.evaluate(grid.take(np.array(tried)))["throughput"]
    scores = list(thpt)
    while len(tried) < budget:
        rest = np.setdiff1d(np.arange(n), np.array(tried))
        if len(scores) >= 4:
            model = PRF(seed=int(rng.integers(1 << 30))).fit(
                feats[np.array(tried)], np.array(scores))
            ucb = model.ucb(feats[rest], kappa=kappa)
            order = rest[np.argsort(-ucb)]
        else:
            order = rng.permutation(rest)
        pick = order[: min(batch_size, budget - len(tried))]
        got = ev.evaluate(grid.take(pick))["throughput"]
        tried.extend(int(i) for i in pick)
        scores.extend(got)
    return _result(ev, grid, np.array(tried))


def search_nsga2(ev: BatchedEvaluator, pop_size: int = 32,
                 generations: int = 12, seed: int = 0,
                 objectives: Sequence[Objective] = DEFAULT_OBJECTIVES,
                 mutation_p: float = 0.3,
                 grid: Optional[StrategyBatch] = None) -> SearchResult:
    """NSGA-II-lite over the valid strategy grid.

    Genomes are grid indices; crossover/mutation act in log2-degree
    space and land back on the grid via nearest-valid-point repair, so
    every individual is a real (mappable) design point.  The cache makes
    revisits free."""
    grid = grid if grid is not None else enumerate_strategy_batch(
        ev.w, ev.mcm)
    n = len(grid)
    if n == 0:
        return _result(ev, grid, np.arange(0))
    rng = np.random.default_rng(seed)
    feats = grid.features()                      # (n, 6) log2 coords
    pop = rng.permutation(n)[: min(pop_size, n)]
    seen = set(int(i) for i in pop)

    def rank_crowd(idx: np.ndarray):
        m = ev.evaluate(grid.take(idx))
        obj = np.stack([m[f] for f, _ in objectives], 1)
        obj = np.where(m["feasible"][:, None], obj, np.nan)
        maximize = [mx for _, mx in objectives]
        ranks = nondominated_sort(obj, maximize)
        crowd = np.zeros(len(idx))
        for r in np.unique(ranks):
            sel = ranks == r
            if r >= len(idx) or sel.sum() == 0:
                continue
            sub = np.nan_to_num(obj[sel], nan=-np.inf)
            crowd[sel] = crowding_distance(sub, maximize)
        return ranks, crowd

    def repair(coords: np.ndarray) -> np.ndarray:
        """Nearest valid grid point (L1 in log2 space) per child row."""
        d = np.abs(feats[None, :, :] - coords[:, None, :]).sum(-1)
        return np.argmin(d, 1)

    for _ in range(generations):
        ranks, crowd = rank_crowd(pop)

        def tourney() -> int:
            a, b = rng.integers(len(pop), size=2)
            if (ranks[a], -crowd[a]) <= (ranks[b], -crowd[b]):
                return a
            return b

        children = []
        for _ in range(len(pop)):
            pa, pb = feats[pop[tourney()]], feats[pop[tourney()]]
            mask = rng.random(feats.shape[1]) < 0.5
            child = np.where(mask, pa, pb)
            if rng.random() < mutation_p:
                j = rng.integers(feats.shape[1])
                child[j] += rng.choice([-1.0, 1.0])
            children.append(child)
        kid_idx = repair(np.stack(children))
        union = np.unique(np.concatenate([pop, kid_idx]))
        seen.update(int(i) for i in kid_idx)
        ranks_u, crowd_u = rank_crowd(union)
        order = np.lexsort((-crowd_u, ranks_u))
        pop = union[order[: min(pop_size, len(union))]]

    return _result(ev, grid, np.array(sorted(seen), np.int64))


DRIVERS: Dict[str, Callable] = {
    "exhaustive": search_exhaustive,
    "random": search_random,
    "prf": search_prf_ucb,
    "nsga2": search_nsga2,
}


# ---------------------------------------------------------------------------
# Cross-layer sweep over a DesignSpace
# ---------------------------------------------------------------------------
@dataclass
class SweepResult:
    """Concatenated evaluations across every (MCM, fabric) cell."""

    space: DesignSpace
    batch: StrategyBatch
    mcm_idx: np.ndarray            # (B,) index into space.mcms
    fabric: np.ndarray             # (B,) str
    metrics: Dict[str, np.ndarray]
    n_sim: int = 0
    n_cache_hits: int = 0
    elapsed_s: float = 0.0

    def __len__(self) -> int:
        return len(self.batch)

    @property
    def best(self) -> Optional[int]:
        if not len(self) or not self.metrics["feasible"].any():
            return None
        return int(np.argmax(self.metrics["throughput"]))

    def pareto_indices(self) -> np.ndarray:
        """Non-dominated set over (throughput max, cost min, power min)."""
        feas = self.metrics["feasible"]
        obj = np.stack([self.metrics["throughput"], self.metrics["cost"],
                        self.metrics["power"]], 1)
        obj = np.where(feas[:, None], obj, np.nan)
        mask = pareto_mask(obj, [True, False, False])
        idx = np.nonzero(mask)[0]
        return idx[np.argsort(-self.metrics["throughput"][idx])]

    def describe(self, i: int) -> Dict:
        b = self.batch
        mcm = self.space.mcms[int(self.mcm_idx[i])]
        return {
            "strategy": {"TP": int(b.tp[i]), "DP": int(b.dp[i]),
                         "PP": int(b.pp[i]), "CP": int(b.cp[i]),
                         "EP": int(b.ep[i]), "n_micro": int(b.n_micro[i])},
            "mcm": {"n_mcm": mcm.n_mcm, "x": mcm.x, "y": mcm.y, "m": mcm.m,
                    "cpo_ratio": mcm.cpo_ratio},
            "fabric": str(self.fabric[i]),
            "throughput_tok_s": float(self.metrics["throughput"][i]),
            "step_time_s": float(self.metrics["step_time"][i]),
            "mfu": float(self.metrics["mfu"][i]),
            "cost_usd": float(self.metrics["cost"][i]),
            "power_w": float(self.metrics["power"][i]),
        }


def _sweep_fused(space: DesignSpace, backend: str) -> SweepResult:
    """Exhaustive sweep as ONE batched_simulate call per fabric: the
    strategy grids of every MCM variant are concatenated and evaluated
    against an ``MCMBatch`` of per-point parameters — no per-cell
    Python, which is what makes small-grid model configs fast too."""
    import time
    from repro.dse.batched_sim import MCMBatch
    t0 = time.perf_counter()
    cells = list(space.batches())
    by_fabric: Dict[str, List] = {}
    for mcm, fabric, grid in cells:
        by_fabric.setdefault(fabric, []).append((mcm, grid))
    batches, mcm_idx, fabric_col, metric_parts, n_sim = [], [], [], [], 0
    for fabric, sub in by_fabric.items():
        batch = StrategyBatch.concat([g for _, g in sub])
        local = np.concatenate([np.full(len(g), i, np.int64)
                                for i, (_, g) in enumerate(sub)])
        mcms = [m for m, _ in sub]
        res = batched_simulate(space.workload, batch,
                               MCMBatch.from_mcms(mcms, local),
                               fabric=fabric, reuse=space.reuse,
                               hw=mcms[0].hw, backend=backend)
        costs = np.array([cluster_cost(m, None, fabric=fabric,
                                       hw=m.hw).total for m in mcms])[local]
        batches.append(batch)
        mcm_idx.append(np.array([space.mcms.index(m) for m in mcms],
                                np.int64)[local])
        fabric_col.append(np.full(len(batch), fabric))
        metric_parts.append({**{f: np.asarray(getattr(res, f))
                                for f in _RESULT_FIELDS}, "cost": costs})
        n_sim += len(batch)
    elapsed = time.perf_counter() - t0
    if not batches:
        empty = StrategyBatch.from_strategies([])
        return SweepResult(space, empty, np.zeros(0, np.int64),
                           np.zeros(0, "<U8"),
                           {f: np.zeros(0) for f in
                            (*_RESULT_FIELDS, "cost")}, 0, 0, elapsed)
    metrics = {f: np.concatenate([p[f] for p in metric_parts])
               for f in (*_RESULT_FIELDS, "cost")}
    return SweepResult(space, StrategyBatch.concat(batches),
                       np.concatenate(mcm_idx),
                       np.concatenate(fabric_col), metrics,
                       n_sim=n_sim, n_cache_hits=0, elapsed_s=elapsed)


def sweep_design_space(space: DesignSpace, driver: str = "exhaustive",
                       backend: str = "numpy", seed: int = 0,
                       **driver_kw) -> SweepResult:
    """Run one driver over every (MCM, fabric) cell and concatenate.
    The exhaustive driver takes the fused cross-variant path (one
    batched call per fabric)."""
    import time
    if driver == "exhaustive":
        return _sweep_fused(space, backend)
    run = DRIVERS[driver]
    t0 = time.perf_counter()
    parts: List[Tuple[int, str, SearchResult]] = []
    for ci, (mcm, fabric, grid) in enumerate(space.batches()):
        ev = BatchedEvaluator(space.workload, mcm, fabric, space.reuse,
                              backend=backend)
        kw = dict(driver_kw)
        kw.setdefault("seed", seed + ci)
        res = run(ev, grid=grid, **kw)
        mi = space.mcms.index(mcm)
        parts.append((mi, fabric, res))
    elapsed = time.perf_counter() - t0
    if not parts:
        empty = StrategyBatch.from_strategies([])
        return SweepResult(space, empty, np.zeros(0, np.int64),
                           np.zeros(0, "<U8"),
                           {f: np.zeros(0) for f in
                            (*_RESULT_FIELDS, "cost")}, 0, 0, elapsed)
    batch = StrategyBatch.concat([r.batch for _, _, r in parts])
    mcm_idx = np.concatenate([np.full(len(r.batch), mi, np.int64)
                              for mi, _, r in parts])
    fabric = np.concatenate([np.full(len(r.batch), fb)
                             for _, fb, r in parts])
    metrics = {f: np.concatenate([r.metrics[f] for _, _, r in parts])
               for f in (*_RESULT_FIELDS, "cost")}
    return SweepResult(space, batch, mcm_idx, fabric, metrics,
                       n_sim=sum(r.n_sim for _, _, r in parts),
                       n_cache_hits=sum(r.n_cache_hits for _, _, r in parts),
                       elapsed_s=elapsed)


def refine_top_points(sweep: SweepResult, top_k: int = 8):
    """Re-evaluate the best sweep points through the scalar oracle —
    derives real OI topologies and exact (OCS-inclusive) costs.
    Returns core.optimizer.DesignPoint objects, best-first."""
    from repro.core.optimizer import evaluate_point   # lazy: no cycle
    feas = np.nonzero(sweep.metrics["feasible"])[0]
    order = feas[np.argsort(-sweep.metrics["throughput"][feas])][:top_k]
    out = []
    for i in order:
        mcm = sweep.space.mcms[int(sweep.mcm_idx[i])]
        s = sweep.batch.take(np.array([i])).to_strategies()[0]
        pt = evaluate_point(sweep.space.workload, s, mcm,
                            fabric=str(sweep.fabric[i]),
                            reuse=sweep.space.reuse)
        if pt is not None:
            out.append(pt)
    out.sort(key=lambda p: -p.throughput)
    return out
