# Vectorized batched design-space exploration (see DESIGN.md §repro.dse).
# Evaluates thousands of (strategy, MCM, fabric) points per call via the
# SoA port of core.simulator.simulate; the scalar simulator is the oracle.
from repro.dse.space import (DesignSpace, StrategyBatch, FABRICS,  # noqa: F401
                             P_ORDER, P_IDX, enumerate_mcm_grid,
                             enumerate_space_batch,
                             enumerate_strategy_batch)
from repro.dse.batched_sim import (BatchedSimResult,  # noqa: F401
                                   batched_simulate, map_intra_batch,
                                   traffic_volumes_batch,
                                   allocate_links_batch,
                                   allocate_links_railx_batch)
from repro.dse.pareto import (crowding_distance, nondominated_sort,  # noqa: F401
                              pareto_front_indices, pareto_mask)
from repro.dse.search import (DRIVERS, BatchedEvaluator,  # noqa: F401
                              SearchResult, SweepResult, refine_cell_rows,
                              refine_sweep_rows, refine_top_points,
                              search_exhaustive, search_nsga2,
                              search_prf_ucb, search_random,
                              sweep_design_space)
from repro.dse.outer import (VariantEval, mcm_variant_key,  # noqa: F401
                             outer_search)
