"""DEPRECATED CLI shim — use ``python -m repro.cli`` instead.

The old batched-DSE CLI (``python -m repro.dse.run --model ... --C ...``)
is subsumed by the unified scenario CLI; every flag it accepted is still
accepted there.  This shim keeps old invocations working: it emits a
``DeprecationWarning`` and forwards the argv unchanged, so it produces
exactly what ``repro.cli.main`` produces for the same argv.  One default
changed with the new surface: scalar refinement of the top points is now
ON by default (``--refine-top``, legacy ``--refine`` still maps to
refining the top ``--top`` points); artifacts are per-study
``StudyResult`` JSON instead of the old sweep list.
"""
from __future__ import annotations

import sys
import warnings


def main(argv=None) -> int:
    warnings.warn(
        "repro.dse.run is deprecated; use `python -m repro.cli` "
        "(same flags, plus scenario JSON files)", DeprecationWarning,
        stacklevel=2)
    from repro import cli
    return cli.main(argv)


if __name__ == "__main__":
    sys.exit(main())
