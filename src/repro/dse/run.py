"""CLI for the batched DSE engine.

    PYTHONPATH=src python -m repro.dse.run --model qwen3_moe_235b_a22b \
        --C 4e6 --fabrics oi,ib --driver exhaustive --top 5

Sweeps the full (strategy x MCM-variant x fabric) grid at a cluster
compute constant C, prints the best points + Pareto surface and writes a
JSON artifact.  ``--model all`` sweeps every config in the model zoo.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

from repro.core.workload import Workload
from repro.dse.search import refine_top_points, sweep_design_space
from repro.dse.space import DesignSpace


def _sweep_one(name: str, args) -> dict:
    from repro.configs import get_config
    cfg = get_config(name)
    w = Workload(model=cfg, seq_len=args.seq_len,
                 global_batch=args.global_batch)
    space = DesignSpace.from_compute(
        w, args.C, fabrics=tuple(args.fabrics.split(",")),
        reuse=not args.no_reuse,
        dies_per_mcm=tuple(int(x) for x in args.dies.split(",")),
        m=tuple(int(x) for x in args.m.split(",")),
        cpo_ratio=tuple(float(x) for x in args.cpo.split(",")))
    kw = {}
    if args.driver in ("random", "prf"):
        kw["budget"] = args.budget
    elif args.driver == "nsga2":
        kw["pop_size"] = min(args.budget, 64)
        kw["generations"] = args.generations
    sweep = sweep_design_space(space, driver=args.driver,
                               backend=args.backend, seed=args.seed, **kw)
    n = len(sweep)
    rate = sweep.n_sim / sweep.elapsed_s if sweep.elapsed_s else 0.0
    print(f"\n=== {name}: {n} points evaluated "
          f"({sweep.n_sim} sim / {sweep.n_cache_hits} cached) in "
          f"{sweep.elapsed_s:.2f}s — {rate:,.0f} points/s ===")
    best = sweep.best
    pareto = sweep.pareto_indices()
    out = {"model": name, "C_tflops": args.C, "driver": args.driver,
           "evaluated": int(n), "sim_calls": int(sweep.n_sim),
           "points_per_s": rate,
           "best": sweep.describe(best) if best is not None else None,
           "pareto": [sweep.describe(int(i)) for i in pareto[:args.top * 4]]}
    if best is not None:
        feas = np.nonzero(sweep.metrics["feasible"])[0]
        order = feas[np.argsort(-sweep.metrics["throughput"][feas])]
        for i in order[: args.top]:
            d = sweep.describe(int(i))
            print(f"  {d['throughput_tok_s']:.3e} tok/s  mfu={d['mfu']:.2f}"
                  f"  ${d['cost_usd'] / 1e6:7.1f}M {d['power_w'] / 1e6:5.2f}MW"
                  f"  {d['fabric']:6s} m={d['mcm']['m']:<2d}"
                  f" r={d['mcm']['cpo_ratio']:.1f} {d['strategy']}")
        print(f"  pareto surface: {len(pareto)} non-dominated points")
        if args.refine:
            pts = refine_top_points(sweep, top_k=args.top)
            for p in pts:
                print(f"  refined: {p.throughput:.3e} tok/s  "
                      f"${p.cost / 1e6:.1f}M  (exact topo/OCS cost)")
            out["refined"] = [
                {"throughput_tok_s": p.throughput, "cost_usd": p.cost}
                for p in pts]
    else:
        print("  no feasible point")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="qwen3_moe_235b_a22b",
                    help="config name, or 'all' for the whole zoo")
    ap.add_argument("--C", type=float, default=4e6,
                    help="total cluster compute, TFLOPS")
    ap.add_argument("--seq-len", type=int, default=10240)
    ap.add_argument("--global-batch", type=int, default=512)
    ap.add_argument("--fabrics", default="oi")
    ap.add_argument("--dies", default="8,16,32")
    ap.add_argument("--m", default="2,4,6,8,12")
    ap.add_argument("--cpo", default="0.3,0.6,0.9")
    ap.add_argument("--driver", default="exhaustive",
                    choices=("exhaustive", "random", "prf", "nsga2"))
    ap.add_argument("--budget", type=int, default=256,
                    help="per-cell budget for non-exhaustive drivers")
    ap.add_argument("--generations", type=int, default=12)
    ap.add_argument("--backend", default="numpy",
                    choices=("numpy", "jax"))
    ap.add_argument("--no-reuse", action="store_true")
    ap.add_argument("--refine", action="store_true",
                    help="scalar-oracle refinement of the top points")
    ap.add_argument("--top", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="artifacts/dse/sweep.json")
    args = ap.parse_args(argv)

    if args.model == "all":
        from repro.configs import ARCH_IDS
        names = list(ARCH_IDS)
    else:
        names = [args.model]
    results = [_sweep_one(n, args) for n in names]

    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(results, indent=2))
    print(f"\nwrote {out_path}")


if __name__ == "__main__":
    main()
