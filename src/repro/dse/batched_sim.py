"""Vectorized (SoA) port of ``core.simulator.simulate``.

Evaluates a whole ``StrategyBatch`` on one (MCM, fabric) cell with a
fixed number of numpy array ops — no per-point Python.  Parity contract:
for every point i, ``batched_simulate(w, batch, mcm, fabric, reuse,
hw)`` reproduces ``simulate(w, batch[i], mcm, fabric, topo=None, reuse,
hw)`` — same feasibility mask, same step time (float64, same operation
order; checked element-wise to 1e-9 rel in tests/test_dse.py).  The
scalar simulator remains the oracle; this module is the hot path.

Two backends for the compute/collective cost terms:
  * ``numpy``  (default) — straight float64 array math;
  * ``jax``    — the same term function run through jax.vmap + jit
                 under x64, for accelerator offload of very large grids.
                 Compiled functions are cached per (fabric, hw,
                 workload scalars) AND per shape bucket: batches are
                 edge-padded to the next power of two, so sweeping
                 grids of varying size re-traces only when a new bucket
                 appears, not on every call.
  * ``auto``   — ``jax`` when available and the batch clears
                 ``JAX_AUTO_MIN_BATCH`` rows (where vmap+jit wins over
                 plain numpy), else ``numpy``.

The integer/combinatorial stages (intra-MCM packing, link allocation,
reuse-pair choice) always run in numpy: they are data-dependent control
flow that a vmap would serialize anyway.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.hardware import HW
from repro.core.mcm import MCMArch
from repro.core.workload import Workload
from repro.dse.space import P_IDX, StrategyBatch
from repro.obs import metrics as obs_metrics


@dataclass(frozen=True)
class MCMBatch:
    """Per-design-point MCM parameters (SoA) — lets ONE batched_simulate
    call span heterogeneous MCM variants (the cross-cell fused sweep).

    For a homogeneous batch just pass an ``MCMArch``; everything here is
    scalar-broadcast from its properties, so results are bit-identical
    either way.
    """

    dies_per_mcm: np.ndarray      # (B,) int
    n_devices: np.ndarray         # (B,) int
    n_mcm: np.ndarray             # (B,) int
    m: np.ndarray                 # (B,) int HBM stacks per die
    hbm_bw: np.ndarray            # (B,) B/s per die
    hbm_capacity: np.ndarray      # (B,) bytes per die
    nop_bw: np.ndarray            # (B,) B/s per D2D link
    total_links: np.ndarray       # (B,) optical links per MCM
    die_flops: np.ndarray         # (B,) FLOP/s per die

    _FIELDS = ("dies_per_mcm", "n_devices", "n_mcm", "m", "hbm_bw",
               "hbm_capacity", "nop_bw", "total_links", "die_flops")

    def __len__(self) -> int:
        return int(self.dies_per_mcm.shape[0])

    def take(self, idx) -> "MCMBatch":
        if np.ndim(self.dies_per_mcm) == 0:      # scalar pseudo-batch
            return self
        return MCMBatch(*(getattr(self, f)[idx] for f in self._FIELDS))

    @classmethod
    def from_mcms(cls, mcms, idx: np.ndarray) -> "MCMBatch":
        """Gather per-point parameters: point i uses mcms[idx[i]]."""
        idx = np.asarray(idx, np.int64)
        def g(fn, dtype):
            vals = np.array([fn(m) for m in mcms], dtype)
            return vals[idx]
        return cls(
            dies_per_mcm=g(lambda m: m.dies_per_mcm, np.int64),
            n_devices=g(lambda m: m.n_devices, np.int64),
            n_mcm=g(lambda m: m.n_mcm, np.int64),
            m=g(lambda m: m.m, np.int64),
            hbm_bw=g(lambda m: m.hbm_bw, np.float64),
            hbm_capacity=g(lambda m: m.hbm_capacity, np.float64),
            nop_bw=g(lambda m: m.nop_bw, np.float64),
            total_links=g(lambda m: m.total_links, np.int64),
            die_flops=g(lambda m: m.die_flops, np.float64))


def _mcm_params(mcm) -> "MCMBatch":
    """Normalize MCMArch -> scalar-field pseudo-batch (broadcasts)."""
    if isinstance(mcm, MCMBatch):
        return mcm
    return MCMBatch(
        dies_per_mcm=np.int64(mcm.dies_per_mcm),
        n_devices=np.int64(mcm.n_devices),
        n_mcm=np.int64(mcm.n_mcm),
        m=np.int64(mcm.m),
        hbm_bw=np.float64(mcm.hbm_bw),
        hbm_capacity=np.float64(mcm.hbm_capacity),
        nop_bw=np.float64(mcm.nop_bw),
        total_links=np.int64(mcm.total_links),
        die_flops=np.float64(mcm.die_flops))

# reuse-pair candidates, in ``reusable_pairs`` candidate order
_REUSE_CANDS = (("CP", "EP"), ("CP", "DP"), ("EP", "DP"), ("PP", "DP"))

# simple board-power model for the Pareto objective (documented in
# DESIGN.md): static die/HBM/optics power + utilisation-scaled dynamic
DIE_IDLE_W = 150.0          # leakage + uncore per logic die
DIE_DYN_W = 550.0           # dynamic at full compute utilisation
HBM_W_PER_STACK = 30.0
OI_W_PER_LINK = 15.0        # CPO 400G port, both ends + laser
NIC_W_PER_DEV = 25.0        # IB NIC (electrical fabrics)


def board_power(mcm, fabric: str, util: float) -> float:
    """Scalar board power for one MCMArch at the given compute
    utilisation — the same model the batched path applies element-wise,
    so refined (scalar-oracle) records stay comparable to sweep rows."""
    n_dev = mcm.n_devices
    power = n_dev * (DIE_IDLE_W + DIE_DYN_W * util) \
        + n_dev * mcm.m * HBM_W_PER_STACK
    if fabric == "oi":
        return power + mcm.n_mcm * mcm.total_links * OI_W_PER_LINK
    return power + n_dev * NIC_W_PER_DEV

# infeasibility reason codes
OK, BAD_DEVICES, UNMAPPABLE, HBM_CAPACITY = 0, 1, 2, 3
REASONS = {OK: "", BAD_DEVICES: "strategy devices != cluster",
           UNMAPPABLE: "unmappable intra-MCM packing",
           HBM_CAPACITY: "HBM capacity"}


@dataclass(frozen=True)
class BatchedSimResult:
    """SoA mirror of a list of ``SimResult`` (arrays over the batch)."""

    feasible: np.ndarray        # (B,) bool
    step_time: np.ndarray       # (B,) float64, inf where infeasible
    throughput: np.ndarray      # (B,) tokens/s, 0 where infeasible
    mfu: np.ndarray             # (B,)
    power: np.ndarray           # (B,) watts, inf where infeasible
    t_comp: np.ndarray          # (B,)
    t_mem: np.ndarray           # (B,)
    t_coll: np.ndarray          # (B, 5) per-parallelism, P_ORDER
    exposed: np.ndarray         # (B,) serial comm exposure (non-DP)
    dp_exposed: np.ndarray      # (B,)
    bubble: np.ndarray          # (B,)
    reuse_active: np.ndarray    # (B,) bool
    reason_code: np.ndarray     # (B,) int, REASONS

    def __len__(self) -> int:
        return int(self.step_time.shape[0])

    def logs(self) -> Dict[str, np.ndarray]:
        """Array analogue of ``SimResult.logs`` (planner-facing signals)."""
        with np.errstate(invalid="ignore"):
            util = np.where(self.feasible, self.t_comp / self.step_time, 0.0)
        return {
            "compute_util": util,
            "exposed_comm": self.exposed + self.dp_exposed,
            "bubble": self.bubble,
            "reuse_active": self.reuse_active.astype(float),
            "hbm_bw_bound": (self.t_mem > self.t_comp).astype(float),
        }


# ---------------------------------------------------------------------------
# Vectorized intra-MCM packing (port of simulator.map_intra)
# ---------------------------------------------------------------------------
def map_intra_batch(batch: StrategyBatch, mcm: MCMArch
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (mappable (B,), intra (B,5), inter (B,5)) degree arrays.

    Mirrors ``map_intra``: TP always intra; if the package is larger,
    the first exact-fit group among CP, EP, PP fills it, else a
    hierarchical DP slice; otherwise the point is unmappable.
    """
    dies = mcm.dies_per_mcm
    deg = batch.degrees()                       # (B, 5)
    tp, dp, pp, cp, ep = (deg[:, P_IDX[p]] for p in
                          ("TP", "DP", "PP", "CP", "EP"))
    ok = (tp <= dies) & (dies % np.maximum(tp, 1) == 0) & (tp >= 1)
    rem = np.where(ok, dies // np.maximum(tp, 1), 0)

    intra = np.ones_like(deg)
    inter = deg.copy()
    intra[:, P_IDX["TP"]] = tp
    inter[:, P_IDX["TP"]] = 1

    need = ok & (rem > 1)
    cp_fit = need & (cp == rem)
    ep_fit = need & ~cp_fit & (ep == rem)
    pp_fit = need & ~cp_fit & ~ep_fit & (pp == rem)
    for name, fit in (("CP", cp_fit), ("EP", ep_fit), ("PP", pp_fit)):
        i = P_IDX[name]
        intra[:, i] = np.where(fit, rem, intra[:, i])
        inter[:, i] = np.where(fit, 1, inter[:, i])
    rem2 = np.where(cp_fit | ep_fit | pp_fit, 1, rem)

    need2 = ok & (rem2 > 1)
    dp_fit = need2 & (dp % np.maximum(rem2, 1) == 0)
    i = P_IDX["DP"]
    intra[:, i] = np.where(dp_fit, rem2, intra[:, i])
    inter[:, i] = np.where(dp_fit, dp // np.maximum(rem2, 1), inter[:, i])
    rem3 = np.where(dp_fit, 1, rem2)

    mappable = ok & (rem3 <= 1)
    return mappable, intra, inter


# ---------------------------------------------------------------------------
# Vectorized traffic volumes (port of traffic.traffic_volumes)
# ---------------------------------------------------------------------------
def traffic_volumes_batch(w: Workload, batch: StrategyBatch) -> np.ndarray:
    """(B, 5) bytes/device/step per parallelism, in P_ORDER.

    Degrees are pre-cast to float64 once (exact for these magnitudes)
    so each expression below is pure float arithmetic — the values stay
    bit-identical to ``traffic_volumes``'s int->float promotions.
    """
    B = len(batch)
    tp, dp, pp, cp, ep = (batch.tp.astype(np.float64),
                          batch.dp.astype(np.float64),
                          batch.pp.astype(np.float64),
                          batch.cp.astype(np.float64),
                          batch.ep.astype(np.float64))
    vols = np.zeros((B, 5))
    layers_ps = np.maximum(w.n_layers // batch.pp, 1)
    attn_ps = np.maximum(w.n_attn_layers // batch.pp, 1) \
        if w.n_attn_layers else 0
    moe_ps = np.maximum(w.n_moe_layers // batch.pp, 1) \
        if w.n_moe_layers else 0
    t_stage = w.tokens_per_step / (dp * cp)
    act = t_stage * w.d_model * w.bytes_act

    v_tp = 8.0 * layers_ps * act * (tp - 1.0) / tp
    vols[:, P_IDX["TP"]] = np.where(tp > 1, v_tp, 0.0)

    if w.n_attn_layers:
        kv_shard = np.minimum(tp, w.model.attn.n_kv_heads) \
            if w.model.attn else tp
        kv = t_stage * w.kv_bytes_per_token / kv_shard
        v_cp = 2.0 * attn_ps * (cp - 1.0) * kv
        vols[:, P_IDX["CP"]] = np.where(cp > 1, v_cp, 0.0)

    if w.n_moe_layers:
        topk = w.model.moe.top_k
        v_ep = (4.0 * moe_ps * (t_stage / tp) * topk
                * w.d_model * w.bytes_act * (ep - 1.0) / ep)
        vols[:, P_IDX["EP"]] = np.where(ep > 1, v_ep, 0.0)

    local = (w.nonexpert_params / (tp * pp)
             + w.expert_params / (tp * pp * ep))
    v_dp = 2.0 * local * w.bytes_grad * (dp - 1.0) / dp
    vols[:, P_IDX["DP"]] = np.where(dp > 1, v_dp, 0.0)

    v_pp = 2.0 * (t_stage / tp) * w.d_model * w.bytes_act
    vols[:, P_IDX["PP"]] = np.where(pp > 1, v_pp, 0.0)
    return vols


# ---------------------------------------------------------------------------
# HBM capacity demand (port of simulate's capacity check)
# ---------------------------------------------------------------------------
def hbm_demand_batch(w: Workload, batch: StrategyBatch
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-point (HBM bytes demanded, local parameter count): weights +
    optimizer state + pipeline-held activations.  The ONE batched copy
    of the oracle's capacity-check expressions — both the feasibility
    gate here and the refinement stage's ``mem_pressure`` log go
    through it, so they cannot drift."""
    tp, dp, pp, cp, ep = batch.tp, batch.dp, batch.pp, batch.cp, batch.ep
    nm = np.maximum(batch.n_micro, 1)
    layers_stage = np.maximum(w.n_layers // pp, 1)
    local_params = (w.nonexpert_params / (tp * pp)
                    + w.expert_params / (tp * pp * ep))
    mem_bytes = local_params * (2 + 2) + local_params * 12 / dp
    tokens_micro = w.tokens_per_step / (dp * cp * nm)
    act_bytes = (tokens_micro * w.d_model * w.bytes_act / tp
                 * layers_stage * 2 * np.minimum(pp, nm))
    return mem_bytes + act_bytes, local_params


# ---------------------------------------------------------------------------
# Vectorized link allocation (port of network.allocate_links)
# ---------------------------------------------------------------------------
def _trim_over_budget(alloc, usage, total_links, inter_mask, active,
                      pair_a=None, pair_b=None, first=None):
    """Shared trim loop: decrement the largest claim (first-max in
    P_ORDER, matching dict iteration order) until within budget or all
    claims are at the 1-link floor.  Overshoot is bounded by the number
    of min-1 bumps, so this converges in <= 6 passes."""
    B = alloc.shape[0]
    rows = np.arange(B)
    done = ~active
    for _ in range(8):
        tot = usage.sum(1)
        over = ~done & (tot > total_links)
        if not over.any():
            break
        masked = np.where(inter_mask, usage, -1)
        j = np.argmax(masked, 1)
        mx = masked[rows, j]
        act = over & (mx > 1)
        done |= over & (mx <= 1)
        if not act.any():
            break
        usage[rows[act], j[act]] -= 1
        alloc[rows[act], j[act]] -= 1
        if pair_a is not None:
            hit = act & (j == first)
            r = rows[hit]
            alloc[r, pair_a[hit]] = usage[r, j[hit]]
            alloc[r, pair_b[hit]] = usage[r, j[hit]]
    return alloc


def allocate_links_batch(vols: np.ndarray, inter_mask: np.ndarray,
                         total_links: int,
                         pair_a: Optional[np.ndarray] = None,
                         pair_b: Optional[np.ndarray] = None) -> np.ndarray:
    """(B, 5) link allocation (integer-valued float64); pair_a/pair_b
    are per-row parallelism indices of the reuse pair (-1 = no reuse).
    Mirrors ``network.allocate_links`` including its overshoot trim."""
    B = vols.shape[0]
    rows = np.arange(B)
    L = np.asarray(total_links, np.float64)
    Lc = L[:, None] if L.ndim else L          # per-point budgets (MCMBatch)
    mvols = np.where(inter_mask, vols, 0.0)
    ssum = mvols.sum(1)
    ssafe = np.where(ssum > 0, ssum, 1.0)
    alloc = np.where(inter_mask,
                     np.maximum(np.floor(Lc * mvols
                                         / ssafe[:, None]), 1.0),
                     0.0)                 # integer-valued float64 throughout
    usage = alloc.copy()
    alloc = _trim_over_budget(alloc, usage, total_links, inter_mask,
                              active=ssum > 0)

    if pair_a is None:
        return alloc
    has = (pair_a >= 0)
    if not has.any():
        return alloc
    pa = np.where(has, pair_a, 0)
    pb = np.where(has, pair_b, 0)
    va = vols[rows, pa]
    vb = vols[rows, pb]
    vmax = np.maximum(va, vb)
    pair_slots = np.zeros_like(inter_mask)
    pair_slots[rows, pa] = True
    pair_slots[rows, pb] = True
    others = inter_mask & ~pair_slots
    so = np.where(others, vols, 0.0).sum(1)
    denom = so + vmax
    dsafe = np.where(denom > 0, denom, 1.0)
    l_reuse = np.maximum(np.floor(L * vmax / dsafe), 1.0)
    rest = L - l_reuse
    so_safe = np.where(so > 0, so, 1.0)
    alloc_r = np.where(
        others, np.maximum(np.floor(rest[:, None] * vols / so_safe[:, None]),
                           1.0), 0.0)
    alloc_r[rows, pa] = l_reuse
    alloc_r[rows, pb] = l_reuse
    # pair links counted once, charged to the member first in P_ORDER
    first = np.minimum(pa, pb)
    usage_r = np.where(others, alloc_r, 0.0)
    usage_r[rows, first] = l_reuse
    alloc_r = _trim_over_budget(alloc_r, usage_r, total_links, inter_mask,
                                active=has, pair_a=pa, pair_b=pb,
                                first=first)
    return np.where(has[:, None], alloc_r, alloc)


# ---------------------------------------------------------------------------
# RailX allocation variant (port of optimizer.railx_topology's link split)
# ---------------------------------------------------------------------------
# inter-parallelism columns in the scalar ``ps`` order (map_intra's inter
# dict: DP, PP, CP, EP) — P_ORDER[1:], so pair indices map via ``- 1``
_RAILX_COLS = ("DP", "PP", "CP", "EP")


def allocate_links_railx_batch(vols: np.ndarray, inter: np.ndarray,
                               inter_mask: np.ndarray, total_links,
                               pair_a: np.ndarray, pair_b: np.ndarray,
                               ocs_ports: int
                               ) -> Tuple[np.ndarray, np.ndarray,
                                          np.ndarray]:
    """RailX link allocation: at most TWO rail dimensions with UNIFORM
    budgets (``L // 2`` each), parallelism groups packed onto the dims by
    the fewest-OCS split (the grouping search of
    ``core.optimizer.railx_topology``, vectorized over the 15 subset
    masks of the four inter parallelisms), links within a dim split
    traffic-proportionally.  Returns ``(alloc (B, 5), pair_shared (B,),
    derivable (B,))``: ``pair_shared`` marks rows whose reuse pair landed
    on ONE dim (only then can the pair share links), ``derivable`` rows
    with a valid grouping (scan-level signal; refinement re-derives the
    exact topology and drops the rest).  Undervisable-but-active rows get
    a best-effort single-dim split so the scan still ranks them."""
    B = vols.shape[0]
    rows = np.arange(B)
    cols = np.array([P_IDX[p] for p in _RAILX_COLS])
    deg4 = inter[:, cols].astype(np.int64)
    act = deg4 > 1                      # group membership is by DEGREE
    vols4 = vols[:, cols]
    # members with degree > 1 but zero traffic exist in the dim but are
    # outside inter_vols — the scalar code gives them the 1-link floor
    L = np.broadcast_to(np.asarray(total_links, np.int64), (B,))
    l_half = np.maximum(L // 2, 1).astype(np.float64)

    big = np.iinfo(np.int64).max
    best_ocs = np.full(B, big)
    best_mask = np.zeros(B, np.int64)
    for mask in range(1, 16):
        bits = np.array([(mask >> i) & 1 for i in range(4)], bool)
        g1 = act & bits
        g2 = act & ~bits
        valid = ~(bits & ~act).any(1) & g1.any(1)
        n1 = np.where(g1, deg4, 1).prod(1)
        n2 = np.where(g2, deg4, 1).prod(1)
        has2 = g2.any(1)
        # k_i = ceil(n_i / P) passes validate() only at k == 1
        valid &= n1 <= ocs_ports
        valid &= ~has2 | (n2 <= ocs_ports)
        valid &= ~has2 | (2 * l_half <= L)       # sum(R_i) <= L
        ocs = np.where(has2, (n1 + n2) * l_half.astype(np.int64),
                       l_half.astype(np.int64))
        better = valid & (ocs < best_ocs)
        best_ocs = np.where(better, ocs, best_ocs)
        best_mask = np.where(better, mask, best_mask)

    n_act = act.sum(1)
    derivable = (best_ocs < big) | (n_act == 0)
    # best-effort fallback for underivable active rows: one dim, all ps
    best_mask = np.where((n_act > 0) & ~derivable, 15, best_mask)

    bits1 = ((best_mask[:, None] >> np.arange(4)[None, :]) & 1) > 0
    g1 = act & bits1
    g2 = act & ~bits1

    has_pair = (pair_a >= 0)
    pa = np.where(has_pair, pair_a - 1, 0)       # P_ORDER index -> col4
    pb = np.where(has_pair, pair_b - 1, 0)
    pair_slots = np.zeros_like(act)
    pair_slots[rows, pa] = has_pair
    pair_slots[rows, pb] |= has_pair

    def dim_alloc(grp: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(alloc4 (B, 4), pair_here (B,)) for one rail dimension."""
        pair_here = has_pair & grp[rows, pa] & grp[rows, pb]
        # plain traffic-proportional split, volumes floored at 1.0
        vf = np.where(grp, np.maximum(vols4, 1.0), 0.0)
        sv = vf.sum(1)
        svs = np.where(sv > 0, sv, 1.0)
        plain = np.where(
            grp, np.maximum(np.floor(l_half[:, None] * vf
                                     / svs[:, None]), 1.0), 0.0)
        if not pair_here.any():
            return plain, pair_here
        # pair shares l_reuse links; others get the remainder (raw vols)
        vmax = np.maximum(vols4[rows, pa], vols4[rows, pb])
        others = grp & ~pair_slots
        vo = np.where(others, vols4, 0.0)
        so = vo.sum(1)
        denom = so + vmax
        l_r = np.where(denom > 0,
                       np.maximum(np.floor(l_half * vmax
                                           / np.where(denom > 0, denom,
                                                      1.0)), 1.0),
                       l_half)
        rest = l_half - l_r
        sos = np.where(so > 0, so, 1.0)
        shared = np.where(
            others,
            np.where(so[:, None] > 0,
                     np.maximum(np.floor(rest[:, None] * vo
                                         / sos[:, None]), 1.0), 1.0),
            0.0)
        shared[rows, pa] = l_r
        shared[rows, pb] = l_r
        # non-pair rows keep the plain split (shared is discarded there)
        return np.where(pair_here[:, None], shared, plain), pair_here

    a1, p1 = dim_alloc(g1)
    a2, p2 = dim_alloc(g2)
    alloc = np.zeros_like(vols)
    alloc[:, cols] = a1 + a2             # groups are disjoint
    return alloc, p1 | p2, derivable


# ---------------------------------------------------------------------------
# Reuse-pair selection (port of traffic.reusable_pairs + simulate filter)
# ---------------------------------------------------------------------------
def pick_reuse_pairs(vols: np.ndarray, inter_mask: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-row (pair_a, pair_b) parallelism indices of the selected reuse
    pair, or (-1, -1).  Highest min-volume inter-active candidate wins,
    candidate-order tie-break — identical to ``reusable_pairs`` followed
    by the simulator's inter_vols filter."""
    B = vols.shape[0]
    keys = np.full((B, len(_REUSE_CANDS)), -np.inf)
    for k, (a, b) in enumerate(_REUSE_CANDS):
        ia, ib = P_IDX[a], P_IDX[b]
        valid = inter_mask[:, ia] & inter_mask[:, ib]
        keys[:, k] = np.where(valid,
                              np.minimum(vols[:, ia], vols[:, ib]), -np.inf)
    sel = np.argmax(keys, 1)
    any_valid = np.isfinite(keys[np.arange(B), sel])
    ia = np.array([P_IDX[a] for a, _ in _REUSE_CANDS])[sel]
    ib = np.array([P_IDX[b] for _, b in _REUSE_CANDS])[sel]
    return (np.where(any_valid, ia, -1), np.where(any_valid, ib, -1))


def _ceil_log2_int(x: np.ndarray) -> np.ndarray:
    """Exact integer ceil(log2(x)) for x >= 1 (frexp-based, no libm)."""
    x = np.maximum(x, 1).astype(np.int64)
    _, e = np.frexp(x.astype(np.float64))
    is_pow2 = (x & (x - 1)) == 0
    return (e - is_pow2.astype(e.dtype)).astype(np.int64)


# ---------------------------------------------------------------------------
# GEMM shape efficiency (port of simulator._gemm_eff)
# ---------------------------------------------------------------------------
def gemm_eff_batch(w: Workload, batch: StrategyBatch, hw: HW) -> np.ndarray:
    m_tok = w.tokens_per_step / (batch.dp * batch.cp
                                 * np.maximum(batch.n_micro, 1))
    em = lambda m: m / (m + hw.gemm_m_half)
    en = lambda n: n / (n + hw.gemm_n_half)
    model = w.model
    a = model.attn
    tp = batch.tp
    if model.moe is not None:
        moe = model.moe
        m_exp = m_tok * moe.top_k / moe.n_experts
        n_ffn = np.maximum(moe.d_ff_expert / tp, 1.0)
        eff_ffn = em(m_exp) * en(n_ffn)
        ffn_flops = moe.top_k * 3 * model.d_model * moe.d_ff_expert
    else:
        d_ff = model.d_ff if model.d_ff else 2 * model.d_model
        eff_ffn = em(m_tok) * en(np.maximum(d_ff / tp, 1.0))
        ffn_flops = 3 * model.d_model * d_ff
    if a is not None:
        other_w = np.maximum(a.n_heads * a.head_dim / tp, 1.0)
        other_flops = model._attn_params()
    else:
        other_w = np.maximum(2 * model.d_model / tp, 1.0)
        other_flops = model._ssm_params() if model.ssm else \
            2 * model.d_model * model.d_model
    eff_other = em(m_tok) * en(other_w)
    f = ffn_flops / max(ffn_flops + other_flops, 1.0)
    return 1.0 / (f / np.maximum(eff_ffn, 1e-3)
                  + (1 - f) / np.maximum(eff_other, 1e-3))


# ---------------------------------------------------------------------------
# Cost-term core — backend-generic (numpy batched / jax vmapped point)
# ---------------------------------------------------------------------------
def _terms_core(xp, a: Dict, fabric: str, hw: HW):
    """Collective/memory/exposure terms -> step time.

    ``a`` holds per-point arrays (MCM parameters included, so one call
    can span heterogeneous MCM variants); with numpy the leading batch
    dim rides along every op, under jax.vmap the same code runs per
    point.  Every expression mirrors ``core.simulator.simulate``
    operation-for-operation (float64 parity).
    """
    vols, alloc = a["vols"], a["alloc"]
    inv, hops = a["inv"], a["hops"]
    intra, inter_mask = a["intra"], a["inter_mask"]
    t_comp, local_params = a["t_comp"], a["local_params"]
    layers_stage, nm = a["layers_stage"], a["nm"]
    tp, dp, pp, cp = a["tp"], a["dp"], a["pp"], a["cp"]
    reuse_overhead = a["reuse_overhead"]
    hbm_bw, nop_bw, dies = a["hbm_bw"], a["nop_bw"], a["dies"]

    hbm_cap_bw = hbm_bw / 2.0              # insight 5: relay = read+write
    t_coll = xp.zeros_like(vols)

    # ---- intra-MCM collectives ----
    intra_active = (intra > 1) & (vols > 0)
    if fabric == "nvlink":
        bw_i = xp.minimum(hw.nvlink_bw * hw.fabric_eff_elec,
                          hbm_cap_bw)[..., None]
        t_intra = vols / bw_i
    else:
        dil = xp.maximum(1.0, xp.sqrt(intra.astype(vols.dtype)) / 2.0)
        bw_i = xp.minimum(nop_bw[..., None] / dil, hbm_cap_bw[..., None])
        t_intra = vols / bw_i
    t_coll = t_coll + xp.where(intra_active,
                               t_intra + inv * hops * hw.lat_intra_s, 0.0)

    # ---- inter-MCM collectives ----
    if fabric in ("ib", "nvlink"):
        shared = xp.sum(xp.where(inter_mask, vols, 0.0), axis=-1)
        bw_sh = xp.minimum(hw.ib_bw * hw.fabric_eff_elec, hbm_cap_bw)
        t_sh = shared / bw_sh
        shared_safe = xp.where(shared > 0, shared, 1.0)
        t_coll = t_coll + xp.where(
            inter_mask,
            t_sh[..., None] * vols / shared_safe[..., None]
            + inv * hops * hw.lat_ib_s, 0.0)
    elif fabric == "oi":
        links = xp.maximum(alloc, 1.0)
        bw = xp.minimum(links * hw.oi_link_bw * hw.fabric_eff_oi
                        / dies[..., None], hbm_cap_bw[..., None])
        t_coll = t_coll + xp.where(inter_mask,
                                   vols / bw + inv * hops * hw.lat_oi_s, 0.0)
    else:
        raise ValueError(fabric)

    # ---- memory streaming ----
    w_scal = a["w_scalars"]     # (bytes_param, tokens_per_step, d_model,
    #                              bytes_act) — python floats/ints
    bytes_param, tokens, d_model, bytes_act = w_scal
    hbm_stream = (local_params * bytes_param * 2.0 * nm
                  + local_params * 16.0
                  + 12.0 * tokens / (dp * cp * tp)
                  * d_model * bytes_act * layers_stage)
    t_mem = hbm_stream / hbm_bw

    # ---- exposure / overlap ----
    t_attn = t_comp * 0.3
    exposed = t_coll[..., P_IDX["TP"]]
    exposed = exposed + xp.maximum(0.0, t_coll[..., P_IDX["CP"]]
                                   - t_attn * hw.cp_overlap_frac)
    exposed = exposed + t_coll[..., P_IDX["EP"]]
    exposed = exposed + t_coll[..., P_IDX["PP"]]
    t_dp = t_coll[..., P_IDX["DP"]]
    dp_exposed = xp.maximum(0.0, t_dp - (2.0 / 3.0) * t_comp
                            * hw.dp_overlap_frac)

    bubble = (pp - 1) / nm
    body = xp.maximum(t_comp, t_mem) + exposed
    step = body * (1.0 + bubble) + dp_exposed + reuse_overhead
    return {"step": step, "t_mem": t_mem, "t_coll": t_coll,
            "exposed": exposed, "dp_exposed": dp_exposed, "bubble": bubble}


_TERM_KEYS = ("vols", "alloc", "inv", "hops", "intra", "inter_mask",
              "t_comp", "local_params", "layers_stage", "nm", "tp", "dp",
              "pp", "cp", "reuse_overhead", "hbm_bw", "nop_bw", "dies")


# incremented once per jax trace of the point function — lets tests (and
# profiling) confirm the shape-bucketed cache actually stops re-tracing
_JAX_TRACES = {"count": 0}

# below this many rows the numpy path beats jax dispatch overhead; used
# by backend="auto"
JAX_AUTO_MIN_BATCH = 4096


def jax_stats() -> Dict[str, int]:
    """Public snapshot of the jit-cache perf internals: cumulative
    ``traces`` of the point function since process start (a repeated
    same-bucket sweep must not grow it) and the ``auto`` backend
    crossover.  Deltas of this feed ``StudyResult.provenance.metrics``
    (``jax.retraces``)."""
    return {"traces": int(_JAX_TRACES["count"]),
            "auto_min_batch": JAX_AUTO_MIN_BATCH}


@functools.lru_cache(maxsize=64)
def _jax_terms_fn(fabric: str, hw: HW, w_scalars: Tuple):
    import jax
    import jax.numpy as jnp

    def point_fn(*arrs):
        # runs at TRACE time only — both side effects count retraces
        _JAX_TRACES["count"] += 1
        obs_metrics.inc("batched_sim.jax_retraces")
        a = dict(zip(_TERM_KEYS, arrs))
        a["w_scalars"] = w_scalars
        return _terms_core(jnp, a, fabric, hw)

    return jax.jit(jax.vmap(point_fn))


@functools.lru_cache(maxsize=1)
def _jax_available() -> bool:
    try:
        import jax                                   # noqa: F401
        return True
    except Exception:
        return False


def resolve_backend(backend: str, n_rows: int) -> str:
    """Map ``auto`` to a concrete backend for a batch of ``n_rows``."""
    if backend != "auto":
        return backend
    if n_rows >= JAX_AUTO_MIN_BATCH and _jax_available():
        return "jax"
    return "numpy"


def _bucket(n: int) -> int:
    """Next power of two >= n (floor 8) — the jit-cache shape grid."""
    return 1 << max(int(n - 1).bit_length(), 3)


def _run_terms(a: Dict, fabric: str, hw: HW, backend: str):
    if backend == "numpy":
        return _terms_core(np, a, fabric, hw)
    if backend == "jax":
        from jax.experimental import enable_x64
        fn = _jax_terms_fn(fabric, hw, a["w_scalars"])
        B = a["vols"].shape[0]
        pad = _bucket(B) - B
        obs_metrics.inc("batched_sim.jax_calls")
        obs_metrics.inc("batched_sim.jax_pad_rows", pad)
        obs_metrics.gauge("batched_sim.jax_bucket", _bucket(B))
        args = []
        for k in _TERM_KEYS:
            v = np.asarray(a[k])
            if pad:                     # edge rows: real values, so the
                v = np.pad(v,           # padded tail stays finite
                           ((0, pad),) + ((0, 0),) * (v.ndim - 1),
                           mode="edge")
            args.append(v)
        with enable_x64():
            out = fn(*args)
        return {k: np.asarray(v)[:B] for k, v in out.items()}
    raise ValueError(f"unknown backend {backend!r}")


# ---------------------------------------------------------------------------
# The batched simulator
# ---------------------------------------------------------------------------
def batched_simulate(w: Workload, batch: StrategyBatch, mcm,
                     fabric: str = "oi", reuse: bool = True,
                     hw: Optional[HW] = None,
                     backend: str = "numpy",
                     alloc_mode: str = "chiplight") -> BatchedSimResult:
    """``mcm`` may be an ``MCMArch`` (homogeneous batch) or an
    ``MCMBatch`` of per-point parameters (fused cross-variant sweep; an
    explicit ``hw`` is then required).  ``alloc_mode`` selects the OI
    link allocator: ``"chiplight"`` (traffic-proportional + dynamic
    reuse) or ``"railx"`` (uniform 50/50 two-rail-dim baseline)."""
    if hw is None:
        if isinstance(mcm, MCMBatch):
            raise ValueError("pass hw= explicitly with an MCMBatch")
        hw = mcm.hw
    mb = _mcm_params(mcm)
    B = len(batch)
    backend = resolve_backend(backend, B)
    if B == 0:
        z = np.zeros(0)
        zb = np.zeros(0, bool)
        zi = np.zeros(0, np.int64)
        return BatchedSimResult(zb, z, z, z, z, z, z, np.zeros((0, 5)), z,
                                z, z, zb, zi)
    n_dev = mb.n_devices
    tp, dp, pp, cp, ep = (batch.tp, batch.dp, batch.pp, batch.cp, batch.ep)
    nm = np.maximum(batch.n_micro, 1)

    ok_dev = batch.n_devices == n_dev
    mappable, intra, inter = map_intra_batch(batch, mb)

    layers_stage = np.maximum(w.n_layers // pp, 1)
    attn_stage = np.maximum(w.n_attn_layers // pp, 1) \
        if w.n_attn_layers else np.zeros(B, np.int64)
    moe_stage = np.maximum(w.n_moe_layers // pp, 1) \
        if w.n_moe_layers else np.zeros(B, np.int64)

    # ---------------- memory capacity ----------------
    demand, local_params = hbm_demand_batch(w, batch)
    mem_ok = demand <= mb.hbm_capacity

    feasible = ok_dev & mappable & mem_ok
    reason = np.full(B, OK, np.int64)
    reason[~mem_ok] = HBM_CAPACITY
    reason[~mappable] = UNMAPPABLE
    reason[~ok_dev] = BAD_DEVICES

    # ---------------- compact to the feasible rows ----------------
    # infeasible points would only produce discarded numbers; the heavy
    # stages run on the survivors and scatter back at the end.
    sel = None if bool(feasible.all()) else np.nonzero(feasible)[0]
    if sel is not None:
        batch = batch.take(sel)
        mb = mb.take(sel)
        tp, dp, pp, cp, ep = (batch.tp, batch.dp, batch.pp, batch.cp,
                              batch.ep)
        nm = nm[sel]
        n_dev = mb.n_devices
        layers_stage = layers_stage[sel]
        attn_stage = attn_stage[sel]
        moe_stage = moe_stage[sel]
        intra, inter = intra[sel], inter[sel]
        local_params = local_params[sel]
    Bs = len(batch)

    def scatter(fill, vals, shape=None):
        if sel is None:
            return vals
        full = np.full(shape or B, fill)
        full[sel] = vals
        return full

    if Bs == 0:
        return BatchedSimResult(
            feasible=feasible, step_time=np.full(B, np.inf),
            throughput=np.zeros(B), mfu=np.zeros(B),
            power=np.full(B, np.inf), t_comp=np.zeros(B),
            t_mem=np.zeros(B), t_coll=np.zeros((B, 5)),
            exposed=np.zeros(B), dp_exposed=np.zeros(B),
            bubble=np.zeros(B), reuse_active=np.zeros(B, bool),
            reason_code=reason)

    # ---------------- compute time ----------------
    flops_dev = w.step_flops() / n_dev
    if hw.model_gemm_eff:
        eff = gemm_eff_batch(w, batch, hw)
        t_comp = flops_dev / (mb.die_flops * hw.mfu_ceiling * eff)
    else:   # eff == 1.0: multiplying the denominator by it is an identity
        t_comp = flops_dev / (mb.die_flops * hw.mfu_ceiling)
    t_comp = np.broadcast_to(np.asarray(t_comp, np.float64), (Bs,))

    # ---------------- traffic + link allocation ----------------
    vols = traffic_volumes_batch(w, batch)
    inter_mask = (inter > 1) & (vols > 0)

    inv = np.empty((Bs, 5))
    inv[:, P_IDX["TP"]] = 8 * layers_stage * nm
    inv[:, P_IDX["DP"]] = 1.0
    inv[:, P_IDX["PP"]] = 2 * nm
    inv[:, P_IDX["CP"]] = 2 * attn_stage * nm
    inv[:, P_IDX["EP"]] = 4 * moe_stage * nm
    hops = np.empty((Bs, 5))
    hops[:, P_IDX["TP"]] = tp - 1
    hops[:, P_IDX["DP"]] = 2 * (dp - 1)
    hops[:, P_IDX["PP"]] = 1.0
    hops[:, P_IDX["CP"]] = cp - 1
    hops[:, P_IDX["EP"]] = np.maximum(
        _ceil_log2_int(np.maximum(ep, 2)), 1)

    reuse_overhead = np.zeros(Bs)
    reuse_active_s = np.zeros(Bs, bool)
    alloc = np.zeros((Bs, 5))
    if alloc_mode not in ("chiplight", "railx"):
        raise ValueError(f"unknown alloc_mode {alloc_mode!r}; "
                         f"use 'chiplight' or 'railx'")
    if fabric == "oi":
        pair_a = np.full(Bs, -1, np.int64)
        pair_b = np.full(Bs, -1, np.int64)
        if reuse:
            pair_a, pair_b = pick_reuse_pairs(vols, inter_mask)
        alloc_rx = None
        if alloc_mode == "railx":
            alloc_rx, pair_shared, _ = allocate_links_railx_batch(
                vols, inter, inter_mask, mb.total_links, pair_a, pair_b,
                hw.ocs_ports)
            # the pair can only share links when railx co-locates it
            pair_a = np.where(pair_shared, pair_a, -1)
            pair_b = np.where(pair_shared, pair_b, -1)
        pair_pre_gate = pair_a >= 0
        if reuse:
            # bank-swap feasibility of flipping the shared links
            gap = t_comp / np.maximum(layers_stage * nm, 1) / 2.0
            if hw.ocs_reuse_mode != "paper":
                with np.errstate(divide="ignore"):
                    ok_swap = (gap > 0) & (np.ceil(
                        hw.ocs_switch_latency_s / np.where(gap > 0, gap, 1.0)
                    ) <= nm)
                pair_a = np.where(ok_swap, pair_a, -1)
                pair_b = np.where(ok_swap, pair_b, -1)
            reuse_active_s = pair_a >= 0
            if hw.ocs_reuse_mode != "paper":
                reuse_overhead = np.where(
                    reuse_active_s, 2.0 * hw.ocs_switch_latency_s / nm, 0.0)
        if alloc_mode == "railx":
            alloc = alloc_rx
            gated = pair_pre_gate & (pair_a < 0)
            if gated.any():
                # mirror simulate(): a topology reuse pair that cannot
                # bank-swap falls back to the traffic-proportional alloc
                none_p = np.full(Bs, -1, np.int64)
                alloc_cl = allocate_links_batch(
                    vols, inter_mask, mb.total_links, none_p, none_p)
                alloc = np.where(gated[:, None], alloc_cl, alloc_rx)
        else:
            alloc = allocate_links_batch(vols, inter_mask, mb.total_links,
                                         pair_a, pair_b)

    # ---------------- cost terms (numpy or jax.vmap) ----------------
    a = {"vols": vols, "alloc": alloc, "inv": inv,
         "hops": hops, "intra": intra.astype(np.float64),
         "inter_mask": inter_mask, "t_comp": t_comp,
         "local_params": local_params,
         "layers_stage": layers_stage.astype(np.float64),
         "nm": nm.astype(np.float64), "tp": tp.astype(np.float64),
         "dp": dp.astype(np.float64), "pp": pp.astype(np.float64),
         "cp": cp.astype(np.float64), "reuse_overhead": reuse_overhead,
         "hbm_bw": np.broadcast_to(np.asarray(mb.hbm_bw, np.float64),
                                   (Bs,)),
         "nop_bw": np.broadcast_to(np.asarray(mb.nop_bw, np.float64),
                                   (Bs,)),
         "dies": np.broadcast_to(
             np.asarray(mb.dies_per_mcm, np.float64), (Bs,)),
         "w_scalars": (float(w.bytes_param), float(w.tokens_per_step),
                       float(w.d_model), float(w.bytes_act))}
    with np.errstate(divide="ignore", invalid="ignore"):
        t = _run_terms(a, fabric, hw, backend)
        step = t["step"]
        thpt = w.tokens_per_step / step
        mfu = w.step_flops() / step / (mb.die_flops * n_dev)
        util = t_comp / step

    # board power: static + utilisation-scaled dynamic (see DESIGN.md)
    power = n_dev * (DIE_IDLE_W + DIE_DYN_W * util) \
        + n_dev * mb.m * HBM_W_PER_STACK
    if fabric == "oi":
        power = power + mb.n_mcm * mb.total_links * OI_W_PER_LINK
    else:
        power = power + n_dev * NIC_W_PER_DEV

    return BatchedSimResult(
        feasible=feasible,
        step_time=scatter(np.inf, step),
        throughput=scatter(0.0, thpt),
        mfu=scatter(0.0, np.broadcast_to(np.asarray(mfu, np.float64),
                                         (Bs,))),
        power=scatter(np.inf, np.broadcast_to(
            np.asarray(power, np.float64), (Bs,))),
        t_comp=scatter(0.0, t_comp),
        t_mem=scatter(0.0, t["t_mem"]),
        t_coll=scatter(0.0, t["t_coll"], shape=(B, 5)),
        exposed=scatter(0.0, t["exposed"]),
        dp_exposed=scatter(0.0, t["dp_exposed"]),
        bubble=scatter(0.0, t["bubble"]),
        reuse_active=scatter(False, reuse_active_s),
        reason_code=reason)
