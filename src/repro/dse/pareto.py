"""Fast non-dominated sorting over batched DSE objectives.

Objectives arrive as an (N, K) float matrix plus a per-column sense
(maximize / minimize).  ``pareto_mask`` finds the non-dominated set by
sorting on the first objective and comparing each chunk only against the
still-alive points that could possibly dominate it (those at least as
good on objective 0) — O(N * front) broadcasting in practice, a few
milliseconds for tens of thousands of points, with the same O(N^2)
worst case only when nearly everything is mutually non-dominated.
``nondominated_sort`` peels fronts NSGA-II-style and
``crowding_distance`` supplies the diversity metric for the
evolutionary driver.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np


def _as_max(objectives: np.ndarray, maximize: Sequence[bool]) -> np.ndarray:
    obj = np.asarray(objectives, np.float64)
    if obj.ndim != 2:
        raise ValueError("objectives must be (N, K)")
    sign = np.where(np.asarray(maximize, bool), 1.0, -1.0)
    return obj * sign


def pareto_mask(objectives: np.ndarray, maximize: Sequence[bool],
                chunk: int = 512) -> np.ndarray:
    """(N,) bool — True where no other point weakly dominates the point
    (>= in every objective, > in at least one).  Duplicate points keep
    each other (neither strictly dominates)."""
    M = _as_max(objectives, maximize)
    # a point with any NaN objective never survives
    keep = ~np.isnan(M).any(1)
    idx = np.nonzero(keep)[0]
    if not len(idx):
        return keep
    # descending objective-0 order: a dominator of row j must sit at or
    # before j's value band (obj0 >= obj0_j), so each chunk is compared
    # against the alive prefix only.  Not-yet-processed rows inside that
    # prefix are safe dominators: weak dominance is transitive, so if
    # such a row is later culled, whatever culled it dominates too.
    Mv = M[idx]
    order = np.argsort(-Mv[:, 0], kind="stable")
    Ms = Mv[order]
    m = len(order)
    alive = np.ones(m, bool)
    neg0 = -Ms[:, 0]                                 # ascending
    for lo in range(0, m, chunk):
        hi = min(lo + chunk, m)
        blk = Ms[lo:hi]                              # (c, K)
        # stage 1: cull against the already-settled front (cheap — the
        # front is tiny, and it kills most of the chunk).  Transitivity
        # makes the two-stage split safe: any chunk row that could have
        # culled a sibling but died here is dominated by a front member
        # that culls the sibling too.
        prior = np.nonzero(alive[:lo])[0]
        if len(prior):
            alive[lo:hi] &= ~_dominated_by(Ms[prior], blk)
        # stage 2: survivors vs the alive slice of their own obj0 band —
        # the chunk itself plus any later rows tied on objective 0 (blk
        # is sorted, so the band's minimum is its last row)
        live = np.nonzero(alive[lo:hi])[0] + lo
        if not len(live):
            continue
        stop = np.searchsorted(neg0, -blk[-1, 0], side="right")
        band = np.nonzero(alive[lo:stop])[0] + lo
        alive[live] &= ~_dominated_by(Ms[band], Ms[live])
    keep[idx[order[~alive]]] = False
    return keep


def _dominated_by(C: np.ndarray, B: np.ndarray) -> np.ndarray:
    """(len(B),) bool — B_j weakly dominated by some C_i (>= everywhere,
    > somewhere; equal rows do not dominate).  Built from per-objective
    2-D comparisons to avoid 3-D broadcast temporaries."""
    ge = np.ones((C.shape[0], B.shape[0]), bool)
    eq = np.ones_like(ge)
    for k in range(C.shape[1]):
        ck = C[:, k, None]
        bk = B[None, :, k]
        ge &= ck >= bk
        eq &= ck == bk
    return (ge & ~eq).any(0)


def nondominated_sort(objectives: np.ndarray, maximize: Sequence[bool],
                      max_fronts: int = 0) -> np.ndarray:
    """NSGA-II fast non-dominated sort: (N,) int rank, 0 = Pareto front.

    Points never ranked (NaN objectives, or beyond ``max_fronts``) get
    rank N (worst)."""
    obj = np.asarray(objectives, np.float64)
    n = obj.shape[0]
    ranks = np.full(n, n, np.int64)
    remaining = ~np.isnan(obj).any(1)
    rank = 0
    while remaining.any():
        if max_fronts and rank >= max_fronts:
            break
        idx = np.nonzero(remaining)[0]
        front = pareto_mask(obj[idx], maximize)
        ranks[idx[front]] = rank
        remaining[idx[front]] = False
        rank += 1
    return ranks


def crowding_distance(objectives: np.ndarray,
                      maximize: Sequence[bool]) -> np.ndarray:
    """NSGA-II crowding distance within one front (larger = lonelier)."""
    M = _as_max(objectives, maximize)
    n, k = M.shape
    if n <= 2:
        return np.full(n, np.inf)
    dist = np.zeros(n)
    for j in range(k):
        order = np.argsort(M[:, j], kind="stable")
        span = M[order[-1], j] - M[order[0], j]
        dist[order[0]] = dist[order[-1]] = np.inf
        if span <= 0:
            continue
        gaps = (M[order[2:], j] - M[order[:-2], j]) / span
        dist[order[1:-1]] += gaps
    return dist


def pareto_front_indices(objectives: np.ndarray, maximize: Sequence[bool]
                         ) -> np.ndarray:
    """Indices of the non-dominated set, best-first by objective 0."""
    mask = pareto_mask(objectives, maximize)
    idx = np.nonzero(mask)[0]
    M = _as_max(objectives[idx], maximize)
    return idx[np.argsort(-M[:, 0], kind="stable")]
