"""Fast non-dominated sorting over batched DSE objectives.

Objectives arrive as an (N, K) float matrix plus a per-column sense
(maximize / minimize).  ``pareto_mask`` finds the non-dominated set with
chunked O(N^2) numpy broadcasting (no Python pair loops) — a few
milliseconds for tens of thousands of points.  ``nondominated_sort``
peels fronts NSGA-II-style and ``crowding_distance`` supplies the
diversity metric for the evolutionary driver.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np


def _as_max(objectives: np.ndarray, maximize: Sequence[bool]) -> np.ndarray:
    obj = np.asarray(objectives, np.float64)
    if obj.ndim != 2:
        raise ValueError("objectives must be (N, K)")
    sign = np.where(np.asarray(maximize, bool), 1.0, -1.0)
    return obj * sign


def pareto_mask(objectives: np.ndarray, maximize: Sequence[bool],
                chunk: int = 512) -> np.ndarray:
    """(N,) bool — True where no other point weakly dominates the point
    (>= in every objective, > in at least one).  Duplicate points keep
    each other (neither strictly dominates)."""
    M = _as_max(objectives, maximize)
    n = M.shape[0]
    keep = np.ones(n, bool)
    # a point with any NaN objective never survives
    keep &= ~np.isnan(M).any(1)
    idx = np.nonzero(keep)[0]
    Mv = M[idx]
    alive = np.ones(len(idx), bool)
    for lo in range(0, len(idx), chunk):
        blk = Mv[lo:lo + chunk]                       # (c, K)
        # dominated[j] = exists i alive: M_i >= blk_j (all) and > (any)
        ge = (Mv[:, None, :] >= blk[None, :, :]).all(-1)      # (n, c)
        gt = (Mv[:, None, :] > blk[None, :, :]).any(-1)
        dom = (ge & gt & alive[:, None]).any(0)
        alive[lo:lo + chunk] &= ~dom
    keep[idx] = alive
    return keep


def nondominated_sort(objectives: np.ndarray, maximize: Sequence[bool],
                      max_fronts: int = 0) -> np.ndarray:
    """NSGA-II fast non-dominated sort: (N,) int rank, 0 = Pareto front.

    Points never ranked (NaN objectives, or beyond ``max_fronts``) get
    rank N (worst)."""
    obj = np.asarray(objectives, np.float64)
    n = obj.shape[0]
    ranks = np.full(n, n, np.int64)
    remaining = ~np.isnan(obj).any(1)
    rank = 0
    while remaining.any():
        if max_fronts and rank >= max_fronts:
            break
        idx = np.nonzero(remaining)[0]
        front = pareto_mask(obj[idx], maximize)
        ranks[idx[front]] = rank
        remaining[idx[front]] = False
        rank += 1
    return ranks


def crowding_distance(objectives: np.ndarray,
                      maximize: Sequence[bool]) -> np.ndarray:
    """NSGA-II crowding distance within one front (larger = lonelier)."""
    M = _as_max(objectives, maximize)
    n, k = M.shape
    if n <= 2:
        return np.full(n, np.inf)
    dist = np.zeros(n)
    for j in range(k):
        order = np.argsort(M[:, j], kind="stable")
        span = M[order[-1], j] - M[order[0], j]
        dist[order[0]] = dist[order[-1]] = np.inf
        if span <= 0:
            continue
        gaps = (M[order[2:], j] - M[order[:-2], j]) / span
        dist[order[1:-1]] += gaps
    return dist


def pareto_front_indices(objectives: np.ndarray, maximize: Sequence[bool]
                         ) -> np.ndarray:
    """Indices of the non-dominated set, best-first by objective 0."""
    mask = pareto_mask(objectives, maximize)
    idx = np.nonzero(mask)[0]
    M = _as_max(objectives[idx], maximize)
    return idx[np.argsort(-M[:, 0], kind="stable")]
