"""Population-based batched outer search over MCM architecture (§IV-B).

The nested ChipLight flow wraps an outer search over the MCM
architecture (N, x, y, m, r) around the para-topo inner search.  The
single-walker form walks ONE architecture per outer iteration through
the bottleneck-driven planner — every inner search is a fresh scan, and
revisited architectures pay full price.  This module hosts the
population form:

  * W walkers each hold an architecture; every round, each walker's
    bottleneck-driven moves (``core.optimizer.propose_moves`` — the same
    §IV-B-3 heuristics) are generated up front, plus crossover between
    walkers and a random perturbation;
  * the round's candidate architectures are deduplicated by MCM-variant
    key and the NEW ones are evaluated together: their strategy grids
    ride in one fused ``sweep_design_space`` call per fabric (a single
    ``MCMBatch``), and the vectorized ``refine_sweep_rows`` derives
    physical topologies and OCS-inclusive costs for each variant's
    winners in one batch;
  * an evaluation cache keyed by the MCM-variant key makes revisited
    architectures free;
  * each walker greedily adopts its best candidate (or stays);
  * optionally (``event_replay=K``), each round's candidate winners are
    compiled into ``StepProgram``s and replayed through ONE vectorized
    ``repro.events.batch.replay_batch`` wavefront call, and walkers
    adopt by the event-resolved throughput instead of the analytic one
    — the event engine as a first-class search objective.  Off by
    default: ``event_replay=0`` is bit-identical to the pre-hook
    search.

``method="scalar"`` is the original single-walker nested loop,
bit-identical to the pre-population ``chiplight_optimize`` for the same
seed (which is now a thin ``walkers=1, method="scalar"`` wrapper).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.hardware import HW, DEFAULT_HW
from repro.core.mcm import MCMArch, mcm_from_compute
from repro.core.optimizer import (DSEResult, DesignPoint, inner_search,
                                  pareto_front, propose_mcm, propose_moves)
from repro.core.workload import Workload
from repro.dse.search import refine_sweep_rows, sweep_design_space
from repro.dse.space import DesignSpace, enumerate_strategy_batch
from repro.obs import metrics as obs_metrics
from repro.obs import span

VariantKey = Tuple[int, int, int, int, float]


def mcm_variant_key(mcm: MCMArch) -> VariantKey:
    """Hashable identity of an MCM variant (the evaluation-cache key)."""
    return (mcm.n_mcm, mcm.x, mcm.y, mcm.m, round(mcm.cpo_ratio, 6))


@dataclass
class VariantEval:
    """Cached inner-search outcome of one MCM variant.

    ``event_step_time`` / ``event_thpt`` are stamped by the fused
    per-round event replay (``outer_search(event_replay=K)``): the
    event-resolved step time of the variant's best replayed point and
    its event-corrected throughput (analytic throughput rescaled by
    analytic/event step time).  Zero when the hook is off or no point
    of the variant compiled."""

    mcm: MCMArch
    best: Optional[DesignPoint]
    points: List[DesignPoint]
    grid_size: int
    event_step_time: Optional[float] = None
    event_thpt: float = 0.0

    @property
    def best_thpt(self) -> float:
        return self.best.throughput if self.best is not None else 0.0


def outer_search(w: Workload, total_tflops: float,
                 dies_per_mcm: int = 16, m0: int = 6, rounds: int = 8,
                 inner_budget: int = 48, walkers: int = 8,
                 fabric: str = "oi", reuse: bool = True,
                 hw: HW = DEFAULT_HW, seed: int = 0, cpo0: float = 0.6,
                 method: str = "population",
                 inner_method: str = "batched",
                 refine_per_variant: int = 8,
                 backend: str = "numpy",
                 event_replay: int = 0,
                 event_schedule=("1f1b",)) -> DSEResult:
    """Outer MCM-architecture search at constant cluster compute C.

    ``method="population"`` (default) runs ``walkers`` walkers for
    ``rounds`` rounds with fused batched evaluation and a variant cache;
    each variant's top ``refine_per_variant`` scan winners get the full
    vectorized refinement (the batched scan ranks the rest).
    ``method="scalar"`` is the single-walker nested loop (requires
    ``walkers == 1``; ``inner_budget`` points per round get the scalar
    treatment), reproducing the legacy ``chiplight_optimize`` trace
    bit-identically for the same seed.  ``outer_trace`` has
    ``rounds + 1`` entries either way — one per evaluation round.

    ``event_replay=K`` (population only) turns on the fused per-round
    event replay: each newly evaluated variant's top-K refined winners
    are vector-compiled (``events.compile_batch`` — no per-record DAG
    walks) and batch-replayed under EVERY ``event_schedule`` candidate
    (a name or a sequence of names; interleaved expands over its
    ``virtual_chunks`` grid), each point scored by its best schedule;
    walkers then adopt by event-resolved throughput — the schedule is a
    search dimension of the outer loop, not a fixed input.
    """
    event_schedule = (event_schedule,) if isinstance(event_schedule, str) \
        else tuple(event_schedule)
    if event_replay:
        from repro.events.dag import SCHEDULES
        bad = [s for s in event_schedule if s not in SCHEDULES]
        if bad:
            raise ValueError(f"unknown event_schedule {bad}; known: "
                             f"{list(SCHEDULES)}")
        if method == "scalar":
            raise ValueError("event_replay requires method='population' "
                             "(the scalar path has no fused rounds)")
    if method == "scalar":
        if walkers != 1:
            raise ValueError(f"method='scalar' is the single-walker "
                             f"path; got walkers={walkers}")
        return _outer_scalar(w, total_tflops, dies_per_mcm, m0, rounds,
                             inner_budget, fabric, reuse, hw, seed, cpo0,
                             inner_method)
    if method != "population":
        raise ValueError(f"unknown outer method {method!r}; "
                         f"use 'population' or 'scalar'")
    if walkers < 1:
        raise ValueError(f"walkers must be >= 1, got {walkers}")
    return _OuterPopulation(w, total_tflops, dies_per_mcm, m0, rounds,
                            inner_budget, walkers, fabric, reuse, hw,
                            seed, cpo0, refine_per_variant, backend,
                            event_replay, event_schedule).run()


# ---------------------------------------------------------------------------
# Scalar single-walker path (the legacy chiplight_optimize loop)
# ---------------------------------------------------------------------------
def _outer_scalar(w: Workload, total_tflops: float, dies_per_mcm: int,
                  m0: int, rounds: int, inner_budget: int, fabric: str,
                  reuse: bool, hw: HW, seed: int, cpo0: float,
                  inner_method: str) -> DSEResult:
    """One ``np.random.default_rng(seed)`` drives every ``propose_mcm``
    move (the inner scan is deterministic), so the run is reproducible
    from the arguments alone.  The MCM proposed by the LAST planner move
    is evaluated too — ``outer_trace`` has ``rounds + 1`` entries."""
    rng = np.random.default_rng(seed)
    mcm = mcm_from_compute(total_tflops, dies_per_mcm, m0,
                           cpo_ratio=cpo0, hw=hw)
    all_pts: List[DesignPoint] = []
    trace: List[Dict] = []
    n_sim = 0
    variants = set()
    for it in range(rounds + 1):
        best, pts = inner_search(w, mcm, fabric=fabric, reuse=reuse,
                                 budget=inner_budget, hw=hw,
                                 method=inner_method)
        n_sim += len(enumerate_strategy_batch(w, mcm))   # memoized
        variants.add(mcm_variant_key(mcm))
        all_pts.extend(pts)
        trace.append({
            "iter": it, "mcm": (mcm.n_mcm, mcm.x, mcm.y, mcm.m,
                                mcm.cpo_ratio),
            "best_thpt": best.throughput if best else 0.0,
            "bottleneck": best.sim.bottleneck if best else "none",
        })
        if it < rounds:
            mcm = propose_mcm(mcm, best, rng)
    best = max(all_pts, key=lambda p: p.throughput, default=None)
    return DSEResult(best=best, frontier=pareto_front(all_pts),
                     history=all_pts, outer_trace=trace,
                     stats={"n_sim": n_sim, "n_rounds": rounds + 1,
                            "n_variants": len(variants), "n_cache_hits": 0,
                            "n_refined": len(all_pts)})


# ---------------------------------------------------------------------------
# Population path
# ---------------------------------------------------------------------------
class _OuterPopulation:
    def __init__(self, w: Workload, total_tflops: float,
                 dies_per_mcm: int, m0: int, rounds: int,
                 inner_budget: int, walkers: int, fabric: str,
                 reuse: bool, hw: HW, seed: int, cpo0: float,
                 refine_per_variant: int, backend: str,
                 event_replay: int = 0,
                 event_schedule: Tuple[str, ...] = ("1f1b",)):
        self.w = w
        self.total_tflops = total_tflops
        self.dies_per_mcm = dies_per_mcm
        self.m0 = m0
        self.rounds = rounds
        self.inner_budget = inner_budget
        self.walkers = walkers
        self.fabric = fabric
        self.reuse = reuse
        self.hw = hw
        self.cpo0 = cpo0
        self.refine_per_variant = refine_per_variant
        self.backend = backend
        self.event_replay = event_replay
        self.event_schedule = event_schedule
        self.n_event_replayed = 0
        self.rng = np.random.default_rng(seed)
        self.cache: Dict[VariantKey, VariantEval] = {}
        self.history: List[DesignPoint] = []
        self.trace: List[Dict] = []
        self.n_sim = 0
        self.n_requested = 0     # incl. cache-served revisits, in points
        self.cache_hits = 0
        self.n_refined = 0

    # -- walker population -------------------------------------------------
    def run(self) -> DSEResult:
        mcm0 = mcm_from_compute(self.total_tflops, self.dies_per_mcm,
                                self.m0, cpo_ratio=self.cpo0, hw=self.hw)
        pop = [mcm0]
        for _ in range(self.walkers - 1):
            pop.append(self._perturb(mcm0))
        with span("outer.round", round=0, walkers=len(pop)):
            self._evaluate(pop)
            self._record_round(0, pop)
        for r in range(1, self.rounds + 1):
            with span("outer.round", round=r, walkers=len(pop)):
                cands = [self._candidates(m, pop) for m in pop]
                self._evaluate([c for cs in cands for c in cs])
                pop = [self._adopt(m, cs) for m, cs in zip(pop, cands)]
                self._record_round(r, pop)
        best = max(self.history, key=lambda p: p.throughput, default=None)
        return DSEResult(
            best=best, frontier=pareto_front(self.history),
            history=list(self.history), outer_trace=self.trace,
            stats={"n_sim": self.n_sim, "n_requested": self.n_requested,
                   "n_rounds": self.rounds + 1,
                   "n_variants": len(self.cache),
                   "n_cache_hits": self.cache_hits,
                   "n_refined": self.n_refined,
                   "n_event_replayed": self.n_event_replayed})

    def _usable(self, mcm: MCMArch) -> bool:
        return mcm.feasible() and (self.fabric != "oi"
                                   or mcm.total_links > 0)

    def _perturb(self, cur: MCMArch) -> MCMArch:
        """Random jitter of (m, cpo) at the walker's die count."""
        m = int(np.clip(cur.m + self.rng.integers(-2, 3), 1, 16))
        cpo = float(np.clip(
            round(cur.cpo_ratio + 0.1 * self.rng.integers(-2, 3), 6),
            0.1, 1.0))
        return mcm_from_compute(self.total_tflops, cur.dies_per_mcm, m,
                                cpo_ratio=cpo, hw=self.hw)

    def _crossover(self, a: MCMArch, pop: List[MCMArch]) -> MCMArch:
        """Child takes each of (dies, m, cpo) from parent a or a random
        partner walker."""
        b = pop[int(self.rng.integers(len(pop)))]
        take = self.rng.random(3) < 0.5
        dies = a.dies_per_mcm if take[0] else b.dies_per_mcm
        m = a.m if take[1] else b.m
        cpo = a.cpo_ratio if take[2] else b.cpo_ratio
        return mcm_from_compute(self.total_tflops, dies, m,
                                cpo_ratio=cpo, hw=self.hw)

    def _candidates(self, mcm: MCMArch, pop: List[MCMArch]
                    ) -> List[MCMArch]:
        """One walker's move set: bottleneck-driven heuristic moves plus
        crossover and perturbation, deduplicated by variant key."""
        ev = self.cache.get(mcm_variant_key(mcm))
        logs = ev.best.sim.logs if ev is not None and ev.best else None
        moves = propose_moves(mcm, logs, self.rng)
        moves.append(self._crossover(mcm, pop))
        moves.append(self._perturb(mcm))
        out, seen = [], {mcm_variant_key(mcm)}
        for c in moves:
            k = mcm_variant_key(c)
            if k not in seen and self._usable(c):
                seen.add(k)
                out.append(c)
        return out

    def _rank_thpt(self, ev: VariantEval) -> float:
        """Adoption key: event-resolved throughput when the fused
        per-round replay is on, the analytic one otherwise."""
        return ev.event_thpt if self.event_replay else ev.best_thpt

    def _adopt(self, cur: MCMArch, cands: List[MCMArch]) -> MCMArch:
        """Greedy: move to the best-throughput candidate, stay otherwise
        (first-max tie-break; a walker with no feasible point anywhere
        takes its first candidate to keep exploring)."""
        cur_ev = self.cache[mcm_variant_key(cur)]
        if not cands:
            return cur
        best_c = max(cands,
                     key=lambda m: self._rank_thpt(
                         self.cache[mcm_variant_key(m)]))
        best_ev = self.cache[mcm_variant_key(best_c)]
        if cur_ev.best is None and best_ev.best is None:
            return cands[0]
        if self._rank_thpt(best_ev) > self._rank_thpt(cur_ev):
            return best_c
        return cur

    # -- fused evaluation --------------------------------------------------
    def _refine(self, sweep, rows: np.ndarray) -> List[DesignPoint]:
        pts = refine_sweep_rows(sweep, rows) if len(rows) else []
        self.n_refined += len(pts)
        return pts

    def _evaluate(self, mcms: List[MCMArch]) -> None:
        """Evaluate every not-yet-cached variant in ONE fused sweep per
        fabric, then refine each variant's winners in one batched call."""
        new: List[MCMArch] = []
        seen = set()
        for m in mcms:
            k = mcm_variant_key(m)
            if k in self.cache:
                self.cache_hits += 1
                obs_metrics.inc("outer.variant_cache.hits")
            elif k in seen:
                pass
            elif self._usable(m):
                seen.add(k)
                new.append(m)
            else:
                seen.add(k)
                self.cache[k] = VariantEval(m, None, [], 0)
        if not new:
            self.n_requested += sum(
                self.cache[mcm_variant_key(m)].grid_size for m in mcms)
            return
        obs_metrics.inc("outer.variants_evaluated", len(new))
        space = DesignSpace(workload=self.w, mcms=tuple(new),
                            fabrics=(self.fabric,), reuse=self.reuse)
        sweep = sweep_design_space(space, driver="exhaustive",
                                   backend=self.backend)
        self.n_sim += sweep.n_sim
        grid_sizes = np.bincount(sweep.mcm_idx, minlength=len(new)) \
            if len(sweep) else np.zeros(len(new), np.int64)

        # per-variant winners: refine each variant's top-budget rows,
        # then top up (down to 4x the budget deep) only the variants
        # whose rows failed physical-rail derivation
        by_key: Dict[VariantKey, List[DesignPoint]] = {}
        if len(sweep):
            feas = np.nonzero(sweep.metrics["feasible"])[0]
            order = feas[np.argsort(-sweep.metrics["throughput"][feas],
                                    kind="stable")]
            by_var = order[np.argsort(sweep.mcm_idx[order], kind="stable")]
            mi = sweep.mcm_idx[by_var]
            starts = np.searchsorted(mi, np.arange(len(new)))
            rank_in_var = np.arange(len(by_var)) - starts[mi]
            rpv = self.refine_per_variant
            for p in self._refine(sweep, by_var[rank_in_var < rpv]):
                by_key.setdefault(mcm_variant_key(p.mcm), []).append(p)
            short = [i for i, m in enumerate(new)
                     if len(by_key.get(mcm_variant_key(m), [])) < rpv]
            if short:
                window2 = by_var[(rank_in_var >= rpv)
                                 & (rank_in_var < 4 * rpv)
                                 & np.isin(mi, np.array(short))]
                for p in self._refine(sweep, window2):
                    # window-2 rows rank below window 1, so appending
                    # keeps each variant's list in ranking order
                    by_key.setdefault(mcm_variant_key(p.mcm),
                                      []).append(p)
        for i, m in enumerate(new):
            k = mcm_variant_key(m)
            pts = by_key.get(k, [])[: self.refine_per_variant]
            best = max(pts, key=lambda p: p.throughput, default=None)
            self.cache[k] = VariantEval(m, best, pts,
                                        int(grid_sizes[i]))
            self.history.extend(pts)
        if self.event_replay:
            self._event_replay([self.cache[mcm_variant_key(m)]
                                for m in new])
        # search-requested volume: every variant the walkers asked for
        # this call, whether freshly simulated or served by the cache
        self.n_requested += sum(
            self.cache[mcm_variant_key(m)].grid_size for m in mcms)

    def _event_replay(self, evs: List[VariantEval]) -> None:
        """Fused per-round event replay with schedule search: the
        round's candidate winners (top ``event_replay`` refined points
        per new variant) are vector-compiled by
        ``events.compile_batch`` and batch-replayed once per
        ``(schedule, virtual_chunks)`` candidate; each point is scored
        by its BEST schedule, its logs stamped with the event-resolved
        step time and winning schedule, and each variant with its best
        event-corrected throughput."""
        from repro.dse.space import schedule_axis
        from repro.events.compile_batch import compile_batch
        from repro.events.dag import SCHEDULES
        pts, owners = [], []
        for ev in evs:
            for p in ev.points[: self.event_replay]:
                pts.append(p)
                owners.append(ev)
        if not pts:
            return
        cands = schedule_axis(self.event_schedule)
        N = len(pts)
        steps = np.full((len(cands), N), np.inf)
        errs = np.full((len(cands), N), np.nan)
        vs = np.ones((len(cands), N), np.int64)
        feas_any = np.zeros(N, bool)
        for ci, (sched, v) in enumerate(cands):
            cb = compile_batch(
                self.w, [p.strategy for p in pts],
                [p.mcm for p in pts],
                fabric=[p.fabric for p in pts],
                topos=[p.topo for p in pts], reuse=self.reuse,
                hw=self.hw, schedule=sched, virtual_chunks=v)
            res = cb.replay(backend=self.backend)
            steps[ci] = res["step_time"]
            errs[ci] = res["err"]
            vs[ci] = cb.v
            feas_any |= cb.feasible
        n_ok = int(feas_any.sum())
        if not n_ok:
            return                # no point compiled under any schedule
        obs_metrics.inc("outer.event_replayed", n_ok)
        self.n_event_replayed += n_ok
        win = np.argmin(steps, axis=0)
        for j, (ev, p) in enumerate(zip(owners, pts)):
            if not feas_any[j]:
                continue
            ci = int(win[j])
            st = float(steps[ci, j])
            p.sim.logs["event_step_time"] = st
            p.sim.logs["event_err"] = float(errs[ci, j])
            # logs are float-valued: the schedule rides as its index
            p.sim.logs["event_schedule"] = float(
                SCHEDULES.index(cands[ci][0]))
            p.sim.logs["event_v"] = float(vs[ci, j])
            thpt = (p.throughput * p.sim.step_time / st) if st > 0 else 0.0
            if thpt > ev.event_thpt:
                ev.event_thpt = thpt
                ev.event_step_time = st

    # -- trace -------------------------------------------------------------
    def _record_round(self, r: int, pop: List[MCMArch]) -> None:
        walkers = []
        pop_pts: List[DesignPoint] = []
        seen = set()
        for mcm in pop:
            k = mcm_variant_key(mcm)
            ev = self.cache[k]
            row = {
                "mcm": list(k),
                "best_thpt": float(ev.best_thpt),
                "bottleneck": ev.best.sim.bottleneck if ev.best else "none",
            }
            # event keys only when the hook is on — the legacy trace
            # stays schema-identical with event_replay=0
            if self.event_replay:
                row["event_thpt"] = float(ev.event_thpt)
                row["event_step_time"] = ev.event_step_time
            walkers.append(row)
            if k not in seen:
                seen.add(k)
                pop_pts.extend(ev.points)
        front = pareto_front(pop_pts)
        self.trace.append({
            "round": r,
            "walkers": walkers,
            "frontier": [[float(p.cost), float(p.throughput)]
                         for p in front],
            "n_sim": int(self.n_sim),
            "n_variants": len(self.cache),
            "n_cache_hits": int(self.cache_hits),
        })
