"""Attention block: init / train / prefill / decode with KV cache.

Local/global alternation (gemma) is expressed as a *dynamic* per-layer
window scalar so a single scanned layer stack serves both layer kinds.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import AttnConfig
from repro.kernels import ops
from repro.models import common


def attn_init(key, d_model, a: AttnConfig, dtype):
    ks = jax.random.split(key, 6)
    p = {
        "wq": common.dense_init(ks[0], d_model, a.n_heads * a.head_dim,
                                dtype),
        "wk": common.dense_init(ks[1], d_model, a.n_kv_heads * a.head_dim,
                                dtype),
        "wv": common.dense_init(ks[2], d_model, a.n_kv_heads * a.head_dim,
                                dtype),
        "wo": common.dense_init(ks[3], a.n_heads * a.head_dim, d_model,
                                dtype),
    }
    if a.qk_norm:
        p["q_norm"] = jnp.ones((a.head_dim,), dtype)
        p["k_norm"] = jnp.ones((a.head_dim,), dtype)
    return p


def _project_qkv(params, x, a: AttnConfig, positions, norm_eps, backend,
                 rope=True):
    b, s, _ = x.shape
    q = (x @ params["wq"]).reshape(b, s, a.n_heads, a.head_dim)
    k = (x @ params["wk"]).reshape(b, s, a.n_kv_heads, a.head_dim)
    v = (x @ params["wv"]).reshape(b, s, a.n_kv_heads, a.head_dim)
    if a.qk_norm:
        q = common.norm(q, params["q_norm"], norm_eps, backend)
        k = common.norm(k, params["k_norm"], norm_eps, backend)
    q = jnp.moveaxis(q, 1, 2)   # (B,H,S,D)
    k = jnp.moveaxis(k, 1, 2)
    v = jnp.moveaxis(v, 1, 2)
    if rope:
        q = common.apply_rope(q, positions, a.rope_theta)
        k = common.apply_rope(k, positions, a.rope_theta)
    return q, k, v


def layer_window(a: AttnConfig, is_global, seq_len):
    """Window for a (possibly alternating) layer.

    Uniform archs get a STATIC python int (enables statically-skipped
    block attention); alternating archs (gemma) get a traced scalar from
    the per-layer flag — unless the caller uses the period-grouped layer
    scan, which passes static windows itself (see transformer.py).
    """
    if a.window is None:
        return None
    if a.local_global_period == 0:
        return int(a.window)
    if isinstance(is_global, (bool, int)):
        return None if is_global else int(a.window)
    big = jnp.int32(seq_len + 1)
    return jnp.where(is_global, big, jnp.int32(a.window))


def attn_train(params, x, a: AttnConfig, *, window=None, norm_eps, ex,
               causal=True, kv_source=None):
    """Full-sequence attention (train / prefill / encoder / cross).

    kv_source: if given, keys/values come from this tensor (cross-attn;
    no causal mask, no rope on kv source positions mismatch is the
    caller's concern).  Returns (out, (k, v)) with k/v pre-rope-cache
    layout (B,Hkv,S,D) for prefill cache building.
    """
    b, s, _ = x.shape
    positions = jnp.arange(s)
    if kv_source is None:
        q, k, v = _project_qkv(params, x, a, positions, norm_eps,
                               ex.backend, rope=True)
        o = ops.flash_attention(q, k, v, window=window, causal=causal,
                                softcap=a.attn_softcap, block=ex.attn_block,
                                backend=ex.backend)
    else:
        # cross attention: q from x, k/v from source (no rope, whisper-style)
        sk = kv_source.shape[1]
        q = (x @ params["wq"]).reshape(b, s, a.n_heads, a.head_dim)
        q = jnp.moveaxis(q, 1, 2)
        k = (kv_source @ params["wk"]).reshape(b, sk, a.n_kv_heads,
                                               a.head_dim)
        v = (kv_source @ params["wv"]).reshape(b, sk, a.n_kv_heads,
                                               a.head_dim)
        k = jnp.moveaxis(k, 1, 2)
        v = jnp.moveaxis(v, 1, 2)
        o = ops.flash_attention(q, k, v, window=None, causal=False,
                                softcap=a.attn_softcap, block=ex.attn_block,
                                backend=ex.backend)
    out = jnp.moveaxis(o, 1, 2).reshape(b, s, a.n_heads * a.head_dim)
    return out @ params["wo"], (k, v)


def attn_decode(params, x, cache_k, cache_v, pos, a: AttnConfig, *,
                is_global, norm_eps, ex, rolling_window=None):
    """One-token decode.  x: (B,1,D_model); caches: (B,Hkv,Smax,hd).

    pos: int32 scalar — index of the new token.  rolling_window: if the
    cache is a rolling buffer of this size, positions wrap (mixtral SWA).
    Returns (out, new_cache_k, new_cache_v).
    """
    b = x.shape[0]
    positions = jnp.full((1,), pos, jnp.int32)
    q, k, v = _project_qkv(params, x, a, positions, norm_eps, ex.backend,
                           rope=True)
    smax = cache_k.shape[2]
    slot = pos % smax if rolling_window is not None else pos
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k.astype(cache_k.dtype), (0, 0, slot, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v.astype(cache_v.dtype), (0, 0, slot, 0))
    if rolling_window is not None:
        # every slot in the rolling buffer is within the window; only mask
        # unfilled slots during warm-up.
        eff_pos = jnp.minimum(pos, smax - 1)
        o = ops.decode_attention(q, cache_k, cache_v, eff_pos,
                                 window=None, softcap=a.attn_softcap)
    else:
        window = layer_window(a, is_global, smax)
        o = ops.decode_attention(q, cache_k, cache_v, pos, window=window,
                                 softcap=a.attn_softcap)
    out = jnp.moveaxis(o, 1, 2).reshape(b, 1, a.n_heads * a.head_dim)
    return out @ params["wo"], cache_k, cache_v


def cross_decode(params, x, ck, cv, a: AttnConfig):
    """Decode-time cross attention against precomputed enc K/V."""
    b = x.shape[0]
    q = (x @ params["wq"]).reshape(b, 1, a.n_heads, a.head_dim)
    q = jnp.moveaxis(q, 1, 2)
    o = ops.decode_attention(q, ck, cv, jnp.int32(ck.shape[2] - 1),
                             softcap=a.attn_softcap)
    out = jnp.moveaxis(o, 1, 2).reshape(b, 1, a.n_heads * a.head_dim)
    return out @ params["wo"]
