"""Unified model API: ``build_model(cfg)`` -> ModelFns.

Every family exposes the same surface:
  init(key, ex) -> params
  loss(params, batch, ex) -> (scalar, metrics)          [train]
  prefill(params, batch, ex) -> (logits, cache)         [inference]
  decode_step(params, cache, tokens, pos, ex) -> (logits, cache)
  init_cache(batch, seq_len, ex) -> cache
  input_specs(shape, ex) -> batch of ShapeDtypeStructs  [AOT dry-run]
  make_batch(key, shape, ex) -> concrete synthetic batch [smoke/e2e]
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, hybrid, ssm_lm, transformer
from repro.models.common import ExecConfig


@dataclass(frozen=True)
class ModelFns:
    cfg: ModelConfig
    init: Callable
    loss: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable
    input_specs: Callable
    make_batch: Callable


def _token_specs(cfg, shape: ShapeConfig, ex, kind):
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if kind == "train":
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                 "labels": jax.ShapeDtypeStruct((b, s), i32)}
    elif kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    else:
        raise ValueError(kind)
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_prefix_tokens, cfg.d_model), ex.compute_dtype)
        if kind == "train":
            batch["loss_mask"] = jax.ShapeDtypeStruct((b, s), jnp.float32)
    if cfg.family == "encdec":
        batch["encoder_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_len, cfg.d_model), ex.compute_dtype)
    return batch


def _cache_specs(init_cache, cfg, shape: ShapeConfig, ex):
    cache = jax.eval_shape(
        lambda: init_cache(shape.global_batch, shape.seq_len, ex))
    return {
        "tokens": jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "cache": cache,
    }


def _make_token_batch(key, cfg, shape: ShapeConfig, ex, kind):
    ks = jax.random.split(key, 4)
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab)}
    if kind == "train":
        batch["labels"] = jax.random.randint(ks[1], (b, s), 0, cfg.vocab)
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jax.random.normal(
            ks[2], (b, cfg.n_prefix_tokens, cfg.d_model),
            ex.compute_dtype)
        if kind == "train":
            mask = np.ones((b, s), np.float32)
            mask[:, :cfg.n_prefix_tokens] = 0.0
            batch["loss_mask"] = jnp.asarray(mask)
    if cfg.family == "encdec":
        batch["encoder_embeds"] = jax.random.normal(
            ks[3], (b, cfg.encoder_len, cfg.d_model), ex.compute_dtype)
    return batch


def build_model(cfg: ModelConfig) -> ModelFns:
    fam = cfg.family

    if fam in ("dense", "moe", "vlm"):
        def init(key, ex):
            return transformer.lm_init(key, cfg, ex)

        def loss(params, batch, ex):
            return transformer.lm_loss(params, batch, cfg, ex)

        def prefill(params, batch, ex):
            return transformer.lm_prefill(params, batch["tokens"], cfg, ex,
                                          batch.get("prefix_embeds"))

        def decode_step(params, cache, tokens, pos, ex):
            return transformer.lm_decode_step(params, cache, tokens, pos,
                                              cfg, ex)

        def init_cache(batch, seq_len, ex):
            return transformer.init_cache(cfg, batch, seq_len,
                                          ex.compute_dtype)

    elif fam == "ssm":
        def init(key, ex):
            return ssm_lm.ssm_lm_init(key, cfg, ex)

        def loss(params, batch, ex):
            return ssm_lm.ssm_lm_loss(params, batch, cfg, ex)

        def prefill(params, batch, ex):
            return ssm_lm.ssm_lm_prefill(params, batch["tokens"], cfg, ex)

        def decode_step(params, cache, tokens, pos, ex):
            return ssm_lm.ssm_lm_decode_step(params, cache, tokens, pos,
                                             cfg, ex)

        def init_cache(batch, seq_len, ex):
            return ssm_lm.ssm_lm_init_cache(cfg, batch, seq_len,
                                            ex.compute_dtype)

    elif fam == "hybrid":
        def init(key, ex):
            return hybrid.hybrid_init(key, cfg, ex)

        def loss(params, batch, ex):
            return hybrid.hybrid_loss(params, batch, cfg, ex)

        def prefill(params, batch, ex):
            return hybrid.hybrid_prefill(params, batch["tokens"], cfg, ex)

        def decode_step(params, cache, tokens, pos, ex):
            return hybrid.hybrid_decode_step(params, cache, tokens, pos,
                                             cfg, ex)

        def init_cache(batch, seq_len, ex):
            return hybrid.hybrid_init_cache(cfg, batch, seq_len,
                                            ex.compute_dtype)

    elif fam == "encdec":
        def init(key, ex):
            return encdec.encdec_init(key, cfg, ex)

        def loss(params, batch, ex):
            return encdec.encdec_loss(params, batch, cfg, ex)

        def prefill(params, batch, ex):
            return encdec.encdec_prefill(params, batch["tokens"],
                                         batch["encoder_embeds"], cfg, ex)

        def decode_step(params, cache, tokens, pos, ex):
            return encdec.encdec_decode_step(params, cache, tokens, pos,
                                             cfg, ex)

        def init_cache(batch, seq_len, ex):
            return encdec.encdec_init_cache(cfg, batch, seq_len,
                                            ex.compute_dtype)

    else:
        raise ValueError(f"unknown family {fam!r}")

    def input_specs(shape: ShapeConfig, ex, kind=None):
        kind = kind or shape.kind
        if kind in ("train", "prefill"):
            return _token_specs(cfg, shape, ex, kind)
        return _cache_specs(init_cache, cfg, shape, ex)

    def make_batch(key, shape: ShapeConfig, ex, kind=None):
        kind = kind or shape.kind
        if kind in ("train", "prefill"):
            return _make_token_batch(key, cfg, shape, ex, kind)
        return {
            "tokens": jax.random.randint(key, (shape.global_batch,), 0,
                                         cfg.vocab),
            "pos": jnp.int32(shape.seq_len - 1),
            "cache": init_cache(shape.global_batch, shape.seq_len, ex),
        }

    return ModelFns(cfg=cfg, init=init, loss=loss, prefill=prefill,
                    decode_step=decode_step, init_cache=init_cache,
                    input_specs=input_specs, make_batch=make_batch)
