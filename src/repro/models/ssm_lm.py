"""Pure-SSM (Mamba2) language model: embed -> scanned SSD blocks -> head."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ref as kref
from repro.models import common, ssm


def ssm_lm_init(key, cfg: ModelConfig, ex: common.ExecConfig):
    dtype = ex.param_dtype
    k_embed, k_layers = jax.random.split(key)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)

    def one(k):
        return {"ln": jnp.ones((cfg.d_model,), dtype),
                "ssm": ssm.ssm_init(k, cfg, dtype)}

    return {
        "embed": common.initializer(k_embed, (cfg.vocab, cfg.d_model),
                                    0.02, dtype),
        "layers": jax.vmap(one)(layer_keys),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }


def ssm_lm_hidden(params, tokens, cfg: ModelConfig, ex):
    x = common.shard_batch(
        params["embed"][tokens].astype(ex.compute_dtype), ex)

    def body(x, lp):
        h = common.norm(x, lp["ln"], cfg.norm_eps, ex.backend)
        return common.shard_acts(x + ssm.ssm_train(lp["ssm"], h, cfg, ex),
                                 ex), None

    body = ex.wrap_remat(body)
    x, _ = common.layer_scan(ex, body, x, params["layers"])
    return common.norm(x, params["final_norm"], cfg.norm_eps, ex.backend)


def ssm_lm_loss(params, batch, cfg: ModelConfig, ex):
    x = ssm_lm_hidden(params, batch["tokens"], cfg, ex)
    logits = x @ params["embed"].T
    ce = common.cross_entropy(logits, batch["labels"],
                              mask=batch.get("loss_mask"))
    return ce, {"ce": ce, "aux": 0.0}


def ssm_lm_init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype):
    del seq_len  # O(1)-in-seq state
    return {"ssm": jax.vmap(
        lambda _: ssm.ssm_init_state(cfg, batch, dtype))(
            jnp.arange(cfg.n_layers))}


def _train_with_state(lp, h, cfg, ex):
    """Like ssm.ssm_train but also returns (conv_state, ssm_state)."""
    s_cfg = cfg.ssm
    b, s, _ = h.shape
    di, nh, d_xbc = ssm.ssm_dims(cfg)
    gn = s_cfg.n_groups * s_cfg.d_state

    proj = h @ lp["in_proj"]
    z, xbc_raw, dt = ssm._split_in_proj(proj, cfg)
    xbc = ssm._causal_conv(xbc_raw, lp["conv_w"], lp["conv_b"])
    xs, bmat, cmat = jnp.split(xbc, [di, di + gn], axis=-1)
    xs = xs.reshape(b, s, nh, s_cfg.head_dim)
    bmat = bmat.reshape(b, s, s_cfg.n_groups, s_cfg.d_state)
    cmat = cmat.reshape(b, s, s_cfg.n_groups, s_cfg.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + lp["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(lp["A_log"].astype(jnp.float32))
    y, final_state = kref.ssd_chunked_ref(
        xs, dt, a, bmat, cmat, chunk=ex.ssd_chunk,
        unroll=ex.backend == "xla_blocked")
    y = y + xs * lp["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(b, s, di)
    y = common.norm(y * jax.nn.silu(z), lp["gate_norm"], cfg.norm_eps,
                    ex.backend)
    conv_state = xbc_raw[:, -(s_cfg.conv_width - 1):, :]
    return y @ lp["out_proj"], conv_state, final_state


def ssm_lm_prefill(params, tokens, cfg: ModelConfig, ex):
    x = common.shard_batch(
        params["embed"][tokens].astype(ex.compute_dtype), ex)

    def body(x, lp):
        h = common.norm(x, lp["ln"], cfg.norm_eps, ex.backend)
        y, conv_st, ssm_st = _train_with_state(lp["ssm"], h, cfg, ex)
        return common.shard_acts(x + y, ex), \
            (conv_st.astype(ex.compute_dtype), ssm_st)

    x, (conv, st) = common.layer_scan(ex, body, x, params["layers"])
    x = common.norm(x, params["final_norm"], cfg.norm_eps, ex.backend)
    logits = x[:, -1] @ params["embed"].T
    return logits, {"ssm": {"conv": conv, "ssm": st}}


def ssm_lm_decode_step(params, cache, tokens, pos, cfg: ModelConfig, ex):
    del pos  # stateful; position-free
    x = common.shard_batch(
        params["embed"][tokens][:, None, :].astype(ex.compute_dtype), ex)

    def body(x, inp):
        lp, st_conv, st_ssm = inp
        h = common.norm(x, lp["ln"], cfg.norm_eps, ex.backend)
        y, st = ssm.ssm_decode(lp["ssm"], h,
                               {"conv": st_conv, "ssm": st_ssm}, cfg, ex)
        return x + y, (st["conv"], st["ssm"])

    x, (conv, st) = common.layer_scan(ex, 
        body, x, (params["layers"], cache["ssm"]["conv"],
                  cache["ssm"]["ssm"]))
    x = common.norm(x, params["final_norm"], cfg.norm_eps, ex.backend)
    logits = x[:, 0] @ params["embed"].T
    return logits, {"ssm": {"conv": conv, "ssm": st}}
