from repro.models.api import build_model, ModelFns  # noqa: F401
from repro.models.common import ExecConfig  # noqa: F401
