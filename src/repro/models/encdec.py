"""Whisper-style encoder-decoder (audio conv frontend STUBBED).

``input_specs`` provides precomputed frame embeddings (B, encoder_len, D) —
the conv1d+GELU frontend of Whisper is a modality stub per the assignment.
Encoder: bidirectional attention; decoder: causal self-attn + cross-attn.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, common


def _enc_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": attention.attn_init(ks[0], cfg.d_model, cfg.attn, dtype),
        "mlp": common.mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                               cfg.gated_mlp, dtype),
    }


def _dec_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln_x": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": attention.attn_init(ks[0], cfg.d_model, cfg.attn, dtype),
        "xattn": attention.attn_init(ks[1], cfg.d_model, cfg.attn, dtype),
        "mlp": common.mlp_init(ks[2], cfg.d_model, cfg.d_ff,
                               cfg.gated_mlp, dtype),
    }


def encdec_init(key, cfg: ModelConfig, ex: common.ExecConfig):
    dtype = ex.param_dtype
    ke, kd, kemb, kpos = jax.random.split(key, 4)
    enc_keys = jax.random.split(ke, cfg.encoder_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    return {
        "embed": common.initializer(kemb, (cfg.vocab, cfg.d_model),
                                    0.02, dtype),
        "pos_embed": common.initializer(kpos, (cfg.encoder_len,
                                               cfg.d_model), 0.02, dtype),
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg, dtype))(
            enc_keys),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(k, cfg, dtype))(
            dec_keys),
        "enc_norm": jnp.ones((cfg.d_model,), dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }


def encode(params, frames, cfg: ModelConfig, ex):
    """frames: (B, encoder_len, D) stub embeddings -> (B, len, D)."""
    x = common.shard_batch(
        frames.astype(ex.compute_dtype) + params["pos_embed"][None], ex)

    def body(x, lp):
        h = common.norm(x, lp["ln1"], cfg.norm_eps, ex.backend)
        a, _ = attention.attn_train(lp["attn"], h, cfg.attn, window=None,
                                    norm_eps=cfg.norm_eps, ex=ex,
                                    causal=False)
        x = x + a
        h = common.norm(x, lp["ln2"], cfg.norm_eps, ex.backend)
        return common.shard_acts(
            x + common.mlp_apply(lp["mlp"], h, cfg.gated_mlp), ex), None

    body = ex.wrap_remat(body)
    x, _ = common.layer_scan(ex, body, x, params["enc_layers"])
    return common.norm(x, params["enc_norm"], cfg.norm_eps, ex.backend)


def _dec_layer(lp, x, enc_out, cfg, ex, collect_kv=False):
    h = common.norm(x, lp["ln1"], cfg.norm_eps, ex.backend)
    a, kv = attention.attn_train(lp["attn"], h, cfg.attn, window=None,
                                 norm_eps=cfg.norm_eps, ex=ex)
    x = x + a
    h = common.norm(x, lp["ln_x"], cfg.norm_eps, ex.backend)
    xa, xkv = attention.attn_train(lp["xattn"], h, cfg.attn, window=None,
                                   norm_eps=cfg.norm_eps, ex=ex,
                                   kv_source=enc_out)
    x = x + xa
    h = common.norm(x, lp["ln2"], cfg.norm_eps, ex.backend)
    x = common.shard_acts(x + common.mlp_apply(lp["mlp"], h, cfg.gated_mlp),
                          ex)
    return x, (kv, xkv)


def encdec_loss(params, batch, cfg: ModelConfig, ex):
    enc_out = encode(params, batch["encoder_embeds"], cfg, ex)
    x = common.shard_batch(
        params["embed"][batch["tokens"]].astype(ex.compute_dtype), ex)

    def body(x, lp):
        x, _ = _dec_layer(lp, x, enc_out, cfg, ex)
        return x, None

    body = ex.wrap_remat(body)
    x, _ = common.layer_scan(ex, body, x, params["dec_layers"])
    x = common.norm(x, params["final_norm"], cfg.norm_eps, ex.backend)
    logits = x @ params["embed"].T
    ce = common.cross_entropy(logits, batch["labels"],
                              mask=batch.get("loss_mask"))
    return ce, {"ce": ce, "aux": 0.0}


def encdec_init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype):
    a = cfg.attn
    l = cfg.n_layers
    return {
        "k": jnp.zeros((l, batch, a.n_kv_heads, seq_len, a.head_dim),
                       dtype),
        "v": jnp.zeros((l, batch, a.n_kv_heads, seq_len, a.head_dim),
                       dtype),
        # precomputed cross-attention K/V over encoder output
        "xk": jnp.zeros((l, batch, a.n_kv_heads, cfg.encoder_len,
                         a.head_dim), dtype),
        "xv": jnp.zeros((l, batch, a.n_kv_heads, cfg.encoder_len,
                         a.head_dim), dtype),
    }


def encdec_prefill(params, tokens, frames, cfg: ModelConfig, ex):
    enc_out = encode(params, frames, cfg, ex)
    x = common.shard_batch(
        params["embed"][tokens].astype(ex.compute_dtype), ex)

    def body(x, lp):
        x, (kv, xkv) = _dec_layer(lp, x, enc_out, cfg, ex, collect_kv=True)
        return x, (kv[0], kv[1], xkv[0], xkv[1])

    x, (ck, cv, xk, xv) = common.layer_scan(ex, body, x, params["dec_layers"])
    x = common.norm(x, params["final_norm"], cfg.norm_eps, ex.backend)
    logits = x[:, -1] @ params["embed"].T
    return logits, {"k": ck, "v": cv, "xk": xk, "xv": xv}


def encdec_decode_step(params, cache, tokens, pos, cfg: ModelConfig, ex):
    x = common.shard_batch(
        params["embed"][tokens][:, None, :].astype(ex.compute_dtype), ex)
    a_cfg = cfg.attn

    def body(x, inp):
        lp, ck, cv, xk, xv = inp
        h = common.norm(x, lp["ln1"], cfg.norm_eps, ex.backend)
        att, ck, cv = attention.attn_decode(
            lp["attn"], h, ck, cv, pos, a_cfg, is_global=1,
            norm_eps=cfg.norm_eps, ex=ex)
        x = x + att
        h = common.norm(x, lp["ln_x"], cfg.norm_eps, ex.backend)
        x = x + attention.cross_decode(lp["xattn"], h, xk, xv, a_cfg)
        h = common.norm(x, lp["ln2"], cfg.norm_eps, ex.backend)
        x = x + common.mlp_apply(lp["mlp"], h, cfg.gated_mlp)
        return x, (ck, cv)

    x, (ck, cv) = common.layer_scan(ex, 
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    x = common.norm(x, params["final_norm"], cfg.norm_eps, ex.backend)
    logits = x[:, 0] @ params["embed"].T
    return logits, dict(cache, k=ck, v=cv)
