"""Shared model-execution config + small building blocks."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops


@dataclass(frozen=True)
class ExecConfig:
    """Runtime execution knobs (orthogonal to the architecture config)."""
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    # activation checkpointing policy applied to each scanned layer:
    #   'none' | 'full' | 'dots'
    remat: str = "none"
    attn_block: int = 128
    ssd_chunk: int = 128
    backend: Optional[str] = None      # kernel backend override
    # MoE dispatch implementation: 'dense' (padded-bucket einsum, pjit
    # partitions it) — 'shard_map' A2A lives in parallel/moe_a2a.py.
    moe_impl: str = "dense"
    # mesh axis names carrying the batch dim of activations; a
    # with_sharding_constraint is seeded after every embedding gather
    # (GSPMD cannot infer batch sharding through a 2D-sharded table
    # gather, so without this the whole model runs batch-replicated).
    batch_axes: Optional[tuple] = None
    # mesh axis for Megatron-style sequence parallelism: the (B, S, D)
    # layer carry is kept sequence-sharded on this axis between blocks
    # (16x smaller remat residuals; GSPMD inserts the AG/RS pair around
    # attention/FFN exactly like Megatron-SP).
    seq_axis: Optional[str] = None
    # Period-grouped layer scan for alternating local/global archs: the
    # scan iterates over pattern periods and unrolls within, so every
    # sub-layer's window is STATIC (enables statically-skipped block
    # attention + correct AOT flop accounting).
    static_layer_pattern: bool = False
    # Fully unroll the layer scan (used by the dry-run depth variants so
    # XLA cost analysis sees every layer; scan bodies are counted once).
    layer_unroll: bool = False
    # MoE bucket sharding: scatter outputs have no inferable sharding, so
    # the (E, cap, D) dispatch buckets are constrained explicitly —
    # expert dim on ``moe_expert_axis`` when n_experts divides it (EP),
    # else the capacity dim on the batch axes.
    moe_expert_axis: Optional[str] = None
    # Additionally shard the CAPACITY dim of the buckets over these axes
    # (None = paper-faithful baseline, where each data-parallel rank
    # redundantly computes every expert's full capacity; setting this to
    # the batch axes is the §Perf hillclimb fix — 16x less expert compute
    # at the cost of a real all-to-all).
    moe_cap_axes: Optional[tuple] = None
    # concrete jax Mesh, required when moe_impl == "a2a" (shard_map path)
    mesh: Any = None

    def wrap_remat(self, fn):
        if self.remat == "none":
            return fn
        if self.remat == "full":
            return jax.checkpoint(fn)
        if self.remat == "dots":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.checkpoint_dots)
        raise ValueError(self.remat)


def layer_scan(ex: "ExecConfig", body, init, xs):
    """lax.scan for layer stacks, honouring ex.layer_unroll."""
    return jax.lax.scan(body, init, xs,
                        unroll=True if ex.layer_unroll else 1)


def shard_batch(x, ex: "ExecConfig"):
    """Constrain the leading (batch) dim of an activation to ex.batch_axes."""
    if ex.batch_axes is None:
        return x
    from jax.sharding import PartitionSpec as P
    spec = P(ex.batch_axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def shard_acts(x, ex: "ExecConfig"):
    """Constrain a (B, S, D) layer carry: batch over batch_axes and, when
    sequence parallelism is on, S over seq_axis."""
    if ex.batch_axes is None and ex.seq_axis is None:
        return x
    if x.ndim != 3 or x.shape[1] == 1 or ex.seq_axis is None:
        return shard_batch(x, ex)
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        x, P(ex.batch_axes, ex.seq_axis, None))


def initializer(key, shape, scale, dtype):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def dense_init(key, d_in, d_out, dtype, scale=None):
    if scale is None:
        scale = d_in ** -0.5
    return initializer(key, (d_in, d_out), scale, dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                     / head_dim)


def apply_rope(x, positions, theta: float):
    """x: (B, H, S, D); positions: (S,) or (B, S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (D/2,)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
        ang = ang[None, None]                          # (1,1,S,D/2)
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs
        ang = ang[:, None]                             # (B,1,S,D/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Norms / MLP
# ---------------------------------------------------------------------------
def norm(x, w, eps, backend=None):
    return ops.rmsnorm(x, w, eps=eps, backend=backend)


def mlp_apply(params, x, gated: bool):
    if gated:
        h = jax.nn.silu(x @ params["w1"]) * (x @ params["w3"])
    else:
        h = jax.nn.gelu(x @ params["w1"])
    return h @ params["w2"]


def mlp_init(key, d_model, d_ff, gated: bool, dtype):
    ks = jax.random.split(key, 3)
    p = {"w1": dense_init(ks[0], d_model, d_ff, dtype),
         "w2": dense_init(ks[1], d_ff, d_model, dtype)}
    if gated:
        p["w3"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def cross_entropy(logits, labels, *, logit_softcap=0.0, mask=None):
    """logits: (B,S,V) fp32-safe CE; labels: (B,S) int32.  mask: (B,S).

    The gold logit is extracted with a masked reduction rather than
    take_along_axis so a vocab-sharded logits tensor never gets
    all-gathered under pjit (the reduction stays local + one psum).
    """
    logits = logits.astype(jnp.float32)
    if logit_softcap:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    vocab_iota = jnp.arange(logits.shape[-1], dtype=labels.dtype)
    onehot = (labels[..., None] == vocab_iota)
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = lse - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
