"""Mamba2 (SSD) block: init / train / decode-step."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import common


def ssm_dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    d_xbc = di + 2 * s.n_groups * s.d_state
    return di, nh, d_xbc


def ssm_init(key, cfg: ModelConfig, dtype):
    s = cfg.ssm
    d = cfg.d_model
    di, nh, d_xbc = ssm_dims(cfg)
    ks = jax.random.split(key, 5)
    d_in_proj = 2 * di + 2 * s.n_groups * s.d_state + nh
    return {
        "in_proj": common.dense_init(ks[0], d, d_in_proj, dtype),
        "out_proj": common.dense_init(ks[1], di, d, dtype),
        "conv_w": common.initializer(ks[2], (s.conv_width, d_xbc),
                                     s.conv_width ** -0.5, dtype),
        "conv_b": jnp.zeros((d_xbc,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(dtype),
        "D": jnp.ones((nh,), dtype),
        "dt_bias": common.initializer(ks[3], (nh,), 0.5, dtype),
        "gate_norm": jnp.ones((di,), dtype),
    }


def _split_in_proj(proj, cfg: ModelConfig):
    s = cfg.ssm
    di, nh, _ = ssm_dims(cfg)
    gn = s.n_groups * s.d_state
    z, xbc, dt = jnp.split(proj, [di, di + di + 2 * gn], axis=-1)
    return z, xbc, dt  # dt: (..., nh)


def _causal_conv(xbc, w, b):
    """Depthwise causal conv1d.  xbc: (B, S, C); w: (W, C)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        pad, w[:, None, :],
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=xbc.shape[-1])
    return jax.nn.silu(out + b)


def ssm_train(params, x, cfg: ModelConfig, ex):
    """x: (B,S,D) -> (B,S,D)."""
    s_cfg = cfg.ssm
    b, s, _ = x.shape
    di, nh, _ = ssm_dims(cfg)
    gn = s_cfg.n_groups * s_cfg.d_state

    proj = x @ params["in_proj"]
    z, xbc, dt = _split_in_proj(proj, cfg)
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xs, bmat, cmat = jnp.split(xbc, [di, di + gn], axis=-1)
    xs = xs.reshape(b, s, nh, s_cfg.head_dim)
    bmat = bmat.reshape(b, s, s_cfg.n_groups, s_cfg.d_state)
    cmat = cmat.reshape(b, s, s_cfg.n_groups, s_cfg.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["A_log"].astype(jnp.float32))

    y = ops.ssd(xs, dt, a, bmat, cmat, chunk=ex.ssd_chunk,
                backend=ex.backend)
    y = y + xs * params["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(b, s, di)
    y = common.norm(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps,
                    ex.backend)
    return y @ params["out_proj"]


def ssm_init_state(cfg: ModelConfig, batch, dtype):
    s = cfg.ssm
    di, nh, d_xbc = ssm_dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, d_xbc), dtype),
        "ssm": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
    }


def ssm_decode(params, x, state, cfg: ModelConfig, ex):
    """One-token step.  x: (B,1,D).  Returns (y, new_state)."""
    s_cfg = cfg.ssm
    b = x.shape[0]
    di, nh, d_xbc = ssm_dims(cfg)
    gn = s_cfg.n_groups * s_cfg.d_state

    proj = x[:, 0] @ params["in_proj"]                  # (B, dproj)
    z, xbc, dt = _split_in_proj(proj, cfg)
    # conv over stored window + current input
    win = jnp.concatenate([state["conv"], xbc[:, None, :]], axis=1)
    conv = jnp.einsum("bwc,wc->bc", win.astype(jnp.float32),
                      params["conv_w"].astype(jnp.float32))
    xbc_t = jax.nn.silu(conv + params["conv_b"].astype(jnp.float32))
    new_conv = win[:, 1:].astype(state["conv"].dtype)

    xs, bmat, cmat = jnp.split(xbc_t, [di, di + gn], axis=-1)
    xs = xs.reshape(b, nh, s_cfg.head_dim)
    bmat = bmat.reshape(b, s_cfg.n_groups, s_cfg.d_state)
    cmat = cmat.reshape(b, s_cfg.n_groups, s_cfg.d_state)
    rep = nh // s_cfg.n_groups
    bh = jnp.repeat(bmat, rep, axis=1)                  # (B, nh, N)
    ch = jnp.repeat(cmat, rep, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["A_log"].astype(jnp.float32))

    decay = jnp.exp(dt * a[None, :])[..., None, None]   # (B,nh,1,1)
    upd = (dt[..., None, None] * bh[:, :, None, :] * xs[..., :, None])
    new_ssm = state["ssm"] * decay + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_ssm, ch)
    y = y + xs * params["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, di).astype(x.dtype)
    y = common.norm(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps,
                    ex.backend)
    out = (y @ params["out_proj"])[:, None, :]
    return out, {"conv": new_conv, "ssm": new_ssm}
