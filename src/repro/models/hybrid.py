"""Zamba2-style hybrid: Mamba2 backbone + ONE shared attention+MLP block
applied every ``hybrid_period`` layers (weights reused per application).

The layer stack is scanned in PERIOD groups: each scan step runs
``hybrid_period`` SSM layers then the shared block once — no lax.cond, so
compiled flop counts are exact and the shared-attn KV cache is simply the
per-period ys (n_apps = n_layers // period entries).  Leftover layers
(n_layers % period) run unrolled without the shared block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, common, ssm


def _shared_block_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": attention.attn_init(ks[0], cfg.d_model, cfg.attn, dtype),
        "mlp": common.mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                               cfg.gated_mlp, dtype),
    }


def hybrid_init(key, cfg: ModelConfig, ex: common.ExecConfig):
    dtype = ex.param_dtype
    k_embed, k_layers, k_shared = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)

    def one(k):
        return {"ln": jnp.ones((cfg.d_model,), dtype),
                "ssm": ssm.ssm_init(k, cfg, dtype)}

    return {
        "embed": common.initializer(k_embed, (cfg.vocab, cfg.d_model),
                                    0.02, dtype),
        "layers": jax.vmap(one)(layer_keys),
        "shared": _shared_block_init(k_shared, cfg, dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }


def n_shared_applications(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.hybrid_period


def _split(tree, cfg):
    p = cfg.hybrid_period
    n_full = cfg.n_layers // p
    main = jax.tree.map(
        lambda t: t[:n_full * p].reshape(n_full, p, *t.shape[1:]), tree)
    rest = jax.tree.map(lambda t: t[n_full * p:], tree)
    return main, rest, n_full, cfg.n_layers - n_full * p


def _ssm_layer(lp, x, cfg, ex):
    h = common.norm(x, lp["ln"], cfg.norm_eps, ex.backend)
    return common.shard_acts(x + ssm.ssm_train(lp["ssm"], h, cfg, ex), ex)


def _shared_train(shared, x, cfg, ex):
    h = common.norm(x, shared["ln1"], cfg.norm_eps, ex.backend)
    a, kv = attention.attn_train(shared["attn"], h, cfg.attn, window=None,
                                 norm_eps=cfg.norm_eps, ex=ex)
    x = x + a
    h = common.norm(x, shared["ln2"], cfg.norm_eps, ex.backend)
    x = common.shard_acts(
        x + common.mlp_apply(shared["mlp"], h, cfg.gated_mlp), ex)
    return x, kv


def hybrid_hidden(params, tokens, cfg: ModelConfig, ex, collect_kv=False):
    x = common.shard_batch(
        params["embed"][tokens].astype(ex.compute_dtype), ex)
    shared = params["shared"]
    main, rest, n_full, n_rest = _split(params["layers"], cfg)
    p = cfg.hybrid_period

    def body(x, lp_grp):
        for j in range(p):
            lp = jax.tree.map(lambda t: t[j], lp_grp)
            x = _ssm_layer(lp, x, cfg, ex)
        x, kv = _shared_train(shared, x, cfg, ex)
        return x, (kv if collect_kv else None)

    if not collect_kv:
        body = ex.wrap_remat(body)
    x, kvs = common.layer_scan(ex, body, x, main)
    for j in range(n_rest):
        lp = jax.tree.map(lambda t: t[j], rest)
        x = _ssm_layer(lp, x, cfg, ex)
    x = common.norm(x, params["final_norm"], cfg.norm_eps, ex.backend)
    return x, kvs


def hybrid_loss(params, batch, cfg: ModelConfig, ex):
    x, _ = hybrid_hidden(params, batch["tokens"], cfg, ex)
    logits = x @ params["embed"].T
    ce = common.cross_entropy(logits, batch["labels"],
                              mask=batch.get("loss_mask"))
    return ce, {"ce": ce, "aux": 0.0}


def hybrid_init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype):
    a = cfg.attn
    napps = n_shared_applications(cfg)
    return {
        "ssm": jax.vmap(lambda _: ssm.ssm_init_state(cfg, batch, dtype))(
            jnp.arange(cfg.n_layers)),
        "k": jnp.zeros((napps, batch, a.n_kv_heads, seq_len, a.head_dim),
                       dtype),
        "v": jnp.zeros((napps, batch, a.n_kv_heads, seq_len, a.head_dim),
                       dtype),
    }


def hybrid_prefill(params, tokens, cfg: ModelConfig, ex):
    """Prefill: shared-attn KV caches come out as the period-scan ys."""
    x, kvs = hybrid_hidden(params, tokens, cfg, ex, collect_kv=True)
    logits = x[:, -1] @ params["embed"].T
    b, s = tokens.shape
    cache = hybrid_init_cache(cfg, b, s, ex.compute_dtype)
    ck, cv = kvs
    return logits, dict(cache, k=ck.astype(ex.compute_dtype),
                        v=cv.astype(ex.compute_dtype))


def hybrid_decode_step(params, cache, tokens, pos, cfg: ModelConfig, ex):
    x = common.shard_batch(
        params["embed"][tokens][:, None, :].astype(ex.compute_dtype), ex)
    shared = params["shared"]
    a_cfg = cfg.attn
    p = cfg.hybrid_period
    main, rest, n_full, n_rest = _split(params["layers"], cfg)
    st_main, st_rest, _, _ = _split(cache["ssm"], cfg)

    def ssm_step(lp, x, st_conv, st_ssm):
        h = common.norm(x, lp["ln"], cfg.norm_eps, ex.backend)
        y, st = ssm.ssm_decode(lp["ssm"], h,
                               {"conv": st_conv, "ssm": st_ssm}, cfg, ex)
        return x + y, st

    def body(x, inp):
        lp_grp, stc, sts, ck, cv = inp
        new_c, new_s = [], []
        for j in range(p):
            lp = jax.tree.map(lambda t: t[j], lp_grp)
            x, st = ssm_step(lp, x, stc[j], sts[j])
            new_c.append(st["conv"])
            new_s.append(st["ssm"])
        h = common.norm(x, shared["ln1"], cfg.norm_eps, ex.backend)
        att, ck, cv = attention.attn_decode(
            shared["attn"], h, ck, cv, pos, a_cfg, is_global=1,
            norm_eps=cfg.norm_eps, ex=ex)
        x = x + att
        h = common.norm(x, shared["ln2"], cfg.norm_eps, ex.backend)
        x = x + common.mlp_apply(shared["mlp"], h, cfg.gated_mlp)
        return x, (jnp.stack(new_c), jnp.stack(new_s), ck, cv)

    x, (conv_m, ssm_m, ck, cv) = common.layer_scan(ex, 
        body, x, (main, st_main["conv"], st_main["ssm"],
                  cache["k"], cache["v"]))

    rest_c, rest_s = [], []
    for j in range(n_rest):
        lp = jax.tree.map(lambda t: t[j], rest)
        x, st = ssm_step(lp, x, st_rest["conv"][j], st_rest["ssm"][j])
        rest_c.append(st["conv"])
        rest_s.append(st["ssm"])

    conv = conv_m.reshape(n_full * p, *conv_m.shape[2:])
    ssm_st = ssm_m.reshape(n_full * p, *ssm_m.shape[2:])
    if n_rest:
        conv = jnp.concatenate([conv, jnp.stack(rest_c)], 0)
        ssm_st = jnp.concatenate([ssm_st, jnp.stack(rest_s)], 0)

    x = common.norm(x, params["final_norm"], cfg.norm_eps, ex.backend)
    logits = x[:, 0] @ params["embed"].T
    return logits, {"ssm": {"conv": conv, "ssm": ssm_st}, "k": ck, "v": cv}
