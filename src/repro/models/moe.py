"""Mixture-of-Experts layer: top-k router + capacity-padded dispatch.

The dense-compile path builds (E, capacity, D) buckets with sort-free
rank-based scatter and runs the expert FFNs as one batched einsum — the
same data movement the Pallas ``moe_gmm`` kernel performs on TPU, and the
form XLA SPMD can partition over an expert-sharded mesh axis (EP).  An
explicit shard_map all-to-all variant lives in parallel/moe_a2a.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models import common


def moe_init(key, d_model, m: MoEConfig, dtype):
    ks = jax.random.split(key, 4)
    e, f = m.n_experts, m.d_ff_expert
    scale = d_model ** -0.5
    return {
        "router": common.dense_init(ks[0], d_model, e, dtype,
                                    scale=d_model ** -0.5),
        "w1": common.initializer(ks[1], (e, d_model, f), scale, dtype),
        "w3": common.initializer(ks[2], (e, d_model, f), scale, dtype),
        "w2": common.initializer(ks[3], (e, f, d_model), f ** -0.5, dtype),
    }


def _capacity(n_tokens: int, m: MoEConfig) -> int:
    cap = int(n_tokens * m.top_k * m.capacity_factor / m.n_experts)
    return max(8, -(-cap // 8) * 8)   # round up to multiple of 8


def router_topk(logits, m: MoEConfig):
    """logits: (T, E) fp32 -> (weights (T,k), ids (T,k), aux_loss)."""
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, m.top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss
    e = logits.shape[-1]
    me = probs.mean(0)                                    # mean router prob
    one_hot = jax.nn.one_hot(ids[:, 0], e)                # primary expert
    ce = one_hot.mean(0)                                  # fraction routed
    aux = e * jnp.sum(me * ce)
    return weights, ids, aux


def _shard_buckets(t, ex):
    """Constrain an (E, cap, ...) tensor per ex.moe_expert_axis /
    ex.moe_cap_axes."""
    if ex.moe_expert_axis is None and ex.batch_axes is None \
            and ex.moe_cap_axes is None:
        return t
    from jax.sharding import PartitionSpec as P
    cap = ex.moe_cap_axes
    if ex.moe_expert_axis is not None:
        spec = P(ex.moe_expert_axis, cap, *([None] * (t.ndim - 2)))
    else:
        spec = P(None, cap if cap is not None else ex.batch_axes,
                 *([None] * (t.ndim - 2)))
    return jax.lax.with_sharding_constraint(t, spec)


def moe_apply(params, x, m: MoEConfig, ex):
    """x: (B, S, D) -> (y, aux_loss)."""
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    logits = (xf @ params["router"]).astype(jnp.float32)
    weights, ids, aux = router_topk(logits, m)

    cap = _capacity(t, m)
    e = m.n_experts
    flat_e = ids.reshape(-1)                               # (T*k,)
    tok_of = jnp.repeat(jnp.arange(t), m.top_k)            # (T*k,)

    # rank of each (token, choice) within its expert, in token order
    sort_idx = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_idx]
    group_start = jnp.searchsorted(sorted_e, jnp.arange(e))
    rank_sorted = jnp.arange(t * m.top_k) - group_start[sorted_e]
    ranks = jnp.zeros((t * m.top_k,), jnp.int32).at[sort_idx].set(
        rank_sorted.astype(jnp.int32))

    keep = ranks < cap
    slot = jnp.where(keep, ranks, cap)                     # cap = drop slot

    # dispatch: buckets (E, cap, D) — the scatter IS the A2A under an
    # expert-sharded constraint
    buckets = jnp.zeros((e, cap + 1, d), x.dtype)
    buckets = buckets.at[flat_e, slot].add(xf[tok_of], mode="drop")
    buckets = _shard_buckets(buckets[:, :cap], ex)

    # expert FFN: batched gated MLP over the expert dim
    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", buckets, params["w1"]))
         * jnp.einsum("ecd,edf->ecf", buckets, params["w3"]))
    h = _shard_buckets(h, ex)
    out_b = _shard_buckets(jnp.einsum("ecf,efd->ecd", h, params["w2"]), ex)

    # combine
    out_b = jnp.concatenate(
        [out_b, jnp.zeros((e, 1, d), out_b.dtype)], axis=1)
    gathered = out_b[flat_e, slot]                         # (T*k, D)
    gathered = gathered * (weights.reshape(-1, 1)
                           * keep[:, None]).astype(gathered.dtype)
    y = gathered.reshape(t, m.top_k, d).sum(1)
    return y.reshape(b, s, d), aux
