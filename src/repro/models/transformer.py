"""Decoder-only transformer LM (dense / MoE / VLM-backbone).

One scanned, homogeneous layer stack serves every layer: local/global
attention alternation is a per-layer dynamic window scalar (gemma), MoE vs
dense is static per-arch.  Compile time and HLO size are O(1) in depth.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, common, moe


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------
def layer_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": attention.attn_init(ks[0], cfg.d_model, cfg.attn, dtype),
    }
    if cfg.moe is not None:
        p["moe"] = moe.moe_init(ks[1], cfg.d_model, cfg.moe, dtype)
    else:
        p["mlp"] = common.mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                   cfg.gated_mlp, dtype)
    return p


def lm_init(key, cfg: ModelConfig, ex: common.ExecConfig):
    dtype = ex.param_dtype
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: layer_init(k, cfg, dtype))(layer_keys)
    params = {
        "embed": common.initializer(k_embed, (cfg.vocab, cfg.d_model),
                                    0.02, dtype),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = common.dense_init(k_head, cfg.d_model,
                                              cfg.vocab, dtype)
    return params


def layer_flags(cfg: ModelConfig):
    """(L,) int32 — 1 where the layer uses GLOBAL (full) attention."""
    l = cfg.n_layers
    if cfg.attn is None or cfg.attn.local_global_period == 0:
        return jnp.ones((l,), jnp.int32)   # uniform (window handled statically)
    p = cfg.attn.local_global_period
    idx = jnp.arange(l)
    return (idx % p == p - 1).astype(jnp.int32)


def _embed(params, tokens, cfg, ex, prefix_embeds=None):
    x = common.shard_batch(
        params["embed"][tokens].astype(ex.compute_dtype), ex)
    if prefix_embeds is not None:
        p = prefix_embeds.shape[1]
        x = jnp.concatenate(
            [prefix_embeds.astype(ex.compute_dtype), x[:, p:]], axis=1)
    return x


def _unembed(params, x, cfg):
    table = params.get("lm_head")
    if table is None:
        return x @ params["embed"].T
    return x @ table


def _cache_len(cfg: ModelConfig, seq_len: int):
    a = cfg.attn
    if a is not None and a.window and a.local_global_period == 0:
        return min(seq_len, a.window), a.window   # rolling
    return seq_len, None


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------
def _layer_train(x, lp, window, cfg, ex, collect_kv=False):
    h = common.norm(x, lp["ln1"], cfg.norm_eps, ex.backend)
    a, kv = attention.attn_train(lp["attn"], h, cfg.attn, window=window,
                                 norm_eps=cfg.norm_eps, ex=ex)
    x = x + a
    h = common.norm(x, lp["ln2"], cfg.norm_eps, ex.backend)
    if cfg.moe is not None:
        if ex.moe_impl == "a2a" and ex.mesh is not None:
            from repro.parallel.moe_a2a import moe_apply_a2a
            m, aux = moe_apply_a2a(lp["moe"], h, cfg.moe, ex, ex.mesh)
        else:
            m, aux = moe.moe_apply(lp["moe"], h, cfg.moe, ex)
    else:
        m, aux = common.mlp_apply(lp["mlp"], h, cfg.gated_mlp), 0.0
    x = common.shard_acts(x + m, ex)
    return x, aux, (kv if collect_kv else None)


def _use_period_path(cfg: ModelConfig, ex) -> bool:
    a = cfg.attn
    return (ex.static_layer_pattern and a is not None
            and a.local_global_period > 1)


def _split_periods(tree, period, n_layers):
    n_full = n_layers // period
    main = jax.tree.map(
        lambda t: t[:n_full * period].reshape(n_full, period,
                                              *t.shape[1:]), tree)
    rest = jax.tree.map(lambda t: t[n_full * period:], tree)
    return main, rest, n_full, n_layers - n_full * period


def _period_window(cfg: ModelConfig, j: int):
    """Static window for position j inside a pattern period."""
    a = cfg.attn
    return None if j == a.local_global_period - 1 else int(a.window)


def lm_hidden(params, tokens, cfg: ModelConfig, ex, prefix_embeds=None):
    """Full-sequence forward -> (hidden (B,S,D), aux_loss)."""
    x = _embed(params, tokens, cfg, ex, prefix_embeds)
    s = x.shape[1]

    if _use_period_path(cfg, ex):
        p = cfg.attn.local_global_period
        main, rest, n_full, n_rest = _split_periods(params["layers"], p,
                                                    cfg.n_layers)

        def pbody(carry, lp_grp):
            x, aux = carry
            for j in range(p):
                lp = jax.tree.map(lambda t: t[j], lp_grp)
                x, a_, _ = _layer_train(x, lp, _period_window(cfg, j),
                                        cfg, ex)
                aux = aux + a_
            return (x, aux), None

        pbody = ex.wrap_remat(pbody)
        (x, aux), _ = common.layer_scan(ex, pbody, (x, 0.0), main)
        for j in range(n_rest):
            lp = jax.tree.map(lambda t: t[j], rest)
            x, a_, _ = _layer_train(x, lp, _period_window(cfg, j), cfg, ex)
            aux = aux + a_
    else:
        flags = layer_flags(cfg)

        def body(carry, inp):
            x, aux = carry
            lp, flag = inp
            window = attention.layer_window(cfg.attn, flag, s) \
                if cfg.attn else None
            x, a_, _ = _layer_train(x, lp, window, cfg, ex)
            return (x, aux + a_), None

        body = ex.wrap_remat(body)
        (x, aux), _ = common.layer_scan(ex, body, (x, 0.0),
                                   (params["layers"], flags))
    x = common.norm(x, params["final_norm"], cfg.norm_eps, ex.backend)
    return x, aux


def lm_loss(params, batch, cfg: ModelConfig, ex):
    x, aux = lm_hidden(params, batch["tokens"], cfg, ex,
                       batch.get("prefix_embeds"))
    logits = _unembed(params, x, cfg)
    ce = common.cross_entropy(logits, batch["labels"],
                              logit_softcap=cfg.logit_softcap,
                              mask=batch.get("loss_mask"))
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}


def lm_prefill(params, tokens, cfg: ModelConfig, ex, prefix_embeds=None):
    """-> (last-position logits (B,V), cache dict)."""
    x = _embed(params, tokens, cfg, ex, prefix_embeds)
    s = tokens.shape[1]
    clen, rolling = _cache_len(cfg, s)

    def trim(kv):
        k, v = kv
        if rolling is not None and s > clen:
            k = k[:, :, -clen:]
            v = v[:, :, -clen:]
        return k, v

    if _use_period_path(cfg, ex):
        p = cfg.attn.local_global_period
        main, rest, n_full, n_rest = _split_periods(params["layers"], p,
                                                    cfg.n_layers)

        def pbody(x, lp_grp):
            ks, vs = [], []
            for j in range(p):
                lp = jax.tree.map(lambda t: t[j], lp_grp)
                x, _, kv = _layer_train(x, lp, _period_window(cfg, j),
                                        cfg, ex, collect_kv=True)
                k, v = trim(kv)
                ks.append(k)
                vs.append(v)
            return x, (jnp.stack(ks), jnp.stack(vs))

        x, (ck, cv) = common.layer_scan(ex, pbody, x, main)
        ck = ck.reshape(n_full * p, *ck.shape[2:])
        cv = cv.reshape(n_full * p, *cv.shape[2:])
        for j in range(n_rest):
            lp = jax.tree.map(lambda t: t[j], rest)
            x, _, kv = _layer_train(x, lp, _period_window(cfg, j), cfg, ex,
                                    collect_kv=True)
            k, v = trim(kv)
            ck = jnp.concatenate([ck, k[None]], 0)
            cv = jnp.concatenate([cv, v[None]], 0)
    else:
        flags = layer_flags(cfg)

        def body(carry, inp):
            x, aux = carry
            lp, flag = inp
            window = attention.layer_window(cfg.attn, flag, s) \
                if cfg.attn else None
            x, a, kv = _layer_train(x, lp, window, cfg, ex,
                                    collect_kv=True)
            k, v = trim(kv)
            return (x, aux + a), (k, v)

        (x, _), (ck, cv) = common.layer_scan(ex, body, (x, 0.0),
                                        (params["layers"], flags))
    x = common.norm(x, params["final_norm"], cfg.norm_eps, ex.backend)
    logits = _unembed(params, x[:, -1:], cfg)[:, 0]
    return logits, {"k": ck, "v": cv}


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype):
    """Zeroed KV cache sized for ``seq_len`` total positions."""
    a = cfg.attn
    clen, _ = _cache_len(cfg, seq_len)
    shape = (cfg.n_layers, batch, a.n_kv_heads, clen, a.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def lm_decode_step(params, cache, tokens, pos, cfg: ModelConfig, ex):
    """tokens: (B,) int32; pos: () int32.  -> (logits (B,V), new cache)."""
    x = common.shard_batch(
        params["embed"][tokens][:, None, :].astype(ex.compute_dtype), ex)
    flags = layer_flags(cfg)
    a_cfg = cfg.attn
    # rolling=None -> absolute positions; else rolling buffer of that size
    rolling = a_cfg.window if (a_cfg.window and
                               a_cfg.local_global_period == 0) else None

    def body(x, inp):
        lp, flag, ck, cv = inp
        h = common.norm(x, lp["ln1"], cfg.norm_eps, ex.backend)
        att, ck, cv = attention.attn_decode(
            lp["attn"], h, ck, cv, pos, a_cfg, is_global=flag,
            norm_eps=cfg.norm_eps, ex=ex, rolling_window=rolling)
        x = x + att
        h = common.norm(x, lp["ln2"], cfg.norm_eps, ex.backend)
        if cfg.moe is not None:
            m, _ = moe.moe_apply(lp["moe"], h, cfg.moe, ex)
        else:
            m = common.mlp_apply(lp["mlp"], h, cfg.gated_mlp)
        return x + m, (ck, cv)

    x, (ck, cv) = common.layer_scan(ex, 
        body, x, (params["layers"], flags, cache["k"], cache["v"]))
    x = common.norm(x, params["final_norm"], cfg.norm_eps, ex.backend)
    logits = _unembed(params, x[:, 0], cfg)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits, {"k": ck, "v": cv}
