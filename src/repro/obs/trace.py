"""Contextvar-scoped tracing spans — the host-side flight recorder.

A ``Tracer`` collects COMPLETED spans: every ``with span("name", k=v):``
block appends one ``{name, ts_ns, dur_ns, depth, args}`` record when it
exits, timestamped with ``time.perf_counter_ns`` relative to the
tracer's birth.  Spans nest lexically and are LIFO-checked — closing a
span that is not the innermost open one raises, as does a clock that
runs backwards, so a trace that exports cleanly is structurally sound
by construction.

The layer is built to be left in hot loops permanently: when no tracer
is installed (the default), ``span()`` returns a module-level no-op
singleton — no allocation, no clock read, two dict lookups — so
instrumented code costs nothing when tracing is off (pinned by an
allocation guard in tests/test_obs.py).

Install a tracer for a region with::

    with tracing() as tr:
        with span("study.run", driver="exhaustive"):
            ...
    export.chrome_trace_from_tracer(tr)

The contextvar scoping means concurrent tasks (threads, asyncio) each
see their own tracer, and library code never needs a tracer argument.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from contextvars import ContextVar
from typing import Any, Dict, List, Optional, Tuple

_TRACER: ContextVar[Optional["Tracer"]] = ContextVar(
    "repro_obs_tracer", default=None)


class Tracer:
    """Accumulates completed spans and counter samples for one region."""

    def __init__(self):
        self.t0_ns = time.perf_counter_ns()
        self.events: List[Dict[str, Any]] = []
        # (name, ts_ns, value) — cumulative counter values over time,
        # exported as Chrome-trace "C" counter tracks
        self.counter_samples: List[Tuple[str, int, float]] = []
        self._stack: List["_Span"] = []

    def now_ns(self) -> int:
        return time.perf_counter_ns() - self.t0_ns

    def sample(self, name: str, value: float) -> None:
        self.counter_samples.append((name, self.now_ns(), float(value)))

    @property
    def depth(self) -> int:
        return len(self._stack)


class _Span:
    """Live span; records itself on the owning tracer at ``__exit__``."""

    __slots__ = ("tracer", "name", "args", "start_ns", "_depth")

    def __init__(self, tracer: Tracer, name: str,
                 args: Optional[Dict[str, Any]]):
        self.tracer = tracer
        self.name = name
        self.args = args
        self.start_ns = 0
        self._depth = 0

    def __enter__(self) -> "_Span":
        tr = self.tracer
        self._depth = len(tr._stack)
        tr._stack.append(self)
        self.start_ns = tr.now_ns()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        tr = self.tracer
        if not tr._stack or tr._stack[-1] is not self:
            open_name = tr._stack[-1].name if tr._stack else None
            raise RuntimeError(
                f"span {self.name!r} closed out of LIFO order "
                f"(innermost open span: {open_name!r})")
        tr._stack.pop()
        end_ns = tr.now_ns()
        if end_ns < self.start_ns:
            raise RuntimeError(
                f"span {self.name!r}: end {end_ns} < start "
                f"{self.start_ns} — non-monotonic clock")
        tr.events.append({"name": self.name, "ts_ns": self.start_ns,
                          "dur_ns": end_ns - self.start_ns,
                          "depth": self._depth, "args": self.args})
        return False


class _NullSpan:
    """Zero-cost stand-in handed out when tracing is disabled."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


_NULL_SPAN = _NullSpan()


def span(name: str, **args: Any):
    """Context manager timing one region.  With no tracer installed this
    returns a shared no-op singleton: safe (and free) in hot loops."""
    tr = _TRACER.get()
    if tr is None:
        return _NULL_SPAN
    return _Span(tr, name, args or None)


def current_tracer() -> Optional[Tracer]:
    return _TRACER.get()


@contextmanager
def tracing(tracer: Optional[Tracer] = None):
    """Install ``tracer`` (or a fresh one) for the dynamic extent of the
    block; yields the tracer for export."""
    tr = tracer if tracer is not None else Tracer()
    token = _TRACER.set(tr)
    try:
        yield tr
    finally:
        _TRACER.reset(token)
    if tr._stack:
        raise RuntimeError(
            f"{len(tr._stack)} span(s) never closed "
            f"(innermost: {tr._stack[-1].name!r})")
