"""Unified benchmark floor gate — ``python -m repro.cli bench check``.

One place owns the CI regression floors that used to be duplicated as
module constants across ``benchmarks/*.py``: the defaults below,
overridable by a ``quick_floors`` block committed in the matching
``BENCH_*.json`` snapshot.  ``run_checks`` re-measures each subsystem's
quick workload fresh — the same shapes the benchmark scripts' ``--quick``
modes time — reads the rates off ``StudyResult.provenance.metrics``
where the study path is involved, and compares against the floors with
one uniform pass/fail report.  The benchmark scripts delegate their
quick-mode gating here (``enforce``), so a floor lives in exactly one
file.

Floors are deliberately far below a warm laptop-class machine so only a
real regression — a per-row Python loop, a dead cache, a quadratic
rebalance — trips them, not a noisy shared runner.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

DEFAULT_FLOORS: Dict[str, Dict[str, float]] = {
    "study": {"points_per_s_study": 30_000.0},
    "outer": {"points_per_s_requested": 50_000.0,
              "speedup_requested_pts_per_s": 3.0},
    # jitted xla-path kernel calls/s on CPU (benchmarks/kernels_micro
    # --quick); floors catch an interpret-mode fallback or a per-row
    # python loop (100-1000x), not host noise
    "kernels": {"flash_attn_fwd_calls_per_s": 2.0,
                "rmsnorm_calls_per_s": 20.0,
                "ssd_calls_per_s": 1.0},
    # the two batch floors gate the SAME K=64 top-records batch through
    # each wavefront backend of repro.events.batch.replay_batch (warm
    # laptop-class measurements: ~70k numpy, ~400k jax records/s);
    # fused_compile_replay_per_s gates the END-TO-END event stage —
    # events.compile_batch + replay on the auto backend, the path the
    # study re-rank and outer replay take (warm: ~100k+ records/s)
    "events": {"events_per_s": 10_000.0,
               "batch_records_per_s": 8_000.0,
               "batch_records_per_s_jax": 40_000.0,
               "fused_compile_replay_per_s": 20_000.0},
}

BENCH_FILES = {"study": "BENCH_study.json", "outer": "BENCH_outer.json",
               "events": "BENCH_events.json",
               "kernels": "BENCH_kernels.json"}

BATCH_K = 64          # batch-replay width of the events check


def load_floors(which: str, root: Optional[Path] = None
                ) -> Dict[str, float]:
    """Defaults overlaid with the ``quick_floors`` block of the
    committed ``BENCH_<which>.json`` (when present)."""
    if which not in DEFAULT_FLOORS:
        raise KeyError(f"unknown bench {which!r}; known: "
                       f"{sorted(DEFAULT_FLOORS)}")
    floors = dict(DEFAULT_FLOORS[which])
    path = Path(root or ".") / BENCH_FILES[which]
    if path.exists():
        data = json.loads(path.read_text())
        for k, v in data.get("quick_floors", {}).items():
            floors[k] = float(v)
    return floors


def enforce(which: str, measured: Dict[str, float],
            root: Optional[Path] = None) -> List[dict]:
    """Compare ``measured`` against the floors for ``which``; prints one
    uniform OK/FAIL line per floor and returns the row dicts."""
    floors = load_floors(which, root)
    rows = []
    for name, floor in sorted(floors.items()):
        if name not in measured:
            raise KeyError(f"bench {which!r}: floor {name!r} has no "
                           f"measured value (got {sorted(measured)})")
        value = float(measured[name])
        ok = value >= floor
        mark = "OK  " if ok else "FAIL"
        print(f"  {mark} {which}.{name}: {value:,.1f} "
              f"(floor {floor:,.1f})")
        rows.append({"bench": which, "metric": name, "value": value,
                     "floor": floor, "ok": ok})
    return rows


# ---------------------------------------------------------------------------
# Shared wall-clock timing (kernel benchmarks + the profiling harness)
# ---------------------------------------------------------------------------
def time_fn(fn, *args, reps: int = 3, warmup: int = 1) -> float:
    """Best-of-``reps`` wall seconds per ``fn(*args)`` call after
    ``warmup`` untimed calls (jit compile + first dispatch).

    ``jax.block_until_ready`` accepts any pytree, so tuple-returning
    kernels need no special casing (the old
    ``benchmarks/kernels_micro._time`` re-ran the function once just to
    probe tuple-ness and branched on it).
    """
    import jax
    for _ in range(max(int(warmup), 0)):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(max(int(reps), 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


# ---------------------------------------------------------------------------
# Quick measurements (the shapes benchmarks/*.py --quick time)
# ---------------------------------------------------------------------------
def quick_study_scenario():
    from repro.api import Scenario
    return Scenario(model="tinyllama_1_1b", total_tflops=4e6,
                    seq_len=4096, global_batch=512, fabrics=("oi",),
                    name="tinyllama_study_quick")


def quick_outer_scenario():
    from repro.api import Scenario
    return Scenario(model="tinyllama_1_1b", total_tflops=1e5,
                    seq_len=4096, global_batch=256, dies_per_mcm=(16,),
                    m=(6,), cpo_ratio=(0.6,), driver="chiplight-outer",
                    driver_kw={"rounds": 4, "walkers": 6,
                               "inner_budget": 16},
                    keep_top=64, name="tinyllama_outer_quick")


def scalar_outer_variant(sc):
    """The pre-population single-walker flow of the same scenario."""
    kw = dict(sc.driver_kw)
    rounds = kw.get("rounds", kw.get("outer_iters", 8))
    return sc.replace(driver_kw={
        "method": "scalar", "inner_method": "scalar",
        "outer_iters": rounds,
        "inner_budget": kw.get("inner_budget", 48)})


def quick_events_scenario():
    from repro.api import Scenario
    return Scenario(model="tinyllama_1_1b", total_tflops=1e6,
                    seq_len=4096, global_batch=256, fabrics=("oi",),
                    refine_top=8, name="tinyllama_events_quick")


def pipelined_programs(sc, schedule: str = "1f1b", top: int = 8,
                       deep: bool = False) -> Tuple[object, List]:
    """Compile the top records of one study into ``StepProgram``s and
    return ``(prog, built)`` where ``prog`` is a PIPELINED program (big
    DAG — the realistic engine load).  Top records are often pp=1, so
    when needed the best feasible pp>1 strategy on the winning MCM is
    substituted (also replacing ``built[0]``).  ``deep=True`` always
    substitutes the DEEPEST feasible pipeline instead (max ``pp *
    n_micro`` on the winning MCM) — the worst-case wavefront DAG the
    replay benchmarks stress."""
    from repro.api import Study
    from repro.events import compile_step
    from repro.events.validate import _rebuild, _top_records
    res = Study(sc).run()
    built = []
    for i in _top_records(res, top):
        s, mcm, topo, fabric = _rebuild(res.records[i], sc)
        built.append(compile_step(sc.build_workload(), s, mcm,
                                  fabric=fabric, topo=topo,
                                  reuse=sc.reuse, hw=sc.build_hw(),
                                  schedule=schedule))
    built.sort(key=lambda p: -(p.n_stages * p.n_micro))
    prog = built[0]
    if prog.n_stages == 1 or deep:
        from repro.core.optimizer import enumerate_strategies
        from repro.core.simulator import simulate
        w, hw = sc.build_workload(), sc.build_hw()
        mcm = built[0].mcm
        best = None
        for s in enumerate_strategies(w, mcm):
            if s.pp <= 1:
                continue
            r = simulate(w, s, mcm, hw=hw)
            if not r.feasible:
                continue
            rank = s.pp * s.n_micro if deep else r.throughput
            if best is None or rank > best[1]:
                best = (s, rank)
        if best is not None:
            prog = compile_step(w, best[0], mcm, reuse=sc.reuse, hw=hw,
                                schedule=schedule)
            built[0] = prog
    return prog, built


def top_record_batch(sc, k: int = BATCH_K, top: int = 8):
    """``(w, hw, strategies, mcms, topos, fabrics)`` of one study's top
    records cycled out to ``k`` rows — the record set the fused
    compile+replay stage (``events.compile_batch``) and its
    compile-per-record baseline both consume.  Like
    ``pipelined_programs``, pp=1 records are replaced by the best
    feasible PIPELINED strategies on the winning MCM: a pp=1 record
    compiles to a two-node program, so an all-pp=1 batch would time the
    degenerate path, not the schedule recurrence the event stage
    exists for."""
    from repro.api import Study
    from repro.events.validate import _rebuild, _top_records
    res = Study(sc).run()
    w, hw = sc.build_workload(), sc.build_hw()
    recs = [_rebuild(res.records[i], sc, hw=hw)
            for i in _top_records(res, top)]
    piped = [r for r in recs if r[0].pp > 1]
    if len(piped) < max(2, top // 2):
        from repro.core.optimizer import enumerate_strategies
        from repro.core.simulator import simulate
        _s0, mcm, _t0, fabric = recs[0]
        cand = []
        for s in enumerate_strategies(w, mcm):
            if s.pp <= 1:
                continue
            r = simulate(w, s, mcm, hw=hw)
            if r.feasible:
                cand.append((r.throughput, s))
        cand.sort(key=lambda c: -c[0])
        # topo=None: the batch compiler derives the allocation per row,
        # exactly what compile_step does for a fresh strategy
        piped += [(s, mcm, None, fabric)
                  for _, s in cand[: top - len(piped)]]
    recs = piped or recs
    rows = [recs[i % len(recs)] for i in range(k)]
    return (w, hw, [r[0] for r in rows], [r[1] for r in rows],
            [r[2] for r in rows], [r[3] for r in rows])


def measure_study_quick(repeats: int = 3,
                        trace_path: Optional[str] = None
                        ) -> Dict[str, float]:
    """Best-of-``repeats`` study throughput, read off the
    ``provenance.metrics`` block; optionally writes the host trace of
    the final repeat to ``trace_path``."""
    from contextlib import nullcontext

    from repro.api import Study
    from repro.obs import (chrome_trace_from_tracer, tracing,
                           write_chrome_trace)
    study = Study(quick_study_scenario())
    study.run()                                            # warm-up
    best = 0.0
    for i in range(repeats):
        last = trace_path is not None and i == repeats - 1
        with tracing() if last else nullcontext() as tr:
            res = study.run()
        best = max(best, res.provenance["metrics"]["points_per_s"])
        if last:
            write_chrome_trace(trace_path, chrome_trace_from_tracer(tr))
            print(f"  wrote host trace {trace_path}")
    return {"points_per_s_study": best}


def measure_outer_quick(repeats: int = 2) -> Dict[str, float]:
    from repro.api import Study

    def rate(sc) -> float:
        study = Study(sc)
        best = 0.0
        for _ in range(repeats):
            res = study.run()
            p = res.provenance
            n_req = int(p.get("n_requested", p["n_sim"]))
            best = max(best, n_req / res.timings["total_s"])
        return best

    sc = quick_outer_scenario()
    pop = rate(sc)
    scalar = rate(scalar_outer_variant(sc))
    return {"points_per_s_requested": pop,
            "speedup_requested_pts_per_s": pop / scalar}


def measure_events_quick(repeats: int = 3) -> Dict[str, float]:
    """Scalar engine + BOTH wavefront backends on the same K=64
    top-records batch (the jax jit cache is warmed before timing, so
    the floor gates steady-state dispatch, not trace time)."""
    from repro.events import replay, replay_batch
    prog, built = pipelined_programs(quick_events_scenario())
    t_sc, n_events = float("inf"), 0
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = replay(prog)
        t_sc = min(t_sc, time.perf_counter() - t0)
        n_events = r.n_events
    programs = [built[i % len(built)] for i in range(BATCH_K)]
    out = {"events_per_s": n_events / t_sc}
    for backend, key in (("numpy", "batch_records_per_s"),
                         ("jax", "batch_records_per_s_jax")):
        replay_batch(programs, backend=backend)        # warm jit cache
        t_b = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            replay_batch(programs, backend=backend)
            t_b = min(t_b, time.perf_counter() - t0)
        out[key] = BATCH_K / t_b

    # fused end-to-end event stage: vectorized record->program compile
    # (events.compile_batch) + batch replay on the production "auto"
    # backend — the study re-rank / outer replay path
    from repro.events.compile_batch import compile_batch
    sc = quick_events_scenario()
    w, hw, ss, mcms, topos, fabs = top_record_batch(sc)

    def fused():
        cb = compile_batch(w, ss, mcms, fabric=fabs, topos=topos,
                           reuse=sc.reuse, hw=hw, schedule="1f1b")
        cb.replay(backend="auto")

    fused()                    # warm (jax trace at the auto bucket)
    t_f = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fused()
        t_f = min(t_f, time.perf_counter() - t0)
    out["fused_compile_replay_per_s"] = BATCH_K / t_f
    return out


def measure_kernels_quick(reps: int = 3) -> Dict[str, float]:
    """Jitted xla-path kernel calls/s — the shapes
    ``benchmarks/kernels_micro.py --quick`` gates."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops
    key = jax.random.PRNGKey(0)
    s = 256
    q = jax.random.normal(key, (1, 8, s, 64), jnp.float32)
    k = jax.random.normal(key, (1, 2, s, 64), jnp.float32)
    v = jax.random.normal(key, (1, 2, s, 64), jnp.float32)
    f_fa = jax.jit(lambda q_, k_, v_: ops.flash_attention(
        q_, k_, v_, block=128, backend="xla"))
    t_fa = time_fn(f_fa, q, k, v, reps=reps)

    x = jax.random.normal(key, (4096, 1024))
    w = jnp.ones((1024,))
    f_rn = jax.jit(lambda x_: ops.rmsnorm(x_, w))
    t_rn = time_fn(f_rn, x, reps=reps)

    bb, ss, h, p, g, n = 1, 512, 8, 64, 1, 64
    xs = jax.random.normal(key, (bb, ss, h, p))
    dt = jax.nn.softplus(jax.random.normal(key, (bb, ss, h)))
    a = -jnp.exp(jax.random.normal(key, (h,)) * 0.5)
    bm = jax.random.normal(key, (bb, ss, g, n)) * 0.3
    cm = jax.random.normal(key, (bb, ss, g, n)) * 0.3
    f_ssd = jax.jit(lambda *t: ops.ssd(*t, chunk=128, backend="xla"))
    t_ssd = time_fn(f_ssd, xs, dt, a, bm, cm, reps=reps)
    return {"flash_attn_fwd_calls_per_s": 1.0 / t_fa,
            "rmsnorm_calls_per_s": 1.0 / t_rn,
            "ssd_calls_per_s": 1.0 / t_ssd}


_MEASURE = {"study": measure_study_quick, "outer": measure_outer_quick,
            "events": measure_events_quick,
            "kernels": measure_kernels_quick}


def run_checks(which: Sequence[str] = ("study", "outer", "events"),
               trace_path: Optional[str] = None,
               root: Optional[Path] = None) -> int:
    """Measure + enforce each requested bench; returns 0 when every
    floor holds, 1 otherwise."""
    bad = sorted(set(which) - set(_MEASURE))
    if bad:
        raise KeyError(f"unknown bench(es) {bad}; known: "
                       f"{sorted(_MEASURE)}")
    rows: List[dict] = []
    for name in which:
        print(f"bench check: {name} (quick)")
        t0 = time.perf_counter()
        kwargs = {"trace_path": trace_path} if name == "study" else {}
        measured = _MEASURE[name](**kwargs)
        rows += enforce(name, measured, root=root)
        print(f"  ({time.perf_counter() - t0:.1f}s)")
    n_fail = sum(not r["ok"] for r in rows)
    if n_fail:
        print(f"FAIL: {n_fail}/{len(rows)} floors violated")
        return 1
    print(f"OK: all {len(rows)} floors hold")
    return 0
