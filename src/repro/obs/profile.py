"""Kernel profiling harness — execution-grounded cost measurements.

Runs the repo's real kernels (``repro.kernels.ops``: flash attention
fwd+bwd, moe_gmm, ssd, rmsnorm, decode_attention) over an (M, N) shape
grid and reports, per measurement, the achieved FLOP/s and bytes/s
alongside the analytic FLOP/byte counts.  ``repro.calib`` fits the
analytic cost constants from these measurements — effective peak
FLOP/s, effective HBM bandwidth, and the ``M/(M+half)`` saturation
curves behind ``core/simulator._gemm_eff`` — and writes the
schema-versioned ``CALIB.json`` artifact the rest of the stack consumes
(``HW.calibrated``, ``Scenario.calibration``, ``cli calibrate``).

Every timed grid point runs under a ``profile.measure`` span and
samples the achieved rates onto the installed tracer as
``profile.achieved_tflops`` / ``profile.achieved_gbs`` gauge tracks, so
``cli calibrate --trace`` renders the whole grid as a Perfetto timeline
with counter tracks over it.

On CPU the harness exercises the xla (blockwise-jnp) kernel path: the
absolute rates are host numbers, but they saturate with M exactly like
the accelerator curves — which is what the fit extracts.  On a TPU host
``default_backend()`` selects the Pallas kernels and the same harness
measures those.  jax and the kernel package are imported lazily so
``repro.obs`` itself stays import-light.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.obs import metrics
from repro.obs.trace import span

# kernels the harness knows how to drive, in measurement order
PROFILE_KERNELS = ("flash_attention_fwd", "flash_attention_bwd",
                   "moe_gmm", "ssd", "rmsnorm", "decode_attention")

# roofline regime each kernel's curve is fitted in (repro.calib):
# compute-bound kernels fit achieved FLOP/s, memory-bound kernels fit
# achieved bytes/s
KERNEL_KIND = {
    "flash_attention_fwd": "compute",
    "flash_attention_bwd": "compute",
    "moe_gmm": "compute",
    "ssd": "compute",
    "rmsnorm": "memory",
    "decode_attention": "memory",
}

_F32 = 4  # bytes per element; the harness measures in float32 throughout


def _grids(quick: bool) -> Dict[str, List[int]]:
    """M-axis grid per kernel (sequence length / rows / tokens /
    cache length).  ``quick`` drops the most expensive point and is the
    CI / ``--check`` grid — a strict prefix of the full grid so quick
    fits stay comparable to the committed full-grid artifact."""
    g = {
        "flash_attention_fwd": [128, 256, 512, 1024, 2048],
        "flash_attention_bwd": [128, 256, 512, 1024],
        "moe_gmm": [64, 128, 256, 512, 1024, 2048],
        "ssd": [128, 256, 512, 1024],
        "rmsnorm": [128, 512, 2048, 8192, 32768],
        "decode_attention": [512, 2048, 8192, 16384],
    }
    if quick:
        g = {k: v[:-1] for k, v in g.items()}
    return g


# N-axis grid (TP-sharded width) for the grouped matmul: fixed M, swept
# N — fits the ``N/(N+gemm_n_half)`` width-dimension curve
_MOE_N_GRID = [32, 64, 128, 256, 512]
_MOE_N_GRID_QUICK = [32, 64, 128, 256]


# ---------------------------------------------------------------------------
# Per-kernel workloads: build (jitted fn, args, flops, bytes, shape)
# ---------------------------------------------------------------------------
def _fa_case(s: int, bwd: bool):
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops
    b, h, d = 1, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, h, s, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, h, s, d), jnp.float32)
    block = min(128, s)

    def fwd(q_, k_, v_):
        return ops.flash_attention(q_, k_, v_, causal=True, block=block,
                                   backend="xla")

    if bwd:
        # fwd + bwd in one call (the custom-VJP recompute path): the
        # scan path executes every (masked) block, so ~2.5x fwd work on
        # top of the fwd pass
        fn = jax.jit(jax.grad(lambda *t: fwd(*t).sum(), argnums=(0, 1, 2)))
        flops = 14.0 * b * h * s * s * d
    else:
        fn = jax.jit(fwd)
        flops = 4.0 * b * h * s * s * d
    bytes_ = _F32 * (4.0 * b * h * s * d) * (3.0 if bwd else 1.0)
    return fn, (q, k, v), flops, bytes_, {"b": b, "h": h, "s": s, "d": d}


def _moe_case(t: int, n: int):
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops
    e, k = 4, 256
    sizes = [t // e] * e
    sizes[0] += t - sum(sizes)
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    x = jax.random.normal(ks[0], (t, k), jnp.float32)
    w = jax.random.normal(ks[1], (e, k, n), jnp.float32) * 0.1
    # group sizes are static (the xla/ref path requires concrete sizes)
    fn = jax.jit(lambda x_, w_: ops.moe_gmm(x_, w_, sizes, backend="xla"))
    flops = 2.0 * t * k * n
    bytes_ = _F32 * (t * k + e * k * n + t * n)
    return fn, (x, w), flops, bytes_, {"t": t, "e": e, "k": k, "n": n}


def _ssd_case(s: int):
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops
    b, h, p, g, n = 1, 4, 32, 1, 32
    chunk = min(64, s)
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bm = jax.random.normal(ks[3], (b, s, g, n)) * 0.3
    cm = jax.random.normal(ks[4], (b, s, g, n)) * 0.3
    fn = jax.jit(lambda *t: ops.ssd(*t, chunk=chunk, backend="xla"))
    # order-of-magnitude analytic count (state outer products + intra-
    # chunk attention-like term); only this kernel's own curve uses it
    flops = b * s * h * (6.0 * p * n + 2.0 * chunk * p)
    bytes_ = _F32 * b * s * (2.0 * h * p + h + 2.0 * g * n)
    return fn, (x, dt, a, bm, cm), flops, bytes_, \
        {"b": b, "s": s, "h": h, "p": p, "n": n, "chunk": chunk}


def _rmsnorm_case(rows: int):
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops
    d = 1024
    x = jax.random.normal(jax.random.PRNGKey(3), (rows, d), jnp.float32)
    w = jnp.ones((d,), jnp.float32)
    fn = jax.jit(lambda x_, w_: ops.rmsnorm(x_, w_, backend="xla"))
    flops = 4.0 * rows * d
    bytes_ = _F32 * (2.0 * rows * d + d)
    return fn, (x, w), flops, bytes_, {"rows": rows, "d": d}


def _decode_case(smax: int):
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops
    b, hq, hkv, d = 1, 8, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (b, hq, 1, d), jnp.float32)
    kc = jax.random.normal(ks[1], (b, hkv, smax, d), jnp.float32)
    vc = jax.random.normal(ks[2], (b, hkv, smax, d), jnp.float32)
    pos = jnp.int32(smax - 1)
    fn = jax.jit(lambda q_, k_, v_, p_: ops.decode_attention(q_, k_, v_, p_))
    flops = 4.0 * b * hq * smax * d
    bytes_ = _F32 * (2.0 * b * hkv * smax * d + 2.0 * b * hq * d)
    return fn, (q, kc, vc, pos), flops, bytes_, \
        {"b": b, "hq": hq, "hkv": hkv, "smax": smax, "d": d}


def _cases(name: str, quick: bool):
    """(axis, x, builder()) tuples for one kernel's grid."""
    grid = _grids(quick)[name]
    if name == "flash_attention_fwd":
        return [("m", s, lambda s=s: _fa_case(s, bwd=False)) for s in grid]
    if name == "flash_attention_bwd":
        return [("m", s, lambda s=s: _fa_case(s, bwd=True)) for s in grid]
    if name == "moe_gmm":
        cases = [("m", t, lambda t=t: _moe_case(t, n=256)) for t in grid]
        n_grid = _MOE_N_GRID_QUICK if quick else _MOE_N_GRID
        cases += [("n", n, lambda n=n: _moe_case(512, n=n))
                  for n in n_grid]
        return cases
    if name == "ssd":
        return [("m", s, lambda s=s: _ssd_case(s)) for s in grid]
    if name == "rmsnorm":
        return [("m", r, lambda r=r: _rmsnorm_case(r)) for r in grid]
    if name == "decode_attention":
        return [("m", s, lambda s=s: _decode_case(s)) for s in grid]
    raise KeyError(f"unknown kernel {name!r}; known: "
                   f"{list(PROFILE_KERNELS)}")


# ---------------------------------------------------------------------------
# The harness
# ---------------------------------------------------------------------------
def profile_kernels(kernels: Optional[Sequence[str]] = None, *,
                    quick: bool = False,
                    reps: Optional[int] = None) -> List[dict]:
    """Measure every requested kernel over its (M, N) grid.

    Returns one measurement dict per grid point: ``{kernel, kind, axis,
    x, shape, flops, bytes, time_s, flops_per_s, bytes_per_s, reps}``.
    Timing is best-of-``reps`` after a warm-up call (jit compile), via
    ``obs.bench.time_fn``.
    """
    from repro.obs.bench import time_fn
    names = tuple(kernels) if kernels else PROFILE_KERNELS
    bad = sorted(set(names) - set(PROFILE_KERNELS))
    if bad:
        raise KeyError(f"unknown kernel(s) {bad}; known: "
                       f"{list(PROFILE_KERNELS)}")
    reps = reps if reps is not None else (2 if quick else 3)
    out: List[dict] = []
    for name in names:
        kind = KERNEL_KIND[name]
        with span("profile.kernel", kernel=name, kind=kind):
            for axis, x, build in _cases(name, quick):
                fn, args, flops, bytes_, shape = build()
                with span("profile.measure", kernel=name, axis=axis,
                          x=x, reps=reps):
                    t = time_fn(fn, *args, reps=reps, warmup=1)
                m = {"kernel": name, "kind": kind, "axis": axis,
                     "x": int(x), "shape": shape, "flops": flops,
                     "bytes": bytes_, "time_s": t,
                     "flops_per_s": flops / t, "bytes_per_s": bytes_ / t,
                     "reps": reps}
                metrics.inc("profile.measurements")
                metrics.gauge("profile.achieved_tflops",
                              m["flops_per_s"] / 1e12)
                metrics.gauge("profile.achieved_gbs",
                              m["bytes_per_s"] / 1e9)
                out.append(m)
        metrics.inc("profile.kernels")
    return out
