"""``repro.obs`` — structured tracing, counters, Perfetto timelines.

Three zero-dependency layers (see DESIGN.md §observability):

* ``trace``   — contextvar-scoped nested spans with a no-op fast path;
* ``metrics`` — named counters/gauges, scoped registries, frozen
  JSON snapshot schema (``METRICS_SCHEMA``);
* ``export``  — Chrome Trace Event Format JSON (Perfetto /
  chrome://tracing) for both the host pipeline and the simulated
  training step, plus structural validation and per-track idle
  accounting;
* ``bench``   — the unified BENCH_*.json floor gate behind
  ``python -m repro.cli bench check`` (plus ``time_fn``, the shared
  kernel wall-clock timer);
* ``profile`` — the kernel profiling harness feeding ``repro.calib``
  and ``python -m repro.cli calibrate`` (jax imported lazily).
"""
from repro.obs.metrics import METRICS_SCHEMA, Metrics, gauge, inc, scope
from repro.obs.trace import Tracer, current_tracer, span, tracing
from repro.obs.export import (chrome_trace_from_event_result,
                              chrome_trace_from_tracer, track_idle,
                              validate_chrome_trace, write_chrome_trace)
from repro.obs.profile import PROFILE_KERNELS, profile_kernels

__all__ = [
    "METRICS_SCHEMA", "Metrics", "gauge", "inc", "scope",
    "Tracer", "current_tracer", "span", "tracing",
    "chrome_trace_from_event_result", "chrome_trace_from_tracer",
    "track_idle", "validate_chrome_trace", "write_chrome_trace",
    "PROFILE_KERNELS", "profile_kernels",
]
