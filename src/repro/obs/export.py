"""Chrome Trace Event Format export — open the JSON in Perfetto
(https://ui.perfetto.dev) or chrome://tracing.

Two producers share the format:

* ``chrome_trace_from_tracer`` — the HOST trace: where ``Study.run()``
  spent its wall time (sweep rounds, refinement, validation), one
  nested-span track plus one counter track per metric name.

* ``chrome_trace_from_event_result`` — the SIMULATED step: an
  ``EventResult`` replayed with ``record_timeline=True`` becomes one
  track per pipeline stage (compute tiles and PHASE-tagged collectives)
  plus one track per (rail, stage) resource, with OCS reconfigurations
  as instant markers and explicit ``ocs_wait`` stall spans.  Timestamps
  are simulated seconds scaled to microseconds, so a gpipe and an
  interleaved trace of the same design point are directly diffable —
  the bubble is the white space.

``validate_chrome_trace`` structurally checks the required keys and
types (what tests pin), and ``track_idle`` computes per-track busy/idle
from the events themselves — the basis of the schedule-bubble assertion
in tests/test_obs.py.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.obs.trace import Tracer

# Process ids in the simulated-step trace
PID_HOST = 1
PID_DEVICES = 1
PID_RAILS = 2

_NS_PER_US = 1000.0
_S_TO_US = 1e6


def _meta(pid: int, name: str, tid: Optional[int] = None) -> dict:
    ev = {"ph": "M", "pid": pid, "ts": 0,
          "name": "process_name" if tid is None else "thread_name",
          "args": {"name": name}}
    if tid is not None:
        ev["tid"] = tid
    return ev


# ---------------------------------------------------------------------------
# Host trace (Tracer -> spans + counter tracks)
# ---------------------------------------------------------------------------
def chrome_trace_from_tracer(tracer: Tracer,
                             process_name: str = "repro host") -> dict:
    events: List[dict] = [_meta(PID_HOST, process_name),
                          _meta(PID_HOST, "spans", tid=1)]
    for e in tracer.events:
        events.append({
            "name": e["name"], "cat": "host", "ph": "X",
            "ts": e["ts_ns"] / _NS_PER_US,
            "dur": e["dur_ns"] / _NS_PER_US,
            "pid": PID_HOST, "tid": 1,
            "args": dict(e["args"] or {}),
        })
    for name, ts_ns, value in tracer.counter_samples:
        events.append({
            "name": name, "cat": "metric", "ph": "C",
            "ts": ts_ns / _NS_PER_US, "pid": PID_HOST,
            "args": {"value": value},
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


# ---------------------------------------------------------------------------
# Simulated-step trace (EventResult -> device/rail tracks)
# ---------------------------------------------------------------------------
def chrome_trace_from_event_result(ev, title: str = "simulated step"
                                   ) -> dict:
    """Chrome trace of one event-engine replay.  ``ev`` must come from
    ``replay(prog, record_timeline=True)`` — otherwise the per-device
    and per-rail timelines are empty and there is nothing to draw."""
    if not ev.device_timeline:
        raise ValueError(
            "EventResult has no device timeline; replay the program "
            "with record_timeline=True")
    events: List[dict] = [
        _meta(PID_DEVICES, f"{title} [{ev.schedule}] devices"),
        _meta(PID_RAILS, f"{title} [{ev.schedule}] rails"),
    ]
    for s in range(ev.n_stages):
        events.append(_meta(PID_DEVICES, f"stage {s}", tid=s))
    rail_tid: Dict[Tuple[str, int], int] = {}
    for rail, s, _label, _t0, _t1 in ev.rail_timeline:
        rail_tid.setdefault((rail, s), len(rail_tid))
    for rail, s, _t, _w in ev.reconf_events:
        rail_tid.setdefault((rail, s), len(rail_tid))
    for (rail, s), tid in sorted(rail_tid.items(), key=lambda kv: kv[1]):
        events.append(_meta(PID_RAILS, f"rail {rail} / stage {s}",
                            tid=tid))
    for s, kind, phase, label, t0, t1 in ev.device_timeline:
        events.append({
            "name": label, "cat": kind, "ph": "X",
            "ts": t0 * _S_TO_US, "dur": (t1 - t0) * _S_TO_US,
            "pid": PID_DEVICES, "tid": int(s),
            "args": {"phase": phase, "kind": kind},
        })
    for rail, s, label, t0, t1 in ev.rail_timeline:
        events.append({
            "name": label, "cat": "rail", "ph": "X",
            "ts": t0 * _S_TO_US, "dur": (t1 - t0) * _S_TO_US,
            "pid": PID_RAILS, "tid": rail_tid[(rail, s)],
            "args": {"rail": rail},
        })
    for rail, s, t, wait in ev.reconf_events:
        tid = rail_tid[(rail, s)]
        events.append({
            "name": "ocs_reconfig", "cat": "ocs", "ph": "i", "s": "t",
            "ts": t * _S_TO_US, "pid": PID_RAILS, "tid": tid,
            "args": {"rail": rail, "wait_s": wait},
        })
        if wait > 0:
            events.append({
                "name": "ocs_wait", "cat": "ocs", "ph": "X",
                "ts": t * _S_TO_US, "dur": wait * _S_TO_US,
                "pid": PID_RAILS, "tid": tid,
                "args": {"rail": rail},
            })
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"schedule": ev.schedule,
                          "n_stages": ev.n_stages,
                          "n_micro": ev.n_micro,
                          "step_time_s": ev.step_time,
                          "bubble": ev.bubble}}


# ---------------------------------------------------------------------------
# IO + structural validation
# ---------------------------------------------------------------------------
def write_chrome_trace(path, trace: dict) -> Path:
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(trace) + "\n")
    return p


def validate_chrome_trace(trace: dict) -> Dict[str, int]:
    """Structural check of the Chrome Trace Event Format contract; raises
    ``ValueError`` on the first violation, returns per-phase counts."""
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        raise ValueError("trace must be a dict with a 'traceEvents' key")
    evs = trace["traceEvents"]
    if not isinstance(evs, list):
        raise ValueError("'traceEvents' must be a list")
    counts: Dict[str, int] = {}
    for i, e in enumerate(evs):
        if not isinstance(e, dict):
            raise ValueError(f"event {i} is not an object")
        ph = e.get("ph")
        if not isinstance(ph, str) or not ph:
            raise ValueError(f"event {i} missing string 'ph'")
        if not isinstance(e.get("name"), str):
            raise ValueError(f"event {i} ({ph}) missing string 'name'")
        if not isinstance(e.get("pid"), int):
            raise ValueError(f"event {i} ({ph}) missing int 'pid'")
        if ph in ("X", "C", "i", "M"):
            if ph != "M" and not isinstance(e.get("ts"), (int, float)):
                raise ValueError(f"event {i} ({ph}) missing numeric 'ts'")
        else:
            raise ValueError(f"event {i} has unsupported phase {ph!r}")
        if ph == "X":
            if not isinstance(e.get("tid"), int):
                raise ValueError(f"event {i} (X) missing int 'tid'")
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(
                    f"event {i} (X) needs numeric 'dur' >= 0, got {dur!r}")
        elif ph == "i":
            if e.get("s") not in ("t", "p", "g"):
                raise ValueError(f"event {i} (i) needs scope 's' in "
                                 f"t/p/g, got {e.get('s')!r}")
        elif ph == "C":
            args = e.get("args")
            if not isinstance(args, dict) or not args or not all(
                    isinstance(v, (int, float)) for v in args.values()):
                raise ValueError(f"event {i} (C) needs numeric 'args'")
        elif ph == "M":
            if e.get("name") not in ("process_name", "thread_name"):
                raise ValueError(f"event {i} (M) has unknown metadata "
                                 f"name {e.get('name')!r}")
            if not isinstance(e.get("args"), dict):
                raise ValueError(f"event {i} (M) missing 'args'")
        counts[ph] = counts.get(ph, 0) + 1
    return counts


def track_idle(trace: dict, pid: int = PID_DEVICES
               ) -> Dict[int, Dict[str, float]]:
    """Per-track busy/idle (µs) for the "X" events of one process,
    measured against the process-wide [earliest start, latest end]
    window so tracks share a time base.  Busy is the union of event
    intervals (overlaps counted once); idle is the rest of the window —
    on a device track, the pipeline bubble."""
    per_tid: Dict[int, List[Tuple[float, float]]] = {}
    lo, hi = float("inf"), float("-inf")
    for e in trace["traceEvents"]:
        if e.get("ph") != "X" or e.get("pid") != pid:
            continue
        t0, t1 = float(e["ts"]), float(e["ts"]) + float(e["dur"])
        per_tid.setdefault(int(e["tid"]), []).append((t0, t1))
        lo, hi = min(lo, t0), max(hi, t1)
    out: Dict[int, Dict[str, float]] = {}
    span = max(hi - lo, 0.0) if per_tid else 0.0
    for tid, iv in per_tid.items():
        iv.sort()
        busy, cur0, cur1 = 0.0, iv[0][0], iv[0][1]
        for t0, t1 in iv[1:]:
            if t0 > cur1:
                busy += cur1 - cur0
                cur0, cur1 = t0, t1
            else:
                cur1 = max(cur1, t1)
        busy += cur1 - cur0
        out[tid] = {"span_us": span, "busy_us": busy,
                    "idle_us": max(span - busy, 0.0)}
    return out
