"""Named counters and gauges with a frozen JSON snapshot schema.

Counters are monotonically increasing event counts
(``batch_replay.scalar_fallback``, ``dse.cache.hits``); gauges are
last-write-wins levels (``batched_sim.jax_bucket``).  Names are dotted
``<subsystem>.<noun>[.<qualifier>]`` — see DESIGN.md §observability for
the naming discipline.

Two accumulation levels:

* a process-global root registry (``root()``) that everything folds
  into eventually, and
* contextvar-stacked SCOPES (``with scope() as m:``) giving a region —
  one ``Study.run()``, one fidelity harness sweep — its own registry.
  On exit a scope folds its counts into its parent (outer scope or the
  root), so per-run metric blocks and whole-process totals coexist.

``inc``/``gauge`` write to the innermost scope and, when a tracer is
installed (``repro.obs.trace``), also emit a counter sample so Perfetto
renders the counter as a track over time.  ``snapshot()`` is the frozen
wire format (``METRICS_SCHEMA``) embedded in ``StudyResult.provenance``
and round-tripped through its JSON artifact.
"""
from __future__ import annotations

from contextlib import contextmanager
from contextvars import ContextVar
from typing import Dict, Optional

from repro.obs import trace as _trace

# Frozen snapshot schema: {"schema": 1, "counters": {name: number},
# "gauges": {name: number}}.  Bump only on incompatible change.
METRICS_SCHEMA = 1

# Declared metric names.  Every ``inc``/``gauge`` call site with a
# literal name must use a name listed here — enforced statically by
# ``repro.analysis`` (the determinism/schema rule), so a typo'd or
# undeclared metric name fails `cli lint` instead of silently forking
# the snapshot schema consumers key on.
KNOWN_COUNTERS = frozenset({
    "batch_replay.jax_calls",
    "batch_replay.jax_pad_rows",
    "batch_replay.jax_retraces",
    "batch_replay.records",
    "batch_replay.scalar_fallback",
    "batched_sim.jax_calls",
    "batched_sim.jax_pad_rows",
    "batched_sim.jax_retraces",
    "compile_batch.records",
    "dse.cache.fallback_rows",
    "dse.cache.hits",
    "dse.cache.sim",
    "outer.event_replayed",
    "outer.variant_cache.hits",
    "outer.variants_evaluated",
    "profile.kernels",
    "profile.measurements",
})
KNOWN_GAUGES = frozenset({
    "batch_replay.jax_bucket",
    "batched_sim.jax_bucket",
    "profile.achieved_gbs",
    "profile.achieved_tflops",
})


class Metrics:
    """One registry of counters and gauges."""

    __slots__ = ("counters", "gauges")

    def __init__(self):
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}

    def inc(self, name: str, n: float = 1) -> float:
        v = self.counters.get(name, 0) + n
        self.counters[name] = v
        return v

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = value

    def snapshot(self) -> dict:
        return {"schema": METRICS_SCHEMA,
                "counters": dict(self.counters),
                "gauges": dict(self.gauges)}

    def fold_into(self, parent: "Metrics") -> None:
        for k, v in self.counters.items():
            parent.counters[k] = parent.counters.get(k, 0) + v
        parent.gauges.update(self.gauges)


_ROOT = Metrics()
_SCOPE: ContextVar[Optional[Metrics]] = ContextVar(
    "repro_obs_metrics", default=None)


def root() -> Metrics:
    """The process-global registry every scope eventually folds into."""
    return _ROOT


def active() -> Metrics:
    """The innermost scope, or the root when none is open."""
    m = _SCOPE.get()
    return m if m is not None else _ROOT


def inc(name: str, n: float = 1) -> None:
    """Add ``n`` to counter ``name`` in the active registry (and sample
    it on the installed tracer, if any)."""
    v = active().inc(name, n)
    tr = _trace.current_tracer()
    if tr is not None:
        tr.sample(name, v)


def gauge(name: str, value: float) -> None:
    """Set gauge ``name`` in the active registry (and, like ``inc``,
    sample it on the installed tracer so Perfetto renders the gauge as
    a counter track over time — e.g. the profiling harness's achieved
    FLOP/s)."""
    active().gauge(name, value)
    tr = _trace.current_tracer()
    if tr is not None:
        tr.sample(name, float(value))


@contextmanager
def scope():
    """Fresh registry for the block; folds into the parent on exit."""
    m = Metrics()
    token = _SCOPE.set(m)
    try:
        yield m
    finally:
        _SCOPE.reset(token)
        m.fold_into(active())
