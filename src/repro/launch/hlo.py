"""Compiled-HLO analysis: collective bytes + roofline terms.

``cost_analysis`` gives FLOPs and HBM bytes; collective traffic is NOT in
it, so we parse the post-SPMD HLO text and sum the bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Two aggregates are reported per op kind:
  * result_bytes — sum of output-shape bytes (raw),
  * wire_bytes   — ring-algorithm per-device traffic:
        all-reduce:       2 * size * (n-1)/n
        all-gather:       size * (n-1)/n          (size = result)
        reduce-scatter:   in_size * (n-1)/n  = result * (n-1)
        all-to-all:       size * (n-1)/n
        collective-permute: size
The collective roofline term uses wire_bytes / (chips * link_bw).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

import numpy as np

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:\w+\[[\d,]*\]\S*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(txt: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota format [num_groups,group_size]<=[...]
        return int(m.group(2))
    return 2


@dataclass
class CollectiveStats:
    result_bytes: Dict[str, float] = field(default_factory=dict)
    wire_bytes: Dict[str, float] = field(default_factory=dict)
    counts: Dict[str, int] = field(default_factory=dict)

    @property
    def total_wire(self) -> float:
        return sum(self.wire_bytes.values())

    @property
    def total_result(self) -> float:
        return sum(self.result_bytes.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    st = CollectiveStats()
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_txt, op = m.group(1), m.group(2)
        if "-done(" in line:
            continue   # async pair: count only the -start
        size = _shape_bytes(shape_txt)
        n = _group_size(line)
        if op == "all-reduce":
            wire = 2.0 * size * (n - 1) / max(n, 1)
        elif op == "all-gather":
            wire = size * (n - 1) / max(n, 1)
        elif op == "reduce-scatter":
            wire = size * (n - 1)
        elif op == "all-to-all":
            wire = size * (n - 1) / max(n, 1)
        else:  # collective-permute
            wire = size
        st.result_bytes[op] = st.result_bytes.get(op, 0.0) + size
        st.wire_bytes[op] = st.wire_bytes.get(op, 0.0) + wire
        st.counts[op] = st.counts.get(op, 0) + 1
    return st


# ---------------------------------------------------------------------------
# Roofline terms (TPU v5e per the assignment)
# ---------------------------------------------------------------------------
def roofline_terms(flops: float, hbm_bytes: float, wire_bytes: float,
                   n_chips: int, *, peak_flops=197e12, hbm_bw=819e9,
                   link_bw=50e9) -> Dict[str, float]:
    """All three terms in SECONDS (cluster-level work / cluster capacity).

    flops/hbm_bytes from cost_analysis are per-program (already per-device
    under SPMD? No — cost_analysis of an SPMD module reports the PER-DEVICE
    program).  wire_bytes likewise per-device.  So divide by per-chip peak.
    """
    return {
        "compute_s": flops / peak_flops,
        "memory_s": hbm_bytes / hbm_bw,
        "collective_s": wire_bytes / link_bw,
    }
