"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — required because
the dry-run must set XLA_FLAGS before any jax initialisation.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math

    import numpy as np
    from jax.sharding import Mesh
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) == need:
        return jax.make_mesh(shape, axes)
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devs)} — run "
            f"under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    return Mesh(np.array(devs[:need]).reshape(shape), axes)


def fsdp_axes(mesh) -> tuple:
    """Axes carrying the batch / FSDP dimension."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"


def make_mesh_from_plan(tp: int, dp: int, *, pod: int = 1):
    """Build a mesh realising a ChipLight ``ParallelPlan``'s TP x DP grid
    (EP/CP ride the data axis, see parallel/plan.py)."""
    if pod > 1:
        return jax.make_mesh((pod, dp, tp), ("pod", "data", "model"))
    return jax.make_mesh((dp, tp), ("data", "model"))
