import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- multi-pod AOT dry-run ------------------------------------------------
# Lowers + compiles every (architecture x input-shape x mesh) cell against
# the production mesh with ShapeDtypeStruct inputs (no allocation), prints
# memory_analysis / cost_analysis, parses collective bytes from the
# compiled HLO, and writes a JSON artifact per cell for the roofline
# benchmark.  Resumable: existing artifacts are skipped unless --force.
# ---------------------------------------------------------------------------
import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, get_config  # noqa: E402
from repro.launch import hlo as hlo_mod  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import (init_train_state, make_prefill_step,  # noqa
                                make_serve_step, make_train_step,
                                TrainState)
from repro.models import build_model  # noqa: E402
from repro.models.common import ExecConfig  # noqa: E402
from repro.optim import AdamWState  # noqa: E402
from repro.parallel.sharding import batch_specs, cache_specs, \
    param_specs  # noqa: E402

ART = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

# ExecConfig field overrides applied by the §Perf hillclimb harness
# (benchmarks/perf_iter.py) — empty for the baseline dry-run.
EXEC_OVERRIDES: dict = {}

# Cells skipped per DESIGN.md §shape-cell-skips (pure full attention at
# 500k decode; enc-dec audio backbone bounded at 1500 frames).
LONG_OK = {"mamba2_780m", "zamba2_7b", "mixtral_8x7b", "gemma2_2b",
           "gemma3_27b"}


def cell_enabled(arch: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch in LONG_OK
    return True


def _exec_config(cfg, multi_pod: bool, shape, counting: bool = False):
    """counting=True: the depth-variant compiles that feed the roofline —
    fully unrolled block loops so cost_analysis sees every FLOP.  The
    main (full-depth) compile only supplies memory_analysis and uses the
    compact scan formulation (same memory behaviour, much faster SPMD
    partitioning)."""
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    fsdp_size = 32 if multi_pod else 16
    if shape.kind == "decode" and shape.global_batch % fsdp_size != 0:
        batch_axes = None   # long_500k B=1: shard the KV cache, not batch
    # Megatron-style sequence parallelism between blocks for full-sequence
    # passes (16x smaller layer carries / remat residuals).
    seq_axis = "model" if shape.kind in ("train", "prefill") else None
    moe_axis = None
    if cfg.moe is not None and cfg.moe.n_experts % 16 == 0:
        moe_axis = "model"   # matches parallel.sharding._ep_on_model
    block = 2048 if shape.seq_len >= 32768 else 1024
    # larger SSD chunks at long seq (better MXU utilisation per chunk,
    # and 4x fewer chunk bodies in the counting compiles)
    chunk = 1024 if shape.seq_len >= 32768 else 256
    ex = ExecConfig(param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
                    remat="full", attn_block=block, ssd_chunk=chunk,
                    batch_axes=batch_axes, seq_axis=seq_axis,
                    backend="xla_blocked" if counting else "xla",
                    static_layer_pattern=True,
                    layer_unroll=counting,
                    moe_expert_axis=moe_axis)
    if EXEC_OVERRIDES:
        import dataclasses
        ex = dataclasses.replace(ex, **EXEC_OVERRIDES)
    return ex


def _depth_variants(cfg):
    """Two reduced-depth configs for the trip-count extrapolation.

    cost_analysis counts a lax.scan body ONCE regardless of trip count, so
    per-cell roofline terms are extrapolated from two depth points:
      term(L) = t1 + (L - L1) * (t2 - t1) / (L2 - L1).
    Period-structured archs step in whole periods; enc-dec scales both
    stacks together.
    """
    import dataclasses
    if cfg.family == "hybrid":
        p = cfg.hybrid_period
        return (dataclasses.replace(cfg, n_layers=p),
                dataclasses.replace(cfg, n_layers=2 * p),
                p, 2 * p, cfg.n_layers)
    if cfg.attn is not None and cfg.attn.local_global_period > 1:
        p = cfg.attn.local_global_period
        return (dataclasses.replace(cfg, n_layers=p),
                dataclasses.replace(cfg, n_layers=2 * p),
                p, 2 * p, cfg.n_layers)
    if cfg.family == "encdec":
        return (dataclasses.replace(cfg, n_layers=1, encoder_layers=1),
                dataclasses.replace(cfg, n_layers=2, encoder_layers=2),
                1, 2, cfg.n_layers)
    import dataclasses as dc
    return (dc.replace(cfg, n_layers=1), dc.replace(cfg, n_layers=2),
            1, 2, cfg.n_layers)


def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               cfg_override=None, layer_unroll=False):
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = SHAPES[shape_name]
    ex = _exec_config(cfg, multi_pod, shape, counting=layer_unroll)
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    if ex.moe_impl == "a2a":
        import dataclasses
        ex = dataclasses.replace(ex, mesh=mesh)

    params_shape = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0), ex))
    p_specs = param_specs(cfg, params_shape, mesh)
    p_sh = _ns(mesh, p_specs)

    if shape.kind == "train":
        step = make_train_step(cfg, ex)
        state_shape = jax.eval_shape(
            lambda: TrainState(
                params=params_shape,
                opt=AdamWState(
                    step=jax.ShapeDtypeStruct((), jnp.int32),
                    m=jax.tree.map(
                        lambda s: jax.ShapeDtypeStruct(s.shape,
                                                       jnp.float32),
                        params_shape),
                    v=jax.tree.map(
                        lambda s: jax.ShapeDtypeStruct(s.shape,
                                                       jnp.float32),
                        params_shape))))
        state_sh = TrainState(
            params=p_sh,
            opt=AdamWState(step=NamedSharding(mesh, P()),
                           m=jax.tree.map(lambda s: s, p_sh),
                           v=jax.tree.map(lambda s: s, p_sh)))
        batch_shape = model.input_specs(shape, ex, kind="train")
        bs = batch_specs(cfg, shape, mesh, kind="train")
        batch_sh = {k: NamedSharding(mesh, bs(k)) for k in batch_shape}
        with mesh:
            lowered = jax.jit(step, in_shardings=(state_sh, batch_sh)
                              ).lower(state_shape, batch_shape)
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, ex)
        batch_shape = model.input_specs(shape, ex, kind="prefill")
        bs = batch_specs(cfg, shape, mesh, kind="prefill")
        batch_sh = {k: NamedSharding(mesh, bs(k)) for k in batch_shape}
        with mesh:
            lowered = jax.jit(step, in_shardings=(p_sh, batch_sh)
                              ).lower(params_shape, batch_shape)
    else:  # decode
        step = make_serve_step(cfg, ex)
        specs = model.input_specs(shape, ex)
        c_rule = cache_specs(cfg, shape, mesh)
        cache_sh = jax.tree_util.tree_map_with_path(
            lambda p, l: NamedSharding(mesh, c_rule(p, l)), specs["cache"])
        # batch shards over fsdp axes only when divisible
        fsdp = tuple(a for a in mesh.axis_names if a != "model")
        fsdp_size = 1
        for a in fsdp:
            fsdp_size *= mesh.shape[a]
        tok_sh = NamedSharding(
            mesh, P(fsdp) if shape.global_batch % fsdp_size == 0
            else P())
        pos_sh = NamedSharding(mesh, P())
        with mesh:
            lowered = jax.jit(step, in_shardings=(
                p_sh, cache_sh, tok_sh, pos_sh)).lower(
                    params_shape, specs["cache"], specs["tokens"],
                    specs["pos"])
    return lowered, mesh, cfg, shape


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             force: bool = False, verbose: bool = True):
    ART.mkdir(parents=True, exist_ok=True)
    out_path = ART / f"{arch}__{shape_name}__{mesh_kind}.json"
    if out_path.exists() and not force:
        if verbose:
            print(f"[skip] {out_path.name} exists")
        return json.loads(out_path.read_text())
    if not cell_enabled(arch, shape_name):
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "skipped": True,
               "reason": "long_500k inapplicable (see DESIGN.md)"}
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    multi = mesh_kind == "multi"
    t0 = time.time()
    lowered, mesh, cfg, shape = lower_cell(arch, shape_name, multi)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    text = compiled.as_text()
    coll = hlo_mod.parse_collectives(text)
    n_chips = 512 if multi else 256

    # --- depth extrapolation (scan bodies are cost-counted once) ---
    # The roofline table is single-pod (assignment §Roofline); multi-pod
    # cells prove the pod-axis sharding compiles and reuse the single-pod
    # per-device terms scaled by the chip-count ratio.
    single_art = ART / f"{arch}__{shape_name}__single.json"
    if os.environ.get("DRYRUN_SKIP_COUNTING"):
        # fallback fidelity: raw scan-counted terms scaled by the layer
        # (period) count — used when the unrolled counting compiles are
        # impractical on this host; flagged in the artifact.
        p = cfg.hybrid_period if cfg.family == "hybrid" else 1
        reps = max(cfg.n_layers // max(p, 1), 1)
        flops_x = float(cost.get("flops", 0.0)) * reps
        bytes_x = float(cost.get("bytes accessed", 0.0)) * reps
        wire_x = coll.total_wire * reps
        pts, l1, l2 = [], 0, 0
    elif multi and single_art.exists():
        prev = json.loads(single_art.read_text())
        if not prev.get("skipped"):
            scale = prev["n_chips"] / 512.0
            flops_x = prev["hlo_flops_per_device"] * scale
            bytes_x = prev["hlo_bytes_per_device"] * scale
            wire_x = prev["coll_wire_bytes_per_device"] * scale
            pts, l1, l2 = prev["depth_points"]["pts"], 0, 0
        else:
            flops_x = bytes_x = wire_x = 0.0
            pts, l1, l2 = [], 0, 0
    else:
        cfg1, cfg2, l1, l2, l_full = _depth_variants(cfg)
        pts = []
        for cvar in (cfg1, cfg2):
            lw, _, _, _ = lower_cell(arch, shape_name, multi,
                                     cfg_override=cvar, layer_unroll=True)
            cc = lw.compile()
            cst = cc.cost_analysis() or {}
            cl = hlo_mod.parse_collectives(cc.as_text())
            pts.append((float(cst.get("flops", 0.0)),
                        float(cst.get("bytes accessed", 0.0)),
                        cl.total_wire))

        def extrap(i):
            t1, t2 = pts[0][i], pts[1][i]
            return t1 + (l_full - l1) * (t2 - t1) / max(l2 - l1, 1)

        flops_x, bytes_x, wire_x = extrap(0), extrap(1), extrap(2)

    def _mem(attr):
        return float(getattr(mem, attr, 0) or 0)

    tokens = (shape.global_batch * shape.seq_len
              if shape.kind != "decode" else shape.global_batch)
    mult = 6.0 if shape.kind == "train" else 2.0
    model_flops = mult * cfg.active_param_count() * tokens

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "kind": shape.kind, "n_chips": n_chips,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "hlo_flops_per_device": flops_x,
        "hlo_bytes_per_device": bytes_x,
        "coll_wire_bytes_per_device": wire_x,
        "raw_flops_per_device": float(cost.get("flops", 0.0)),
        "raw_bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "raw_wire_bytes_per_device": coll.total_wire,
        "depth_points": {"l1": l1, "l2": l2, "pts": pts},
        "coll_result_bytes_per_device": coll.total_result,
        "coll_breakdown": coll.wire_bytes,
        "coll_counts": coll.counts,
        "mem_argument_bytes": _mem("argument_size_in_bytes"),
        "mem_output_bytes": _mem("output_size_in_bytes"),
        "mem_temp_bytes": _mem("temp_size_in_bytes"),
        "mem_generated_code_bytes": _mem("generated_code_size_in_bytes"),
        "model_flops_step": model_flops,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "lower_s": t_lower, "compile_s": t_compile,
    }
    out_path.write_text(json.dumps(rec, indent=1))
    if verbose:
        print(f"[ok] {arch} {shape_name} {mesh_kind}: "
              f"flops/dev={rec['hlo_flops_per_device']:.3e} "
              f"bytes/dev={rec['hlo_bytes_per_device']:.3e} "
              f"wire/dev={rec['coll_wire_bytes_per_device']:.3e} "
              f"argbytes/dev={rec['mem_argument_bytes'] / 1e9:.2f}GB "
              f"temp/dev={rec['mem_temp_bytes'] / 1e9:.2f}GB "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        print("  memory_analysis:", mem)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=[None] + list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or args.arch is None) \
        else [args.arch.replace("-", "_").replace(".", "_")]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                try:
                    run_cell(arch, shape, mk, force=args.force)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mk, repr(e)))
                    print(f"[FAIL] {arch} {shape} {mk}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nALL CELLS OK")


if __name__ == "__main__":
    main()
