"""jit-able train / prefill / serve step builders shared by the trainer,
the server and the AOT dry-run."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.models.common import ExecConfig
from repro.optim import AdamWState, adamw_init, adamw_update, \
    cosine_schedule


class TrainState(NamedTuple):
    params: dict
    opt: AdamWState


def init_train_state(cfg: ModelConfig, ex: ExecConfig, seed: int = 0
                     ) -> TrainState:
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed), ex)
    return TrainState(params=params, opt=adamw_init(params))


def make_train_step(cfg: ModelConfig, ex: ExecConfig, *, base_lr=3e-4,
                    warmup=100, total=10000, accum: int = 1):
    """Returns train_step(state, batch) -> (state, metrics).

    accum > 1 folds gradient accumulation (microbatching) into the step:
    the batch's leading dim is split into ``accum`` microbatches scanned
    sequentially — the jax-native analogue of PP-style microbatching for
    memory, and the knob ChipLight's n_micro maps to on a 2D mesh.
    """
    model = build_model(cfg)
    lr_fn = cosine_schedule(base_lr, warmup, total)

    def loss_fn(params, batch):
        cast = jax.tree.map(lambda p: p.astype(ex.compute_dtype)
                            if jnp.issubdtype(p.dtype, jnp.floating)
                            else p, params)
        return model.loss(cast, batch, ex)

    def train_step(state: TrainState, batch):
        if accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
        else:
            def micro(carry, mb):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mb)
                return (jax.tree.map(jnp.add, gsum, g), lsum + l), None

            mbs = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum,
                                    *x.shape[1:]), batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (gsum, lsum), _ = jax.lax.scan(micro, (zero, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum
            metrics = {"ce": loss, "aux": 0.0}
        new_params, new_opt, om = adamw_update(state.params, grads,
                                               state.opt, lr_fn)
        metrics = dict(metrics, loss=loss, **om)
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, ex: ExecConfig):
    model = build_model(cfg)

    def prefill_step(params, batch):
        cast = jax.tree.map(lambda p: p.astype(ex.compute_dtype)
                            if jnp.issubdtype(p.dtype, jnp.floating)
                            else p, params)
        return model.prefill(cast, batch, ex)

    return prefill_step


def make_serve_step(cfg: ModelConfig, ex: ExecConfig):
    """One decode step: (params, cache, tokens, pos) -> (logits, cache)."""
    model = build_model(cfg)

    def serve_step(params, cache, tokens, pos):
        cast = jax.tree.map(lambda p: p.astype(ex.compute_dtype)
                            if jnp.issubdtype(p.dtype, jnp.floating)
                            else p, params)
        return model.decode_step(cast, cache, tokens, pos, ex)

    return serve_step
