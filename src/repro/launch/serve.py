"""Serving driver: batched prefill + decode loop.

``python -m repro.launch.serve --arch tinyllama-1.1b --reduced`` runs a
small batched generation end-to-end on CPU.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.models.common import ExecConfig


def generate(cfg, ex, prompt_len=32, gen_len=32, batch=2, seed=0):
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed), ex)
    shape = ShapeConfig("serve", "prefill", prompt_len, batch)
    batch_in = model.make_batch(jax.random.PRNGKey(seed + 1), shape, ex,
                                kind="prefill")

    prefill = jax.jit(lambda p, b: model.prefill(p, b, ex))
    decode = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos,
                                                            ex))

    logits, cache = prefill(params, batch_in)
    # decode caches sized for prompt+gen: rebuild cache with headroom
    full = model.init_cache(batch, prompt_len + gen_len, ex)
    cache = jax.tree.map(
        lambda dst, src: jax.lax.dynamic_update_slice(
            dst, src.astype(dst.dtype), (0,) * dst.ndim)
        if dst.shape != src.shape else src.astype(dst.dtype),
        full, cache)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    for i in range(gen_len - 1):
        logits, cache = decode(params, cache, tok,
                               jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    return jnp.stack(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--batch", type=int, default=2)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    ex = ExecConfig(ssd_chunk=8, attn_block=32)
    t0 = time.time()
    tokens = generate(cfg, ex, args.prompt_len, args.gen_len, args.batch)
    dt = time.time() - t0
    n = tokens.size
    print(f"generated {tokens.shape} tokens in {dt:.1f}s "
          f"({n / dt:.1f} tok/s)")
    print(tokens[:, :12])


if __name__ == "__main__":
    main()
