"""Training driver: ``python -m repro.launch.train --arch <id> ...``

Composes: config -> model -> sharded train step (pjit over the production
or a custom mesh) -> deterministic data pipeline -> fault-tolerant loop
with async checkpointing.  ``--chiplight`` runs the cross-layer DSE first
and prints the strategy it would deploy (TP/EP mapped to the model axis,
DP/CP to data — see parallel/plan.py).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeConfig
from repro.data import DataPipeline
from repro.checkpoint import CheckpointManager
from repro.launch.steps import TrainState, init_train_state, \
    make_train_step
from repro.models.common import ExecConfig
from repro.optim import AdamWState
from repro.parallel.sharding import batch_specs, param_specs
from repro.runtime import FaultTolerantLoop


def build_sharded_train(cfg, ex, mesh, shape, accum=1, base_lr=3e-4):
    step_fn = make_train_step(cfg, ex, base_lr=base_lr, accum=accum)
    params_shape = jax.eval_shape(
        lambda: init_train_state(cfg, ex).params)
    p_specs = param_specs(cfg, params_shape, mesh)
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                        is_leaf=lambda x: isinstance(x, P))
    state_sh = TrainState(
        params=p_sh,
        opt=AdamWState(step=NamedSharding(mesh, P()),
                       m=p_sh, v=p_sh))
    bs = batch_specs(cfg, shape, mesh, kind="train")
    jitted = jax.jit(step_fn, in_shardings=(state_sh, None),
                     donate_argnums=(0,))
    return jitted, state_sh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-runnable)")
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = ShapeConfig("cli", "train", args.seq, args.batch)
    ex = ExecConfig(ssd_chunk=min(64, args.seq), attn_block=128)

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev, 1), ("data", "model")) if n_dev > 1 \
        else jax.make_mesh((1, 1), ("data", "model"))

    with mesh:
        step_fn, state_sh = build_sharded_train(cfg, ex, mesh, shape,
                                                accum=args.accum,
                                                base_lr=args.lr)
        state = init_train_state(cfg, ex, seed=args.seed)
        pipeline = DataPipeline(cfg, shape, seed=args.seed, ex=ex)
        ckpt = CheckpointManager(args.ckpt_dir)
        loop = FaultTolerantLoop(step_fn, ckpt, pipeline,
                                 checkpoint_every=args.ckpt_every)
        start = 0
        if args.resume:
            state, start = loop.resume_or_init(state)
            print(f"resumed from step {start}")

        def on_metrics(step, metrics, dt):
            if step % 10 == 0 or step <= 3:
                print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"{dt * 1e3:.0f}ms")

        state, last = loop.run(state, args.steps, start_step=start,
                               on_metrics=on_metrics)
        print(f"done at step {last}; stragglers={loop.straggler_steps}")
    return state


if __name__ == "__main__":
    main()
