"""tinyllama-1.1b: llama2-architecture small dense LM.

[arXiv:2401.02385; hf] 22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.
"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="tinyllama_1_1b",
    family="dense",
    n_layers=22,
    d_model=2048,
    d_ff=5632,
    vocab=32000,
    attn=AttnConfig(n_heads=32, n_kv_heads=4, head_dim=64),
    tie_embeddings=False,
    supports_long_context=False,  # pure full attention
    source="arXiv:2401.02385",
)
