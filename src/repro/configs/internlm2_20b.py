"""internlm2-20b: dense GQA LM.

[arXiv:2403.17297; hf] 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544.
"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="internlm2_20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    d_ff=16384,
    vocab=92544,
    attn=AttnConfig(n_heads=48, n_kv_heads=8, head_dim=128,
                    rope_theta=1_000_000.0),
    tie_embeddings=False,
    supports_long_context=False,  # pure full attention
    source="arXiv:2403.17297",
)
