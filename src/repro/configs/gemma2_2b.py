"""gemma2-2b: local+global alternating attention, logit softcap.

[arXiv:2408.00118; hf] 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000,
window 4096, alternating local/global (period 2), attn softcap 50, final
logit softcap 30.
"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma2_2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    d_ff=9216,
    vocab=256000,
    attn=AttnConfig(n_heads=8, n_kv_heads=4, head_dim=256, window=4096,
                    local_global_period=2, attn_softcap=50.0),
    logit_softcap=30.0,
    tie_embeddings=True,
    supports_long_context=True,   # local layers bounded; global linear decode
    source="arXiv:2408.00118",
)
