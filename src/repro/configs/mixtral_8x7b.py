"""mixtral-8x7b: 8-expert top-2 MoE with sliding-window attention.

[arXiv:2401.04088; hf] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000,
MoE 8e top-2, SWA window 4096.
"""
from repro.configs.base import AttnConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral_8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab=32000,
    attn=AttnConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                    rope_theta=1_000_000.0, window=4096),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=14336),
    tie_embeddings=False,
    supports_long_context=True,   # SWA -> bounded KV, sub-quadratic
    source="arXiv:2401.04088",
)
