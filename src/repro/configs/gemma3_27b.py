"""gemma3-27b: 5:1 local:global attention, 128k context class.

[hf:google/gemma-3-1b-pt; unverified] 62L d_model=5376 32H (GQA kv=16)
d_ff=21504 vocab=262144, window 1024, period 6 (5 local : 1 global), QK-norm.
"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma3_27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    d_ff=21504,
    vocab=262144,
    attn=AttnConfig(n_heads=32, n_kv_heads=16, head_dim=128, window=1024,
                    local_global_period=6, qk_norm=True,
                    rope_theta=1_000_000.0),
    tie_embeddings=True,
    supports_long_context=True,
    source="hf:google/gemma-3-1b-pt (scaled)",
)
