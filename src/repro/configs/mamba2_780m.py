"""mamba2-780m: attention-free SSD (state-space duality) LM.

[arXiv:2405.21060; unverified] 48L d_model=1536 d_ff=0 vocab=50280,
ssm_state=128.  SSD: d_inner = 2*d_model = 3072, head_dim=64 -> 48 heads.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2_780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4,
                  chunk=128, n_groups=1),
    supports_long_context=True,   # O(1)-in-seq decode state
    source="arXiv:2405.21060",
)
