"""whisper-medium: enc-dec audio transformer backbone (conv frontend STUB).

[arXiv:2212.04356; unverified] 24L d_model=1024 16H (MHA kv=16) d_ff=4096
vocab=51865.  The audio conv frontend is stubbed: ``input_specs`` provides
precomputed frame embeddings of length ``encoder_len``.
"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper_medium",
    family="encdec",
    n_layers=24,
    d_model=1024,
    d_ff=4096,
    vocab=51865,
    attn=AttnConfig(n_heads=16, n_kv_heads=16, head_dim=64),
    encoder_layers=24,
    encoder_len=1500,
    gated_mlp=False,          # whisper uses plain GELU MLP
    tie_embeddings=True,
    supports_long_context=False,
    source="arXiv:2212.04356",
)
