"""qwen3-moe-235b-a22b: 128-expert top-8 MoE (the paper's target model).

[hf:Qwen/Qwen3-30B-A3B; hf] 94L d_model=4096 64H (GQA kv=4) d_ff_expert=1536
vocab=151936, MoE 128e top-8.
"""
from repro.configs.base import AttnConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3_moe_235b_a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    d_ff=12288,               # unused (all layers MoE); kept for reference
    vocab=151936,
    attn=AttnConfig(n_heads=64, n_kv_heads=4, head_dim=128,
                    rope_theta=1_000_000.0, qk_norm=True),
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536),
    tie_embeddings=False,
    supports_long_context=False,  # pure full attention
    source="hf:Qwen/Qwen3-30B-A3B (scaled per arXiv:2505.09388)",
)
