"""Model / shape configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``; reduced
versions (for CPU smoke tests) are derived with ``.reduced()``.  The FULL
configs are only ever lowered AOT (ShapeDtypeStruct) by the dry-run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class AttnConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    # Sliding window size (None = full attention everywhere).
    window: Optional[int] = None
    # Local:global alternating pattern period.  0 = uniform (all layers use
    # ``window`` if set, else full).  period=2 -> (local, global) alternating
    # (gemma2); period=6 -> 5 local + 1 global (gemma3).  Global layers use
    # full attention, local layers use ``window``.
    local_global_period: int = 0
    attn_softcap: float = 0.0
    qk_norm: bool = False


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    router_jitter: float = 0.0
    # capacity factor for padded (sort-based) dispatch
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    d_state: int
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128
    # number of B/C groups (like GQA for SSM)
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    attn: Optional[AttnConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): a *shared* attention+MLP block applied every
    # ``hybrid_period`` ssm layers (weights reused at every application).
    hybrid_period: int = 0
    # enc-dec (whisper): number of encoder layers and fixed source length of
    # the (stubbed) audio frontend output.
    encoder_layers: int = 0
    encoder_len: int = 0
    # vlm (llava): number of (stubbed) image-patch prefix embeddings.
    n_prefix_tokens: int = 0
    norm_eps: float = 1e-6
    logit_softcap: float = 0.0
    tie_embeddings: bool = True
    # llama-style gated MLP everywhere except whisper (gelu MLP)
    gated_mlp: bool = True
    # long_500k eligibility (sub-quadratic decode path); see DESIGN.md.
    supports_long_context: bool = False
    source: str = ""

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        period = self.attn.local_global_period if self.attn else 0
        n_layers = max(2, period) if period else 2
        if self.family == "hybrid":
            n_layers = 4
        attn = None
        if self.attn is not None:
            attn = dataclasses.replace(
                self.attn, n_heads=4, n_kv_heads=2, head_dim=16,
                window=(16 if self.attn.window else None))
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, n_experts=4, top_k=min(2, self.moe.top_k),
                d_ff_expert=32)
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(self.ssm, d_state=16, head_dim=8,
                                      chunk=8)
        return dataclasses.replace(
            self, n_layers=n_layers, d_model=64, d_ff=128, vocab=512,
            attn=attn, moe=moe, ssm=ssm,
            hybrid_period=2 if self.hybrid_period else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_len=16 if self.encoder_len else 0,
            n_prefix_tokens=8 if self.n_prefix_tokens else 0,
            name=self.name + "-reduced")

    # ------------------------------------------------------------------
    # Analytic parameter counts (used for roofline MODEL_FLOPS = 6*N*D and
    # by the ChipLight traffic/memory models).
    def _attn_params(self) -> int:
        a = self.attn
        if a is None:
            return 0
        d = self.d_model
        return (d * a.n_heads * a.head_dim            # q
                + 2 * d * a.n_kv_heads * a.head_dim   # k, v
                + a.n_heads * a.head_dim * d)         # o

    def _mlp_params(self, d_ff: int) -> int:
        mult = 3 if self.gated_mlp else 2
        return mult * self.d_model * d_ff

    def _ssm_params(self) -> int:
        s = self.ssm
        if s is None:
            return 0
        d = self.d_model
        di = s.d_inner(d)
        nh = s.n_heads(d)
        # in_proj produces [z, x, B, C, dt]
        in_proj = d * (2 * di + 2 * s.n_groups * s.d_state + nh)
        out_proj = di * d
        conv = s.conv_width * (di + 2 * s.n_groups * s.d_state)
        extra = nh * 3  # A_log, D, dt_bias
        return in_proj + out_proj + conv + extra

    def layer_params(self) -> int:
        """Parameters of one (decoder) layer, incl. norms."""
        d = self.d_model
        if self.family == "ssm":
            return self._ssm_params() + d
        if self.family == "hybrid":
            # ssm layer only; the shared block is counted once in param_count
            return self._ssm_params() + d
        p = self._attn_params() + 2 * d
        if self.moe is not None:
            router = d * self.moe.n_experts
            p += router + self.moe.n_experts * self._mlp_params(
                self.moe.d_ff_expert)
        else:
            p += self._mlp_params(self.d_ff)
        return p

    def param_count(self) -> int:
        p = self.n_layers * self.layer_params()
        p += self.vocab * self.d_model  # embedding
        if not self.tie_embeddings:
            p += self.vocab * self.d_model
        p += self.d_model  # final norm
        if self.family == "hybrid":
            # one shared attention+MLP block
            p += self._attn_params() + self._mlp_params(self.d_ff) + 2 * self.d_model
        if self.family == "encdec":
            enc_layer = self._attn_params() + self._mlp_params(self.d_ff) + 2 * self.d_model
            cross = self._attn_params() + self.d_model
            p += self.encoder_layers * enc_layer + self.n_layers * cross
        return p

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        dense_layer = self._attn_params() + 2 * d + d * self.moe.n_experts
        active_ffn = self.moe.top_k * self._mlp_params(self.moe.d_ff_expert)
        p = self.n_layers * (dense_layer + active_ffn)
        p += self.vocab * d + d
        return p

    # FLOPs per token for a forward pass (2*active params + attention term)
    def fwd_flops_per_token(self, seq_len: int) -> float:
        base = 2.0 * self.active_param_count()
        if self.attn is not None:
            a = self.attn
            n_attn_layers = self.n_layers
            if self.family == "hybrid" and self.hybrid_period:
                n_attn_layers = self.n_layers // self.hybrid_period
            if self.family == "encdec":
                n_attn_layers = self.n_layers + self.encoder_layers
            # causal: average key length seq/2 per query
            eff = seq_len
            if a.window:
                frac_local = 1.0
                if a.local_global_period:
                    frac_local = (a.local_global_period - 1) / a.local_global_period
                eff = frac_local * min(a.window, seq_len) + (1 - frac_local) * seq_len
            base += n_attn_layers * 4.0 * a.n_heads * a.head_dim * (eff / 2.0)
        return base


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}
