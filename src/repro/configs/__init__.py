"""Architecture config registry: ``get_config("<arch-id>")``."""
from __future__ import annotations

import importlib

from repro.configs.base import (AttnConfig, ModelConfig, MoEConfig, SHAPES,
                                ShapeConfig, SSMConfig)

ARCH_IDS = [
    "whisper_medium",
    "mamba2_780m",
    "llava_next_34b",
    "qwen3_moe_235b_a22b",
    "mixtral_8x7b",
    "zamba2_7b",
    "gemma2_2b",
    "gemma3_27b",
    "tinyllama_1_1b",
    "internlm2_20b",
]

_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


def canonical_arch(name: str) -> str:
    name = name.replace("-", "_").replace(".", "_")
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    return name


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical_arch(name)}")
    return mod.CONFIG


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = ["AttnConfig", "ModelConfig", "MoEConfig", "SSMConfig",
           "ShapeConfig", "SHAPES", "ARCH_IDS", "get_config", "all_configs",
           "canonical_arch"]
