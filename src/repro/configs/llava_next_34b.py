"""llava-next-34b: VLM; transformer BACKBONE only (anyres tiling STUB).

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified] 60L d_model=7168 56H
(GQA kv=8) d_ff=20480 vocab=64000.  ``input_specs`` provides precomputed
patch embeddings (n_prefix_tokens) standing in for the vision tower +
anyres tiling.
"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="llava_next_34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    d_ff=20480,
    vocab=64000,
    attn=AttnConfig(n_heads=56, n_kv_heads=8, head_dim=128,
                    rope_theta=5_000_000.0),
    n_prefix_tokens=576,      # one anyres tile of 24x24 patches (stub)
    tie_embeddings=False,
    supports_long_context=False,  # pure full attention
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
