"""zamba2-7b: Mamba2 backbone + shared attention blocks (hybrid).

[arXiv:2411.15242; unverified] 81L d_model=3584 32H (MHA kv=32) d_ff=14336
vocab=32000, ssm_state=64.  A single *shared* attention+MLP block is applied
every ``hybrid_period`` mamba layers (weights reused each application).
"""
from repro.configs.base import AttnConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2_7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    d_ff=14336,
    vocab=32000,
    attn=AttnConfig(n_heads=32, n_kv_heads=32, head_dim=112),
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_width=4,
                  chunk=128, n_groups=1),
    hybrid_period=6,
    supports_long_context=True,   # SSM backbone; sparse shared-attn blocks
    source="arXiv:2411.15242",
)
