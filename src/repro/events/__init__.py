"""Event-driven timeline validator (DESIGN.md §events).

``compile_step`` turns a design point into a per-microbatch task DAG
under a pipeline schedule, ``replay`` runs it through the fluid
discrete-event engine on the derived topology, ``replay_batch`` is the
vectorized K-records-at-once path, and the ``validate_*`` harness sweeps
the scenario zoo comparing event against analytic step times.

The validate layer imports ``repro.api`` and is loaded lazily so that
``repro.api`` itself (Scenario schedule validation) can import this
package without a cycle.
"""
from repro.events.dag import (SCHEDULES, StepProgram, TaskSpec,  # noqa: F401
                              compile_step, device_op_order)
from repro.events.engine import EventResult, replay  # noqa: F401
from repro.events.batch import replay_batch, replay_rows  # noqa: F401
from repro.events.compile_batch import (CompiledBatch,  # noqa: F401
                                        compile_batch)

_LAZY = ("validate_scenario", "validate_zoo", "stamp_validation",
         "fidelity_table", "FIDELITY_SCHEMA", "DEFAULT_TOLERANCE")


def __getattr__(name):
    if name in _LAZY:
        from repro.events import validate as _v
        return getattr(_v, name)
    raise AttributeError(f"module 'repro.events' has no attribute {name!r}")


__all__ = ["SCHEDULES", "StepProgram", "TaskSpec", "compile_step",
           "device_op_order", "EventResult", "replay", "replay_batch",
           "replay_rows", "CompiledBatch", "compile_batch", *_LAZY]
