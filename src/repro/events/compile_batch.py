"""Vectorized record->program compilation for batch replay.

``compile_batch`` is the SoA counterpart of ``events.dag.compile_step``:
it turns K design points — (strategies, MCM parameters, fabric, optional
per-row ``OITopology``) — into the (6, K) ``_ROW_KEYS`` matrix that
``events.batch.replay_rows`` consumes, without building K ``StepProgram``
task DAGs or running K scalar ``simulate`` calls.  All unit costs are
(K,) arrays produced by the SAME vectorized pieces the batched analytic
simulator uses (``dse.batched_sim``: traffic volumes, intra/inter
mapping, GEMM efficiency, link allocation, reuse-pair selection and the
bank-swap gate, ``_terms_core`` for the embedded analytic step), and the
node spans come from the closed form of the compiled node template's
longest path.  For BOTH directions the template's task chain reduces to

    span_d = sh*U_TP + ffn_d + join_d + sh*U_EP + (0.5/nm)*U_PP

with ``sh = 0.5 / (n_micro * v)``, ``U_p`` the per-parallelism serial
cost (launch latency + bytes at the steady-state rail rate, summed over
its intra/inter segments) and ``join_d`` the attention/CP overlap join
``max(attn_d, max(attn_d - credit_d, 0) + sh*U_CP)``; the DP all-reduce
cost is ``U_DP`` at share 1.  Parity with the per-record
``compile_step(...).spans()`` walk is pinned at 1e-9 in
tests/test_events.py and watched statically by the
``compile_step~compile_batch`` pair in ``analysis.parity``.

Feasibility differs by construction: ``compile_step`` raises on an
infeasible point, the batch marks the row in ``CompiledBatch.feasible``
(rows are NaN there) and ``CompiledBatch.replay`` scatters ``inf`` step
times back.  This is what lets the event engine sit INSIDE the search
loop (``Study.run``'s ``study.event_rerank`` stage, the outer search's
per-round replay) instead of validating after it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.hardware import HW
from repro.core.mcm import MCMArch
from repro.core.network import OITopology
from repro.core.traffic import Strategy
from repro.core.workload import Workload
from repro.dse.batched_sim import (MCMBatch, _ceil_log2_int, _mcm_params,
                                   _terms_core, allocate_links_batch,
                                   gemm_eff_batch, hbm_demand_batch,
                                   map_intra_batch, pick_reuse_pairs,
                                   traffic_volumes_batch)
from repro.dse.space import P_IDX, StrategyBatch
from repro.events.batch import replay_rows
from repro.events.dag import SCHEDULES
from repro.obs import metrics


@dataclass(frozen=True)
class CompiledBatch:
    """K records compiled for batch replay (see module docstring).

    ``rows`` is the (6, K) ``events.batch._ROW_KEYS`` matrix (tau_f,
    tau_b, t_dp, credit, nmv, analytic step time); ``shape_keys`` the
    unique (schedule, pp, v, n_micro) wavefront keys of the FEASIBLE
    rows and ``key_rows`` the per-record index into it (-1 where
    infeasible).  ``v`` is the per-row clamped interleave depth."""

    schedule: str
    rows: np.ndarray                  # (6, K) float64, NaN if infeasible
    shape_keys: List[Tuple[str, int, int, int]]
    key_rows: np.ndarray              # (K,) int64, -1 if infeasible
    feasible: np.ndarray              # (K,) bool
    v: np.ndarray                     # (K,) int64

    def __len__(self) -> int:
        return int(self.feasible.shape[0])

    @property
    def analytic_step_time(self) -> np.ndarray:
        return self.rows[5]

    def take(self, idx) -> "CompiledBatch":
        idx = np.asarray(idx)
        return CompiledBatch(self.schedule, self.rows[:, idx],
                             self.shape_keys, self.key_rows[idx],
                             self.feasible[idx], self.v[idx])

    def replay(self, backend: str = "auto") -> Dict[str, np.ndarray]:
        """Run the wavefront on the feasible rows and scatter back:
        same result keys as ``replay_batch``, with ``step_time = inf``
        and NaN diagnostics on infeasible rows."""
        K = len(self)
        out: Dict[str, np.ndarray] = {
            k: np.full(K, np.nan) for k in
            ("makespan_body", "bubble", "dp_exposed",
             "analytic_step_time", "err")}
        out["step_time"] = np.full(K, np.inf)
        out["scalar_fallback"] = np.zeros(K, bool)
        sel = np.nonzero(self.feasible)[0]
        if sel.size:
            res = replay_rows(self.shape_keys, self.key_rows[sel],
                              np.ascontiguousarray(self.rows[:, sel]),
                              backend=backend)
            for k in out:
                out[k][sel] = res[k]
        return out


def _compile_group(w: Workload, batch: StrategyBatch, mb: MCMBatch,
                   fabric: str, hw: HW, reuse: bool, schedule: str,
                   virtual_chunks: Optional[int],
                   topos: Optional[Sequence[Optional[OITopology]]]
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """One homogeneous (fabric, hw) group -> (rows (6, B), feasible,
    v).  Every expression mirrors ``compile_step`` (and through it
    ``simulate``) operation-for-operation; see the parity pin."""
    B = len(batch)
    tp, dp, pp, cp, ep = batch.tp, batch.dp, batch.pp, batch.cp, batch.ep
    nm = np.maximum(batch.n_micro, 1)

    ok_dev = batch.n_devices == mb.n_devices
    mappable, intra, inter = map_intra_batch(batch, mb)
    demand, local_params = hbm_demand_batch(w, batch)
    mem_ok = demand <= mb.hbm_capacity
    feasible = ok_dev & mappable & mem_ok

    layers_stage = np.maximum(w.n_layers // pp, 1)
    attn_stage = np.maximum(w.n_attn_layers // pp, 1) \
        if w.n_attn_layers else np.zeros(B, np.int64)
    moe_stage = np.maximum(w.n_moe_layers // pp, 1) \
        if w.n_moe_layers else np.zeros(B, np.int64)

    # ---- interleave depth (per-row clamp, identical to compile_step) --
    if schedule == "interleaved":
        base = virtual_chunks if virtual_chunks is not None else 2
        v = np.maximum(1, np.minimum(base, np.minimum(layers_stage, nm))
                       ).astype(np.int64)
    else:
        v = np.ones(B, np.int64)

    with np.errstate(divide="ignore", invalid="ignore"):
        # ---- unit costs (identical to simulate()) ----------------------
        flops_dev = w.step_flops() / mb.n_devices
        if hw.model_gemm_eff:
            eff = gemm_eff_batch(w, batch, hw)
            t_comp = flops_dev / (mb.die_flops * hw.mfu_ceiling * eff)
        else:
            t_comp = flops_dev / (mb.die_flops * hw.mfu_ceiling)
        t_comp = np.broadcast_to(np.asarray(t_comp, np.float64), (B,))
        hbm_stream = (local_params * w.bytes_param * 2.0 * nm
                      + local_params * 16.0
                      + 12.0 * w.tokens_per_step / (dp * cp * tp)
                      * w.d_model * w.bytes_act * layers_stage)
        t_mem = hbm_stream / mb.hbm_bw
        tile = np.maximum(t_comp, t_mem)

        vols = traffic_volumes_batch(w, batch)
        inter_mask = (inter > 1) & (vols > 0)

        # invocation counts / hops — simulate()'s latency model
        inv = np.empty((B, 5))
        inv[:, P_IDX["TP"]] = 8 * layers_stage * nm
        inv[:, P_IDX["DP"]] = 1.0
        inv[:, P_IDX["PP"]] = 2 * nm
        inv[:, P_IDX["CP"]] = 2 * attn_stage * nm
        inv[:, P_IDX["EP"]] = 4 * moe_stage * nm
        hops = np.empty((B, 5))
        hops[:, P_IDX["TP"]] = tp - 1
        hops[:, P_IDX["DP"]] = 2 * (dp - 1)
        hops[:, P_IDX["PP"]] = 1.0
        hops[:, P_IDX["CP"]] = cp - 1
        hops[:, P_IDX["EP"]] = np.maximum(
            _ceil_log2_int(np.maximum(ep, 2)), 1)

        # ---- reuse decision + link allocation --------------------------
        # replicates simulate()'s dynamic-reuse block; per-row topologies
        # override the pair/alloc exactly like compile_step's topo branch
        alloc = np.zeros((B, 5))
        reuse_overhead = np.zeros(B)
        reuse_active = np.zeros(B, bool)
        pair_a = np.full(B, -1, np.int64)
        pair_b = np.full(B, -1, np.int64)
        if fabric == "oi":
            has_topo = np.zeros(B, bool)
            topo_alloc = np.zeros((B, 5))
            if topos is not None:
                for i, t in enumerate(topos):
                    if t is None:
                        continue
                    has_topo[i] = True
                    for p, links in t.link_alloc.items():
                        topo_alloc[i, P_IDX[p]] = links
                    if t.reuse_pair is not None:
                        pair_a[i] = P_IDX[t.reuse_pair[0]]
                        pair_b[i] = P_IDX[t.reuse_pair[1]]
            if reuse:
                pa, pb = pick_reuse_pairs(vols, inter_mask)
                pair_a = np.where(has_topo, pair_a, pa)
                pair_b = np.where(has_topo, pair_b, pb)
            pre_gate = pair_a >= 0
            if hw.ocs_reuse_mode != "paper":
                # bank-swap feasibility of flipping the shared links
                gap = t_comp / np.maximum(layers_stage * nm, 1) / 2.0
                ok_swap = (gap > 0) & (np.ceil(
                    hw.ocs_switch_latency_s / np.where(gap > 0, gap, 1.0)
                ) <= nm)
                pair_a = np.where(ok_swap, pair_a, -1)
                pair_b = np.where(ok_swap, pair_b, -1)
            reuse_active = pair_a >= 0
            if hw.ocs_reuse_mode != "paper":
                reuse_overhead = np.where(
                    reuse_active, 2.0 * hw.ocs_switch_latency_s / nm, 0.0)
            # ONE allocator call covers both populations: non-topo rows
            # use their post-gate pair (equivalent to the scalar
            # pick -> alloc -> gate -> realloc order), topo rows the
            # no-pair alloc — which is exactly the fallback a GATED topo
            # row needs; un-gated topo rows keep their topology's alloc.
            alloc = allocate_links_batch(
                vols, inter_mask, mb.total_links,
                np.where(has_topo, -1, pair_a),
                np.where(has_topo, -1, pair_b))
            keep_topo = has_topo & ~(pre_gate & ~reuse_active)
            alloc = np.where(keep_topo[:, None], topo_alloc, alloc)

        # ---- per-parallelism serial comm cost U_p ----------------------
        # U_p = sum over p's segments of (inv*hops*alpha + bytes/rate)
        # at share 1; the rate is the steady-state fair share
        # min(rail_capacity / mult, hbm_relay) of StepProgram.steady_rate
        relay = np.broadcast_to(
            np.asarray(mb.hbm_bw, np.float64) / 2.0, (B,))
        intra_active = (intra > 1) & (vols > 0)
        U = np.zeros((B, 5))
        if fabric == "nvlink":
            rate_i = np.minimum(hw.nvlink_bw * hw.fabric_eff_elec,
                                relay)[:, None]
        else:
            dil = np.maximum(1.0, np.sqrt(intra.astype(np.float64)) / 2.0)
            nop = np.broadcast_to(np.asarray(mb.nop_bw, np.float64), (B,))
            rate_i = np.minimum(nop[:, None] / dil, relay[:, None])
        U += np.where(intra_active,
                      inv * hops * hw.lat_intra_s + vols / rate_i, 0.0)
        if fabric in ("ib", "nvlink"):
            rate_x = np.minimum(hw.ib_bw * hw.fabric_eff_elec,
                                relay)[:, None]
            U += np.where(inter_mask,
                          inv * hops * hw.lat_ib_s + vols / rate_x, 0.0)
        else:
            links = np.maximum(alloc, 1.0)
            # the (CP, EP) pair time-divides ONE rail whose capacity is
            # written by the last member in P_ORDER (EP) — mirror that
            is_cpep = reuse_active & (pair_a == P_IDX["CP"]) \
                & (pair_b == P_IDX["EP"])
            links[:, P_IDX["CP"]] = np.where(
                is_cpep, links[:, P_IDX["EP"]], links[:, P_IDX["CP"]])
            dies = np.broadcast_to(
                np.asarray(mb.dies_per_mcm, np.float64), (B,))
            rate_x = np.minimum(
                links * hw.oi_link_bw * hw.fabric_eff_oi / dies[:, None],
                relay[:, None])
            U += np.where(inter_mask,
                          inv * hops * hw.lat_oi_s + vols / rate_x, 0.0)

        # ---- closed-form node spans (see module docstring) -------------
        nmv = (nm * v).astype(np.float64)
        nm_f = nm.astype(np.float64)
        u_tp = U[:, P_IDX["TP"]]
        u_cp = U[:, P_IDX["CP"]]
        u_ep = U[:, P_IDX["EP"]]
        u_pp = U[:, P_IDX["PP"]]
        has_cp = (cp > 1) & (vols[:, P_IDX["CP"]] > 0)

        def node_span(dirfrac: float) -> np.ndarray:
            node_tile = tile * dirfrac / nmv
            sh = 0.5 / nmv           # fwd/bwd halves of per-layer comm
            credit = 0.3 * t_comp * hw.cp_overlap_frac * dirfrac / nmv
            attn = 0.3 * node_tile
            ffn = np.where(has_cp, 0.7, 1.0) * node_tile
            join = np.where(
                has_cp,
                np.maximum(attn,
                           np.maximum(attn - credit, 0.0) + sh * u_cp),
                0.0)
            return sh * u_tp + ffn + join + sh * u_ep \
                + (0.5 / nm_f) * u_pp

        tau_f = node_span(1.0 / 3.0)
        tau_b = node_span(2.0 / 3.0)

        has_dp = (dp > 1) & (vols[:, P_IDX["DP"]] > 0)
        t_dp = np.where(has_dp, U[:, P_IDX["DP"]], 0.0)
        dp_overlap = np.where(
            has_dp, (2.0 / 3.0) * t_comp * hw.dp_overlap_frac, 0.0)

        # ---- embedded analytic step (simulate() parity) ----------------
        a = {"vols": vols, "alloc": alloc, "inv": inv,
             "hops": hops, "intra": intra.astype(np.float64),
             "inter_mask": inter_mask, "t_comp": t_comp,
             "local_params": local_params,
             "layers_stage": layers_stage.astype(np.float64),
             "nm": nm.astype(np.float64), "tp": tp.astype(np.float64),
             "dp": dp.astype(np.float64), "pp": pp.astype(np.float64),
             "cp": cp.astype(np.float64),
             "reuse_overhead": reuse_overhead,
             "hbm_bw": np.broadcast_to(
                 np.asarray(mb.hbm_bw, np.float64), (B,)),
             "nop_bw": np.broadcast_to(
                 np.asarray(mb.nop_bw, np.float64), (B,)),
             "dies": np.broadcast_to(
                 np.asarray(mb.dies_per_mcm, np.float64), (B,)),
             "w_scalars": (float(w.bytes_param), float(w.tokens_per_step),
                           float(w.d_model), float(w.bytes_act))}
        analytic = _terms_core(np, a, fabric, hw)["step"]

    rows = np.empty((6, B))
    rows[0] = tau_f
    rows[1] = tau_b
    rows[2] = t_dp
    rows[3] = dp_overlap
    rows[4] = nmv
    rows[5] = analytic
    rows[:, ~feasible] = np.nan
    return rows, feasible, v


def compile_batch(w: Workload,
                  strategies: Union[StrategyBatch, Sequence[Strategy]],
                  mcm: Union[MCMArch, MCMBatch, Sequence[MCMArch]],
                  fabric: Union[str, Sequence[str]] = "oi", *,
                  topos: Optional[Sequence[Optional[OITopology]]] = None,
                  reuse: bool = True, hw: Optional[HW] = None,
                  schedule: str = "1f1b",
                  virtual_chunks: Optional[int] = None) -> CompiledBatch:
    """Compile K design points into replay rows under ONE schedule.

    ``strategies`` is a ``StrategyBatch`` or a ``Strategy`` sequence;
    ``mcm`` an ``MCMArch`` (homogeneous batch), an ``MCMBatch`` (an
    explicit ``hw`` is then required) or a per-row ``MCMArch`` sequence;
    ``fabric`` a string or a per-row sequence; ``topos`` an optional
    per-row sequence of derived ``OITopology`` (None entries = derive
    the allocation, like ``compile_step``).  Rows are grouped by
    (fabric, hw) internally — at most a handful of vectorized passes."""
    if schedule not in SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r}; "
                         f"known: {list(SCHEDULES)}")
    batch = strategies if isinstance(strategies, StrategyBatch) \
        else StrategyBatch.from_strategies(list(strategies))
    K = len(batch)
    if topos is not None and len(topos) != K:
        raise ValueError(f"topos has {len(topos)} entries for {K} records")

    if isinstance(mcm, MCMBatch):
        if hw is None:
            raise ValueError("pass hw= explicitly with an MCMBatch")
        mcm_mode = "batch"
        hw_row: List[HW] = [hw] * K
    elif isinstance(mcm, MCMArch):
        mcm_mode = "single"
        hw_row = [hw or mcm.hw] * K
    else:
        mcm = list(mcm)
        if len(mcm) != K:
            raise ValueError(f"mcm has {len(mcm)} entries for {K} records")
        mcm_mode = "list"
        hw_row = [hw or m.hw for m in mcm]

    if isinstance(fabric, str):
        fab_row = [fabric] * K
    else:
        fab_row = list(fabric)
        if len(fab_row) != K:
            raise ValueError(
                f"fabric has {len(fab_row)} entries for {K} records")

    metrics.inc("compile_batch.records", K)
    rows = np.full((6, K), np.nan)
    feasible = np.zeros(K, bool)
    v_arr = np.ones(K, np.int64)
    # group key by identity: HW is frozen/hashable but hashing one per
    # row is measurable at bench sizes; equal-but-distinct HW objects
    # just split into equivalent groups
    groups: Dict[Tuple, List[int]] = {}
    for i in range(K):
        groups.setdefault((fab_row[i], id(hw_row[i])), []).append(i)
    for (fab, _hid), members in groups.items():
        ghw = hw_row[members[0]]
        idx = np.asarray(members, np.int64)
        gb = batch.take(idx)
        if mcm_mode == "batch":
            mb = mcm.take(idx)
        elif mcm_mode == "single":
            mb = _mcm_params(mcm)
        else:
            mb = MCMBatch.from_mcms(mcm, idx)
        gtopos = [topos[i] for i in idx] if topos is not None else None
        grows, gfeas, gv = _compile_group(
            w, gb, mb, fab, ghw, reuse, schedule, virtual_chunks, gtopos)
        rows[:, idx] = grows
        feasible[idx] = gfeas
        v_arr[idx] = gv

    key_of: Dict[Tuple, int] = {}
    key_rows = np.full(K, -1, np.int64)
    nmc = np.maximum(batch.n_micro, 1)
    for i in np.nonzero(feasible)[0]:
        key = (schedule, int(batch.pp[i]), int(v_arr[i]), int(nmc[i]))
        key_rows[i] = key_of.setdefault(key, len(key_of))
    return CompiledBatch(schedule=schedule, rows=rows,
                         shape_keys=list(key_of), key_rows=key_rows,
                         feasible=feasible, v=v_arr)
