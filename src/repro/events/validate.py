"""Fidelity harness: event-driven replay vs the analytic model.

Two entry points sit on top of the engine:

* ``stamp_validation(result, top, schedule)`` — the ``Study.run``
  integration: batch-replays the top-K records of a ``StudyResult`` and
  stamps each with ``validated_step_time`` / ``fidelity_err`` metrics
  (plus a ``validate`` provenance block), keeping validation off the
  study's critical path via ``repro.events.batch``.

* ``validate_scenario`` / ``validate_zoo`` — the standalone harness
  behind ``python -m repro.cli validate``: runs each scenario preset,
  replays its top points with the full discrete-event engine under every
  requested schedule, and writes a VERSIONED fidelity report artifact
  (``FIDELITY_SCHEMA``) with per-point analytic vs event step times,
  errors, measured bubbles and OCS reconfiguration counts.  Rows whose
  schedule matches the analytic model's bubble assumption (``gpipe`` /
  ``1f1b``) are asserted to agree within ``tolerance`` (default 15%);
  ``interleaved`` rows are reported only — their smaller bubble is
  scenario diversity the analytic model cannot express.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.mcm import MCMArch
from repro.core.network import OITopology, RailDim
from repro.core.traffic import Strategy
from repro.events.dag import SCHEDULES, compile_step
from repro.events.engine import replay

FIDELITY_SCHEMA = 1
DEFAULT_TOLERANCE = 0.15
ASSERTED_SCHEDULES = ("gpipe", "1f1b")


# ---------------------------------------------------------------------------
# Record -> engine objects
# ---------------------------------------------------------------------------
def _rebuild_topo(topo: Optional[dict]) -> Optional[OITopology]:
    if not topo:
        return None
    return OITopology(
        dims=tuple(RailDim(n=int(n), r=int(r), k=int(k))
                   for n, r, k in topo.get("dims", [])),
        mapping=tuple(tuple(g) for g in topo.get("mapping", [])),
        link_alloc=dict(topo.get("link_alloc", {})),
        reuse_pair=tuple(topo["reuse_pair"]) if topo.get("reuse_pair")
        else None)


def _rebuild(record, scenario, hw=None) -> Tuple[Strategy, MCMArch,
                                                 Optional[OITopology], str]:
    st = record.strategy
    s = Strategy(tp=int(st["TP"]), dp=int(st["DP"]), pp=int(st["PP"]),
                 cp=int(st["CP"]), ep=int(st["EP"]),
                 n_micro=int(st["n_micro"]))
    mc = record.mcm
    mcm = MCMArch(n_mcm=int(mc["n_mcm"]), x=int(mc["x"]), y=int(mc["y"]),
                  m=int(mc["m"]), cpo_ratio=float(mc["cpo_ratio"]),
                  hw=hw if hw is not None else scenario.build_hw())
    return s, mcm, _rebuild_topo(record.topo), record.fabric


def _top_records(result, top: int) -> List[int]:
    """Indices of the top-``top`` feasible records by throughput, one per
    unique design point (refined duplicates win over batched rows —
    they carry the derived topology)."""
    ranked = sorted(
        (i for i, r in enumerate(result.records) if r.feasible),
        key=lambda i: (-result.records[i].throughput,
                       result.records[i].source != "refined"))
    seen, keep = set(), []
    for i in ranked:
        r = result.records[i]
        key = (tuple(sorted(r.strategy.items())),
               tuple(sorted(r.mcm.items())), r.fabric)
        if key in seen:
            continue
        seen.add(key)
        keep.append(i)
        if len(keep) >= top:
            break
    return keep


# ---------------------------------------------------------------------------
# Study integration (batch replay — off the critical path)
# ---------------------------------------------------------------------------
def _schedule_names(schedule: str) -> Tuple[str, ...]:
    """Resolve a schedule spec — one name, a comma list, or ``search``
    (every known schedule) — to a tuple of names."""
    if schedule == "search":
        return tuple(SCHEDULES)
    return tuple(s.strip() for s in str(schedule).split(","))


def stamp_validation(result, top: int, schedule: str = "gpipe",
                     backend: str = "auto") -> dict:
    """Replay the top-``top`` records of ``result`` and stamp each with
    ``validated_step_time`` / ``fidelity_err``; returns (and attaches to
    ``result.provenance['validate']``) a summary block.

    Records are vector-compiled by ``events.compile_batch`` (no
    per-record DAG walks) and replayed in one batched wavefront call per
    resolved ``(schedule, v)`` group.  ``schedule`` may be one name, a
    comma list or ``"search"``: with more than one candidate each record
    validates under its OWN re-rank winner (the ``event_schedule`` /
    ``event_v`` metrics stamped by ``Study.run``'s event re-rank stage),
    falling back to the first candidate.  ``backend`` picks the
    wavefront implementation (``numpy`` | ``jax`` | ``auto``, see
    ``repro.events.batch``)."""
    from repro.events.compile_batch import compile_batch
    t0 = time.perf_counter()
    sc = result.scenario
    idx = _top_records(result, top)
    scheds = _schedule_names(schedule)
    w = sc.build_workload()
    hw = sc.build_hw()
    # group records by their resolved (schedule, virtual_chunks): one
    # compile_batch + replay per group (usually exactly one group)
    groups: Dict[Tuple[str, Optional[int]], List[tuple]] = {}
    for i in idx:
        r = result.records[i]
        try:
            s, mcm, topo, fabric = _rebuild(r, sc, hw=hw)
        except (KeyError, TypeError, ValueError):
            continue
        rsched = str(r.metrics.get("event_schedule", scheds[0]))
        if rsched not in SCHEDULES:
            rsched = scheds[0]
        rv = r.metrics.get("event_v")
        key = (rsched, int(rv) if rv is not None else None)
        groups.setdefault(key, []).append((i, s, mcm, topo, fabric))
    errs: List[float] = []
    n_validated, n_fb = 0, 0
    for (sched, rv), members in groups.items():
        cb = compile_batch(w, [m[1] for m in members],
                           [m[2] for m in members],
                           fabric=[m[4] for m in members],
                           topos=[m[3] for m in members],
                           reuse=sc.reuse, hw=hw, schedule=sched,
                           virtual_chunks=rv)
        res = cb.replay(backend=backend)
        n_fb += int(res["scalar_fallback"].sum())
        for j, m in enumerate(members):
            if not cb.feasible[j]:
                continue              # infeasible under the oracle
            rec = result.records[m[0]]
            rec.metrics["validated_step_time"] = float(res["step_time"][j])
            rec.metrics["fidelity_err"] = float(res["err"][j])
            errs.append(abs(float(res["err"][j])))
            n_validated += 1
    summary = {"n_validated": n_validated, "schedule": schedule,
               "method": "batch", "backend": backend,
               "max_abs_err": max(errs) if errs else None,
               "n_scalar_fallback": n_fb,
               "scalar_fallback_frac": n_fb / n_validated
               if n_validated else 0.0,
               "elapsed_s": time.perf_counter() - t0}
    result.provenance["validate"] = summary
    result.timings["validate_s"] = summary["elapsed_s"]
    return summary


# ---------------------------------------------------------------------------
# Standalone fidelity harness (scalar engine — the ground truth)
# ---------------------------------------------------------------------------
def validate_scenario(scenario, top: int = 4,
                      schedules: Sequence[str] = SCHEDULES,
                      tolerance: float = DEFAULT_TOLERANCE) -> dict:
    """Run one scenario, replay its top points under every schedule with
    the full event engine, and return a per-point fidelity block."""
    from repro.api import Study
    bad = [s for s in schedules if s not in SCHEDULES]
    if bad:
        raise ValueError(f"unknown schedules {bad}; known: "
                         f"{list(SCHEDULES)}")
    t0 = time.perf_counter()
    # validate_top=0: the harness replays the points itself (scalar
    # engine, every schedule) — don't batch-validate them a first time
    result = Study(scenario).run(validate_top=0)
    rows = []
    for i in _top_records(result, top):
        rec = result.records[i]
        try:
            s, mcm, topo, fabric = _rebuild(rec, scenario)
        except (KeyError, TypeError):
            continue
        for sched in schedules:
            try:
                prog = compile_step(scenario.build_workload(), s, mcm,
                                    fabric=fabric, topo=topo,
                                    reuse=scenario.reuse,
                                    hw=scenario.build_hw(), schedule=sched)
            except ValueError:
                continue
            ev = replay(prog)
            asserted = sched in ASSERTED_SCHEDULES
            rows.append({
                "scenario": scenario.name,
                "schedule": sched,
                "strategy": dict(rec.strategy),
                "mcm": dict(rec.mcm),
                "fabric": fabric,
                "analytic_step_time": ev.analytic_step_time,
                "event_step_time": ev.step_time,
                "err": ev.err,
                "bubble_event": ev.bubble,
                "bubble_analytic": float(
                    prog.analytic.logs.get("bubble", 0.0)),
                "peak_inflight": ev.peak_inflight,
                "n_reconf": ev.n_reconf,
                "reconf_wait_s": ev.reconf_wait_s,
                "n_events": ev.n_events,
                "asserted": asserted,
                "ok": (abs(ev.err) <= tolerance) if asserted else True,
            })
    n_points = len({(tuple(sorted(r["strategy"].items())),
                     tuple(sorted(r["mcm"].items())), r["fabric"])
                    for r in rows})
    return {"scenario": scenario.name,
            "scenario_hash": scenario.scenario_hash(),
            "n_points": n_points,
            "rows": rows, "elapsed_s": time.perf_counter() - t0}


def execution_anchor(calib_path: str = "CALIB.json"):
    """The fidelity report's execution-grounded block: a summary of the
    committed calibration artifact (``repro.calib``), or ``None`` when
    no usable artifact exists at ``calib_path``."""
    from repro.calib import execution_block, load_calibration
    try:
        calib = load_calibration(calib_path)
    except (OSError, ValueError):
        return None
    return execution_block(calib, source=calib_path)


def validate_zoo(paths: Sequence = (), top: int = 4,
                 schedules: Sequence[str] = SCHEDULES,
                 tolerance: float = DEFAULT_TOLERANCE,
                 out: Optional[str] = None) -> dict:
    """Sweep scenario JSON files (default: ``scenarios/*.json``) through
    ``validate_scenario`` and write the versioned fidelity report."""
    from repro.api import Scenario
    from repro.obs import metrics, span
    paths = list(paths) or sorted(Path("scenarios").glob("*.json"))
    blocks = []
    with metrics.scope() as ms:
        for path in paths:
            sc = Scenario.load(path)
            with span("validate.scenario", scenario=sc.name):
                blocks.append(validate_scenario(
                    sc, top=top, schedules=schedules,
                    tolerance=tolerance))
    # batch-replay fallback counters observed while the harness ran
    # (zero when every replay went through the scalar ground-truth
    # engine — the harness default)
    n_rec = int(ms.counters.get("batch_replay.records", 0))
    n_fb = int(ms.counters.get("batch_replay.scalar_fallback", 0))
    rows = [r for b in blocks for r in b["rows"]]
    asserted = [r for r in rows if r["asserted"]]
    violations = [r for r in asserted if not r["ok"]]
    report = {
        "schema": FIDELITY_SCHEMA,
        "tolerance": tolerance,
        "schedules": list(schedules),
        "top_per_scenario": top,
        "n_scenarios": len(blocks),
        "n_rows": len(rows),
        "n_asserted": len(asserted),
        "n_violations": len(violations),
        "max_abs_err_asserted": max((abs(r["err"]) for r in asserted),
                                    default=None),
        "batch_replay": {
            "records": n_rec,
            "scalar_fallback": n_fb,
            "fallback_frac": n_fb / n_rec if n_rec else 0.0,
        },
        "scenarios": blocks,
    }
    # Execution-grounded anchor: if a committed CALIB.json exists, the
    # report records what the analytic constants were fitted against
    # (non-asserted — drift gating is `cli calibrate --check`'s job).
    anchor = execution_anchor()
    if anchor is not None:
        report["execution"] = anchor
    if out:
        p = Path(out)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(report, indent=2) + "\n")
    return report


def fidelity_table(report: dict) -> List[Dict]:
    """Per-(scenario, schedule) summary rows for reporting (README)."""
    agg: Dict[Tuple[str, str], List[dict]] = {}
    for b in report["scenarios"]:
        for r in b["rows"]:
            agg.setdefault((r["scenario"], r["schedule"]), []).append(r)
    out = []
    for (name, sched), rows in sorted(agg.items()):
        out.append({
            "scenario": name, "schedule": sched, "n": len(rows),
            "max_abs_err": max(abs(r["err"]) for r in rows),
            "mean_err": sum(r["err"] for r in rows) / len(rows),
            "mean_bubble_event": sum(r["bubble_event"] for r in rows)
            / len(rows),
            "mean_bubble_analytic": sum(r["bubble_analytic"] for r in rows)
            / len(rows),
        })
    return out
