"""Fluid discrete-event replay of a compiled ``StepProgram``.

The engine executes the per-microbatch task DAG on the derived topology:
every pipeline stage is a device advancing IN ORDER through its static
schedule (``dag.device_op_order``), compute tiles take fixed time, and
collectives are FLUID FLOWS on shared rail resources — at any instant a
flow's rate is its fair share ``capacity / sum(active multiplicities)``
of every resource it traverses (its parallelism's rail, plus the
device's HBM relay engine — paper insight 5: every relayed chunk is a
read + write).  Whenever a flow starts or finishes, rates are rebalanced
and completions reprojected — congestion is resolved from the actual
schedule, not assumed.

Reused rails (the dynamic CP/EP pair) carry a configuration state: a
flow needing the other configuration triggers an explicit OCS
reconfiguration event, charged ``hw.ocs_switch_latency_s`` minus the
time the idle bank already had to re-train (two-bank model); under the
paper's ``ocs_reuse_mode="paper"`` the swap is counted but free.

The result is an ``EventResult``: schedule-resolved step time, per-phase
busy time, per-rail utilization, measured bubble / exposure /
peak-in-flight actuals, byte-conservation counters and the event count.
Deterministic: no randomness; heap ties break on a sequence counter.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.events.dag import (StepProgram, TaskSpec, device_op_order,
                              op_dependency)


# ---------------------------------------------------------------------------
# Result
# ---------------------------------------------------------------------------
@dataclass
class EventResult:
    """Schedule-resolved replay of one training step."""

    step_time: float
    makespan_body: float            # last node end (pre-DP)
    analytic_step_time: float
    err: float                      # (event - analytic) / analytic
    schedule: str
    n_stages: int
    v: int
    n_micro: int
    bubble: float                   # measured: makespan / mean busy - 1
    exposed_comm: float             # comm time with no concurrent compute
    dp_exposed: float               # DP tail beyond the last node end
    peak_inflight: int              # max fwd-done minus bwd-done per stage
    n_events: int
    n_reconf: int
    reconf_wait_s: float
    phase_times: Dict[str, float]   # rep-stage busy seconds per phase
    link_util: Dict[str, float]     # bytes / (capacity * step) per rail
    bytes_moved: Dict[str, float]   # per-parallelism, rep device
    timeline: List[Tuple[str, str, float, float]] = field(
        default_factory=list)       # (phase, label, start, end), rep stage
    # full ``record_timeline=True`` capture, every stage (repro.obs
    # exports these as Perfetto tracks — one per device, one per rail):
    device_timeline: List[Tuple[int, str, str, str, float, float]] = \
        field(default_factory=list)  # (stage, kind, phase, label, t0, t1)
    rail_timeline: List[Tuple[str, int, str, float, float]] = field(
        default_factory=list)        # (rail, stage, label, t0, t1)
    reconf_events: List[Tuple[str, int, float, float]] = field(
        default_factory=list)        # (rail, stage, t, wait_s)


# ---------------------------------------------------------------------------
# Internal state
# ---------------------------------------------------------------------------
class _Rail:
    __slots__ = ("cap", "active", "config", "last_swap", "bytes_done")

    def __init__(self, cap: float):
        self.cap = cap
        self.active = 0.0           # sum of active flow weights
        self.config = ""
        self.last_swap = -math.inf
        self.bytes_done = 0.0


class _Flow:
    __slots__ = ("task", "dev", "node", "tidx", "remaining", "rails",
                 "weights", "rate", "epoch", "fluid", "projected")

    def __init__(self, task: TaskSpec, dev: int, node: "_Node", tidx: int,
                 rails: List[_Rail], weights: List[float]):
        self.task = task
        self.dev = dev
        self.node = node
        self.tidx = tidx
        self.remaining = float(task.nbytes)
        self.rails = rails
        self.weights = weights
        self.rate = 0.0
        self.epoch = 0
        self.fluid = task.latency <= 0.0
        self.projected = False


class _Node:
    """One (dir, stage, chunk, micro) instance with task timings."""

    __slots__ = ("key", "tasks", "starts", "ends", "scheduled", "n_done",
                 "start_t", "end_t")

    def __init__(self, key, tasks: Tuple[TaskSpec, ...]):
        self.key = key
        self.tasks = tasks
        self.starts: List[Optional[float]] = [None] * len(tasks)
        self.ends: List[Optional[float]] = [None] * len(tasks)
        self.scheduled = [False] * len(tasks)
        self.n_done = 0
        self.start_t: Optional[float] = None
        self.end_t: Optional[float] = None


class _Replay:
    def __init__(self, prog: StepProgram, record_timeline: bool,
                 rep_stage: int):
        self.prog = prog
        self.pp, self.v, self.nm = prog.n_stages, prog.v, prog.n_micro
        self.rep = min(rep_stage, self.pp - 1)
        self.record_timeline = record_timeline
        # per-stage resources: stages occupy disjoint MCM groups, so
        # rails never cross stages; the HBM relay is per die
        self.rails: Dict[Tuple[str, int], _Rail] = {}
        for s in range(self.pp):
            for name, cap in prog.resources.items():
                self.rails[(name, s)] = _Rail(cap)
            self.rails[("hbm", s)] = _Rail(prog.hbm_relay_bw)
        self.orders = [device_op_order(prog.schedule, self.pp, self.v,
                                       self.nm, s) for s in range(self.pp)]
        self.nodes: Dict[tuple, _Node] = {}
        for s in range(self.pp):
            for d, c, m in self.orders[s]:
                tmpl = prog.fwd_node if d == "F" else prog.bwd_node
                self.nodes[(d, s, c, m)] = _Node((d, s, c, m), tmpl)
        self.dp_nodes: Dict[int, _Node] = {}
        if prog.dp_tasks:
            for s in range(self.pp):
                self.dp_nodes[s] = _Node(("D", s, 0, 0), prog.dp_tasks)
        self.tau_b = prog.node_span("bwd")
        self.op_idx = [0] * self.pp
        self.dev_node: List[Optional[_Node]] = [None] * self.pp
        self.dp_started = [False] * self.pp
        self.dp_planned: set = set()
        self.dev_busy = [0.0] * self.pp
        self.fwd_done = [0] * self.pp
        self.bwd_done = [0] * self.pp
        self.peak_inflight = 0
        self.compute_active = [0] * self.pp
        self.flow_active = [0] * self.pp
        self.exposed_s = [0.0] * self.pp
        self.flows: Dict[int, _Flow] = {}
        self.heap: List[tuple] = []
        self.seq = 0
        self.now = 0.0
        self.n_events = 0
        self.n_reconf = 0
        self.reconf_wait = 0.0
        self.phase_times: Dict[str, float] = {}
        self.bytes_moved: Dict[str, float] = {}
        self.timeline: List[Tuple[str, str, float, float]] = []
        self.device_timeline: List[Tuple[int, str, str, str,
                                         float, float]] = []
        self.rail_timeline: List[Tuple[str, int, str, float, float]] = []
        self.reconf_events: List[Tuple[str, int, float, float]] = []

    # -- plumbing ----------------------------------------------------------
    def push(self, t: float, kind: str, data: tuple):
        heapq.heappush(self.heap, (t, self.seq, kind, data))
        self.seq += 1

    def node_of(self, key) -> _Node:
        return self.dp_nodes[key[1]] if key[0] == "D" else self.nodes[key]

    def advance(self, t: float):
        dt = t - self.now
        if dt > 0:
            for f in self.flows.values():
                if f.rate > 0 and f.remaining > 0:
                    f.remaining = max(f.remaining - f.rate * dt, 0.0)
            for s in range(self.pp):
                if self.flow_active[s] > 0 and self.compute_active[s] == 0:
                    self.exposed_s[s] += dt
        self.now = t

    def rebalance(self):
        for fid, f in self.flows.items():
            if not f.fluid or f.remaining <= 0:
                continue
            rate = math.inf
            for r, wgt in zip(f.rails, f.weights):
                rate = min(rate, r.cap / max(r.active, wgt))
            if rate != f.rate or not f.projected:
                f.rate = rate
                f.epoch += 1
                f.projected = True
                if rate > 0:
                    self.push(self.now + f.remaining / rate, "flow_done",
                              (fid, f.epoch))

    # -- device scheduling -------------------------------------------------
    def try_start_next(self, s: int):
        """In-order: start device ``s``'s next op if the device is idle
        and the op's cross-DAG dependency has completed."""
        if self.dev_node[s] is not None:
            return
        if self.op_idx[s] >= len(self.orders[s]):
            self.maybe_start_dp(s, final=True)
            return
        d, c, m = self.orders[s][self.op_idx[s]]
        node = self.nodes[(d, s, c, m)]
        dep = op_dependency(d, s, c, m, self.pp, self.v)
        if dep is not None:
            dn = self.nodes.get(dep)
            if dn is None or dn.end_t is None:
                return               # retried when the dep completes
        self.op_idx[s] += 1
        self.dev_node[s] = node
        node.start_t = self.now
        if d == "B":
            self.plan_dp_launch(s)
        self.begin_task(node, 0)

    def plan_dp_launch(self, s: int):
        """When a bwd node starts, check whether the DP all-reduce can
        launch within it: remaining bwd work after the launch point must
        equal the overlap credit (the analytic overlap model,
        event-resolved at sub-node granularity)."""
        if self.dp_started[s] or s in self.dp_planned \
                or s not in self.dp_nodes:
            return
        rest = sum(1 for k in range(self.op_idx[s], len(self.orders[s]))
                   if self.orders[s][k][0] == "B") * self.tau_b
        credit = self.prog.dp_overlap
        if rest + self.tau_b <= credit:
            self.dp_planned.add(s)
            self.start_dp(s)
        elif rest < credit:
            self.dp_planned.add(s)
            delay = max(0.0, self.tau_b - (credit - rest))
            self.push(self.now + delay, "dp_begin", (s,))

    def start_dp(self, s: int):
        if self.dp_started[s]:
            return
        self.dp_started[s] = True
        node = self.dp_nodes[s]
        node.start_t = self.now
        self.begin_task(node, 0)

    def maybe_start_dp(self, s: int, final: bool = False):
        """Launch the DP all-reduce once the stage's remaining bwd work
        (steady-state estimate) fits inside the overlap credit — the
        analytic overlap model, event-resolved."""
        if self.dp_started[s] or s not in self.dp_nodes:
            return
        if not final:
            remaining = sum(
                1 for k in range(self.op_idx[s], len(self.orders[s]))
                if self.orders[s][k][0] == "B") * self.tau_b
            if self.dev_node[s] is not None:
                remaining += self.tau_b      # current node, conservatively
            if remaining > self.prog.dp_overlap:
                return
        self.start_dp(s)

    # -- tasks -------------------------------------------------------------
    def begin_task(self, node: _Node, i: int):
        node.scheduled[i] = True
        node.starts[i] = self.now
        task = node.tasks[i]
        s = node.key[1]
        if task.kind == "compute":
            self.compute_active[s] += 1
            self.push(self.now + task.dur, "task_done", (node.key, i))
        else:
            self.launch_flow(node, i)
        self.schedule_successors(node)

    def launch_flow(self, node: _Node, i: int):
        task = node.tasks[i]
        s = node.key[1]
        rail = self.rails[(task.rail, s)]
        if task.config and rail.config != task.config:
            if rail.config:          # initial configuration is free
                # bank-swap model: the links are banked across the
                # n_micro microbatches (the analytic gate's assumption,
                # _bank_swap_reuse_ok), so a configuration swapped in
                # now had n_micro inter-swap gaps to retrain; the swap
                # only stalls when even that pipelined window is
                # shorter than the MEMS reconfiguration time
                wait = 0.0 if self.prog.ocs_paper_mode else max(
                    0.0, self.prog.ocs_switch_latency_s
                    - (self.now - rail.last_swap) * max(self.nm, 1))
                self.n_reconf += 1
                self.reconf_wait += wait
                if self.record_timeline:
                    self.reconf_events.append(
                        (task.rail, s, self.now, wait))
                rail.config = task.config
                rail.last_swap = self.now
                if wait > 0:
                    node.starts[i] = None        # restarts after the swap
                    self.push(self.now + wait, "task_begin", (node.key, i))
                    return
            else:
                rail.config = task.config
                rail.last_swap = self.now
        f = _Flow(task, s, node, i, [rail, self.rails[("hbm", s)]],
                  [float(task.mult), 1.0])
        fid = self.seq
        self.seq += 1
        self.flows[fid] = f
        for r, wgt in zip(f.rails, f.weights):
            r.active += wgt
        self.flow_active[s] += 1
        if not f.fluid:
            self.push(self.now + task.latency, "flow_fluid", (fid,))
            self.rebalance()         # co-located flows see the new sharer
        else:
            self.rebalance()

    def schedule_successors(self, node: _Node):
        """Schedule every not-yet-scheduled task whose preds permit a
        start time (overlap windows look ahead into fixed-duration
        predecessors)."""
        for j, t in enumerate(node.tasks):
            if node.scheduled[j] or not t.preds:
                continue
            best = 0.0
            ok = True
            for k, slack in t.preds:
                if node.starts[k] is None or not node.scheduled[k]:
                    ok = False
                    break
                if node.ends[k] is not None:
                    cand = max(node.ends[k] - slack, node.starts[k])
                elif slack > 0 and node.tasks[k].kind == "compute":
                    cand = max(node.starts[k] + node.tasks[k].dur - slack,
                               node.starts[k])
                else:
                    ok = False
                    break
                best = max(best, cand)
            if not ok:
                continue
            node.scheduled[j] = True
            if best <= self.now:
                node.scheduled[j] = False     # begin_task re-marks it
                self.begin_task(node, j)
            else:
                self.push(best, "task_begin", (node.key, j))

    def finish_task(self, node: _Node, i: int):
        task = node.tasks[i]
        s = node.key[1]
        node.ends[i] = self.now
        node.n_done += 1
        if task.kind == "compute":
            self.compute_active[s] -= 1
        if self.record_timeline:
            kind = ("dp" if node.key[0] == "D"
                    else "compute" if task.kind == "compute" else "coll")
            self.device_timeline.append(
                (s, kind, task.phase, task.label, node.starts[i], self.now))
            if task.kind != "compute":
                self.rail_timeline.append(
                    (task.rail, s, task.label, node.starts[i], self.now))
        if s == self.rep and node.key[0] != "D":
            self.phase_times[task.phase] = \
                self.phase_times.get(task.phase, 0.0) \
                + (self.now - node.starts[i])
            if self.record_timeline:
                self.timeline.append((task.phase, task.label,
                                      node.starts[i], self.now))
        self.schedule_successors(node)
        if node.n_done < len(node.tasks):
            return
        node.end_t = self.now
        if node.key[0] == "D":
            return
        self.dev_busy[s] += node.end_t - node.start_t
        if node.key[0] == "F":
            self.fwd_done[s] += 1
        else:
            self.bwd_done[s] += 1
        self.peak_inflight = max(self.peak_inflight,
                                 self.fwd_done[s] - self.bwd_done[s])
        self.dev_node[s] = None
        self.maybe_start_dp(s)
        for s2 in range(self.pp):     # this node may unblock peers
            self.try_start_next(s2)

    # -- main loop ---------------------------------------------------------
    def run(self):
        for s in range(self.pp):
            self.try_start_next(s)
        n_tasks = len(self.nodes) * max(len(self.prog.fwd_node), 1)
        max_events = 400 * (n_tasks + 64)
        while self.heap:
            self.n_events += 1
            if self.n_events > max_events:
                raise RuntimeError(
                    "event-engine runaway: schedule deadlock suspected")
            t, _, kind, data = heapq.heappop(self.heap)
            self.advance(t)
            if kind == "task_begin":
                key, i = data
                node = self.node_of(key)
                if node.starts[i] is None:
                    self.begin_task(node, i)
            elif kind == "task_done":
                key, i = data
                self.finish_task(self.node_of(key), i)
                self.rebalance()
            elif kind == "dp_begin":
                (s,) = data
                self.start_dp(s)
            elif kind == "flow_fluid":
                (fid,) = data
                f = self.flows.get(fid)
                if f is not None:
                    f.fluid = True
                    self.rebalance()
            elif kind == "flow_done":
                fid, epoch = data
                f = self.flows.get(fid)
                if f is None or f.epoch != epoch:
                    continue          # stale projection
                if f.remaining > 1e-9 * max(f.task.nbytes, 1.0):
                    f.projected = False
                    self.rebalance()
                    continue
                for r, wgt in zip(f.rails, f.weights):
                    r.active -= wgt
                    r.bytes_done += f.task.nbytes * wgt
                del self.flows[fid]
                self.flow_active[f.dev] -= 1
                if f.dev == self.rep:
                    p = f.task.parallelism
                    self.bytes_moved[p] = \
                        self.bytes_moved.get(p, 0.0) + f.task.nbytes
                self.finish_task(f.node, f.tidx)
                self.rebalance()
        unfinished = [n.key for n in self.nodes.values() if n.end_t is None]
        if unfinished:
            raise RuntimeError(
                f"replay incomplete: {len(unfinished)} nodes never "
                f"finished (first: {unfinished[0]}) — schedule deadlock")

    def result(self) -> EventResult:
        prog = self.prog
        body_end = max((n.end_t for n in self.nodes.values()), default=0.0)
        step = body_end
        dp_exposed = 0.0
        for node in self.dp_nodes.values():
            if node.end_t is not None:
                step = max(step, node.end_t)
                dp_exposed = max(dp_exposed, node.end_t - body_end)
        busy_mean = sum(self.dev_busy) / max(self.pp, 1)
        bubble = body_end / busy_mean - 1.0 if busy_mean > 0 else 0.0
        link_util: Dict[str, float] = {}
        for (name, _s), r in self.rails.items():
            if r.bytes_done > 0 and step > 0:
                u = r.bytes_done / (r.cap * step)
                link_util[name] = max(link_util.get(name, 0.0), u)
        analytic = prog.analytic.step_time if prog.analytic \
            else float("nan")
        return EventResult(
            step_time=step, makespan_body=body_end,
            analytic_step_time=analytic,
            err=(step - analytic) / analytic if analytic else float("nan"),
            schedule=prog.schedule, n_stages=self.pp, v=self.v,
            n_micro=self.nm, bubble=bubble,
            exposed_comm=max(self.exposed_s, default=0.0),
            dp_exposed=max(dp_exposed, 0.0),
            peak_inflight=self.peak_inflight, n_events=self.n_events,
            n_reconf=self.n_reconf, reconf_wait_s=self.reconf_wait,
            phase_times=self.phase_times, link_util=link_util,
            bytes_moved=self.bytes_moved, timeline=self.timeline,
            device_timeline=self.device_timeline,
            rail_timeline=self.rail_timeline,
            reconf_events=self.reconf_events)


def replay(prog: StepProgram, record_timeline: bool = False,
           rep_stage: int = 0) -> EventResult:
    """Replay one training step of ``prog``; see the module docstring."""
    r = _Replay(prog, record_timeline, rep_stage)
    r.run()
    return r.result()
