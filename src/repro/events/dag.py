"""Task-DAG compilation for the event-driven timeline validator.

``compile_step`` turns one design point — (Workload, Strategy, MCMArch,
fabric, optional derived OITopology) — into a ``StepProgram``: the
per-microbatch task DAG one training step executes under a selectable
pipeline schedule (``gpipe`` / ``1f1b`` / ``interleaved``).  Nodes are
(pipeline stage, virtual chunk, microbatch, direction) units whose task
chains interleave compute tiles with collective invocations tagged by
``traffic.PHASE``; collectives carry BYTES and a rail resource, not a
precomputed duration — their time emerges from the replay engine's
per-rail fair-share (``repro.events.engine``).

Cost primitives are shared with the analytic model: traffic volumes come
from ``traffic.traffic_volumes``, the intra/inter split from
``simulator.map_intra``, OI link allocation and the dynamic-reuse
bank-swap gate replicate ``simulator.simulate`` exactly (same functions,
same order), and per-rail capacities mirror the bandwidth expressions of
``batched_sim._terms_core``.  The event engine therefore diffs against
the analytic model on SCHEDULE STRUCTURE (pipeline bubbles, overlap,
congestion, OCS reconfiguration) — not on unit costs.  See DESIGN.md
§events.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.hardware import HW
from repro.core.mcm import MCMArch
from repro.core.network import OITopology, allocate_links
from repro.core.simulator import (SimResult, _bank_swap_reuse_ok, _gemm_eff,
                                  map_intra, simulate)
from repro.core.traffic import (PARALLELISMS, PHASE, Strategy,
                                reusable_pairs, traffic_volumes)
from repro.core.workload import Workload

SCHEDULES = ("gpipe", "1f1b", "interleaved")


# ---------------------------------------------------------------------------
# Task / program data model
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TaskSpec:
    """One task of a node template.

    ``kind`` is ``compute`` (fixed ``dur``) or ``coll`` (a flow of
    ``nbytes`` on ``rail``, behind a fixed serial ``latency``).  ``mult``
    is the number of identical sibling flows the replayed representative
    stands for on its rail (the dies of an MCM share the rail, so a
    collective of a fully-lockstep group contends with ``mult`` copies
    of itself).  ``preds`` are node-internal dependencies as
    ``(task_index, slack_s)`` — a positive slack lets this task start
    that many seconds BEFORE the predecessor finishes (the CP /
    ring-attention overlap window).  ``config`` names the rail
    configuration a reuse-shared rail must be switched to before the
    flow can start (OCS reconfiguration events).
    """

    kind: str                      # "compute" | "coll"
    label: str
    phase: str                     # traffic.PHASE tag or "compute"
    parallelism: str = ""
    dur: float = 0.0               # compute only
    nbytes: float = 0.0            # coll only (per device copy)
    rail: str = ""                 # resource template name (coll only)
    mult: int = 1                  # sibling flows sharing the rail
    latency: float = 0.0           # fixed serial launch/propagation time
    config: str = ""               # required rail configuration
    preds: Tuple[Tuple[int, float], ...] = ()


@dataclass(frozen=True)
class StepProgram:
    """Compiled one-step task DAG plus the resources it runs on."""

    workload: Workload
    strategy: Strategy
    mcm: MCMArch
    fabric: str
    schedule: str
    n_stages: int                  # pp
    v: int                         # virtual chunks per stage (interleaved)
    n_micro: int
    fwd_node: Tuple[TaskSpec, ...]
    bwd_node: Tuple[TaskSpec, ...]
    dp_tasks: Tuple[TaskSpec, ...]     # chained segments (intra -> inter)
    dp_overlap: float                  # seconds creditable against bwd
    resources: Dict[str, float]        # rail template name -> capacity B/s
    hbm_relay_bw: float                # per-die relay cap (hbm_bw / 2)
    reuse_rail: str = ""               # shared rail template ("" = none)
    reuse_pair: Optional[Tuple[str, str]] = None
    ocs_paper_mode: bool = False
    ocs_switch_latency_s: float = 0.0
    analytic: Optional[SimResult] = None
    bytes_expected: Dict[str, float] = field(default_factory=dict)
    meta: Dict[str, float] = field(default_factory=dict)

    # -- steady-state node spans (the batch-replay unit costs) ----------
    def steady_rate(self, t: TaskSpec) -> float:
        """Per-copy flow rate when every sibling is active (the analytic
        model's bandwidth assumption)."""
        return min(self.resources[t.rail] / t.mult, self.hbm_relay_bw)

    def task_cost(self, t: TaskSpec) -> float:
        if t.kind == "compute":
            return t.dur
        return t.latency + t.nbytes / self.steady_rate(t)

    def node_span(self, direction: str) -> float:
        """Steady-state span of one (stage, chunk, micro) node."""
        tasks = self.fwd_node if direction == "fwd" else self.bwd_node
        starts: List[float] = []
        ends: List[float] = []
        for t in tasks:
            start = 0.0
            for j, slack in t.preds:
                # slack may pull the start earlier, but never before the
                # predecessor itself started
                start = max(start, max(ends[j] - slack, starts[j]))
            starts.append(start)
            ends.append(start + self.task_cost(t))
        return max(ends) if ends else 0.0

    def dp_cost(self) -> float:
        return sum(self.task_cost(t) for t in self.dp_tasks)

    def spans(self) -> Tuple[float, float, float, float]:
        """(fwd span, bwd span, dp cost, dp overlap credit), memoized
        per instance: batch replay reads these once per record and the
        Python task walk would otherwise dominate its setup."""
        cached = self.__dict__.get("_span_cache")
        if cached is None:
            cached = (self.node_span("fwd"), self.node_span("bwd"),
                      self.dp_cost(), self.dp_overlap)
            object.__setattr__(self, "_span_cache", cached)
        return cached


# ---------------------------------------------------------------------------
# Compilation
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class _Segment:
    rail: str
    mult: int
    alpha: float               # per-hop launch latency on this segment


def _chain(tasks: List[TaskSpec]) -> Tuple[TaskSpec, ...]:
    """Default-serialize a task list: each task after the previous one,
    preserving explicitly-set preds (the CP overlap pair)."""
    import dataclasses
    out: List[TaskSpec] = []
    for i, t in enumerate(tasks):
        if not t.preds and i > 0:
            t = dataclasses.replace(t, preds=((i - 1, 0.0),))
        out.append(t)
    return tuple(out)


def compile_step(w: Workload, s: Strategy, mcm: MCMArch,
                 fabric: str = "oi", topo: Optional[OITopology] = None,
                 reuse: bool = True, hw: Optional[HW] = None,
                 schedule: str = "1f1b",
                 virtual_chunks: Optional[int] = None) -> StepProgram:
    """Compile one design point into its per-microbatch task DAG."""
    if schedule not in SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r}; "
                         f"known: {list(SCHEDULES)}")
    hw = hw or mcm.hw
    analytic = simulate(w, s, mcm, fabric=fabric, topo=topo, reuse=reuse,
                        hw=hw)
    if not analytic.feasible:
        raise ValueError(f"infeasible design point: {analytic.reason}")
    intra, inter = map_intra(w, s, mcm)
    n_micro = max(s.n_micro, 1)
    layers_stage = max(w.n_layers // s.pp, 1)
    attn_stage = max(w.n_attn_layers // s.pp, 1) if w.n_attn_layers else 0
    moe_stage = max(w.n_moe_layers // s.pp, 1) if w.n_moe_layers else 0

    v = virtual_chunks if virtual_chunks is not None else \
        (2 if schedule == "interleaved" else 1)
    v = max(1, min(v, layers_stage, n_micro))
    if schedule != "interleaved":
        v = 1

    # ---------------- unit costs (identical to simulate()) -------------
    flops_dev = w.step_flops() / mcm.n_devices
    eff = _gemm_eff(w, s, hw) if hw.model_gemm_eff else 1.0
    t_comp = flops_dev / (mcm.die_flops * hw.mfu_ceiling * eff)
    local_params = (w.nonexpert_params / (s.tp * s.pp)
                    + w.expert_params / (s.tp * s.pp * s.ep))
    hbm_stream = (local_params * w.bytes_param * 2.0 * n_micro
                  + local_params * 16.0
                  + 12.0 * w.tokens_per_step / (s.dp * s.cp * s.tp)
                  * w.d_model * w.bytes_act * layers_stage)
    t_mem = hbm_stream / mcm.hbm_bw
    tile = max(t_comp, t_mem)

    vols = traffic_volumes(w, s)
    inter_vols = {p: vols[p] for p in PARALLELISMS
                  if inter.get(p, 1) > 1 and vols[p] > 0}
    hbm_relay = mcm.hbm_bw / 2.0

    # ---------------- reuse decision + link allocation ------------------
    # replicates simulate()'s dynamic-reuse block operation-for-operation
    reuse_pair: Optional[Tuple[str, str]] = None
    alloc: Dict[str, int] = {}
    if fabric == "oi":
        if topo is not None:
            alloc = dict(topo.link_alloc)
            reuse_pair = topo.reuse_pair
        else:
            if reuse:
                pairs = [pr for pr in reusable_pairs(w, s)
                         if pr[0] in inter_vols and pr[1] in inter_vols]
                reuse_pair = pairs[0] if pairs else None
            alloc = allocate_links(inter_vols, mcm.total_links, reuse_pair)
        if reuse_pair is not None:
            gap = t_comp / max(layers_stage * n_micro, 1) / 2.0
            if hw.ocs_reuse_mode == "paper":
                pass
            elif not _bank_swap_reuse_ok(gap, n_micro, hw):
                reuse_pair = None
                alloc = allocate_links(inter_vols, mcm.total_links, None)

    # ---------------- per-parallelism comm segments ---------------------
    resources: Dict[str, float] = {}
    segments: Dict[str, List[_Segment]] = {p: [] for p in PARALLELISMS}
    reuse_rail = ""
    for p in PARALLELISMS:
        deg = s.degree(p)
        if deg <= 1 or vols[p] == 0.0:
            continue
        if intra.get(p, 1) > 1:
            if fabric == "nvlink":
                cap = hw.nvlink_bw * hw.fabric_eff_elec
            else:
                cap = mcm.intra_ring_bw(intra[p])
            name = f"intra:{p}"
            resources[name] = cap
            segments[p].append(_Segment(name, 1, hw.lat_intra_s))
        if inter.get(p, 1) > 1:
            if fabric in ("ib", "nvlink"):
                name = "pipe"
                resources[name] = hw.ib_bw * hw.fabric_eff_elec
                segments[p].append(_Segment(name, 1, hw.lat_ib_s))
            else:
                # only the (CP, EP) pair time-divides ONE rail with
                # mid-layer bank swaps (the paper's primary pair —
                # per-layer attention/FFN alternation).  Step-edge
                # pairs (X, DP) are modelled as disjoint rails of the
                # shared allocation: a single long all-reduce cannot
                # bank-swap against per-layer traffic, and the HBM
                # relay still congests them when they overlap.
                if reuse_pair == ("CP", "EP") and p in reuse_pair:
                    name = "oi:CP+EP"
                    reuse_rail = name
                else:
                    name = f"oi:{p}"
                links = max(alloc.get(p, 1), 1)
                resources[name] = links * hw.oi_link_bw * hw.fabric_eff_oi
                segments[p].append(_Segment(name, mcm.dies_per_mcm,
                                            hw.lat_oi_s))

    # invocation counts / hops — simulate()'s latency model
    inv = {"TP": 8 * layers_stage * n_micro,
           "CP": 2 * attn_stage * n_micro,
           "EP": 4 * moe_stage * n_micro,
           "DP": 1,
           "PP": 2 * n_micro}
    hops = {"TP": s.tp - 1, "CP": s.cp - 1,
            "EP": max(int(math.ceil(math.log2(max(s.ep, 2)))), 1),
            "DP": 2 * (s.dp - 1), "PP": 1}

    def coll(p: str, share: float, overlap_pred=None) -> List[TaskSpec]:
        """Coll tasks for parallelism ``p`` carrying ``share`` of its
        per-step bytes+latency (one task per segment, chained)."""
        out = []
        for seg in segments[p]:
            cfg = p if (reuse_pair is not None and p in reuse_pair
                        and seg.rail == reuse_rail) else ""
            out.append(TaskSpec(
                kind="coll", label=f"{p.lower()}", phase=PHASE[p],
                parallelism=p, nbytes=vols[p] * share, rail=seg.rail,
                mult=seg.mult, latency=inv[p] * hops[p] * seg.alpha * share,
                config=cfg,
                preds=(overlap_pred,) if overlap_pred and not out else ()))
        return out

    # ---------------- node templates ------------------------------------
    has_cp = bool(segments["CP"])
    nmv = n_micro * v

    def build_node(direction: str) -> Tuple[TaskSpec, ...]:
        import dataclasses
        dirfrac = (1.0 / 3.0) if direction == "fwd" else (2.0 / 3.0)
        node_tile = tile * dirfrac / nmv
        share = 0.5 / nmv            # fwd/bwd halves of per-layer comm
        credit = 0.3 * t_comp * hw.cp_overlap_frac * dirfrac / nmv
        tasks: List[TaskSpec] = []
        barrier = None               # (attn_i, cp_last_i) sync point

        def add_attn_cp():
            nonlocal barrier
            tasks.append(TaskSpec(kind="compute", label="attn",
                                  phase="attention", dur=0.3 * node_tile))
            ai = len(tasks) - 1
            tasks.extend(coll("CP", share, overlap_pred=(ai, credit)))
            barrier = (ai, len(tasks) - 1)

        def add_after_barrier(t: TaskSpec):
            nonlocal barrier
            if barrier is not None:
                t = dataclasses.replace(
                    t, preds=((barrier[0], 0.0), (barrier[1], 0.0)))
                barrier = None
            tasks.append(t)

        other_t = TaskSpec(kind="compute", label="ffn", phase="ffn",
                           dur=(0.7 if has_cp else 1.0) * node_tile)
        tasks.extend(coll("TP", share))
        if direction == "fwd":
            if has_cp:
                add_attn_cp()
            add_after_barrier(other_t)
            tasks.extend(coll("EP", share))
        else:
            tasks.append(other_t)
            tasks.extend(coll("EP", share))
            if has_cp:
                add_attn_cp()
        if s.pp > 1 and vols["PP"] > 0:
            # one stage-boundary send per node; charged uniformly across
            # stages as the analytic model does (interleaving pays v of
            # them per microbatch — a real cost the analytic model
            # cannot see)
            for t in coll("PP", 0.5 / n_micro):
                add_after_barrier(t)
        return _chain(tasks)

    fwd_node = build_node("fwd")
    bwd_node = build_node("bwd")
    dp_tasks = _chain(coll("DP", 1.0))
    dp_overlap = (2.0 / 3.0) * t_comp * hw.dp_overlap_frac \
        if dp_tasks else 0.0

    bytes_expected = {}
    for p in PARALLELISMS:
        nseg = len(segments[p])
        if not nseg or vols[p] == 0.0:
            continue
        mult_v = v if p == "PP" else 1
        bytes_expected[p] = vols[p] * nseg * mult_v

    prog = StepProgram(
        workload=w, strategy=s, mcm=mcm, fabric=fabric, schedule=schedule,
        n_stages=s.pp, v=v, n_micro=n_micro,
        fwd_node=fwd_node, bwd_node=bwd_node, dp_tasks=dp_tasks,
        dp_overlap=dp_overlap, resources=resources,
        hbm_relay_bw=hbm_relay, reuse_rail=reuse_rail,
        reuse_pair=reuse_pair,
        ocs_paper_mode=hw.ocs_reuse_mode == "paper",
        ocs_switch_latency_s=hw.ocs_switch_latency_s,
        analytic=analytic, bytes_expected=bytes_expected,
        meta={"t_comp": t_comp, "t_mem": t_mem, "tile": tile,
              "reuse_active": float(reuse_pair is not None)})
    return prog


# ---------------------------------------------------------------------------
# Pipeline schedules: static per-device op orders
# ---------------------------------------------------------------------------
def _fwd_order(pp: int, v: int, nm: int) -> List[Tuple[int, int]]:
    """Interleaved (chunk, micro) forward order: microbatch groups of
    ``pp`` cycle through the virtual chunks (Megatron's interleaved
    ordering); degenerates to plain micro order at v == 1."""
    out = []
    i = 0
    while len(out) < nm * v:
        c = (i // pp) % v
        m = (i // (pp * v)) * pp + i % pp
        i += 1
        if m < nm:
            out.append((c, m))
    return out


def device_op_order(schedule: str, pp: int, v: int, nm: int, stage: int
                    ) -> List[Tuple[str, int, int]]:
    """Static (dir, chunk, micro) execution order for one device-stage."""
    if schedule == "gpipe":
        fwd = [("F", c, m) for c in range(v) for m in range(nm)]
        bwd = [("B", c, m) for c in reversed(range(v))
               for m in reversed(range(nm))]
        return fwd + bwd
    # 1F1B family: warmup forwards, steady (F, B) pairs, cooldown bwds
    fwd = [("F", c, m) for c, m in _fwd_order(pp, v, nm)]
    if schedule == "interleaved":
        bwd = [("B", v - 1 - c, m) for c, m in _fwd_order(pp, v, nm)]
        warm = min(len(fwd), (pp - stage - 1) * 2 + (v - 1) * pp)
    elif schedule == "1f1b":
        bwd = [("B", 0, m) for m in range(nm)]
        warm = min(len(fwd), pp - stage - 1)
    else:
        raise ValueError(f"unknown schedule {schedule!r}")
    order = fwd[:warm]
    rest = fwd[warm:]
    for i, b in enumerate(bwd):
        if i < len(rest):
            order.append(rest[i])
        order.append(b)
    return order


def op_dependency(direction: str, stage: int, chunk: int, micro: int,
                  pp: int, v: int) -> Optional[Tuple[str, int, int, int]]:
    """Cross-node dependency of one op: (dir, stage, chunk, micro) of the
    node whose END this op's START waits for (None = no dependency)."""
    vs = chunk * pp + stage
    if direction == "F":
        if vs == 0:
            return None
        if stage > 0:
            return ("F", stage - 1, chunk, micro)
        return ("F", pp - 1, chunk - 1, micro)
    if vs == pp * v - 1:
        return ("F", stage, chunk, micro)       # own forward
    if stage < pp - 1:
        return ("B", stage + 1, chunk, micro)
    return ("B", 0, chunk + 1, micro)
