"""Vectorized batch replay: NumPy event queues over K records at once.

``replay_batch`` replays many compiled ``StepProgram``s together, the
same discipline as ``repro.dse.batched_sim``: the per-device event
queues of every record advance in lockstep slot order, with one numpy
operation per (stage, slot) wave across ALL records — no per-record
Python in the recurrence.  Node spans and the DP all-reduce use each
program's steady-state rates (every sibling flow active — the fair-share
fixed point of a lockstep schedule), so the batch path reproduces the
scalar engine up to its sub-node congestion dynamics (DP/HBM-relay
sharing, OCS bank waits); parity is pinned in tests/test_events.py.

This is what keeps ``Study.run(validate_top=K)`` off the critical path:
stamping K refined records costs one vectorized wavefront instead of K
full discrete-event replays.  ``interleaved`` programs fall back to the
scalar engine (their chunk-wrap dependencies are not expressible as a
monotone stage sweep); ``gpipe`` and ``1f1b`` run fully vectorized.
"""
from __future__ import annotations

import warnings
from typing import Dict, List, Sequence

import numpy as np

from repro.events.dag import StepProgram, device_op_order
from repro.events.engine import replay
from repro.obs import metrics


def replay_batch(programs: Sequence[StepProgram]) -> Dict[str, np.ndarray]:
    """Replay K programs; returns SoA arrays over the batch:
    ``step_time``, ``makespan_body``, ``bubble``, ``dp_exposed``,
    ``analytic_step_time``, ``err``, plus a ``scalar_fallback`` bool
    mask of the rows that took the scalar engine (non-vectorizable
    schedules — counted on ``batch_replay.scalar_fallback``)."""
    K = len(programs)
    out = {k: np.zeros(K) for k in
           ("step_time", "makespan_body", "bubble", "dp_exposed",
            "analytic_step_time", "err")}
    out["scalar_fallback"] = np.zeros(K, bool)
    if K == 0:
        return out

    vec_rows = [i for i, p in enumerate(programs)
                if p.schedule in ("gpipe", "1f1b")]
    n_fb = K - len(vec_rows)
    metrics.inc("batch_replay.records", K)
    if n_fb:
        metrics.inc("batch_replay.scalar_fallback", n_fb)
        scheds = sorted({p.schedule for i, p in enumerate(programs)
                         if i not in set(vec_rows)})
        warnings.warn(
            f"replay_batch: {n_fb}/{K} programs (schedules {scheds}) "
            f"are not expressible as a monotone stage sweep and fall "
            f"back to the scalar event engine",
            RuntimeWarning, stacklevel=2)
    for i, p in enumerate(programs):
        if i not in vec_rows:                 # interleaved: scalar engine
            r = replay(p)
            out["step_time"][i] = r.step_time
            out["makespan_body"][i] = r.makespan_body
            out["bubble"][i] = r.bubble
            out["dp_exposed"][i] = r.dp_exposed
            out["scalar_fallback"][i] = True
    if vec_rows:
        sub = [programs[i] for i in vec_rows]
        res = _replay_wavefront(sub)
        for k, v in res.items():
            out[k][np.array(vec_rows)] = v
    out["analytic_step_time"] = np.array(
        [p.analytic.step_time if p.analytic else np.nan for p in programs])
    with np.errstate(invalid="ignore", divide="ignore"):
        out["err"] = (out["step_time"] - out["analytic_step_time"]) \
            / out["analytic_step_time"]
    return out


def _replay_wavefront(progs: List[StepProgram]) -> Dict[str, np.ndarray]:
    """Lockstep (stage, slot) wavefront over K gpipe/1f1b programs."""
    K = len(progs)
    pp = np.array([p.n_stages for p in progs], np.int64)
    nm = np.array([p.n_micro for p in progs], np.int64)
    tau_f = np.array([p.node_span("fwd") for p in progs])
    tau_b = np.array([p.node_span("bwd") for p in progs])
    t_dp = np.array([p.dp_cost() for p in progs])
    credit = np.array([p.dp_overlap for p in progs])
    S, O, M = int(pp.max()), int(2 * nm.max()), int(nm.max())

    # static op identity per (record, stage, slot): dir 0=F, 1=B, -1=none
    dirs = np.full((K, S, O), -1, np.int64)
    micro = np.zeros((K, S, O), np.int64)
    for k, p in enumerate(progs):
        for s in range(int(pp[k])):
            for i, (d, _c, m) in enumerate(
                    device_op_order(p.schedule, int(pp[k]), 1,
                                    int(nm[k]), s)):
                dirs[k, s, i] = 0 if d == "F" else 1
                micro[k, s, i] = m

    f_end = np.zeros((K, S, M))
    b_end = np.zeros((K, S, M))
    dev_free = np.zeros((K, S))
    ks = np.arange(K)

    any_f = (dirs == 0).any(0)              # (S, O) wave masks
    any_b = (dirs == 1).any(0)
    for i in range(O):
        for s in range(S):                  # fwd deps point down-stage
            if not any_f[s, i]:
                continue
            sel = dirs[:, s, i] == 0
            rows = ks[sel]
            m = micro[rows, s, i]
            dep = f_end[rows, s - 1, m] if s > 0 else 0.0
            start = np.maximum(dev_free[rows, s], dep)
            end = start + tau_f[rows]
            f_end[rows, s, m] = end
            dev_free[rows, s] = end
        for s in range(S - 1, -1, -1):      # bwd deps point up-stage
            if not any_b[s, i]:
                continue
            sel = dirs[:, s, i] == 1
            rows = ks[sel]
            m = micro[rows, s, i]
            last = s == (pp[rows] - 1)
            nxt = np.minimum(s + 1, S - 1)
            dep = np.where(last, f_end[rows, s, m], b_end[rows, nxt, m])
            start = np.maximum(dev_free[rows, s], dep)
            end = start + tau_b[rows]
            b_end[rows, s, m] = end
            dev_free[rows, s] = end

    body_end = dev_free.max(1)
    busy = nm * (tau_f + tau_b)
    with np.errstate(invalid="ignore", divide="ignore"):
        bubble = np.where(busy > 0, body_end / busy - 1.0, 0.0)
    dp_exposed = np.maximum(t_dp - credit, 0.0)
    dp_exposed = np.where(t_dp > 0, dp_exposed, 0.0)
    return {"step_time": body_end + dp_exposed,
            "makespan_body": body_end, "bubble": bubble,
            "dp_exposed": dp_exposed}
