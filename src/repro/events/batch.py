"""Vectorized batch replay: one wavefront over K records, numpy or jax.

``replay_batch`` replays many compiled ``StepProgram``s together, the
same discipline as ``repro.dse.batched_sim``: the recurrence advances in
static topological LEVELS of the step DAG, with one array operation per
level across ALL records — no per-record Python in the recurrence.
Node spans and the DP all-reduce use each program's steady-state rates
(every sibling flow active — the fair-share fixed point of a lockstep
schedule), so the batch path reproduces the scalar engine up to its
sub-node congestion dynamics (DP/HBM-relay sharing, OCS bank waits);
parity is pinned in tests/test_events.py.

The schedule structure is entirely static per (schedule, pp, v,
n_micro): ``_shape_tables`` compiles ``device_op_order`` +
``op_dependency`` once per shape into level-indexed integer tables.
Ops are layered by Kahn's algorithm over the op DAG (each device's
in-order slot chain plus the cross-device ``op_dependency`` edges), so
every dependency lands in a strictly earlier level and each (stage,
level) holds at most one op.  The tables, all ``(S, L)``:

  * ``ldir``    direction of the op a stage runs at each level
                (0=F, 1=B, -1=idle);
  * ``ldep_s``  the stage whose node END this op's START waits for
                (-1 = no cross dependency);
  * ``ldep_l``  the LEVEL that dependency completed at — the
                dependency-index table that makes chunk-wrapped
                ``interleaved`` deps as cheap as ``gpipe``'s monotone
                ones.

The recurrence is ``end[s, l] = max(dev_end[s], end[ldep_s, ldep_l])
+ tau`` — every schedule (``gpipe`` / ``1f1b`` / ``interleaved``) runs
through this one vectorized wavefront; there is no scalar fallback (the
``scalar_fallback`` output key is kept, always ``False``, for schema
stability).

Two backends for the recurrence (``backend=`` numpy|jax|auto).
``numpy`` loops the L levels in Python with (K, S) array ops per level
over the gathered per-record tables; records are processed in K-chunks
sized to ``NUMPY_CHUNK_BUDGET_BYTES`` of scratch — deep-pipeline shapes
(e.g. pp=16, v=2, nm=64) otherwise grow the per-record history past
the last-level cache and large K replays SLOWER than small K (the
one-time BENCH_events.json qwen3 anomaly: 3.6k rec/s at K=1024 vs
4.4k at K=64; chunked, rates are monotone in K).  ``jax`` goes further
than ``batched_sim``'s vmap-a-traced-function discipline: because the
tables are compile-time constants per shape key, ``_jax_shape_fn``
unrolls the whole recurrence AT TRACE TIME into a straight-line program
over (K,) vectors — no gathers, no carried history, no loop (a traced
``fori_loop`` over levels measures ~15x slower on CPU: XLA loop
overhead plus the O(S·L) carried history swamp the ~S flops per level).
Mixed-shape batches are grouped by shape key, one jit call per group;
each group's rows are edge-padded to the next power of two, so the jit
cache keys on (schedule, pp, v, n_micro, K-bucket) and a same-bucket
batch stream never re-traces — ``_JAX_TRACES`` counts traces exactly
like ``batched_sim._JAX_TRACES``.  ``auto`` picks jax at
``JAX_AUTO_MIN_RECORDS`` rows when jax imports.  This is what keeps
``Study.run(validate_top=K)`` and the outer search's fused per-round
event replay off the critical path.
"""
from __future__ import annotations

import functools
from collections import deque
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.dse.batched_sim import _bucket, _jax_available
from repro.events.dag import StepProgram, device_op_order, op_dependency
from repro.obs import metrics

# below this many records the numpy level loop beats jax dispatch
# overhead; used by backend="auto" (the crossover is far lower than
# batched_sim's: one replay record is a whole schedule recurrence, not
# one closed-form expression).  The chunked numpy wavefront scales
# monotonically in K, so the crossover is K-independent and 32 holds
# across the bench shapes.
JAX_AUTO_MIN_RECORDS = 32

# per-chunk scratch budget for the numpy wavefront: float64 history +
# three gathered int32 tables ~ 20 bytes per (record, stage, level)
# cell.  Chunking K keeps the history resident in cache while the
# level loop sweeps it (see module docstring).
NUMPY_CHUNK_BUDGET_BYTES = 8 << 20

# incremented once per jax trace of a shape-keyed wavefront — the same
# contract as dse.batched_sim._JAX_TRACES (tests pin that a same-bucket
# batch stream does not grow it)
_JAX_TRACES = {"count": 0}


def jax_stats() -> Dict[str, int]:
    """Snapshot of the wavefront jit-cache internals: cumulative
    ``traces`` since process start and the ``auto`` crossover."""
    return {"traces": int(_JAX_TRACES["count"]),
            "auto_min_records": JAX_AUTO_MIN_RECORDS}


def resolve_backend(backend: str, n_records: int) -> str:
    """Map ``auto`` to a concrete wavefront backend for K records."""
    if backend == "auto":
        return "jax" if (n_records >= JAX_AUTO_MIN_RECORDS
                         and _jax_available()) else "numpy"
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown backend {backend!r}; "
                         f"use 'numpy', 'jax' or 'auto'")
    return backend


# ---------------------------------------------------------------------------
# Static shape tables: schedule structure compiled once per shape
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=512)
def _shape_tables(schedule: str, pp: int, v: int, nm: int
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(ldir, ldep_s, ldep_l), each (S, L) — see module docstring.

    Kahn layering: an op lands at level 1 + max(level of preds) where
    its preds are the previous slot on the same device and its
    ``op_dependency`` target.  Because the same-device chain is always
    an edge, levels are strictly increasing along each device's order,
    giving the at-most-one-op-per-(stage, level) property the dense
    recurrence relies on — and making the same-device predecessor
    always available as the running per-device end, so only the cross
    dependency needs an index.
    """
    orders = [device_op_order(schedule, pp, v, nm, s) for s in range(pp)]
    O = max(len(o) for o in orders)
    slot_of: Dict[Tuple[str, int, int, int], int] = {}
    for s, order in enumerate(orders):
        for i, (d, c, m) in enumerate(order):
            slot_of[(d, s, c, m)] = i

    dep_s = np.full((pp, O), -1, np.int32)
    dep_i = np.full((pp, O), -1, np.int32)
    for s, order in enumerate(orders):
        for i, (d, c, m) in enumerate(order):
            dep = op_dependency(d, s, c, m, pp, v)
            if dep is not None:
                dd, ds, dc, dm = dep
                dep_s[s, i] = ds
                dep_i[s, i] = slot_of[(dd, ds, dc, dm)]

    # Kahn layering over (in-order chain + cross-dep) edges
    def preds(s: int, i: int) -> List[Tuple[int, int]]:
        out = [(s, i - 1)] if i > 0 else []
        if dep_s[s, i] >= 0:
            out.append((int(dep_s[s, i]), int(dep_i[s, i])))
        return out

    n_ops = sum(len(o) for o in orders)
    indeg: Dict[Tuple[int, int], int] = {}
    succ: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
    for s, order in enumerate(orders):
        for i in range(len(order)):
            ps = preds(s, i)
            indeg[(s, i)] = len(ps)
            for p in ps:
                succ.setdefault(p, []).append((s, i))
    lvl = np.full((pp, O), -1, np.int32)
    q = deque(k for k, d in indeg.items() if d == 0)
    n_done = 0
    while q:
        s, i = q.popleft()
        n_done += 1
        lvl[s, i] = max((lvl[ps, pi] for ps, pi in preds(s, i)),
                        default=-1) + 1
        for nxt in succ.get((s, i), ()):
            indeg[nxt] -= 1
            if indeg[nxt] == 0:
                q.append(nxt)
    if n_done != n_ops:
        raise ValueError(
            f"cyclic op dependencies for schedule={schedule!r} "
            f"pp={pp} v={v} nm={nm} ({n_ops - n_done} ops unplaced)")

    L = int(lvl.max()) + 1
    ldir = np.full((pp, L), -1, np.int32)
    ldep_s = np.full((pp, L), -1, np.int32)
    ldep_l = np.full((pp, L), -1, np.int32)
    for s, order in enumerate(orders):
        for i, (d, _c, _m) in enumerate(order):
            lv = lvl[s, i]
            ldir[s, lv] = 0 if d == "F" else 1
            if dep_s[s, i] >= 0:
                ldep_s[s, lv] = dep_s[s, i]
                ldep_l[s, lv] = lvl[dep_s[s, i], dep_i[s, i]]
    for a in (ldir, ldep_s, ldep_l):
        a.setflags(write=False)
    return ldir, ldep_s, ldep_l


def _shape_key(p: StepProgram) -> Tuple[str, int, int, int]:
    return (p.schedule, p.n_stages, p.v, p.n_micro)


def _stack_tables(shape_keys: Sequence[Tuple], key_rows: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Gather per-record tables (K, S, L), padded to the batch maxima
    with -1 sentinels; table construction is paid once per shape
    (memoized), the per-record cost is one fancy-index gather.
    ``shape_keys`` lists the batch's unique shape keys and ``key_rows``
    maps each record to its index in that list."""
    tabs = [_shape_tables(*key) for key in shape_keys]
    S = max(t[0].shape[0] for t in tabs)
    L = max(t[0].shape[1] for t in tabs)
    U = len(tabs)
    stacks = [np.full((U, S, L), -1, np.int32) for _ in range(3)]
    for u, tab in enumerate(tabs):
        for a, src in zip(stacks, tab):
            a[u, :src.shape[0], :src.shape[1]] = src
    return tuple(a[key_rows] for a in stacks)


# ---------------------------------------------------------------------------
# The wave recurrence — numpy level loop
# ---------------------------------------------------------------------------
def _wavefront_numpy(ldir: np.ndarray, ldep_s: np.ndarray,
                     ldep_l: np.ndarray, tau_f: np.ndarray,
                     tau_b: np.ndarray) -> np.ndarray:
    """(K,) body makespans from (K, S, L) tables."""
    K, S, L = ldir.shape
    hist = np.zeros((K, S, L))          # end time of the op at (s, lv)
    dev_end = np.zeros((K, S))          # running end per device
    kk = np.arange(K)[:, None]
    tf = tau_f[:, None]
    tb = tau_b[:, None]
    for lv in range(L):
        d = ldir[:, :, lv]                          # (K, S)
        act = d >= 0
        ds = ldep_s[:, :, lv]
        has = ds >= 0
        dep = np.where(
            has,
            hist[kk, np.where(has, ds, 0),
                 np.where(has, ldep_l[:, :, lv], 0)],
            0.0)
        tau = np.where(d == 0, tf, tb)
        val = np.maximum(dev_end, dep) + tau
        hist[:, :, lv] = np.where(act, val, 0.0)
        dev_end = np.where(act, val, dev_end)
    return dev_end.max(axis=1)


def _wavefront_numpy_chunked(shape_keys: Sequence[Tuple],
                             key_rows: np.ndarray, tau_f: np.ndarray,
                             tau_b: np.ndarray) -> np.ndarray:
    """(K,) body makespans, gathering tables and running the level loop
    in K-chunks bounded by ``NUMPY_CHUNK_BUDGET_BYTES`` of scratch."""
    K = key_rows.shape[0]
    tabs = [_shape_tables(*key) for key in shape_keys]
    S = max(t[0].shape[0] for t in tabs)
    L = max(t[0].shape[1] for t in tabs)
    per_rec = 20 * S * L              # hist float64 + 3 int32 tables
    kc = max(NUMPY_CHUNK_BUDGET_BYTES // max(per_rec, 1), 16)
    if kc >= K:
        return _wavefront_numpy(*_stack_tables(shape_keys, key_rows),
                                tau_f, tau_b)
    out = np.empty(K)
    for lo in range(0, K, kc):
        sl = slice(lo, min(lo + kc, K))
        out[sl] = _wavefront_numpy(
            *_stack_tables(shape_keys, key_rows[sl]), tau_f[sl], tau_b[sl])
    return out


# ---------------------------------------------------------------------------
# The wave recurrence — jax, unrolled at trace time per shape key
# ---------------------------------------------------------------------------
# row order of the per-record input matrix handed to both backends
# (spans + per-program scalars, gathered once per unique program)
_ROW_KEYS = ("tau_f", "tau_b", "t_dp", "credit", "nmv", "analytic")
# row order of the stacked result matrix
_RES_KEYS = ("step_time", "makespan_body", "bubble", "dp_exposed", "err")


@functools.lru_cache(maxsize=512)
def _jax_shape_fn(schedule: str, pp: int, v: int, nm: int):
    """jit(rows (6, K) -> results (5, K)) for ONE shape key.

    The level tables are compile-time constants here, so the trace
    emits the recurrence as straight-line SSA over (K,) vectors: one
    ``maximum`` + ``add`` per op, dependencies resolved by NAME at
    trace time (no gathers, no carried history array, no loop; a
    traced ``fori_loop`` over levels measures ~15x slower on CPU).
    The bubble/DP epilogue is fused into the same trace.  The jit
    cache then keys only on the (bucketed) K — a new trace happens per
    (shape key, K-bucket), counted by ``_JAX_TRACES``."""
    import jax
    import jax.numpy as jnp

    ldir, ldep_s, ldep_l = _shape_tables(schedule, pp, v, nm)
    S, L = ldir.shape
    # plain int lists: the unroll below must not touch numpy at trace
    # time (jax-hygiene: no np.* inside a jit entry)
    ldir_t = [[int(x) for x in row] for row in ldir]
    ldep_s_t = [[int(x) for x in row] for row in ldep_s]
    ldep_l_t = [[int(x) for x in row] for row in ldep_l]

    def batch_fn(rows):
        # runs at TRACE time only — both side effects count retraces
        _JAX_TRACES["count"] += 1
        metrics.inc("batch_replay.jax_retraces")
        tau_f, tau_b, t_dp, credit, nmv, analytic = rows
        hist: Dict[Tuple[int, int], object] = {}
        dev_end: List[object] = [None] * S
        for lv in range(L):
            for s in range(S):
                d = ldir_t[s][lv]
                if d < 0:
                    continue
                tau = tau_f if d == 0 else tau_b
                # static table lookup, decided at trace time
                dep = hist[(ldep_s_t[s][lv], ldep_l_t[s][lv])] \
                    if ldep_s_t[s][lv] >= 0 else None  # chiplint: ignore[jax-hygiene]
                prev = dev_end[s]
                if prev is None and dep is None:
                    val = tau
                elif dep is None:
                    val = prev + tau
                elif prev is None:
                    val = dep + tau
                else:
                    val = jnp.maximum(prev, dep) + tau
                hist[(s, lv)] = val
                dev_end[s] = val
        body_end = dev_end[0]
        for s in range(1, S):
            # skip never-scheduled stages, known at trace time
            if dev_end[s] is not None:  # chiplint: ignore[jax-hygiene]
                body_end = jnp.maximum(body_end, dev_end[s])
        # epilogue: same expressions as the numpy path in replay_batch
        busy = nmv * (tau_f + tau_b)
        bubble = jnp.where(busy > 0, body_end / busy - 1.0, 0.0)
        dp_exposed = jnp.maximum(t_dp - credit, 0.0)
        dp_exposed = jnp.where(t_dp > 0, dp_exposed, 0.0)
        step_time = body_end + dp_exposed
        err = (step_time - analytic) / analytic
        return jnp.stack((step_time, body_end, bubble, dp_exposed, err))

    return jax.jit(batch_fn)


def _pad_edge(a: np.ndarray, nb: int) -> np.ndarray:
    """Edge-pad the trailing axis to the bucket: padded rows replicate
    the last real record, so the tail traces the same recurrence."""
    n = a.shape[-1]
    if nb == n:
        return a
    out = np.empty(a.shape[:-1] + (nb,))
    out[..., :n] = a
    out[..., n:] = a[..., n - 1:n]
    return out


def _replay_jax(shape_keys: Sequence[Tuple], key_rows: np.ndarray,
                rows: np.ndarray) -> np.ndarray:
    """(5, K) results from (6, K) inputs.  Group records by shape key
    (``key_rows`` maps row -> index into ``shape_keys``), one jit call
    per group, rows edge-padded to the next power-of-two bucket,
    scatter back."""
    from jax.experimental import enable_x64
    K = rows.shape[1]
    n_keys = len(shape_keys)
    metrics.inc("batch_replay.jax_calls", n_keys)
    with enable_x64():
        if n_keys == 1:                 # fast path: no gather/scatter
            nb = _bucket(K)
            fn = _jax_shape_fn(*shape_keys[0])
            metrics.inc("batch_replay.jax_pad_rows", nb - K)
            metrics.gauge("batch_replay.jax_bucket", nb)
            return np.asarray(fn(_pad_edge(rows, nb)))[:, :K]
        out = np.empty((len(_RES_KEYS), K))
        for ki in range(n_keys):
            idx = np.nonzero(key_rows == ki)[0]
            n = idx.shape[0]
            nb = _bucket(n)
            fn = _jax_shape_fn(*shape_keys[ki])
            metrics.inc("batch_replay.jax_pad_rows", nb - n)
            metrics.gauge("batch_replay.jax_bucket", nb)
            out[:, idx] = np.asarray(fn(_pad_edge(rows[:, idx], nb)))[:, :n]
    return out


# ---------------------------------------------------------------------------
# replay_rows / replay_batch
# ---------------------------------------------------------------------------
def replay_rows(shape_keys: Sequence[Tuple], key_rows: np.ndarray,
                rows: np.ndarray, backend: str = "auto"
                ) -> Dict[str, np.ndarray]:
    """Replay K pre-compiled record rows: ``rows`` is the (6, K)
    ``_ROW_KEYS`` matrix, ``shape_keys`` the batch's unique
    (schedule, pp, v, n_micro) keys and ``key_rows`` the per-record
    index into it.  This is the shared wavefront entry: ``replay_batch``
    extracts rows from ``StepProgram``s, ``events.compile_batch`` builds
    them vectorized without any programs.  Returns the SoA result dict
    (see ``replay_batch``)."""
    K = rows.shape[1]
    if K == 0:
        out = {k: np.zeros(0) for k in
               ("step_time", "makespan_body", "bubble", "dp_exposed",
                "analytic_step_time", "err")}
        out["scalar_fallback"] = np.zeros(0, bool)
        return out
    metrics.inc("batch_replay.records", K)
    backend = resolve_backend(backend, K)

    if backend == "jax":
        res = _replay_jax(shape_keys, key_rows, rows)
        out = dict(zip(_RES_KEYS, res))
        out["analytic_step_time"] = rows[5]
        out["scalar_fallback"] = np.zeros(K, bool)
        return out

    tau_f, tau_b, t_dp, credit, nmv, analytic = rows
    body_end = _wavefront_numpy_chunked(shape_keys, key_rows, tau_f, tau_b)

    busy = nmv * (tau_f + tau_b)
    with np.errstate(invalid="ignore", divide="ignore"):
        bubble = np.where(busy > 0, body_end / busy - 1.0, 0.0)
        dp_exposed = np.maximum(t_dp - credit, 0.0)
        dp_exposed = np.where(t_dp > 0, dp_exposed, 0.0)
        step_time = body_end + dp_exposed
        err = (step_time - analytic) / analytic
    return {"step_time": step_time, "makespan_body": body_end,
            "bubble": bubble, "dp_exposed": dp_exposed,
            "analytic_step_time": analytic, "err": err,
            "scalar_fallback": np.zeros(K, bool)}


def replay_batch(programs: Sequence[StepProgram],
                 backend: str = "auto") -> Dict[str, np.ndarray]:
    """Replay K programs; returns SoA arrays over the batch:
    ``step_time``, ``makespan_body``, ``bubble``, ``dp_exposed``,
    ``analytic_step_time``, ``err``, plus a ``scalar_fallback`` bool
    mask kept for schema stability — always ``False`` now that every
    schedule (gpipe / 1f1b / interleaved) runs through the vectorized
    wavefront.  ``backend`` selects the recurrence implementation
    (``numpy`` | ``jax`` | ``auto``, see module docstring)."""
    K = len(programs)
    if K == 0:
        return replay_rows((), np.zeros(0, np.int64), np.zeros((6, 0)),
                           backend=backend)

    # Dedupe by object identity at C speed: bench batches and outer
    # rounds replay few unique programs many times, so all per-record
    # Python (span walks, attribute reads, shape keying) is paid once
    # per UNIQUE program.  Held references keep ids unique.
    ids = np.fromiter(map(id, programs), np.int64, count=K)
    _, first, inv = np.unique(ids, return_index=True, return_inverse=True)
    uprogs = [programs[int(i)] for i in first]
    urows = np.array([p.spans() + (p.n_micro * p.v,
                                   p.analytic.step_time if p.analytic
                                   else np.nan)
                      for p in uprogs])                 # (U, 6)
    key_of: Dict[Tuple, int] = {}
    ukey_idx = np.empty(len(uprogs), np.int64)
    for u, p in enumerate(uprogs):
        ukey_idx[u] = key_of.setdefault(_shape_key(p), len(key_of))
    shape_keys = list(key_of)
    key_rows = ukey_idx[inv]                            # (K,)
    rows = np.ascontiguousarray(urows[inv].T)           # (6, K)
    return replay_rows(shape_keys, key_rows, rows, backend=backend)
