"""Unified study CLI — the one entrypoint over ``repro.api``.

    PYTHONPATH=src python -m repro.cli scenarios/paper_qwen3.json
    PYTHONPATH=src python -m repro.cli --model qwen3_moe_235b_a22b \
        --C 4e6 --fabrics oi,ib --driver exhaustive --top 5
    PYTHONPATH=src python -m repro.cli validate scenarios/*.json

Runs ``Study.run()`` on scenario JSON files (flags override fields) or on
a scenario built from flags alone (``--model all`` sweeps the whole
zoo), prints the best points + Pareto summary, and writes one versioned
``StudyResult`` JSON artifact per study.  Subsumes the old
``repro.dse.run`` CLI (kept as a deprecation shim).

The ``validate`` subcommand runs the event-driven fidelity harness
(``repro.events.validate``) over scenario presets: top points are
replayed by the discrete-event engine under the requested pipeline
schedules and compared against the analytic model, writing a versioned
fidelity report artifact.

Observability (``repro.obs``): ``--trace out.json`` on a study or
``validate`` run writes the HOST trace (where the pipeline spent its
wall time) as Chrome Trace Event JSON — open it in
https://ui.perfetto.dev.  The ``timeline`` subcommand replays a
scenario's best design point through the event engine with full
timeline recording and writes the SIMULATED step as a Perfetto trace
(one track per pipeline stage and per rail, OCS reconfigurations as
instant markers).  ``bench check`` re-measures the quick benchmark
workloads and gates them on the committed BENCH_*.json floors.

``calibrate`` is the execution-grounded loop (``repro.obs.profile`` +
``repro.calib``): profile the repo's real kernels, fit the analytic
cost constants (effective peak FLOP/s, HBM bytes/s, and the
``M/(M+half)`` efficiency curves), and write the schema-versioned
``CALIB.json`` — or, with ``--check``, re-measure and gate drift
against the committed artifact.

``lint`` runs chiplint (``repro.analysis``), the AST-based invariant
analyzer: parity drift between the scalar/batched/event-DAG engines,
jax trace hygiene, physical-unit mismatches, and determinism/metric-
schema violations — against the committed baseline
(``chiplint_baseline.json``).

Exit codes: 0 ok; 2 bad arguments; 3 when a study found NO feasible
design point (every sweep cell infeasible); ``validate``: 1 when any
asserted point exceeds the fidelity tolerance; ``bench check``: 1 when
any floor is violated; ``calibrate --check``: 1 when any gated
constant drifted beyond tolerance; ``lint``: 1 on findings outside
the baseline (or stale baseline entries).
"""
from __future__ import annotations

import argparse
import sys
from contextlib import contextmanager
from pathlib import Path
from typing import List, Optional, Tuple

from repro.api import DRIVERS, Scenario, Study, StudyResult

EXIT_OK, EXIT_USAGE, EXIT_INFEASIBLE = 0, 2, 3


# ---------------------------------------------------------------------------
# Validated comma-list parsing (--fabrics/--dies/--m/--cpo/--objectives)
# ---------------------------------------------------------------------------
def _csv(conv, what: str):
    """argparse type: reject empty items and duplicates with one clear
    message instead of a deep traceback out of the engine."""

    def parse(text: str) -> Tuple:
        items = [t.strip() for t in text.split(",")]
        if not text.strip() or any(not t for t in items):
            raise argparse.ArgumentTypeError(
                f"empty entry in {what} list {text!r}")
        try:
            vals = tuple(conv(t) for t in items)
        except ValueError:
            raise argparse.ArgumentTypeError(
                f"{what} list {text!r} has a non-{conv.__name__} "
                f"entry") from None
        if len(set(vals)) != len(vals):
            raise argparse.ArgumentTypeError(
                f"duplicate entries in {what} list {text!r}")
        return vals

    return parse


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.cli",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("scenario", nargs="*",
                    help="scenario JSON file(s); flags override fields")
    ap.add_argument("--model", default=None,
                    help="config name, or 'all' for the whole zoo")
    ap.add_argument("--C", type=float, default=None,
                    help="total cluster compute, TFLOPS")
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--fabrics", type=_csv(str, "--fabrics"), default=None)
    ap.add_argument("--dies", type=_csv(int, "--dies"), default=None)
    ap.add_argument("--m", type=_csv(int, "--m"), default=None)
    ap.add_argument("--cpo", type=_csv(float, "--cpo"), default=None)
    ap.add_argument("--objectives", type=_csv(str, "--objectives"),
                    default=None)
    ap.add_argument("--driver", default=None, choices=DRIVERS.names())
    ap.add_argument("--budget", type=int, default=None,
                    help="per-cell budget for non-exhaustive drivers")
    ap.add_argument("--generations", type=int, default=None)
    ap.add_argument("--backend", default=None,
                choices=("numpy", "jax", "auto"))
    ap.add_argument("--no-reuse", action="store_true")
    ap.add_argument("--refine", action="store_true",
                    help="(legacy) refine the top --top points; "
                         "refinement is otherwise on by default with "
                         "--refine-top winners")
    ap.add_argument("--refine-top", type=int, default=None,
                    help="scalar-oracle refinement of the top N points "
                         "(0 disables)")
    ap.add_argument("--keep-top", type=int, default=None,
                    help="records kept in the artifact (0 = all)")
    ap.add_argument("--validate-top", type=int, default=None,
                    help="event-replay validation of the top N records "
                         "(stamps validated_step_time/fidelity_err)")
    ap.add_argument("--schedule", default=None,
                    choices=("gpipe", "1f1b", "interleaved", "search"),
                    help="pipeline schedule(s) the event engine uses; "
                         "'search' makes the schedule a search "
                         "dimension (event re-rank of the frontier)")
    ap.add_argument("--top", type=int, default=5,
                    help="best points to print")
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--quick", action="store_true",
                    help="smoke mode: first grid cell only, small budgets")
    ap.add_argument("--out", default="artifacts/studies",
                    help="output .json file (single study) or directory")
    ap.add_argument("--trace", default=None, metavar="TRACE_JSON",
                    help="write the host trace (Chrome Trace Event "
                         "JSON, Perfetto-loadable) covering every study")
    return ap


# ---------------------------------------------------------------------------
# Scenario assembly
# ---------------------------------------------------------------------------
_FLAG_FIELDS = {          # argparse dest -> Scenario field
    "model": "model", "C": "total_tflops", "seq_len": "seq_len",
    "global_batch": "global_batch", "fabrics": "fabrics",
    "dies": "dies_per_mcm", "m": "m", "cpo": "cpo_ratio",
    "objectives": "objectives", "driver": "driver", "backend": "backend",
    "refine_top": "refine_top", "keep_top": "keep_top", "seed": "seed",
    "validate_top": "validate_top", "schedule": "schedule",
}


def _overrides(args) -> dict:
    over = {field: getattr(args, dest)
            for dest, field in _FLAG_FIELDS.items()
            if getattr(args, dest) is not None}
    if args.no_reuse:
        over["reuse"] = False
    if args.refine and args.refine_top is None:
        over["refine_top"] = args.top       # legacy: refine top_k=--top
    kw = {}
    if args.budget is not None:
        kw["budget"] = args.budget
    if args.generations is not None:
        kw["generations"] = args.generations
    if kw:
        over["driver_kw"] = kw
    return over


def _quick(sc: Scenario) -> Scenario:
    """Smoke-mode shrink: one MCM grid cell, small budgets."""
    kw = dict(sc.driver_kw)
    for k, cap in (("budget", 32), ("generations", 3), ("pop_size", 16),
                   ("outer_iters", 2), ("inner_budget", 8),
                   ("rounds", 2), ("walkers", 4)):
        if k in kw:
            kw[k] = min(kw[k], cap)
    if sc.driver in ("random", "prf"):
        kw["budget"] = min(kw.get("budget", 32), 32)
    return sc.replace(dies_per_mcm=sc.dies_per_mcm[:1], m=sc.m[:1],
                      cpo_ratio=sc.cpo_ratio[:1], fabrics=sc.fabrics[:1],
                      refine_top=min(sc.refine_top, 3),
                      keep_top=min(sc.keep_top, 32) or 32,
                      validate_top=min(sc.validate_top, 2), driver_kw=kw)


def build_scenarios(args) -> List[Scenario]:
    over = _overrides(args)
    out: List[Scenario] = []
    if args.scenario:
        for path in args.scenario:
            d = Scenario.load(path).to_dict()
            kw = dict(over)
            if "driver_kw" in kw:
                kw["driver_kw"] = {**d.get("driver_kw", {}),
                                   **kw["driver_kw"]}
            d.update(kw)
            out.append(Scenario.from_dict(d))
    else:
        base = dict(over)
        base.setdefault("total_tflops", 4e6)
        models = [base.pop("model", "qwen3_moe_235b_a22b")]
        if models == ["all"]:
            from repro.configs import ARCH_IDS
            models = list(ARCH_IDS)
        out = [Scenario(model=m, **base) for m in models]
    if args.quick:
        out = [_quick(sc) for sc in out]
    return out


# ---------------------------------------------------------------------------
# Reporting
# ---------------------------------------------------------------------------
def _print_study(res: StudyResult, top: int):
    sc = res.scenario
    prov = res.provenance
    n_eval = prov.get("grid_evaluated", prov.get("n_evaluated", 0))
    print(f"\n=== {sc.name}: driver={sc.driver} C={sc.total_tflops:.0e} "
          f"— {n_eval} points evaluated in "
          f"{res.timings.get('total_s', 0.0):.2f}s ===")
    if res.best is None:
        print("  no feasible design point")
        return
    shown = 0
    for r in res.records:
        if not r.feasible or (res.points and r.source == "refined"):
            continue
        m = r.metrics
        print(f"  {m['throughput']:.3e} tok/s  mfu={m['mfu']:.2f}  "
              f"${m['cost'] / 1e6:7.1f}M {m['power'] / 1e6:5.2f}MW  "
              f"{r.fabric:6s} m={r.mcm['m']:<2d} "
              f"r={r.mcm['cpo_ratio']:.1f} {r.strategy}")
        shown += 1
        if shown >= top:
            break
    for r in res.records:
        if r.source == "refined":
            print(f"  refined: {r.throughput:.3e} tok/s  "
                  f"${r.metrics['cost'] / 1e6:.1f}M  "
                  f"(exact topo/OCS cost)")
    print(f"  pareto set ({'/'.join(sc.objectives)}): "
          f"{len(res.pareto)} non-dominated records")
    rr = res.provenance.get("event_rerank")
    if rr:
        wins = ", ".join(f"{k}:{v}" for k, v in
                         sorted(rr["winners"].items()))
        print(f"  event re-rank: {rr['n_reranked']} rows x "
              f"{len(rr['candidates'])} schedule candidates "
              f"(winners {wins})")
    val = res.provenance.get("validate")
    if val:
        err = val.get("max_abs_err")
        fb = val.get("n_scalar_fallback", 0)
        tail = (f", {fb}/{val['n_validated']} scalar-engine fallback"
                if fb else "")
        print(f"  event-validated {val['n_validated']} records "
              f"({val['schedule']}): max |fidelity err| "
              f"{err * 100:.1f}%{tail}" if err is not None else
              f"  event-validated 0 records")


def _out_path(out: str, sc: Scenario, n_studies: int) -> Path:
    p = Path(out)
    if p.suffix == ".json" and n_studies == 1:
        return p
    return p / f"{sc.name}.json"


@contextmanager
def _maybe_tracing(path: Optional[str]):
    """Install a host tracer for the block when ``path`` is given and
    write the Chrome trace on exit."""
    if not path:
        yield None
        return
    from repro.obs import (chrome_trace_from_tracer, tracing,
                           write_chrome_trace)
    with tracing() as tr:
        yield tr
    p = write_chrome_trace(path, chrome_trace_from_tracer(tr))
    print(f"  wrote host trace {p} — open in https://ui.perfetto.dev")


# ---------------------------------------------------------------------------
# `validate` subcommand — the event-driven fidelity harness
# ---------------------------------------------------------------------------
def build_validate_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.cli validate",
        description="Event-driven fidelity harness: replay top design "
                    "points of each scenario with repro.events and "
                    "compare against the analytic model.")
    ap.add_argument("scenario", nargs="*",
                    help="scenario JSON file(s); default: scenarios/*.json")
    ap.add_argument("--top", type=int, default=4,
                    help="points replayed per scenario")
    ap.add_argument("--schedules", type=_csv(str, "--schedules"),
                    default=("gpipe", "1f1b", "interleaved"),
                    help="pipeline schedules to replay")
    ap.add_argument("--tolerance", type=float, default=None,
                    help="asserted |err| bound for gpipe/1f1b rows "
                         "(default 0.15)")
    ap.add_argument("--quick", action="store_true",
                    help="smoke mode: first scenario, top 2, "
                         "gpipe+1f1b only")
    ap.add_argument("--out", default="artifacts/fidelity_report.json",
                    help="fidelity report JSON path")
    ap.add_argument("--trace", default=None, metavar="TRACE_JSON",
                    help="write the harness host trace (Chrome Trace "
                         "Event JSON, Perfetto-loadable)")
    return ap


def main_validate(argv: List[str]) -> int:
    from repro.events.validate import DEFAULT_TOLERANCE, validate_zoo
    ap = build_validate_parser()
    args = ap.parse_args(argv)
    paths = args.scenario or sorted(
        str(p) for p in Path("scenarios").glob("*.json"))
    tol = DEFAULT_TOLERANCE if args.tolerance is None else args.tolerance
    top, schedules = args.top, tuple(args.schedules)
    if args.quick:
        paths = paths[:1]
        top = min(top, 2)
        schedules = tuple(s for s in schedules
                          if s in ("gpipe", "1f1b")) or ("gpipe",)
    try:
        with _maybe_tracing(args.trace):
            report = validate_zoo(paths, top=top, schedules=schedules,
                                  tolerance=tol, out=args.out)
    except (ValueError, KeyError, OSError) as e:
        ap.exit(EXIT_USAGE, f"{ap.prog}: error: {e}\n")
    print(f"\n=== fidelity report: {report['n_scenarios']} scenarios, "
          f"{report['n_rows']} replays, tolerance ±{tol:.0%} ===")
    for block in report["scenarios"]:
        by_sched: dict = {}
        for r in block["rows"]:
            by_sched.setdefault(r["schedule"], []).append(r)
        parts = []
        for sched, rows in sorted(by_sched.items()):
            worst = max(abs(r["err"]) for r in rows)
            parts.append(f"{sched}: max|err| {worst * 100:4.1f}%")
        print(f"  {block['scenario']:24s} "
              f"({block['n_points']} pts)  " + "   ".join(parts))
    br = report.get("batch_replay", {})
    if br.get("records"):
        print(f"  batch replay: {br['scalar_fallback']}/{br['records']} "
              f"records fell back to the scalar engine "
              f"({br['fallback_frac']:.0%})")
    else:
        print("  batch replay: not exercised (scalar-engine harness)")
    print(f"  wrote {args.out}")
    if report["n_violations"]:
        print(f"FAIL: {report['n_violations']} asserted replays exceed "
              f"±{tol:.0%}")
        return 1
    print(f"OK: all {report['n_asserted']} asserted replays within "
          f"±{tol:.0%} of the analytic model")
    return EXIT_OK


# ---------------------------------------------------------------------------
# `timeline` subcommand — the simulated-step Perfetto trace
# ---------------------------------------------------------------------------
def build_timeline_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.cli timeline",
        description="Replay a scenario's best design point through the "
                    "event engine with full timeline recording and "
                    "write the simulated training step as Chrome Trace "
                    "Event JSON (one track per pipeline stage / rail; "
                    "open in https://ui.perfetto.dev — the bubble is "
                    "the white space).")
    ap.add_argument("scenario", help="scenario JSON file")
    ap.add_argument("--schedule", default="1f1b",
                    choices=("gpipe", "1f1b", "interleaved"),
                    help="pipeline schedule to replay")
    ap.add_argument("--top", type=int, default=8,
                    help="top records considered when picking the "
                         "(preferably pipelined) point to replay")
    ap.add_argument("--out", default=None,
                    help="trace JSON path (default: artifacts/"
                         "timeline_<scenario>_<schedule>.json)")
    return ap


def main_timeline(argv: List[str]) -> int:
    from repro.events import replay
    from repro.obs import (chrome_trace_from_event_result, track_idle,
                           write_chrome_trace)
    from repro.obs.bench import pipelined_programs
    ap = build_timeline_parser()
    args = ap.parse_args(argv)
    try:
        sc = Scenario.load(args.scenario)
        prog, _ = pipelined_programs(sc, schedule=args.schedule,
                                     top=args.top)
    except (ValueError, KeyError, OSError) as e:
        ap.exit(EXIT_USAGE, f"{ap.prog}: error: {e}\n")
    ev = replay(prog, record_timeline=True)
    trace = chrome_trace_from_event_result(ev, title=sc.name)
    out = args.out or (f"artifacts/timeline_{sc.name}_"
                       f"{args.schedule}.json")
    path = write_chrome_trace(out, trace)
    idle = track_idle(trace)
    total_idle = sum(v["idle_us"] for v in idle.values())
    total_busy = sum(v["busy_us"] for v in idle.values())
    print(f"=== {sc.name}: schedule={ev.schedule} pp={ev.n_stages} "
          f"n_micro={ev.n_micro} ===")
    print(f"  step {ev.step_time * 1e3:.3f} ms  bubble {ev.bubble:.3f}  "
          f"reconf {ev.n_reconf} (wait {ev.reconf_wait_s * 1e3:.3f} ms)")
    print(f"  device tracks: {len(idle)}  busy {total_busy / 1e3:.3f} ms"
          f"  idle {total_idle / 1e3:.3f} ms "
          f"({total_idle / max(total_idle + total_busy, 1e-12):.0%})")
    print(f"  wrote {path} — open in https://ui.perfetto.dev")
    return EXIT_OK


# ---------------------------------------------------------------------------
# `bench check` subcommand — the unified BENCH_*.json floor gate
# ---------------------------------------------------------------------------
def build_bench_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.cli bench",
        description="Re-measure the quick benchmark workloads and gate "
                    "them on the committed BENCH_*.json floors "
                    "(repro.obs.bench) — the single CI perf gate.")
    ap.add_argument("action", choices=("check",),
                    help="'check': measure and compare against floors")
    ap.add_argument("--which", type=_csv(str, "--which"),
                    default=("study", "outer", "events"),
                    help="comma list of benches (study,outer,events)")
    ap.add_argument("--quick", action="store_true",
                    help="quick floors (the only supported mode; "
                         "accepted for CI-invocation clarity)")
    ap.add_argument("--trace", default=None, metavar="TRACE_JSON",
                    help="write the quick study's host trace JSON")
    return ap


def main_bench(argv: List[str]) -> int:
    from repro.obs.bench import run_checks
    ap = build_bench_parser()
    args = ap.parse_args(argv)
    try:
        return run_checks(tuple(args.which), trace_path=args.trace)
    except (ValueError, KeyError, OSError) as e:
        ap.exit(EXIT_USAGE, f"{ap.prog}: error: {e}\n")


# ---------------------------------------------------------------------------
# `calibrate` subcommand — measured kernel constants + the drift gate
# ---------------------------------------------------------------------------
def build_calibrate_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.cli calibrate",
        description="Execution-grounded calibration (repro.obs.profile "
                    "+ repro.calib): run the repo's real kernels over "
                    "an (M, N) grid, fit the analytic cost constants "
                    "(effective peak FLOP/s, HBM bytes/s, and the "
                    "M/(M+half) efficiency curves), and write the "
                    "schema-versioned CALIB.json artifact.  --check "
                    "re-measures and gates per-kernel drift against "
                    "the committed artifact instead (exit 1 on "
                    "breach).")
    ap.add_argument("--out", default="CALIB.json",
                    help="calibration artifact path (also the "
                         "committed artifact --check compares against)")
    ap.add_argument("--kernels", type=_csv(str, "--kernels"),
                    default=None,
                    help="comma list of kernels (default: all; see "
                         "repro.obs.profile.PROFILE_KERNELS)")
    ap.add_argument("--quick", action="store_true",
                    help="CI grid: drop the most expensive point per "
                         "kernel, 2 reps")
    ap.add_argument("--check", action="store_true",
                    help="drift mode: re-measure and compare against "
                         "--out instead of rewriting it")
    ap.add_argument("--fidelity", default="FIDELITY.json",
                    help="fidelity report to stamp with the execution-"
                         "grounded block on write ('' disables; "
                         "missing file = skipped)")
    ap.add_argument("--trace", default=None, metavar="TRACE_JSON",
                    help="write the profile host trace (spans + "
                         "achieved-rate counter tracks, Perfetto-"
                         "loadable)")
    return ap


def main_calibrate(argv: List[str]) -> int:
    from repro.calib import (check_drift, fit_calibration,
                             load_calibration, stamp_fidelity,
                             write_calibration)
    from repro.obs.profile import profile_kernels
    ap = build_calibrate_parser()
    args = ap.parse_args(argv)
    try:
        committed = load_calibration(args.out) if args.check else None
        with _maybe_tracing(args.trace):
            measurements = profile_kernels(args.kernels,
                                           quick=args.quick)
        calib = fit_calibration(measurements, quick=args.quick)
    except (ValueError, KeyError, OSError) as e:
        ap.exit(EXIT_USAGE, f"{ap.prog}: error: {e}\n")
    eff = calib["effective"]
    print(f"\n=== calibrate: {len(measurements)} measurements, "
          f"{len(calib['kernels'])} kernels "
          f"({calib['provenance']['backend']}/"
          f"{calib['provenance']['device']}) ===")
    for name, f in sorted(calib["kernels"].items()):
        unit = "FLOP/s" if f["kind"] == "compute" else "B/s"
        tail = (f"  n_half={f['n_half']:7.1f}" if "n_half" in f else "")
        print(f"  {name:22s} {f['kind']:7s} peak {f['peak']:.3e} {unit}"
              f"  m_half={f['m_half']:7.1f}  "
              f"resid {f['rel_rmse'] * 100:4.1f}%{tail}")
    if "die_tflops" in eff:
        print(f"  effective: die_tflops={eff['die_tflops']:.4f} "
              f"gemm_m_half={eff.get('gemm_m_half', 0.0):.1f} "
              f"gemm_n_half={eff.get('gemm_n_half', 0.0):.1f}")
    if "hbm_bw_per_die" in eff:
        print(f"  effective: hbm_bw_per_die="
              f"{eff['hbm_bw_per_die']:.3e} B/s")

    if args.check:
        print(f"\ndrift vs {args.out}:")
        rows = check_drift(calib, committed)
        n_fail = sum(not r["ok"] for r in rows)
        n_gated = sum(r["asserted"] for r in rows)
        if n_fail:
            print(f"FAIL: {n_fail}/{n_gated} gated constants drifted "
                  f"beyond tolerance")
            return 1
        print(f"OK: all {n_gated} gated constants within tolerance")
        return EXIT_OK

    path = write_calibration(calib, args.out)
    print(f"  wrote {path}")
    if args.fidelity:
        stamped = stamp_fidelity(calib, args.fidelity)
        if stamped:
            print(f"  stamped execution block -> {stamped}")
    return EXIT_OK


# ---------------------------------------------------------------------------
# `lint` subcommand — chiplint, the AST invariant analyzer
# ---------------------------------------------------------------------------
def build_lint_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.cli lint",
        description="chiplint: AST-based invariant analysis "
                    "(repro.analysis) — parity drift between the "
                    "scalar/batched/event-DAG engines, jax trace "
                    "hygiene, physical-unit mismatches, determinism "
                    "and metric-schema violations.  Exit 1 on findings "
                    "not covered by the baseline, or on stale baseline "
                    "entries.")
    ap.add_argument("--root", default=".",
                    help="repository root to analyze (default: cwd)")
    ap.add_argument("--baseline", default=None,
                    help="grandfathered-findings file (default: "
                         "<root>/chiplint_baseline.json; absent file "
                         "= empty baseline)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline to the current findings "
                         "and exit 0")
    ap.add_argument("--json", default=None, metavar="REPORT_JSON",
                    help="also write the machine-readable findings "
                         "report")
    return ap


def main_lint(argv: List[str]) -> int:
    import json as _json

    from repro.analysis import (DEFAULT_CONFIG, diff_baseline,
                                load_baseline, save_baseline)
    from repro.analysis.findings import DEFAULT_BASELINE, report_dict
    from repro.analysis.runner import run_lint

    ap = build_lint_parser()
    args = ap.parse_args(argv)
    root = Path(args.root)
    if not root.is_dir():
        ap.exit(EXIT_USAGE, f"{ap.prog}: error: no such directory: "
                            f"{root}\n")
    baseline_path = Path(args.baseline) if args.baseline \
        else root / DEFAULT_BASELINE

    report = run_lint(root, DEFAULT_CONFIG)
    if args.update_baseline:
        p = save_baseline(baseline_path, report.findings)
        print(f"chiplint: baselined {len(report.findings)} finding(s) "
              f"-> {p}")
        return EXIT_OK

    try:
        baseline = load_baseline(baseline_path)
    except (ValueError, OSError) as e:
        ap.exit(EXIT_USAGE, f"{ap.prog}: error: {e}\n")
    new, stale = diff_baseline(report.findings, baseline)

    if args.json:
        out = Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(_json.dumps(
            report_dict(report.findings, new, stale,
                        report.n_suppressed, report.n_files),
            indent=1) + "\n")
        print(f"  wrote {out}")

    for f in new:
        print(f.render())
    for fp in stale:
        print(f"stale baseline entry (fix shipped? run "
              f"--update-baseline): {fp}")
    n_base = len(report.findings) - len(new)
    print(f"chiplint: {report.n_files} files, "
          f"{len(report.findings)} finding(s) "
          f"({n_base} baselined, {len(new)} new, "
          f"{report.n_suppressed} suppressed, "
          f"{len(stale)} stale baseline)")
    return EXIT_OK if not new and not stale else 1


def main(argv: Optional[List[str]] = None) -> int:
    argv = sys.argv[1:] if argv is None else list(argv)
    if argv and argv[0] == "validate":
        return main_validate(argv[1:])
    if argv and argv[0] == "timeline":
        return main_timeline(argv[1:])
    if argv and argv[0] == "bench":
        return main_bench(argv[1:])
    if argv and argv[0] == "calibrate":
        return main_calibrate(argv[1:])
    if argv and argv[0] == "lint":
        return main_lint(argv[1:])
    ap = build_parser()
    args = ap.parse_args(argv)
    try:
        scenarios = build_scenarios(args)
    except (ValueError, KeyError, OSError) as e:
        ap.exit(EXIT_USAGE, f"{ap.prog}: error: {e}\n")

    all_feasible = True
    with _maybe_tracing(args.trace):
        for sc in scenarios:
            try:
                res = Study(sc).run()
            except ValueError as e:      # driver_kw / grid-shape misuse
                ap.exit(EXIT_USAGE, f"{ap.prog}: error: {e}\n")
            _print_study(res, args.top)
            path = res.save(_out_path(args.out, sc, len(scenarios)))
            print(f"  wrote {path}")
            if res.best is None:
                all_feasible = False
    return EXIT_OK if all_feasible else EXIT_INFEASIBLE


if __name__ == "__main__":
    sys.exit(main())
