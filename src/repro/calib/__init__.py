"""``repro.calib`` — fit analytic cost constants from kernel profiles.

The execution-grounded half of the cost model (ROADMAP item 3b):
``repro.obs.profile`` measures the repo's real kernels over an (M, N)
grid; this package fits the analytic constants the simulator runs on —
per kernel a ``y = peak * x / (x + half)`` saturation curve (the exact
family ``core/simulator._gemm_eff`` models with ``gemm_m_half`` /
``gemm_n_half``), plus the effective peak FLOP/s and HBM bytes/s the
curves saturate to — and writes the schema-versioned ``CALIB.json``
artifact with full provenance (jax version, backend, device, commit,
and the raw measurement rows the fits came from).

Consumers:

* ``HW.calibrated(calib)`` — an ``HW`` running on the measured
  ``effective`` block (``die_tflops`` = fitted peak / 1e12 with
  ``mfu_ceiling=1.0`` — the fitted peak is already the ACHIEVED
  asymptote — and ``model_gemm_eff=True`` with the fitted halves);
* ``Scenario.calibration`` — a path to the artifact; ``build_hw()``
  starts from ``HW.calibrated`` and ``Study.run`` stamps the constants
  into ``StudyResult.provenance["calibration"]``;
* ``python -m repro.cli calibrate`` — measure + fit + write, and the
  ``--check`` drift gate comparing a fresh measurement against the
  committed artifact (CI);
* ``events.validate.validate_zoo`` — the ``execution`` block of the
  fidelity report, anchoring model-vs-model agreement to a measured
  artifact.

Drift gating: fitted PEAKS are asserted within ``2**log2_peak`` of the
committed artifact (default 8x — wide enough for a different CI host,
narrow enough to catch a 100-1000x regression like an interpret-mode
fallback or a per-row python loop).  The ``half`` shape constants are
reported but NOT gated — they are poorly conditioned on the quick grid
(same discipline as the fidelity harness's non-asserted ``interleaved``
rows).
"""
from __future__ import annotations

import functools
import json
import math
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

CALIB_SCHEMA = 1
DEFAULT_CALIB_PATH = "CALIB.json"

# |log2(current/committed)| tolerances for `calibrate --check`;
# overridable per-artifact via a committed "check_tolerances" block
DEFAULT_TOLERANCES = {"log2_peak": 3.0, "log2_half": 2.0}


# ---------------------------------------------------------------------------
# Curve fitting
# ---------------------------------------------------------------------------
def fit_saturation(xs: Sequence[float], ys: Sequence[float]
                   ) -> Tuple[float, float, float]:
    """Least-squares fit of ``y = peak * x / (x + half)``.

    Grid-searches ``half`` over a log-spaced range spanning the data
    (the model is linear in ``peak`` given ``half``, so ``peak`` is
    closed-form per candidate).  Returns ``(peak, half, rel_rmse)``
    where ``rel_rmse`` is the RMS residual relative to the mean level.
    Deterministic; pure python/numpy.
    """
    import numpy as np
    x = np.asarray(xs, np.float64)
    y = np.asarray(ys, np.float64)
    if x.size < 2:
        raise ValueError(f"fit_saturation needs >= 2 points, got {x.size}")
    if np.any(x <= 0) or np.any(y <= 0):
        raise ValueError("fit_saturation needs positive x and y")
    halves = np.geomspace(float(x.min()) / 16.0, float(x.max()) * 16.0, 257)
    best = None
    for h in halves:
        f = x / (x + h)
        p = float((f * y).sum() / (f * f).sum())
        sse = float(((y - p * f) ** 2).sum())
        if best is None or sse < best[0]:
            best = (sse, p, float(h))
    sse, peak, half = best
    rel_rmse = math.sqrt(sse / x.size) / float(y.mean())
    return peak, half, rel_rmse


def _fit_kernel(name: str, rows: List[dict]) -> dict:
    """Fit one kernel's measurement rows (compute kernels fit achieved
    FLOP/s, memory kernels bytes/s) on the M axis, plus the N axis when
    swept (moe_gmm)."""
    kind = rows[0]["kind"]
    rate = "flops_per_s" if kind == "compute" else "bytes_per_s"
    m_rows = [r for r in rows if r["axis"] == "m"]
    peak, half, resid = fit_saturation([r["x"] for r in m_rows],
                                       [r[rate] for r in m_rows])
    out = {"kind": kind, "n_points": len(rows),
           "peak": peak, "m_half": half, "rel_rmse": resid,
           "best_measured": max(r[rate] for r in rows)}
    n_rows = [r for r in rows if r["axis"] == "n"]
    if len(n_rows) >= 2:
        _, n_half, n_resid = fit_saturation([r["x"] for r in n_rows],
                                            [r[rate] for r in n_rows])
        out["n_half"] = n_half
        out["n_rel_rmse"] = n_resid
    return out


def _geomean(vals: Sequence[float]) -> float:
    return math.exp(sum(math.log(v) for v in vals) / len(vals))


def _effective(kernels: Dict[str, dict]) -> dict:
    """The ``HW``-field overrides the fits imply.

    ``die_tflops`` is the best compute asymptote and ``hbm_bw_per_die``
    the best memory asymptote; both are ACHIEVED peaks, so
    ``mfu_ceiling`` goes to 1.0 and the shape curve carries the rest
    (``model_gemm_eff=True``).  ``gemm_m_half``/``gemm_n_half`` come
    from the grouped-matmul fit — the direct analog of the simulator's
    GEMM shape curve — falling back to the geometric mean of the
    compute kernels' halves.
    """
    comp = {k: v for k, v in kernels.items() if v["kind"] == "compute"}
    mem = {k: v for k, v in kernels.items() if v["kind"] == "memory"}
    eff: dict = {}
    if comp:
        eff["die_tflops"] = max(v["peak"] for v in comp.values()) / 1e12
        eff["mfu_ceiling"] = 1.0
        eff["model_gemm_eff"] = True
        gmm = kernels.get("moe_gmm")
        eff["gemm_m_half"] = (gmm or {}).get("m_half") or _geomean(
            [v["m_half"] for v in comp.values()])
        eff["gemm_n_half"] = (gmm or {}).get("n_half", 128.0)
    if mem:
        eff["hbm_bw_per_die"] = max(v["peak"] for v in mem.values())
    return eff


# ---------------------------------------------------------------------------
# Artifact build / io
# ---------------------------------------------------------------------------
def _provenance(measurements: List[dict], quick: bool) -> dict:
    import platform
    import subprocess
    import jax
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        commit = "unknown"
    dev = jax.devices()[0]
    return {
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device": getattr(dev, "device_kind", str(dev)),
        "python": platform.python_version(),
        "machine": platform.machine(),
        "commit": commit,
        "created": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "quick": bool(quick),
        "n_measurements": len(measurements),
        "wall_s": sum(r["time_s"] * r["reps"] for r in measurements),
    }


def fit_calibration(measurements: List[dict], *,
                    quick: bool = False) -> dict:
    """Fit per-kernel curves + the effective constants from
    ``profile_kernels`` output; returns the full CALIB artifact dict
    (measurement rows embedded as the fit's provenance trail)."""
    if not measurements:
        raise ValueError("no measurements to fit")
    by_kernel: Dict[str, List[dict]] = {}
    for r in measurements:
        by_kernel.setdefault(r["kernel"], []).append(r)
    kernels = {name: _fit_kernel(name, rows)
               for name, rows in by_kernel.items()}
    return {
        "schema": CALIB_SCHEMA,
        "provenance": _provenance(measurements, quick),
        "check_tolerances": dict(DEFAULT_TOLERANCES),
        "kernels": kernels,
        "effective": _effective(kernels),
        "measurements": measurements,
    }


def write_calibration(calib: dict, path) -> Path:
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(calib, indent=1, sort_keys=True) + "\n")
    load_calibration.cache_clear()
    return p


def _validate_calib(calib: dict, origin: str) -> dict:
    schema = calib.get("schema")
    if schema != CALIB_SCHEMA:
        raise ValueError(f"{origin}: unsupported calibration schema "
                         f"{schema!r} (this build reads {CALIB_SCHEMA})")
    for key in ("kernels", "effective", "provenance"):
        if not isinstance(calib.get(key), dict):
            raise ValueError(f"{origin}: calibration artifact has no "
                             f"{key!r} block")
    return calib


@functools.lru_cache(maxsize=16)
def load_calibration(path) -> dict:
    """Read + schema-validate a CALIB.json artifact (small, cached)."""
    p = Path(path)
    if not p.exists():
        raise ValueError(f"no calibration artifact at {p} — run "
                         f"`python -m repro.cli calibrate` first")
    try:
        calib = json.loads(p.read_text())
    except json.JSONDecodeError as e:
        raise ValueError(f"{p}: not valid JSON: {e}") from None
    return _validate_calib(calib, str(p))


# ---------------------------------------------------------------------------
# Drift gate (`cli calibrate --check`)
# ---------------------------------------------------------------------------
def _drift_row(name: str, cur: Optional[float], ref: Optional[float],
               tol_log2: float, asserted: bool) -> dict:
    if not cur or not ref or cur <= 0 or ref <= 0:
        drift, ok = float("inf"), False
    else:
        drift = abs(math.log2(cur / ref))
        ok = drift <= tol_log2
    if not asserted:
        ok = True
    return {"metric": name, "current": cur, "committed": ref,
            "drift_log2": drift, "tol_log2": tol_log2,
            "asserted": asserted, "ok": ok}


def check_drift(current: dict, committed: dict) -> List[dict]:
    """Per-kernel relative drift of ``current`` fits vs the committed
    artifact; prints one uniform OK/FAIL/info line per constant
    (``obs.bench.enforce`` style) and returns the row dicts.  Asserted:
    per-kernel peaks + the effective peaks.  Reported only: the
    ``half`` shape constants (see module docstring)."""
    tol = dict(DEFAULT_TOLERANCES)
    tol.update(committed.get("check_tolerances", {}))
    rows: List[dict] = []
    # kernels absent from the CURRENT run (a --kernels subset check)
    # are simply not compared; a kernel the committed artifact lacks
    # still FAILs via the missing-ref path below.
    names = sorted(current["kernels"])
    for name in names:
        cur = current["kernels"][name]
        ref = committed["kernels"].get(name, {})
        rows.append(_drift_row(f"{name}.peak", cur.get("peak"),
                               ref.get("peak"), tol["log2_peak"], True))
        rows.append(_drift_row(f"{name}.m_half", cur.get("m_half"),
                               ref.get("m_half"), tol["log2_half"], False))
        if "n_half" in ref or "n_half" in cur:
            rows.append(_drift_row(
                f"{name}.n_half", cur.get("n_half"), ref.get("n_half"),
                tol["log2_half"], False))
    for f in ("die_tflops", "hbm_bw_per_die"):
        if f not in current["effective"]:
            continue            # subset run measured no such kernels
        rows.append(_drift_row(
            f"effective.{f}", current["effective"][f],
            committed["effective"].get(f), tol["log2_peak"], True))
    for r in rows:
        if not r["asserted"]:
            mark = "info"
        else:
            mark = "OK  " if r["ok"] else "FAIL"
        cur, ref = r["current"], r["committed"]
        if cur and ref and math.isfinite(r["drift_log2"]):
            detail = (f"{cur:.3e} vs {ref:.3e} "
                      f"(drift {2 ** r['drift_log2']:.2f}x"
                      f"{'' if r['asserted'] else ', not gated'}"
                      f" <= {2 ** r['tol_log2']:.0f}x)")
        else:
            detail = f"{cur!r} vs {ref!r} (missing)"
        print(f"  {mark} calibrate.{r['metric']}: {detail}")
    return rows


# ---------------------------------------------------------------------------
# Stack integration blocks
# ---------------------------------------------------------------------------
def calibration_block(path) -> dict:
    """The ``StudyResult.provenance['calibration']`` block for a run
    with ``Scenario.calibration`` set: the effective constants the
    study executed on plus the artifact's measurement provenance."""
    calib = load_calibration(path)
    prov = calib["provenance"]
    return {"schema": calib["schema"], "path": str(path),
            "effective": dict(calib["effective"]),
            "measured_on": {k: prov.get(k) for k in
                            ("jax", "backend", "device", "commit",
                             "created")}}


def execution_block(calib: dict, source: str = DEFAULT_CALIB_PATH) -> dict:
    """The execution-grounded block of the fidelity report: the
    measured anchor behind the analytic-vs-event agreement."""
    prov = calib["provenance"]
    return {
        "source": str(source),
        "calib_schema": calib["schema"],
        "measured_on": {k: prov.get(k) for k in
                        ("jax", "backend", "device", "commit",
                         "created")},
        "effective": dict(calib["effective"]),
        "kernels": {name: {"kind": f["kind"], "peak": f["peak"],
                           "m_half": f["m_half"],
                           "rel_rmse": f["rel_rmse"]}
                    for name, f in sorted(calib["kernels"].items())},
    }


def stamp_fidelity(calib: dict, fidelity_path) -> Optional[Path]:
    """Rewrite the committed fidelity report with this calibration's
    ``execution`` block (no-op returning None when the report is
    absent)."""
    p = Path(fidelity_path)
    if not p.exists():
        return None
    report = json.loads(p.read_text())
    report["execution"] = execution_block(calib, source=DEFAULT_CALIB_PATH)
    p.write_text(json.dumps(report, indent=2) + "\n")
    return p
