from repro.runtime.fault_tolerance import (FaultTolerantLoop,  # noqa: F401
                                           Watchdog)
