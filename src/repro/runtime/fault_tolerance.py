"""Fault-tolerant training runtime.

Mechanisms (designed for 1000+ node clusters, exercised single-host here):

  * checkpoint/restart — CheckpointManager (async, atomic, resharding);
    restart resumes bit-exactly because the data pipeline is stateless
    (batch = f(seed, step)).
  * preemption handling — SIGTERM/SIGINT flips a flag; the loop finishes
    the current step, writes a final checkpoint, exits cleanly (the
    standard TPU-pod maintenance-event protocol).
  * watchdog — a step deadline detects hung collectives (dead host /
    stuck NCCL-analogue); on a real pod the runner would kill + restart
    the job from the last checkpoint, here it raises.
  * straggler mitigation — per-step wall-times feed an EWMA; steps slower
    than ``straggler_factor`` x EWMA are logged with their step id so an
    orchestrator can quarantine the offending host; the synchronous-SGD
    semantics are unchanged (deterministic replay makes the quarantine
    cheap).
  * elastic rescale — restore() accepts a different mesh: shardings come
    from the CURRENT mesh, leaves are resharded on load.
"""
from __future__ import annotations

import signal
import time
from typing import Callable, Optional

import jax


class Watchdog:
    def __init__(self, deadline_s: float = 1800.0):
        self.deadline_s = deadline_s
        self._last = time.monotonic()

    def pet(self):
        self._last = time.monotonic()

    def check(self):
        if time.monotonic() - self._last > self.deadline_s:
            raise TimeoutError(
                f"step exceeded {self.deadline_s}s — hung collective or "
                f"dead host; restart from last checkpoint")


class FaultTolerantLoop:
    def __init__(self, train_step: Callable, ckpt_mgr, pipeline,
                 checkpoint_every: int = 50, watchdog_s: float = 1800.0,
                 straggler_factor: float = 3.0):
        self.train_step = train_step
        self.ckpt = ckpt_mgr
        self.pipeline = pipeline
        self.checkpoint_every = checkpoint_every
        self.watchdog = Watchdog(watchdog_s)
        self.straggler_factor = straggler_factor
        self.preempted = False
        self.step_times = []
        self.straggler_steps = []
        self._ewma: Optional[float] = None
        self._orig_handlers = {}

    # ------------------------------------------------------------------
    def _install_signals(self):
        def handler(signum, frame):
            self.preempted = True
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                self._orig_handlers[sig] = signal.signal(sig, handler)
            except ValueError:
                pass  # non-main thread (tests)

    def _restore_signals(self):
        for sig, h in self._orig_handlers.items():
            signal.signal(sig, h)

    # ------------------------------------------------------------------
    def resume_or_init(self, state, shardings=None):
        """Restore the latest committed checkpoint if one exists."""
        latest = self.ckpt.latest_step()
        if latest is None:
            return state, 0
        restored, extra = self.ckpt.restore(latest, state, shardings)
        seed = pstep = None
        for k, v in extra.items():        # pipeline state rides in extra
            if k.endswith("['seed']"):
                seed = int(v)
            elif k.endswith("['step']"):
                pstep = int(v)
        if seed is not None and pstep is not None:
            self.pipeline.restore({"seed": seed, "step": pstep})
        else:
            self.pipeline.restore({"seed": self.pipeline.state.seed,
                                   "step": latest})
        return restored, latest

    def run(self, state, n_steps: int, start_step: int = 0,
            on_metrics: Optional[Callable] = None):
        """Run up to ``n_steps`` total steps; returns (state, last_step)."""
        self._install_signals()
        try:
            step = start_step
            while step < n_steps and not self.preempted:
                t0 = time.time()
                batch = self.pipeline.batch_at(step)
                state, metrics = self.train_step(state, batch)
                jax.block_until_ready(
                    jax.tree.leaves(metrics)[0])
                dt = time.time() - t0
                self.watchdog.check()
                self.watchdog.pet()
                self.step_times.append(dt)
                if self._ewma is None:
                    self._ewma = dt
                elif dt > self.straggler_factor * self._ewma:
                    self.straggler_steps.append((step, dt, self._ewma))
                else:
                    self._ewma = 0.9 * self._ewma + 0.1 * dt
                step += 1
                self.pipeline.state = self.pipeline.state.advance()
                if on_metrics is not None:
                    on_metrics(step, metrics, dt)
                if step % self.checkpoint_every == 0:
                    self.ckpt.save(step, state,
                                   extra=self.pipeline.checkpoint())
            if self.preempted:
                # graceful preemption: final synchronous checkpoint
                self.ckpt.async_write = False
                self.ckpt.save(step, state,
                               extra=self.pipeline.checkpoint())
            self.ckpt.wait()
            return state, step
        finally:
            self._restore_signals()
