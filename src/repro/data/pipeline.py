"""Deterministic, resumable synthetic-token data pipeline.

Stateless batch generation — batch(step) is a pure function of
(seed, step), so:
  * restart-after-crash resumes bit-exactly from the checkpointed step,
  * elastic rescale (different DP width) replays the same global batches,
  * straggler mitigation by step-skipping needs no coordination.

A real corpus loader would slot in behind the same interface (the
determinism contract is the point — see runtime/fault_tolerance.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class PipelineState:
    seed: int
    step: int

    def advance(self, n: int = 1) -> "PipelineState":
        return PipelineState(self.seed, self.step + n)


class DataPipeline:
    """Synthetic LM batches with zipf-ish token statistics."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, seed: int = 0,
                 ex=None):
        self.cfg = cfg
        self.shape = shape
        self.state = PipelineState(seed=seed, step=0)
        self.ex = ex

    # ------------------------------------------------------------------
    def batch_at(self, step: int) -> dict:
        """Pure function of (seed, step) -> batch dict."""
        cfg, shape = self.cfg, self.shape
        key = jax.random.fold_in(jax.random.PRNGKey(self.state.seed), step)
        ks = jax.random.split(key, 4)
        b, s = shape.global_batch, shape.seq_len
        # zipf-like marginal over the vocab via squared uniform
        u = jax.random.uniform(ks[0], (b, s + 1))
        tokens_full = (u * u * (cfg.vocab - 1)).astype(jnp.int32)
        batch = {"tokens": tokens_full[:, :s],
                 "labels": tokens_full[:, 1:]}
        if cfg.family == "vlm":
            batch["prefix_embeds"] = 0.02 * jax.random.normal(
                ks[1], (b, cfg.n_prefix_tokens, cfg.d_model))
            mask = np.ones((b, s), np.float32)
            mask[:, :cfg.n_prefix_tokens] = 0.0
            batch["loss_mask"] = jnp.asarray(mask)
        if cfg.family == "encdec":
            batch["encoder_embeds"] = 0.1 * jax.random.normal(
                ks[2], (b, cfg.encoder_len, cfg.d_model))
        if self.ex is not None:
            batch = jax.tree.map(
                lambda x: x.astype(self.ex.compute_dtype)
                if jnp.issubdtype(x.dtype, jnp.floating) else x, batch)
        return batch

    def __next__(self):
        batch = self.batch_at(self.state.step)
        self.state = self.state.advance()
        return batch

    def __iter__(self):
        return self

    # ------------------------------------------------------------------
    def checkpoint(self) -> dict:
        return {"seed": self.state.seed, "step": self.state.step}

    def restore(self, ckpt: dict) -> None:
        self.state = PipelineState(seed=int(ckpt["seed"]),
                                   step=int(ckpt["step"]))
