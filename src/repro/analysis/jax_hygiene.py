"""jax-hygiene — static checks on the jit/vmap-traced call graph.

The jax backend's no-retrace guarantee (``dse/batched_sim.py`` shape
buckets, ``_JAX_TRACES``-tested at runtime) only holds if the traced
functions stay trace-friendly.  From each registered entry point this
rule walks the intra-repo call graph and flags, in every reachable
function:

* ``branch-on-tracer``  — ``if``/``while``/``assert`` whose test reads a
  tracer-derived value (entry parameters minus the declared static ones,
  plus anything assigned from them);
* ``tracer-escape``     — ``float()``/``int()``/``bool()`` over a
  tracer-derived argument, or ``.item()``/``.tolist()`` on one — these
  force concretization and fail (or silently constant-fold) under jit;
* ``np-in-jit``         — calls through a NumPy module alias where the
  backend-generic ``xp``/``jnp`` namespace is required — numpy ops
  inside a traced function constant-fold at trace time;
* ``unhashable-default`` — mutable default arguments (list/dict/set
  displays or constructor calls) on reachable functions: they defeat
  the ``lru_cache``/static-argnum hashing the jit cache keys on.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.astutil import (Module, ModuleCache, attr_chain,
                                    names_in, walk_functions)
from repro.analysis.findings import Finding

RULE = "jax-hygiene"

_CONCRETIZERS = ("float", "int", "bool")
_ESCAPE_METHODS = ("item", "tolist")
_NUMPY_MODULES = ("numpy", "np")


@dataclass(frozen=True)
class JaxEntry:
    """A function traced by jit/vmap, with its trace-static parameters
    (closure-like arguments that are python values, not tracers)."""

    path: str
    qualname: str
    static_params: Tuple[str, ...] = ()


DEFAULT_JAX_ENTRIES: Tuple[JaxEntry, ...] = (
    # the backend-generic term core, vmapped per point under jit
    JaxEntry(path="src/repro/dse/batched_sim.py", qualname="_terms_core",
             static_params=("xp", "fabric", "hw")),
    # the per-bucket traced wrapper (its side effects run at trace time)
    JaxEntry(path="src/repro/dse/batched_sim.py",
             qualname="_jax_terms_fn.point_fn"),
    # the event-replay wavefront: the level recurrence is unrolled at
    # trace time from the shape tables, so only `rows` is a tracer
    JaxEntry(path="src/repro/events/batch.py",
             qualname="_jax_shape_fn.batch_fn"),
)


def _tainted_names(fn: ast.FunctionDef, static: Tuple[str, ...]
                   ) -> Set[str]:
    """Entry parameters minus the static ones, plus one forward pass of
    assignment propagation (the traced functions are straight-line)."""
    args = fn.args
    params = [a.arg for a in (args.posonlyargs + args.args
                              + args.kwonlyargs)]
    if args.vararg:
        params.append(args.vararg.arg)
    if args.kwarg:
        params.append(args.kwarg.arg)
    tainted = {p for p in params if p not in static}
    for node in walk_functions(fn):
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            value = node.value
            if value is None:
                continue
            if not (set(names_in(value)) & tainted):
                continue
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name):
                        tainted.add(n.id)
    return tainted


def _check_function(mod: Module, qual: str, fn: ast.FunctionDef,
                    static: Tuple[str, ...], is_entry: bool,
                    out: List[Finding]) -> None:
    tainted = _tainted_names(fn, static)

    # unhashable defaults (checked on the def itself)
    for d in fn.args.defaults + [d for d in fn.args.kw_defaults if d]:
        mutable = isinstance(d, (ast.List, ast.Dict, ast.Set)) or (
            isinstance(d, ast.Call) and isinstance(d.func, ast.Name)
            and d.func.id in ("list", "dict", "set"))
        if mutable:
            out.append(Finding(
                path=mod.rel, line=d.lineno, rule=RULE, symbol=qual,
                message="unhashable-default: mutable default argument on "
                        "a jit-reachable function defeats the trace-cache "
                        "hash"))

    for node in walk_functions(fn):
        if isinstance(node, (ast.If, ast.While)):
            test = node.test
            kind = "if" if isinstance(node, ast.If) else "while"
        elif isinstance(node, ast.Assert):
            test, kind = node.test, "assert"
        elif isinstance(node, ast.IfExp):
            test, kind = node.test, "conditional expression"
        else:
            test = None
        if test is not None:
            hot = sorted(set(names_in(test)) & tainted)
            if hot:
                out.append(Finding(
                    path=mod.rel, line=test.lineno, rule=RULE, symbol=qual,
                    message=f"branch-on-tracer: `{kind}` tests "
                            f"tracer-derived value(s) "
                            f"{', '.join(hot)} — python control flow "
                            f"retraces or fails under jit"))
            continue

        if not isinstance(node, ast.Call):
            continue
        func = node.func
        # float()/int()/bool() over a traced value
        if isinstance(func, ast.Name) and func.id in _CONCRETIZERS:
            hot = sorted({n for a in node.args
                          for n in names_in(a)} & tainted)
            if hot:
                out.append(Finding(
                    path=mod.rel, line=node.lineno, rule=RULE, symbol=qual,
                    message=f"tracer-escape: `{func.id}()` concretizes "
                            f"tracer-derived value(s) {', '.join(hot)}"))
            continue
        if isinstance(func, ast.Attribute):
            # .item()/.tolist() on a traced value
            if func.attr in _ESCAPE_METHODS:
                hot = sorted(set(names_in(func.value)) & tainted)
                if hot:
                    out.append(Finding(
                        path=mod.rel, line=node.lineno, rule=RULE,
                        symbol=qual,
                        message=f"tracer-escape: `.{func.attr}()` on "
                                f"tracer-derived value(s) "
                                f"{', '.join(hot)}"))
                continue
            # np.* where the xp/jnp namespace is required
            chain = attr_chain(func)
            if chain and len(chain) >= 2:
                root = chain[0]
                resolved = mod.module_aliases.get(root, "")
                if root in _NUMPY_MODULES or resolved == "numpy" \
                        or resolved.startswith("numpy."):
                    out.append(Finding(
                        path=mod.rel, line=node.lineno, rule=RULE,
                        symbol=qual,
                        message=f"np-in-jit: `{'.'.join(chain)}(...)` "
                                f"inside a jit-traced path constant-"
                                f"folds at trace time; use the xp/jnp "
                                f"namespace"))


def _resolve_call(cache: ModuleCache, mod: Module, call: ast.Call
                  ) -> Optional[Tuple[Module, str]]:
    """Resolve a call to a function defined in this repository (same
    module, from-imported, or via a ``repro.*`` module alias)."""
    func = call.func
    if isinstance(func, ast.Name):
        name = func.id
        if name in mod.functions:
            return mod, name
        imp = mod.from_imports.get(name)
        if imp and imp[0].startswith("repro"):
            target = cache.get_by_dotted(imp[0])
            if target and imp[1] in target.functions:
                return target, imp[1]
        return None
    chain = attr_chain(func)
    if chain and len(chain) == 2:
        dotted = mod.module_aliases.get(chain[0])
        if dotted and dotted.startswith("repro"):
            target = cache.get_by_dotted(dotted)
            if target and chain[1] in target.functions:
                return target, chain[1]
    return None


def check_jax_hygiene(cache: ModuleCache,
                      entries: Tuple[JaxEntry, ...]) -> List[Finding]:
    out: List[Finding] = []
    seen: Set[Tuple[str, str]] = set()
    callees: List[Tuple[Module, str]] = []

    def visit(mod: Module, qual: str, static: Tuple[str, ...],
              is_entry: bool) -> None:
        seen.add((mod.rel, qual))
        fn = mod.functions[qual]
        _check_function(mod, qual, fn, static, is_entry, out)
        for node in walk_functions(fn):
            if isinstance(node, ast.Call):
                resolved = _resolve_call(cache, mod, node)
                if resolved is not None:
                    callees.append(resolved)

    # entries first — their declared static params must win over the
    # conservative all-tainted treatment of plain callees
    for e in entries:
        mod = cache.get(e.path)
        if mod is None or e.qualname not in mod.functions:
            out.append(Finding(
                path=e.path, line=1, rule=RULE, symbol=e.qualname,
                message="registered jax entry point not found"))
            continue
        visit(mod, e.qualname, e.static_params, True)
    while callees:
        tmod, tqual = callees.pop()
        if (tmod.rel, tqual) not in seen:
            # callees: every parameter is conservatively a tracer
            visit(tmod, tqual, (), False)
    return out
