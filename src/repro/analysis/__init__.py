"""chiplint — AST-based invariant analyzer for this repository.

Four rule families guard the invariants the runtime parity tests can
only sample:

* ``parity-drift``   — mirrored scalar/batched/event implementations of
                       the cost model must read the same hardware /
                       workload attributes and use the same numeric
                       constants (``repro.analysis.parity``);
* ``jax-hygiene``    — functions reachable from the jax backend's
                       traced entry points must not branch on tracer
                       values, concretize tracers, call ``np.`` where
                       the ``xp``/``jnp`` namespace is required, or use
                       unhashable defaults (``repro.analysis.jax_hygiene``);
* ``units``          — physical quantities named by the repo's suffix
                       convention (``_bytes``/``_s``/``_flops``/...)
                       must not be added, subtracted, or compared
                       across units (``repro.analysis.units``);
* ``determinism``    — no unseeded global RNG use, no mutation of
                       frozen dataclasses, and every metrics key must
                       be declared in the frozen ``obs.metrics`` schema
                       (``repro.analysis.determinism``).

Run via ``python -m repro.cli lint``; see DESIGN.md §analysis.
"""
from repro.analysis.findings import (Finding, load_baseline, save_baseline,
                                     diff_baseline)
from repro.analysis.parity import DEFAULT_PARITY_PAIRS, ParityPair, ParitySide
from repro.analysis.runner import (DEFAULT_CONFIG, LintConfig, LintReport,
                                   run_lint)

__all__ = [
    "Finding", "load_baseline", "save_baseline", "diff_baseline",
    "ParityPair", "ParitySide", "DEFAULT_PARITY_PAIRS",
    "LintConfig", "LintReport", "DEFAULT_CONFIG", "run_lint",
]
