"""Finding model, JSON report shape, and the grandfathered baseline.

A finding's FINGERPRINT deliberately excludes the line number: baselined
findings stay matched while unrelated edits shift code around, and a
duplicate message in the same file counts per occurrence (the baseline
is a multiset of fingerprints).
"""
from __future__ import annotations

import json
from collections import Counter
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Tuple

REPORT_SCHEMA = 1
BASELINE_SCHEMA = 1
DEFAULT_BASELINE = "chiplint_baseline.json"


@dataclass(frozen=True, order=True)
class Finding:
    path: str          # root-relative posix path
    line: int          # 1-based
    rule: str          # "parity-drift" | "jax-hygiene" | "units" | ...
    message: str
    symbol: str = ""   # enclosing function / parity-pair name

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}::{self.path}::{self.symbol}::{self.message}"

    def render(self) -> str:
        sym = f" ({self.symbol})" if self.symbol else ""
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}{sym}"

    def to_dict(self) -> dict:
        return {"path": self.path, "line": self.line, "rule": self.rule,
                "message": self.message, "symbol": self.symbol}


def report_dict(findings: List[Finding], new: List[Finding],
                stale: List[str], n_suppressed: int,
                n_files: int) -> dict:
    """The machine-readable report ``cli lint --json`` writes."""
    return {
        "schema": REPORT_SCHEMA,
        "tool": "chiplint",
        "n_files": n_files,
        "n_findings": len(findings),
        "n_suppressed": n_suppressed,
        "n_new": len(new),
        "n_stale_baseline": len(stale),
        "findings": [f.to_dict() for f in sorted(findings)],
        "new": [f.to_dict() for f in sorted(new)],
        "stale_baseline": sorted(stale),
    }


# ---------------------------------------------------------------------------
# Baseline I/O + diff
# ---------------------------------------------------------------------------
def load_baseline(path) -> Counter:
    """Multiset of grandfathered fingerprints ({} when absent)."""
    p = Path(path)
    if not p.is_file():
        return Counter()
    data = json.loads(p.read_text())
    if data.get("schema") != BASELINE_SCHEMA:
        raise ValueError(f"unsupported baseline schema in {p}: "
                         f"{data.get('schema')!r}")
    return Counter(data.get("findings", []))


def save_baseline(path, findings: List[Finding]) -> Path:
    p = Path(path)
    fps = sorted(f.fingerprint for f in findings)
    p.write_text(json.dumps({"schema": BASELINE_SCHEMA, "tool": "chiplint",
                             "findings": fps}, indent=1) + "\n")
    return p


def diff_baseline(findings: List[Finding], baseline: Counter
                  ) -> Tuple[List[Finding], List[str]]:
    """(new findings not covered by the baseline, stale baseline
    fingerprints with no matching finding).  Both must be empty for the
    tree to be baseline-exact."""
    current: Dict[str, List[Finding]] = {}
    for f in findings:
        current.setdefault(f.fingerprint, []).append(f)
    new: List[Finding] = []
    for fp, fs in current.items():
        allowed = baseline.get(fp, 0)
        if len(fs) > allowed:
            new.extend(sorted(fs)[allowed:])
    stale: List[str] = []
    for fp, n in baseline.items():
        have = len(current.get(fp, []))
        stale.extend([fp] * max(n - have, 0))
    return sorted(new), sorted(stale)
