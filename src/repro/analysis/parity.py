"""parity-drift — diff mirrored implementations of the cost model.

The repo's three engines (scalar oracle, batched SoA port, event-DAG
compiler) replicate each other's cost terms operation-for-operation.
This rule makes that correspondence a STATIC invariant: for each
registered pair of mirrored function groups it extracts

* every hardware/workload/MCM/strategy attribute the group reads —
  dotted chains rooted at role-mapped parameter names (one level of
  local aliasing is followed, so ``model = w.model; model.attn.n_heads``
  records ``workload.model.attn.n_heads``), and
* every numeric literal in the group body (as a float),

then symmetric-diffs the two sides.  A model term edited on one side
without the other — a new ``hw.`` field read, a changed ``12`` -> ``13``
— is a finding AT THE LINE of the unmatched read/constant.

Known-legitimate asymmetries (vectorization plumbing like column counts
and pad fills, scalar-only conveniences like ``mcm.hw`` fallbacks) are
declared per side in the registry below, next to a reason.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.analysis.astutil import Module, ModuleCache, attr_chain
from repro.analysis.findings import Finding

RULE = "parity-drift"

# literals too generic to diff (loop floors, sign flips, identity terms)
GENERIC_CONSTS = frozenset({0.0, 1.0, -1.0})

# trailing chain segments that are array/container plumbing, not model
# terms — ``batch.tp.astype(...)`` and ``s.tp`` read the same quantity
_ARRAY_METHODS = frozenset({
    "astype", "reshape", "sum", "take", "copy", "item", "tolist",
    "clip", "max", "min", "mean", "any", "all", "nonzero", "shape",
    "dtype",
})


@dataclass(frozen=True)
class ParitySide:
    """One side of a mirrored pair: a file plus the function group that
    implements the shared cost terms there."""

    path: str                              # root-relative source file
    functions: Tuple[str, ...]             # qualnames within the file
    # parameter/local name -> role ("hw", "workload", "mcm", "strategy")
    roles: Tuple[Tuple[str, str], ...] = ()
    # "role.dotted.path" reads that legitimately have no counterpart
    ignore_attrs: Tuple[str, ...] = ()
    # numeric literals that legitimately have no counterpart
    ignore_consts: Tuple[float, ...] = ()
    # descend into nested defs (closure-heavy sides like the DAG
    # compiler put model terms inside local helpers)
    include_nested: bool = False

    def role_map(self) -> Dict[str, str]:
        return dict(self.roles)


@dataclass(frozen=True)
class ParityPair:
    name: str
    a: ParitySide
    b: ParitySide
    check_attrs: bool = True
    check_consts: bool = True


@dataclass
class SideFacts:
    """Extraction result: first-occurrence site per attr chain/const."""

    attrs: Dict[str, Tuple[str, int]] = field(default_factory=dict)
    consts: Dict[float, Tuple[str, int]] = field(default_factory=dict)
    missing: List[str] = field(default_factory=list)   # unresolved funcs


class _SideVisitor(ast.NodeVisitor):
    """Collects maximal role-rooted attribute chains and numeric
    literals from one function body, following one level of pure-chain
    local aliases (``moe = model.moe``)."""

    def __init__(self, mod: Module, facts: SideFacts, roles: Dict[str, str],
                 descend_nested: bool = False):
        self.descend_nested = descend_nested
        self.mod = mod
        self.facts = facts
        # name -> role-rooted dotted prefix, e.g. {"w": "workload",
        # "model": "workload.model"}
        self.env: Dict[str, str] = dict(roles)
        # declared role names are sticky: ``mb = _mcm_params(mcm)`` and
        # ``hw = mcm.hw`` REFRESH the role, they don't retire it
        self.declared = set(roles)

    def _record_chain(self, node: ast.Attribute) -> bool:
        chain = attr_chain(node)
        if chain is None or chain[0] not in self.env:
            return False
        parts = [self.env[chain[0]]] + chain[1:]
        while len(parts) > 1 and parts[-1] in _ARRAY_METHODS:
            parts.pop()
        if len(parts) > 1:
            dotted = ".".join(parts)
            self.facts.attrs.setdefault(dotted, (self.mod.rel, node.lineno))
        return True

    def visit_Attribute(self, node: ast.Attribute):
        if not self._record_chain(node):
            # not role-rooted: descend (there may be a rooted chain
            # inside, e.g. ``f(mcm.hbm_bw).x``)
            self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign):
        self.visit(node.value)
        # alias tracking: single Name target bound to a pure role chain
        if len(node.targets) == 1 and isinstance(node.targets[0], ast.Name):
            tgt = node.targets[0].id
            if tgt in self.declared:
                return          # declared roles are never rebound
            chain = attr_chain(node.value)
            if chain is not None and chain[0] in self.env:
                self.env[tgt] = ".".join([self.env[chain[0]]] + chain[1:])
            elif tgt in self.env and not (
                    isinstance(node.value, ast.Name)
                    and node.value.id == tgt):
                # a derived alias rebound to something non-role-rooted
                # goes stale
                del self.env[tgt]
        else:
            for t in node.targets:
                self.visit(t)

    def visit_Constant(self, node: ast.Constant):
        v = node.value
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            return
        v = float(v)
        if v in GENERIC_CONSTS:
            return
        self.facts.consts.setdefault(v, (self.mod.rel, node.lineno))

    def visit_FunctionDef(self, node):
        if self.descend_nested:           # closures share the role names
            for stmt in node.body:
                self.visit(stmt)

    visit_AsyncFunctionDef = visit_FunctionDef


def extract_side(cache: ModuleCache, side: ParitySide) -> SideFacts:
    facts = SideFacts()
    mod = cache.get(side.path)
    if mod is None:
        facts.missing.append(f"{side.path} (file not found)")
        return facts
    for qual in side.functions:
        fn = mod.functions.get(qual)
        if fn is None:
            facts.missing.append(f"{side.path}::{qual}")
            continue
        v = _SideVisitor(mod, facts, side.role_map(),
                         descend_nested=side.include_nested)
        for stmt in fn.body:
            v.visit(stmt)
    return facts


def _fmt_const(v: float) -> str:
    return str(int(v)) if v == int(v) and abs(v) < 1e15 else repr(v)


def check_pair(cache: ModuleCache, pair: ParityPair) -> List[Finding]:
    fa = extract_side(cache, pair.a)
    fb = extract_side(cache, pair.b)
    out: List[Finding] = []
    for side, facts in ((pair.a, fa), (pair.b, fb)):
        for miss in facts.missing:
            out.append(Finding(
                path=side.path, line=1, rule=RULE, symbol=pair.name,
                message=f"registered parity function not found: {miss}"))
    if fa.missing or fb.missing:
        return out

    def one_way(facts_have: SideFacts, side_have: ParitySide,
                side_lack: ParitySide, facts_lack: SideFacts):
        if pair.check_attrs:
            for dotted, (rel, line) in sorted(facts_have.attrs.items()):
                if dotted in side_have.ignore_attrs:
                    continue
                if dotted not in facts_lack.attrs:
                    out.append(Finding(
                        path=rel, line=line, rule=RULE, symbol=pair.name,
                        message=f"model term drift: attribute `{dotted}` "
                                f"is read here but not by the mirrored "
                                f"side ({side_lack.path})"))
        if pair.check_consts:
            ignore = set(side_have.ignore_consts)
            for v, (rel, line) in sorted(facts_have.consts.items()):
                if v in ignore:
                    continue
                if v not in facts_lack.consts:
                    out.append(Finding(
                        path=rel, line=line, rule=RULE, symbol=pair.name,
                        message=f"model term drift: constant "
                                f"`{_fmt_const(v)}` appears here but not "
                                f"on the mirrored side ({side_lack.path})"))

    one_way(fa, pair.a, pair.b, fb)
    one_way(fb, pair.b, pair.a, fa)
    return out


def check_parity(cache: ModuleCache, pairs: Tuple[ParityPair, ...]
                 ) -> List[Finding]:
    out: List[Finding] = []
    for pair in pairs:
        out.extend(check_pair(cache, pair))
    return out


# ---------------------------------------------------------------------------
# The repository's mirrored-pair registry
# ---------------------------------------------------------------------------
# Roles shared by the scalar oracle side
_SCAL_SIM = (("w", "workload"), ("s", "strategy"), ("mcm", "mcm"),
             ("hw", "hw"))
_BATCH_ROLES = (("w", "workload"), ("batch", "strategy"), ("mb", "mcm"),
                ("mcm", "mcm"), ("hw", "hw"))

DEFAULT_PARITY_PAIRS: Tuple[ParityPair, ...] = (
    # ---- traffic model: scalar dict vs SoA columns ---------------------
    ParityPair(
        name="traffic_volumes",
        a=ParitySide(
            path="src/repro/core/traffic.py",
            functions=("traffic_volumes",),
            roles=(("w", "workload"), ("s", "strategy")),
        ),
        b=ParitySide(
            path="src/repro/dse/batched_sim.py",
            functions=("traffic_volumes_batch",),
            roles=(("w", "workload"), ("batch", "strategy")),
            # SoA plumbing: the (B, 5) column count
            ignore_consts=(5.0,),
        ),
    ),
    # ---- intra-MCM packing --------------------------------------------
    ParityPair(
        name="map_intra",
        a=ParitySide(
            path="src/repro/core/simulator.py",
            functions=("map_intra",),
            roles=(("mcm", "mcm"),),
        ),
        b=ParitySide(
            path="src/repro/dse/batched_sim.py",
            functions=("map_intra_batch",),
            roles=(("mcm", "mcm"),),
        ),
        # degrees flow through dicts on one side and P_IDX columns on the
        # other; only the mcm reads and the literals are comparable
    ),
    # ---- GEMM shape efficiency ----------------------------------------
    ParityPair(
        name="gemm_eff",
        a=ParitySide(
            path="src/repro/core/simulator.py",
            functions=("_gemm_eff",),
            roles=(("w", "workload"), ("s", "strategy"), ("hw", "hw")),
        ),
        b=ParitySide(
            path="src/repro/dse/batched_sim.py",
            functions=("gemm_eff_batch",),
            roles=(("w", "workload"), ("batch", "strategy"), ("hw", "hw")),
        ),
    ),
    # ---- OI link allocation -------------------------------------------
    ParityPair(
        name="allocate_links",
        a=ParitySide(
            path="src/repro/core/network.py",
            functions=("allocate_links",),
        ),
        b=ParitySide(
            path="src/repro/dse/batched_sim.py",
            functions=("allocate_links_batch", "_trim_over_budget"),
            # 8: bounded trim-pass count (scalar side loops unbounded)
            ignore_consts=(8.0,),
        ),
    ),
    # ---- the full step-time model: scalar oracle vs batched SoA -------
    ParityPair(
        name="simulate~batched",
        a=ParitySide(
            path="src/repro/core/simulator.py",
            functions=("simulate", "_bank_swap_reuse_ok"),
            roles=_SCAL_SIM,
            ignore_attrs=(
                # scalar conveniences with no batched counterpart:
                "mcm.hw",                  # hw fallback (explicit in batch)
                "mcm.intra_ring_bw",       # inlined as nop_bw/dilution
                "strategy.n_devices",      # precomputed batch column
                "strategy.degree",         # per-point dict lookup
            ),
            ignore_consts=(
                1e9,                       # GB formatting in reason strings
            ),
        ),
        b=ParitySide(
            path="src/repro/dse/batched_sim.py",
            functions=("batched_simulate", "_terms_core",
                       "hbm_demand_batch", "pick_reuse_pairs",
                       "_ceil_log2_int"),
            roles=_BATCH_ROLES,
            ignore_attrs=(
                # batched-only surfaces (board power, railx, SoA access)
                "mcm.m", "mcm.n_mcm",      # board-power model (scalar
                                           # twin lives in board_power())
                "hw.ocs_ports",            # railx alloc_mode only
                "strategy.n_devices", "strategy.tp", "strategy.dp",
                "strategy.pp", "strategy.cp", "strategy.ep",
                "strategy.n_micro", "strategy.take",
                "workload.step_flops",     # also read via scalar's w
                # the scalar twin is the mcm.intra_ring_bw(deg) method
                # (ignored on the a side); the SoA carries it as nop_bw
                "mcm.nop_bw",
            ),
            ignore_consts=(
                5.0,                       # (B, 5) parallelism columns
                8.0,                       # also _bucket floor; real 8 is
                                           # matched via inv["TP"]
                3.0,                       # _bucket floor exponent
                64.0,                      # frexp mantissa bits plumbing
            ),
        ),
    ),
    # ---- event-DAG unit costs vs the scalar oracle --------------------
    ParityPair(
        name="simulate~events_dag",
        a=ParitySide(
            path="src/repro/core/simulator.py",
            functions=("simulate",),
            roles=_SCAL_SIM,
            ignore_attrs=(
                "mcm.hw",
                "strategy.n_devices",
                # the DAG replays points simulate() already gated; the
                # capacity check has no replay-side twin
                "mcm.hbm_capacity",
            ),
            ignore_consts=(1e9,),
        ),
        b=ParitySide(
            path="src/repro/events/dag.py",
            functions=("compile_step",),
            roles=_SCAL_SIM,
            ignore_attrs=(
                "mcm.hw",
                "strategy.degree",
                "workload.step_flops",
            ),
            # model terms live in compile_step's local closures
            include_nested=True,
        ),
        # the DAG side splits tiles/shares with schedule-only constants;
        # constants are checked via the dedicated ignore lists below
        check_consts=False,
    ),
    # ---- vectorized record->program compiler vs the scalar DAG walk ---
    # compile_batch replicates compile_step's spans/collectives in SoA
    # form (runtime twin: the 1e-9 pin in tests/test_events.py); a unit
    # cost edited on one side without the other drifts here
    ParityPair(
        name="compile_step~compile_batch",
        a=ParitySide(
            path="src/repro/events/dag.py",
            functions=("compile_step",),
            roles=_SCAL_SIM,
            ignore_attrs=(
                "mcm.hw",
                "strategy.degree",          # per-point dict lookup
                # the batch reads these via hbm_demand_batch's
                # local_params column
                "workload.nonexpert_params",
                "workload.expert_params",
                # the scalar twin is the mcm.intra_ring_bw(deg) method;
                # the SoA carries it as nop_bw + explicit dilution
                "mcm.intra_ring_bw",
            ),
            include_nested=True,
        ),
        b=ParitySide(
            path="src/repro/events/compile_batch.py",
            functions=("compile_batch", "_compile_group"),
            roles=_BATCH_ROLES,
            ignore_attrs=(
                "mcm.hw",
                # feasibility gating: compile_step only sees points
                # simulate() already gated and raises otherwise; the
                # batch marks the row infeasible instead
                "strategy.n_devices",
                "mcm.hbm_capacity",
                "mcm.nop_bw",               # intra_ring_bw twin (above)
            ),
            # closed-form spans live in the node_span local closure
            include_nested=True,
        ),
        # schedule constants (tile splits, shares) differ structurally:
        # the DAG walk builds per-op tasks, the batch the closed form
        check_consts=False,
    ),
)
