"""determinism/schema — reproducibility and frozen-schema invariants.

Three checks, all repo-wide over ``src/repro``:

* ``global-rng``      — use of the process-global RNG state (stdlib
  ``random.x(...)`` or legacy ``np.random.x(...)``): studies must
  thread a seeded ``np.random.default_rng`` / ``random.Random`` so two
  runs of the same config are bit-identical;
* ``frozen-mutation`` — attribute assignment on an instance of a
  ``@dataclass(frozen=True)`` class (raises ``FrozenInstanceError`` at
  runtime; these only hide in dormant code paths);
* ``unknown-metric``  — a literal metric name passed to
  ``obs.metrics.inc``/``gauge`` that is not declared in the
  ``KNOWN_COUNTERS`` / ``KNOWN_GAUGES`` registries of
  ``repro/obs/metrics.py`` (the registries are read via AST, not
  imported, so the linter works on a broken tree too).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.astutil import (Module, ModuleCache, attr_chain,
                                    walk_functions)
from repro.analysis.findings import Finding

RULE = "determinism"

METRICS_DECL_PATH = "src/repro/obs/metrics.py"

# random.X spellings that are fine without a seeded generator object
_RANDOM_OK = frozenset({"Random", "SystemRandom"})
# np.random.X spellings that construct/describe generators, not draws
_NP_RANDOM_OK = frozenset({"default_rng", "Generator", "SeedSequence",
                           "BitGenerator", "PCG64", "Philox", "MT19937",
                           "RandomState"})


# ---------------------------------------------------------------------------
# metric-name registries (read statically from the metrics module)
# ---------------------------------------------------------------------------
def load_declared_metrics(cache: ModuleCache,
                          decl_path: str = METRICS_DECL_PATH
                          ) -> Optional[Tuple[Set[str], Set[str]]]:
    mod = cache.get(decl_path)
    if mod is None:
        return None
    decls: Dict[str, Set[str]] = {}
    for node in mod.tree.body:
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        target = node.targets[0]
        if not isinstance(target, ast.Name) or target.id not in (
                "KNOWN_COUNTERS", "KNOWN_GAUGES"):
            continue
        names: Set[str] = set()
        for sub in ast.walk(node.value):
            if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
                names.add(sub.value)
        decls[target.id] = names
    if "KNOWN_COUNTERS" not in decls or "KNOWN_GAUGES" not in decls:
        return None
    return decls["KNOWN_COUNTERS"], decls["KNOWN_GAUGES"]


def _metrics_aliases(mod: Module) -> Set[str]:
    """Local names that refer to the ``repro.obs.metrics`` module."""
    out = set()
    for alias, dotted in mod.module_aliases.items():
        if dotted in ("repro.obs.metrics", "obs.metrics", "metrics"):
            out.add(alias)
    for alias, (src, name) in mod.from_imports.items():
        if name == "metrics" and src.endswith("obs"):
            out.add(alias)
    return out


# ---------------------------------------------------------------------------
# frozen dataclass registry
# ---------------------------------------------------------------------------
def _frozen_classes(mod: Module) -> Set[str]:
    frozen: Set[str] = set()
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        for dec in node.decorator_list:
            if not (isinstance(dec, ast.Call)
                    and (chain := attr_chain(dec.func)) is not None
                    and chain[-1] == "dataclass"):
                continue
            for kw in dec.keywords:
                if kw.arg == "frozen" and isinstance(kw.value, ast.Constant) \
                        and kw.value.value is True:
                    frozen.add(node.name)
    return frozen


def collect_frozen_classes(cache: ModuleCache, rels: List[str]) -> Set[str]:
    """Names of all @dataclass(frozen=True) classes across the tree.
    Names are collected unqualified: the repo keeps dataclass names
    unique, and a rare collision only widens the check."""
    out: Set[str] = set()
    for rel in rels:
        mod = cache.get(rel)
        if mod is not None:
            out |= _frozen_classes(mod)
    return out


def _frozen_locals(fn: ast.FunctionDef, frozen: Set[str]) -> Set[str]:
    """Local names bound to a construction of a frozen class, or
    annotated/defaulted as one (parameters with a frozen-class
    annotation count)."""
    names: Set[str] = set()
    args = fn.args
    for a in args.posonlyargs + args.args + args.kwonlyargs:
        ann = a.annotation
        if ann is not None:
            for sub in ast.walk(ann):
                if isinstance(sub, ast.Name) and sub.id in frozen:
                    names.add(a.arg)
                elif isinstance(sub, ast.Constant) \
                        and isinstance(sub.value, str) \
                        and sub.value in frozen:
                    names.add(a.arg)
    for node in walk_functions(fn):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            callee = node.value.func
            cname = callee.id if isinstance(callee, ast.Name) else (
                callee.attr if isinstance(callee, ast.Attribute) else None)
            if cname in frozen:
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        names.add(t.id)
    return names


# ---------------------------------------------------------------------------
# the rule
# ---------------------------------------------------------------------------
def check_determinism(cache: ModuleCache, rels: List[str],
                      decl_path: str = METRICS_DECL_PATH) -> List[Finding]:
    out: List[Finding] = []
    declared = load_declared_metrics(cache, decl_path)
    frozen = collect_frozen_classes(cache, rels)

    for rel in rels:
        mod = cache.get(rel)
        if mod is None:
            continue
        m_aliases = _metrics_aliases(mod)
        _check_module(mod, m_aliases, declared, frozen, out,
                      is_decl_module=(rel == decl_path))
    return out


def _check_module(mod: Module, m_aliases: Set[str],
                  declared: Optional[Tuple[Set[str], Set[str]]],
                  frozen: Set[str], out: List[Finding],
                  is_decl_module: bool) -> None:
    # resolve aliases for the random modules in this file
    rng_roots: Dict[str, str] = {}      # local alias -> "random"|"numpy"
    for alias, dotted in mod.module_aliases.items():
        if dotted == "random":
            rng_roots[alias] = "random"
        elif dotted in ("numpy", "numpy.random") \
                or dotted.startswith("numpy."):
            rng_roots[alias] = dotted

    for node in ast.walk(mod.tree):
        # ---- global-rng ----
        if isinstance(node, ast.Call):
            chain = attr_chain(node.func)
            if chain and chain[0] in rng_roots:
                dotted = rng_roots[chain[0]]
                full = dotted.split(".") + chain[1:] if dotted != "random" \
                    else chain
                if dotted == "random" and len(chain) == 2 \
                        and chain[1] not in _RANDOM_OK:
                    out.append(Finding(
                        path=mod.rel, line=node.lineno, rule=RULE,
                        symbol=_enclosing(mod, node),
                        message=f"global-rng: `random.{chain[1]}(...)` "
                                f"draws from the process-global RNG; "
                                f"thread a seeded `random.Random`"))
                elif ".".join(full[:2]) == "numpy.random" \
                        and len(full) >= 3 \
                        and full[2] not in _NP_RANDOM_OK:
                    out.append(Finding(
                        path=mod.rel, line=node.lineno, rule=RULE,
                        symbol=_enclosing(mod, node),
                        message=f"global-rng: `np.random.{full[2]}(...)` "
                                f"uses numpy's legacy global state; use "
                                f"a seeded `np.random.default_rng`"))

            # ---- unknown-metric ----
            if declared is not None and not is_decl_module and chain \
                    and len(chain) >= 2 and chain[-1] in ("inc", "gauge") \
                    and chain[-2] in m_aliases:
                if node.args and isinstance(node.args[0], ast.Constant) \
                        and isinstance(node.args[0].value, str):
                    name = node.args[0].value
                    known = declared[0] if chain[-1] == "inc" \
                        else declared[1]
                    kind = "counter" if chain[-1] == "inc" else "gauge"
                    if name not in known:
                        out.append(Finding(
                            path=mod.rel, line=node.lineno, rule=RULE,
                            symbol=_enclosing(mod, node),
                            message=f"unknown-metric: {kind} "
                                    f"`{name}` is not declared in "
                                    f"obs.metrics.KNOWN_"
                                    f"{'COUNTERS' if kind == 'counter' else 'GAUGES'}"))

    # ---- frozen-mutation ----
    for qual, fn in mod.functions.items():
        local_frozen = _frozen_locals(fn, frozen)
        # methods of a frozen class may not assign to self outside
        # object.__setattr__ — find the owning class
        cls = qual.split(".")[0] if "." in qual else None
        if cls in frozen and fn.name != "__new__":
            local_frozen = local_frozen | {"self"}
        if not local_frozen:
            continue
        for node in walk_functions(fn):
            if not isinstance(node, (ast.Assign, ast.AugAssign)):
                continue
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                if isinstance(t, ast.Attribute) \
                        and isinstance(t.value, ast.Name) \
                        and t.value.id in local_frozen:
                    out.append(Finding(
                        path=mod.rel, line=t.lineno, rule=RULE,
                        symbol=qual,
                        message=f"frozen-mutation: assignment to "
                                f"`{t.value.id}.{t.attr}` on a frozen "
                                f"dataclass instance raises "
                                f"FrozenInstanceError at runtime"))


def _enclosing(mod: Module, node: ast.AST) -> str:
    """Best-effort enclosing function qualname for a node (by line
    range); '<module>' when at top level."""
    best = "<module>"
    best_span = None
    for qual, fn in mod.functions.items():
        end = getattr(fn, "end_lineno", None) or fn.lineno
        if fn.lineno <= node.lineno <= end:
            span = end - fn.lineno
            if best_span is None or span < best_span:
                best, best_span = qual, span
    return best
