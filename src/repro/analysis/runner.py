"""Lint driver: configuration, rule dispatch, suppression, reporting.

``run_lint(root, config)`` parses each target file once (shared
``ModuleCache``), runs the four rule families, drops findings whose
source line carries a matching ``# chiplint: ignore[rule]`` comment,
and returns a ``LintReport``.  Baseline diffing lives in
``repro.analysis.findings``; the CLI front-end in ``repro.cli``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from pathlib import Path
from typing import List, Tuple

from repro.analysis.astutil import ModuleCache, is_suppressed
from repro.analysis.determinism import (METRICS_DECL_PATH,
                                        check_determinism)
from repro.analysis.findings import Finding
from repro.analysis.jax_hygiene import (DEFAULT_JAX_ENTRIES, JaxEntry,
                                        check_jax_hygiene)
from repro.analysis.parity import (DEFAULT_PARITY_PAIRS, ParityPair,
                                   check_parity)
from repro.analysis.units import check_units

# units inference is scoped to the cost/performance model files where
# the suffix convention is the contract, not incidental naming
DEFAULT_UNITS_PATHS: Tuple[str, ...] = (
    "src/repro/core/cost.py",
    "src/repro/core/simulator.py",
    "src/repro/core/network.py",
    "src/repro/events/dag.py",
    "src/repro/events/engine.py",
    "src/repro/events/validate.py",
    "src/repro/events/batch.py",
)

# determinism/schema scans the whole package
DEFAULT_SCAN_GLOB = "src/repro/**/*.py"


@dataclass(frozen=True)
class LintConfig:
    parity_pairs: Tuple[ParityPair, ...] = DEFAULT_PARITY_PAIRS
    jax_entries: Tuple[JaxEntry, ...] = DEFAULT_JAX_ENTRIES
    units_paths: Tuple[str, ...] = DEFAULT_UNITS_PATHS
    scan_glob: str = DEFAULT_SCAN_GLOB
    metrics_decl_path: str = METRICS_DECL_PATH


DEFAULT_CONFIG = LintConfig()


@dataclass
class LintReport:
    findings: List[Finding] = field(default_factory=list)
    n_suppressed: int = 0
    n_files: int = 0


def run_lint(root, config: LintConfig = DEFAULT_CONFIG) -> LintReport:
    root = Path(root)
    cache = ModuleCache(root)
    scan_rels = sorted(
        p.relative_to(root).as_posix()
        for p in root.glob(config.scan_glob) if p.is_file())

    raw: List[Finding] = []
    raw += check_parity(cache, config.parity_pairs)
    raw += check_jax_hygiene(cache, config.jax_entries)
    raw += check_units(cache, config.units_paths)
    raw += check_determinism(cache, scan_rels, config.metrics_decl_path)

    report = LintReport(n_files=len(scan_rels))
    for f in sorted(raw):
        mod = cache.get(f.path)
        if mod is not None and is_suppressed(mod, f.line, f.rule):
            report.n_suppressed += 1
        else:
            report.findings.append(f)
    return report
