"""units — physical-unit inference from the repo's naming convention.

Quantities carry their unit as a name suffix (``mem_bytes``,
``ocs_switch_latency_s``, ``hbm_cap_gbps``, ``die_flops``...).  This
rule infers units for names and attribute reads from those suffixes,
propagates them through local assignments (simple last-writer-wins
dataflow per function), and flags

* ``+`` / ``-`` (and ``+=`` / ``-=``) between two known, different
  units — ``_bytes + _s`` is always a bug, and ``_gb + _bytes`` /
  ``_ms + _s`` are scale bugs the float math cannot catch;
* comparisons between two known, different units;
* assigning a value of one known unit to a name whose suffix declares
  another.

Multiplication/division yields an unknown unit (deriving compound
units is out of scope — ``bytes / s`` legitimately produces bandwidth),
so the rule only fires where the suffix convention makes intent
unambiguous.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from repro.analysis.astutil import Module, ModuleCache, walk_functions
from repro.analysis.findings import Finding

RULE = "units"

# suffix -> unit label (longest suffix wins: ``_gbps`` before ``_s``)
UNIT_SUFFIXES: Tuple[Tuple[str, str], ...] = (
    ("_gbps", "GB/s"),
    ("_bytes", "bytes"),
    ("_flops", "FLOPs"),
    ("_gb", "GB"),
    ("_ms", "ms"),
    ("_us", "us"),
    ("_s", "s"),
    ("_w", "W"),
)


def unit_of_name(name: str) -> Optional[str]:
    low = name.lower()
    for suffix, unit in UNIT_SUFFIXES:
        if low.endswith(suffix) and len(low) > len(suffix):
            return unit
    return None


class _UnitChecker:
    """Per-function unit inference and check pass."""

    def __init__(self, mod: Module, symbol: str, out: List[Finding]):
        self.mod = mod
        self.symbol = symbol
        self.out = out
        self.env: Dict[str, Optional[str]] = {}

    # ---------------- inference ----------------
    def unit_of(self, node: ast.AST) -> Optional[str]:
        if isinstance(node, ast.Name):
            if node.id in self.env:
                return self.env[node.id]
            return unit_of_name(node.id)
        if isinstance(node, ast.Attribute):
            return unit_of_name(node.attr)
        if isinstance(node, ast.BinOp):
            if isinstance(node.op, (ast.Add, ast.Sub)):
                lu = self.unit_of(node.left)
                ru = self.unit_of(node.right)
                if lu is not None and ru is not None and lu == ru:
                    return lu
                return lu if ru is None else ru if lu is None else None
            return None            # * / // % ** — compound units: unknown
        if isinstance(node, ast.UnaryOp):
            return self.unit_of(node.operand)
        if isinstance(node, ast.Call):
            # unit-transparent wrappers: min/max/abs/sum/float and the
            # numpy spellings reached through any module alias
            fname = None
            if isinstance(node.func, ast.Name):
                fname = node.func.id
            elif isinstance(node.func, ast.Attribute):
                fname = node.func.attr
            if fname in ("min", "max", "abs", "sum", "float", "minimum",
                         "maximum", "where", "asarray", "broadcast_to"):
                args = [a for a in node.args
                        if not isinstance(a, ast.Starred)]
                if fname == "where" and len(args) == 3:
                    args = args[1:]       # the condition carries no unit
                units = {u for u in (self.unit_of(a) for a in args)
                         if u is not None}
                if len(units) == 1:
                    return units.pop()
            return None
        if isinstance(node, ast.IfExp):
            bu = self.unit_of(node.body)
            ou = self.unit_of(node.orelse)
            if bu == ou:
                return bu
            return None
        return None

    # ---------------- checks ----------------
    def _flag(self, node: ast.AST, what: str, lu: str, ru: str):
        self.out.append(Finding(
            path=self.mod.rel, line=node.lineno, rule=RULE,
            symbol=self.symbol,
            message=f"unit mismatch: {what} between `{lu}` and `{ru}`"))

    def check_stmt(self, node: ast.AST):
        if isinstance(node, ast.BinOp) and isinstance(
                node.op, (ast.Add, ast.Sub)):
            lu = self.unit_of(node.left)
            ru = self.unit_of(node.right)
            if lu is not None and ru is not None and lu != ru:
                op = "+" if isinstance(node.op, ast.Add) else "-"
                self._flag(node, f"`{op}`", lu, ru)
        elif isinstance(node, ast.Compare):
            units = [self.unit_of(c) for c in
                     [node.left] + list(node.comparators)]
            known = [u for u in units if u is not None]
            if len(set(known)) > 1:
                self._flag(node, "comparison", known[0],
                           next(u for u in known if u != known[0]))
        elif isinstance(node, ast.AugAssign) and isinstance(
                node.op, (ast.Add, ast.Sub)):
            lu = self.unit_of(node.target)
            ru = self.unit_of(node.value)
            if lu is not None and ru is not None and lu != ru:
                op = "+=" if isinstance(node.op, ast.Add) else "-="
                self._flag(node, f"`{op}`", lu, ru)
        elif isinstance(node, ast.Assign):
            vu = self.unit_of(node.value)
            for t in node.targets:
                if isinstance(t, ast.Name):
                    declared = unit_of_name(t.id)
                    if declared is not None and vu is not None \
                            and declared != vu:
                        self._flag(node, f"assignment to `{t.id}`",
                                   declared, vu)
                    self.env[t.id] = vu if declared is None else declared

    def run(self, body) -> None:
        for node in body:
            self.check_stmt(node)


def check_units(cache: ModuleCache, paths: Tuple[str, ...]) -> List[Finding]:
    out: List[Finding] = []
    for rel in paths:
        mod = cache.get(rel)
        if mod is None:
            continue
        # module level (constants etc.)
        top = _UnitChecker(mod, "<module>", out)
        top.run(list(_module_level_nodes(mod.tree)))
        # each function, statement order, with local propagation
        for qual, fn in mod.functions.items():
            checker = _UnitChecker(mod, qual, out)
            checker.run(list(walk_functions(fn)))
    return out


def _module_level_nodes(tree: ast.Module):
    """Module statements in source order, excluding function/class
    bodies (those are checked with their own local environments)."""
    stack: List[ast.AST] = list(ast.iter_child_nodes(tree))[::-1]
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef, ast.Lambda)):
            stack.extend(list(ast.iter_child_nodes(node))[::-1])
