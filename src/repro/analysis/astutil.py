"""Shared AST plumbing for the chiplint rule families.

One parsed view per file (``Module``), dotted-attribute-chain
extraction, per-module import maps (so ``obs_metrics.inc`` resolves to
``repro.obs.metrics.inc``), a qualname -> FunctionDef table (nested
functions and methods as ``outer.inner`` / ``Class.method``), and the
``# chiplint: ignore[rule]`` suppression scanner.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass
class Module:
    """Parsed source file plus the derived tables every rule needs."""

    path: Path                    # absolute
    rel: str                      # root-relative posix path
    tree: ast.Module
    lines: List[str]
    functions: Dict[str, ast.FunctionDef] = field(default_factory=dict)
    # alias -> dotted module name, for ``import numpy as np`` and
    # ``from repro.obs import metrics as obs_metrics``
    module_aliases: Dict[str, str] = field(default_factory=dict)
    # name -> (module, original name), for ``from x import y [as z]``
    from_imports: Dict[str, Tuple[str, str]] = field(default_factory=dict)


class _FunctionIndexer(ast.NodeVisitor):
    def __init__(self, mod: Module):
        self.mod = mod
        self.stack: List[str] = []

    def _visit_scope(self, node):
        self.stack.append(node.name)
        qual = ".".join(self.stack)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self.mod.functions[qual] = node
        self.generic_visit(node)
        self.stack.pop()

    visit_FunctionDef = _visit_scope
    visit_AsyncFunctionDef = _visit_scope
    visit_ClassDef = _visit_scope


def load_module(path: Path, root: Path) -> Module:
    src = path.read_text()
    tree = ast.parse(src, filename=str(path))
    mod = Module(path=path, rel=path.relative_to(root).as_posix(),
                 tree=tree, lines=src.splitlines())
    _FunctionIndexer(mod).visit(tree)
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                mod.module_aliases[a.asname or a.name.split(".")[0]] = \
                    a.name if a.asname else a.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom) and node.module \
                and node.level == 0:
            for a in node.names:
                # an imported submodule acts as a module alias too
                mod.module_aliases.setdefault(
                    a.asname or a.name, f"{node.module}.{a.name}")
                mod.from_imports[a.asname or a.name] = (node.module, a.name)
    return mod


class ModuleCache:
    """Parse each file once per lint run (rules share the parses)."""

    def __init__(self, root: Path):
        self.root = Path(root)
        self._mods: Dict[str, Module] = {}

    def get(self, rel: str) -> Optional[Module]:
        rel = Path(rel).as_posix()
        if rel not in self._mods:
            path = self.root / rel
            if not path.is_file():
                return None
            self._mods[rel] = load_module(path, self.root)
        return self._mods[rel]

    def get_by_dotted(self, dotted: str) -> Optional[Module]:
        """Resolve ``repro.obs.metrics`` to its source file under
        ``src/`` (or a bare top-level layout)."""
        for prefix in ("src/", ""):
            for suffix in (".py", "/__init__.py"):
                mod = self.get(prefix + dotted.replace(".", "/") + suffix)
                if mod is not None:
                    return mod
        return None


def attr_chain(node: ast.AST) -> Optional[List[str]]:
    """``a.b.c`` -> ["a", "b", "c"]; None for non-name-rooted chains."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return None


def walk_functions(fn: ast.FunctionDef):
    """All nodes of ``fn`` excluding nested function bodies, yielded in
    source (pre)order so single-forward-pass dataflow is sound."""
    stack: List[ast.AST] = list(ast.iter_child_nodes(fn))[::-1]
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
            stack.extend(list(ast.iter_child_nodes(node))[::-1])


def names_in(node: ast.AST) -> List[str]:
    return [n.id for n in ast.walk(node) if isinstance(n, ast.Name)]


# ---------------------------------------------------------------------------
# Inline suppressions
# ---------------------------------------------------------------------------
_IGNORE_RE = re.compile(
    r"#\s*chiplint:\s*ignore(?:\[(?P<rules>[\w\-, ]+)\])?")


def suppressed_rules(mod: Module, line: int) -> Optional[set]:
    """Rules suppressed on source line ``line`` (1-based).

    Returns None when the line carries no chiplint comment, the empty
    set for a bare ``# chiplint: ignore`` (suppresses every rule), or
    the named rule set for ``# chiplint: ignore[rule1,rule2]``.
    """
    if not 1 <= line <= len(mod.lines):
        return None
    m = _IGNORE_RE.search(mod.lines[line - 1])
    if m is None:
        return None
    if m.group("rules") is None:
        return set()
    return {r.strip() for r in m.group("rules").split(",") if r.strip()}


def is_suppressed(mod: Module, line: int, rule: str) -> bool:
    rules = suppressed_rules(mod, line)
    if rules is None:
        return False
    return not rules or rule in rules
