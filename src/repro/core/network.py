"""Rail-based OI network model (paper §IV-A, Fig 5b).

A rail dimension D_i = (N_i, R_i, S_i): S_i OCSs connect N_i MCMs, each
MCM contributing R_i links (k_i per OCS, S_i = floor(R_i/k_i)), under the
OCS port bound k_i * N_i <= P.  The full network interweaves rail
dimensions with  prod_i N_i = N  and  sum_i R_i <= L.  OCS count:
S = sum_i (prod_{j != i} N_j) * S_i.

Logical topologies (ring / fully-connected per parallelism) are configured
onto the physical rails by OCS (re)configuration; RailX and TPUv4 are
special cases with 2-3 uniform rail dimensions.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.hardware import HW, DEFAULT_HW
from repro.core.mcm import MCMArch


@dataclass(frozen=True)
class RailDim:
    n: int              # N_i — MCMs per rail
    r: int              # R_i — links per MCM devoted to this dimension
    k: int = 1          # links per MCM per OCS

    @property
    def ocs_per_rail(self) -> int:
        return self.r // self.k     # S_i

    def port_ok(self, ports: int) -> bool:
        return self.k * self.n <= ports

    @property
    def bw_per_mcm(self) -> float:
        """Relative link count usable by traffic on this dimension."""
        return float(self.r)


@dataclass(frozen=True)
class OITopology:
    dims: Tuple[RailDim, ...]
    # parallelisms mapped onto each dim (multiple allowed — §IV-B);
    # entries are tuples like ("CP", "EP") when sharing/reusing a dim.
    mapping: Tuple[Tuple[str, ...], ...] = ()
    # link allocation per parallelism (l_p, §IV-B step 3)
    link_alloc: Dict[str, int] = field(default_factory=dict)
    reuse_pair: Optional[Tuple[str, str]] = None

    def n_mcm(self) -> int:
        out = 1
        for d in self.dims:
            out *= d.n
        return out

    def total_links_used(self) -> int:
        return sum(d.r for d in self.dims)

    def ocs_count(self) -> int:
        """S = sum_i (prod_{j!=i} N_j) * S_i."""
        total = 0
        n_all = self.n_mcm()
        for d in self.dims:
            rails_in_dim = n_all // d.n
            total += rails_in_dim * d.ocs_per_rail
        return total

    def validate(self, mcm: MCMArch, hw: HW = DEFAULT_HW,
                 n_mcm_expected: Optional[int] = None) -> List[str]:
        errs = []
        if n_mcm_expected is not None and self.n_mcm() != n_mcm_expected:
            errs.append(f"prod(N_i)={self.n_mcm()} != N={n_mcm_expected}")
        if self.total_links_used() > mcm.total_links:
            errs.append(f"sum(R_i)={self.total_links_used()} > "
                        f"L={mcm.total_links}")
        for i, d in enumerate(self.dims):
            if not d.port_ok(hw.ocs_ports):
                errs.append(f"dim{i}: k*N={d.k * d.n} > P={hw.ocs_ports}")
            if d.r < 1 or d.n < 2:
                errs.append(f"dim{i}: degenerate ({d.n},{d.r})")
        return errs


# ---------------------------------------------------------------------------
# Link allocation (paper §IV-B step 3 + Eq. 1)
# ---------------------------------------------------------------------------
def allocate_links(volumes: Dict[str, float], total_links: int,
                   reuse_pair: Optional[Tuple[str, str]] = None
                   ) -> Dict[str, int]:
    """l_p = floor(L * v_p / sum(v)); with dynamic reuse, the pair shares
    l_reuse = floor(L * max(v,v') / (sum(v_others) + max(v,v'))) links.
    Every parallelism with traffic gets at least one link."""
    inter = {p: v for p, v in volumes.items() if v > 0}
    if not inter:
        return {}
    alloc: Dict[str, int] = {}
    if reuse_pair is not None:
        a, b = reuse_pair
        if a in inter and b in inter:
            vmax = max(inter[a], inter[b])
            others = {p: v for p, v in inter.items() if p not in (a, b)}
            denom = sum(others.values()) + vmax
            l_reuse = int(total_links * vmax / denom)
            l_reuse = max(l_reuse, 1)
            rest = total_links - l_reuse
            ssum = sum(others.values())
            for p, v in others.items():
                alloc[p] = max(int(rest * v / ssum), 1) if ssum else 0
            alloc[a] = l_reuse
            alloc[b] = l_reuse      # same physical links, reused in time
            # trim rounding/min-1 overshoot — the pair occupies its links
            # ONCE; charge them to whichever member came first in ``inter``
            first = a if list(inter).index(a) < list(inter).index(b) else b
            usage = {p: (alloc[p] if p not in (a, b) else
                         (alloc[p] if p == first else 0)) for p in inter}
            while sum(usage.values()) > total_links \
                    and max(usage.values()) > 1:
                big = max(usage, key=usage.get)
                usage[big] -= 1
                alloc[big] -= 1
                if big == first:
                    alloc[a] = alloc[b] = alloc[big]
            return alloc
    ssum = sum(inter.values())
    for p, v in inter.items():
        alloc[p] = max(int(total_links * v / ssum), 1)
    # trim if rounding/min-1 overshot the budget
    while sum(alloc.values()) > total_links and max(alloc.values()) > 1:
        big = max(alloc, key=alloc.get)
        alloc[big] -= 1
    return alloc


# ---------------------------------------------------------------------------
# Physical-topology derivation (paper §IV-B step 4)
# ---------------------------------------------------------------------------
def _partitions(items: Sequence[str], max_parts: int):
    """All ways to group ``items`` into <= max_parts unordered groups."""
    items = list(items)
    if not items:
        yield []
        return
    first, rest = items[0], items[1:]
    for part in _partitions(rest, max_parts):
        # own group
        if len(part) < max_parts:
            yield [[first]] + part
        # join an existing group
        for i in range(len(part)):
            yield part[:i] + [[first] + part[i]] + part[i + 1:]


def derive_physical(groups_degrees: Dict[str, int],
                    link_alloc: Dict[str, int],
                    mcm: MCMArch,
                    n_mcm: int,
                    hw: HW = DEFAULT_HW,
                    reuse_pair: Optional[Tuple[str, str]] = None
                    ) -> Optional[OITopology]:
    """Enumerate parallelism->rail-dimension assignments (<=4 dims), keep
    feasible ones, return the topology with the fewest OCSs.

    groups_degrees: inter-MCM parallelism degrees (prod == n_mcm).
    If reuse_pair is set, those two parallelisms MUST share one dim.
    """
    ps = [p for p, d in groups_degrees.items() if d > 1]
    if not ps:
        return OITopology(dims=(), mapping=(), link_alloc=link_alloc,
                          reuse_pair=None)
    best: Optional[OITopology] = None
    for part in _partitions(ps, 4):
        if reuse_pair is not None:
            a, b = reuse_pair
            together = any(a in g and b in g for g in part)
            apart = any((a in g) != (b in g) and (a in g or b in g)
                        for g in part)
            if (a in ps and b in ps) and (not together or apart):
                continue
        dims = []
        ok = True
        for g in part:
            n_i = 1
            for p in g:
                n_i *= groups_degrees[p]
            if reuse_pair and all(q in g for q in reuse_pair):
                r_i = link_alloc.get(reuse_pair[0], 1)
                extra = [link_alloc.get(p, 0) for p in g
                         if p not in reuse_pair]
                r_i += sum(extra)
            else:
                r_i = sum(link_alloc.get(p, 0) for p in g)
            r_i = max(r_i, 1)
            # pick k_i: smallest k satisfying the port bound
            k_i = max(1, math.ceil(n_i / hw.ocs_ports))
            if k_i > r_i:
                ok = False
                break
            dims.append(RailDim(n=n_i, r=r_i, k=k_i))
        if not ok:
            continue
        topo = OITopology(dims=tuple(dims),
                          mapping=tuple(tuple(g) for g in part),
                          link_alloc=dict(link_alloc),
                          reuse_pair=reuse_pair)
        errs = topo.validate(mcm, hw, n_mcm_expected=n_mcm)
        if errs:
            continue
        if best is None or topo.ocs_count() < best.ocs_count():
            best = topo
    return best


# ---------------------------------------------------------------------------
# Memoized / batched derivation front-end (the refinement hot path)
# ---------------------------------------------------------------------------
# The partition enumeration above only reads (degrees, alloc, reuse_pair)
# plus mcm.total_links, n_mcm and hw.ocs_ports — nothing else of the MCM
# or HW.  DSE refinement re-derives the same handful of configurations
# over and over (top-K winners cluster on a few strategy shapes), so a
# content-keyed memo turns derivation into a dict hit.  Dict key order
# matters: the fewest-OCS tie-break follows partition enumeration order,
# which follows ``groups_degrees`` insertion order — keys preserve it.
_DERIVE_CACHE: Dict[tuple, Optional[OITopology]] = {}
_DERIVE_CACHE_MAX = 65536


def derive_physical_cached(groups_degrees: Dict[str, int],
                           link_alloc: Dict[str, int],
                           mcm: MCMArch,
                           n_mcm: int,
                           hw: HW = DEFAULT_HW,
                           reuse_pair: Optional[Tuple[str, str]] = None
                           ) -> Optional[OITopology]:
    """``derive_physical`` behind a content-keyed memo (identical
    results; OITopology is frozen, so sharing instances is safe)."""
    key = (tuple(groups_degrees.items()), tuple(link_alloc.items()),
           reuse_pair, mcm.total_links, n_mcm, hw.ocs_ports)
    try:
        return _DERIVE_CACHE[key]
    except KeyError:
        pass
    topo = derive_physical(groups_degrees, link_alloc, mcm, n_mcm, hw,
                           reuse_pair=reuse_pair)
    if len(_DERIVE_CACHE) >= _DERIVE_CACHE_MAX:
        _DERIVE_CACHE.clear()
    _DERIVE_CACHE[key] = topo
    return topo


def derive_physical_batch(rows: Sequence[Tuple[Dict[str, int],
                                               Dict[str, int],
                                               Optional[Tuple[str, str]]]],
                          mcms: Sequence[MCMArch],
                          hw: HW = DEFAULT_HW) -> List[Optional[OITopology]]:
    """Derive one topology per (degrees, alloc, reuse_pair) row; row i
    uses ``mcms[i]``.  The memo collapses duplicate configurations, so a
    top-K refinement batch costs one real derivation per unique shape."""
    return [derive_physical_cached(deg, alloc, mcm, mcm.n_mcm, hw,
                                   reuse_pair=rp)
            for (deg, alloc, rp), mcm in zip(rows, mcms)]
