"""Hardware constants for the ChipLight cluster model.

Sources: the paper §V-A — logic die parameters from H100 [34], memory die
HBM3 [35], chiplet D2D from [8] (658 GB/s/mm @ 0.29 pJ/b), CPO from
[12],[32] (128 GB/s/mm, 400 GB/s links), MEMS OCS as in TPUv4 [13],
cost structure per Chiplet Actuary [36] / RailX [20].  Where the paper is
silent we document our assumption inline.

The TPU-v5e constants at the bottom are for the JAX dry-run roofline only
(the assignment's target runtime), NOT for the paper-faithful experiments.
"""
from __future__ import annotations

from dataclasses import dataclass, fields, replace


@dataclass(frozen=True)
class HW:
    # ---- logic die (H100-class) ----
    die_tflops: float = 989.0          # BF16 dense TFLOPs per H100-class die
    die_area_mm2: float = 814.0
    die_edge_mm: float = 28.5          # ~sqrt(area), square-die assumption
    sram_bytes: float = 50e6

    # ---- memory die (HBM3 stack) ----
    hbm_bw_per_die: float = 0.55e12    # B/s  (6 stacks ~ 3.3 TB/s on H100)
    hbm_cap_per_die: float = 16e9      # bytes (6 x 16 GB = 96 GB class)
    hbm_phy_mm: float = 9.0            # die-edge length consumed per stack
    h100_hbm_dies: int = 6

    # ---- electrical interconnect ----
    nvlink_bw: float = 900e9           # B/s per GPU (paper Fig 1)
    nvlink_domain: int = 8             # GPUs per NVLink scale-up node
    ib_bw: float = 60e9                # B/s per device (paper)

    # ---- chiplet D2D / NoP ----
    d2d_gbps_per_mm: float = 658e9     # B/s per mm of die edge [8]
    d2d_energy_pj_b: float = 0.29

    # ---- optics ----
    cpo_gbps_per_mm: float = 128e9     # B/s per mm of die edge [12],[32]
    oi_link_bw: float = 400e9          # B/s per optical link (paper §III-A)
    ocs_ports: int = 136               # MEMS OCS radix (Google Palomar)
    ocs_switch_latency_s: float = 10e-3   # ms-scale MEMS reconfiguration
    # Dynamic-link-reuse switching model:
    #  'banked' — links flip between the CP/EP configurations only when a
    #             bank-swap schedule gives them >= T_switch of slack
    #             (our physical model; with 10 ms MEMS this DISABLES reuse
    #             at large scale — a quantified limitation of the paper's
    #             assumption, see EXPERIMENTS.md §Fig8),
    #  'paper'  — reconfiguration is hidden inside compute gaps, as the
    #             paper asserts ('switching latency smaller than the
    #             traffic interval ... satisfied in practice').
    ocs_reuse_mode: str = "banked"
    ocs_cost_per_port: float = 300.0   # $ (TopoOpt/RailX-class estimate)
    fiber_cost_per_link: float = 50.0

    # ---- silicon cost model (Chiplet Actuary-style) ----
    wafer_cost: float = 17000.0        # $ per 300 mm wafer, 4 nm class
    wafer_diameter_mm: float = 300.0
    defect_density_per_cm2: float = 0.1
    yield_alpha: float = 6.0           # clustering parameter
    hbm_die_cost: float = 150.0        # $ per stack
    pkg_cost_per_mm2: float = 0.03     # $ interposer+substrate per mm^2
    pkg_base_cost: float = 80.0
    cpo_cost_per_link: float = 120.0   # $ per 400G optical port (CPO side)
    nic_cost_ib: float = 1500.0        # $ per device (IB NIC+cabling)

    # ---- modelled efficiencies ----
    mfu_ceiling: float = 0.55          # achievable fraction of peak FLOPs
    # per-hop collective launch/propagation latency, charged PER INVOCATION
    # (layer x microbatch), by fabric class:
    lat_intra_s: float = 0.7e-6        # NoP / NVLink hop
    lat_oi_s: float = 1.2e-6           # OCS circuit (fiber + serdes)
    lat_ib_s: float = 3.0e-6           # IB switch traversal
    # GEMM shape efficiency: utilisation ~ M/(M+gemm_m_half) in the token
    # (M) dim and analogous in the TP-sharded width (N) dim — models MXU /
    # tensor-core underutilisation when parallelism slices matmuls thin.
    # OFF by default: the paper's ASTRA-sim methodology charges compute at
    # a constant-MFU roofline; enabling this is our beyond-paper realism
    # ablation (see EXPERIMENTS.md).
    model_gemm_eff: bool = False
    gemm_m_half: float = 128.0
    gemm_n_half: float = 128.0
    # achieved fraction of line rate per fabric class: packet-switched
    # electrical clos suffers protocol + ECMP-collision losses; OCS
    # circuits are contention-free (a core ChipLight/TPUv4 argument).
    fabric_eff_elec: float = 0.65
    fabric_eff_oi: float = 0.9
    # Collective exposure follows the paper's ASTRA-sim methodology where
    # comm phases serialise with compute inside a layer; only partial
    # overlap is credited (bucketed DP AR in bwd, ring-attention CP).
    dp_overlap_frac: float = 0.5       # DP AR overlappable with bwd compute
    cp_overlap_frac: float = 0.5       # ring-attention overlap

    @classmethod
    def calibrated(cls, calib: dict, base: "HW" = None) -> "HW":
        """An ``HW`` running on the MEASURED constants of a CALIB.json
        artifact (``repro.calib``): the artifact's ``effective`` block
        overrides the matching fields of ``base`` (default constants
        when omitted).  The fitted peaks are ACHIEVED asymptotes, so
        the block ships ``mfu_ceiling=1.0`` and turns the fitted
        ``M/(M+half)`` shape curve on (``model_gemm_eff=True``)."""
        eff = calib.get("effective")
        if not isinstance(eff, dict) or not eff:
            raise ValueError("calibration artifact has no 'effective' "
                             "block — re-run `cli calibrate`")
        known = {f.name for f in fields(cls)}
        bad = sorted(set(eff) - known)
        if bad:
            raise ValueError(f"calibration 'effective' block has "
                             f"unknown HW fields {bad}")
        return replace(base if base is not None else cls(), **eff)

    def die_cost(self, area_mm2: float) -> float:
        """Yield-adjusted cost of one logic die of the given area."""
        import math
        r = self.wafer_diameter_mm / 2.0
        dies = (math.pi * r * r / area_mm2
                - math.pi * 2.0 * r / math.sqrt(2.0 * area_mm2))
        d0a = self.defect_density_per_cm2 * (area_mm2 / 100.0)
        y = (1.0 + d0a / self.yield_alpha) ** (-self.yield_alpha)
        return self.wafer_cost / max(dies, 1.0) / max(y, 1e-6)


DEFAULT_HW = HW()


def scaled_die(hw: HW, scale: float) -> HW:
    """A logic die scaled to ``scale`` x the H100 compute (area ∝ compute).

    Edge scales with sqrt(area); per-die HBM attach capability unchanged.
    Used by the Fig 9(b) single-die-scale exploration.
    """
    import math
    return replace(hw,
                   die_tflops=hw.die_tflops * scale,
                   die_area_mm2=hw.die_area_mm2 * scale,
                   die_edge_mm=hw.die_edge_mm * math.sqrt(scale))


# --- TPU v5e constants (assignment roofline; NOT the paper's hardware) ---
TPU_V5E_FLOPS = 197e12        # bf16 FLOP/s per chip
TPU_V5E_HBM_BW = 819e9        # B/s
TPU_V5E_ICI_BW = 50e9         # B/s per link
TPU_V5E_HBM_GB = 16.0
