"""Cluster cost model (Chiplet Actuary [36] / RailX [20] style).

Components: yield-adjusted logic silicon, HBM stacks, advanced packaging,
CPO optical ports, OCS switches (per port), fibers, or IB NICs for the
electrical baselines.  Absolute dollars are estimates; all paper
experiments compare *relative* cost, which these constants preserve.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.core.hardware import HW, DEFAULT_HW
from repro.core.mcm import MCMArch
from repro.core.network import OITopology


@dataclass(frozen=True)
class CostBreakdown:
    silicon: float
    hbm: float
    packaging: float
    cpo: float
    ocs: float
    fiber: float
    nic: float

    @property
    def total(self) -> float:
        return (self.silicon + self.hbm + self.packaging + self.cpo
                + self.ocs + self.fiber + self.nic)


def cluster_cost(mcm: MCMArch, topo: Optional[OITopology] = None,
                 fabric: str = "oi", hw: Optional[HW] = None
                 ) -> CostBreakdown:
    hw = hw or mcm.hw
    n_dev = mcm.n_devices
    silicon = n_dev * hw.die_cost(mcm.hw.die_area_mm2)
    hbm = n_dev * mcm.m * hw.hbm_die_cost

    # packaging: interposer area ~ dies + HBM + CPO shoreline (x1.6 overhead)
    die_area = mcm.hw.die_area_mm2
    hbm_area = 110.0  # mm^2 per stack
    pkg_area = 1.6 * (mcm.dies_per_mcm * die_area
                      + mcm.dies_per_mcm * mcm.m * hbm_area)
    packaging = mcm.n_mcm * (hw.pkg_base_cost
                             + hw.pkg_cost_per_mm2 * pkg_area)

    cpo = ocs = fiber = nic = 0.0
    if fabric == "oi":
        links = mcm.n_mcm * mcm.total_links
        cpo = links * hw.cpo_cost_per_link
        fiber = links * hw.fiber_cost_per_link
        if topo is not None:
            ocs = topo.ocs_count() * hw.ocs_ports * hw.ocs_cost_per_port
    elif fabric == "ib":
        nic = n_dev * hw.nic_cost_ib
    elif fabric == "nvlink":
        # NVLink domain + IB scale-out, folded into per-device NIC+switch
        nic = n_dev * (hw.nic_cost_ib + 500.0)
    return CostBreakdown(silicon=silicon, hbm=hbm, packaging=packaging,
                         cpo=cpo, ocs=ocs, fiber=fiber, nic=nic)
