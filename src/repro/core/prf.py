"""Probabilistic-random-forest-lite surrogate (paper's black-box sampler,
PRF [33]) — a small bagged regression forest in pure numpy.

Used by the inner (para-topo) search when the strategy space is too large
to enumerate: fit on evaluated (features -> throughput) points, then rank
unevaluated candidates by UCB = mean + kappa * std across trees.
"""
from __future__ import annotations

import numpy as np


class _Tree:
    __slots__ = ("feat", "thresh", "left", "right", "value")

    def __init__(self):
        self.feat = -1
        self.value = 0.0
        self.left = self.right = None
        self.thresh = 0.0


def _build(x, y, rng, depth, max_depth, min_leaf, n_feat_try):
    node = _Tree()
    node.value = float(y.mean()) if len(y) else 0.0
    if depth >= max_depth or len(y) < 2 * min_leaf or np.ptp(y) < 1e-12:
        return node
    feats = rng.choice(x.shape[1], size=min(n_feat_try, x.shape[1]),
                       replace=False)
    best = (None, None, np.inf)
    for f in feats:
        vals = np.unique(x[:, f])
        if len(vals) < 2:
            continue
        cuts = (vals[:-1] + vals[1:]) / 2.0
        if len(cuts) > 8:
            cuts = rng.choice(cuts, size=8, replace=False)
        for c in cuts:
            m = x[:, f] <= c
            nl, nr = m.sum(), (~m).sum()
            if nl < min_leaf or nr < min_leaf:
                continue
            sse = (np.var(y[m]) * nl + np.var(y[~m]) * nr)
            if sse < best[2]:
                best = (f, c, sse)
    if best[0] is None:
        return node
    f, c, _ = best
    m = x[:, f] <= c
    node.feat, node.thresh = int(f), float(c)
    node.left = _build(x[m], y[m], rng, depth + 1, max_depth, min_leaf,
                       n_feat_try)
    node.right = _build(x[~m], y[~m], rng, depth + 1, max_depth, min_leaf,
                        n_feat_try)
    return node


def _predict_one(node, row):
    while node.feat >= 0:
        node = node.left if row[node.feat] <= node.thresh else node.right
    return node.value


class PRF:
    def __init__(self, n_trees=24, max_depth=6, min_leaf=2, seed=0):
        self.n_trees = n_trees
        self.max_depth = max_depth
        self.min_leaf = min_leaf
        self.rng = np.random.default_rng(seed)
        self.trees = []

    def fit(self, x, y):
        x = np.asarray(x, float)
        y = np.asarray(y, float)
        n = len(y)
        n_feat_try = max(1, int(np.sqrt(x.shape[1])))
        self.trees = []
        for _ in range(self.n_trees):
            idx = self.rng.integers(0, n, size=n)
            self.trees.append(_build(x[idx], y[idx], self.rng, 0,
                                     self.max_depth, self.min_leaf,
                                     n_feat_try))
        return self

    def predict(self, x, return_std=False):
        x = np.asarray(x, float)
        preds = np.array([[_predict_one(t, row) for t in self.trees]
                          for row in x])
        mean = preds.mean(1)
        if return_std:
            return mean, preds.std(1)
        return mean

    def ucb(self, x, kappa=1.0):
        m, s = self.predict(x, return_std=True)
        return m + kappa * s
