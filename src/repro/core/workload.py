"""Training-workload description consumed by the ChipLight models.

Derived from the same ``ModelConfig`` the JAX model zoo executes — the
analytic traffic model and the compiled dry-run HLO therefore describe the
*same* workload (cross-validated in tests/test_traffic_vs_hlo.py).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class Workload:
    model: ModelConfig
    seq_len: int
    global_batch: int          # sequences per step
    bytes_act: int = 2         # bf16 activations
    bytes_grad: int = 4        # fp32 gradient all-reduce (Megatron default)
    bytes_param: int = 2

    @property
    def tokens_per_step(self) -> int:
        return self.seq_len * self.global_batch

    # ------------------------------------------------------------------
    @property
    def n_layers(self) -> int:
        return self.model.n_layers

    @property
    def d_model(self) -> int:
        return self.model.d_model

    @property
    def kv_bytes_per_token(self) -> int:
        a = self.model.attn
        if a is None:
            return 0
        return 2 * a.n_kv_heads * a.head_dim * self.bytes_act

    @property
    def n_attn_layers(self) -> int:
        m = self.model
        if m.attn is None:
            return 0
        if m.family == "hybrid" and m.hybrid_period:
            return m.n_layers // m.hybrid_period
        if m.family == "encdec":
            return m.n_layers + m.encoder_layers
        return m.n_layers

    @property
    def n_moe_layers(self) -> int:
        return self.model.n_layers if self.model.moe is not None else 0

    @property
    def total_params(self) -> int:
        return self.model.param_count()

    @property
    def active_params(self) -> int:
        return self.model.active_param_count()

    @property
    def expert_params(self) -> int:
        m = self.model.moe
        if m is None:
            return 0
        per_layer = m.n_experts * 3 * self.model.d_model * m.d_ff_expert
        return self.model.n_layers * per_layer

    @property
    def nonexpert_params(self) -> int:
        return self.total_params - self.expert_params

    def step_flops(self) -> float:
        """Total cluster FLOPs per training step (fwd+bwd ~ 3x fwd)."""
        return 3.0 * 2.0 * self.active_params * self.tokens_per_step \
            + 3.0 * self._attn_flops()

    def _attn_flops(self) -> float:
        a = self.model.attn
        if a is None:
            return 0.0
        s = self.seq_len
        eff = s
        if a.window:
            frac_local = 1.0
            if a.local_global_period:
                frac_local = ((a.local_global_period - 1)
                              / a.local_global_period)
            eff = frac_local * min(a.window, s) + (1 - frac_local) * s
        per_token = self.n_attn_layers * 4.0 * a.n_heads * a.head_dim \
            * (eff / 2.0)
        return per_token * self.tokens_per_step


# The paper's evaluation target (§V-A): Qwen3-235B-A22B, 10k context.
def paper_workload(global_batch: int = 512) -> Workload:
    from repro.configs import get_config
    return Workload(model=get_config("qwen3_moe_235b_a22b"),
                    seq_len=10240, global_batch=global_batch)
