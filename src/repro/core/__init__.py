# ChipLight core: the paper's contribution as a composable library.
# Traffic model (paper §III), MCM + OI-rail cluster model (§IV-A),
# cross-layer nested optimiser with dynamic link reuse (§IV-B).
from repro.core.hardware import HW, DEFAULT_HW  # noqa: F401
from repro.core.workload import Workload, paper_workload  # noqa: F401
from repro.core.traffic import Strategy, traffic_volumes, \
    traffic_matrix, reusable_pairs  # noqa: F401
from repro.core.mcm import MCMArch, mcm_from_compute  # noqa: F401
from repro.core.network import RailDim, OITopology, allocate_links, \
    derive_physical  # noqa: F401
from repro.core.cost import cluster_cost, CostBreakdown  # noqa: F401
from repro.core.simulator import simulate, SimResult, map_intra  # noqa: F401
from repro.core.optimizer import (  # noqa: F401
    chiplight_optimize, inner_search, railx_search, evaluate_point,
    enumerate_strategies, pareto_front, DesignPoint, DSEResult)
