"""Per-parallelism training-traffic model (paper §III, Fig 3/4).

Volumes are BYTES PER DEVICE PER TRAINING STEP under ring collectives,
matching the paper's ASTRA-sim profiling setup (ring algorithm, hybrid
TP/DP/PP/CP/EP).  The spatial matrix (Fig 4) and the temporal phase tags
(§III-B, link-reuse feasibility) derive from the same projection — the
traffic projection is *independent of the underlying network*, which is
what enables the paper's parallel-centric inner search.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np

from repro.core.workload import Workload

PARALLELISMS = ("TP", "DP", "PP", "CP", "EP")

# temporal phase in which each parallelism communicates (§III-B):
#   CP traffic happens inside attention, EP inside the FFN/expert block,
#   TP throughout the layer, DP at step boundary (bwd), PP at stage edges.
PHASE = {"TP": "layer", "CP": "attention", "EP": "ffn", "DP": "step",
         "PP": "stage"}


@dataclass(frozen=True)
class Strategy:
    tp: int = 1
    dp: int = 1
    pp: int = 1
    cp: int = 1
    ep: int = 1
    n_micro: int = 8

    @property
    def n_devices(self) -> int:
        return self.tp * self.dp * self.pp * self.cp * self.ep

    def degree(self, p: str) -> int:
        return {"TP": self.tp, "DP": self.dp, "PP": self.pp,
                "CP": self.cp, "EP": self.ep}[p]

    def asdict(self):
        return {"TP": self.tp, "DP": self.dp, "PP": self.pp,
                "CP": self.cp, "EP": self.ep}


def traffic_volumes(w: Workload, s: Strategy) -> Dict[str, float]:
    """Bytes per device per step for each parallelism (ring collectives)."""
    v = {p: 0.0 for p in PARALLELISMS}
    layers_per_stage = max(w.n_layers // s.pp, 1)
    attn_per_stage = max(w.n_attn_layers // s.pp, 1) \
        if w.n_attn_layers else 0
    moe_per_stage = max(w.n_moe_layers // s.pp, 1) if w.n_moe_layers else 0
    # tokens a device's stage processes per step
    t_stage = w.tokens_per_step / (s.dp * s.cp)
    act = t_stage * w.d_model * w.bytes_act

    # --- TP: Megatron w/ sequence-parallel: 4 AG + 4 RS per layer (f+b);
    # ring AG/RS of a tensor of ``act`` bytes moves act*(t-1)/t per device.
    if s.tp > 1:
        v["TP"] = 8.0 * layers_per_stage * act * (s.tp - 1) / s.tp

    # --- CP: ring attention; K and V shards circulate (c-1) hops (f),
    # gradient ring mirrors it in bwd (x2).  KV heads shard at most
    # n_kv_heads ways under TP (GQA: beyond that KV is replicated), so the
    # per-device share divides by min(tp, n_kv_heads).
    if s.cp > 1 and attn_per_stage:
        kv_shard = min(s.tp, w.model.attn.n_kv_heads) if w.model.attn \
            else s.tp
        kv = t_stage * w.kv_bytes_per_token / kv_shard
        v["CP"] = 2.0 * attn_per_stage * (s.cp - 1) * kv

    # --- EP: A2A dispatch+combine (x2), fwd+bwd (x2); activations enter
    # the MoE block sequence-parallel over TP (1/tp share per device).
    if s.ep > 1 and moe_per_stage:
        topk = w.model.moe.top_k
        v["EP"] = (4.0 * moe_per_stage * (t_stage / s.tp) * topk
                   * w.d_model * w.bytes_act * (s.ep - 1) / s.ep)

    # --- DP: ring all-reduce of local gradients = 2*(d-1)/d * local params.
    if s.dp > 1:
        local = (w.nonexpert_params / (s.tp * s.pp)
                 + w.expert_params / (s.tp * s.pp * s.ep))
        v["DP"] = 2.0 * local * w.bytes_grad * (s.dp - 1) / s.dp

    # --- PP: activations fwd + grads bwd across each stage boundary
    # (sequence-parallel shards under TP).
    if s.pp > 1:
        v["PP"] = 2.0 * (t_stage / s.tp) * w.d_model * w.bytes_act

    return v


# ---------------------------------------------------------------------------
# Spatial distribution (Fig 4)
# ---------------------------------------------------------------------------
def device_coords(s: Strategy, order=("TP", "CP", "EP", "PP", "DP")):
    """Device id <-> parallel-group coordinates, TP fastest by default."""
    dims = [s.degree(p) for p in order]
    return order, dims


def coords_matrix(s: Strategy, order=("TP", "CP", "EP", "PP", "DP")):
    """(order, dims, strides, (n, len(order)) coordinate matrix) — the
    device-id <-> group-coordinate bijection, fully vectorized."""
    order, dims = device_coords(s, order)
    n = s.n_devices
    strides = np.cumprod([1] + dims[:-1]).astype(np.int64)
    ids = np.arange(n, dtype=np.int64)
    coords = (ids[:, None] // strides[None, :]) % np.asarray(dims, np.int64)
    return order, dims, strides, coords


def traffic_matrix(w: Workload, s: Strategy,
                   order=("TP", "CP", "EP", "PP", "DP"),
                   ep_fc: bool = False) -> np.ndarray:
    """(n, n) bytes sent src->dst per step; ring neighbours only (Fig 4).

    ep_fc: model EP A2A as fully-connected (uniform to all peers) instead
    of a ring — the paper's FC option for EP.

    Fully vectorized: destination ids come from index arithmetic on the
    coordinate matrix (``dst = src + (next - cur) * stride``), one
    ``np.add.at`` scatter per parallelism — no per-device Python.  The
    original nested-loop construction is kept as
    ``_traffic_matrix_loop`` (parity-tested reference).
    """
    n = s.n_devices
    vols = traffic_volumes(w, s)
    mat = np.zeros((n, n))
    order, dims, strides, coords = coords_matrix(s, order)
    src = np.arange(n, dtype=np.int64)

    for pi, p in enumerate(order):
        deg = dims[pi]
        if deg <= 1 or vols[p] == 0.0:
            continue
        cur = coords[:, pi]
        if p == "EP" and ep_fc:
            # uniform A2A: each device sends v/(deg-1) to each peer —
            # dst ids for ALL (src, peer) pairs in one (n, deg) array
            peers = np.arange(deg, dtype=np.int64)
            dst = src[:, None] + (peers[None, :] - cur[:, None]) \
                * strides[pi]
            keep = peers[None, :] != cur[:, None]
            np.add.at(mat, (np.broadcast_to(src[:, None], dst.shape)[keep],
                            dst[keep]), vols[p] / (deg - 1))
            continue
        # ring: all traffic to the next neighbour in the group
        dst = src + (((cur + 1) % deg) - cur) * strides[pi]
        np.add.at(mat, (src, dst), vols[p])
    return mat


def _traffic_matrix_loop(w: Workload, s: Strategy,
                         order=("TP", "CP", "EP", "PP", "DP"),
                         ep_fc: bool = False) -> np.ndarray:
    """Reference nested-loop construction of ``traffic_matrix`` (the
    pre-vectorization implementation) — kept for parity tests only."""
    n = s.n_devices
    vols = traffic_volumes(w, s)
    mat = np.zeros((n, n))
    order, dims = device_coords(s, order)
    strides = np.cumprod([1] + dims[:-1])
    coords = np.zeros((n, len(dims)), dtype=np.int64)
    rem = np.arange(n)
    for i, (d, st) in enumerate(zip(dims, strides)):
        coords[:, i] = (rem // st) % d

    for pi, p in enumerate(order):
        deg = dims[pi]
        if deg <= 1 or vols[p] == 0.0:
            continue
        if p == "EP" and ep_fc:
            per_peer = vols[p] / (deg - 1)
            for src in range(n):
                base = coords[src].copy()
                for t in range(deg):
                    if t == coords[src, pi]:
                        continue
                    dst_c = base.copy()
                    dst_c[pi] = t
                    dst = int(np.dot(dst_c, strides))
                    mat[src, dst] += per_peer
            continue
        for src in range(n):
            dst_c = coords[src].copy()
            dst_c[pi] = (dst_c[pi] + 1) % deg
            dst = int(np.dot(dst_c, strides))
            mat[src, dst] += vols[p]
    return mat


# ---------------------------------------------------------------------------
# Temporal phases (§III-B) — who can share links with whom
# ---------------------------------------------------------------------------
def reusable_pairs(w: Workload, s: Strategy):
    """Parallelism pairs whose traffic is temporally disjoint.

    The paper's primary pair is (CP, EP): CP communicates during attention,
    EP during the expert FFN, separated by output-proj / layernorm compute.
    Reuse also exists among CP/DP/PP (paper notes it but deems CP-EP most
    beneficial).  Pairs are returned most-beneficial-first.
    """
    vols = traffic_volumes(w, s)
    cand = []
    for a, b in (("CP", "EP"), ("CP", "DP"), ("EP", "DP"), ("PP", "DP")):
        if vols[a] > 0 and vols[b] > 0 and PHASE[a] != PHASE[b]:
            cand.append(((a, b), min(vols[a], vols[b])))
    cand.sort(key=lambda kv: -kv[1])
    return [p for p, _ in cand]
