"""ChipLight cross-layer optimisation (paper §IV-B, Fig 6).

Nested flow:
  * inner search — PARALLEL-CENTRIC para-topo co-exploration: scan the
    ENTIRE strategy grid with the vectorized batched simulator
    (repro.dse), then give the top-throughput candidates the full
    scalar treatment — project traffic (network-independent), map TP
    (+ maybe one more group) intra-MCM, allocate links
    traffic-proportionally (Eq. l_p), apply dynamic link reuse (Eq. 1),
    derive the fewest-OCS physical topology, evaluate with the
    simulator.  (Surrogate sampling now lives in
    repro.dse.search.search_prf_ucb for budgeted sweeps.)
  * outer search — heuristic planner (§IV-B-3) reads simulator logs
    (compute util, memory pressure, comm bottleneck) and moves the MCM
    architecture (N, x, y, m, r) to break the bottleneck or trim waste.

Outputs a performance-cost Pareto frontier over (MCM arch, topology,
strategy) plus the best point.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.cost import cluster_cost
from repro.core.hardware import HW, DEFAULT_HW
from repro.core.mcm import MCMArch
from repro.core.network import OITopology, RailDim, allocate_links, \
    derive_physical_cached
from repro.core.simulator import SimResult, map_intra, simulate
from repro.core.traffic import Strategy, traffic_volumes, reusable_pairs
from repro.core.workload import Workload


# ---------------------------------------------------------------------------
# Strategy enumeration
# ---------------------------------------------------------------------------
def _divisors(n: int) -> List[int]:
    out = [d for d in range(1, int(math.isqrt(n)) + 1) if n % d == 0]
    return sorted(set(out + [n // d for d in out]))


def enumerate_strategies(w: Workload, mcm: MCMArch,
                         max_pp: int = 32,
                         min_layers_per_stage: int = 4) -> List[Strategy]:
    n = mcm.n_devices
    dies = mcm.dies_per_mcm
    moe = w.model.moe
    out = []
    tps = [t for t in _divisors(dies) if w.d_model % t == 0]
    for tp in tps:
        rest1 = n // tp
        # pipeline-stage granularity: embedding/head stages + interleaving
        # overhead make <4 layers per stage impractical
        pps = [p for p in _divisors(rest1)
               if p <= min(max_pp, w.n_layers // min_layers_per_stage)
               or p == 1]
        for pp in pps:
            rest2 = rest1 // pp
            if moe is not None:
                eps = [e for e in _divisors(rest2)
                       if moe.n_experts % e == 0]
            else:
                eps = [1]
            for ep in eps:
                rest3 = rest2 // ep
                cps = [c for c in _divisors(rest3)
                       if c <= 64 and w.seq_len % c == 0 and
                       (c == 1 or w.n_attn_layers > 0)]
                for cp in cps:
                    dp = rest3 // cp
                    if dp > 1 and w.global_batch % dp != 0:
                        continue
                    if pp > 1:
                        n_micro = min(4 * pp,
                                      max(w.global_batch // max(dp, 1), 1))
                        if n_micro < pp:
                            continue
                    else:
                        n_micro = 1
                    s = Strategy(tp=tp, dp=dp, pp=pp, cp=cp, ep=ep,
                                 n_micro=n_micro)
                    if map_intra(w, s, mcm) is not None:
                        out.append(s)
    return out


# ---------------------------------------------------------------------------
# Para-topo evaluation (one design point of the inner search)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class DesignPoint:
    strategy: Strategy
    mcm: MCMArch
    topo: Optional[OITopology]
    sim: SimResult
    cost: float
    fabric: str = "oi"

    @property
    def throughput(self) -> float:
        return self.sim.throughput


def evaluate_point(w: Workload, s: Strategy, mcm: MCMArch,
                   fabric: str = "oi", reuse: bool = True,
                   hw: Optional[HW] = None) -> Optional[DesignPoint]:
    hw = hw or mcm.hw
    mapping = map_intra(w, s, mcm)
    if mapping is None:
        return None
    intra, inter = mapping
    topo = None
    if fabric == "oi":
        vols = traffic_volumes(w, s)
        inter_vols = {p: vols[p] for p, d in inter.items()
                      if d > 1 and vols[p] > 0}
        reuse_pair = None
        if reuse:
            pairs = [pr for pr in reusable_pairs(w, s)
                     if pr[0] in inter_vols and pr[1] in inter_vols]
            reuse_pair = pairs[0] if pairs else None
        alloc = allocate_links(inter_vols, mcm.total_links, reuse_pair)
        inter_deg = {p: d for p, d in inter.items() if d > 1}
        topo = derive_physical_cached(inter_deg, alloc, mcm, mcm.n_mcm, hw,
                                      reuse_pair=reuse_pair)
        if topo is None and reuse_pair is not None:
            alloc = allocate_links(inter_vols, mcm.total_links, None)
            topo = derive_physical_cached(inter_deg, alloc, mcm, mcm.n_mcm,
                                          hw, reuse_pair=None)
        if topo is None and inter_deg:
            return None
    sim = simulate(w, s, mcm, fabric=fabric, topo=topo, reuse=reuse, hw=hw)
    if not sim.feasible:
        return None
    cost = cluster_cost(mcm, topo, fabric=fabric, hw=hw).total
    return DesignPoint(strategy=s, mcm=mcm, topo=topo, sim=sim, cost=cost,
                       fabric=fabric)


# ---------------------------------------------------------------------------
# Inner search
# ---------------------------------------------------------------------------
def inner_search(w: Workload, mcm: MCMArch, fabric: str = "oi",
                 reuse: bool = True, budget: int = 64,
                 hw: Optional[HW] = None, seed: int = 0,
                 method: str = "batched"
                 ) -> Tuple[Optional[DesignPoint], List[DesignPoint]]:
    """Parallel-centric para-topo search; returns (best, evaluated).

    The batched engine (repro.dse) scans the ENTIRE strategy grid in one
    vectorized call — no surrogate sampling needed at the strategy level
    — then the top candidates by batched throughput get the full scalar
    treatment (physical-topology derivation, exact OCS cost).  The scan
    is topology-blind, so a candidate can still fail physical-rail
    derivation; the ranking is walked (bounded at ``4 * budget``) until
    ``budget`` points survive, rather than returning nothing.

    ``method="batched"`` (default) gives the survivors the scalar
    treatment vectorized (``repro.dse.search.refine_cell_rows``: one
    batched call + memoized rail derivation for the whole walk window);
    ``method="scalar"`` is the original per-point ``evaluate_point``
    loop, kept as the parity reference.  ``seed`` is kept for API
    compatibility; both paths are deterministic.
    """
    del seed
    hw = hw or mcm.hw
    # lazy import: repro.dse depends on repro.core, not vice versa
    from repro.dse.batched_sim import batched_simulate
    from repro.dse.space import enumerate_strategy_batch

    batch = enumerate_strategy_batch(w, mcm)
    if not len(batch):
        return None, []
    res = batched_simulate(w, batch, mcm, fabric=fabric, reuse=reuse, hw=hw)
    feas = np.nonzero(res.feasible)[0]
    ranked = feas[np.argsort(-res.throughput[feas], kind="stable")]
    cand = ranked[: budget * 4]

    if method == "batched":
        from repro.dse.search import refine_cell_rows
        # two passes: most candidates survive rail derivation, so refine
        # one budget's worth first and top up only on a shortfall
        evaluated = refine_cell_rows(w, mcm, batch, cand[:budget],
                                     fabric=fabric, reuse=reuse, hw=hw)
        if len(evaluated) < budget and len(cand) > budget:
            evaluated += refine_cell_rows(w, mcm, batch, cand[budget:],
                                          fabric=fabric, reuse=reuse,
                                          hw=hw)
            evaluated = evaluated[:budget]
    elif method == "scalar":
        evaluated = []
        for i in cand:
            s = Strategy(tp=int(batch.tp[i]), dp=int(batch.dp[i]),
                         pp=int(batch.pp[i]), cp=int(batch.cp[i]),
                         ep=int(batch.ep[i]), n_micro=int(batch.n_micro[i]))
            pt = evaluate_point(w, s, mcm, fabric, reuse, hw)
            if pt is not None:
                evaluated.append(pt)
                if len(evaluated) >= budget:
                    break
    else:
        raise ValueError(f"unknown inner_search method {method!r}; "
                         f"use 'batched' or 'scalar'")
    best = max(evaluated, key=lambda p: p.throughput, default=None)
    return best, evaluated


# ---------------------------------------------------------------------------
# Outer search: heuristic planner over MCM architecture
# ---------------------------------------------------------------------------
def propose_moves(cur: MCMArch, logs: Optional[Dict[str, float]],
                  rng: np.random.Generator) -> List[MCMArch]:
    """Bottleneck-driven candidate moves (paper §IV-B-3), as a PURE move
    generator: reads the best point's simulator ``logs`` (None = the
    inner search found nothing feasible) and returns every architecture
    the heuristics propose.  Keeps C ~ constant by moving dies between
    packages when scale changes.  ``rng`` is consumed only by the
    last-resort random jitter move, in the same order the single-walker
    planner always used."""
    if logs is None:
        # infeasible inner search — most often memory capacity: raise m
        return [dataclasses.replace(cur, m=min(cur.m + 2, 16))]
    moves = []
    if logs.get("mem_pressure", 0) > 0.85 or logs.get("hbm_bw_bound"):
        moves.append(dataclasses.replace(cur, m=min(cur.m + 2, 16)))
    if logs.get("nop_bound"):
        if cur.m > 2:
            moves.append(dataclasses.replace(cur, m=cur.m - 1))
        if cur.dies_per_mcm > 4:
            moves.append(_rescale_dies(cur, cur.dies_per_mcm // 2))
    if logs.get("oi_bound"):
        if cur.cpo_ratio < 0.95:
            moves.append(dataclasses.replace(
                cur, cpo_ratio=min(cur.cpo_ratio + 0.1, 1.0)))
        moves.append(_rescale_dies(cur, cur.dies_per_mcm * 2))
    if not moves and logs.get("compute_util", 0) > 0.75:
        # healthy: trim over-provisioned resources to cut cost
        if cur.cpo_ratio > 0.3:
            moves.append(dataclasses.replace(
                cur, cpo_ratio=cur.cpo_ratio - 0.1))
        if cur.m > 4:
            moves.append(dataclasses.replace(cur, m=cur.m - 1))
    if not moves:
        moves.append(dataclasses.replace(
            cur, m=int(np.clip(cur.m + rng.integers(-2, 3), 1, 16))))
    return moves


def propose_mcm(cur: MCMArch, best: Optional[DesignPoint],
                rng: np.random.Generator) -> MCMArch:
    """Single-walker planner step: generate the bottleneck-driven moves
    and pick one uniformly (the pre-population behaviour, bit-for-bit:
    same rng consumption order)."""
    moves = propose_moves(cur, best.sim.logs if best is not None else None,
                          rng)
    if best is None:
        return moves[0]
    pick = moves[int(rng.integers(len(moves)))]
    return pick if pick.feasible() else cur


def _rescale_dies(cur: MCMArch, new_dies: int) -> MCMArch:
    """Move dies between packages at constant cluster compute.  A target
    die count that cannot tile ``n_devices`` exactly would silently
    shrink (or grow) the cluster — reject the move instead (the caller
    treats the unchanged architecture as a no-op candidate)."""
    total = cur.n_devices
    new_dies = max(1, new_dies)
    n_mcm = max(int(round(total / new_dies)), 1)
    if n_mcm * new_dies != total:
        return cur
    x = int(math.sqrt(new_dies))
    while new_dies % x:
        x -= 1
    return dataclasses.replace(cur, x=x, y=new_dies // x, n_mcm=n_mcm)


# ---------------------------------------------------------------------------
# Pareto utilities + full nested optimisation
# ---------------------------------------------------------------------------
def pareto_front(points: List[DesignPoint]) -> List[DesignPoint]:
    """Max throughput, min cost — cost-ascending, one representative per
    exact (cost, throughput) pair.  The dominance test is the ONE Pareto
    engine, ``repro.dse.pareto.pareto_mask`` (same semantics the batched
    sweeps use)."""
    if not points:
        return []
    from repro.dse.pareto import pareto_mask   # lazy: no cycle
    obj = np.array([[p.throughput, p.cost] for p in points], np.float64)
    idx = np.nonzero(pareto_mask(obj, [True, False]))[0]
    idx = sorted(idx, key=lambda i: (points[i].cost, -points[i].throughput))
    front, seen = [], set()
    for i in idx:
        key = (points[i].cost, points[i].throughput)
        if key not in seen:
            seen.add(key)
            front.append(points[i])
    return front


@dataclass
class DSEResult:
    best: Optional[DesignPoint]
    frontier: List[DesignPoint]
    history: List[DesignPoint] = field(default_factory=list)
    outer_trace: List[Dict] = field(default_factory=list)
    # engine bookkeeping (points simulated, cache hits, ...) — filled by
    # repro.dse.outer; empty for directly-assembled results
    stats: Dict = field(default_factory=dict)


def chiplight_optimize(w: Workload, total_tflops: float,
                       dies_per_mcm: int = 16, m0: int = 6,
                       outer_iters: int = 8, inner_budget: int = 48,
                       fabric: str = "oi", reuse: bool = True,
                       hw: HW = DEFAULT_HW, seed: int = 0,
                       cpo0: float = 0.6,
                       inner_method: str = "batched") -> DSEResult:
    """Nested outer/inner optimisation (paper §IV-B) — compatibility
    wrapper for the single-walker scalar flow, now hosted by
    ``repro.dse.outer.outer_search(walkers=1, method="scalar")``.

    One ``np.random.default_rng(seed)`` drives every ``propose_mcm``
    move (the inner scan is deterministic), so the whole run is
    reproducible from ``(w, total_tflops, ..., seed)`` alone.  The MCM
    proposed by the LAST planner move is evaluated too — ``outer_trace``
    has ``outer_iters + 1`` entries, one per inner search.
    """
    from repro.dse.outer import outer_search   # lazy: no cycle
    return outer_search(w, total_tflops, dies_per_mcm=dies_per_mcm,
                        m0=m0, rounds=outer_iters,
                        inner_budget=inner_budget, walkers=1,
                        fabric=fabric, reuse=reuse, hw=hw, seed=seed,
                        cpo0=cpo0, method="scalar",
                        inner_method=inner_method)


# ---------------------------------------------------------------------------
# RailX baseline (prior network design [20])
# ---------------------------------------------------------------------------
def railx_topology(mcm: MCMArch, inter_degrees: Dict[str, int],
                   inter_vols: Dict[str, float],
                   reuse_pair=None, hw: HW = DEFAULT_HW
                   ) -> Optional[OITopology]:
    """HammingMesh-like: exactly TWO rail dimensions with UNIFORM links.

    Parallelism groups are packed onto the two dims; links are split
    50/50 regardless of traffic — the contrast with ChipLight's
    traffic-proportional allocation.
    """
    ps = [p for p, d in inter_degrees.items() if d > 1]
    n = 1
    for p in ps:
        n *= inter_degrees[p]
    if n == 1:
        return OITopology(dims=(), mapping=(), link_alloc={})
    l_half = max(mcm.total_links // 2, 1)
    best = None
    for mask in range(1, 1 << len(ps)):
        g1 = [ps[i] for i in range(len(ps)) if mask & (1 << i)]
        g2 = [p for p in ps if p not in g1]
        n1 = 1
        for p in g1:
            n1 *= inter_degrees[p]
        n2 = n // n1
        if n1 < 2 and g1:
            continue
        if g2 and n2 < 2:
            continue
        dims, mapping = [], []
        for grp, ni in ((g1, n1), (g2, n2)):
            if not grp:
                continue
            k = max(1, math.ceil(ni / hw.ocs_ports))
            if k > l_half:
                continue
            dims.append(RailDim(n=ni, r=l_half, k=k))
            mapping.append(tuple(grp))
        if len(dims) != (2 if g2 else 1):
            continue
        # uniform split within a dim, reuse only if the pair landed together
        alloc = {}
        rp = None
        for grp, d in zip(mapping, dims):
            if (reuse_pair and all(q in grp for q in reuse_pair)):
                rp = reuse_pair
                vmax = max(inter_vols.get(q, 0.0) for q in reuse_pair)
                vols_grp = {p: inter_vols.get(p, 0.0) for p in grp}
                others = {p: v for p, v in vols_grp.items()
                          if p not in reuse_pair}
                denom = sum(others.values()) + vmax
                l_r = max(int(d.r * vmax / denom), 1) if denom else d.r
                for p in reuse_pair:
                    alloc[p] = l_r
                rest = d.r - l_r
                so = sum(others.values())
                for p, v in others.items():
                    alloc[p] = max(int(rest * v / so), 1) if so else 1
            else:
                vols_grp = {p: max(inter_vols.get(p, 0.0), 1.0)
                            for p in grp}
                sv = sum(vols_grp.values())
                for p, v in vols_grp.items():
                    alloc[p] = max(int(d.r * v / sv), 1)
        topo = OITopology(dims=tuple(dims), mapping=tuple(mapping),
                          link_alloc=alloc, reuse_pair=rp)
        errs = topo.validate(mcm, hw, n_mcm_expected=n)
        if errs:
            continue
        if best is None or topo.ocs_count() < best.ocs_count():
            best = topo
    return best


def railx_evaluate_point(w: Workload, s: Strategy, mcm: MCMArch,
                         reuse: bool = True, hw: HW = DEFAULT_HW
                         ) -> Optional[DesignPoint]:
    """One design point on the RailX network: derive the uniform two-dim
    rail topology and simulate with its link allocation (the railx
    analogue of ``evaluate_point``; also the refinement oracle for the
    batched railx sweep)."""
    mapping = map_intra(w, s, mcm)
    if mapping is None:
        return None
    intra, inter = mapping
    vols = traffic_volumes(w, s)
    inter_vols = {p: vols[p] for p, d in inter.items()
                  if d > 1 and vols[p] > 0}
    rp = None
    if reuse:
        prs = [pr for pr in reusable_pairs(w, s)
               if pr[0] in inter_vols and pr[1] in inter_vols]
        rp = prs[0] if prs else None
    inter_deg = {p: d for p, d in inter.items() if d > 1}
    topo = railx_topology(mcm, inter_deg, inter_vols, reuse_pair=rp, hw=hw)
    if topo is None and inter_deg:
        return None
    sim = simulate(w, s, mcm, fabric="oi", topo=topo, reuse=reuse, hw=hw)
    if not sim.feasible:
        return None
    cost = cluster_cost(mcm, topo, fabric="oi", hw=hw).total
    return DesignPoint(s, mcm, topo, sim, cost)


def railx_search(w: Workload, mcm: MCMArch, reuse: bool = True,
                 budget: int = 64, hw: HW = DEFAULT_HW, seed: int = 0
                 ) -> Tuple[Optional[DesignPoint], List[DesignPoint]]:
    """Best strategy on the RailX network (fair comparison: same budget).

    The scalar reference loop; the batched engine sweeps the same grids
    at array speed via ``sweep_design_space(alloc_mode="railx")``."""
    evaluated = []
    for s in enumerate_strategies(w, mcm)[: budget * 4]:
        pt = railx_evaluate_point(w, s, mcm, reuse=reuse, hw=hw)
        if pt is not None:
            evaluated.append(pt)
    best = max(evaluated, key=lambda p: p.throughput, default=None)
    return best, evaluated
