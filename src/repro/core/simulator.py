"""Analytic step-time simulator (ASTRA-sim replacement, see DESIGN.md).

Per training step we model:
  * compute — FLOPs / (peak * mfu_ceiling * gemm_shape_efficiency), where
    the shape efficiency term M/(M+c) * N/(N+c) captures MXU/tensor-core
    under-utilisation when parallelism slices matmuls thin (tiny per-device
    token counts or TP-sharded widths) — this is what actually stops
    "free" escapes like CP=64 x PP=32 at strong scaling;
  * memory — per-microbatch weight streaming (weights cannot be cached
    across microbatches) + activation traffic, against m * HBM_bw;
  * collectives — per-parallelism ring/A2A alpha-beta terms with
    PER-INVOCATION latency (layer x microbatch), fabric-dependent alpha;
    bandwidth capped by HBM/2 (paper insight 5: every relayed chunk is a
    read + write);
  * exposure — TP/EP serial, CP partially overlapped with attention,
    DP partially overlapped with backward, PP bubble (pp-1)/n_micro;
  * dynamic link reuse (Eq 1) with bank-swap OCS-switch amortisation.

Fabrics: ``nvlink`` (GPU baseline), ``ib`` (chiplet + electrical scale-out),
``oi`` (chiplet + OCS rails — RailX / ChipLight).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.core.hardware import HW
from repro.core.mcm import MCMArch
from repro.core.network import OITopology, allocate_links
from repro.core.traffic import PARALLELISMS, Strategy, traffic_volumes, \
    reusable_pairs
from repro.core.workload import Workload


@dataclass(frozen=True)
class SimResult:
    feasible: bool
    step_time: float = math.inf
    throughput: float = 0.0          # tokens / s
    mfu: float = 0.0
    breakdown: Dict[str, float] = field(default_factory=dict)
    bottleneck: str = "infeasible"
    logs: Dict[str, float] = field(default_factory=dict)
    reason: str = ""


def map_intra(w: Workload, s: Strategy, mcm: MCMArch
              ) -> Optional[Tuple[Dict[str, int], Dict[str, int]]]:
    """Map parallelism groups to intra-MCM HBD vs inter-MCM rails.

    TP always maps intra (Obs 1).  If the MCM is larger than TP, exactly
    one other parallelism (or a hierarchical slice of DP) fills the rest.
    """
    dies = mcm.dies_per_mcm
    if s.tp > dies or dies % s.tp != 0:
        return None
    rem = dies // s.tp
    intra = {"TP": s.tp}
    inter = {"DP": s.dp, "PP": s.pp, "CP": s.cp, "EP": s.ep}
    if rem > 1:
        for p in ("CP", "EP", "PP"):          # exact-fit groups first
            if inter[p] == rem:
                intra[p] = rem
                inter[p] = 1
                rem = 1
                break
    if rem > 1 and inter["DP"] % rem == 0:    # hierarchical DP slice
        intra["DP"] = rem
        inter["DP"] //= rem
        rem = 1
    if rem > 1:
        return None
    return intra, inter


def _gemm_eff(w: Workload, s: Strategy, hw: HW) -> float:
    """Harmonic-blended GEMM shape efficiency (token dim x width dim)."""
    m_tok = w.tokens_per_step / (s.dp * s.cp * max(s.n_micro, 1))
    em = lambda m: m / (m + hw.gemm_m_half)
    en = lambda n: n / (n + hw.gemm_n_half)
    model = w.model
    a = model.attn
    if model.moe is not None:
        moe = model.moe
        m_exp = m_tok * moe.top_k / moe.n_experts
        n_ffn = max(moe.d_ff_expert / s.tp, 1.0)
        eff_ffn = em(m_exp) * en(n_ffn)
        ffn_flops = moe.top_k * 3 * model.d_model * moe.d_ff_expert
    else:
        d_ff = model.d_ff if model.d_ff else 2 * model.d_model
        eff_ffn = em(m_tok) * en(max(d_ff / s.tp, 1.0))
        ffn_flops = 3 * model.d_model * d_ff
    if a is not None:
        other_w = max(a.n_heads * a.head_dim / s.tp, 1.0)
        other_flops = model._attn_params()
    else:
        other_w = max(2 * model.d_model / s.tp, 1.0)
        other_flops = model._ssm_params() if model.ssm else \
            2 * model.d_model * model.d_model
    eff_other = em(m_tok) * en(other_w)
    f = ffn_flops / max(ffn_flops + other_flops, 1.0)
    return 1.0 / (f / max(eff_ffn, 1e-3)
                  + (1 - f) / max(eff_other, 1e-3))


def _bank_swap_reuse_ok(gap: float, n_micro: int, hw: HW) -> bool:
    if gap <= 0:
        return False
    return math.ceil(hw.ocs_switch_latency_s / gap) <= max(n_micro, 1)


def simulate(w: Workload, s: Strategy, mcm: MCMArch, fabric: str = "oi",
             topo: Optional[OITopology] = None, reuse: bool = True,
             hw: Optional[HW] = None) -> SimResult:
    hw = hw or mcm.hw
    n_dev = mcm.n_devices
    if s.n_devices != n_dev:
        return SimResult(False, reason=f"strategy devices {s.n_devices} "
                                       f"!= cluster {n_dev}")
    mapping = map_intra(w, s, mcm)
    if mapping is None:
        return SimResult(False, reason="unmappable intra-MCM packing")
    intra, inter = mapping

    layers_stage = max(w.n_layers // s.pp, 1)
    attn_stage = max(w.n_attn_layers // s.pp, 1) if w.n_attn_layers else 0
    moe_stage = max(w.n_moe_layers // s.pp, 1) if w.n_moe_layers else 0
    n_micro = max(s.n_micro, 1)

    # ---------------- memory capacity ----------------
    local_params = (w.nonexpert_params / (s.tp * s.pp)
                    + w.expert_params / (s.tp * s.pp * s.ep))
    mem_bytes = local_params * (2 + 2) + local_params * 12 / s.dp
    tokens_micro = w.tokens_per_step / (s.dp * s.cp * n_micro)
    act_bytes = (tokens_micro * w.d_model * w.bytes_act / s.tp
                 * layers_stage * 2 * min(s.pp, n_micro))
    cap = mcm.hbm_capacity
    if mem_bytes + act_bytes > cap:
        return SimResult(False, reason=(
            f"HBM capacity: need {(mem_bytes + act_bytes) / 1e9:.1f} GB "
            f"> {cap / 1e9:.1f} GB"))

    # ---------------- compute & memory time ----------------
    flops_dev = w.step_flops() / n_dev
    eff = _gemm_eff(w, s, hw) if hw.model_gemm_eff else 1.0
    t_comp = flops_dev / (mcm.die_flops * hw.mfu_ceiling * eff)
    hbm_stream = (local_params * w.bytes_param * 2.0 * n_micro   # streaming
                  + local_params * 16.0                          # opt update
                  + 12.0 * w.tokens_per_step / (s.dp * s.cp * s.tp)
                  * w.d_model * w.bytes_act * layers_stage)
    t_mem = hbm_stream / mcm.hbm_bw

    # ---------------- collective times ----------------
    vols = traffic_volumes(w, s)
    hbm_cap_bw = mcm.hbm_bw / 2.0          # insight 5: relay = read+write
    alpha = {"nvlink": hw.lat_ib_s, "ib": hw.lat_ib_s, "oi": hw.lat_oi_s}
    # per-invocation counts and hops per invocation, per parallelism
    inv = {"TP": 8 * layers_stage * n_micro,
           "CP": 2 * attn_stage * n_micro,
           "EP": 4 * moe_stage * n_micro,
           "DP": 1,
           "PP": 2 * n_micro}
    hops = {"TP": s.tp - 1, "CP": s.cp - 1,
            "EP": max(int(math.ceil(math.log2(max(s.ep, 2)))), 1),
            "DP": 2 * (s.dp - 1), "PP": 1}

    t_coll: Dict[str, float] = {}

    def add_lat(p: str, a_s: float):
        if s.degree(p) > 1:
            t_coll[p] = t_coll.get(p, 0.0) + inv[p] * hops[p] * a_s

    inter_vols = {p: vols[p] for p in PARALLELISMS
                  if inter.get(p, 1) > 1 and vols[p] > 0}

    for p, deg in intra.items():
        if deg <= 1 or vols[p] == 0:
            continue
        bw = hw.nvlink_bw if fabric == "nvlink" else mcm.intra_ring_bw(deg)
        bw = min(bw * hw.fabric_eff_elec if fabric == "nvlink" else bw,
                 hbm_cap_bw)
        t_coll[p] = vols[p] / bw
        add_lat(p, hw.lat_intra_s)

    reuse_pair = None
    reuse_cand = None              # pre-gate candidate (why-logs below)
    reuse_gated = False            # bank-swap gate disabled the candidate
    reuse_overhead = 0.0
    if fabric in ("ib", "nvlink"):
        shared = sum(inter_vols.values())
        if shared:
            t_sh = shared / min(hw.ib_bw * hw.fabric_eff_elec, hbm_cap_bw)
            for p, v in inter_vols.items():
                t_coll[p] = t_coll.get(p, 0.0) + t_sh * v / shared
                add_lat(p, hw.lat_ib_s)
    elif fabric == "oi":
        if topo is not None:
            alloc = dict(topo.link_alloc)
            reuse_pair = topo.reuse_pair
        else:
            reuse_pair = None
            if reuse:
                pairs = [pr for pr in reusable_pairs(w, s)
                         if pr[0] in inter_vols and pr[1] in inter_vols]
                reuse_pair = pairs[0] if pairs else None
            alloc = allocate_links(inter_vols, mcm.total_links, reuse_pair)
        reuse_cand = reuse_pair
        if reuse_pair is not None:
            gap = t_comp / max(layers_stage * n_micro, 1) / 2.0
            if hw.ocs_reuse_mode == "paper":
                pass   # switching hidden per the paper's assertion
            elif not _bank_swap_reuse_ok(gap, n_micro, hw):
                reuse_pair = None
                reuse_gated = True
                alloc = allocate_links(inter_vols, mcm.total_links, None)
            else:
                reuse_overhead = 2.0 * hw.ocs_switch_latency_s / n_micro
        for p, v in inter_vols.items():
            links = max(alloc.get(p, 1), 1)
            # links are an MCM resource; the dies of the package share them
            bw = min(links * hw.oi_link_bw * hw.fabric_eff_oi
                     / mcm.dies_per_mcm, hbm_cap_bw)
            t_coll[p] = t_coll.get(p, 0.0) + v / bw
            add_lat(p, hw.lat_oi_s)
    else:
        raise ValueError(fabric)

    # ---------------- exposure / overlap ----------------
    t_attn = t_comp * 0.3
    exposed = t_coll.get("TP", 0.0)
    exposed += max(0.0, t_coll.get("CP", 0.0)
                   - t_attn * hw.cp_overlap_frac)
    exposed += t_coll.get("EP", 0.0)
    exposed += t_coll.get("PP", 0.0)
    t_dp = t_coll.get("DP", 0.0)
    dp_exposed = max(0.0, t_dp - (2.0 / 3.0) * t_comp
                     * hw.dp_overlap_frac)

    bubble = (s.pp - 1) / n_micro
    body = max(t_comp, t_mem) + exposed
    step = body * (1.0 + bubble) + dp_exposed + reuse_overhead

    thpt = w.tokens_per_step / step
    mfu = w.step_flops() / step / (mcm.die_flops * n_dev)

    terms = {"compute": t_comp, "memory": t_mem, **{
        f"coll_{p}": t for p, t in t_coll.items()}}
    bottleneck = max(terms, key=terms.get)
    # reuse-decision provenance (all floats: P_ORDER index or -1) — lets
    # the event engine / analytic model be diffed on WHY they disagree
    # about link reuse, not just by how much.
    pidx = lambda pr, j: float(PARALLELISMS.index(pr[j])) if pr else -1.0
    logs = {
        "compute_util": t_comp / step,
        "gemm_eff": eff,
        "mem_pressure": (mem_bytes + act_bytes) / cap,
        "exposed_comm": exposed + dp_exposed,
        "bubble": bubble,
        "reuse_active": float(reuse_pair is not None),
        "reuse_cand_a": pidx(reuse_cand, 0),
        "reuse_cand_b": pidx(reuse_cand, 1),
        "reuse_pair_a": pidx(reuse_pair, 0),
        "reuse_pair_b": pidx(reuse_pair, 1),
        "reuse_gated": float(reuse_gated),
        "reuse_paper_mode": float(hw.ocs_reuse_mode == "paper"),
        "nop_bound": float(any(p in intra and t_coll.get(p, 0) > t_comp
                               for p in PARALLELISMS)),
        "oi_bound": float(fabric == "oi" and exposed + dp_exposed
                          > 0.3 * step),
        "hbm_bw_bound": float(t_mem > t_comp),
    }
    return SimResult(True, step_time=step, throughput=thpt, mfu=mfu,
                     breakdown=terms, bottleneck=bottleneck, logs=logs)
