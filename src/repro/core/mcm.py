"""MCM architecture model (paper §IV-A, Fig 5a).

Cluster compute C is the input constant; it is split into N MCMs of
``x*y`` logic dies, each coupled with ``m`` memory dies.  Optical I/O dies
sit at the package edge: each perimeter edge unit provides ``o`` links, so
an MCM exposes L = 2*(x+y)*o external links.  The logic-die edge is shared
between D2D (NoP) interfaces, HBM PHYs and (on perimeter dies) CPO — the
m <-> B_p <-> o beachfront trade-off the paper explores.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from repro.core.hardware import HW, DEFAULT_HW


@dataclass(frozen=True)
class MCMArch:
    n_mcm: int                  # N  — number of MCMs in the cluster
    x: int                      # logic-die grid
    y: int
    m: int                      # memory dies per logic die
    cpo_ratio: float = 0.6      # r — fraction of outer edge used for CPO
    hw: HW = field(default_factory=lambda: DEFAULT_HW)

    # ------------------------------------------------------------------
    @property
    def dies_per_mcm(self) -> int:
        return self.x * self.y

    @property
    def n_devices(self) -> int:
        return self.n_mcm * self.dies_per_mcm

    @property
    def die_flops(self) -> float:
        return self.hw.die_tflops * 1e12

    @property
    def mcm_flops(self) -> float:
        return self.die_flops * self.dies_per_mcm

    @property
    def cluster_tflops(self) -> float:
        """Total compute C in TFLOPS (the paper's x-axis)."""
        return self.hw.die_tflops * self.n_devices

    # ------------------------------------------------------------------
    # Beachfront accounting (per logic die)
    @property
    def hbm_bw(self) -> float:
        """Memory bandwidth per logic die."""
        return self.m * self.hw.hbm_bw_per_die

    @property
    def hbm_capacity(self) -> float:
        return self.m * self.hw.hbm_cap_per_die

    def _edge_budget(self) -> float:
        return 4.0 * self.hw.die_edge_mm

    def hbm_edge(self) -> float:
        return self.m * self.hw.hbm_phy_mm

    def cpo_edge(self) -> float:
        """Outer-perimeter edge length used by CPO on a perimeter die."""
        return self.cpo_ratio * self.hw.die_edge_mm

    def d2d_edge_per_side(self) -> float:
        """Edge length available for one D2D (NoP neighbour) interface.

        Remaining beachfront after HBM (all dies) and CPO (perimeter dies,
        conservatively charged to every die) is split across the mesh
        degree (4 for interior dies).
        """
        free = self._edge_budget() - self.hbm_edge() - self.cpo_edge()
        return max(free, 0.0) / 4.0

    @property
    def nop_bw(self) -> float:
        """NoP bandwidth per D2D neighbour link (B/s, per direction)."""
        return self.hw.d2d_gbps_per_mm * self.d2d_edge_per_side()

    def feasible(self) -> bool:
        return (self.d2d_edge_per_side() > 0.5     # >0.5mm per interface
                and self.m >= 1 and self.x >= 1 and self.y >= 1)

    # ------------------------------------------------------------------
    # Optical links
    @property
    def links_per_edge_unit(self) -> int:
        """o — optical links provided per perimeter edge unit (one die)."""
        bw = self.hw.cpo_gbps_per_mm * self.cpo_edge()
        return int(bw // self.hw.oi_link_bw)

    @property
    def total_links(self) -> int:
        """L = 2*(x+y)*o."""
        return 2 * (self.x + self.y) * self.links_per_edge_unit

    @property
    def oi_bw_total(self) -> float:
        return self.total_links * self.hw.oi_link_bw

    # ------------------------------------------------------------------
    def intra_ring_bw(self, group: int) -> float:
        """Effective per-device ring bandwidth for a group of ``group``
        devices embedded in the x*y NoP mesh.

        A ring of g dies embedded in a mesh uses one mesh link per hop;
        per the paper, mesh NoP gets less efficient at larger scale — we
        model a sqrt penalty from ring-to-mesh embedding dilation.
        """
        if group <= 1:
            return float("inf")
        dilation = max(1.0, math.sqrt(group) / 2.0)
        return self.nop_bw / dilation


def mcm_from_compute(total_tflops: float, dies_per_mcm: int, m: int,
                     cpo_ratio: float = 0.6, hw: HW = DEFAULT_HW,
                     aspect=None) -> MCMArch:
    """Build an MCMArch from the cluster compute constant C (paper-style).

    Grid aspect defaults to the most square x*y factorisation.
    """
    n_dev = max(int(round(total_tflops / hw.die_tflops)), 1)
    # round the MCM count to a power of two: clusters are provisioned in
    # factorable sizes so parallelism degrees can tile them (paper tables
    # use powers of two throughout)
    n_mcm = max(n_dev // dies_per_mcm, 1)
    n_mcm = 2 ** int(round(math.log2(n_mcm))) if n_mcm > 1 else 1
    if aspect is None:
        x = int(math.sqrt(dies_per_mcm))
        while dies_per_mcm % x:
            x -= 1
    else:
        x = aspect
    y = dies_per_mcm // x
    return MCMArch(n_mcm=n_mcm, x=x, y=y, m=m, cpo_ratio=cpo_ratio, hw=hw)
