"""Bridge: ChipLight DSE output -> concrete JAX mesh + sharding intent.

A ``ParallelPlan`` captures the strategy the cross-layer optimiser picked.
On a physical (data, model) / (pod, data, model) mesh:
  * TP  -> ``model`` axis (intra-MCM HBD, paper Obs 1),
  * DP / FSDP -> ``data`` (+ ``pod``) axes,
  * EP  -> ``model`` axis when n_experts divides it (expert sharding),
           otherwise experts stay TP-sharded on width,
  * CP  -> the ``data`` axis carries sequence shards for long-context
           decode (flash-decode KV distribution) — temporally disjoint
           from EP's use of the same wires, the jax-native analogue of the
           paper's dynamic link reuse (DESIGN.md §hardware-adaptation),
  * PP  -> parallel/pipeline.py (shard_map collective_permute stages).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.core.optimizer import DesignPoint
from repro.core.traffic import Strategy


@dataclass(frozen=True)
class ParallelPlan:
    tp: int
    dp: int
    pp: int = 1
    cp: int = 1
    ep: int = 1
    n_micro: int = 1
    reuse_pair: Optional[tuple] = None
    link_alloc: Optional[dict] = None

    @property
    def strategy(self) -> Strategy:
        return Strategy(tp=self.tp, dp=self.dp, pp=self.pp, cp=self.cp,
                        ep=self.ep, n_micro=self.n_micro)

    def mesh_shape(self, pod: int = 1):
        if pod > 1:
            return (pod, self.dp // pod, self.tp), ("pod", "data", "model")
        return (self.dp, self.tp), ("data", "model")


def plan_from_design(pt: DesignPoint) -> ParallelPlan:
    s = pt.strategy
    return ParallelPlan(
        tp=s.tp, dp=s.dp * s.cp * s.ep,   # CP/EP ride the data axis
        pp=s.pp, cp=s.cp, ep=s.ep, n_micro=s.n_micro,
        reuse_pair=pt.topo.reuse_pair if pt.topo else None,
        link_alloc=dict(pt.topo.link_alloc) if pt.topo else None)
