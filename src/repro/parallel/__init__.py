from repro.parallel.sharding import (param_specs, batch_specs,  # noqa: F401
                                     cache_specs, named_sharding_tree)
from repro.parallel.plan import ParallelPlan, plan_from_design  # noqa: F401
