"""Sharding rules: pytree path -> PartitionSpec for every arch/step.

Scheme (MaxText-style 2.5D):
  * ``model`` axis — tensor parallelism: attention heads / FFN width /
    vocab / expert dim (EP when the expert count divides the axis).
  * ``data`` (+ ``pod``) axes — FSDP: batch for activations, the
    non-TP dim of every weight (ZeRO-3; XLA inserts the all-gathers).

GSPMD tolerates non-divisible dims (it pads), so the rules only pick WHICH
dims shard; uneven vocab (e.g. 51865) is fine.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


def _axes(mesh):
    fsdp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    fsdp = fsdp if len(fsdp) > 1 else (fsdp[0] if fsdp else None)
    return fsdp, ("model" if "model" in mesh.axis_names else None)


def _ep_on_model(cfg: ModelConfig, mesh) -> bool:
    if cfg.moe is None:
        return False
    msize = mesh.shape.get("model", 1)
    return cfg.moe.n_experts % msize == 0


def _sanitize(spec: P, shape, mesh) -> P:
    """Null out spec entries whose dim is not divisible by the axis size
    (jit in_shardings require exact divisibility, unlike constraints)."""
    out = []
    for d, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(entry if shape[d] % size == 0 else None)
    # pad to rank
    out += [None] * (len(shape) - len(out))
    return P(*out)


def param_specs(cfg: ModelConfig, params_shape: Dict[str, Any], mesh):
    """PartitionSpec tree matching the params pytree (by leaf path)."""
    fsdp, tp = _axes(mesh)
    ep_model = _ep_on_model(cfg, mesh)

    def rule(path, leaf):
        return _sanitize(_rule(path, leaf), leaf.shape, mesh)

    def _rule(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        name = names[-1]
        stacked = "layers" in names or "enc_layers" in names \
            or "dec_layers" in names
        pre = (None,) if stacked else ()
        nd = len(leaf.shape)

        if name in ("embed", "lm_head"):
            # (V, D) / (D, V): shard the big vocab dim by model, other by fsdp
            big = int(np.argmax(leaf.shape))
            spec = [None, None]
            spec[big] = tp
            spec[1 - big] = fsdp
            return P(*spec)
        if name == "pos_embed":
            return P()
        if name in ("wq", "wk", "wv", "in_proj"):
            return P(*pre, fsdp, tp)
        if name in ("wo", "out_proj"):
            return P(*pre, tp, fsdp)
        if name in ("w1", "w3"):
            if nd - len(pre) == 3:      # MoE experts (E, D, F)
                if ep_model:
                    return P(*pre, tp, fsdp, None)
                return P(*pre, None, fsdp, tp)
            return P(*pre, fsdp, tp)
        if name == "w2":
            if nd - len(pre) == 3:      # (E, F, D)
                if ep_model:
                    return P(*pre, tp, None, fsdp)
                return P(*pre, None, tp, fsdp)
            return P(*pre, tp, fsdp)
        if name == "router":
            return P(*pre, fsdp, None)
        if name == "conv_w":
            return P(*pre, None, tp)
        if name == "conv_b":
            return P(*pre, tp)
        # norms, biases, per-head scalars: replicate
        return P()

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, kind=None):
    """PartitionSpecs for a train/prefill batch dict."""
    fsdp, _ = _axes(mesh)
    kind = kind or shape.kind

    def spec_for(key):
        if key in ("tokens", "labels", "loss_mask"):
            return P(fsdp, None) if kind != "decode" else P(fsdp)
        if key in ("prefix_embeds", "encoder_embeds"):
            return P(fsdp, None, None)
        if key == "pos":
            return P()
        raise KeyError(key)

    return spec_for


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """PartitionSpec tree for a decode cache pytree.

    decode_32k (B=128): batch over fsdp, kv-heads over model.
    long_500k (B=1): the KV-cache SEQUENCE dim shards over the fsdp axes
    (flash-decode style distributed KV) and heads over model.
    """
    fsdp, tp = _axes(mesh)
    fsdp_size = 1
    for a in (fsdp if isinstance(fsdp, tuple) else (fsdp,)):
        if a:
            fsdp_size *= mesh.shape[a]
    tp_size = mesh.shape.get("model", 1)
    batch_sharded = shape.global_batch % fsdp_size == 0 \
        and shape.global_batch >= fsdp_size

    def _tp_if(dim_size):
        # jit in_shardings require divisibility (unlike constraints)
        return tp if (tp and dim_size % tp_size == 0) else None

    def _fsdp_if(dim_size):
        return fsdp if dim_size % fsdp_size == 0 else None

    def rule(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        name = names[-1]
        nd = len(leaf.shape)
        if name in ("k", "v", "xk", "xv"):
            # (L|napps, B, Hkv, S, hd)
            if batch_sharded:
                return P(None, _fsdp_if(leaf.shape[1]),
                         _tp_if(leaf.shape[2]), None, None)
            return P(None, None, _tp_if(leaf.shape[2]),
                     _fsdp_if(leaf.shape[3]), None)
        if name == "conv":              # (L, B, W, C)
            return P(None, _fsdp_if(leaf.shape[1]) if batch_sharded
                     else None, None, _tp_if(leaf.shape[3]))
        if name == "ssm":               # (L, B, H, P, N)
            return P(None, _fsdp_if(leaf.shape[1]) if batch_sharded
                     else None, _tp_if(leaf.shape[2]), None, None)
        return P(*([None] * nd))

    return rule


def named_sharding_tree(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
