"""Explicit all-to-all MoE dispatch via shard_map (§Perf optimization).

The pjit dense-bucket dispatch cannot express a true A2A: GSPMD lowers the
global scatter as per-layer ALL-GATHERS of every dispatched token to every
expert shard (~16x the algorithmic traffic; measured in §Perf).  This
module is the TPU-native EP path:

  tokens stay local to their (data, model) tile -> per-destination send
  buffers -> lax.all_to_all over the ``model`` axis (which owns the
  experts) -> local expert grouping -> batched expert FFN -> inverse path.

Wire bytes drop to the paper's own EP traffic-model volume
(tokens x top_k x d_model x (n-1)/n per direction), i.e. the quantity
ChipLight's link allocator budgets for.  Fully differentiable (gathers,
scatters and all_to_all have exact transposes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import MoEConfig
from repro.models.moe import router_topk


def _rank_within(groups, n_groups):
    """rank of each element among equal values of ``groups`` (stable)."""
    order = jnp.argsort(groups, stable=True)
    sorted_g = groups[order]
    start = jnp.searchsorted(sorted_g, jnp.arange(n_groups))
    rank_sorted = jnp.arange(groups.shape[0]) - start[sorted_g]
    ranks = jnp.zeros_like(groups).at[order].set(
        rank_sorted.astype(groups.dtype))
    return ranks


def moe_apply_a2a(params, x, m: MoEConfig, ex, mesh):
    """x: (B, S, D) -> (y, aux).  Requires n_experts % model_axis == 0."""
    model_size = mesh.shape["model"]
    assert m.n_experts % model_size == 0
    e_local = m.n_experts // model_size
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    k = m.top_k

    def local_fn(xl, router, w1, w3, w2):
        # xl: (B_l, S_l, D) local tile
        bl, sl, d = xl.shape
        t_l = bl * sl
        h = xl.reshape(t_l, d)
        logits = (h @ router).astype(jnp.float32)
        weights, ids, aux = router_topk(logits, m)

        flat_ids = ids.reshape(-1)                       # (t_l*k,)
        tok_of = jnp.repeat(jnp.arange(t_l), k)
        dest = flat_ids // e_local                       # model-rank owner
        cap_send = max(8, -(-int(t_l * k * m.capacity_factor
                                 / model_size) // 8) * 8)

        rank_d = _rank_within(dest, model_size)
        keep = rank_d < cap_send
        slot = jnp.where(keep, rank_d, cap_send)

        send = jnp.zeros((model_size, cap_send + 1, d), xl.dtype)
        send = send.at[dest, slot].add(h[tok_of], mode="drop")[:, :cap_send]
        send_e = jnp.full((model_size, cap_send + 1), e_local, jnp.int32)
        send_e = send_e.at[dest, slot].set(
            (flat_ids % e_local).astype(jnp.int32), mode="drop")[
                :, :cap_send]

        recv = jax.lax.all_to_all(send, "model", 0, 0, tiled=False)
        recv_e = jax.lax.all_to_all(send_e, "model", 0, 0, tiled=False)

        rows = recv.reshape(model_size * cap_send, d)
        e_flat = recv_e.reshape(-1)                      # in [0, e_local]
        cap_exp = max(8, -(-model_size * cap_send // e_local // 8) * 8)
        rank_e = _rank_within(e_flat, e_local + 1)
        keep_e = (e_flat < e_local) & (rank_e < cap_exp)
        slot_e = jnp.where(keep_e, rank_e, cap_exp)

        buckets = jnp.zeros((e_local, cap_exp + 1, d), xl.dtype)
        buckets = buckets.at[e_flat, slot_e].add(
            rows, mode="drop")[:, :cap_exp]

        hh = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", buckets, w1))
              * jnp.einsum("ecd,edf->ecf", buckets, w3))
        out_b = jnp.einsum("ecf,efd->ecd", hh, w2)

        out_b = jnp.concatenate(
            [out_b, jnp.zeros((e_local, 1, d), out_b.dtype)], 1)
        back_rows = out_b[e_flat, slot_e] * keep_e[:, None].astype(
            out_b.dtype)
        back = back_rows.reshape(model_size, cap_send, d)
        ret = jax.lax.all_to_all(back, "model", 0, 0, tiled=False)

        ret = jnp.concatenate(
            [ret, jnp.zeros((model_size, 1, d), ret.dtype)], 1)
        gathered = ret[dest, slot] * keep[:, None].astype(ret.dtype)
        gathered = gathered * weights.reshape(-1, 1).astype(gathered.dtype)
        y = gathered.reshape(t_l, k, d).sum(1).reshape(bl, sl, d)
        aux = jax.lax.pmean(jax.lax.pmean(aux, "model"),
                            data_axes if len(data_axes) > 1
                            else data_axes[0])
        return y, aux

    x_spec = P(data_axes if len(data_axes) > 1 else data_axes[0],
               "model", None)
    out = shard_map(
        local_fn, mesh=mesh,
        in_specs=(x_spec, P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(x_spec, P()),
        check_rep=False,
    )(x, params["router"], params["w1"], params["w3"], params["w2"])
    return out
