# Unified Scenario/Study layer over the scalar oracle (repro.core) and
# the batched DSE engine (repro.dse) — see DESIGN.md §repro.api.
from repro.api.registry import (DRIVERS, OBJECTIVES, Objective,  # noqa: F401
                                Registry)
from repro.api.scenario import SCENARIO_SCHEMA, Scenario  # noqa: F401
from repro.api.result import (RESULT_SCHEMA, DesignRecord,  # noqa: F401
                              StudyResult, record_from_point,
                              record_from_search, record_from_sweep,
                              records_from_sweep)
from repro.api.study import Study, run  # noqa: F401
