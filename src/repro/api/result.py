"""Unified study results: ``DesignRecord`` + ``StudyResult``.

One record shape for every engine: the batched sweep (``SweepResult``),
per-cell driver runs (``SearchResult``), the scalar oracle
(``DesignPoint``) and the nested optimiser (``DSEResult``) are all folded
into ``DesignRecord`` rows by the adapters below — no caller outside
``repro.core``/``repro.dse`` constructs the legacy result types.

``StudyResult`` is the versioned, JSON-round-trippable artifact a study
writes: records, best/Pareto indices, traces, timings, and provenance
(scenario + content hash).  Refined records additionally keep the live
``DesignPoint`` (topology, JAX plan hand-off) in the runtime-only
``points`` list.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.api.registry import OBJECTIVES
from repro.api.scenario import Scenario

RESULT_SCHEMA = 1

METRIC_KEYS = ("feasible", "throughput", "step_time", "mfu", "cost",
               "power")


# ---------------------------------------------------------------------------
# DesignRecord
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class DesignRecord:
    """One evaluated design point, engine-independent."""

    strategy: Dict[str, int]       # TP/DP/PP/CP/EP + n_micro
    mcm: Dict[str, float]          # n_mcm/x/y/m/cpo_ratio
    fabric: str
    metrics: Dict[str, float]      # METRIC_KEYS
    source: str                    # "batched" | "refined" | "scalar"
    topo: Optional[Dict[str, Any]] = None   # refined OI points only

    @property
    def feasible(self) -> bool:
        return bool(self.metrics.get("feasible"))

    @property
    def throughput(self) -> float:
        return float(self.metrics.get("throughput", 0.0))

    def to_dict(self) -> Dict[str, Any]:
        return {"strategy": dict(self.strategy), "mcm": dict(self.mcm),
                "fabric": self.fabric,
                "metrics": {k: _jsonable(v)
                            for k, v in self.metrics.items()},
                "source": self.source, "topo": self.topo}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "DesignRecord":
        return cls(strategy=dict(d["strategy"]), mcm=dict(d["mcm"]),
                   fabric=d["fabric"],
                   metrics={k: _unjsonable(v)
                            for k, v in d["metrics"].items()},
                   source=d["source"], topo=d.get("topo"))


def _jsonable(v):
    v = float(v) if isinstance(v, (np.floating, np.integer)) else v
    if isinstance(v, float) and math.isinf(v):
        return "inf" if v > 0 else "-inf"
    return v


def _unjsonable(v):
    if v in ("inf", "-inf"):
        return math.inf if v == "inf" else -math.inf
    return v


# ---------------------------------------------------------------------------
# Adapters over the legacy result types
# ---------------------------------------------------------------------------
def _mcm_dict(mcm) -> Dict[str, float]:
    return {"n_mcm": int(mcm.n_mcm), "x": int(mcm.x), "y": int(mcm.y),
            "m": int(mcm.m), "cpo_ratio": float(mcm.cpo_ratio)}


def record_from_sweep(sweep, i: int) -> DesignRecord:
    """Adapter: one row of a ``repro.dse.search.SweepResult``."""
    return records_from_sweep(sweep, np.array([i], np.int64))[0]


def records_from_sweep(sweep, idx) -> List[DesignRecord]:
    """Columnar adapter: many ``SweepResult`` rows at once.

    The numpy -> Python conversion happens once per COLUMN (one
    ``tolist`` each), not once per element, and the mcm dict is built
    once per unique MCM variant — keeping thousands of Pareto rows
    costs array ops plus one cheap constructor per record."""
    idx = np.asarray(idx, np.int64)
    if not len(idx):
        return []
    b, met = sweep.batch, sweep.metrics
    tp, dp, pp = b.tp[idx].tolist(), b.dp[idx].tolist(), b.pp[idx].tolist()
    cp, ep = b.cp[idx].tolist(), b.ep[idx].tolist()
    nm = b.n_micro[idx].tolist()
    feas = np.asarray(met["feasible"], bool)[idx].tolist()
    thpt = np.asarray(met["throughput"], np.float64)[idx].tolist()
    stime = np.asarray(met["step_time"], np.float64)[idx].tolist()
    mfu = np.asarray(met["mfu"], np.float64)[idx].tolist()
    cost = np.asarray(met["cost"], np.float64)[idx].tolist()
    power = np.asarray(met["power"], np.float64)[idx].tolist()
    mis = np.asarray(sweep.mcm_idx, np.int64)[idx]
    mcm_dicts = {int(m): _mcm_dict(sweep.space.mcms[int(m)])
                 for m in np.unique(mis)}
    mi = mis.tolist()
    fabric = [str(f) for f in np.asarray(sweep.fabric)[idx].tolist()]
    return [DesignRecord(
        strategy={"TP": tp[i], "DP": dp[i], "PP": pp[i], "CP": cp[i],
                  "EP": ep[i], "n_micro": nm[i]},
        mcm=dict(mcm_dicts[mi[i]]), fabric=fabric[i],
        metrics={"feasible": feas[i], "throughput": thpt[i],
                 "step_time": stime[i], "mfu": mfu[i], "cost": cost[i],
                 "power": power[i]},
        source="batched") for i in range(len(idx))]


def record_from_search(res, mcm, fabric: str, i: int) -> DesignRecord:
    """Adapter: one row of a per-cell ``SearchResult`` (single MCM)."""
    b, met = res.batch, res.metrics
    strategy = {"TP": int(b.tp[i]), "DP": int(b.dp[i]), "PP": int(b.pp[i]),
                "CP": int(b.cp[i]), "EP": int(b.ep[i]),
                "n_micro": int(b.n_micro[i])}
    metrics = {k: (bool if k == "feasible" else float)(met[k][i])
               for k in METRIC_KEYS}
    return DesignRecord(strategy=strategy, mcm=_mcm_dict(mcm),
                        fabric=fabric, metrics=metrics, source="batched")


def record_from_point(pt, source: str = "refined",
                      fabric: Optional[str] = None) -> DesignRecord:
    """Adapter: a scalar-oracle ``core.optimizer.DesignPoint`` — exact
    (OCS-inclusive) cost, derived topology, board power recomputed with
    the same model the batched engine uses."""
    from repro.dse.batched_sim import board_power
    fabric = fabric or pt.fabric
    s, sim = pt.strategy, pt.sim
    strategy = {"TP": s.tp, "DP": s.dp, "PP": s.pp, "CP": s.cp, "EP": s.ep,
                "n_micro": s.n_micro}
    metrics = {"feasible": bool(sim.feasible),
               "throughput": float(sim.throughput),
               "step_time": float(sim.step_time),
               "mfu": float(sim.mfu),
               "cost": float(pt.cost),
               "power": board_power(pt.mcm, fabric,
                                    float(sim.logs.get("compute_util", 0.0)))}
    topo = None
    if pt.topo is not None:
        topo = {"dims": [[d.n, d.r, d.k] for d in pt.topo.dims],
                "mapping": [list(g) for g in pt.topo.mapping],
                "link_alloc": dict(pt.topo.link_alloc),
                "reuse_pair": (list(pt.topo.reuse_pair)
                               if pt.topo.reuse_pair else None),
                "ocs_count": int(pt.topo.ocs_count())}
    return DesignRecord(strategy=strategy, mcm=_mcm_dict(pt.mcm),
                        fabric=fabric, metrics=metrics, source=source,
                        topo=topo)


# ---------------------------------------------------------------------------
# StudyResult
# ---------------------------------------------------------------------------
@dataclass
class StudyResult:
    """Versioned result artifact of one ``Study.run()``."""

    scenario: Scenario
    records: List[DesignRecord]
    best: Optional[int]                    # index into records
    pareto: List[int] = field(default_factory=list)
    traces: List[Dict] = field(default_factory=list)
    timings: Dict[str, float] = field(default_factory=dict)
    provenance: Dict[str, Any] = field(default_factory=dict)
    # runtime-only: refined DesignPoints (topology / JAX-plan hand-off),
    # parallel to the records whose source == "refined"; NOT serialized.
    points: List = field(default_factory=list, repr=False, compare=False)

    def __len__(self) -> int:
        return len(self.records)

    @property
    def best_record(self) -> Optional[DesignRecord]:
        return self.records[self.best] if self.best is not None else None

    @property
    def best_point(self):
        """Best refined ``DesignPoint`` (None when no refinement ran)."""
        return self.points[0] if self.points else None

    def pareto_indices(self, objectives: Optional[Sequence[str]] = None
                       ) -> List[int]:
        """Non-dominated records under the scenario's (or the given)
        objectives, throughput-best first."""
        from repro.dse.pareto import pareto_mask
        names = tuple(objectives or self.scenario.objectives)
        objs = [OBJECTIVES.get(n) for n in names]
        if not self.records:
            return []
        cols = np.stack(
            [[float(r.metrics.get(o.metric, np.nan)) for r in self.records]
             for o in objs], 1)
        feas = np.array([r.feasible for r in self.records])
        cols = np.where(feas[:, None], cols, np.nan)
        idx = np.nonzero(pareto_mask(cols, [o.maximize for o in objs]))[0]
        thpt = np.array([self.records[i].throughput for i in idx])
        return [int(i) for i in idx[np.argsort(-thpt, kind="stable")]]

    # ------------------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {"schema": RESULT_SCHEMA,
                "scenario": self.scenario.to_dict(),
                "records": [r.to_dict() for r in self.records],
                "best": self.best, "pareto": list(self.pareto),
                "traces": self.traces, "timings": self.timings,
                "provenance": self.provenance}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "StudyResult":
        schema = d.get("schema", RESULT_SCHEMA)
        if schema != RESULT_SCHEMA:
            raise ValueError(f"unsupported StudyResult schema {schema!r} "
                             f"(this build reads {RESULT_SCHEMA})")
        return cls(scenario=Scenario.from_dict(d["scenario"]),
                   records=[DesignRecord.from_dict(r) for r in d["records"]],
                   best=d.get("best"), pareto=list(d.get("pareto", [])),
                   traces=list(d.get("traces", [])),
                   timings=dict(d.get("timings", {})),
                   provenance=dict(d.get("provenance", {})))

    def save(self, path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_dict(), indent=2) + "\n")
        return path

    @classmethod
    def load(cls, path) -> "StudyResult":
        return cls.from_dict(json.loads(Path(path).read_text()))
