"""``Scenario`` — the one declarative, serializable study spec.

A scenario composes everything a cross-layer study needs: workload (model
name + shape + ``Workload`` byte-format overrides), compute budget C, the
MCM variant grid (dies/m/cpo), fabrics, ``HW`` constant overrides,
objectives, the search driver and its knobs, and a seed.  It is frozen,
validated at construction, and round-trips exactly through
``to_dict``/``from_dict`` (and JSON files under ``scenarios/``), so a
study definition is a first-class artifact that can be swept, stored and
compared — see DESIGN.md §repro.api.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, Tuple

from repro.api.registry import DRIVERS, OBJECTIVES
from repro.core.hardware import DEFAULT_HW, HW
from repro.core.workload import Workload
from repro.dse.space import FABRICS, DesignSpace

SCENARIO_SCHEMA = 1

_HW_FIELDS = {f.name for f in dataclasses.fields(HW)}
_WORKLOAD_OVERRIDES = {"bytes_act", "bytes_grad", "bytes_param"}


def _grid(name: str, values, conv) -> Tuple:
    """Validated grid axis: non-empty, converted, duplicate-free."""
    if isinstance(values, (str, bytes)) or not hasattr(values, "__iter__"):
        raise ValueError(f"{name} must be a list/tuple, got {values!r}")
    vals = tuple(conv(v) for v in values)
    if not vals:
        raise ValueError(f"{name} must not be empty")
    if len(set(vals)) != len(vals):
        raise ValueError(f"{name} has duplicate entries: {list(vals)}")
    return vals


@dataclass(frozen=True, eq=True)
class Scenario:
    """Declarative spec of one design-space study (frozen, validated)."""

    # -- workload --------------------------------------------------------
    model: str                                  # arch id (repro.configs)
    total_tflops: float                         # cluster compute C
    seq_len: int = 10240
    global_batch: int = 512
    workload: Dict[str, Any] = field(default_factory=dict)  # byte formats

    # -- hardware grid ---------------------------------------------------
    dies_per_mcm: Tuple[int, ...] = (8, 16, 32)
    m: Tuple[int, ...] = (2, 4, 6, 8, 12)
    cpo_ratio: Tuple[float, ...] = (0.3, 0.6, 0.9)
    fabrics: Tuple[str, ...] = ("oi",)
    reuse: bool = True
    hw: Dict[str, Any] = field(default_factory=dict)        # HW overrides
    # path to a CALIB.json artifact (repro.calib): ``build_hw`` starts
    # from ``HW.calibrated(...)`` — the measured effective constants —
    # instead of DEFAULT_HW ("" = off).  Explicit ``hw`` overrides
    # still win on top; ``Study.run`` stamps the constants into
    # ``StudyResult.provenance["calibration"]``.
    calibration: str = ""

    # -- search ----------------------------------------------------------
    objectives: Tuple[str, ...] = ("throughput", "cost", "power")
    driver: str = "exhaustive"
    driver_kw: Dict[str, Any] = field(default_factory=dict)
    refine_top: int = 8            # scalar-oracle refinement of winners
    keep_top: int = 256            # records kept in StudyResult (0 = all)
    # event-driven validation (repro.events): replay the top-K records
    # and stamp validated_step_time / fidelity_err (0 = off)
    validate_top: int = 0
    # pipeline schedule(s) the event engine uses: one schedule name, a
    # comma list ("1f1b,interleaved"), or "search" (all schedules).
    # More than one candidate turns on Study.run()'s event re-rank
    # stage; the ONE source of truth for every event-engine consumer
    # (validate_top stamping, the outer driver's event_replay hook).
    schedule: str = "gpipe"
    backend: str = "numpy"
    seed: int = 0
    name: str = ""                 # study label (defaults to model)

    # ------------------------------------------------------------------
    def __post_init__(self):
        from repro.configs import canonical_arch
        set_ = lambda k, v: object.__setattr__(self, k, v)
        set_("model", canonical_arch(self.model))
        set_("name", self.name or self.model)
        set_("total_tflops", float(self.total_tflops))
        if self.total_tflops <= 0:
            raise ValueError(f"total_tflops must be > 0, "
                             f"got {self.total_tflops}")
        for k in ("seq_len", "global_batch"):
            if int(getattr(self, k)) < 1:
                raise ValueError(f"{k} must be >= 1, got {getattr(self, k)}")

        set_("dies_per_mcm", _grid("dies_per_mcm", self.dies_per_mcm, int))
        set_("m", _grid("m", self.m, int))
        set_("cpo_ratio", _grid("cpo_ratio", self.cpo_ratio, float))
        if min(self.dies_per_mcm) < 1 or min(self.m) < 1:
            raise ValueError("dies_per_mcm and m entries must be >= 1")
        if not all(0.0 < r <= 1.0 for r in self.cpo_ratio):
            raise ValueError(f"cpo_ratio entries must be in (0, 1], "
                             f"got {list(self.cpo_ratio)}")

        set_("fabrics", _grid("fabrics", self.fabrics, str))
        bad = [f for f in self.fabrics if f not in FABRICS]
        if bad:
            raise ValueError(f"unknown fabrics {bad}; known: {list(FABRICS)}")

        set_("objectives", _grid("objectives", self.objectives, str))
        for o in self.objectives:
            OBJECTIVES.get(o)               # KeyError lists known names
        DRIVERS.get(self.driver)

        set_("workload", dict(self.workload))
        bad = sorted(set(self.workload) - _WORKLOAD_OVERRIDES)
        if bad:
            raise ValueError(f"unknown workload overrides {bad}; "
                             f"allowed: {sorted(_WORKLOAD_OVERRIDES)}")
        set_("hw", dict(self.hw))
        bad = sorted(set(self.hw) - _HW_FIELDS)
        if bad:
            raise ValueError(f"unknown hw overrides {bad}; "
                             f"allowed: {sorted(_HW_FIELDS)}")
        if not isinstance(self.calibration, str):
            raise ValueError(f"calibration must be a CALIB.json path "
                             f"string, got {self.calibration!r}")
        set_("driver_kw", dict(self.driver_kw))

        if self.backend not in ("numpy", "jax", "auto"):
            raise ValueError(f"backend must be numpy|jax|auto, "
                             f"got {self.backend!r}")
        if self.refine_top < 0 or self.keep_top < 0 or self.validate_top < 0:
            raise ValueError("refine_top, keep_top and validate_top must "
                             "be >= 0")
        from repro.events.dag import SCHEDULES  # core-only dep, no cycle
        for sched in self.schedule_list():
            if sched not in SCHEDULES:
                raise ValueError(f"unknown schedule {sched!r}; known: "
                                 f"{list(SCHEDULES)} or 'search'")

    # ------------------------------------------------------------------
    # Engine-object builders
    # ------------------------------------------------------------------
    def schedule_list(self) -> Tuple[str, ...]:
        """Candidate pipeline schedules: ``"search"`` expands to every
        known schedule, a comma list to its entries, a plain name to a
        1-tuple.  len > 1 means schedule is a search dimension."""
        if self.schedule == "search":
            from repro.events.dag import SCHEDULES
            return tuple(SCHEDULES)
        return tuple(s.strip() for s in self.schedule.split(","))

    def build_workload(self) -> Workload:
        from repro.configs import get_config
        return Workload(model=get_config(self.model), seq_len=self.seq_len,
                        global_batch=self.global_batch, **self.workload)

    def build_hw(self) -> HW:
        base = DEFAULT_HW
        if self.calibration:
            from repro.calib import load_calibration
            base = HW.calibrated(load_calibration(self.calibration))
        return dataclasses.replace(base, **self.hw) if self.hw else base

    def design_space(self, alloc_mode: str = "chiplight") -> DesignSpace:
        return DesignSpace.from_compute(
            self.build_workload(), self.total_tflops, fabrics=self.fabrics,
            reuse=self.reuse, hw=self.build_hw(),
            dies_per_mcm=self.dies_per_mcm, m=self.m,
            cpo_ratio=self.cpo_ratio, alloc_mode=alloc_mode)

    def replace(self, **kw) -> "Scenario":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    # Serialization
    # ------------------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        d = {"schema": SCENARIO_SCHEMA}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            d[f.name] = list(v) if isinstance(v, tuple) else v
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Scenario":
        d = dict(d)
        schema = d.pop("schema", SCENARIO_SCHEMA)
        if schema != SCENARIO_SCHEMA:
            raise ValueError(f"unsupported scenario schema {schema!r} "
                             f"(this build reads {SCENARIO_SCHEMA})")
        known = {f.name for f in dataclasses.fields(cls)}
        bad = sorted(set(d) - known)
        if bad:
            raise ValueError(f"unknown scenario keys {bad}; "
                             f"known: {sorted(known)}")
        return cls(**d)

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "Scenario":
        return cls.from_dict(json.loads(text))

    def save(self, path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.to_json() + "\n")
        return path

    @classmethod
    def load(cls, path) -> "Scenario":
        return cls.from_json(Path(path).read_text())

    def scenario_hash(self) -> str:
        """Content hash over the canonical JSON form (provenance key)."""
        return hashlib.sha256(
            json.dumps(self.to_dict(), sort_keys=True).encode()
        ).hexdigest()[:16]

    # the generated dataclass __hash__ would choke on the dict fields;
    # hash by content so scenarios work in sets / as cache keys
    def __hash__(self) -> int:
        return hash(self.scenario_hash())
