"""Registries for search drivers and study objectives (DESIGN.md §repro.api).

New scenarios plug in new drivers/objectives by registering here — engine
code (``repro.core``, ``repro.dse``) is never touched.  Lookup errors name
the unknown key and the registered alternatives, so a typo in a scenario
JSON fails with one clear line instead of a deep traceback.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Iterator, List


class Registry:
    """Name -> entry mapping with decorator registration + clear errors."""

    def __init__(self, kind: str):
        self.kind = kind
        self._items: Dict[str, object] = {}

    def register(self, name: str) -> Callable:
        if name in self._items:
            raise ValueError(f"{self.kind} {name!r} already registered")

        def deco(obj):
            self._items[name] = obj
            return obj
        return deco

    def get(self, name: str):
        try:
            return self._items[name]
        except KeyError:
            raise KeyError(
                f"unknown {self.kind} {name!r}; registered: "
                f"{sorted(self._items)}") from None

    def names(self) -> List[str]:
        return sorted(self._items)

    def __contains__(self, name: str) -> bool:
        return name in self._items

    def __iter__(self) -> Iterator[str]:
        return iter(sorted(self._items))


# ---------------------------------------------------------------------------
# Objectives — a named metric of a DesignRecord plus its direction
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Objective:
    metric: str            # key into DesignRecord.metrics
    maximize: bool
    units: str = ""


OBJECTIVES = Registry("objective")
OBJECTIVES.register("throughput")(Objective("throughput", True, "tok/s"))
OBJECTIVES.register("cost")(Objective("cost", False, "$"))
OBJECTIVES.register("power")(Objective("power", False, "W"))
OBJECTIVES.register("step_time")(Objective("step_time", False, "s"))
OBJECTIVES.register("mfu")(Objective("mfu", True))


# ---------------------------------------------------------------------------
# Drivers — a runner ``(Scenario) -> StudyResult`` per search engine.
# Runners live in repro.api.study; lazy imports keep registration free of
# import cycles (scenario validation needs the names at class-build time).
# ---------------------------------------------------------------------------
DRIVERS = Registry("driver")


def _batched(name: str):
    def run(scenario):
        from repro.api.study import _run_batched
        return _run_batched(scenario, name)
    run.__name__ = f"run_{name}"
    return run


for _name in ("exhaustive", "random", "prf", "nsga2"):
    DRIVERS.register(_name)(_batched(_name))


@DRIVERS.register("chiplight-outer")
def _run_chiplight_outer(scenario):
    from repro.api.study import _run_outer
    return _run_outer(scenario)


@DRIVERS.register("railx")
def _run_railx_driver(scenario):
    from repro.api.study import _run_railx
    return _run_railx(scenario)
