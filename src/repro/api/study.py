"""``Study`` — one ``run()`` over any engine, from a ``Scenario``.

Dispatch goes through the driver registry: batched drivers (exhaustive /
random / prf / nsga2) take the scan-then-refine path — the vectorized
``repro.dse`` sweep ranks the whole grid, then the vectorized refinement
derives exact topologies and OCS-inclusive costs for the top points.
``chiplight-outer`` runs the population-based batched outer search
(``repro.dse.outer``; ``driver_kw={"method": "scalar"}`` is the legacy
single-walker nested optimiser), and ``railx`` sweeps the same grids
under the uniform RailX link split with exact RailX-topology refinement
(``method="scalar"`` for the legacy loop).  Every path produces the
same ``StudyResult``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.api.registry import DRIVERS, OBJECTIVES
from repro.api.result import (StudyResult, record_from_point,
                              records_from_sweep)
from repro.api.scenario import Scenario
from repro.obs import metrics, span


@dataclass(frozen=True)
class Study:
    """A scenario bound to its runner; ``Study(sc).run()`` is the single
    entrypoint every example, benchmark and CLI flow goes through."""

    scenario: Scenario

    def run(self, validate_top: Optional[int] = None,
            schedule: Optional[str] = None) -> StudyResult:
        """Run the scenario's driver; when ``validate_top`` (argument or
        scenario field) is > 0, the top-K records are replayed by the
        event-driven engine (``repro.events``, vectorized batch path)
        and stamped with ``validated_step_time`` / ``fidelity_err``."""
        sc = self.scenario
        from repro.dse.batched_sim import jax_stats
        t0 = time.perf_counter()
        traces0 = jax_stats()["traces"]
        with metrics.scope() as ms, \
                span("study.run", scenario=sc.name, driver=sc.driver):
            result = DRIVERS.get(sc.driver)(sc)
            k = sc.validate_top if validate_top is None else validate_top
            if k:
                from repro.events.validate import stamp_validation
                with span("study.validate_top", top=k):
                    stamp_validation(result, k, schedule or sc.schedule,
                                     backend=sc.backend)
            result.provenance["metrics"] = _metrics_block(
                result, ms, time.perf_counter() - t0,
                jax_stats()["traces"] - traces0)
            if sc.calibration:
                # the run executed on measured constants — stamp them
                # (plus where they were measured) next to the metrics
                # block so the artifact is self-describing
                from repro.calib import calibration_block
                result.provenance["calibration"] = \
                    calibration_block(sc.calibration)
        return result


def run(scenario: Scenario, **kw) -> StudyResult:
    """Module-level convenience: ``repro.api.run(scenario)``."""
    return Study(scenario).run(**kw)


# ---------------------------------------------------------------------------
# Batched drivers: vectorized sweep -> scalar refinement
# ---------------------------------------------------------------------------
def _sweep_keep_indices(sweep, sc: Scenario) -> np.ndarray:
    """Feasible rows worth keeping: top-``keep_top`` by throughput plus
    the full Pareto set under the scenario objectives (0 = keep all)."""
    from repro.dse.pareto import pareto_mask
    feas = np.nonzero(sweep.metrics["feasible"])[0]
    order = feas[np.argsort(-sweep.metrics["throughput"][feas],
                            kind="stable")]
    if sc.keep_top == 0 or len(order) <= sc.keep_top:
        return order
    objs = [OBJECTIVES.get(n) for n in sc.objectives]
    cols = np.stack([np.asarray(sweep.metrics[o.metric], np.float64)
                     for o in objs], 1)
    cols = np.where(sweep.metrics["feasible"][:, None], cols, np.nan)
    par = np.nonzero(pareto_mask(cols, [o.maximize for o in objs]))[0]
    keep = list(order[: sc.keep_top])
    kept = set(int(i) for i in keep)
    keep += [int(i) for i in par if int(i) not in kept]
    return np.array(keep, np.int64)


def _batched_driver_kw(sc: Scenario, driver: str) -> dict:
    """Translate generic knobs to the driver's signature (``budget`` ->
    ``pop_size`` for nsga2, as the legacy CLI did) and reject anything
    the driver cannot accept with one clear error."""
    import inspect
    from repro.dse.search import DRIVERS as DSE_DRIVERS
    kw = dict(sc.driver_kw)
    if driver == "exhaustive":          # full grid: budgets are moot
        kw.pop("budget", None)
        kw.pop("generations", None)
    elif driver in ("random", "prf"):
        kw.pop("generations", None)
        kw.setdefault("budget", 256)
    elif driver == "nsga2" and "budget" in kw:
        kw.setdefault("pop_size", min(kw.pop("budget"), 64))
    allowed = {p for p in inspect.signature(DSE_DRIVERS[driver]).parameters
               if p not in ("ev", "grid")}
    bad = sorted(set(kw) - allowed - {"seed"})
    if bad:
        raise ValueError(f"driver {driver!r} does not accept driver_kw "
                         f"{bad}; accepted: {sorted(allowed)}")
    return kw


_EVENT_KEYS = ("event_schedule", "event_v", "event_step_time",
               "event_throughput")


def _record_key(rec) -> tuple:
    return (tuple(sorted(rec.strategy.items())),
            tuple(sorted(rec.mcm.items())), rec.fabric)


def _event_rerank_stage(sc: Scenario, sweep, kept: np.ndarray):
    """The ``study.event_rerank`` stage: screen -> RE-RANK -> refine.

    When the scenario makes the pipeline schedule a search dimension
    (``schedule_list()`` > 1 candidate), the top-N analytic frontier is
    compiled per ``(schedule, virtual_chunks)`` candidate through
    ``events.compile_batch`` and batch-replayed; the head of ``kept``
    comes back EVENT-best-first so both the kept records and the
    refinement window honour the event-resolved ranking.  Returns
    ``(kept, rerank_info)`` — ``rerank_info`` is None when the stage is
    off (single schedule: bit-identical to the pre-stage path)."""
    from repro.dse.search import event_rerank_rows
    from repro.dse.space import schedule_axis
    sched_list = sc.schedule_list()
    if len(sched_list) < 2 or not len(kept):
        return kept, None
    n = int(min(len(kept), max(16, 4 * sc.refine_top)))
    cands = schedule_axis(sched_list)
    t0 = time.perf_counter()
    with span("study.event_rerank", rows=n, candidates=len(cands)):
        rr = event_rerank_rows(sweep, kept[:n], cands,
                               backend=sc.backend)
    kept = np.concatenate([kept[:n][rr["order"]], kept[n:]])
    return kept, {"n": n, "cands": cands, "rr": rr,
                  "elapsed_s": time.perf_counter() - t0,
                  "schedules": sched_list}


def _stamp_rerank(records, rerank: dict) -> dict:
    """Stamp the winning ``(schedule, v)`` + event step time on the
    re-ranked head of ``records`` (already event-best-first) and return
    the ``provenance["event_rerank"]`` block."""
    rr, n = rerank["rr"], rerank["n"]
    order = rr["order"]
    winners: dict = {}
    for j in range(n):
        pos = int(order[j])
        rec = records[j]
        step_ev = float(rr["step_time"][pos])
        if not np.isfinite(step_ev):
            continue               # no candidate compiled feasibly
        sched = str(rr["schedule"][pos])
        v = int(rr["v"][pos])
        rec.metrics["event_schedule"] = sched
        rec.metrics["event_v"] = v
        rec.metrics["event_step_time"] = step_ev
        rec.metrics["event_throughput"] = (
            rec.metrics["throughput"] * rec.metrics["step_time"]
            / step_ev) if step_ev > 0 else 0.0
        key = f"{sched}/v{v}"
        winners[key] = winners.get(key, 0) + 1
    return {"n_reranked": n,
            "schedules": list(rerank["schedules"]),
            "candidates": [[s, int(v)] for s, v in rerank["cands"]],
            "winners": winners}


def _run_batched(sc: Scenario, driver: str,
                 alloc_mode: str = "chiplight",
                 engine: Optional[str] = None) -> StudyResult:
    from repro.dse.search import (refine_sweep_rows, refine_top_points,
                                  sweep_design_space)
    t0 = time.perf_counter()
    space = sc.design_space(alloc_mode=alloc_mode)
    kw = _batched_driver_kw(sc, driver) if alloc_mode == "chiplight" \
        else {}
    with span("study.scan", driver=driver):
        sweep = sweep_design_space(space, driver=driver,
                                   backend=sc.backend, seed=sc.seed, **kw)
    kept = _sweep_keep_indices(sweep, sc)
    # the event engine replicates the chiplight link allocation — the
    # railx sweep's analytic rows answer a different alloc, so the
    # schedule re-rank only runs on the chiplight path
    rerank = None
    if alloc_mode == "chiplight":
        kept, rerank = _event_rerank_stage(sc, sweep, kept)
    records = records_from_sweep(sweep, kept)
    rerank_prov = _stamp_rerank(records, rerank) if rerank else None
    t1 = time.perf_counter()
    points = []
    if sc.refine_top and len(kept):
        with span("study.refine", top=sc.refine_top):
            if rerank is not None:
                # kept is event-best-first: refine the event winners in
                # that order (refine_sweep_rows preserves it)
                points = refine_sweep_rows(sweep, kept[: sc.refine_top])
            else:
                points = refine_top_points(sweep, top_k=sc.refine_top)
    refined = [record_from_point(p) for p in points]
    if rerank_prov and refined:
        # carry the winning (schedule, v) onto the refined duplicates
        ev_by_key = {_record_key(r): {k: r.metrics[k]
                                      for k in _EVENT_KEYS
                                      if k in r.metrics}
                     for r in records}
        for r in refined:
            r.metrics.update(ev_by_key.get(_record_key(r), {}))
    records += refined
    t2 = time.perf_counter()

    best: Optional[int] = None
    if points:                       # refined best-first (exact costs)
        best = len(records) - len(points)
    elif records:
        best = 0                     # kept rows are best-first
    timings = {"sweep_s": sweep.elapsed_s,
               "refine_s": t2 - t1, "total_s": t2 - t0}
    if rerank is not None:
        timings["rerank_s"] = rerank["elapsed_s"]
    result = StudyResult(
        scenario=sc, records=records, best=best, points=points,
        traces=[],
        timings=timings,
        provenance=_provenance(sc,
                               engine=engine
                               or f"dse.sweep[{driver}]+refine",
                               grid_evaluated=len(sweep),
                               n_sim=int(sweep.n_sim),
                               n_cache_hits=int(sweep.n_cache_hits),
                               n_feasible=int(sweep.metrics["feasible"]
                                              .sum()),
                               n_kept=len(kept), n_refined=len(points)))
    if rerank_prov is not None:
        result.provenance["event_rerank"] = rerank_prov
    result.pareto = result.pareto_indices()
    return result


# ---------------------------------------------------------------------------
# Outer search (population / scalar) + RailX baseline
# ---------------------------------------------------------------------------
def _points_result(sc: Scenario, pts: List, traces, engine: str,
                   elapsed: float, source: str = "scalar",
                   **extra_prov) -> StudyResult:
    # the outer search revisits MCM variants, re-evaluating identical
    # design points — keep one record per (strategy, mcm, fabric)
    n_raw = len(pts)
    seen, unique = set(), []
    for p in pts:
        s = p.strategy
        key = (s.tp, s.dp, s.pp, s.cp, s.ep, s.n_micro, p.mcm.n_mcm,
               p.mcm.x, p.mcm.y, p.mcm.m, p.mcm.cpo_ratio, p.fabric)
        if key not in seen:
            seen.add(key)
            unique.append(p)
    pts = sorted(unique, key=lambda p: -p.throughput)
    kept = pts if sc.keep_top == 0 else pts[: sc.keep_top]
    records = [record_from_point(p, source=source) for p in kept]
    result = StudyResult(
        scenario=sc, records=records, best=0 if records else None,
        points=kept, traces=list(traces),
        timings={"total_s": elapsed},
        provenance=_provenance(sc, engine=engine, n_evaluated=n_raw,
                               n_unique=len(pts), n_kept=len(kept),
                               **extra_prov))
    result.pareto = result.pareto_indices()
    return result


def _require_single_cell(sc: Scenario):
    """The outer search explores FROM one MCM start point (it moves
    dies/m/cpo itself); a multi-valued grid would be silently dropped,
    so reject it instead."""
    multi = [ax for ax in ("dies_per_mcm", "m", "cpo_ratio", "fabrics")
             if len(getattr(sc, ax)) > 1]
    if multi:
        raise ValueError(
            f"driver {sc.driver!r} starts from a single MCM cell; give "
            f"one value per axis (got multiple for {multi})")


def _run_outer(sc: Scenario) -> StudyResult:
    """``chiplight-outer``: the batched population search by default;
    ``driver_kw={"method": "scalar"}`` (implying ``walkers=1``) is the
    legacy single-walker nested optimiser, bit-identical per seed.  The
    legacy ``outer_iters`` knob maps onto ``rounds``."""
    from repro.dse.outer import outer_search
    _require_single_cell(sc)
    kw = dict(sc.driver_kw)
    method = kw.pop("method", "population")
    rounds = kw.pop("rounds", kw.pop("outer_iters", 8))
    walkers = kw.pop("walkers", 1 if method == "scalar" else 8)
    inner_budget = kw.pop("inner_budget", 48)
    inner_method = kw.pop("inner_method", "batched")
    refine_per_variant = kw.pop("refine_per_variant", 8)
    event_replay = kw.pop("event_replay", 0)
    event_schedule = kw.pop("event_schedule", None)
    if event_schedule is not None:
        import warnings
        warnings.warn(
            "driver_kw 'event_schedule' is deprecated; set "
            "Scenario.schedule (one name, a comma list, or 'search') — "
            "the one source of truth for every event-engine consumer",
            DeprecationWarning, stacklevel=3)
    else:
        event_schedule = sc.schedule_list()
    if kw:
        raise ValueError(
            f"driver 'chiplight-outer' does not accept driver_kw "
            f"{sorted(kw)}; accepted: ['event_replay', 'event_schedule', "
            f"'inner_budget', 'inner_method', 'method', 'outer_iters', "
            f"'refine_per_variant', 'rounds', 'walkers']")
    # knobs that only exist on the OTHER method would be silent no-ops
    dropped = ("refine_per_variant" if method == "scalar"
               else "inner_method")
    if dropped in sc.driver_kw:
        raise ValueError(f"driver_kw {dropped!r} has no effect with "
                         f"method={method!r}")
    t0 = time.perf_counter()
    res = outer_search(
        sc.build_workload(), sc.total_tflops,
        dies_per_mcm=sc.dies_per_mcm[0], m0=sc.m[0], cpo0=sc.cpo_ratio[0],
        rounds=rounds, walkers=walkers, inner_budget=inner_budget,
        fabric=sc.fabrics[0], reuse=sc.reuse, hw=sc.build_hw(),
        seed=sc.seed, method=method, inner_method=inner_method,
        refine_per_variant=refine_per_variant, backend=sc.backend,
        event_replay=event_replay, event_schedule=event_schedule)
    engine = ("core.chiplight_optimize" if method == "scalar"
              else "dse.outer_search[population]")
    source = "scalar" if method == "scalar" else "refined"
    return _points_result(sc, res.history, res.outer_trace, engine,
                          time.perf_counter() - t0, source=source,
                          **res.stats)


def _run_railx(sc: Scenario) -> StudyResult:
    """``railx``: batched sweep over the SAME grids as the chiplight
    drivers (``alloc_mode="railx"`` — uniform 50/50 two-rail-dim link
    split) + exact RailX-topology refinement of the winners;
    ``driver_kw={"method": "scalar"}`` is the legacy single-cell scalar
    loop."""
    kw = dict(sc.driver_kw)
    method = kw.pop("method", "batched")
    if method == "scalar":
        from repro.core.mcm import mcm_from_compute
        from repro.core.optimizer import railx_search
        _require_single_cell(sc)
        budget = kw.pop("budget", 64)
        if kw:
            raise ValueError(f"driver 'railx' (scalar) does not accept "
                             f"driver_kw {sorted(kw)}; accepted: "
                             f"['budget', 'method']")
        t0 = time.perf_counter()
        mcm = mcm_from_compute(sc.total_tflops, sc.dies_per_mcm[0],
                               sc.m[0], cpo_ratio=sc.cpo_ratio[0],
                               hw=sc.build_hw())
        _, pts = railx_search(sc.build_workload(), mcm, reuse=sc.reuse,
                              budget=budget, hw=sc.build_hw(),
                              seed=sc.seed)
        return _points_result(sc, pts, [], "core.railx_search",
                              time.perf_counter() - t0)
    if method != "batched":
        raise ValueError(f"driver 'railx' method must be 'batched' or "
                         f"'scalar', got {method!r}")
    if kw:
        raise ValueError(f"driver 'railx' does not accept driver_kw "
                         f"{sorted(kw)}; accepted: ['method']")
    return _run_batched(sc, "exhaustive", alloc_mode="railx",
                        engine="dse.sweep[railx]+refine")


def _provenance(sc: Scenario, **kw) -> dict:
    return {"scenario_hash": sc.scenario_hash(), "driver": sc.driver,
            "model": sc.model, **kw}


def _metrics_block(result: StudyResult, ms: "metrics.Metrics",
                   wall_s: float, jax_retraces: int) -> dict:
    """The ``provenance["metrics"]`` block stamped on every run: stage
    wall-times, points/s, cache hit rates, jax retrace count, and the
    scoped counter/gauge snapshot (``METRICS_SCHEMA``); round-trips
    through the StudyResult JSON artifact."""
    prov = result.provenance
    n_eval = int(prov.get("grid_evaluated", prov.get("n_evaluated", 0)))
    n_sim = int(prov.get("n_sim", 0))
    hits = int(prov.get("n_cache_hits", 0))
    requests = int(prov.get("n_requested", n_sim + hits))
    wall = {"total": wall_s}
    for key, label in (("sweep_s", "sweep"), ("rerank_s", "rerank"),
                       ("refine_s", "refine"),
                       ("validate_s", "validate"),
                       ("total_s", "driver")):
        if key in result.timings:
            wall[label] = float(result.timings[key])
    snap = ms.snapshot()
    return {
        "schema": metrics.METRICS_SCHEMA,
        "wall_s": wall,
        "points_evaluated": n_eval,
        "points_per_s": n_eval / wall_s if wall_s > 0 else 0.0,
        "cache": {"requests": requests, "hits": hits,
                  "hit_rate": hits / requests if requests else 0.0},
        "jax": {"retraces": int(jax_retraces)},
        "counters": snap["counters"],
        "gauges": snap["gauges"],
    }
