"""Fig 4 reproduction: spatial traffic distribution heatmap.

Emits the device x device matrix stats (sparsity, max/mean imbalance) and
an ASCII mini-heatmap; Observation 3: traffic is sparse + uneven.  Also
times the vectorized ``traffic_matrix`` against the loop reference.
"""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core import Strategy, Workload, traffic_matrix
from repro.core.traffic import _traffic_matrix_loop
from repro.configs import get_config


def run():
    cfg = get_config("qwen3_moe_235b_a22b")
    w = Workload(model=cfg, seq_len=10240, global_batch=512)
    s = Strategy(tp=4, dp=4, pp=2, cp=2, ep=4, n_micro=8)  # 256 devices

    def best_of(fn, n=3):
        ts = []
        for _ in range(n):
            t0 = time.perf_counter()
            fn(w, s, ep_fc=True)
            ts.append(time.perf_counter() - t0)
        return min(ts)

    t_vec = best_of(traffic_matrix)
    t_loop = best_of(_traffic_matrix_loop)
    print(f"traffic_matrix (ep_fc): loop {t_loop * 1e3:.2f} ms -> "
          f"vectorized {t_vec * 1e3:.2f} ms = {t_loop / t_vec:.1f}x")

    mat = traffic_matrix(w, s)
    n = mat.shape[0]
    nz = mat > 0
    sparsity = nz.mean()
    vals = mat[nz]
    imbalance = vals.max() / max(vals.mean(), 1e-9)
    rows = [[n, f"{sparsity:.4f}", f"{vals.max() / 1e9:.2f}",
             f"{vals.mean() / 1e9:.2f}", f"{imbalance:.1f}"]]
    emit("fig4_heatmap", rows,
         ["devices", "nonzero_frac", "max_link_GB", "mean_link_GB",
          "max_over_mean"])
    # coarse ascii heatmap (16x16 blocks)
    blk = n // 16
    coarse = mat[:16 * blk, :16 * blk].reshape(16, blk, 16, blk).sum((1, 3))
    scale = coarse.max()
    chars = " .:-=+*#%@"
    print("coarse traffic heatmap (16x16 device blocks):")
    for r in coarse:
        print("".join(chars[int(9 * v / scale)] for v in r))
    ok = sparsity < 0.1 and imbalance > 2
    print(f"Observation 3 (sparse + uneven): "
          f"{'CONFIRMED' if ok else 'VIOLATED'}")
    return {"sparsity": float(sparsity), "imbalance": float(imbalance),
            "obs3": ok}


if __name__ == "__main__":
    run()
