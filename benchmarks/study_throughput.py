"""Study-path throughput benchmark -> BENCH_study.json.

Where ``dse_throughput.py`` tracks the raw batched-sweep kernel, this
benchmark tracks the FULL ``Study.run()`` pipeline — sweep + Pareto
keep-set + columnar record building + batched refinement — which is
what users actually run.  The acceptance target of the perf PR that
introduced it: ``points_per_s_study`` must be >= 10x the values frozen
in BENCH_dse.json (the pre-optimization study path), with refined
records ranked identically to the scalar-oracle refinement.

    PYTHONPATH=src:. python benchmarks/study_throughput.py
    PYTHONPATH=src:. python benchmarks/study_throughput.py --quick

``--quick`` runs the tinyllama scenario only and exits non-zero if the
study path regresses below the checked-in floor — the CI smoke mode.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from benchmarks.common import emit
from repro.api import Scenario, Study

REPO = Path(__file__).resolve().parents[1]
OUT = REPO / "BENCH_study.json"
BASELINE = REPO / "BENCH_dse.json"

# CI regression floor (points/s through Study.run()).  Deliberately far
# below the ~200-500k pts/s a warm laptop-class machine reaches, so only
# a real regression (an accidental per-row Python loop, a quadratic
# keep-set, an O(N^2) Pareto pass) trips it — not a noisy shared runner.
QUICK_FLOOR_PTS_PER_S = 30_000.0

MODELS = [
    ("tinyllama_1_1b", 4096, 512),
    ("qwen3_moe_235b_a22b", 10240, 512),
    ("mixtral_8x7b", 8192, 256),
]


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _baseline_study_pts() -> dict:
    """points_per_s_study per model from the frozen BENCH_dse.json."""
    if not BASELINE.exists():
        return {}
    data = json.loads(BASELINE.read_text())
    return {r["model"]: r.get("points_per_s_study")
            for r in data.get("results", [])}


def _refine_ranking_matches(sc: Scenario) -> bool:
    """Batched refinement must rank identically to the scalar oracle."""
    from repro.dse.search import refine_top_points, sweep_design_space
    sweep = sweep_design_space(sc.design_space(), backend=sc.backend,
                               seed=sc.seed)
    key = lambda p: ((p.strategy.tp, p.strategy.dp, p.strategy.pp,
                      p.strategy.cp, p.strategy.ep, p.strategy.n_micro),
                     p.mcm.n_mcm, p.mcm.m, p.fabric)
    batched = refine_top_points(sweep, top_k=sc.refine_top)
    scalar = refine_top_points(sweep, top_k=sc.refine_top,
                               method="scalar")
    return [key(p) for p in batched] == [key(p) for p in scalar]


def bench_model(name: str, seq_len: int, global_batch: int,
                C: float = 4e6, repeats: int = 5) -> dict:
    sc = Scenario(model=name, total_tflops=C, seq_len=seq_len,
                  global_batch=global_batch, fabrics=("oi",))
    study = Study(sc)
    res = study.run()                                       # warm-up
    t_study = min(_timed(study.run) for _ in range(repeats))
    n = int(res.provenance["grid_evaluated"])
    return {
        "model": name, "C_tflops": C, "design_points": n,
        "n_records": len(res.records),
        "n_refined": int(res.provenance["n_refined"]),
        "study_s": t_study,
        "sweep_s": res.timings["sweep_s"],
        "points_per_s_study": n / t_study,
        "refine_ranking_matches_scalar": _refine_ranking_matches(sc),
    }


def run(quick: bool = False) -> int:
    base = _baseline_study_pts()
    models = MODELS[:1] if quick else MODELS
    results = []
    for name, seq_len, gb in models:
        r = bench_model(name, seq_len, gb)
        b = base.get(name)
        r["baseline_points_per_s_study"] = b
        r["speedup_vs_baseline"] = (r["points_per_s_study"] / b) if b \
            else None
        results.append(r)

    rows = [[r["model"], r["design_points"],
             f"{r['study_s'] * 1e3:.1f}",
             f"{r['points_per_s_study']:.0f}",
             f"{r['speedup_vs_baseline']:.1f}"
             if r["speedup_vs_baseline"] else "n/a",
             r["refine_ranking_matches_scalar"]]
            for r in results]
    emit("study_throughput", rows,
         ["model", "points", "study_ms", "points_per_s_study",
          "speedup_vs_BENCH_dse", "refine_rank_ok"])

    rc = 0
    for r in results:
        if not r["refine_ranking_matches_scalar"]:
            print(f"FAIL: {r['model']} batched refinement ranking "
                  f"diverges from the scalar oracle")
            rc = 1
    if quick:
        pts = results[0]["points_per_s_study"]
        if pts < QUICK_FLOOR_PTS_PER_S:
            print(f"FAIL: study path at {pts:,.0f} pts/s is below the "
                  f"floor of {QUICK_FLOOR_PTS_PER_S:,.0f} pts/s")
            rc = 1
        else:
            print(f"OK: study path at {pts:,.0f} pts/s "
                  f"(floor {QUICK_FLOOR_PTS_PER_S:,.0f})")
        return rc                        # quick mode never rewrites JSON

    speedups = [r["speedup_vs_baseline"] for r in results
                if r["speedup_vs_baseline"]]
    min_speedup = min(speedups) if speedups else None
    payload = {"bench": "study_throughput", "results": results,
               "min_speedup_vs_baseline": min_speedup}
    OUT.write_text(json.dumps(payload, indent=2) + "\n")
    vs = f"{min_speedup:.0f}x" if min_speedup is not None \
        else "n/a — no baseline in BENCH_dse.json"
    print(f"wrote {OUT}  (min speedup vs BENCH_dse study path {vs})")
    return rc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="tinyllama only + regression floor (CI smoke); "
                         "does not rewrite BENCH_study.json")
    args = ap.parse_args(argv)
    return run(quick=args.quick)


if __name__ == "__main__":
    sys.exit(main())
