"""Study-path throughput benchmark -> BENCH_study.json.

Where ``dse_throughput.py`` tracks the raw batched-sweep kernel, this
benchmark tracks the FULL ``Study.run()`` pipeline — sweep + Pareto
keep-set + columnar record building + batched refinement — which is
what users actually run.  The acceptance target of the perf PR that
introduced it: ``points_per_s_study`` must be >= 10x the values frozen
in BENCH_dse.json (the pre-optimization study path), with refined
records ranked identically to the scalar-oracle refinement.

    PYTHONPATH=src:. python benchmarks/study_throughput.py
    PYTHONPATH=src:. python benchmarks/study_throughput.py --quick

``--quick`` runs the tinyllama scenario only and gates it on the floor
owned by ``repro.obs.bench`` (the CI smoke mode — also reachable as
``python -m repro.cli bench check --which study --quick``).

Each model is additionally timed with a host tracer installed
(``repro.obs``), so the written snapshot records the tracing overhead:
``tracing_overhead_frac`` (enabled vs disabled) and — when ``--baseline
prev.json`` maps models to a pre-observability measurement from the
SAME machine — ``tracing_off_vs_baseline`` (the "instrumentation left
in the hot path costs nothing when disabled" acceptance number).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from benchmarks.common import emit
from repro.api import Scenario, Study
from repro.obs import tracing
from repro.obs.bench import DEFAULT_FLOORS, enforce

REPO = Path(__file__).resolve().parents[1]
OUT = REPO / "BENCH_study.json"
BASELINE = REPO / "BENCH_dse.json"

MODELS = [
    ("tinyllama_1_1b", 4096, 512),
    ("qwen3_moe_235b_a22b", 10240, 512),
    ("mixtral_8x7b", 8192, 256),
]


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _baseline_study_pts() -> dict:
    """points_per_s_study per model from the frozen BENCH_dse.json."""
    if not BASELINE.exists():
        return {}
    data = json.loads(BASELINE.read_text())
    return {r["model"]: r.get("points_per_s_study")
            for r in data.get("results", [])}


def _refine_ranking_matches(sc: Scenario) -> bool:
    """Batched refinement must rank identically to the scalar oracle."""
    from repro.dse.search import refine_top_points, sweep_design_space
    sweep = sweep_design_space(sc.design_space(), backend=sc.backend,
                               seed=sc.seed)
    key = lambda p: ((p.strategy.tp, p.strategy.dp, p.strategy.pp,
                      p.strategy.cp, p.strategy.ep, p.strategy.n_micro),
                     p.mcm.n_mcm, p.mcm.m, p.fabric)
    batched = refine_top_points(sweep, top_k=sc.refine_top)
    scalar = refine_top_points(sweep, top_k=sc.refine_top,
                               method="scalar")
    return [key(p) for p in batched] == [key(p) for p in scalar]


def _timed_traced(study: Study) -> float:
    t0 = time.perf_counter()
    with tracing():
        study.run()
    return time.perf_counter() - t0


def _scenario(name: str, seq_len: int, global_batch: int,
              C: float = 4e6) -> Scenario:
    return Scenario(model=name, total_tflops=C, seq_len=seq_len,
                    global_batch=global_batch, fabrics=("oi",))


def bench_model(name: str, seq_len: int, global_batch: int,
                C: float = 4e6, repeats: int = 5) -> dict:
    sc = _scenario(name, seq_len, global_batch, C)
    study = Study(sc)
    res = study.run()                                       # warm-up
    t_study = min(_timed(study.run) for _ in range(repeats))
    n = int(res.provenance["grid_evaluated"])
    return {
        "model": name, "C_tflops": C, "design_points": n,
        "n_records": len(res.records),
        "n_refined": int(res.provenance["n_refined"]),
        "study_s": t_study,
        "sweep_s": res.timings["sweep_s"],
        "points_per_s_study": n / t_study,
        "refine_ranking_matches_scalar": _refine_ranking_matches(sc),
    }


def bench_model_traced(r: dict, seq_len: int, global_batch: int,
                       repeats: int = 5) -> None:
    """Second pass: the same workload timed with a host tracer
    installed.  Kept separate from (and run after) ALL untraced
    timings — traced runs allocate large span lists, and the heap
    churn they leave measurably skews untraced timings taken later in
    the same process."""
    study = Study(_scenario(r["model"], seq_len, global_batch,
                            r["C_tflops"]))
    study.run()                                             # warm-up
    t_traced = min(_timed_traced(study) for _ in range(repeats))
    r["traced_study_s"] = t_traced
    r["points_per_s_traced"] = r["design_points"] / t_traced
    r["tracing_overhead_frac"] = t_traced / r["study_s"] - 1.0


def run(quick: bool = False, pre_obs: dict | None = None) -> int:
    base = _baseline_study_pts()
    pre_obs = pre_obs or {}
    models = MODELS[:1] if quick else MODELS
    results = []
    for name, seq_len, gb in models:
        r = bench_model(name, seq_len, gb)
        results.append(r)
    for r, (_name, seq_len, gb) in zip(results, models):
        bench_model_traced(r, seq_len, gb)
    for r in results:
        name = r["model"]
        b = base.get(name)
        r["baseline_points_per_s_study"] = b
        r["speedup_vs_baseline"] = (r["points_per_s_study"] / b) if b \
            else None
        p = pre_obs.get(name)
        r["pre_obs_points_per_s_study"] = p
        r["tracing_off_vs_pre_obs"] = (r["points_per_s_study"] / p) \
            if p else None

    rows = [[r["model"], r["design_points"],
             f"{r['study_s'] * 1e3:.1f}",
             f"{r['points_per_s_study']:.0f}",
             f"{r['tracing_overhead_frac'] * 100:+.1f}%",
             f"{r['speedup_vs_baseline']:.1f}"
             if r["speedup_vs_baseline"] else "n/a",
             r["refine_ranking_matches_scalar"]]
            for r in results]
    emit("study_throughput", rows,
         ["model", "points", "study_ms", "points_per_s_study",
          "trace_ovh", "speedup_vs_BENCH_dse", "refine_rank_ok"])

    rc = 0
    for r in results:
        if not r["refine_ranking_matches_scalar"]:
            print(f"FAIL: {r['model']} batched refinement ranking "
                  f"diverges from the scalar oracle")
            rc = 1
    if quick:
        got = enforce("study", {
            "points_per_s_study": results[0]["points_per_s_study"]},
            root=REPO)
        return rc or int(any(not row["ok"] for row in got))
        # quick mode never rewrites JSON

    speedups = [r["speedup_vs_baseline"] for r in results
                if r["speedup_vs_baseline"]]
    min_speedup = min(speedups) if speedups else None
    payload = {"bench": "study_throughput", "results": results,
               "min_speedup_vs_baseline": min_speedup,
               "max_tracing_overhead_frac":
                   max(r["tracing_overhead_frac"] for r in results),
               "quick_floors": dict(DEFAULT_FLOORS["study"])}
    OUT.write_text(json.dumps(payload, indent=2) + "\n")
    vs = f"{min_speedup:.0f}x" if min_speedup is not None \
        else "n/a — no baseline in BENCH_dse.json"
    print(f"wrote {OUT}  (min speedup vs BENCH_dse study path {vs})")
    return rc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="tinyllama only + regression floor (CI smoke); "
                         "does not rewrite BENCH_study.json")
    ap.add_argument("--baseline", metavar="JSON",
                    help="same-machine pre-observability measurement "
                         "{model: points_per_s_study}; recorded in the "
                         "snapshot as tracing_off_vs_pre_obs")
    args = ap.parse_args(argv)
    pre = json.loads(Path(args.baseline).read_text()) \
        if args.baseline else None
    return run(quick=args.quick, pre_obs=pre)


if __name__ == "__main__":
    sys.exit(main())
