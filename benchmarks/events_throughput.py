"""Event-engine throughput benchmark -> BENCH_events.json.

Tracks the two replay paths of ``repro.events``:

* scalar discrete-event engine — replays/s and events/s on one compiled
  program per model (the fidelity-harness ground truth);
* vectorized batch replay — records/s through each wavefront backend
  (``numpy`` and ``jax``) of ``replay_batch`` (the path
  ``Study.run(validate_top=K)`` and the outer search's fused per-round
  event replay go through), at K=64 and K=1024;
* fused compile+replay — the END-TO-END event stage: the vectorized
  record->program compiler (``events.compile_batch``) plus batch replay
  on the ``auto`` backend, against the compile-per-record baseline
  (K ``compile_step`` DAG walks + one ``replay_batch``) on the same
  K=64 top-records set.  ``fused_speedup_k64`` is the headline the
  schedule-search re-rank stage rides on (target >= 10x per model).

The replay-only batch loads are measured per model: the DEEPEST feasible
interleaved pipeline replicated K times (the worst-case wavefront DAG —
the headline ``batch_records_per_s`` rows and the per-backend speedups),
and the mixed top-8-records batch (the ``validate_top`` shape).

    PYTHONPATH=src:. python benchmarks/events_throughput.py
    PYTHONPATH=src:. python benchmarks/events_throughput.py --quick
    PYTHONPATH=src:. python benchmarks/events_throughput.py --backend jax

``--quick`` runs tinyllama only and gates BOTH backends on the floors
owned by ``repro.obs.bench`` (the CI smoke mode — also reachable as
``python -m repro.cli bench check --which events --quick``).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from benchmarks.common import emit
from repro.api import Scenario
from repro.events import replay, replay_batch
from repro.obs.bench import (BATCH_K, DEFAULT_FLOORS, enforce,
                             measure_events_quick, pipelined_programs,
                             top_record_batch)

REPO = Path(__file__).resolve().parents[1]
OUT = REPO / "BENCH_events.json"

MODELS = [
    ("tinyllama_1_1b", 1e6, 4096, 256),
    ("qwen3_moe_235b_a22b", 4e6, 10240, 512),
    ("mixtral_8x7b", 4e6, 8192, 256),
]

BATCH_KS = (BATCH_K, 1024)


def _batch_rate(programs, backend: str, repeats: int) -> float:
    """Best-of-``repeats`` records/s; the first (untimed) call pays any
    jax trace so the rate reflects steady-state dispatch."""
    replay_batch(programs, backend=backend)
    t_b = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        replay_batch(programs, backend=backend)
        t_b = min(t_b, time.perf_counter() - t0)
    return len(programs) / t_b


def _fused_vs_baseline(sc: Scenario, repeats: int) -> dict:
    """End-to-end event-stage throughput at K=64 on the study's top
    records: fused (``compile_batch`` + replay) vs the per-record
    baseline (K ``compile_step`` walks + one ``replay_batch``), both on
    the production ``auto`` backend."""
    from repro.events.compile_batch import compile_batch
    from repro.events.dag import compile_step
    w, hw, ss, mcms, topos, fabs = top_record_batch(sc, k=BATCH_K)

    def fused():
        cb = compile_batch(w, ss, mcms, fabric=fabs, topos=topos,
                           reuse=sc.reuse, hw=hw, schedule="1f1b")
        cb.replay(backend="auto")

    def baseline():
        progs = [compile_step(w, s, m, fabric=f, topo=t, reuse=sc.reuse,
                              hw=hw, schedule="1f1b")
                 for s, m, t, f in zip(ss, mcms, topos, fabs)]
        replay_batch(progs, backend="auto")

    out = {}
    for name, fn in (("fused", fused), ("per_record", baseline)):
        fn()                        # warm (jax trace at the auto bucket)
        t = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn()
            t = min(t, time.perf_counter() - t0)
        out[f"{name}_compile_replay_per_s"] = BATCH_K / t
    out["fused_speedup_k64"] = (out["fused_compile_replay_per_s"]
                                / out["per_record_compile_replay_per_s"])
    return out


def bench_model(model: str, C: float, seq_len: int, gb: int,
                backends, repeats: int = 3) -> dict:
    sc = Scenario(model=model, total_tflops=C, seq_len=seq_len,
                  global_batch=gb, fabrics=("oi",), refine_top=8)
    # the deepest feasible interleaved pipeline: the worst-case
    # wavefront DAG (largest level count), replicated K times
    deep, _ = pipelined_programs(sc, schedule="interleaved", top=8,
                                 deep=True)
    # the mixed top-records batch Study.run(validate_top=K) replays
    _, built = pipelined_programs(sc, schedule="1f1b", top=8)
    mixed = [built[i % len(built)] for i in range(BATCH_K)]

    # scalar engine on the deep program (the fidelity ground truth for
    # the same DAG the batch rows replay)
    t_sc, n_events = float("inf"), 0
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = replay(deep)
        t_sc = min(t_sc, time.perf_counter() - t0)
        n_events = r.n_events

    batch = {b: {str(K): _batch_rate([deep] * K, b, repeats)
                 for K in BATCH_KS} for b in backends}
    mixed_rates = {b: _batch_rate(mixed, b, repeats) for b in backends}
    fused = _fused_vs_baseline(sc, repeats)

    res = {
        "model": model, "C_tflops": C,
        "schedule": deep.schedule, "pp": deep.n_stages, "v": deep.v,
        "n_micro": deep.n_micro,
        "n_events": n_events,
        "scalar_replay_s": t_sc,
        "events_per_s": n_events / t_sc,
        "batch_k": list(BATCH_KS),
        "batch_records_per_s": batch,
        "mixed_top8_records_per_s": mixed_rates,
        **fused,
    }
    if "numpy" in batch:
        res["batch_speedup_vs_scalar"] = \
            batch["numpy"][str(BATCH_K)] * t_sc
    if "numpy" in batch and "jax" in batch:
        for K in BATCH_KS:
            res[f"jax_speedup_k{K}"] = (batch["jax"][str(K)]
                                        / batch["numpy"][str(K)])
    return res


def run(quick: bool = False, backend: str = "both") -> int:
    if quick:
        # same measurement + floors as `cli bench check --which events`:
        # scalar engine + BOTH backends on the K=64 top-records batch
        got = enforce("events", measure_events_quick(), root=REPO)
        return int(any(not row["ok"] for row in got))
        # quick mode never rewrites JSON

    backends = ("numpy", "jax") if backend == "both" else (backend,)
    results = [bench_model(*m, backends=backends) for m in MODELS]

    rows = []
    for r in results:
        for b in backends:
            rows.append(
                [r["model"], b,
                 f"pp{r['pp']}xv{r['v']}xnm{r['n_micro']}",
                 r["n_events"], f"{r['events_per_s']:.0f}"]
                + [f"{r['batch_records_per_s'][b][str(K)]:.0f}"
                   for K in BATCH_KS]
                + [f"{r.get(f'jax_speedup_k{BATCH_KS[0]}', 0):.1f}"
                   if b == "jax" else ""]
                + ([f"{r['fused_compile_replay_per_s']:.0f}",
                    f"{r['fused_speedup_k64']:.1f}"]
                   if b == backends[0] else ["", ""]))
    emit("events_throughput", rows,
         ["model", "backend", "deep_shape", "events", "events_per_s"]
         + [f"batch_rec_per_s_k{K}" for K in BATCH_KS]
         + ["jax_speedup_k64", "fused_rec_per_s_k64",
            "fused_speedup_k64"])

    payload = {"bench": "events_throughput", "results": results,
               "quick_floors": dict(DEFAULT_FLOORS["events"])}
    OUT.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {OUT}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="tinyllama only, both backends + regression "
                         "floors (CI smoke); does not rewrite "
                         "BENCH_events.json")
    ap.add_argument("--backend", default="both",
                    choices=("numpy", "jax", "auto", "both"),
                    help="wavefront backend(s) to measure in full mode")
    args = ap.parse_args(argv)
    return run(quick=args.quick, backend=args.backend)


if __name__ == "__main__":
    sys.exit(main())
