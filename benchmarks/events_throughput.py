"""Event-engine throughput benchmark -> BENCH_events.json.

Tracks the two replay paths of ``repro.events``:

* scalar discrete-event engine — replays/s and events/s on one compiled
  program per model (the fidelity-harness ground truth);
* vectorized batch replay — records/s when K replicated top records are
  replayed through the NumPy wavefront at once (the path
  ``Study.run(validate_top=K)`` stamps records with), and its speedup
  over K scalar replays.

    PYTHONPATH=src:. python benchmarks/events_throughput.py
    PYTHONPATH=src:. python benchmarks/events_throughput.py --quick

``--quick`` runs tinyllama only and gates it on the floors owned by
``repro.obs.bench`` (the CI smoke mode — also reachable as
``python -m repro.cli bench check --which events --quick``).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from benchmarks.common import emit
from repro.api import Scenario
from repro.events import replay, replay_batch
from repro.obs.bench import (BATCH_K, DEFAULT_FLOORS, enforce,
                             pipelined_programs)

REPO = Path(__file__).resolve().parents[1]
OUT = REPO / "BENCH_events.json"

MODELS = [
    ("tinyllama_1_1b", 1e6, 4096, 256),
    ("qwen3_moe_235b_a22b", 4e6, 10240, 512),
    ("mixtral_8x7b", 4e6, 8192, 256),
]


def bench_model(model: str, C: float, seq_len: int, gb: int,
                repeats: int = 3) -> dict:
    sc = Scenario(model=model, total_tflops=C, seq_len=seq_len,
                  global_batch=gb, fabrics=("oi",), refine_top=8)
    # pipelined_programs times a PIPELINED program (big DAG — the
    # realistic engine load); top records are often pp=1, so it picks
    # the best feasible pp>1 point on the winning MCM when needed
    prog, built = pipelined_programs(sc, schedule="1f1b", top=8)

    # scalar engine
    t_scalar, n_events = [], 0
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = replay(prog)
        t_scalar.append(time.perf_counter() - t0)
        n_events = r.n_events
    t_sc = min(t_scalar)

    # batch replay over K replicated records
    programs = [built[i % len(built)] for i in range(BATCH_K)]
    t_batch = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        replay_batch(programs)
        t_batch.append(time.perf_counter() - t0)
    t_b = min(t_batch)

    return {
        "model": model, "C_tflops": C,
        "pp": prog.n_stages, "n_micro": prog.n_micro,
        "n_events": n_events,
        "scalar_replay_s": t_sc,
        "events_per_s": n_events / t_sc,
        "batch_k": BATCH_K,
        "batch_s": t_b,
        "batch_records_per_s": BATCH_K / t_b,
        "batch_speedup_vs_scalar": (t_sc * BATCH_K) / t_b,
    }


def run(quick: bool = False) -> int:
    models = MODELS[:1] if quick else MODELS
    results = [bench_model(*m) for m in models]

    rows = [[r["model"], f"pp{r['pp']}xnm{r['n_micro']}", r["n_events"],
             f"{r['scalar_replay_s'] * 1e3:.1f}",
             f"{r['events_per_s']:.0f}",
             f"{r['batch_records_per_s']:.0f}",
             f"{r['batch_speedup_vs_scalar']:.1f}"]
            for r in results]
    emit("events_throughput", rows,
         ["model", "shape", "events", "scalar_ms", "events_per_s",
          "batch_rec_per_s", "batch_speedup"])

    if quick:
        r = results[0]
        got = enforce("events", {
            "events_per_s": r["events_per_s"],
            "batch_records_per_s": r["batch_records_per_s"]}, root=REPO)
        return int(any(not row["ok"] for row in got))
        # quick mode never rewrites JSON

    payload = {"bench": "events_throughput", "results": results,
               "quick_floors": dict(DEFAULT_FLOORS["events"])}
    OUT.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {OUT}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="tinyllama only + regression floors (CI smoke); "
                         "does not rewrite BENCH_events.json")
    args = ap.parse_args(argv)
    return run(quick=args.quick)


if __name__ == "__main__":
    sys.exit(main())
