"""Fig 9 reproduction: optimal MCM scale (a) and single-die scale (b).

(a) sweep dies-per-MCM 4..64 at fixed C=8e6: small MCMs match large ones
    on throughput (OI narrows the HBD gap) while large MCMs cut cost
    (insight 3).
(b) sweep single-die scale 1, 1/2, 1/4 at fixed C and MCM compute:
    quarter dies lose little performance and cut cost ~23% (insight 4).
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit
from repro.core import inner_search, mcm_from_compute, cluster_cost
from repro.core.hardware import DEFAULT_HW, scaled_die
from repro.core.workload import paper_workload

C = 8e6


def run(budget: int = 40):
    w = paper_workload(global_batch=512)
    t = lambda p: p.throughput if p else 0.0

    # ---- (a) MCM scale ----
    rows_a = []
    perf, cost = {}, {}
    for dies in (4, 8, 16, 32, 64):
        mcm = mcm_from_compute(C, dies_per_mcm=dies, m=6)
        best, _ = inner_search(w, mcm, fabric="oi", budget=budget)
        perf[dies] = t(best)
        cost[dies] = best.cost if best else float("inf")
        rows_a.append([dies, f"{perf[dies]:.3e}",
                       f"{cost[dies] / 1e6:.1f}",
                       best.strategy.asdict() if best else "-"])
    emit("fig9a_mcm_scale", rows_a,
         ["dies_per_mcm", "tok_s", "cost_M$", "strategy"])
    small_vs_large = perf[4] / max(perf[64], 1)
    cost_ratio = cost[64] / max(cost[4], 1)
    print(f"insight 3: perf(4-die)/perf(64-die) = {small_vs_large:.2f} "
          f"(paper: ~1.0); cost(64)/cost(4) = {cost_ratio:.2f} (<1 means "
          f"large integration is cheaper)")

    # ---- (b) single-die scale ----
    rows_b = []
    perf_b, cost_b, sil_b = {}, {}, {}
    for scale, dies in ((1.0, 16), (0.5, 32), (0.25, 64)):
        hw = scaled_die(DEFAULT_HW, scale)
        mcm = mcm_from_compute(C, dies_per_mcm=dies, m=max(
            2, int(6 * scale)), hw=hw)
        best, _ = inner_search(w, mcm, fabric="oi", budget=budget, hw=hw)
        perf_b[scale] = t(best)
        cost_b[scale] = best.cost if best else float("inf")
        cb = cluster_cost(best.mcm, best.topo, fabric="oi", hw=hw) \
            if best else None
        # silicon-side cost (die yield + HBM + packaging) — the economics
        # insight 4 is about; optics cost is topology-volatile and
        # reported separately
        sil_b[scale] = (cb.silicon + cb.hbm + cb.packaging) if cb else 0
        rows_b.append([scale, dies, f"{perf_b[scale]:.3e}",
                       f"{cost_b[scale] / 1e6:.1f}",
                       f"{sil_b[scale] / 1e6:.1f}"])
    emit("fig9b_die_scale", rows_b,
         ["die_scale", "dies_per_mcm", "tok_s", "cost_M$",
          "silicon_side_M$"])
    perf_drop = 1 - perf_b[0.25] / max(perf_b[1.0], 1)
    cost_cut = 1 - cost_b[0.25] / max(cost_b[1.0], 1)
    sil_cut = 1 - sil_b[0.25] / max(sil_b[1.0], 1)
    print(f"insight 4: quarter-die perf drop {perf_drop * 100:.0f}% "
          f"(paper: small); silicon-side cost cut {sil_cut * 100:.0f}% "
          f"(paper: ~23% total); total incl. optics "
          f"{cost_cut * 100:.0f}%")
    return {"i3_perf_ratio": small_vs_large, "i3_cost_ratio": cost_ratio,
            "i4_perf_drop": perf_drop, "i4_cost_cut": cost_cut,
            "i4_silicon_cut": sil_cut}


if __name__ == "__main__":
    run()
