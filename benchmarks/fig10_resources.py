"""Fig 10 reproduction: memory-die count (a) and CPO edge ratio (b).

(a) sweep m (HBM stacks per logic die): throughput rises until m ~ 14
    for MCMs (insight 5 — NoP-class interconnect needs more memory bw
    than GPUs' NVLink did), cost rises linearly.
(b) sweep r (CPO edge fraction): throughput saturates past r ~ 0.6 while
    OCS cost keeps climbing (insight 6).
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit
from repro.core import inner_search, mcm_from_compute
from repro.core.mcm import MCMArch
from repro.core.workload import paper_workload

C = 8e6


def run(budget: int = 32):
    w = paper_workload(global_batch=512)
    t = lambda p: p.throughput if p else 0.0

    rows_a = []
    thpt_by_m = {}
    base = mcm_from_compute(C, dies_per_mcm=16, m=6)
    for m in (2, 4, 6, 8, 10, 12, 14, 16):
        mcm = dataclasses.replace(base, m=m)
        if not mcm.feasible():
            rows_a.append([m, "infeasible", "-"])
            continue
        best, _ = inner_search(w, mcm, fabric="oi", budget=budget)
        thpt_by_m[m] = t(best)
        rows_a.append([m, f"{thpt_by_m[m]:.3e}",
                       f"{(best.cost if best else 0) / 1e6:.1f}"])
    emit("fig10a_memory_dies", rows_a, ["m", "tok_s", "cost_M$"])
    ms = sorted(thpt_by_m)
    m_opt = max(thpt_by_m, key=thpt_by_m.get)
    print(f"insight 5: throughput-optimal m = {m_opt} (paper: ~14); "
          f"gain m=2 -> m_opt: "
          f"{thpt_by_m[m_opt] / max(thpt_by_m[ms[0]], 1):.2f}x")

    rows_b = []
    thpt_by_r, cost_by_r = {}, {}
    for r in (0.2, 0.4, 0.6, 0.8, 1.0):
        mcm = dataclasses.replace(base, cpo_ratio=r)
        if not mcm.feasible():
            rows_b.append([r, "infeasible", "-"])
            continue
        best, _ = inner_search(w, mcm, fabric="oi", budget=budget)
        thpt_by_r[r] = t(best)
        cost_by_r[r] = best.cost if best else 0
        rows_b.append([r, mcm.total_links, f"{thpt_by_r[r]:.3e}",
                       f"{cost_by_r[r] / 1e6:.1f}"])
    emit("fig10b_cpo_ratio", rows_b, ["r", "links", "tok_s", "cost_M$"])
    if 0.6 in thpt_by_r and 1.0 in thpt_by_r:
        extra_perf = thpt_by_r[1.0] / max(thpt_by_r[0.6], 1) - 1
        extra_cost = cost_by_r[1.0] / max(cost_by_r[0.6], 1) - 1
        print(f"insight 6: r 0.6 -> 1.0 adds {extra_perf * 100:.0f}% perf "
              f"for {extra_cost * 100:.0f}% cost (paper: disproportionate "
              f"beyond r ~ 0.6)")
    return {"m_opt": m_opt, "thpt_by_r": thpt_by_r}


if __name__ == "__main__":
    run()
