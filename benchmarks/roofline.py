"""Roofline analysis from the dry-run artifacts (assignment §Roofline).

Per (arch x shape x mesh): three terms in seconds —
  compute    = HLO_FLOPs / peak_FLOP/s        (per chip)
  memory     = HLO_bytes / HBM_bw
  collective = collective_wire_bytes / link_bw
plus the dominant term, MODEL_FLOPS/HLO_FLOPs usefulness ratio, and a
bottleneck note.  TPU v5e: 197 TF bf16, 819 GB/s HBM, 50 GB/s/link.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit
from repro.core.hardware import (TPU_V5E_FLOPS, TPU_V5E_HBM_BW,
                                 TPU_V5E_ICI_BW)

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def _advice(dom, rec):
    if dom == "compute":
        return "raise MODEL/HLO ratio (less remat/masked-waste)"
    if dom == "memory":
        return "fuse/bf16 intermediates; shard or shrink caches"
    return "rebalance sharding to cut collective bytes"


def analyze(mesh="single"):
    rows, recs = [], []
    for f in sorted(ART.glob(f"*__{mesh}.json")):
        rec = json.loads(f.read_text())
        if rec.get("skipped"):
            rows.append([rec["arch"], rec["shape"], "SKIP",
                         rec.get("reason", ""), "", "", "", "", ""])
            continue
        comp = rec["hlo_flops_per_device"] / TPU_V5E_FLOPS
        mem = rec["hlo_bytes_per_device"] / TPU_V5E_HBM_BW
        coll = rec["coll_wire_bytes_per_device"] / TPU_V5E_ICI_BW
        terms = {"compute": comp, "memory": mem, "collective": coll}
        dom = max(terms, key=terms.get)
        model_per_dev = rec["model_flops_step"] / rec["n_chips"]
        useful = model_per_dev / max(rec["hlo_flops_per_device"], 1.0)
        # roofline fraction: model-useful compute time over the
        # achievable step floor (max of the three terms)
        frac = (model_per_dev / TPU_V5E_FLOPS) / max(terms.values())
        recs.append(dict(rec, terms=terms, dom=dom, useful=useful,
                         frac=frac))
        rows.append([rec["arch"], rec["shape"], f"{comp:.4f}",
                     f"{mem:.4f}", f"{coll:.4f}", dom,
                     f"{useful:.2f}", f"{frac:.2f}", _advice(dom, rec)])
    emit(f"roofline_{mesh}", rows,
         ["arch", "shape", "compute_s", "memory_s", "collective_s",
          "dominant", "model/hlo", "roofline_frac", "next_move"])
    return recs


def run():
    recs = analyze("single")
    analyze("multi")
    live = [r for r in recs if "terms" in r]
    if live:
        worst = min(live, key=lambda r: r["frac"])
        collb = max(live, key=lambda r: r["terms"]["collective"]
                    / max(sum(r["terms"].values()), 1e-12))
        print(f"\nworst roofline fraction: {worst['arch']}/{worst['shape']}"
              f" ({worst['frac']:.2f})")
        print(f"most collective-bound:   {collb['arch']}/{collb['shape']}")
    return recs


if __name__ == "__main__":
    run()
