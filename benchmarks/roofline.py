"""Roofline analysis from the dry-run artifacts (assignment §Roofline).

Per (arch x shape x mesh): three terms in seconds —
  compute    = HLO_FLOPs / peak_FLOP/s        (per chip)
  memory     = HLO_bytes / HBM_bw
  collective = collective_wire_bytes / link_bw
plus the dominant term, MODEL_FLOPS/HLO_FLOPs usefulness ratio, and a
bottleneck note.

Peaks are a parameter, not import-time constants: the default is the
datasheet TPU v5e (197 TF bf16, 819 GB/s HBM, 50 GB/s/link), but
``--calib CALIB.json`` swaps in the execution-grounded peaks fitted by
``python -m repro.cli calibrate``, and ``resolve_peaks`` also accepts
an ``HW`` instance (the simulator's own constants).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from pathlib import Path
from typing import Optional, Union

from benchmarks.common import emit
from repro.core.hardware import HW

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


@dataclasses.dataclass(frozen=True)
class Peaks:
    """The three roofline denominators plus where they came from."""
    flops: float        # peak FLOP/s per chip
    hbm_bw: float       # HBM bytes/s per chip
    ici_bw: float       # interconnect bytes/s per link
    source: str = "tpu_v5e"


def resolve_peaks(source: Union[None, HW, str, Path] = None) -> Peaks:
    """Build ``Peaks`` from (in order of preference):

    * ``None`` — the TPU v5e datasheet constants (the historical
      behaviour);
    * an ``HW`` instance — the simulator's own per-die constants;
    * a path — a ``CALIB.json`` artifact's fitted effective peaks
      (``ici_bw`` stays at the v5e datasheet value: calibration runs
      single-host, so no link measurement exists).
    """
    from repro.core.hardware import (TPU_V5E_FLOPS, TPU_V5E_HBM_BW,
                                     TPU_V5E_ICI_BW)
    if source is None:
        return Peaks(TPU_V5E_FLOPS, TPU_V5E_HBM_BW, TPU_V5E_ICI_BW)
    if isinstance(source, HW):
        return Peaks(source.die_tflops * 1e12 * source.mfu_ceiling,
                     source.hbm_bw_per_die, source.oi_link_bw,
                     source="hw")
    from repro.calib import load_calibration
    calib = load_calibration(str(source))
    eff = calib["effective"]
    return Peaks(eff["die_tflops"] * 1e12, eff["hbm_bw_per_die"],
                 TPU_V5E_ICI_BW, source=str(source))


def _advice(dom, rec):
    if dom == "compute":
        return "raise MODEL/HLO ratio (less remat/masked-waste)"
    if dom == "memory":
        return "fuse/bf16 intermediates; shard or shrink caches"
    return "rebalance sharding to cut collective bytes"


def analyze(mesh="single", peaks: Optional[Peaks] = None):
    peaks = peaks if peaks is not None else resolve_peaks()
    rows, recs = [], []
    for f in sorted(ART.glob(f"*__{mesh}.json")):
        rec = json.loads(f.read_text())
        if rec.get("skipped"):
            rows.append([rec["arch"], rec["shape"], "SKIP",
                         rec.get("reason", ""), "", "", "", "", ""])
            continue
        comp = rec["hlo_flops_per_device"] / peaks.flops
        mem = rec["hlo_bytes_per_device"] / peaks.hbm_bw
        coll = rec["coll_wire_bytes_per_device"] / peaks.ici_bw
        terms = {"compute": comp, "memory": mem, "collective": coll}
        dom = max(terms, key=terms.get)
        model_per_dev = rec["model_flops_step"] / rec["n_chips"]
        useful = model_per_dev / max(rec["hlo_flops_per_device"], 1.0)
        # roofline fraction: model-useful compute time over the
        # achievable step floor (max of the three terms)
        frac = (model_per_dev / peaks.flops) / max(terms.values())
        recs.append(dict(rec, terms=terms, dom=dom, useful=useful,
                         frac=frac))
        rows.append([rec["arch"], rec["shape"], f"{comp:.4f}",
                     f"{mem:.4f}", f"{coll:.4f}", dom,
                     f"{useful:.2f}", f"{frac:.2f}", _advice(dom, rec)])
    emit(f"roofline_{mesh}", rows,
         ["arch", "shape", "compute_s", "memory_s", "collective_s",
          "dominant", "model/hlo", "roofline_frac", "next_move"])
    return recs


def run(calib: Optional[str] = None):
    peaks = resolve_peaks(calib)
    print(f"peaks [{peaks.source}]: {peaks.flops / 1e12:.1f} TF, "
          f"{peaks.hbm_bw / 1e9:.0f} GB/s HBM, "
          f"{peaks.ici_bw / 1e9:.0f} GB/s/link")
    recs = analyze("single", peaks=peaks)
    analyze("multi", peaks=peaks)
    live = [r for r in recs if "terms" in r]
    if live:
        worst = min(live, key=lambda r: r["frac"])
        collb = max(live, key=lambda r: r["terms"]["collective"]
                    / max(sum(r["terms"].values()), 1e-12))
        print(f"\nworst roofline fraction: {worst['arch']}/{worst['shape']}"
              f" ({worst['frac']:.2f})")
        print(f"most collective-bound:   {collb['arch']}/{collb['shape']}")
    return recs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--calib", default=None, metavar="CALIB_JSON",
                    help="use fitted peaks from this calibration "
                         "artifact instead of TPU v5e datasheet values")
    args = ap.parse_args(argv)
    run(calib=args.calib)
    return 0


if __name__ == "__main__":
    sys.exit(main())
