"""Fig 8 reproduction: training-throughput scaling across cluster types.

GPU(NVLink+IB) vs Chiplet+IB vs RailX(+reuse) vs ChipLight, sweeping the
total compute C.  Headline paper claims validated at the end:
  * the GPU scaling point (growth-rate knee, paper: ~4e6 TFLOPS),
  * ChipLight / GPU gain at the largest C (paper: 19.58x at its endpoint),
  * ChipLight / RailX at C=16e6 (paper: +41%),
  * no-reuse throughput drop (paper: -30%), measured on the
    CP+EP-active strategy where reuse binds (the paper's configuration).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import emit
from repro.core import (Strategy, evaluate_point, inner_search,
                        mcm_from_compute)
from repro.core.optimizer import chiplight_optimize, railx_search
from repro.core.workload import paper_workload
from repro.dse.batched_sim import batched_simulate
from repro.dse.space import StrategyBatch

CS = [1e6, 2e6, 4e6, 8e6, 16e6, 32e6, 64e6]


def run(budget: int = 48, outer_iters: int = 6):
    w = paper_workload(global_batch=512)
    rows = []
    results = {}
    t = lambda p: p.throughput if p else 0.0
    for c in CS:
        gpu = mcm_from_compute(c, dies_per_mcm=8, m=6)
        bg, _ = inner_search(w, gpu, fabric="nvlink", budget=budget)
        chip = mcm_from_compute(c, dies_per_mcm=16, m=6)
        bi, _ = inner_search(w, chip, fabric="ib", budget=budget)
        dse = chiplight_optimize(w, c, dies_per_mcm=16, m0=6,
                                 outer_iters=outer_iters,
                                 inner_budget=budget)
        bc = dse.best
        mcm_opt = bc.mcm if bc else chip
        br, _ = railx_search(w, mcm_opt, reuse=True, budget=budget)
        bn, _ = inner_search(w, mcm_opt, fabric="oi", reuse=False,
                             budget=budget)
        results[c] = dict(gpu=bg, ib=bi, cl=bc, railx=br, noreuse=bn)
        rows.append([f"{c:.0e}", f"{t(bg):.3e}", f"{t(bi):.3e}",
                     f"{t(br):.3e}", f"{t(bc):.3e}", f"{t(bn):.3e}",
                     bc.strategy.asdict() if bc else "-",
                     (bc.mcm.m, round(bc.mcm.cpo_ratio, 2)) if bc else "-"])
    emit("fig8_scaling", rows,
         ["C_tflops", "gpu_tok_s", "chiplet_ib_tok_s", "railx_tok_s",
          "chiplight_tok_s", "cl_noreuse_tok_s", "cl_strategy", "cl_mcm"])

    # ---- headline claims ----
    # scaling point: first C where gpu efficiency < 70% of small-scale
    eff0 = t(results[CS[0]]["gpu"]) / CS[0]
    knee = next((c for c in CS
                 if t(results[c]["gpu"]) / c < 0.7 * eff0), None)
    big = results[CS[-1]]
    gain_gpu = t(big["cl"]) / max(t(big["gpu"]), 1)
    r16 = results[16e6]
    gain_railx16 = t(r16["cl"]) / max(t(r16["railx"]), 1)

    # reuse ablation on the paper-style CP+EP-active strategies at 16e6,
    # under the paper's switching assumption ('paper' mode) AND our
    # physical bank-swap model ('banked' — quantifies the assumption).
    # The whole candidate set goes through the batched engine at once.
    mcm = r16["cl"].mcm if r16["cl"] else mcm_from_compute(
        16e6, dies_per_mcm=16, m=6)
    hw_paper = dataclasses.replace(mcm.hw, ocs_reuse_mode="paper")
    cand = StrategyBatch.from_strategies(list(_ep_cp_strategies(w, mcm)))

    def max_reuse_drop(hw):
        """Batched screen over all candidates, then confirm the winner
        through evaluate_point so the reported drop comes from a point
        with a realizable physical rail topology."""
        on = batched_simulate(w, cand, mcm, fabric="oi", reuse=True, hw=hw)
        off = batched_simulate(w, cand, mcm, fabric="oi", reuse=False,
                               hw=hw)
        ok = on.feasible & off.feasible & on.reuse_active
        if not ok.any():
            return None
        with np.errstate(invalid="ignore", divide="ignore"):
            drops = np.where(ok, 1 - off.throughput / on.throughput,
                             -np.inf)
        for i in np.argsort(-drops):
            if not ok[i]:
                break
            s = cand.take(np.array([i])).to_strategies()[0]
            pr = evaluate_point(w, s, mcm, fabric="oi", reuse=True, hw=hw)
            pn = evaluate_point(w, s, mcm, fabric="oi", reuse=False, hw=hw)
            if pr and pn and pr.sim.logs.get("reuse_active"):
                return 1 - pn.throughput / pr.throughput
        return None

    reuse_drop = max_reuse_drop(hw_paper)
    banked_drop = max_reuse_drop(mcm.hw)

    summary = {
        "gpu_scaling_point_C": knee,
        "chiplight_over_gpu_endpoint": gain_gpu,
        "chiplight_over_railx_16e6": gain_railx16,
        "reuse_drop_paper_mode": reuse_drop,
        "reuse_drop_banked_mode": banked_drop,
    }
    print("\n--- paper-claim validation ---")
    print(f"GPU scaling point:      C ~ {knee:.0e} (paper: 4e6)")
    print(f"ChipLight/GPU endpoint: {gain_gpu:.2f}x (paper: 19.58x)")
    print(f"ChipLight/RailX @16e6:  {gain_railx16:.2f}x (paper: 1.41x)")
    print(f"reuse-off drop (paper switching assumption): "
          f"{(reuse_drop or 0) * 100:.0f}% (paper: 30%)")
    print(f"reuse-off drop (banked 10ms-MEMS model):     "
          f"{(banked_drop or 0) * 100:.0f}% — reuse infeasible with "
          f"deployed MEMS at this scale unless switching <~100us")
    return summary


def _ep_cp_strategies(w, mcm):
    """CP+EP-active strategies matching the paper's reuse experiment."""
    n = mcm.n_devices
    out = []
    for tp in (8, 16):
        for ep in (8, 16, 32):
            for cp in (4, 8, 16, 32):
                for pp in (1, 2, 4, 8):
                    dp = n // (tp * ep * cp * pp)
                    if dp < 1 or tp * ep * cp * pp * dp != n:
                        continue
                    if w.global_batch % dp:
                        continue
                    nm = min(4 * pp, max(w.global_batch // dp, 1))
                    if pp > 1 and nm < pp:
                        continue
                    out.append(Strategy(tp=tp, dp=dp, pp=pp, cp=cp, ep=ep,
                                        n_micro=nm if pp > 1 else 1))
    return out      # no cap: the batched engine evaluates them all at once


if __name__ == "__main__":
    run()
