"""Kernel microbenchmarks: interpret-mode correctness timing + the
xla-blockwise path wall-time per call on CPU (not TPU numbers — the
kernels' TPU performance is assessed structurally via the roofline)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.kernels import ops


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    for s, blk in ((512, 128), (1024, 256)):
        q = jax.random.normal(key, (1, 8, s, 64), jnp.float32)
        k = jax.random.normal(key, (1, 2, s, 64), jnp.float32)
        v = jax.random.normal(key, (1, 2, s, 64), jnp.float32)
        f_scan = jax.jit(lambda q, k, v: ops.flash_attention(
            q, k, v, block=blk, backend="xla"))
        f_blk = jax.jit(lambda q, k, v: ops.flash_attention(
            q, k, v, block=blk, backend="xla_blocked"))
        us1 = _time(f_scan, q, k, v)
        us2 = _time(f_blk, q, k, v)
        rows.append([f"flash_attn_s{s}", f"{us1:.0f}",
                     f"blocked={us2:.0f}us speedup={us1 / us2:.2f}x"])

    bb, s, h, p, g, n = 1, 512, 8, 64, 1, 64
    x = jax.random.normal(key, (bb, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(key, (bb, s, h)))
    a = -jnp.exp(jax.random.normal(key, (h,)) * 0.5)
    bm = jax.random.normal(key, (bb, s, g, n)) * 0.3
    cm = jax.random.normal(key, (bb, s, g, n)) * 0.3
    f_ssd = jax.jit(lambda *t: ops.ssd(*t, chunk=128, backend="xla"))
    rows.append(["ssd_s512", f"{_time(f_ssd, x, dt, a, bm, cm):.0f}", ""])

    xx = jax.random.normal(key, (4096, 1024))
    w = jnp.ones((1024,))
    f_rn = jax.jit(lambda x_: ops.rmsnorm(x_, w))
    rows.append(["rmsnorm_4096x1024", f"{_time(f_rn, xx):.0f}", ""])
    emit("kernels_micro", rows, ["name", "us_per_call", "derived"])
    return rows


if __name__ == "__main__":
    run()
