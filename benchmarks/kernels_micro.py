"""Kernel microbenchmarks: interpret-mode correctness timing + the
xla-blockwise path wall-time per call on CPU (not TPU numbers — the
kernels' TPU performance is assessed structurally via the roofline,
and the fitted efficiency curves via ``python -m repro.cli calibrate``).

    PYTHONPATH=src:. python benchmarks/kernels_micro.py
    PYTHONPATH=src:. python benchmarks/kernels_micro.py --quick

``--quick`` gates the three headline kernels against the floors owned
by ``repro.obs.bench`` (the CI smoke mode — also reachable as
``python -m repro.cli bench check --which kernels --quick``).  Timing
goes through ``repro.obs.bench.time_fn`` (best-of-reps after warmup),
the same helper the calibration profiler uses.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.kernels import ops
from repro.obs.bench import (DEFAULT_FLOORS, enforce,
                             measure_kernels_quick, time_fn)

REPO = Path(__file__).resolve().parents[1]
OUT = REPO / "BENCH_kernels.json"


def _us(fn, *args, reps: int = 3) -> float:
    return time_fn(fn, *args, reps=reps) * 1e6


def bench_all() -> list:
    rows = []
    key = jax.random.PRNGKey(0)
    for s, blk in ((512, 128), (1024, 256)):
        q = jax.random.normal(key, (1, 8, s, 64), jnp.float32)
        k = jax.random.normal(key, (1, 2, s, 64), jnp.float32)
        v = jax.random.normal(key, (1, 2, s, 64), jnp.float32)
        f_scan = jax.jit(lambda q, k, v: ops.flash_attention(
            q, k, v, block=blk, backend="xla"))
        f_blk = jax.jit(lambda q, k, v: ops.flash_attention(
            q, k, v, block=blk, backend="xla_blocked"))
        us1 = _us(f_scan, q, k, v)
        us2 = _us(f_blk, q, k, v)
        rows.append([f"flash_attn_s{s}", f"{us1:.0f}",
                     f"blocked={us2:.0f}us speedup={us1 / us2:.2f}x"])

    bb, s, h, p, g, n = 1, 512, 8, 64, 1, 64
    x = jax.random.normal(key, (bb, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(key, (bb, s, h)))
    a = -jnp.exp(jax.random.normal(key, (h,)) * 0.5)
    bm = jax.random.normal(key, (bb, s, g, n)) * 0.3
    cm = jax.random.normal(key, (bb, s, g, n)) * 0.3
    f_ssd = jax.jit(lambda *t: ops.ssd(*t, chunk=128, backend="xla"))
    rows.append(["ssd_s512", f"{_us(f_ssd, x, dt, a, bm, cm):.0f}", ""])

    xx = jax.random.normal(key, (4096, 1024))
    w = jnp.ones((1024,))
    f_rn = jax.jit(lambda x_: ops.rmsnorm(x_, w))
    rows.append(["rmsnorm_4096x1024", f"{_us(f_rn, xx):.0f}", ""])
    emit("kernels_micro", rows, ["name", "us_per_call", "derived"])
    return rows


def run(quick: bool = False) -> int:
    if quick:
        # same measurement + floors as `cli bench check --which kernels`
        got = enforce("kernels", measure_kernels_quick(), root=REPO)
        return int(any(not row["ok"] for row in got))
        # quick mode never rewrites JSON

    rows = bench_all()
    payload = {"bench": "kernels_micro",
               "results": [dict(zip(("name", "us_per_call", "derived"),
                                    r)) for r in rows],
               "quick": measure_kernels_quick(),
               "quick_floors": dict(DEFAULT_FLOORS["kernels"])}
    OUT.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {OUT}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="three headline kernels vs regression floors "
                         "(CI smoke); does not rewrite "
                         "BENCH_kernels.json")
    args = ap.parse_args(argv)
    return run(quick=args.quick)


if __name__ == "__main__":
    sys.exit(main())
