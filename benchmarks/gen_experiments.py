"""Generate EXPERIMENTS.md sections from artifacts (dryrun/bench/perf).

    PYTHONPATH=src python -m benchmarks.gen_experiments
"""
from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DRY = ROOT / "artifacts" / "dryrun"
BENCH = ROOT / "artifacts" / "bench"
PERF = ROOT / "artifacts" / "perf"

from repro.core.hardware import (TPU_V5E_FLOPS, TPU_V5E_HBM_BW,
                                 TPU_V5E_ICI_BW)


def _cells(mesh):
    out = []
    for f in sorted(DRY.glob(f"*__{mesh}.json")):
        out.append(json.loads(f.read_text()))
    return out


def dryrun_section():
    lines = ["## §Dry-run", "",
             "Every (architecture x shape) cell lowered AND compiled "
             "(`.lower().compile()`) against the 16x16=256-chip single-pod "
             "mesh and the 2x16x16=512-chip multi-pod mesh "
             "(`--xla_force_host_platform_device_count=512`, AOT "
             "ShapeDtypeStructs, zero allocation).  Skipped cells follow "
             "DESIGN.md §shape-cell-skips (long_500k for pure "
             "full-attention archs).", "",
             "| arch | shape | mesh | params/dev GB | temp GB | "
             "flops/dev | HBM bytes/dev | wire bytes/dev | collectives |",
             "|---|---|---|---|---|---|---|---|---|"]
    n_ok = n_skip = 0
    for mesh in ("single", "multi"):
        for r in _cells(mesh):
            if r.get("skipped"):
                n_skip += 1
                lines.append(f"| {r['arch']} | {r['shape']} | {mesh} | "
                             f"SKIP ({r['reason'][:40]}...) | | | | | |")
                continue
            n_ok += 1
            cc = r.get("coll_counts", {})
            cstr = " ".join(f"{k.split('-')[0]}:{v}"
                            for k, v in sorted(cc.items()))
            lines.append(
                f"| {r['arch']} | {r['shape']} | {mesh} | "
                f"{r['mem_argument_bytes'] / 1e9:.2f} | "
                f"{r['mem_temp_bytes'] / 1e9:.2f} | "
                f"{r['hlo_flops_per_device']:.2e} | "
                f"{r['hlo_bytes_per_device']:.2e} | "
                f"{r['coll_wire_bytes_per_device']:.2e} | {cstr} |")
    lines.insert(2, f"**{n_ok} compiled cells, {n_skip} documented skips** "
                    f"(see table).")
    return "\n".join(lines)


def roofline_section():
    lines = ["## §Roofline", "",
             "Single-pod (256 x TPU v5e: 197 TF bf16, 819 GB/s HBM, "
             "50 GB/s/link).  Terms in seconds per step; scan trip counts "
             "recovered by two-point depth extrapolation (DESIGN.md).  "
             "`MODEL/HLO` = 6·N_active·D / compiled FLOPs (usefulness); "
             "`frac` = useful-compute time / dominant term.", "",
             "| arch | shape | compute_s | memory_s | collective_s | "
             "dominant | MODEL/HLO | frac | next move |",
             "|---|---|---|---|---|---|---|---|---|"]
    worst, collb = None, None
    for r in _cells("single"):
        if r.get("skipped"):
            continue
        comp = r["hlo_flops_per_device"] / TPU_V5E_FLOPS
        mem = r["hlo_bytes_per_device"] / TPU_V5E_HBM_BW
        coll = r["coll_wire_bytes_per_device"] / TPU_V5E_ICI_BW
        terms = {"compute": comp, "memory": mem, "collective": coll}
        dom = max(terms, key=terms.get)
        model = r["model_flops_step"] / r["n_chips"]
        useful = model / max(r["hlo_flops_per_device"], 1.0)
        frac = (model / TPU_V5E_FLOPS) / max(terms.values())
        advice = {"compute": "cut remat/masked waste (raise MODEL/HLO)",
                  "memory": "shrink caches / fuse intermediates",
                  "collective": "re-map shardings to cut wire bytes"}[dom]
        key = (r["arch"], r["shape"])
        if worst is None or frac < worst[1]:
            worst = (key, frac)
        share = coll / max(comp + mem + coll, 1e-12)
        if collb is None or share > collb[1]:
            collb = (key, share)
        lines.append(f"| {r['arch']} | {r['shape']} | {comp:.4f} | "
                     f"{mem:.4f} | {coll:.4f} | {dom} | {useful:.2f} | "
                     f"{frac:.2f} | {advice} |")
    lines += ["", f"**Worst roofline fraction**: {worst[0]} "
                  f"({worst[1]:.2f})" if worst else "",
              f"**Most collective-bound**: {collb[0]} "
              f"({collb[1] * 100:.0f}% of terms)" if collb else ""]
    return "\n".join(lines)


def perf_section():
    lines = ["## §Perf — hillclimbing log", "",
             "Three cells hillclimbed (worst roofline fraction, most "
             "collective-bound, most paper-representative).  Each row: "
             "hypothesis -> change -> measured before/after on the "
             "dominant term.  Paper-faithful BASELINE and beyond-paper "
             "OPTIMIZED are separate rows.", ""]
    for f in sorted(PERF.glob("*.jsonl")):
        recs = [json.loads(l) for l in f.read_text().splitlines()]
        if not recs:
            continue
        cell = f.stem.replace("__", " / ")
        lines.append(f"### {cell}")
        lines.append("")
        lines.append("| variant | hypothesis | compute_s | memory_s | "
                     "collective_s | dominant | frac | temp GB |")
        lines.append("|---|---|---|---|---|---|---|---|")
        for r in recs:
            lines.append(
                f"| {r['variant']} | {r.get('hypothesis', '')[:60]} | "
                f"{r['compute_s']:.4f} | {r['memory_s']:.4f} | "
                f"{r['collective_s']:.4f} | {r['dominant']} | "
                f"{r['roofline_frac']:.2f} | {r['temp_gb']:.1f} |")
        lines.append("")
    return "\n".join(lines)


def bench_summary_section():
    p = BENCH / "summary.json"
    if not p.exists():
        return "## §Benchmarks\n\n(run `python -m benchmarks.run`)"
    s = json.loads(p.read_text())
    lines = ["## §Benchmark summary", ""]
    for k, v in s.items():
        lines.append(f"- **{k}**: {'OK' if v.get('ok') else 'FAIL'} "
                     f"`{v.get('metrics', v.get('error', ''))}`")
    return "\n".join(lines)


def main():
    header = (ROOT / "EXPERIMENTS.header.md").read_text() \
        if (ROOT / "EXPERIMENTS.header.md").exists() else \
        "# EXPERIMENTS\n"
    doc = "\n\n".join([header, bench_summary_section(), dryrun_section(),
                       roofline_section(), perf_section()])
    (ROOT / "EXPERIMENTS.md").write_text(doc)
    print(f"EXPERIMENTS.md written ({len(doc)} chars)")


if __name__ == "__main__":
    main()
