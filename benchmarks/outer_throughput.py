"""Outer-search throughput benchmark -> BENCH_outer.json.

Measures ``chiplight-outer`` via ``Study.run()`` on
``scenarios/paper_qwen3_outer.json``: the batched population path
(walkers x rounds, fused per-round sweeps, variant cache) against the
scalar single-walker nested optimiser (``method="scalar",
inner_method="scalar"`` — the pre-population flow).

Two rates are reported per path:

  * ``points_per_s_sim``       — design points actually SIMULATED per
                                 wall-second (the raw kernel burn rate);
  * ``points_per_s_requested`` — design points the outer search asked
                                 for per wall-second, cache-served
                                 revisits included.  The scalar walker
                                 has no variant cache, so its two rates
                                 coincide; the population's requested
                                 rate is the one the variant cache (free
                                 revisits) and the fused per-round
                                 sweeps buy.  This is the acceptance
                                 metric (>= 10x the scalar baseline).

    PYTHONPATH=src:. python benchmarks/outer_throughput.py
    PYTHONPATH=src:. python benchmarks/outer_throughput.py --quick

``--quick`` runs a shrunken tinyllama scenario and gates it on the
floors owned by ``repro.obs.bench`` (the CI smoke mode — also reachable
as ``python -m repro.cli bench check --which outer --quick``; it never
rewrites BENCH_outer.json).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from benchmarks.common import emit
from repro.api import Scenario, Study
from repro.obs.bench import (DEFAULT_FLOORS, enforce, quick_outer_scenario,
                             scalar_outer_variant)

REPO = Path(__file__).resolve().parents[1]
OUT = REPO / "BENCH_outer.json"
SCENARIO = REPO / "scenarios" / "paper_qwen3_outer.json"


def _run(sc: Scenario, repeats: int = 3) -> dict:
    study = Study(sc)
    res = study.run()                                      # warm-up
    t = res.timings["total_s"]
    for _ in range(repeats - 1):
        t = min(t, study.run().timings["total_s"])
    p = res.provenance
    n_sim = int(p["n_sim"])
    n_req = int(p.get("n_requested", n_sim))   # scalar: no cache
    return {
        "engine": p["engine"],
        "rounds": int(p["n_rounds"]),
        "variants": int(p["n_variants"]),
        "cache_hits": int(p["n_cache_hits"]),
        "n_sim": n_sim,
        "n_requested": n_req,
        "wall_s": t,
        "points_per_s_sim": n_sim / t,
        "points_per_s_requested": n_req / t,
        "best_throughput_tok_s": res.best_record.throughput
        if res.best_record else 0.0,
    }


def bench(sc: Scenario, repeats: int = 3) -> dict:
    scalar = _run(scalar_outer_variant(sc), repeats)
    pop = _run(sc, repeats)
    speedup = (pop["points_per_s_requested"]
               / scalar["points_per_s_requested"])
    return {"scenario": sc.name, "scalar": scalar, "population": pop,
            "speedup_requested_pts_per_s": speedup,
            "best_ratio_pop_over_scalar":
                (pop["best_throughput_tok_s"]
                 / scalar["best_throughput_tok_s"])
                if scalar["best_throughput_tok_s"] else None}


def run(quick: bool = False) -> int:
    sc = quick_outer_scenario() if quick else Scenario.load(SCENARIO)
    t0 = time.perf_counter()
    r = bench(sc)
    rows = [[r["scenario"], path, d["variants"], d["n_sim"],
             d["n_requested"], f"{d['wall_s'] * 1e3:.1f}",
             f"{d['points_per_s_sim']:.0f}",
             f"{d['points_per_s_requested']:.0f}"]
            for path, d in (("scalar", r["scalar"]),
                            ("population", r["population"]))]
    emit("outer_throughput", rows,
         ["scenario", "path", "variants", "n_sim", "n_requested",
          "wall_ms", "pts_per_s_sim", "pts_per_s_requested"])
    print(f"speedup (requested pts/s): "
          f"{r['speedup_requested_pts_per_s']:.1f}x   "
          f"best ratio pop/scalar: "
          f"{r['best_ratio_pop_over_scalar']:.3f}   "
          f"({time.perf_counter() - t0:.1f}s)")

    if quick:
        got = enforce("outer", {
            "points_per_s_requested":
                r["population"]["points_per_s_requested"],
            "speedup_requested_pts_per_s":
                r["speedup_requested_pts_per_s"]}, root=REPO)
        return int(any(not row["ok"] for row in got))
        # quick mode never rewrites JSON

    payload = {"bench": "outer_throughput", "results": [r],
               "quick_floors": dict(DEFAULT_FLOORS["outer"])}
    OUT.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {OUT}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="shrunken tinyllama scenario + regression "
                         "floors (CI smoke); does not rewrite "
                         "BENCH_outer.json")
    args = ap.parse_args(argv)
    return run(quick=args.quick)


if __name__ == "__main__":
    sys.exit(main())
