"""DSE-engine throughput benchmark -> BENCH_dse.json.

Measures the hot path this repo optimizes: design-point evaluation.
Compares the batched engine (``repro.dse.batched_sim`` / the fused
cross-variant sweep) against the scalar ``core.simulator.simulate``
loop on the SAME points, and records design-points/sec so the perf
trajectory of this path is tracked across PRs.  The design space comes
from a ``repro.api.Scenario`` (the same spec the CLI runs), and the
full ``Study.run()`` end-to-end time (sweep + refinement + record
assembly) is tracked alongside the raw kernel time.

NOTE: the ``points_per_s_study`` values frozen in BENCH_dse.json are
the BASELINE that ``benchmarks/study_throughput.py`` measures its
speedup against — re-running this script rewrites them to the current
(optimized) study path, so only regenerate BENCH_dse.json when you
mean to move that baseline.

    PYTHONPATH=src:. python benchmarks/dse_throughput.py
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import emit
from repro.api import Scenario, Study
from repro.core.simulator import simulate
from repro.dse.batched_sim import MCMBatch, batched_simulate
from repro.dse.space import StrategyBatch

OUT = Path(__file__).resolve().parents[1] / "BENCH_dse.json"


def _fused_inputs(space):
    cells = list(space.batches())
    batch = StrategyBatch.concat([g for _, _, g in cells])
    local = np.concatenate([np.full(len(g), i, np.int64)
                            for i, (_, _, g) in enumerate(cells)])
    mcms = [m for m, _, _ in cells]
    return batch, MCMBatch.from_mcms(mcms, local), mcms, local


def bench_model(name: str, seq_len: int, global_batch: int,
                C: float = 4e6, scalar_cap: int = 4000,
                repeats: int = 5) -> dict:
    sc = Scenario(model=name, total_tflops=C, seq_len=seq_len,
                  global_batch=global_batch, fabrics=("oi",))
    w = sc.build_workload()
    space = sc.design_space()
    batch, mb, mcms, local = _fused_inputs(space)
    n = len(batch)

    batched_simulate(w, batch, mb, fabric="oi", reuse=True,
                     hw=mcms[0].hw)                       # warm-up
    t_batched = min(_timed(lambda: batched_simulate(
        w, batch, mb, fabric="oi", reuse=True, hw=mcms[0].hw))
        for _ in range(repeats))

    # full api path: sweep + scalar refinement + StudyResult assembly
    study = Study(sc)
    t_study = min(_timed(study.run) for _ in range(repeats))

    # scalar oracle loop over the same points (capped + extrapolated
    # when the grid is huge — the per-point cost is flat)
    idx = np.arange(n) if n <= scalar_cap else \
        np.random.default_rng(0).choice(n, scalar_cap, replace=False)
    strategies = batch.take(idx).to_strategies()
    t0 = time.perf_counter()
    for i, s in zip(idx, strategies):
        simulate(w, s, mcms[int(local[i])], fabric="oi", topo=None,
                 reuse=True)
    t_scalar = (time.perf_counter() - t0) / len(idx) * n

    return {
        "model": name, "C_tflops": C, "design_points": int(n),
        "mcm_variants": len(mcms),
        "batched_s": t_batched, "scalar_s": t_scalar,
        "study_s": t_study,
        "scalar_sampled": int(len(idx)),
        "speedup": t_scalar / t_batched,
        "points_per_s_batched": n / t_batched,
        "points_per_s_scalar": n / t_scalar,
        "points_per_s_study": n / t_study,
    }


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run() -> dict:
    results = [
        bench_model("tinyllama_1_1b", 4096, 512),
        bench_model("qwen3_moe_235b_a22b", 10240, 512),
        bench_model("mixtral_8x7b", 8192, 256),
    ]
    rows = [[r["model"], r["design_points"], f"{r['batched_s'] * 1e3:.2f}",
             f"{r['study_s'] * 1e3:.1f}", f"{r['scalar_s'] * 1e3:.1f}",
             f"{r['speedup']:.0f}", f"{r['points_per_s_batched']:.0f}"]
            for r in results]
    emit("dse_throughput", rows,
         ["model", "points", "batched_ms", "study_ms", "scalar_ms",
          "speedup", "points_per_s"])
    payload = {"bench": "dse_throughput", "results": results,
               "min_speedup": min(r["speedup"] for r in results)}
    OUT.write_text(json.dumps(payload, indent=2))
    print(f"wrote {OUT}  (min speedup {payload['min_speedup']:.0f}x)")
    return payload


if __name__ == "__main__":
    run()
