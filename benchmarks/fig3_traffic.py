"""Fig 3 reproduction: parallelism-wise traffic volumes for Qwen3-235B
on 1024 devices under the paper's strategy table, across context lengths.

Checks Observation 1: TP > (CP, EP) > (DP, PP), with the parenthesised
orders flipping with context length.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit
from repro.core import Strategy, Workload, traffic_volumes
from repro.configs import get_config

# the paper's Fig 3 strategy table (1024 devices)
STRATEGIES = {
    "S1": Strategy(tp=8, dp=16, pp=4, cp=2, ep=1, n_micro=16),
    "S2": Strategy(tp=8, dp=4, pp=4, cp=2, ep=4, n_micro=16),
    "S3": Strategy(tp=4, dp=4, pp=4, cp=2, ep=8, n_micro=16),
    "S4": Strategy(tp=8, dp=2, pp=2, cp=4, ep=8, n_micro=8),
}
CONTEXTS = [4096, 10240, 32768]


def run():
    cfg = get_config("qwen3_moe_235b_a22b")
    rows = []
    ok_order = True
    for ctx in CONTEXTS:
        w = Workload(model=cfg, seq_len=ctx,
                     global_batch=max(512, 1024 * 4096 // ctx // 2))
        for name, s in STRATEGIES.items():
            v = traffic_volumes(w, s)
            rows.append([ctx, name, s.tp, s.dp, s.pp, s.cp, s.ep]
                        + [f"{v[p] / 1e9:.2f}" for p in
                           ("TP", "DP", "PP", "CP", "EP")])
            # Obs 1 is stated for the paper's 10k-ctx profiling setup and
            # 'generally follows'; at tp=4 with top-8 routing EP can edge
            # past TP (the paper's own 'relative order varies' caveat), so
            # the check covers the tp>=8 configurations.
            if s.tp >= 8 and ctx == 10240:
                for p in ("CP", "EP", "DP", "PP"):
                    if v[p] > 0 and v[p] > 1.1 * v["TP"]:
                        ok_order = False
    emit("fig3_traffic", rows,
         ["ctx", "strategy", "tp", "dp", "pp", "cp", "ep",
          "TP_GB", "DP_GB", "PP_GB", "CP_GB", "EP_GB"])
    print(f"Observation 1 (TP dominates): {'CONFIRMED' if ok_order else 'VIOLATED'}")
    return {"obs1_tp_dominates": ok_order}


if __name__ == "__main__":
    run()
