"""Shared benchmark utilities: CSV emission + artifact paths."""
from __future__ import annotations

import csv
import io
import time
from pathlib import Path

ART = Path(__file__).resolve().parents[1] / "artifacts" / "bench"
ART.mkdir(parents=True, exist_ok=True)


def emit(name: str, rows, header):
    """Print a ``name,us_per_call,derived`` style CSV block + save it."""
    buf = io.StringIO()
    w = csv.writer(buf)
    w.writerow(header)
    for r in rows:
        w.writerow(r)
    text = buf.getvalue()
    print(f"\n=== {name} ===")
    print(text)
    (ART / f"{name}.csv").write_text(text)
    return text


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.dt = time.time() - self.t0
