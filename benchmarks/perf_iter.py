"""§Perf hillclimbing harness: hypothesis -> change -> re-lower -> measure.

Each iteration applies ExecConfig overrides to one (arch x shape) cell,
recompiles the depth variants (flop/byte/wire terms) + the full model
(memory), and appends a record to artifacts/perf/<cell>.jsonl.

    PYTHONPATH=src python -m benchmarks.perf_iter \
        --arch qwen3-moe-235b-a22b --shape train_4k \
        --variant moe_cap_shard --hypothesis "..."
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse  # noqa: E402
import json      # noqa: E402
import time      # noqa: E402
from pathlib import Path  # noqa: E402

import repro.launch.dryrun as dr  # noqa: E402
from repro.configs import canonical_arch  # noqa: E402
from repro.launch import hlo as hlo_mod  # noqa: E402
from repro.core.hardware import (TPU_V5E_FLOPS, TPU_V5E_HBM_BW,  # noqa
                                 TPU_V5E_ICI_BW)

PERF_ART = Path(__file__).resolve().parents[1] / "artifacts" / "perf"

# named variants: ExecConfig overrides
VARIANTS = {
    "baseline": {},
    # hillclimb moves
    "moe_cap_shard": {"moe_cap_axes": ("data",)},
    "moe_cap_shard_multi": {"moe_cap_axes": ("pod", "data")},
    "remat_dots": {"remat": "dots"},
    "no_remat": {"remat": "none"},
    "no_seq_parallel": {"seq_axis": None},
    "attn_block_512": {"attn_block": 512},
    "attn_block_2048": {"attn_block": 2048},
    "ssd_chunk_512": {"ssd_chunk": 512},
    "ssd_chunk_1024": {"ssd_chunk": 1024},
    "moe_a2a": {"moe_impl": "a2a"},
    "fp32_params": {},   # placeholder (param dtype handled separately)
}


def measure(arch: str, shape: str, mesh: str, overrides: dict):
    multi = mesh == "multi"
    dr.EXEC_OVERRIDES.clear()
    dr.EXEC_OVERRIDES.update(overrides)
    t0 = time.time()
    # depth variants -> extrapolated terms
    cfg = dr.get_config(arch)
    cfg1, cfg2, l1, l2, l_full = dr._depth_variants(cfg)
    pts = []
    for cvar in (cfg1, cfg2):
        lw, _, _, _ = dr.lower_cell(arch, shape, multi, cfg_override=cvar,
                                    layer_unroll=True)
        cc = lw.compile()
        cst = cc.cost_analysis() or {}
        cl = hlo_mod.parse_collectives(cc.as_text())
        pts.append((float(cst.get("flops", 0.0)),
                    float(cst.get("bytes accessed", 0.0)),
                    cl.total_wire))

    def extrap(i):
        t1, t2 = pts[0][i], pts[1][i]
        return t1 + (l_full - l1) * (t2 - t1) / max(l2 - l1, 1)

    # full compile -> memory
    lw, _, _, shp = dr.lower_cell(arch, shape, multi)
    cc = lw.compile()
    mem = cc.memory_analysis()
    dr.EXEC_OVERRIDES.clear()

    flops, bts, wire = extrap(0), extrap(1), extrap(2)
    n_chips = 512 if multi else 256
    tokens = (shp.global_batch * shp.seq_len if shp.kind != "decode"
              else shp.global_batch)
    mult = 6.0 if shp.kind == "train" else 2.0
    model_flops = mult * cfg.active_param_count() * tokens
    terms = {"compute_s": flops / TPU_V5E_FLOPS,
             "memory_s": bts / TPU_V5E_HBM_BW,
             "collective_s": wire / TPU_V5E_ICI_BW}
    dom = max(terms, key=terms.get)
    frac = (model_flops / n_chips / TPU_V5E_FLOPS) / max(terms.values())
    return {
        "flops_per_dev": flops, "bytes_per_dev": bts, "wire_per_dev": wire,
        **terms, "dominant": dom, "roofline_frac": frac,
        "model_over_hlo": model_flops / n_chips / max(flops, 1.0),
        "temp_gb": float(mem.temp_size_in_bytes) / 1e9,
        "arg_gb": float(mem.argument_size_in_bytes) / 1e9,
        "wall_s": time.time() - t0,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--variant", default="baseline",
                    choices=sorted(VARIANTS))
    ap.add_argument("--hypothesis", default="")
    args = ap.parse_args()

    arch = canonical_arch(args.arch)
    rec = measure(arch, args.shape, args.mesh, VARIANTS[args.variant])
    rec.update(variant=args.variant, hypothesis=args.hypothesis,
               arch=arch, shape=args.shape, mesh=args.mesh)
    PERF_ART.mkdir(parents=True, exist_ok=True)
    out = PERF_ART / f"{arch}__{args.shape}__{args.mesh}.jsonl"
    with out.open("a") as f:
        f.write(json.dumps(rec) + "\n")
    print(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
