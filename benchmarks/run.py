"""Benchmark harness — one entry per paper table/figure + the roofline.

``PYTHONPATH=src python -m benchmarks.run [--quick]``
Prints ``name,us_per_call,derived``-style CSV blocks per benchmark and a
paper-claim validation summary.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller search budgets")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    budget = 24 if args.quick else 48

    from benchmarks import (fig3_traffic, fig4_heatmap, fig8_scaling,
                            fig9_packaging, fig10_resources, kernels_micro,
                            roofline)

    jobs = {
        "fig3": lambda: fig3_traffic.run(),
        "fig4": lambda: fig4_heatmap.run(),
        "fig8": lambda: fig8_scaling.run(budget=budget,
                                         outer_iters=4 if args.quick else 6),
        "fig9": lambda: fig9_packaging.run(budget=max(budget // 2, 16)),
        "fig10": lambda: fig10_resources.run(budget=max(budget // 2, 16)),
        "kernels": lambda: kernels_micro.run(),
        "roofline": lambda: roofline.run(),
    }
    if args.only:
        jobs = {k: v for k, v in jobs.items() if k in args.only.split(",")}

    summary = {}
    for name, job in jobs.items():
        t0 = time.time()
        try:
            out = job()
            summary[name] = {"ok": True, "wall_s": time.time() - t0}
            if isinstance(out, dict):
                summary[name]["metrics"] = {
                    k: v for k, v in out.items()
                    if isinstance(v, (int, float, str, bool, type(None)))}
        except Exception as e:  # noqa: BLE001
            summary[name] = {"ok": False, "error": repr(e)}
            print(f"[bench {name} FAILED] {e!r}")
    out_path = Path(__file__).resolve().parents[1] / "artifacts" / \
        "bench" / "summary.json"
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(summary, indent=1, default=str))
    print("\n=== benchmark summary ===")
    for k, v in summary.items():
        print(f"{k}: {'OK' if v.get('ok') else 'FAIL'} "
              f"{v.get('metrics', v.get('error', ''))}")


if __name__ == '__main__':
    main()
