"""Batched serving example: prefill + decode with a reduced config.

    PYTHONPATH=src python examples/serve_decode.py --arch gemma2-2b
"""
from repro.launch.serve import main

if __name__ == "__main__":
    import sys
    if "--reduced" not in sys.argv:
        sys.argv.append("--reduced")
    main()
