"""End-to-end training driver: a ~100M-param llama-class model for a few
hundred steps with checkpointing + fault-tolerant loop (CPU-runnable).

    PYTHONPATH=src python examples/train_e2e.py --steps 200
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.configs.base import AttnConfig, ModelConfig, ShapeConfig
from repro.checkpoint import CheckpointManager
from repro.data import DataPipeline
from repro.launch.steps import init_train_state, make_train_step
from repro.models.common import ExecConfig
from repro.runtime import FaultTolerantLoop

# ~100M params: 12L d512 8H d_ff 2048 vocab 32000
CFG = ModelConfig(
    name="llama_100m", family="dense", n_layers=12, d_model=512,
    d_ff=2048, vocab=32000,
    attn=AttnConfig(n_heads=8, n_kv_heads=4, head_dim=64),
    tie_embeddings=True, supports_long_context=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    n_params = CFG.param_count()
    print(f"model: {n_params / 1e6:.0f}M params")
    ex = ExecConfig(attn_block=128, remat="full")
    shape = ShapeConfig("e2e", "train", args.seq, args.batch)
    step = jax.jit(make_train_step(CFG, ex, base_lr=3e-4, warmup=20,
                                   total=args.steps), donate_argnums=(0,))
    state = init_train_state(CFG, ex)
    pipe = DataPipeline(CFG, shape, seed=0, ex=ex)
    ckpt = CheckpointManager("artifacts/e2e_ckpt", keep=2)
    loop = FaultTolerantLoop(step, ckpt, pipe, checkpoint_every=50)
    start = 0
    if args.resume:
        state, start = loop.resume_or_init(state)
        print(f"resumed at step {start}")

    def log(stp, m, dt):
        if stp % 20 == 0 or stp <= 3:
            print(f"step {stp:4d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}  {dt * 1e3:.0f} ms")

    state, last = loop.run(state, args.steps, start_step=start,
                           on_metrics=log)
    print(f"finished at step {last}")


if __name__ == "__main__":
    main()
