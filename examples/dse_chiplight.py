"""Full cross-layer DSE study: Pareto frontier + cluster comparison.

Reproduces the paper's workflow end-to-end through the unified
``repro.api`` surface: one Scenario per cluster configuration, one
``Study.run()`` each — batched grid sweep, nested ChipLight
optimisation, then GPU / Chiplet+IB / RailX baselines as scenario
variants of the SAME spec (that is the point: a baseline is a field
change, not another code path).

    PYTHONPATH=src python examples/dse_chiplight.py --C 4e6
"""
import argparse

from repro.api import Scenario, Study
from repro.core import traffic_volumes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--C", type=float, default=4e6,
                    help="total cluster compute, TFLOPS")
    ap.add_argument("--budget", type=int, default=40)
    args = ap.parse_args()

    base = Scenario(model="qwen3_moe_235b_a22b", total_tflops=args.C,
                    seq_len=10240, global_batch=512)
    t = lambda r: r.best_record.throughput if r.best is not None else 0.0

    print("=== batched grid sweep (repro.dse via repro.api) ===")
    sweep = Study(base.replace(fabrics=("oi", "ib"), refine_top=0,
                               name="grid_sweep")).run()
    n = sweep.provenance["grid_evaluated"]
    rate = n / max(sweep.timings["sweep_s"], 1e-9)
    print(f"  {n} design points (strategies x MCM variants x fabrics) "
          f"in {sweep.timings['sweep_s']:.2f}s — {rate:,.0f} points/s")
    if sweep.best is not None:
        d = sweep.best_record
        print(f"  grid best: {d.throughput:.3e} tok/s "
              f"{d.fabric} m={d.mcm['m']} {d.strategy}")
        print(f"  pareto surface (thpt/cost/power): "
              f"{len(sweep.pareto)} points")

    print("\n=== nested ChipLight optimisation ===")
    chip = Study(base.replace(
        driver="chiplight-outer", dies_per_mcm=(16,), m=(6,),
        cpo_ratio=(0.6,), name="chiplight",
        driver_kw={"outer_iters": 5, "inner_budget": args.budget})).run()
    best = chip.best_point

    print("\n=== traffic projection (network-independent) ===")
    vols = traffic_volumes(base.build_workload(), best.strategy)
    for p, v in sorted(vols.items(), key=lambda kv: -kv[1]):
        print(f"  {p}: {v / 1e9:8.1f} GB/device/step")

    print(f"\n=== cluster comparison at C={args.C:.0e} TFLOPS ===")
    budget_kw = {"refine_top": args.budget, "keep_top": args.budget}
    gpu = Study(base.replace(fabrics=("nvlink",), dies_per_mcm=(8,),
                             m=(6,), cpo_ratio=(0.6,), name="gpu",
                             **budget_kw)).run()
    ib = Study(base.replace(fabrics=("ib",), dies_per_mcm=(16,), m=(6,),
                            cpo_ratio=(0.6,), name="chiplet_ib",
                            **budget_kw)).run()
    railx = Study(base.replace(
        driver="railx", dies_per_mcm=(best.mcm.dies_per_mcm,),
        m=(best.mcm.m,), cpo_ratio=(best.mcm.cpo_ratio,), name="railx",
        driver_kw={}, **budget_kw)).run()   # batched: full-grid sweep
    print(f"  GPU (NVLink+IB):  {t(gpu):.3e} tok/s")
    print(f"  Chiplet+IB:       {t(ib):.3e} tok/s")
    print(f"  RailX:            {t(railx):.3e} tok/s")
    print(f"  ChipLight:        {t(chip):.3e} tok/s  "
          f"({t(chip) / max(t(gpu), 1):.2f}x over GPU)")

    print(f"\n=== performance-cost Pareto frontier "
          f"({len(chip.pareto)} points) ===")
    for i in chip.pareto:
        r = chip.records[i]
        print(f"  ${r.metrics['cost'] / 1e6:7.1f}M  "
              f"{r.throughput:.3e} tok/s  "
              f"m={r.mcm['m']} r={r.mcm['cpo_ratio']:.1f} {r.strategy}")


if __name__ == "__main__":
    main()
