"""Full cross-layer DSE study: Pareto frontier + cluster comparison.

Reproduces the paper's workflow end-to-end: profile traffic -> co-optimise
MCM/parallelism/topology -> compare against GPU, Chiplet+IB and RailX at
one compute point, then emit the performance-cost Pareto frontier.  All
strategy scans run through the vectorized ``repro.dse`` engine (the
scalar simulator is only used to refine winners); the grid sweep at the
top shows the full (strategy x MCM x fabric) design space the batched
engine covers in one shot.

    PYTHONPATH=src python examples/dse_chiplight.py --C 4e6
"""
import argparse

from repro.core import (chiplight_optimize, inner_search,
                        mcm_from_compute, traffic_volumes)
from repro.core.optimizer import railx_search
from repro.core.workload import paper_workload
from repro.dse import DesignSpace, sweep_design_space


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--C", type=float, default=4e6,
                    help="total cluster compute, TFLOPS")
    ap.add_argument("--budget", type=int, default=40)
    args = ap.parse_args()

    w = paper_workload(global_batch=512)
    t = lambda p: p.throughput if p else 0.0

    print("=== batched grid sweep (repro.dse) ===")
    space = DesignSpace.from_compute(w, args.C, fabrics=("oi", "ib"))
    sweep = sweep_design_space(space)
    rate = sweep.n_sim / max(sweep.elapsed_s, 1e-9)
    print(f"  {sweep.n_sim} design points "
          f"({len(space.mcms)} MCM variants x fabrics x strategies) "
          f"in {sweep.elapsed_s:.2f}s — {rate:,.0f} points/s")
    if sweep.best is not None:
        d = sweep.describe(sweep.best)
        print(f"  grid best: {d['throughput_tok_s']:.3e} tok/s "
              f"{d['fabric']} m={d['mcm']['m']} {d['strategy']}")
        print(f"  pareto surface (thpt/cost/power): "
              f"{len(sweep.pareto_indices())} points")

    print(f"\n=== traffic projection (network-independent) ===")
    res = chiplight_optimize(w, args.C, dies_per_mcm=16, m0=6,
                             outer_iters=5, inner_budget=args.budget)
    best = res.best
    vols = traffic_volumes(w, best.strategy)
    for p, v in sorted(vols.items(), key=lambda kv: -kv[1]):
        print(f"  {p}: {v / 1e9:8.1f} GB/device/step")

    print(f"\n=== cluster comparison at C={args.C:.0e} TFLOPS ===")
    gpu = mcm_from_compute(args.C, dies_per_mcm=8, m=6)
    bg, _ = inner_search(w, gpu, fabric="nvlink", budget=args.budget)
    chip = mcm_from_compute(args.C, dies_per_mcm=16, m=6)
    bi, _ = inner_search(w, chip, fabric="ib", budget=args.budget)
    br, _ = railx_search(w, best.mcm, reuse=True, budget=args.budget)
    print(f"  GPU (NVLink+IB):  {t(bg):.3e} tok/s")
    print(f"  Chiplet+IB:       {t(bi):.3e} tok/s")
    print(f"  RailX:            {t(br):.3e} tok/s")
    print(f"  ChipLight:        {t(best):.3e} tok/s  "
          f"({t(best) / max(t(bg), 1):.2f}x over GPU)")

    print(f"\n=== performance-cost Pareto frontier "
          f"({len(res.frontier)} points) ===")
    for p in res.frontier:
        print(f"  ${p.cost / 1e6:7.1f}M  {p.throughput:.3e} tok/s  "
              f"m={p.mcm.m} r={p.mcm.cpo_ratio:.1f} "
              f"{p.strategy.asdict()}")


if __name__ == "__main__":
    main()
