"""Quickstart: the ChipLight DSE in ~30 lines.

Optimises a 1e6-TFLOPS chiplet+OI cluster for Qwen3-235B training and
prints the chosen MCM architecture, parallel strategy, OI topology and
the JAX deployment plan.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import chiplight_optimize, cluster_cost
from repro.core.workload import paper_workload
from repro.parallel.plan import plan_from_design

w = paper_workload(global_batch=512)
print(f"workload: {w.model.name}, ctx={w.seq_len}, "
      f"{w.tokens_per_step / 1e6:.1f}M tokens/step, "
      f"{w.total_params / 1e9:.0f}B params ({w.active_params / 1e9:.0f}B "
      f"active)")

res = chiplight_optimize(w, total_tflops=1e6, dies_per_mcm=16, m0=6,
                         outer_iters=4, inner_budget=32)
best = res.best
print(f"\nbest design point ({len(res.history)} evaluated, "
      f"{len(res.frontier)} on the Pareto front):")
print(f"  MCM: {best.mcm.n_mcm} packages of {best.mcm.x}x{best.mcm.y} "
      f"dies, m={best.mcm.m} HBM/die, CPO ratio {best.mcm.cpo_ratio:.1f} "
      f"-> {best.mcm.total_links} optical links each")
print(f"  strategy: {best.strategy.asdict()} "
      f"(n_micro={best.strategy.n_micro})")
if best.topo and best.topo.dims:
    print(f"  rails: {[(d.n, d.r, d.k) for d in best.topo.dims]} "
          f"mapping {best.topo.mapping} reuse={best.topo.reuse_pair}")
    print(f"  link allocation l_p: {best.topo.link_alloc} "
          f"({best.topo.ocs_count()} OCS)")
print(f"  throughput: {best.throughput:.3e} tokens/s  "
      f"MFU {best.sim.mfu:.2f}  bottleneck: {best.sim.bottleneck}")
print(f"  cluster cost: ${best.cost / 1e6:.1f}M")

plan = plan_from_design(best)
print(f"\nJAX deployment plan: mesh {plan.mesh_shape()} "
      f"(TP->model, DP*CP*EP->data), pp={plan.pp}, n_micro={plan.n_micro}")

print("\nouter-search trace (heuristic planner moves):")
for t in res.outer_trace:
    print(f"  iter {t['iter']}: mcm(n,x,y,m,r)={t['mcm']} "
          f"thpt={t['best_thpt']:.2e} bottleneck={t['bottleneck']}")
