"""Quickstart: one Scenario, one Study.run() — the ChipLight DSE in ~30
lines.

Optimises a 1e6-TFLOPS chiplet+OI cluster for Qwen3-235B training via the
unified ``repro.api`` surface and prints the chosen MCM architecture,
parallel strategy, OI topology and the JAX deployment plan.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import Scenario, Study
from repro.parallel.plan import plan_from_design

sc = Scenario(model="qwen3_moe_235b_a22b", total_tflops=1e6,
              seq_len=10240, global_batch=512, driver="chiplight-outer",
              dies_per_mcm=(16,), m=(6,), cpo_ratio=(0.6,),
              driver_kw={"outer_iters": 4, "inner_budget": 32})
w = sc.build_workload()
print(f"workload: {w.model.name}, ctx={w.seq_len}, "
      f"{w.tokens_per_step / 1e6:.1f}M tokens/step, "
      f"{w.total_params / 1e9:.0f}B params ({w.active_params / 1e9:.0f}B "
      f"active)")

res = Study(sc).run()
best = res.best_point            # scalar-oracle DesignPoint, topology incl.
rec = res.best_record
print(f"\nbest design point ({res.provenance['n_evaluated']} evaluated, "
      f"{len(res.pareto)} on the Pareto front):")
print(f"  MCM: {best.mcm.n_mcm} packages of {best.mcm.x}x{best.mcm.y} "
      f"dies, m={best.mcm.m} HBM/die, CPO ratio {best.mcm.cpo_ratio:.1f} "
      f"-> {best.mcm.total_links} optical links each")
print(f"  strategy: {best.strategy.asdict()} "
      f"(n_micro={best.strategy.n_micro})")
if best.topo and best.topo.dims:
    print(f"  rails: {[(d.n, d.r, d.k) for d in best.topo.dims]} "
          f"mapping {best.topo.mapping} reuse={best.topo.reuse_pair}")
    print(f"  link allocation l_p: {best.topo.link_alloc} "
          f"({best.topo.ocs_count()} OCS)")
print(f"  throughput: {best.throughput:.3e} tokens/s  "
      f"MFU {best.sim.mfu:.2f}  bottleneck: {best.sim.bottleneck}")
print(f"  cluster cost: ${rec.metrics['cost'] / 1e6:.1f}M  "
      f"board power: {rec.metrics['power'] / 1e6:.2f}MW")

plan = plan_from_design(best)
print(f"\nJAX deployment plan: mesh {plan.mesh_shape()} "
      f"(TP->model, DP*CP*EP->data), pp={plan.pp}, n_micro={plan.n_micro}")

print("\nouter-search trace (population rounds):")
for t in res.traces:
    lead = max(t["walkers"], key=lambda wk: wk["best_thpt"])
    print(f"  round {t['round']}: {len(t['walkers'])} walkers, "
          f"{t['n_variants']} variants seen, lead mcm(n,x,y,m,r)="
          f"{lead['mcm']} thpt={lead['best_thpt']:.2e} "
          f"bottleneck={lead['bottleneck']}")

path = res.save("artifacts/studies/quickstart.json")
print(f"\nstudy artifact: {path} "
      f"(scenario hash {res.provenance['scenario_hash']})")
