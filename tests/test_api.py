"""Tests for the unified repro.api Scenario/Study layer + repro.cli.

Contracts under test: Scenario dict/JSON round-trips exactly (incl. hw
and workload overrides, and every preset shipped under ``scenarios/``);
registry lookups fail with clear errors; ``Study.run()`` reproduces the
engine-level ``sweep_design_space`` + ``refine_top_points`` best point
exactly; the ``repro.dse.run`` shim emits DeprecationWarning while
returning identical results; the CLI rejects malformed comma lists and
exits non-zero when every sweep cell is infeasible.
"""
import json
from pathlib import Path

import pytest

from repro import cli
from repro.api import (DRIVERS, OBJECTIVES, DesignRecord, Registry,
                       Scenario, Study, StudyResult)

REPO = Path(__file__).resolve().parents[1]

TINY = dict(model="tinyllama_1_1b", total_tflops=1e6, seq_len=4096,
            global_batch=256, dies_per_mcm=(16,), m=(2, 6),
            cpo_ratio=(0.3, 0.9), refine_top=2, keep_top=8)


# ---------------------------------------------------------------------------
# Scenario round-trips + validation
# ---------------------------------------------------------------------------
def test_scenario_roundtrip_all_presets():
    presets = sorted((REPO / "scenarios").glob("*.json"))
    assert len(presets) >= 6
    for path in presets:
        sc = Scenario.load(path)
        assert Scenario.from_dict(sc.to_dict()) == sc, path.name
        assert Scenario.from_json(sc.to_json()) == sc, path.name
        assert sc.scenario_hash() == \
            Scenario.from_dict(sc.to_dict()).scenario_hash()


def test_scenario_roundtrip_hw_and_workload_overrides():
    sc = Scenario(model="qwen3-moe-235b-a22b",      # alias canonicalizes
                  total_tflops=2e6, seq_len=8192, global_batch=128,
                  workload={"bytes_grad": 2, "bytes_act": 4},
                  hw={"ocs_reuse_mode": "paper", "mfu_ceiling": 0.5,
                      "ib_bw": 1e11},
                  driver="prf", driver_kw={"budget": 64, "kappa": 2.0},
                  objectives=("throughput", "step_time"))
    assert sc.model == "qwen3_moe_235b_a22b"
    rt = Scenario.from_dict(json.loads(sc.to_json()))
    assert rt == sc
    hw = rt.build_hw()
    assert hw.ocs_reuse_mode == "paper" and hw.mfu_ceiling == 0.5
    w = rt.build_workload()
    assert w.bytes_grad == 2 and w.bytes_act == 4 and w.seq_len == 8192


@pytest.mark.parametrize("kw,msg", [
    (dict(m=(2, 2)), "duplicate"),
    (dict(dies_per_mcm=()), "empty"),
    (dict(fabrics=("oi", "pcie")), "unknown fabrics"),
    (dict(cpo_ratio=(0.0,)), "cpo_ratio"),
    (dict(hw={"warp_speed": 9}), "unknown hw overrides"),
    (dict(workload={"seq": 1}), "unknown workload overrides"),
    (dict(total_tflops=-1.0), "total_tflops"),
    (dict(backend="torch"), "backend"),
])
def test_scenario_validation_errors(kw, msg):
    base = dict(model="tinyllama_1_1b", total_tflops=1e6)
    with pytest.raises(ValueError, match=msg):
        Scenario(**{**base, **kw})


def test_scenario_from_dict_rejects_unknown_keys_and_schema():
    d = Scenario(model="tinyllama_1_1b", total_tflops=1e6).to_dict()
    with pytest.raises(ValueError, match="unknown scenario keys"):
        Scenario.from_dict({**d, "budget": 3})
    with pytest.raises(ValueError, match="schema"):
        Scenario.from_dict({**d, "schema": 99})


# ---------------------------------------------------------------------------
# Registries
# ---------------------------------------------------------------------------
def test_registry_lookup_errors_name_alternatives():
    with pytest.raises(KeyError, match="exhaustive"):
        DRIVERS.get("gradient-descent")
    with pytest.raises(KeyError, match="throughput"):
        OBJECTIVES.get("carbon")
    with pytest.raises(KeyError, match="unknown driver 'nope'"):
        Scenario(model="tinyllama_1_1b", total_tflops=1e6, driver="nope")
    with pytest.raises(KeyError, match="objective"):
        Scenario(model="tinyllama_1_1b", total_tflops=1e6,
                 objectives=("throughput", "carbon"))


def test_registry_rejects_duplicate_registration():
    reg = Registry("widget")
    reg.register("a")(1)
    with pytest.raises(ValueError, match="already registered"):
        reg.register("a")
    assert reg.names() == ["a"] and "a" in reg


# ---------------------------------------------------------------------------
# Study.run() parity with the engine-level flow
# ---------------------------------------------------------------------------
def test_study_reproduces_sweep_plus_refine_exactly():
    from repro.dse.search import refine_top_points, sweep_design_space
    sc = Scenario(model="qwen3_moe_235b_a22b", total_tflops=4e6,
                  seq_len=10240, global_batch=512, dies_per_mcm=(16,),
                  m=(4, 6), cpo_ratio=(0.6,), refine_top=4, keep_top=16)
    res = Study(sc).run()

    sweep = sweep_design_space(sc.design_space(), driver="exhaustive",
                               backend="numpy", seed=0)
    pts = refine_top_points(sweep, top_k=4)
    assert pts and res.best is not None
    best = res.best_record
    assert best.source == "refined"
    assert best.metrics["throughput"] == pts[0].throughput
    assert best.metrics["cost"] == pts[0].cost
    assert res.best_point.strategy == pts[0].strategy
    # the top batched record mirrors the sweep's own best row
    top = res.records[0]
    d = sweep.describe(sweep.best)
    assert top.metrics["throughput"] == d["throughput_tok_s"]
    assert top.metrics["cost"] == d["cost_usd"]
    assert top.strategy == d["strategy"]


def test_scalar_drivers_deterministic_from_scenario_seed():
    sc = Scenario(model="tinyllama_1_1b", total_tflops=3e4, seq_len=4096,
                  global_batch=256, dies_per_mcm=(4,), m=(6,),
                  cpo_ratio=(0.6,), driver="chiplight-outer",
                  driver_kw={"method": "scalar", "outer_iters": 2,
                             "inner_budget": 8},
                  keep_top=8, seed=7)
    r1, r2 = Study(sc).run(), Study(sc).run()
    assert len(r1.traces) == 3          # outer_iters + 1 (final proposal)
    assert r1.traces == r2.traces
    assert [r.to_dict() for r in r1.records] == \
        [r.to_dict() for r in r2.records]
    assert all(r.source == "scalar" for r in r1.records)
    assert r1.best == 0
    assert r1.records[0].throughput == \
        max(r.throughput for r in r1.records)


def test_chiplight_outer_trace_includes_final_proposed_mcm():
    from repro.core.optimizer import chiplight_optimize
    from repro.core.workload import Workload
    from repro.configs import get_config
    w = Workload(model=get_config("tinyllama_1_1b"), seq_len=4096,
                 global_batch=256)
    res = chiplight_optimize(w, 3e4, dies_per_mcm=4, m0=6, outer_iters=2,
                             inner_budget=8, seed=1)
    assert len(res.outer_trace) == 3
    # the last entry is an EVALUATION of the final planner proposal
    assert res.outer_trace[-1]["best_thpt"] >= 0.0
    assert "mcm" in res.outer_trace[-1]
    res2 = chiplight_optimize(w, 3e4, dies_per_mcm=4, m0=6, outer_iters=2,
                              inner_budget=8, seed=1)
    assert res.outer_trace == res2.outer_trace


# ---------------------------------------------------------------------------
# StudyResult artifact round-trip
# ---------------------------------------------------------------------------
def test_studyresult_roundtrip(tmp_path):
    res = Study(Scenario(**TINY)).run()
    path = res.save(tmp_path / "study.json")
    loaded = StudyResult.load(path)
    assert loaded.scenario == res.scenario
    assert loaded.best == res.best and loaded.pareto == res.pareto
    assert [r.to_dict() for r in loaded.records] == \
        [r.to_dict() for r in res.records]
    assert loaded.provenance["scenario_hash"] == \
        res.scenario.scenario_hash()
    assert json.loads(path.read_text())["schema"] == 1
    with pytest.raises(ValueError, match="schema"):
        StudyResult.from_dict({**res.to_dict(), "schema": 42})


def test_record_sources_and_pareto():
    res = Study(Scenario(**TINY)).run()
    sources = {r.source for r in res.records}
    assert sources == {"batched", "refined"}
    refined = [r for r in res.records if r.source == "refined"]
    assert len(refined) == 2 and len(res.points) == 2
    assert refined[0].topo is not None          # OI topology captured
    assert refined[0].metrics["cost"] > 0       # OCS-inclusive
    par = res.pareto_indices(("throughput", "cost"))
    assert all(res.records[i].feasible for i in par)
    # no record outside the 3-objective set dominates a member on it
    assert set(res.pareto) == set(res.pareto_indices())


def test_records_from_sweep_columnar_matches_single_row_adapter():
    from repro.api import record_from_sweep, records_from_sweep
    from repro.dse.search import sweep_design_space
    sc = Scenario(**TINY)
    sweep = sweep_design_space(sc.design_space())
    idx = list(range(0, len(sweep), max(len(sweep) // 50, 1)))
    recs = records_from_sweep(sweep, idx)
    assert [r.to_dict() for r in recs] == \
        [record_from_sweep(sweep, i).to_dict() for i in idx]
    assert records_from_sweep(sweep, []) == []


def test_sweep_keep_indices_unique_and_pareto_complete():
    import numpy as np
    from repro.api.study import _sweep_keep_indices
    from repro.dse.search import sweep_design_space
    sc = Scenario(**{**TINY, "keep_top": 4})
    sweep = sweep_design_space(sc.design_space())
    kept = _sweep_keep_indices(sweep, sc)
    assert len(set(int(i) for i in kept)) == len(kept)   # no duplicates
    pareto = set(int(i) for i in sweep.pareto_indices())
    assert pareto <= set(int(i) for i in kept)           # front retained
    order = np.argsort(-sweep.metrics["throughput"][kept[:4]])
    assert np.array_equal(order, np.arange(4))           # top-N first


def test_record_from_search_adapter_matches_cell():
    from repro.api import record_from_search
    from repro.dse.search import BatchedEvaluator, search_exhaustive
    from repro.core.mcm import mcm_from_compute
    sc = Scenario(**TINY)
    w = sc.build_workload()
    mcm = mcm_from_compute(1e6, dies_per_mcm=16, m=6)
    res = search_exhaustive(BatchedEvaluator(w, mcm, "oi"))
    recs = [record_from_search(res, mcm, "oi", i) for i in range(len(res.batch))]
    assert len(recs) == res.grid_size
    i = res.best
    assert recs[i].metrics["throughput"] == res.metrics["throughput"][i]
    assert recs[i].mcm["m"] == 6 and recs[i].source == "batched"


def test_scenario_hashable_by_content():
    a, b = Scenario(**TINY), Scenario(**TINY)
    assert hash(a) == hash(b) and len({a, b}) == 1
    assert hash(a) != hash(a.replace(seed=99))


def test_single_cell_drivers_reject_multi_cell_grid():
    sc = Scenario(**{**TINY, "driver": "chiplight-outer", "m": (2, 6)})
    with pytest.raises(ValueError, match="single MCM cell"):
        Study(sc).run()
    # the scalar railx loop is single-cell too; the batched railx sweep
    # (default) accepts the full grid
    sc = Scenario(**{**TINY, "driver": "railx", "m": (2, 6),
                     "driver_kw": {"method": "scalar"}})
    with pytest.raises(ValueError, match="single MCM cell"):
        Study(sc).run()
    res = Study(Scenario(**{**TINY, "driver": "railx",
                            "m": (2, 6)})).run()
    assert res.best is not None
    assert res.provenance["engine"] == "dse.sweep[railx]+refine"


def test_batched_driver_kw_translated_and_validated(tmp_path, capsys):
    # legacy --budget under nsga2 maps to pop_size instead of crashing
    rc = cli.main(["--model", "tinyllama_1_1b", "--C", "1e5",
                   "--driver", "nsga2", "--budget", "8",
                   "--generations", "2", "--dies", "16", "--m", "6",
                   "--cpo", "0.6", "--refine-top", "0",
                   "--out", str(tmp_path / "n.json")])
    capsys.readouterr()
    assert rc == 0
    # unknown driver_kw fails with one clear line, not a TypeError
    sc = Scenario(**{**TINY, "driver": "prf",
                     "driver_kw": {"budget": 8, "warp": 1}})
    with pytest.raises(ValueError, match="does not accept driver_kw"):
        Study(sc).run()
    with pytest.raises(SystemExit) as e:
        cli.main([str(sc.save(tmp_path / "bad.json")),
                  "--out", str(tmp_path / "b.json")])
    assert e.value.code == 2
    assert "does not accept driver_kw" in capsys.readouterr().err


def test_cli_legacy_refine_flag_maps_to_top(tmp_path, capsys):
    rc = cli.main(["--model", "tinyllama_1_1b", "--C", "1e6", "--dies",
                   "16", "--m", "6", "--cpo", "0.6", "--refine",
                   "--top", "3", "--out", str(tmp_path / "r.json")])
    capsys.readouterr()
    assert rc == 0
    assert StudyResult.load(tmp_path / "r.json").scenario.refine_top == 3


def test_design_record_roundtrip_handles_inf():
    rec = DesignRecord(strategy={"TP": 1}, mcm={"m": 2}, fabric="oi",
                       metrics={"feasible": False, "step_time": float("inf"),
                                "throughput": 0.0},
                       source="batched")
    rt = DesignRecord.from_dict(json.loads(json.dumps(rec.to_dict())))
    assert rt.metrics["step_time"] == float("inf")
    assert rt.to_dict() == rec.to_dict()


# ---------------------------------------------------------------------------
# Deprecation shim + CLI
# ---------------------------------------------------------------------------
_CLI_ARGS = ["--model", "tinyllama_1_1b", "--C", "1e6", "--dies", "16",
             "--m", "2,6", "--cpo", "0.3,0.9", "--refine-top", "2",
             "--keep-top", "8"]


def test_dse_run_shim_warns_and_matches_cli(tmp_path, capsys):
    from repro.dse import run as dse_run
    rc_new = cli.main(_CLI_ARGS + ["--out", str(tmp_path / "new.json")])
    with pytest.warns(DeprecationWarning, match="repro.cli"):
        rc_old = dse_run.main(_CLI_ARGS + ["--out",
                                           str(tmp_path / "old.json")])
    capsys.readouterr()
    assert rc_new == rc_old == 0
    new = json.loads((tmp_path / "new.json").read_text())
    old = json.loads((tmp_path / "old.json").read_text())
    assert old["records"] == new["records"]
    assert old["best"] == new["best"] and old["pareto"] == new["pareto"]
    assert old["scenario"] == new["scenario"]


@pytest.mark.parametrize("bad", [
    ["--dies", "8,,16"], ["--dies", "8,8"], ["--m", ""],
    ["--cpo", "0.3,x"], ["--fabrics", "oi,oi"],
])
def test_cli_rejects_malformed_lists(bad, capsys):
    with pytest.raises(SystemExit) as e:
        cli.main(["--model", "tinyllama_1_1b", "--C", "1e6"] + bad)
    assert e.value.code == 2
    err = capsys.readouterr().err
    assert "list" in err and "Traceback" not in err


def test_cli_infeasible_sweep_exits_nonzero(tmp_path, capsys):
    # m=16 HBM stacks consume the whole beachfront: no feasible MCM cell
    rc = cli.main(["--model", "tinyllama_1_1b", "--C", "1e6",
                   "--dies", "4", "--m", "16", "--cpo", "0.9",
                   "--out", str(tmp_path / "inf.json")])
    out = capsys.readouterr().out
    assert rc == 3 and "no feasible design point" in out
    assert json.loads((tmp_path / "inf.json").read_text())["best"] is None


def test_cli_scenario_file_with_flag_overrides(tmp_path, capsys):
    sc = Scenario(**TINY)
    path = sc.save(tmp_path / "tiny.json")
    rc = cli.main([str(path), "--driver", "random", "--budget", "16",
                   "--seed", "3", "--out", str(tmp_path / "res.json")])
    capsys.readouterr()
    assert rc == 0
    res = StudyResult.load(tmp_path / "res.json")
    assert res.scenario.driver == "random"
    assert res.scenario.driver_kw["budget"] == 16
    assert res.scenario.seed == 3
    assert res.scenario.model == "tinyllama_1_1b"   # file field kept


def test_cli_quick_mode_shrinks_grid(tmp_path, capsys):
    path = Scenario(**{**TINY, "m": (2, 4, 6), "fabrics": ("oi", "ib")}
                    ).save(tmp_path / "s.json")
    rc = cli.main([str(path), "--quick",
                   "--out", str(tmp_path / "q.json")])
    capsys.readouterr()
    assert rc == 0
    res = StudyResult.load(tmp_path / "q.json")
    assert res.scenario.m == (2,) and res.scenario.fabrics == ("oi",)


# ---------------------------------------------------------------------------
# Legacy result types only ever come from adapters (acceptance criterion)
# ---------------------------------------------------------------------------
def test_no_direct_legacy_result_construction_outside_core_dse():
    import re
    legacy = re.compile(
        r"\b(DesignPoint|DSEResult|SweepResult|SearchResult)\s*\(")
    offenders = []
    for path in (*REPO.glob("examples/*.py"), *REPO.glob("benchmarks/*.py"),
                 *(REPO / "src" / "repro").rglob("*.py")):
        rel = path.relative_to(REPO).as_posix()
        if rel.startswith(("src/repro/core/", "src/repro/dse/")):
            continue
        for i, line in enumerate(path.read_text().splitlines(), 1):
            if legacy.search(line):
                offenders.append(f"{rel}:{i}")
    assert not offenders, offenders
