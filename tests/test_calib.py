"""repro.calib + repro.obs.profile: fitting, the CALIB.json artifact,
HW/Scenario/Study integration, the drift gate, and the CLI."""
import copy
import json
import math

import pytest

from repro import cli
from repro.calib import (check_drift, execution_block, fit_calibration,
                         fit_saturation, load_calibration,
                         stamp_fidelity, write_calibration)
from repro.core.hardware import DEFAULT_HW, HW


# ---------------------------------------------------------------------------
# fit_saturation
# ---------------------------------------------------------------------------
def test_fit_saturation_recovers_synthetic_curve():
    peak, half = 3.2e12, 192.0
    xs = [32, 64, 128, 256, 512, 1024, 4096]
    ys = [peak * x / (x + half) for x in xs]
    p, h, resid = fit_saturation(xs, ys)
    assert abs(p / peak - 1) < 0.02
    assert abs(math.log2(h / half)) < 0.2
    assert resid < 0.01


def test_fit_saturation_rejects_degenerate_input():
    with pytest.raises(ValueError):
        fit_saturation([128], [1.0])
    with pytest.raises(ValueError):
        fit_saturation([1, 2], [1.0, -1.0])


# ---------------------------------------------------------------------------
# profile -> fit -> artifact (one real measurement pass, module-scoped)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def quick_calib():
    from repro.obs.profile import profile_kernels
    ms = profile_kernels(["rmsnorm", "moe_gmm"], quick=True, reps=1)
    return fit_calibration(ms, quick=True), ms


def test_profile_measurement_rows(quick_calib):
    _, ms = quick_calib
    kinds = {r["kernel"]: r["kind"] for r in ms}
    assert kinds == {"rmsnorm": "memory", "moe_gmm": "compute"}
    for r in ms:
        assert r["time_s"] > 0 and r["flops_per_s"] > 0
        assert set(r) >= {"kernel", "kind", "axis", "x", "shape",
                          "flops", "bytes", "time_s", "reps"}
    # moe_gmm sweeps both axes (m for gemm_m_half, n for gemm_n_half)
    assert {r["axis"] for r in ms if r["kernel"] == "moe_gmm"} == {"m", "n"}


def test_profile_rejects_unknown_kernel():
    from repro.obs.profile import profile_kernels
    with pytest.raises(KeyError):
        profile_kernels(["not_a_kernel"], quick=True)


def test_calib_artifact_schema(quick_calib, tmp_path):
    calib, _ = quick_calib
    assert calib["schema"] == 1
    assert calib["provenance"]["backend"]
    assert calib["provenance"]["quick"] is True
    fits = calib["kernels"]
    assert fits["moe_gmm"]["kind"] == "compute"
    assert "n_half" in fits["moe_gmm"]
    assert fits["rmsnorm"]["kind"] == "memory"
    eff = calib["effective"]
    assert eff["mfu_ceiling"] == 1.0 and eff["model_gemm_eff"] is True
    assert eff["die_tflops"] > 0 and eff["hbm_bw_per_die"] > 0

    p = tmp_path / "CALIB.json"
    write_calibration(calib, p)
    loaded = load_calibration(str(p))
    assert loaded["effective"] == json.loads(json.dumps(eff))


def test_load_calibration_errors(tmp_path):
    with pytest.raises(ValueError, match="calibrate"):
        load_calibration(str(tmp_path / "missing.json"))
    bad = tmp_path / "bad.json"
    bad.write_text('{"schema": 99}')
    with pytest.raises(ValueError, match="schema"):
        load_calibration(str(bad))


# ---------------------------------------------------------------------------
# HW / Scenario / Study integration
# ---------------------------------------------------------------------------
def test_hw_calibrated(quick_calib):
    calib, _ = quick_calib
    hw = HW.calibrated(calib)
    assert hw.die_tflops == calib["effective"]["die_tflops"]
    assert hw.mfu_ceiling == 1.0 and hw.model_gemm_eff is True
    # untouched fields come from the base
    assert hw.oi_link_bw == DEFAULT_HW.oi_link_bw
    with pytest.raises(ValueError, match="unknown HW fields"):
        HW.calibrated({"effective": {"die_tflops": 1.0, "nope": 2}})
    with pytest.raises(ValueError, match="effective"):
        HW.calibrated({"kernels": {}})


def test_scenario_calibration_and_study_stamp(quick_calib, tmp_path):
    from repro.api import Scenario, Study
    calib, _ = quick_calib
    p = tmp_path / "CALIB.json"
    write_calibration(calib, p)

    # the cluster is sized as total_tflops / die_tflops, so a study on
    # measured (cpu-scale) constants needs a proportionally scaled C —
    # ~64 calibrated dies here
    C = calib["effective"]["die_tflops"] * 64
    sc = Scenario(model="tinyllama_1_1b", total_tflops=C, seq_len=4096,
                  global_batch=256, fabrics=("oi",),
                  calibration=str(p))
    sc2 = Scenario.from_dict(sc.to_dict())
    assert sc2.calibration == str(p)
    assert sc.build_hw().die_tflops == calib["effective"]["die_tflops"]
    with pytest.raises(ValueError):
        Scenario(model="tinyllama_1_1b", total_tflops=1e6,
                 calibration=123)

    res = Study(sc).run()
    assert res.records            # feasible designs at the scaled C
    block = res.provenance["calibration"]
    assert block["schema"] == 1
    assert block["effective"]["die_tflops"] == \
        calib["effective"]["die_tflops"]
    assert block["measured_on"]["backend"] == \
        calib["provenance"]["backend"]
    # and it round-trips through the result artifact
    rt = json.loads(json.dumps(res.to_dict()))
    assert rt["provenance"]["calibration"] == block


def test_scenario_without_calibration_untouched():
    from repro.api import Scenario
    sc = Scenario(model="tinyllama_1_1b", total_tflops=1e6)
    assert sc.calibration == ""
    assert sc.build_hw() == DEFAULT_HW


# ---------------------------------------------------------------------------
# Drift gate
# ---------------------------------------------------------------------------
def test_check_drift_self_is_clean(quick_calib):
    calib, _ = quick_calib
    rows = check_drift(calib, calib)
    assert rows and all(r["ok"] for r in rows)


def test_check_drift_catches_perturbed_peak(quick_calib):
    calib, _ = quick_calib
    bad = copy.deepcopy(calib)
    bad["kernels"]["moe_gmm"]["peak"] *= 1e3   # way past the 8x gate
    rows = check_drift(calib, bad)
    fails = {r["metric"] for r in rows if not r["ok"]}
    assert "moe_gmm.peak" in fails
    # half constants never gate, even when absurd
    bad2 = copy.deepcopy(calib)
    bad2["kernels"]["moe_gmm"]["m_half"] *= 1e3
    assert all(r["ok"] for r in check_drift(calib, bad2))


def test_check_drift_respects_artifact_tolerances(quick_calib):
    calib, _ = quick_calib
    bad = copy.deepcopy(calib)
    bad["kernels"]["moe_gmm"]["peak"] *= 3.0   # inside 8x, outside 2x
    assert all(r["ok"] for r in check_drift(calib, bad)
               if r["metric"] == "moe_gmm.peak")
    bad["check_tolerances"] = {"log2_peak": 1.0}
    rows = check_drift(calib, bad)
    assert any(r["metric"] == "moe_gmm.peak" and not r["ok"]
               for r in rows)


# ---------------------------------------------------------------------------
# Fidelity stamp + CLI
# ---------------------------------------------------------------------------
def test_execution_block_and_fidelity_stamp(quick_calib, tmp_path):
    calib, _ = quick_calib
    blk = execution_block(calib)
    assert blk["calib_schema"] == 1
    assert set(blk["kernels"]) == {"moe_gmm", "rmsnorm"}

    fid = tmp_path / "FIDELITY.json"
    assert stamp_fidelity(calib, tmp_path / "absent.json") is None
    fid.write_text(json.dumps({"schema": 1, "scenarios": []}))
    stamp_fidelity(calib, fid)
    report = json.loads(fid.read_text())
    assert report["execution"]["effective"] == \
        json.loads(json.dumps(calib["effective"]))
    assert report["scenarios"] == []   # rest of the report intact


def test_cli_calibrate_roundtrip_and_check(tmp_path, capsys):
    out = tmp_path / "CALIB.json"
    rc = cli.main(["calibrate", "--quick", "--kernels", "rmsnorm,moe_gmm",
                   "--out", str(out), "--fidelity", ""])
    assert rc == 0 and out.exists()
    assert capsys.readouterr().out.count("peak") >= 2

    # check vs what we just wrote: same host, must hold
    rc = cli.main(["calibrate", "--quick", "--kernels",
                   "rmsnorm,moe_gmm", "--out", str(out), "--check"])
    assert rc == 0
    assert "OK: all" in capsys.readouterr().out

    # perturb the committed artifact beyond tolerance -> exit 1
    calib = json.loads(out.read_text())
    calib["kernels"]["moe_gmm"]["peak"] *= 1e3
    calib["effective"]["die_tflops"] *= 1e3
    write_calibration(calib, out)
    rc = cli.main(["calibrate", "--quick", "--kernels",
                   "rmsnorm,moe_gmm", "--out", str(out), "--check"])
    assert rc == 1
    assert "FAIL" in capsys.readouterr().out


def test_cli_calibrate_usage_errors(tmp_path):
    with pytest.raises(SystemExit) as ei:
        cli.main(["calibrate", "--quick", "--kernels", "bogus",
                  "--out", str(tmp_path / "c.json")])
    assert ei.value.code == cli.EXIT_USAGE
    with pytest.raises(SystemExit) as ei:
        cli.main(["calibrate", "--check",
                  "--out", str(tmp_path / "missing.json")])
    assert ei.value.code == cli.EXIT_USAGE
