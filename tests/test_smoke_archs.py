"""Per-architecture smoke tests: reduced config, one train step + one
prefill + one decode step on CPU.  Asserts output shapes and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.models.common import ExecConfig

EX = ExecConfig(ssd_chunk=8, attn_block=16)
SMOKE_SHAPE = ShapeConfig("smoke", "train", seq_len=32, global_batch=2)


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch(request):
    return request.param


@pytest.fixture(scope="module")
def setup(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), EX)
    return cfg, model, params


def _finite(tree):
    return all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


def test_loss_and_grad(setup):
    cfg, model, params = setup
    batch = model.make_batch(jax.random.PRNGKey(1), SMOKE_SHAPE, EX,
                             kind="train")
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: model.loss(p, batch, EX), has_aux=True)(params)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"loss={loss}"
    assert _finite(grads), "non-finite grads"
    # gradient should be nonzero for the embedding at least
    gnorm = sum(jnp.sum(g.astype(jnp.float32) ** 2)
                for g in jax.tree.leaves(grads))
    assert float(gnorm) > 0.0


def test_prefill_then_decode(setup):
    cfg, model, params = setup
    batch = model.make_batch(jax.random.PRNGKey(2), SMOKE_SHAPE, EX,
                             kind="prefill")
    logits, cache = model.prefill(params, batch, EX)
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache2 = model.decode_step(params, cache, tok,
                                        jnp.int32(SMOKE_SHAPE.seq_len - 1),
                                        EX)
    assert logits2.shape == (2, cfg.vocab)
    assert bool(jnp.isfinite(logits2).all())
    # cache must keep its structure
    assert (jax.tree.structure(cache) == jax.tree.structure(cache2))


def test_decode_from_zero_cache(setup):
    """serve_step lowering path: decode against a fresh cache."""
    cfg, model, params = setup
    dec_shape = ShapeConfig("smoke_dec", "decode", seq_len=32,
                            global_batch=2)
    batch = model.make_batch(jax.random.PRNGKey(3), dec_shape, EX)
    logits, _ = model.decode_step(params, batch["cache"], batch["tokens"],
                                  batch["pos"], EX)
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_param_count_formula(setup):
    """Analytic param_count tracks the real pytree within 5%."""
    cfg, model, params = setup
    real = sum(x.size for x in jax.tree.leaves(params))
    pred = cfg.param_count()
    assert abs(real - pred) / real < 0.05, (real, pred)
