"""Tests for the population-based batched outer search (repro.dse.outer)
and its satellites: the vectorized inner-search refinement, the pure
move generator + ``_rescale_dies`` device-count preservation, the single
Pareto engine, seed determinism for both outer methods, and the batched
RailX baseline."""
import dataclasses
import json

import numpy as np
import pytest

from repro.api import Scenario, Study
from repro.configs import get_config
from repro.core.mcm import MCMArch, mcm_from_compute
from repro.core.optimizer import (DesignPoint, _rescale_dies,
                                  chiplight_optimize, inner_search,
                                  pareto_front, propose_moves,
                                  railx_evaluate_point, railx_search)
from repro.core.workload import Workload
from repro.dse.outer import outer_search
from repro.dse.space import (DesignSpace, enumerate_space_batch,
                             enumerate_strategy_batch)
from repro.dse.search import refine_top_points, sweep_design_space

W_DENSE = Workload(model=get_config("tinyllama_1_1b"), seq_len=4096,
                   global_batch=256)
W_MOE = Workload(model=get_config("mixtral_8x7b"), seq_len=4096,
                 global_batch=256)


def _pt_key(p: DesignPoint):
    s = p.strategy
    return (s.tp, s.dp, s.pp, s.cp, s.ep, s.n_micro, p.mcm.n_mcm,
            p.mcm.x, p.mcm.y, p.mcm.m, p.mcm.cpo_ratio, p.fabric,
            p.throughput, p.cost, p.sim.step_time, p.topo)


# ---------------------------------------------------------------------------
# Satellite: _rescale_dies must preserve n_devices (or reject the move)
# ---------------------------------------------------------------------------
def test_rescale_dies_preserves_device_count():
    cur = MCMArch(n_mcm=8, x=4, y=4, m=6)           # 128 devices
    up = _rescale_dies(cur, 32)
    assert up.n_devices == cur.n_devices == 128
    assert up.dies_per_mcm == 32 and up.n_mcm == 4
    down = _rescale_dies(cur, 8)
    assert down.n_devices == 128 and down.n_mcm == 16


def test_rescale_dies_rejects_indivisible_target():
    cur = MCMArch(n_mcm=3, x=4, y=4, m=6)           # 48 devices
    # 48 // 32 = 1 would silently shrink the cluster to 32 devices
    out = _rescale_dies(cur, 32)
    assert out is cur                               # move rejected
    assert out.n_devices == 48
    ok = _rescale_dies(cur, 8)                      # 48 = 6 * 8: exact
    assert ok.n_devices == 48 and ok.dies_per_mcm == 8


def test_propose_moves_pure_generator_matches_planner():
    cur = mcm_from_compute(3e4, 4, 6)
    rng = np.random.default_rng(0)
    assert propose_moves(cur, None, rng) == \
        [dataclasses.replace(cur, m=min(cur.m + 2, 16))]
    moves = propose_moves(cur, {"mem_pressure": 0.9, "oi_bound": 1.0},
                          rng)
    assert len(moves) == 3          # m+2, cpo+0.1, dies*2
    assert all(m.n_devices == cur.n_devices for m in moves)


# ---------------------------------------------------------------------------
# Satellite: one Pareto implementation (pareto_front via pareto_mask)
# ---------------------------------------------------------------------------
def test_pareto_front_matches_bruteforce():
    _, pts = inner_search(W_DENSE, mcm_from_compute(1e5, 16, 6),
                          budget=24)
    assert len(pts) > 4
    front = pareto_front(pts)
    # brute force: p survives iff no q weakly dominates it (better or
    # equal everywhere, strictly better somewhere)
    expect = [p for p in pts
              if not any(q.cost <= p.cost and q.throughput >= p.throughput
                         and (q.cost < p.cost
                              or q.throughput > p.throughput)
                         for q in pts)]
    assert {(p.cost, p.throughput) for p in front} == \
        {(p.cost, p.throughput) for p in expect}
    # cost-ascending, throughput-ascending along the front, no duplicates
    costs = [p.cost for p in front]
    thpts = [p.throughput for p in front]
    assert costs == sorted(costs)
    assert thpts == sorted(thpts)
    assert len({(p.cost, p.throughput) for p in front}) == len(front)


# ---------------------------------------------------------------------------
# Satellite: inner_search rerouted through the vectorized refinement
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("w,C,dies", [(W_DENSE, 1e5, 16),
                                      (W_MOE, 2e5, 8)])
def test_inner_search_batched_matches_scalar(w, C, dies):
    mcm = mcm_from_compute(C, dies, 6)
    best_b, pts_b = inner_search(w, mcm, budget=16, method="batched")
    best_s, pts_s = inner_search(w, mcm, budget=16, method="scalar")
    assert len(pts_b) == len(pts_s) > 0
    assert [_pt_key(p) for p in pts_b] == [_pt_key(p) for p in pts_s]
    assert _pt_key(best_b) == _pt_key(best_s)


def test_inner_search_rejects_unknown_method():
    with pytest.raises(ValueError, match="method"):
        inner_search(W_DENSE, mcm_from_compute(1e5, 16, 6),
                     method="quantum")


# ---------------------------------------------------------------------------
# Scalar outer path: bit-identical wrapper + inner-method parity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("w,C,dies", [(W_DENSE, 3e4, 4), (W_MOE, 2e5, 8)])
def test_scalar_outer_trace_bit_identical_across_inner_methods(w, C, dies):
    """The scalar single-walker trace must not move under the vectorized
    inner refinement (dense + MoE)."""
    kw = dict(dies_per_mcm=dies, m0=6, outer_iters=2, inner_budget=8,
              seed=7)
    res_b = chiplight_optimize(w, C, inner_method="batched", **kw)
    res_s = chiplight_optimize(w, C, inner_method="scalar", **kw)
    assert res_b.outer_trace == res_s.outer_trace
    assert _pt_key(res_b.best) == _pt_key(res_s.best)
    assert [_pt_key(p) for p in res_b.history] == \
        [_pt_key(p) for p in res_s.history]


def test_chiplight_optimize_is_outer_search_scalar_wrapper():
    res_w = chiplight_optimize(W_DENSE, 3e4, dies_per_mcm=4, m0=6,
                               outer_iters=2, inner_budget=8, seed=7)
    res_o = outer_search(W_DENSE, 3e4, dies_per_mcm=4, m0=6, rounds=2,
                         inner_budget=8, walkers=1, seed=7,
                         method="scalar")
    assert res_w.outer_trace == res_o.outer_trace
    assert _pt_key(res_w.best) == _pt_key(res_o.best)
    with pytest.raises(ValueError, match="single-walker"):
        outer_search(W_DENSE, 3e4, walkers=4, method="scalar")
    with pytest.raises(ValueError, match="outer method"):
        outer_search(W_DENSE, 3e4, method="annealing")


# ---------------------------------------------------------------------------
# Population path: determinism, structure, cache
# ---------------------------------------------------------------------------
def _pop(seed=0, **kw):
    args = dict(dies_per_mcm=16, m0=6, rounds=3, inner_budget=8,
                walkers=4, seed=seed)
    args.update(kw)
    return outer_search(W_DENSE, 1e5, **args)


def test_population_seed_determinism():
    r1, r2 = _pop(), _pop()
    assert r1.outer_trace == r2.outer_trace
    assert _pt_key(r1.best) == _pt_key(r2.best)
    assert [_pt_key(p) for p in r1.history] == \
        [_pt_key(p) for p in r2.history]
    assert r1.stats == r2.stats


def test_population_trace_structure_and_cache():
    res = _pop()
    assert len(res.outer_trace) == 4            # rounds + 1
    for entry in res.outer_trace:
        assert len(entry["walkers"]) == 4
        assert all(len(wk["mcm"]) == 5 for wk in entry["walkers"])
        json.dumps(entry)                       # JSON-serializable
    st = res.stats
    # the cache makes revisited architectures free: the walkers asked
    # for more points than were ever simulated
    assert st["n_cache_hits"] > 0
    assert st["n_requested"] > st["n_sim"] > 0
    assert st["n_variants"] >= 4
    # population covers walker 0's start variant, so its best is at
    # least the single-variant inner-search best
    best0, _ = inner_search(W_DENSE, mcm_from_compute(1e5, 16, 6),
                            budget=8)
    assert res.best.throughput >= best0.throughput
    # every walker's best MCM keeps the cluster-compute constant
    n_dev = mcm_from_compute(1e5, 16, 6).n_devices
    for p in res.history:
        assert p.mcm.n_devices == n_dev


def test_population_study_records_deterministic_and_refined():
    sc = Scenario(model="tinyllama_1_1b", total_tflops=1e5, seq_len=4096,
                  global_batch=256, dies_per_mcm=(16,), m=(6,),
                  cpo_ratio=(0.6,), driver="chiplight-outer",
                  driver_kw={"rounds": 2, "walkers": 4,
                             "inner_budget": 8}, keep_top=16, seed=11)
    r1, r2 = Study(sc).run(), Study(sc).run()
    h = lambda r: json.dumps(r.to_dict(), sort_keys=True)
    assert [h(r) for r in r1.records] == [h(r) for r in r2.records]
    assert r1.traces == r2.traces
    assert len(r1.traces) == 3
    assert all(r.source == "refined" for r in r1.records)
    assert r1.records[0].topo is not None
    assert r1.provenance["engine"] == "dse.outer_search[population]"
    assert r1.provenance["n_cache_hits"] >= 0
    # walkers=1 + method=scalar reproduces the legacy engine label
    r3 = Study(sc.replace(driver_kw={"method": "scalar",
                                     "outer_iters": 2,
                                     "inner_budget": 8})).run()
    assert r3.provenance["engine"] == "core.chiplight_optimize"
    assert all(r.source == "scalar" for r in r3.records)


def test_outer_driver_rejects_unknown_kw():
    sc = Scenario(model="tinyllama_1_1b", total_tflops=1e5,
                  dies_per_mcm=(16,), m=(6,), cpo_ratio=(0.6,),
                  driver="chiplight-outer", driver_kw={"budget": 8})
    with pytest.raises(ValueError, match="does not accept driver_kw"):
        Study(sc).run()


# ---------------------------------------------------------------------------
# Satellite: batched strategy enumeration across MCM variants
# ---------------------------------------------------------------------------
def test_enumerate_space_batch_concatenates_variant_grids():
    mcms = [mcm_from_compute(1e5, 16, m) for m in (4, 6, 8)]
    batch, idx = enumerate_space_batch(W_DENSE, mcms)
    grids = [enumerate_strategy_batch(W_DENSE, m) for m in mcms]
    assert len(batch) == sum(len(g) for g in grids)
    assert np.array_equal(np.bincount(idx),
                          [len(g) for g in grids])
    # variants sharing (n_devices, dies) share ONE memoized grid
    assert grids[0] is grids[1] is grids[2]
    sub = batch.take(np.nonzero(idx == 1)[0])
    assert np.array_equal(sub.tp, grids[1].tp)


# ---------------------------------------------------------------------------
# RailX folded into the batched engine
# ---------------------------------------------------------------------------
def test_railx_batched_scan_matches_scalar_oracle():
    mcm = mcm_from_compute(1e5, 16, 6)
    space = DesignSpace(workload=W_DENSE, mcms=(mcm,), fabrics=("oi",),
                        reuse=True, alloc_mode="railx")
    sweep = sweep_design_space(space, driver="exhaustive")
    batch = enumerate_strategy_batch(W_DENSE, mcm)
    strats = batch.to_strategies()
    assert len(sweep) == len(strats) > 0
    checked = 0
    for i, s in enumerate(strats):
        pt = railx_evaluate_point(W_DENSE, s, mcm)
        if pt is None:
            continue        # scan is topology-blind; refinement drops it
        assert sweep.metrics["feasible"][i]
        assert sweep.metrics["throughput"][i] == \
            pytest.approx(pt.throughput, rel=1e-9)
        checked += 1
    assert checked >= len(strats) // 2


def test_railx_refinement_matches_scalar_search_best():
    mcm = mcm_from_compute(1e5, 16, 6)
    space = DesignSpace(workload=W_DENSE, mcms=(mcm,), fabrics=("oi",),
                        reuse=True, alloc_mode="railx")
    sweep = sweep_design_space(space, driver="exhaustive")
    pts = refine_top_points(sweep, top_k=8)
    best, _ = railx_search(W_DENSE, mcm, budget=10 ** 6)
    assert pts and best is not None
    assert pts[0].throughput == best.throughput
    assert pts[0].topo is not None


def test_railx_study_sweeps_multi_cell_grid():
    sc = Scenario(model="tinyllama_1_1b", total_tflops=1e5, seq_len=4096,
                  global_batch=256, dies_per_mcm=(16,), m=(4, 6),
                  cpo_ratio=(0.3, 0.6), driver="railx", refine_top=2,
                  keep_top=8)
    res = Study(sc).run()
    assert res.best is not None
    assert {r.source for r in res.records} == {"batched", "refined"}
    assert res.provenance["engine"] == "dse.sweep[railx]+refine"
    # refined railx records carry the derived (uniform-dim) topology
    refined = [r for r in res.records if r.source == "refined"]
    assert refined and refined[0].topo is not None
    rs = [d[1] for d in refined[0].topo["dims"]]
    assert len(set(rs)) <= 1            # uniform link split across dims
