"""Unit + property tests for the ChipLight core (paper §III/§IV)."""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core import (DEFAULT_HW, MCMArch, OITopology, RailDim, Strategy,
                        Workload, allocate_links, cluster_cost,
                        derive_physical, enumerate_strategies,
                        evaluate_point, inner_search, map_intra,
                        mcm_from_compute, pareto_front, simulate,
                        traffic_matrix, traffic_volumes)
from repro.core.optimizer import railx_topology
from repro.core.prf import PRF
from repro.core.traffic import reusable_pairs
from repro.core.workload import paper_workload

W = paper_workload(global_batch=512)


# ---------------------------------------------------------------------------
# Traffic model (paper §III, Obs 1-4)
# ---------------------------------------------------------------------------
def test_observation1_ordering():
    """Obs 1: TP > (CP, EP) > (DP, PP) for the paper's profiling setup."""
    s = Strategy(tp=8, dp=4, pp=4, cp=2, ep=4, n_micro=16)  # 1024 devices
    v = traffic_volumes(W, s)
    assert v["TP"] > v["CP"] and v["TP"] > v["EP"]
    assert v["EP"] > v["DP"] and v["EP"] > v["PP"]


def test_volumes_scale_linearly_in_batch():
    s = Strategy(tp=8, dp=4, pp=4, cp=2, ep=4, n_micro=16)
    w2 = Workload(model=W.model, seq_len=W.seq_len,
                  global_batch=W.global_batch * 2)
    v1, v2 = traffic_volumes(W, s), traffic_volumes(w2, s)
    for p in ("TP", "CP", "EP", "PP"):
        if v1[p] > 0:
            assert v2[p] == pytest.approx(2 * v1[p], rel=1e-6)
    assert v2["DP"] == pytest.approx(v1["DP"], rel=1e-6)  # batch-invariant


def test_moe_free_arch_has_no_ep_traffic():
    w = Workload(model=get_config("tinyllama_1_1b"), seq_len=4096,
                 global_batch=256)
    v = traffic_volumes(w, Strategy(tp=4, dp=8, pp=2, cp=2, ep=1))
    assert v["EP"] == 0.0


def test_ssm_arch_has_reduced_cp_traffic():
    """CP for attention-free archs: no ring-attention volume."""
    w = Workload(model=get_config("mamba2_780m"), seq_len=4096,
                 global_batch=256)
    v = traffic_volumes(w, Strategy(tp=2, dp=16, pp=1, cp=4, ep=1))
    assert v["CP"] == 0.0   # no attention layers


def test_traffic_matrix_sparse_and_conserving():
    """Fig 4: spatially sparse; row sums equal summed per-parallelism
    volumes."""
    s = Strategy(tp=4, dp=4, pp=2, cp=2, ep=2, n_micro=8)
    m = traffic_matrix(W, s)
    n = s.n_devices
    assert m.shape == (n, n)
    vols = traffic_volumes(W, s)
    np.testing.assert_allclose(m.sum(1), sum(vols.values()), rtol=1e-9)
    sparsity = (m > 0).mean()
    assert sparsity < 0.1, f"traffic should be sparse, got {sparsity:.2f}"


def test_temporal_reuse_pairs():
    """Obs 4: CP-EP is the primary reuse pair for MoE + long ctx."""
    s = Strategy(tp=8, dp=4, pp=1, cp=4, ep=8, n_micro=1)
    pairs = reusable_pairs(W, s)
    assert ("CP", "EP") in pairs or ("EP", "CP") in pairs


# ---------------------------------------------------------------------------
# MCM model (beachfront trade-offs)
# ---------------------------------------------------------------------------
def test_mcm_link_budget_formula():
    mcm = MCMArch(n_mcm=64, x=4, y=4, m=6, cpo_ratio=0.6)
    assert mcm.total_links == 2 * (4 + 4) * mcm.links_per_edge_unit


def test_more_hbm_dies_reduce_nop_bw():
    lo = MCMArch(n_mcm=1, x=4, y=4, m=4)
    hi = MCMArch(n_mcm=1, x=4, y=4, m=10)
    assert hi.hbm_bw > lo.hbm_bw
    assert hi.nop_bw < lo.nop_bw        # beachfront trade-off


def test_more_cpo_means_more_links_less_nop():
    lo = MCMArch(n_mcm=1, x=4, y=4, m=6, cpo_ratio=0.3)
    hi = MCMArch(n_mcm=1, x=4, y=4, m=6, cpo_ratio=0.9)
    assert hi.total_links > lo.total_links
    assert hi.nop_bw < lo.nop_bw


# ---------------------------------------------------------------------------
# OI network model (rail dimensions)
# ---------------------------------------------------------------------------
def test_ocs_count_formula():
    # paper: S = sum_i (prod_{j!=i} N_j) * S_i
    topo = OITopology(dims=(RailDim(n=8, r=4, k=1), RailDim(n=16, r=6, k=1)))
    assert topo.n_mcm() == 128
    assert topo.ocs_count() == 16 * 4 + 8 * 6


def test_port_constraint():
    d = RailDim(n=200, r=4, k=1)
    assert not d.port_ok(DEFAULT_HW.ocs_ports)
    assert RailDim(n=100, r=4, k=1).port_ok(DEFAULT_HW.ocs_ports)


@given(st.integers(2, 64), st.integers(2, 64), st.integers(1, 40))
@settings(max_examples=50, deadline=None)
def test_derive_physical_invariants(n1, n2, links):
    mcm = MCMArch(n_mcm=n1 * n2, x=4, y=4, m=6)
    degrees = {"DP": n1, "CP": n2}
    alloc = {"DP": max(links // 2, 1), "CP": max(links // 2, 1)}
    topo = derive_physical(degrees, alloc, mcm, n1 * n2)
    if topo is not None:
        assert topo.n_mcm() == n1 * n2                      # prod N_i = N
        assert topo.total_links_used() <= mcm.total_links   # sum R_i <= L
        for d in topo.dims:
            assert d.k * d.n <= DEFAULT_HW.ocs_ports or d.k > 1


@given(st.dictionaries(st.sampled_from(["DP", "PP", "CP", "EP"]),
                       st.floats(1e6, 1e12), min_size=1, max_size=4),
       st.integers(4, 128))
@settings(max_examples=80, deadline=None)
def test_allocate_links_conservation(vols, total):
    alloc = allocate_links(vols, total)
    assert sum(alloc.values()) <= total
    assert all(v >= 1 for v in alloc.values())


def test_link_reuse_eq1():
    # paper Eq (1): l_reuse = floor(L * max(v,v') / (sum_others + max))
    vols = {"CP": 4e9, "EP": 6e9, "DP": 2e9}
    total = 80
    alloc = allocate_links(vols, total, reuse_pair=("CP", "EP"))
    expect = int(total * 6e9 / (2e9 + 6e9))
    assert alloc["CP"] == alloc["EP"] == expect
    # reused pair gets MORE than its no-reuse share
    no_reuse = allocate_links(vols, total)
    assert alloc["EP"] > no_reuse["EP"]


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------
def test_die_cost_monotone_in_area():
    hw = DEFAULT_HW
    assert hw.die_cost(400) < hw.die_cost(800)
    # quarter dies are MORE than 4x cheaper (yield gain) per unit compute
    assert 4 * hw.die_cost(814 / 4) < hw.die_cost(814)


def test_cost_components():
    mcm = mcm_from_compute(1e6, dies_per_mcm=16, m=6)
    s = Strategy(tp=8, dp=64, pp=2, cp=1, ep=1, n_micro=8)
    pt = evaluate_point(W, s, mcm, fabric="oi")
    if pt is not None:
        cb = cluster_cost(mcm, pt.topo, fabric="oi")
        assert cb.silicon > 0 and cb.hbm > 0 and cb.cpo > 0
        assert cb.ocs > 0
        assert cb.total == pytest.approx(
            cb.silicon + cb.hbm + cb.packaging + cb.cpo + cb.ocs
            + cb.fiber + cb.nic)


# ---------------------------------------------------------------------------
# Simulator
# ---------------------------------------------------------------------------
def test_map_intra_tp_always_inside():
    mcm = MCMArch(n_mcm=64, x=4, y=4, m=6)
    got = map_intra(W, Strategy(tp=16, dp=64, pp=1, cp=1, ep=1), mcm)
    assert got is not None and got[0]["TP"] == 16
    # TP larger than the package is rejected
    assert map_intra(W, Strategy(tp=32, dp=32, pp=1, cp=1, ep=1),
                     mcm) is None


def test_simulator_memory_infeasible():
    mcm = MCMArch(n_mcm=4, x=2, y=2, m=1)   # 16 GB per die
    s = Strategy(tp=4, dp=4, pp=1, cp=1, ep=1)
    r = simulate(W, s, mcm)
    assert not r.feasible and "HBM capacity" in r.reason


def test_oi_beats_ib_at_scale():
    """Insight 1-ish: at large scale the OI fabric wins clearly."""
    mcm = mcm_from_compute(16e6, dies_per_mcm=16, m=6)
    best_ib, _ = inner_search(W, mcm, fabric="ib", budget=24, seed=1)
    best_oi, _ = inner_search(W, mcm, fabric="oi", budget=24, seed=1)
    assert best_oi.throughput > best_ib.throughput


def test_reuse_never_hurts_throughput():
    mcm = mcm_from_compute(16e6, dies_per_mcm=16, m=8)
    s = Strategy(tp=8, dp=8, pp=8, cp=4, ep=8, n_micro=32)
    pt_r = evaluate_point(W, s, mcm, fabric="oi", reuse=True)
    pt_n = evaluate_point(W, s, mcm, fabric="oi", reuse=False)
    if pt_r and pt_n:
        assert pt_r.throughput >= pt_n.throughput * 0.999


def test_railx_is_special_case_with_two_dims():
    mcm = mcm_from_compute(4e6, dies_per_mcm=16, m=6)
    degrees = {"DP": 16, "CP": 16}
    vols = {"DP": 5e9, "CP": 8e9}
    topo = railx_topology(mcm, degrees, vols)
    assert topo is not None and len(topo.dims) == 2
    assert topo.dims[0].r == topo.dims[1].r    # uniform split


# ---------------------------------------------------------------------------
# Optimizer / PRF
# ---------------------------------------------------------------------------
def test_enumerate_strategies_products():
    mcm = mcm_from_compute(1e6, dies_per_mcm=16, m=6)
    for s in enumerate_strategies(W, mcm)[:200]:
        assert s.n_devices == mcm.n_devices


def test_pareto_front_dominance():
    mcm = mcm_from_compute(1e6, dies_per_mcm=16, m=6)
    _, pts = inner_search(W, mcm, budget=16, seed=2)
    front = pareto_front(pts)
    for i, a in enumerate(front):
        for b in front[i + 1:]:
            # no point on the front dominates another
            assert not (a.cost <= b.cost and a.throughput >= b.throughput)


def test_prf_learns_simple_function():
    rng = np.random.default_rng(0)
    x = rng.uniform(0, 4, size=(200, 3))
    y = 2 * x[:, 0] - x[:, 1] ** 2 + 0.1 * rng.normal(size=200)
    model = PRF(seed=1).fit(x[:150], y[:150])
    pred = model.predict(x[150:])
    resid = np.mean((pred - y[150:]) ** 2)
    base = np.mean((y[150:] - y[:150].mean()) ** 2)
    assert resid < base * 0.5     # clearly better than predicting the mean


def test_inner_search_improves_over_random_point():
    mcm = mcm_from_compute(2e6, dies_per_mcm=16, m=6)
    best, pts = inner_search(W, mcm, budget=24, seed=3)
    assert best is not None
    med = float(np.median([p.throughput for p in pts]))
    assert best.throughput >= med
