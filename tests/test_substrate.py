"""Substrate tests: optimizer, data, checkpointing, fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager, restore_pytree, save_pytree
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data import DataPipeline
from repro.launch.steps import init_train_state, make_train_step
from repro.models.common import ExecConfig
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         compress_int8, cosine_schedule, decompress_int8,
                         ef_compress_update)
from repro.runtime import FaultTolerantLoop

EX = ExecConfig(ssd_chunk=8, attn_block=16)
SHAPE = ShapeConfig("t", "train", seq_len=32, global_batch=4)
CFG = get_config("tinyllama_1_1b").reduced()


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------
def test_adamw_decreases_quadratic():
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw_init(params)
    lr = cosine_schedule(0.1, warmup=1, total=100)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, lr,
                                        weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    got = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
    assert got == pytest.approx(1.0, rel=1e-5)


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_cosine_schedule_bounds(step):
    lr = cosine_schedule(1e-3, warmup=100, total=10_000)(jnp.int32(step))
    assert 0.0 <= float(lr) <= 1e-3 + 1e-9


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------
@given(st.integers(1, 8), st.integers(1, 64))
@settings(max_examples=20, deadline=None)
def test_int8_roundtrip_bounded_error(rows, cols):
    x = jax.random.normal(jax.random.PRNGKey(rows * 100 + cols),
                          (rows, cols))
    q, s = compress_int8(x)
    back = decompress_int8(q, s, x.shape)
    scale = jnp.max(jnp.abs(x.reshape(rows, -1)), -1, keepdims=True)
    assert float(jnp.max(jnp.abs(back - x))) <= float(scale.max()) / 127 + 1e-6


def test_error_feedback_accumulates():
    g = {"w": jnp.array([[0.001, 1.0, -1.0, 0.0004]])}
    deq1, err1 = ef_compress_update(g, None)
    # the residual carries what quantisation dropped
    total = jnp.abs(deq1["w"] + err1["w"] - g["w"]).max()
    assert float(total) < 1e-6


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------
def test_pipeline_deterministic_and_resumable():
    p1 = DataPipeline(CFG, SHAPE, seed=7)
    batches = [next(p1) for _ in range(3)]
    p2 = DataPipeline(CFG, SHAPE, seed=7)
    p2.restore({"seed": 7, "step": 2})
    b2 = next(p2)
    np.testing.assert_array_equal(batches[2]["tokens"], b2["tokens"])
    assert batches[0]["tokens"].max() < CFG.vocab


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------
def test_pytree_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.bfloat16),
            "b": {"c": jnp.ones((4,), jnp.float32)}}
    save_pytree(tree, tmp_path / "ck")
    back = restore_pytree(tree, tmp_path / "ck")
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))
        assert x.dtype == y.dtype


def test_checkpoint_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_write=False)
    tree = {"x": jnp.zeros((2,))}
    for s in (10, 20, 30):
        mgr.save(s, tree)
    assert mgr.all_steps() == [20, 30]
    assert mgr.latest_step() == 30


# ---------------------------------------------------------------------------
# End-to-end: train -> crash -> restore -> bitwise continuation
# ---------------------------------------------------------------------------
def _fresh(seed=0):
    return init_train_state(CFG, EX, seed=seed)


def test_loss_decreases_over_training():
    step = jax.jit(make_train_step(CFG, EX, base_lr=5e-3, warmup=5,
                                   total=120))
    state = _fresh()
    pipe = DataPipeline(CFG, SHAPE, seed=1)
    losses = []
    for i in range(60):
        state, m = step(state, pipe.batch_at(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses


def test_crash_restore_bitwise_identical(tmp_path):
    step = jax.jit(make_train_step(CFG, EX, base_lr=1e-4))
    pipe = DataPipeline(CFG, SHAPE, seed=3)
    mgr = CheckpointManager(tmp_path / "ck", async_write=False)
    loop = FaultTolerantLoop(step, mgr, pipe, checkpoint_every=4)
    state, last = loop.run(_fresh(), 6)   # ckpt at 4, stop at 6

    # uninterrupted reference: 10 steps straight
    pipe_ref = DataPipeline(CFG, SHAPE, seed=3)
    ref = _fresh()
    for i in range(10):
        ref, _ = step(ref, pipe_ref.batch_at(i))

    # "crash": new process state, resume from step 4 and run to 10
    pipe2 = DataPipeline(CFG, SHAPE, seed=3)
    loop2 = FaultTolerantLoop(step, mgr, pipe2, checkpoint_every=100)
    restored, start = loop2.resume_or_init(_fresh(seed=9))
    assert start == 4
    state2, last2 = loop2.run(restored, 10, start_step=start)
    assert last2 == 10
    for a, b in zip(jax.tree.leaves(ref.params),
                    jax.tree.leaves(state2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grad_accumulation_matches_large_batch():
    """accum=2 over batch 4 == one step over the same 4 sequences."""
    step1 = jax.jit(make_train_step(CFG, EX, base_lr=1e-4))
    step2 = jax.jit(make_train_step(CFG, EX, base_lr=1e-4, accum=2))
    pipe = DataPipeline(CFG, SHAPE, seed=5)
    batch = pipe.batch_at(0)
    s1, m1 = step1(_fresh(), batch)
    s2, m2 = step2(_fresh(), batch)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)
