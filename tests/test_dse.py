"""Tests for the batched DSE engine (repro.dse).

The contract under test: ``batched_simulate`` must reproduce the scalar
oracle ``core.simulator.simulate`` element-wise — same feasibility mask,
step times within 1e-9 relative — over >=1000 sampled design points,
plus Pareto / allocation / driver invariants.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.mcm import mcm_from_compute
from repro.core.network import allocate_links
from repro.core.simulator import simulate
from repro.core.traffic import PARALLELISMS
from repro.core.workload import Workload, paper_workload
from repro.dse.batched_sim import (MCMBatch, allocate_links_batch,
                                   batched_simulate)
from repro.dse.pareto import (crowding_distance, nondominated_sort,
                              pareto_mask)
from repro.dse.search import (BatchedEvaluator, search_exhaustive,
                              search_nsga2, search_prf_ucb, search_random,
                              sweep_design_space)
from repro.dse.space import (DesignSpace, P_IDX, StrategyBatch,
                             enumerate_strategy_batch)

W = paper_workload(global_batch=512)
TINY = Workload(model=get_config("tinyllama_1_1b"), seq_len=4096,
                global_batch=256)


def _assert_parity(w, batch, mcm, fabric, reuse, hw=None):
    res = batched_simulate(w, batch, mcm, fabric=fabric, reuse=reuse, hw=hw)
    n_checked = 0
    for i, s in enumerate(batch.to_strategies()):
        r = simulate(w, s, mcm, fabric=fabric, topo=None, reuse=reuse,
                     hw=hw)
        assert r.feasible == bool(res.feasible[i]), (s, r.reason)
        if r.feasible:
            assert res.step_time[i] == pytest.approx(r.step_time, rel=1e-9)
            assert res.throughput[i] == pytest.approx(r.throughput,
                                                      rel=1e-9)
        n_checked += 1
    return n_checked


# ---------------------------------------------------------------------------
# Enumeration
# ---------------------------------------------------------------------------
def test_enumeration_matches_scalar():
    from repro.core.optimizer import enumerate_strategies
    for w, c in ((W, 4e6), (TINY, 1e6)):
        mcm = mcm_from_compute(c, dies_per_mcm=16, m=6)
        scal = {(s.tp, s.dp, s.pp, s.cp, s.ep, s.n_micro)
                for s in enumerate_strategies(w, mcm)}
        batch = enumerate_strategy_batch(w, mcm)
        soa = set(batch.keys())
        assert soa == scal and len(batch) == len(scal)


# ---------------------------------------------------------------------------
# Element-wise parity vs the scalar oracle (>= 1000 points total)
# ---------------------------------------------------------------------------
def test_parity_paper_workload_all_fabrics():
    mcm = mcm_from_compute(4e6, dies_per_mcm=16, m=6)
    batch = enumerate_strategy_batch(W, mcm)
    n = 0
    for fabric in ("oi", "ib", "nvlink"):
        n += _assert_parity(W, batch, mcm, fabric, reuse=True)
    n += _assert_parity(W, batch, mcm, "oi", reuse=False)
    assert n >= 1000          # the acceptance floor, on this test alone


def test_parity_includes_infeasible_and_invalid_points():
    rng = np.random.default_rng(3)
    mcm = mcm_from_compute(1e6, dies_per_mcm=16, m=2)   # tight HBM
    vals = np.array([1, 2, 4, 8, 16, 32, 64])
    batch = StrategyBatch(*(rng.choice(vals, 80) for _ in range(5)),
                          rng.choice([1, 2, 8, 32], 80))
    res = batched_simulate(W, batch, mcm)
    assert not res.feasible.all()            # invalid products / HBM
    _assert_parity(W, batch, mcm, "oi", reuse=True)


def test_parity_reuse_paper_mode_and_gemm_eff():
    mcm = mcm_from_compute(16e6, dies_per_mcm=16, m=8)
    hw_p = dataclasses.replace(mcm.hw, ocs_reuse_mode="paper")
    batch = enumerate_strategy_batch(W, mcm)
    sub = batch.take(np.arange(len(batch))[:: max(len(batch) // 80, 1)])
    _assert_parity(W, sub, mcm, "oi", reuse=True, hw=hw_p)
    hw_g = dataclasses.replace(mcm.hw, model_gemm_eff=True)
    _assert_parity(W, sub, mcm, "oi", reuse=True, hw=hw_g)


def test_parity_moe_free_and_fused_mcm_batch():
    space = DesignSpace.from_compute(TINY, 1e6, fabrics=("oi",),
                                     m=(2, 6), cpo_ratio=(0.3, 0.9))
    cells = list(space.batches())
    batch = StrategyBatch.concat([g for _, _, g in cells])
    local = np.concatenate([np.full(len(g), i, np.int64)
                            for i, (_, _, g) in enumerate(cells)])
    mcms = [m for m, _, _ in cells]
    res = batched_simulate(TINY, batch, MCMBatch.from_mcms(mcms, local),
                           fabric="oi", reuse=True, hw=mcms[0].hw)
    for i, s in enumerate(batch.to_strategies()):
        r = simulate(TINY, s, mcms[local[i]], fabric="oi", topo=None)
        assert r.feasible == bool(res.feasible[i])
        if r.feasible:
            assert res.step_time[i] == pytest.approx(r.step_time, rel=1e-9)


def test_jax_backend_matches_numpy():
    mcm = mcm_from_compute(2e6, dies_per_mcm=16, m=6)
    batch = enumerate_strategy_batch(W, mcm)
    rn = batched_simulate(W, batch, mcm, backend="numpy")
    rj = batched_simulate(W, batch, mcm, backend="jax")
    assert np.array_equal(rn.feasible, rj.feasible)
    ok = rn.feasible
    np.testing.assert_allclose(rj.step_time[ok], rn.step_time[ok],
                               rtol=1e-9)


# ---------------------------------------------------------------------------
# Link allocation
# ---------------------------------------------------------------------------
def test_allocate_links_batch_matches_scalar():
    rng = np.random.default_rng(7)
    B = 300
    vols = rng.uniform(1e6, 1e12, size=(B, 5))
    mask = rng.random((B, 5)) < 0.7
    vols = np.where(mask, vols, 0.0)
    pair_choices = [(-1, -1), (P_IDX["CP"], P_IDX["EP"]),
                    (P_IDX["CP"], P_IDX["DP"]), (P_IDX["EP"], P_IDX["DP"])]
    picks = rng.integers(len(pair_choices), size=B)
    pa = np.array([pair_choices[p][0] for p in picks])
    pb = np.array([pair_choices[p][1] for p in picks])
    # a pair only counts when both members carry inter traffic
    valid = (pa >= 0) & mask[np.arange(B), np.maximum(pa, 0)] \
        & mask[np.arange(B), np.maximum(pb, 0)]
    pa, pb = np.where(valid, pa, -1), np.where(valid, pb, -1)
    for L in (3, 17, 96):
        got = allocate_links_batch(vols, mask, L, pa, pb)
        for i in range(B):
            d = {p: vols[i, P_IDX[p]] for p in PARALLELISMS
                 if mask[i, P_IDX[p]]}
            rp = None
            if pa[i] >= 0:
                rp = (PARALLELISMS[pa[i]], PARALLELISMS[pb[i]])
            want = allocate_links(d, L, rp)
            for p, v in want.items():
                assert got[i, P_IDX[p]] == v, (i, L, d, rp, want)


def test_allocate_links_reuse_respects_budget():
    # the fixed trim: l_reuse + others (pair counted once) <= L
    vols = {"CP": 5e9, "EP": 9e9, "DP": 4e9, "PP": 1e3}
    for L in (3, 4, 5, 8, 64):
        alloc = allocate_links(vols, L, ("CP", "EP"))
        used = alloc["CP"] + alloc["DP"] + alloc["PP"]
        assert used <= L or max(alloc.values()) <= 1
        assert alloc["CP"] == alloc["EP"]


# ---------------------------------------------------------------------------
# Pareto invariants
# ---------------------------------------------------------------------------
def test_pareto_mask_no_dominated_survivor():
    rng = np.random.default_rng(0)
    obj = rng.normal(size=(400, 3))
    obj[50:60] = obj[40:50]                  # duplicates must survive
    maximize = [True, False, True]
    keep = pareto_mask(obj, maximize)
    sign = np.where(maximize, 1.0, -1.0)
    M = obj * sign
    for i in np.nonzero(keep)[0]:
        dom = (M >= M[i]).all(1) & (M > M[i]).any(1)
        assert not dom.any()
    # and every removed point IS dominated by someone
    for i in np.nonzero(~keep)[0]:
        dom = (M >= M[i]).all(1) & (M > M[i]).any(1)
        assert dom.any()


def test_nondominated_sort_fronts_are_clean():
    rng = np.random.default_rng(1)
    obj = rng.normal(size=(200, 2))
    maximize = [True, True]
    ranks = nondominated_sort(obj, maximize)
    assert (ranks[pareto_mask(obj, maximize)] == 0).all()
    for r in range(int(ranks.max()) + 1):
        sel = ranks >= r
        front = pareto_mask(obj[sel], maximize)
        assert (ranks[np.nonzero(sel)[0][front]] == r).all()
    d = crowding_distance(obj[ranks == 0], maximize)
    assert np.isinf(d).sum() >= 2            # boundary points


def test_sweep_pareto_and_best():
    space = DesignSpace.from_compute(TINY, 1e6, fabrics=("oi", "ib"),
                                     m=(2, 6, 8), cpo_ratio=(0.6,))
    sweep = sweep_design_space(space)
    assert len(sweep) > 500
    pi = sweep.pareto_indices()
    assert len(pi) > 0
    best = sweep.best
    t, c, p = (sweep.metrics["throughput"], sweep.metrics["cost"],
               sweep.metrics["power"])
    feas = np.nonzero(sweep.metrics["feasible"])[0]
    for i in pi:
        dom = (t[feas] >= t[i]) & (c[feas] <= c[i]) & (p[feas] <= p[i]) \
            & ((t[feas] > t[i]) | (c[feas] < c[i]) | (p[feas] < p[i]))
        assert not dom.any()
    assert best in pi                        # max-throughput is on the front


# ---------------------------------------------------------------------------
# Drivers + cache
# ---------------------------------------------------------------------------
def test_drivers_and_cache():
    mcm = mcm_from_compute(2e6, dies_per_mcm=16, m=6)
    full = search_exhaustive(BatchedEvaluator(W, mcm))
    t_best = full.metrics["throughput"].max()
    assert full.metrics["feasible"].any()

    r = search_random(BatchedEvaluator(W, mcm), budget=60, seed=0)
    assert r.n_sim <= 60
    p = search_prf_ucb(BatchedEvaluator(W, mcm), budget=60, seed=0)
    assert p.n_sim <= 60
    assert p.metrics["throughput"].max() <= t_best + 1e-9
    g = search_nsga2(BatchedEvaluator(W, mcm), pop_size=16, generations=4,
                     seed=0)
    assert g.metrics["throughput"].max() <= t_best + 1e-9
    assert (g.batch.n_devices == mcm.n_devices).all()   # repair keeps grid

    ev = BatchedEvaluator(W, mcm)
    search_exhaustive(ev)
    n = ev.n_sim
    again = search_exhaustive(ev)
    assert ev.n_sim == n and ev.n_hits >= len(again.batch)


# ---------------------------------------------------------------------------
# Vectorized evaluation cache
# ---------------------------------------------------------------------------
def test_evaluator_cache_vectorized_hits_and_values():
    mcm = mcm_from_compute(2e6, dies_per_mcm=16, m=6)
    ev = BatchedEvaluator(W, mcm)
    grid = enumerate_strategy_batch(W, mcm)
    half = grid.take(np.arange(len(grid) // 2))
    m1 = ev.evaluate(half)
    assert ev.n_sim == len(half) and ev.n_hits == 0
    m2 = ev.evaluate(grid)                    # first half must be hits
    assert ev.n_hits == len(half)
    assert ev.n_sim == len(grid)
    for k in m1:
        np.testing.assert_array_equal(m2[k][: len(half)], m1[k])
    # duplicate rows inside one batch resolve consistently
    dup = grid.take(np.array([0, 0, 1, 1, 0]))
    m3 = ev.evaluate(dup)
    assert m3["step_time"][0] == m3["step_time"][1] == m3["step_time"][4]


def test_evaluator_cache_falls_back_on_unpackable_degrees():
    mcm = mcm_from_compute(2e6, dies_per_mcm=16, m=6)
    ev = BatchedEvaluator(W, mcm)
    huge = StrategyBatch(*(np.full(4, 1 << 11, np.int64)
                           for _ in range(6)))
    m1 = ev.evaluate(huge)                    # 6 x 12 bits > 64 -> dict
    assert ev._fallback is not None
    assert not m1["feasible"].any()
    m2 = ev.evaluate(huge)
    assert ev.n_hits >= len(huge)             # still caches correctly
    np.testing.assert_array_equal(m1["step_time"], m2["step_time"])


def test_evaluator_cache_repacks_when_widths_grow():
    mcm = mcm_from_compute(2e6, dies_per_mcm=16, m=6)
    ev = BatchedEvaluator(W, mcm)
    grid = enumerate_strategy_batch(W, mcm)
    small = grid.take(np.arange(8))
    ev.evaluate(small)
    wide = StrategyBatch(np.array([4096]), np.array([1]), np.array([1]),
                         np.array([1]), np.array([1]), np.array([1]))
    ev.evaluate(wide)                         # forces width growth+repack
    n = ev.n_sim
    m = ev.evaluate(small)                    # old keys still hit
    assert ev.n_sim == n
    assert len(m["step_time"]) == len(small)


# ---------------------------------------------------------------------------
# Fused multi-cell driving == per-cell driving
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("driver,kw", [
    ("random", {"budget": 24}),
    ("prf", {"budget": 24}),
    ("nsga2", {"pop_size": 10, "generations": 2}),
])
def test_sweep_fused_driver_matches_per_cell(driver, kw):
    from repro.dse.search import DRIVERS
    space = DesignSpace.from_compute(TINY, 1e6, fabrics=("oi", "ib"),
                                     m=(2, 6), cpo_ratio=(0.6,))
    sweep = sweep_design_space(space, driver=driver, seed=3, **kw)
    run = DRIVERS[driver]
    pos = {id(m): i for i, m in enumerate(space.mcms)}
    tp, thpt, cost, mi, fb = [], [], [], [], []
    for ci, (mcm, fabric, grid) in enumerate(space.batches()):
        ev = BatchedEvaluator(space.workload, mcm, fabric, space.reuse)
        res = run(ev, grid=grid, seed=3 + ci, **kw)
        tp.append(res.batch.tp)
        thpt.append(res.metrics["throughput"])
        cost.append(res.metrics["cost"])
        mi.append(np.full(len(res.batch), pos[id(mcm)]))
        fb.append(np.full(len(res.batch), fabric))
    assert np.array_equal(sweep.batch.tp, np.concatenate(tp))
    assert np.array_equal(sweep.metrics["throughput"],
                          np.concatenate(thpt))
    assert np.array_equal(sweep.metrics["cost"], np.concatenate(cost))
    assert np.array_equal(sweep.mcm_idx, np.concatenate(mi))
    assert np.array_equal(sweep.fabric, np.concatenate(fb))


def test_fused_paths_respect_per_mcm_hw():
    """A hand-built DesignSpace may mix HW configs across MCM variants;
    fused sweeps and batched refinement must simulate each cell with
    ITS hw, not the first cell's."""
    import dataclasses as dc
    from repro.dse.search import refine_top_points
    m1 = mcm_from_compute(1e6, dies_per_mcm=16, m=6)
    hw2 = dc.replace(m1.hw, mfu_ceiling=m1.hw.mfu_ceiling / 2)
    m2 = dc.replace(mcm_from_compute(1e6, dies_per_mcm=16, m=2), hw=hw2)
    space = DesignSpace(workload=TINY, mcms=(m1, m2), fabrics=("oi",))
    for driver, kw in (("exhaustive", {}), ("random", {"budget": 16})):
        sweep = sweep_design_space(space, driver=driver, **kw)
        for i in (0, len(sweep) - 1):
            s = sweep.batch.take(np.array([i])).to_strategies()[0]
            mcm = space.mcms[int(sweep.mcm_idx[i])]
            r = simulate(TINY, s, mcm, fabric="oi", topo=None,
                         hw=mcm.hw)
            assert bool(sweep.metrics["feasible"][i]) == r.feasible
            if r.feasible:
                assert sweep.metrics["step_time"][i] == pytest.approx(
                    r.step_time, rel=1e-9)
    sweep = sweep_design_space(space)
    got = refine_top_points(sweep, top_k=12)
    want = refine_top_points(sweep, top_k=12, method="scalar")
    assert [p.strategy for p in got] == [p.strategy for p in want]
    for pg, pw in zip(got, want):
        assert pg.throughput == pytest.approx(pw.throughput, rel=1e-9)


# ---------------------------------------------------------------------------
# Batched refinement == scalar oracle (dense + MoE presets)
# ---------------------------------------------------------------------------
def _assert_refine_parity(space, top_k):
    from repro.dse.search import refine_top_points
    sweep = sweep_design_space(space)
    batched = refine_top_points(sweep, top_k=top_k)
    scalar = refine_top_points(sweep, top_k=top_k, method="scalar")
    assert len(batched) == len(scalar) > 0
    for pb, ps in zip(batched, scalar):
        assert pb.strategy == ps.strategy          # identical ranking
        assert pb.mcm == ps.mcm and pb.fabric == ps.fabric
        assert pb.throughput == pytest.approx(ps.throughput, rel=1e-9)
        assert pb.cost == pytest.approx(ps.cost, rel=1e-9)
        assert pb.sim.step_time == pytest.approx(ps.sim.step_time,
                                                 rel=1e-9)
        assert pb.sim.mfu == pytest.approx(ps.sim.mfu, rel=1e-9)
        if ps.topo is None:
            assert pb.topo is None
        else:
            assert pb.topo.dims == ps.topo.dims
            assert pb.topo.mapping == ps.topo.mapping
            assert dict(pb.topo.link_alloc) == dict(ps.topo.link_alloc)
            assert pb.topo.reuse_pair == ps.topo.reuse_pair
        assert pb.sim.bottleneck == ps.sim.bottleneck
        assert set(pb.sim.breakdown) == set(ps.sim.breakdown)
        for k, v in ps.sim.logs.items():
            assert pb.sim.logs[k] == pytest.approx(v, rel=1e-9, abs=0.0), k
    return batched


def test_refine_batched_matches_scalar_dense():
    space = DesignSpace.from_compute(TINY, 1e6, fabrics=("oi", "ib"),
                                     m=(2, 6), cpo_ratio=(0.3, 0.9))
    _assert_refine_parity(space, top_k=24)


def test_refine_batched_matches_scalar_moe():
    space = DesignSpace.from_compute(W, 4e6, fabrics=("oi",),
                                     dies_per_mcm=(8, 16), m=(4, 6),
                                     cpo_ratio=(0.6,))
    pts = _assert_refine_parity(space, top_k=24)
    # refined OI points carry a derived physical topology
    assert any(p.topo is not None and p.topo.dims for p in pts)


def test_refine_board_power_matches_scalar_records():
    from repro.api import record_from_point
    from repro.dse.search import refine_top_points
    space = DesignSpace.from_compute(TINY, 1e6, fabrics=("oi",),
                                     m=(2, 6), cpo_ratio=(0.6,))
    sweep = sweep_design_space(space)
    recs_b = [record_from_point(p)
              for p in refine_top_points(sweep, top_k=8)]
    recs_s = [record_from_point(p)
              for p in refine_top_points(sweep, top_k=8,
                                         method="scalar")]
    for rb, rs in zip(recs_b, recs_s):
        for k in ("throughput", "cost", "power"):
            assert rb.metrics[k] == pytest.approx(rs.metrics[k],
                                                  rel=1e-9), k


def test_refine_rejects_unknown_method():
    from repro.dse.search import refine_top_points
    space = DesignSpace.from_compute(TINY, 1e6, fabrics=("oi",),
                                     m=(6,), cpo_ratio=(0.6,))
    sweep = sweep_design_space(space)
    with pytest.raises(ValueError, match="refine method"):
        refine_top_points(sweep, top_k=2, method="quantum")


# ---------------------------------------------------------------------------
# JAX backend: bucketed jit cache + auto resolution
# ---------------------------------------------------------------------------
def test_jax_backend_parity_all_fabrics_and_fused():
    mcm = mcm_from_compute(2e6, dies_per_mcm=16, m=6)
    batch = enumerate_strategy_batch(W, mcm)
    for fabric in ("oi", "ib", "nvlink"):
        rn = batched_simulate(W, batch, mcm, fabric=fabric,
                              backend="numpy")
        rj = batched_simulate(W, batch, mcm, fabric=fabric,
                              backend="jax")
        assert np.array_equal(rn.feasible, rj.feasible)
        ok = rn.feasible
        np.testing.assert_allclose(rj.step_time[ok], rn.step_time[ok],
                                   rtol=1e-9)
        np.testing.assert_allclose(rj.power[ok], rn.power[ok], rtol=1e-9)
    # heterogeneous MCMBatch through the jax path
    space = DesignSpace.from_compute(TINY, 1e6, fabrics=("oi",),
                                     m=(2, 6), cpo_ratio=(0.3, 0.9))
    cells = list(space.batches())
    fused = StrategyBatch.concat([g for _, _, g in cells])
    local = np.concatenate([np.full(len(g), i, np.int64)
                            for i, (_, _, g) in enumerate(cells)])
    mb = MCMBatch.from_mcms([m for m, _, _ in cells], local)
    hw = cells[0][0].hw
    rn = batched_simulate(TINY, fused, mb, hw=hw, backend="numpy")
    rj = batched_simulate(TINY, fused, mb, hw=hw, backend="jax")
    assert np.array_equal(rn.feasible, rj.feasible)
    ok = rn.feasible
    np.testing.assert_allclose(rj.step_time[ok], rn.step_time[ok],
                               rtol=1e-9)
    # no-reuse path too
    rn = batched_simulate(W, batch, mcm, reuse=False, backend="numpy")
    rj = batched_simulate(W, batch, mcm, reuse=False, backend="jax")
    np.testing.assert_allclose(rj.step_time[rn.feasible],
                               rn.step_time[rn.feasible], rtol=1e-9)


def test_jax_bucketed_jit_does_not_retrace():
    from repro.dse import batched_sim as bs
    mcm = mcm_from_compute(2e6, dies_per_mcm=16, m=6)
    batch = enumerate_strategy_batch(W, mcm)
    n0 = len(batch) // 2
    batched_simulate(W, batch.take(np.arange(n0)), mcm, backend="jax")
    before = bs._JAX_TRACES["count"]
    for n in range(n0, n0 + 8):       # same power-of-two bucket
        batched_simulate(W, batch.take(np.arange(n)), mcm,
                         backend="jax")
    assert bs._JAX_TRACES["count"] == before


def test_auto_backend_resolution():
    from repro.dse.batched_sim import JAX_AUTO_MIN_BATCH, resolve_backend
    assert resolve_backend("numpy", 10 ** 9) == "numpy"
    assert resolve_backend("jax", 1) == "jax"
    assert resolve_backend("auto", 4) == "numpy"
    assert resolve_backend("auto", JAX_AUTO_MIN_BATCH) == "jax"
    mcm = mcm_from_compute(1e6, dies_per_mcm=16, m=6)
    batch = enumerate_strategy_batch(TINY, mcm)
    ra = batched_simulate(TINY, batch, mcm, backend="auto")
    rn = batched_simulate(TINY, batch, mcm, backend="numpy")
    ok = rn.feasible
    np.testing.assert_allclose(ra.step_time[ok], rn.step_time[ok],
                               rtol=1e-9)


# ---------------------------------------------------------------------------
# pareto_mask: randomized brute-force cross-check
# ---------------------------------------------------------------------------
def test_pareto_mask_matches_bruteforce_randomized():
    rng = np.random.default_rng(11)
    for _ in range(15):
        n = int(rng.integers(1, 250))
        k = int(rng.integers(1, 4))
        obj = rng.normal(size=(n, k))
        if n > 20:
            obj[5:10] = obj[0:5]                 # duplicates
            obj[10:15, 0] = obj[15:20, 0]        # obj0 ties
            obj[int(rng.integers(n))] = np.nan
        maximize = [bool(b) for b in rng.integers(2, size=k)]
        got = pareto_mask(obj, maximize,
                          chunk=int(rng.choice([1, 7, 64, 512])))
        sign = np.where(maximize, 1.0, -1.0)
        M = obj * sign
        ok = ~np.isnan(M).any(1)
        want = ok.copy()
        for j in range(n):
            if not want[j]:
                continue
            dom = (M >= M[j]).all(1) & (M > M[j]).any(1) & ok
            want[j] = not dom.any()
        assert np.array_equal(got, want)


def test_inner_search_uses_batched_scan():
    from repro.core.optimizer import inner_search
    mcm = mcm_from_compute(2e6, dies_per_mcm=16, m=6)
    best, pts = inner_search(W, mcm, budget=16)
    assert best is not None and len(pts) <= 16
    # the refined best must be the throughput argmax of its pool
    assert best.throughput == max(p.throughput for p in pts)
    # and must sit at the top of the batched ranking of the full grid
    ev = BatchedEvaluator(W, mcm)
    full = search_exhaustive(ev)
    assert best.throughput >= 0.95 * full.metrics["throughput"].max()
