"""Tests for the batched DSE engine (repro.dse).

The contract under test: ``batched_simulate`` must reproduce the scalar
oracle ``core.simulator.simulate`` element-wise — same feasibility mask,
step times within 1e-9 relative — over >=1000 sampled design points,
plus Pareto / allocation / driver invariants.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.mcm import mcm_from_compute
from repro.core.network import allocate_links
from repro.core.simulator import simulate
from repro.core.traffic import PARALLELISMS
from repro.core.workload import Workload, paper_workload
from repro.dse.batched_sim import (MCMBatch, allocate_links_batch,
                                   batched_simulate)
from repro.dse.pareto import (crowding_distance, nondominated_sort,
                              pareto_mask)
from repro.dse.search import (BatchedEvaluator, search_exhaustive,
                              search_nsga2, search_prf_ucb, search_random,
                              sweep_design_space)
from repro.dse.space import (DesignSpace, P_IDX, StrategyBatch,
                             enumerate_strategy_batch)

W = paper_workload(global_batch=512)
TINY = Workload(model=get_config("tinyllama_1_1b"), seq_len=4096,
                global_batch=256)


def _assert_parity(w, batch, mcm, fabric, reuse, hw=None):
    res = batched_simulate(w, batch, mcm, fabric=fabric, reuse=reuse, hw=hw)
    n_checked = 0
    for i, s in enumerate(batch.to_strategies()):
        r = simulate(w, s, mcm, fabric=fabric, topo=None, reuse=reuse,
                     hw=hw)
        assert r.feasible == bool(res.feasible[i]), (s, r.reason)
        if r.feasible:
            assert res.step_time[i] == pytest.approx(r.step_time, rel=1e-9)
            assert res.throughput[i] == pytest.approx(r.throughput,
                                                      rel=1e-9)
        n_checked += 1
    return n_checked


# ---------------------------------------------------------------------------
# Enumeration
# ---------------------------------------------------------------------------
def test_enumeration_matches_scalar():
    from repro.core.optimizer import enumerate_strategies
    for w, c in ((W, 4e6), (TINY, 1e6)):
        mcm = mcm_from_compute(c, dies_per_mcm=16, m=6)
        scal = {(s.tp, s.dp, s.pp, s.cp, s.ep, s.n_micro)
                for s in enumerate_strategies(w, mcm)}
        batch = enumerate_strategy_batch(w, mcm)
        soa = set(batch.keys())
        assert soa == scal and len(batch) == len(scal)


# ---------------------------------------------------------------------------
# Element-wise parity vs the scalar oracle (>= 1000 points total)
# ---------------------------------------------------------------------------
def test_parity_paper_workload_all_fabrics():
    mcm = mcm_from_compute(4e6, dies_per_mcm=16, m=6)
    batch = enumerate_strategy_batch(W, mcm)
    n = 0
    for fabric in ("oi", "ib", "nvlink"):
        n += _assert_parity(W, batch, mcm, fabric, reuse=True)
    n += _assert_parity(W, batch, mcm, "oi", reuse=False)
    assert n >= 1000          # the acceptance floor, on this test alone


def test_parity_includes_infeasible_and_invalid_points():
    rng = np.random.default_rng(3)
    mcm = mcm_from_compute(1e6, dies_per_mcm=16, m=2)   # tight HBM
    vals = np.array([1, 2, 4, 8, 16, 32, 64])
    batch = StrategyBatch(*(rng.choice(vals, 80) for _ in range(5)),
                          rng.choice([1, 2, 8, 32], 80))
    res = batched_simulate(W, batch, mcm)
    assert not res.feasible.all()            # invalid products / HBM
    _assert_parity(W, batch, mcm, "oi", reuse=True)


def test_parity_reuse_paper_mode_and_gemm_eff():
    mcm = mcm_from_compute(16e6, dies_per_mcm=16, m=8)
    hw_p = dataclasses.replace(mcm.hw, ocs_reuse_mode="paper")
    batch = enumerate_strategy_batch(W, mcm)
    sub = batch.take(np.arange(len(batch))[:: max(len(batch) // 80, 1)])
    _assert_parity(W, sub, mcm, "oi", reuse=True, hw=hw_p)
    hw_g = dataclasses.replace(mcm.hw, model_gemm_eff=True)
    _assert_parity(W, sub, mcm, "oi", reuse=True, hw=hw_g)


def test_parity_moe_free_and_fused_mcm_batch():
    space = DesignSpace.from_compute(TINY, 1e6, fabrics=("oi",),
                                     m=(2, 6), cpo_ratio=(0.3, 0.9))
    cells = list(space.batches())
    batch = StrategyBatch.concat([g for _, _, g in cells])
    local = np.concatenate([np.full(len(g), i, np.int64)
                            for i, (_, _, g) in enumerate(cells)])
    mcms = [m for m, _, _ in cells]
    res = batched_simulate(TINY, batch, MCMBatch.from_mcms(mcms, local),
                           fabric="oi", reuse=True, hw=mcms[0].hw)
    for i, s in enumerate(batch.to_strategies()):
        r = simulate(TINY, s, mcms[local[i]], fabric="oi", topo=None)
        assert r.feasible == bool(res.feasible[i])
        if r.feasible:
            assert res.step_time[i] == pytest.approx(r.step_time, rel=1e-9)


def test_jax_backend_matches_numpy():
    mcm = mcm_from_compute(2e6, dies_per_mcm=16, m=6)
    batch = enumerate_strategy_batch(W, mcm)
    rn = batched_simulate(W, batch, mcm, backend="numpy")
    rj = batched_simulate(W, batch, mcm, backend="jax")
    assert np.array_equal(rn.feasible, rj.feasible)
    ok = rn.feasible
    np.testing.assert_allclose(rj.step_time[ok], rn.step_time[ok],
                               rtol=1e-9)


# ---------------------------------------------------------------------------
# Link allocation
# ---------------------------------------------------------------------------
def test_allocate_links_batch_matches_scalar():
    rng = np.random.default_rng(7)
    B = 300
    vols = rng.uniform(1e6, 1e12, size=(B, 5))
    mask = rng.random((B, 5)) < 0.7
    vols = np.where(mask, vols, 0.0)
    pair_choices = [(-1, -1), (P_IDX["CP"], P_IDX["EP"]),
                    (P_IDX["CP"], P_IDX["DP"]), (P_IDX["EP"], P_IDX["DP"])]
    picks = rng.integers(len(pair_choices), size=B)
    pa = np.array([pair_choices[p][0] for p in picks])
    pb = np.array([pair_choices[p][1] for p in picks])
    # a pair only counts when both members carry inter traffic
    valid = (pa >= 0) & mask[np.arange(B), np.maximum(pa, 0)] \
        & mask[np.arange(B), np.maximum(pb, 0)]
    pa, pb = np.where(valid, pa, -1), np.where(valid, pb, -1)
    for L in (3, 17, 96):
        got = allocate_links_batch(vols, mask, L, pa, pb)
        for i in range(B):
            d = {p: vols[i, P_IDX[p]] for p in PARALLELISMS
                 if mask[i, P_IDX[p]]}
            rp = None
            if pa[i] >= 0:
                rp = (PARALLELISMS[pa[i]], PARALLELISMS[pb[i]])
            want = allocate_links(d, L, rp)
            for p, v in want.items():
                assert got[i, P_IDX[p]] == v, (i, L, d, rp, want)


def test_allocate_links_reuse_respects_budget():
    # the fixed trim: l_reuse + others (pair counted once) <= L
    vols = {"CP": 5e9, "EP": 9e9, "DP": 4e9, "PP": 1e3}
    for L in (3, 4, 5, 8, 64):
        alloc = allocate_links(vols, L, ("CP", "EP"))
        used = alloc["CP"] + alloc["DP"] + alloc["PP"]
        assert used <= L or max(alloc.values()) <= 1
        assert alloc["CP"] == alloc["EP"]


# ---------------------------------------------------------------------------
# Pareto invariants
# ---------------------------------------------------------------------------
def test_pareto_mask_no_dominated_survivor():
    rng = np.random.default_rng(0)
    obj = rng.normal(size=(400, 3))
    obj[50:60] = obj[40:50]                  # duplicates must survive
    maximize = [True, False, True]
    keep = pareto_mask(obj, maximize)
    sign = np.where(maximize, 1.0, -1.0)
    M = obj * sign
    for i in np.nonzero(keep)[0]:
        dom = (M >= M[i]).all(1) & (M > M[i]).any(1)
        assert not dom.any()
    # and every removed point IS dominated by someone
    for i in np.nonzero(~keep)[0]:
        dom = (M >= M[i]).all(1) & (M > M[i]).any(1)
        assert dom.any()


def test_nondominated_sort_fronts_are_clean():
    rng = np.random.default_rng(1)
    obj = rng.normal(size=(200, 2))
    maximize = [True, True]
    ranks = nondominated_sort(obj, maximize)
    assert (ranks[pareto_mask(obj, maximize)] == 0).all()
    for r in range(int(ranks.max()) + 1):
        sel = ranks >= r
        front = pareto_mask(obj[sel], maximize)
        assert (ranks[np.nonzero(sel)[0][front]] == r).all()
    d = crowding_distance(obj[ranks == 0], maximize)
    assert np.isinf(d).sum() >= 2            # boundary points


def test_sweep_pareto_and_best():
    space = DesignSpace.from_compute(TINY, 1e6, fabrics=("oi", "ib"),
                                     m=(2, 6, 8), cpo_ratio=(0.6,))
    sweep = sweep_design_space(space)
    assert len(sweep) > 500
    pi = sweep.pareto_indices()
    assert len(pi) > 0
    best = sweep.best
    t, c, p = (sweep.metrics["throughput"], sweep.metrics["cost"],
               sweep.metrics["power"])
    feas = np.nonzero(sweep.metrics["feasible"])[0]
    for i in pi:
        dom = (t[feas] >= t[i]) & (c[feas] <= c[i]) & (p[feas] <= p[i]) \
            & ((t[feas] > t[i]) | (c[feas] < c[i]) | (p[feas] < p[i]))
        assert not dom.any()
    assert best in pi                        # max-throughput is on the front


# ---------------------------------------------------------------------------
# Drivers + cache
# ---------------------------------------------------------------------------
def test_drivers_and_cache():
    mcm = mcm_from_compute(2e6, dies_per_mcm=16, m=6)
    full = search_exhaustive(BatchedEvaluator(W, mcm))
    t_best = full.metrics["throughput"].max()
    assert full.metrics["feasible"].any()

    r = search_random(BatchedEvaluator(W, mcm), budget=60, seed=0)
    assert r.n_sim <= 60
    p = search_prf_ucb(BatchedEvaluator(W, mcm), budget=60, seed=0)
    assert p.n_sim <= 60
    assert p.metrics["throughput"].max() <= t_best + 1e-9
    g = search_nsga2(BatchedEvaluator(W, mcm), pop_size=16, generations=4,
                     seed=0)
    assert g.metrics["throughput"].max() <= t_best + 1e-9
    assert (g.batch.n_devices == mcm.n_devices).all()   # repair keeps grid

    ev = BatchedEvaluator(W, mcm)
    search_exhaustive(ev)
    n = ev.n_sim
    again = search_exhaustive(ev)
    assert ev.n_sim == n and ev.n_hits >= len(again.batch)


def test_inner_search_uses_batched_scan():
    from repro.core.optimizer import inner_search
    mcm = mcm_from_compute(2e6, dies_per_mcm=16, m=6)
    best, pts = inner_search(W, mcm, budget=16)
    assert best is not None and len(pts) <= 16
    # the refined best must be the throughput argmax of its pool
    assert best.throughput == max(p.throughput for p in pts)
    # and must sit at the top of the batched ranking of the full grid
    ev = BatchedEvaluator(W, mcm)
    full = search_exhaustive(ev)
    assert best.throughput >= 0.95 * full.metrics["throughput"].max()
