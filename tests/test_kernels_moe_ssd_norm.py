"""Sweeps for moe_gmm, ssd_scan, rmsnorm kernels vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssd_scan import ssd_scan


@pytest.mark.parametrize("sizes,bt", [
    ([16, 8, 0, 24], 8),
    ([32, 0, 0, 0], 8),
    ([8, 8, 8, 8], 8),
    ([64, 16, 16, 32], 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm(sizes, bt, dtype):
    e, k, n = len(sizes), 64, 96
    t = int(sum(sizes))
    x = jax.random.normal(jax.random.PRNGKey(0), (t, k), dtype)
    w = (jax.random.normal(jax.random.PRNGKey(1), (e, k, n)) * 0.1).astype(
        dtype)
    gids = np.repeat(np.arange(e), np.asarray(sizes) // bt).astype(np.int32)
    out = moe_gmm(x, w, jnp.asarray(gids), block_t=bt, block_n=32,
                  block_k=32, interpret=True)
    r = ref.moe_gmm_ref(x, w, np.asarray(sizes))
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("bb,s,h,p,g,n,chunk", [
    (1, 32, 2, 8, 1, 16, 8),
    (2, 64, 4, 16, 2, 16, 16),
    (1, 128, 4, 8, 4, 32, 32),   # n_groups == n_heads
    (2, 64, 8, 16, 1, 8, 64),    # single big chunk
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan(bb, s, h, p, g, n, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = jax.random.normal(ks[0], (bb, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bb, s, h))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = (jax.random.normal(ks[3], (bb, s, g, n)) * 0.3).astype(dtype)
    C = (jax.random.normal(ks[4], (bb, s, g, n)) * 0.3).astype(dtype)
    y = ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    yr, _ = ref.ssd_ref(x.astype(jnp.float32), dt.astype(jnp.float32), A,
                        B.astype(jnp.float32), C.astype(jnp.float32))
    tol = 3e-4 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), rtol=tol,
                               atol=tol)


def test_ssd_chunk_invariance():
    """Chunk size must not change the result (property of the algorithm)."""
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    bb, s, h, p, g, n = 1, 64, 2, 8, 1, 8
    x = jax.random.normal(ks[0], (bb, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bb, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (bb, s, g, n)) * 0.3
    C = jax.random.normal(ks[4], (bb, s, g, n)) * 0.3
    outs = [ssd_scan(x, dt, A, B, C, chunk=c, interpret=True)
            for c in (8, 16, 32, 64)]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("shape", [(4, 64), (3, 7, 128), (1, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("offset", [0.0, 1.0])
def test_rmsnorm(shape, dtype, offset):
    x = jax.random.normal(jax.random.PRNGKey(0), shape, dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), shape[-1:], dtype) * 0.1
    o = rmsnorm(x, w, weight_offset=offset, block_rows=8, interpret=True)
    r = ref.rmsnorm_ref(x, w, weight_offset=offset)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), rtol=tol, atol=tol)
