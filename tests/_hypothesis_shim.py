"""Minimal stand-in for the ``hypothesis`` subset this suite uses.

Loaded by ``conftest.py`` ONLY when the real package is missing (the
bare CI image).  It draws deterministic pseudo-random examples — no
shrinking, no database, no health checks — which is enough for the
property tests here (they assert invariants over sampled inputs).
Install real ``hypothesis`` (see requirements.txt) to get the full
engine; this file then goes unused.
"""
from __future__ import annotations

import functools
import inspect
import random

__version__ = "0.0-shim"

_SEED = 0x51DE  # fixed: the suite must be reproducible run-to-run
_DEFAULT_EXAMPLES = 30


class _Strategy:
    """A strategy is just a draw function over a ``random.Random``."""

    def __init__(self, draw):
        self._draw = draw

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred, max_tries=200):
        def draw(rng):
            for _ in range(max_tries):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError("shim filter(): predicate too strict")
        return _Strategy(draw)


def _edge_biased_int(rng, lo, hi):
    # bias toward the bounds like hypothesis does: edges find more bugs
    r = rng.random()
    if r < 0.1:
        return lo
    if r < 0.2:
        return hi
    return rng.randint(lo, hi)


class strategies:
    @staticmethod
    def integers(min_value=0, max_value=2 ** 31 - 1):
        return _Strategy(lambda rng: _edge_biased_int(
            rng, min_value, max_value))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: elements[rng.randrange(len(elements))])

    @staticmethod
    def lists(elems, min_size=0, max_size=8):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elems._draw(rng) for _ in range(n)]
        return _Strategy(draw)

    @staticmethod
    def tuples(*strats):
        return _Strategy(lambda rng: tuple(s._draw(rng) for s in strats))

    @staticmethod
    def just(value):
        return _Strategy(lambda rng: value)

    @staticmethod
    def dictionaries(keys, values, min_size=0, max_size=8):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            out = {}
            tries = 0
            while len(out) < n and tries < 64 * (n + 1):
                out[keys._draw(rng)] = values._draw(rng)
                tries += 1
            return out
        return _Strategy(draw)


def settings(**kw):
    max_examples = kw.get("max_examples")

    def deco(f):
        if max_examples is not None:
            f._shim_max_examples = max_examples
        return f
    return deco


def given(*strats, **kwstrats):
    def deco(f):
        @functools.wraps(f)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples",
                        getattr(f, "_shim_max_examples", _DEFAULT_EXAMPLES))
            rng = random.Random(_SEED)
            for _ in range(n):
                vals = [s._draw(rng) for s in strats]
                kvals = {k: s._draw(rng) for k, s in kwstrats.items()}
                f(*args, *vals, **{**kwargs, **kvals})
        # carry a pre-applied @settings mark through @given
        if hasattr(f, "_shim_max_examples"):
            wrapper._shim_max_examples = f._shim_max_examples
        # hide strategy-bound params from pytest's fixture resolution
        params = list(inspect.signature(f).parameters.values())
        bound = len(strats) + len(kwstrats)
        keep = params[:-bound] if bound else params
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature(keep)
        return wrapper
    return deco
