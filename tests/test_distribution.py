"""Distribution-layer tests: sharding rules, ChipLight->mesh plan, and a
small-mesh end-to-end compile (8 fake devices, fast)."""
import os
import sys

import pytest

# 8 host devices for this module ONLY (subprocess isolation via pytest-run
# is unavailable; skip if jax was already initialised with 1 device by a
# previous module in the same process — covered standalone in CI loop).
if "XLA_FLAGS" not in os.environ and "jax" not in sys.modules:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.configs.base import ShapeConfig  # noqa: E402
from repro.core import chiplight_optimize  # noqa: E402
from repro.core.workload import Workload  # noqa: E402
from repro.launch.steps import TrainState, init_train_state, \
    make_train_step  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.models.common import ExecConfig  # noqa: E402
from repro.optim import AdamWState  # noqa: E402
from repro.parallel import plan_from_design  # noqa: E402
from repro.parallel.sharding import param_specs, _sanitize  # noqa: E402

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs 8 fake devices (run standalone)")


def _mesh():
    return jax.make_mesh((4, 2), ("data", "model"))


def test_param_specs_cover_tree_and_divide():
    cfg = get_config("mixtral_8x7b").reduced()
    ex = ExecConfig()
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0), ex))
    mesh = _mesh()
    specs = param_specs(cfg, shapes, mesh)
    n = 0
    for leaf, spec in zip(jax.tree.leaves(shapes),
                          jax.tree.leaves(
                              specs, is_leaf=lambda x: isinstance(x, P))):
        n += 1
        for d, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            assert leaf.shape[d] % size == 0, (spec, leaf.shape)
    assert n > 5


def test_sanitize_nulls_nondivisible():
    mesh = _mesh()
    spec = _sanitize(P("model", "data"), (51865, 64), mesh)
    assert spec == P(None, "data")


def test_sharded_train_step_runs_tiny():
    """Real (not AOT) sharded train step on 8 fake devices."""
    cfg = get_config("tinyllama_1_1b").reduced()
    ex = ExecConfig(attn_block=16, batch_axes=("data",))
    mesh = _mesh()
    model = build_model(cfg)
    step = make_train_step(cfg, ex)
    state = init_train_state(cfg, ex)
    shapes = jax.eval_shape(lambda: state.params)
    p_specs = param_specs(cfg, shapes, mesh)
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                        is_leaf=lambda x: isinstance(x, P))
    state_sh = TrainState(params=p_sh,
                          opt=AdamWState(step=NamedSharding(mesh, P()),
                                         m=p_sh, v=p_sh))
    shape = ShapeConfig("t", "train", 32, 4)
    batch = model.make_batch(jax.random.PRNGKey(0), shape, ex, "train")
    with mesh:
        state = jax.device_put(state, state_sh)
        jitted = jax.jit(step, in_shardings=(state_sh, None))
        new_state, metrics = jitted(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))


def test_chiplight_plan_to_mesh_roundtrip():
    """The paper's technique as a first-class feature: DSE output ->
    ParallelPlan -> a mesh whose axes carry the strategy."""
    cfg = get_config("tinyllama_1_1b")
    w = Workload(model=cfg, seq_len=4096, global_batch=256)
    res = chiplight_optimize(w, total_tflops=3e4, dies_per_mcm=4, m0=6,
                             outer_iters=2, inner_budget=12)
    assert res.best is not None
    plan = plan_from_design(res.best)
    shape, axes = plan.mesh_shape()
    assert shape[0] * shape[1] == res.best.strategy.n_devices \
        // res.best.strategy.pp
    assert axes == ("data", "model")
    # strategy degrees survive the round trip
    assert plan.strategy.tp == res.best.strategy.tp
