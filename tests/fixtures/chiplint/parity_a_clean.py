"""Parity fixture, side A (clean): mirrors parity_b exactly."""


def cost(w, hw):
    act = w.tokens * w.d_model
    return act / hw.bw_gbps + 12.0 * hw.hop_latency_s
