"""Mini obs.metrics stand-in for determinism fixtures: only the
declared-name registries chiplint reads via AST."""

KNOWN_COUNTERS = frozenset({
    "fixture.count",
})
KNOWN_GAUGES = frozenset({
    "fixture.level",
})


def inc(name, n=1):
    pass


def gauge(name, value):
    pass
