"""units fixture (clean): same-unit arithmetic and unit-transparent
wrappers only."""


def combine(a_bytes, b_bytes, lat_s, jitter_s):
    total_bytes = a_bytes + b_bytes
    t_s = lat_s + jitter_s
    worst_s = max(lat_s, jitter_s)
    return total_bytes, t_s, worst_s
