"""determinism fixture (clean): seeded generators, declared metric
names, no frozen mutation."""
import dataclasses
import random

import numpy as np

from repro.obs import metrics as obs_metrics


@dataclasses.dataclass(frozen=True)
class Cfg:
    depth: int = 4


def draw(seed):
    rng = np.random.default_rng(seed)
    r = random.Random(seed)
    obs_metrics.inc("fixture.count")
    obs_metrics.gauge("fixture.level", 3.0)
    cfg = Cfg(depth=8)
    widened = dataclasses.replace(cfg, depth=cfg.depth * 2)
    return rng.random() + r.random(), widened
