"""jax-hygiene fixture (firing): one finding per sub-check.

Line numbers matter — tests assert findings land on the marked lines.
"""
import numpy as np


def terms(xp, x, hw):
    y = x * 2.0
    if y > 0:                    # branch-on-tracer (line 10)
        y = float(x)             # tracer-escape (line 11)
    z = np.exp(y)                # np-in-jit (line 12)
    return helper(z)


def helper(a, opts={}):          # unhashable-default (line 16)
    return a
