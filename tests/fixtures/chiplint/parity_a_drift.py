"""Parity fixture, side A (drifted): reads one extra hw attribute and
changed the 12.0 constant to 13.0 — both must be findings."""


def cost(w, hw):
    act = w.tokens * w.d_model
    base = act / hw.bw_gbps + 13.0 * hw.hop_latency_s
    return base * hw.derate
