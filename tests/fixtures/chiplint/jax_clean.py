"""jax-hygiene fixture (clean): branches only on static params, stays
inside the xp namespace, hashable defaults."""


def terms(xp, x, hw):
    y = x * 2.0
    if hw == "nvlink":          # static param: fine
        y = y + 1.0
    return xp.maximum(y, 0.0)
