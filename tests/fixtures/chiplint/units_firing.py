"""units fixture (firing): one finding per sub-check.

Line numbers matter — tests assert findings land on the marked lines.
"""


def mix(total_bytes, lat_s, cap_gb):
    bad = total_bytes + lat_s        # `+` bytes vs s (line 8)
    if total_bytes > lat_s:          # comparison bytes vs s (line 9)
        bad = bad * 2.0
    size_gb = total_bytes            # GB name, bytes value (line 11)
    return bad, size_gb, cap_gb
