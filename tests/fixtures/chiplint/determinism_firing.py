"""determinism fixture (firing): one finding per sub-check.

Line numbers matter — tests assert findings land on the marked lines.
"""
import dataclasses
import random

import numpy as np

from repro.obs import metrics as obs_metrics


@dataclasses.dataclass(frozen=True)
class Cfg:
    depth: int = 4


def draw():
    x = random.random()              # global-rng stdlib (line 19)
    y = np.random.rand(3)            # global-rng numpy legacy (line 20)
    obs_metrics.inc("not.declared")  # unknown-metric (line 21)
    cfg = Cfg(depth=8)
    cfg.depth = 16                   # frozen-mutation (line 23)
    return x, y, cfg
