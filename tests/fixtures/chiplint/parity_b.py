"""Parity fixture, side B: the batched mirror of parity_a's cost()."""


def cost_batch(w, hw):
    act = w.tokens * w.d_model
    return act / hw.bw_gbps + 12.0 * hw.hop_latency_s
