"""Property-based parity for the two kernels the profiling harness
leans on hardest: ``decode_attention`` (the memory-bound case) and
``moe_gmm`` (the GEMM-curve case), both vs the ``kernels/ref.py``
oracles over randomized shapes — including ragged and zero-sized
expert groups."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref
from repro.kernels.moe_gmm import moe_gmm as moe_gmm_pallas


@given(
    b=st.integers(1, 2),
    hkv=st.sampled_from([1, 2, 4]),
    group=st.sampled_from([1, 2]),
    smax=st.sampled_from([16, 32, 64]),
    pos_frac=st.floats(0.0, 1.0),
    window=st.sampled_from([None, 4, 8]),
    softcap=st.sampled_from([0.0, 20.0]),
)
@settings(max_examples=25, deadline=None)
def test_decode_attention_matches_ref(b, hkv, group, smax, pos_frac,
                                      window, softcap):
    """decode on a (pos+1)-long cache == dense ref with a length-1
    query occupying the LAST position of the key range (the exact
    causal convention ``attention_ref`` documents), for every window /
    softcap / GQA-group combination."""
    d, hq = 16, hkv * group
    pos = int(pos_frac * (smax - 1))
    ks = jax.random.split(jax.random.PRNGKey(pos * 7 + smax), 3)
    q = jax.random.normal(ks[0], (b, hq, 1, d), jnp.float32)
    k_cache = jax.random.normal(ks[1], (b, hkv, smax, d), jnp.float32)
    v_cache = jax.random.normal(ks[2], (b, hkv, smax, d), jnp.float32)

    out = ops.decode_attention(q, k_cache, v_cache, jnp.int32(pos),
                               window=window, softcap=softcap)
    # the live cache is cache[:pos+1]; entries past pos are masked, so
    # the oracle only ever sees the slice
    r = ref.attention_ref(q, k_cache[:, :, :pos + 1],
                          v_cache[:, :, :pos + 1], causal=True,
                          window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r),
                               rtol=2e-5, atol=2e-5)


@given(
    counts=st.lists(st.integers(0, 6), min_size=2, max_size=5),
    bt=st.sampled_from([8, 16]),
    n=st.sampled_from([32, 96]),
)
@settings(max_examples=15, deadline=None)
def test_moe_gmm_ragged_groups_match_ref(counts, bt, n):
    """Pallas (interpret) and the xla dispatch path agree with the
    python-loop oracle on ragged group splits, including zero-sized
    experts at any position."""
    if sum(counts) == 0:
        counts[0] = 1                  # at least one token
    sizes = [c * bt for c in counts]
    e, k, t = len(sizes), 32, sum(sizes)
    x = jax.random.normal(jax.random.PRNGKey(t), (t, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (e, k, n)) * 0.1
    r = ref.moe_gmm_ref(x, w, np.asarray(sizes))

    gids = np.repeat(np.arange(e), np.asarray(sizes) // bt).astype(np.int32)
    out_pl = moe_gmm_pallas(x, w, jnp.asarray(gids), block_t=bt,
                            block_n=32, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out_pl), np.asarray(r),
                               rtol=1e-5, atol=1e-5)

    # the ops-layer xla path (what the profiler measures on CPU)
    out_xla = ops.moe_gmm(x, w, sizes, backend="xla", block_t=bt)
    np.testing.assert_allclose(np.asarray(out_xla), np.asarray(r),
                               rtol=1e-5, atol=1e-5)
