"""Tests for repro.obs — tracing spans, metrics registries, Chrome-trace
export — plus the instrumentation satellites: batch-replay fallback
observability, public cache/retrace stats, and the simulated-step
timeline reproducing the schedule-bubble fidelity finding."""
import json
import tracemalloc
import warnings

import pytest

from repro.configs import get_config
from repro.core.mcm import mcm_from_compute
from repro.core.optimizer import enumerate_strategies
from repro.core.simulator import simulate
from repro.core.workload import Workload
from repro.events import compile_step, replay, replay_batch
from repro.obs import (METRICS_SCHEMA, Tracer, chrome_trace_from_event_result,
                       chrome_trace_from_tracer, current_tracer, metrics, span,
                       tracing, track_idle, validate_chrome_trace)
from repro.obs.export import PID_DEVICES
from repro.obs.trace import _NULL_SPAN

TINY = Workload(model=get_config("tinyllama_1_1b"), seq_len=4096,
                global_batch=256)
MCM_TINY = mcm_from_compute(1e6, 16, 6)


def _tiny_scenario(**kw):
    from repro.api import Scenario
    return Scenario(model="tinyllama_1_1b", total_tflops=1e6, seq_len=4096,
                    global_batch=256, dies_per_mcm=(16,), m=(6,),
                    cpo_ratio=(0.6,), fabrics=("oi",), refine_top=3,
                    keep_top=16, **kw)


def _pipelined(min_nm=8):
    """Best feasible pipelined strategy on the tiny MCM."""
    best = None
    for s in enumerate_strategies(TINY, MCM_TINY):
        if s.pp <= 1 or s.n_micro < max(min_nm, s.pp):
            continue
        r = simulate(TINY, s, MCM_TINY)
        if r.feasible and (best is None or r.throughput > best[1]):
            best = (s, r.throughput)
    if best is None:
        pytest.skip("no pipelined strategy on the tiny MCM")
    return best[0]


# ---------------------------------------------------------------------------
# Tracer core: nesting, LIFO, monotonicity, disabled fast path
# ---------------------------------------------------------------------------
def test_span_nesting_depths_and_order():
    with tracing() as tr:
        with span("outer", k=1):
            with span("inner"):
                pass
            with span("inner2"):
                pass
    assert current_tracer() is None
    names = [e["name"] for e in tr.events]
    assert names == ["inner", "inner2", "outer"]   # completion order
    by = {e["name"]: e for e in tr.events}
    assert by["outer"]["depth"] == 0
    assert by["inner"]["depth"] == by["inner2"]["depth"] == 1
    assert by["outer"]["args"] == {"k": 1}
    assert by["inner"]["args"] is None
    # children nest inside the parent's [ts, ts+dur] window
    for child in ("inner", "inner2"):
        assert by[child]["ts_ns"] >= by["outer"]["ts_ns"]
        assert (by[child]["ts_ns"] + by[child]["dur_ns"]
                <= by["outer"]["ts_ns"] + by["outer"]["dur_ns"])
    assert all(e["dur_ns"] >= 0 for e in tr.events)


def test_span_lifo_violation_raises():
    tr = Tracer()
    with tracing(tr):
        a = span("a")
        b = span("b")
        a.__enter__()
        b.__enter__()
        with pytest.raises(RuntimeError, match="LIFO"):
            a.__exit__(None, None, None)
        # clean up so tracing() doesn't also raise
        b.__exit__(None, None, None)
        a.__exit__(None, None, None)


def test_tracing_rejects_unclosed_spans():
    with pytest.raises(RuntimeError, match="never closed"):
        with tracing():
            span("leaked").__enter__()


def test_disabled_span_is_shared_singleton():
    assert current_tracer() is None
    s = span("hot", rows=123)
    assert s is _NULL_SPAN
    assert span("other") is s


def test_disabled_span_allocates_nothing():
    # the disabled path must stay allocation-free: safe in hot loops
    for _ in range(64):                                    # warm caches
        with span("warm", i=0):
            pass
    tracemalloc.start()
    before = tracemalloc.take_snapshot()
    for _ in range(1000):
        with span("hot"):
            pass
    after = tracemalloc.take_snapshot()
    tracemalloc.stop()
    grown = sum(st.size_diff for st in
                after.compare_to(before, "lineno") if st.size_diff > 0)
    # tracemalloc's own bookkeeping costs a little; 1000 span dicts
    # would cost >60kB
    assert grown < 10_000


def test_span_exception_still_recorded():
    with tracing() as tr:
        with pytest.raises(ValueError):
            with span("boom"):
                raise ValueError("x")
    assert [e["name"] for e in tr.events] == ["boom"]


# ---------------------------------------------------------------------------
# Metrics registries: scoping, folding, tracer sampling
# ---------------------------------------------------------------------------
def test_metrics_scope_folds_into_parent():
    root_before = metrics.root().counters.get("t.x", 0)
    with metrics.scope() as outer:
        metrics.inc("t.x", 2)
        with metrics.scope() as inner:
            metrics.inc("t.x", 3)
            metrics.gauge("t.g", 7)
        assert inner.counters["t.x"] == 3
        assert outer.counters["t.x"] == 5          # folded on exit
        assert outer.gauges["t.g"] == 7
    assert metrics.root().counters["t.x"] == root_before + 5


def test_metrics_snapshot_schema():
    m = metrics.Metrics()
    m.inc("a.b", 4)
    m.gauge("a.g", 1.5)
    snap = m.snapshot()
    assert snap == {"schema": METRICS_SCHEMA, "counters": {"a.b": 4},
                    "gauges": {"a.g": 1.5}}
    assert json.loads(json.dumps(snap)) == snap


def test_inc_samples_on_tracer():
    with tracing() as tr, metrics.scope():
        metrics.inc("t.sampled")
        metrics.inc("t.sampled", 2)
    assert [(n, v) for n, _, v in tr.counter_samples] == \
        [("t.sampled", 1.0), ("t.sampled", 3.0)]


# ---------------------------------------------------------------------------
# Chrome-trace export: structural validity of both trace flavours
# ---------------------------------------------------------------------------
def test_host_trace_chrome_valid():
    with tracing() as tr, metrics.scope():
        with span("study.run", scenario="t"):
            with span("study.scan"):
                metrics.inc("dse.cache.hits", 5)
    trace = chrome_trace_from_tracer(tr)
    counts = validate_chrome_trace(trace)
    assert counts["X"] == 2
    assert counts["C"] == 1
    assert counts["M"] >= 1
    names = {e["name"] for e in trace["traceEvents"] if e["ph"] == "X"}
    assert names == {"study.run", "study.scan"}


def test_simulated_step_trace_chrome_valid():
    s = _pipelined()
    prog = compile_step(TINY, s, MCM_TINY, schedule="1f1b")
    ev = replay(prog, record_timeline=True)
    trace = chrome_trace_from_event_result(ev, "tiny 1f1b")
    counts = validate_chrome_trace(trace)
    assert counts["X"] > 0 and counts["M"] > 0
    # one device track per pipeline stage
    tids = {e["tid"] for e in trace["traceEvents"]
            if e["ph"] == "X" and e["pid"] == PID_DEVICES}
    assert len(tids) == prog.n_stages
    assert trace["otherData"]["schedule"] == "1f1b"


def test_replay_without_timeline_has_no_device_events():
    s = _pipelined()
    ev = replay(compile_step(TINY, s, MCM_TINY, schedule="1f1b"))
    assert ev.device_timeline == []
    with pytest.raises(ValueError, match="record_timeline"):
        chrome_trace_from_event_result(ev, "x")


# ---------------------------------------------------------------------------
# Acceptance: the timeline reproduces the schedule-bubble finding —
# interleaving shrinks idle, measured from the trace's own durations
# ---------------------------------------------------------------------------
def test_timeline_interleaved_idle_below_gpipe():
    s = _pipelined()

    def idle(schedule):
        prog = compile_step(TINY, s, MCM_TINY, schedule=schedule)
        ev = replay(prog, record_timeline=True)
        trace = chrome_trace_from_event_result(ev, schedule)
        per_track = track_idle(trace)
        assert set(per_track) == set(range(prog.n_stages))
        return sum(t["idle_us"] for t in per_track.values()), ev

    idle_g, ev_g = idle("gpipe")
    idle_i, ev_i = idle("interleaved")
    assert idle_g > 0
    assert idle_i < 0.75 * idle_g
    # the trace-derived idle agrees with the engine's own bubble ratio
    assert ev_i.bubble < 0.75 * ev_g.bubble


# ---------------------------------------------------------------------------
# Study.run() provenance.metrics block + JSON round-trip
# ---------------------------------------------------------------------------
def test_study_metrics_block_and_roundtrip(tmp_path):
    from repro.api import Study, StudyResult
    res = Study(_tiny_scenario()).run()
    m = res.provenance["metrics"]
    assert m["schema"] == METRICS_SCHEMA
    assert m["wall_s"]["total"] > 0
    assert m["points_evaluated"] > 0
    assert m["points_per_s"] > 0
    assert 0.0 <= m["cache"]["hit_rate"] <= 1.0
    assert m["jax"]["retraces"] >= 0
    # the exhaustive driver takes the fused no-cache sweep, so its
    # counter set is empty — but the block must still be present
    assert isinstance(m["counters"], dict)

    path = tmp_path / "res.json"
    res.save(path)
    back = StudyResult.load(path)
    assert back.provenance["metrics"] == m


def test_study_traced_emits_stage_spans():
    from repro.api import Study
    with tracing() as tr:
        Study(_tiny_scenario()).run()
    names = {e["name"] for e in tr.events}
    assert {"study.run", "study.scan", "study.refine",
            "sweep", "refine"} <= names


def test_driver_sweep_populates_cache_counters():
    from repro.api import Study
    res = Study(_tiny_scenario(driver="prf",
                               driver_kw={"budget": 256})).run()
    c = res.provenance["metrics"]["counters"]
    assert c["dse.cache.sim"] > 0
    assert res.provenance["metrics"]["cache"]["requests"] >= \
        res.provenance["metrics"]["cache"]["hits"]


# ---------------------------------------------------------------------------
# Satellite: interleaved batch replay is vectorized — no fallback left
# ---------------------------------------------------------------------------
def test_batch_replay_interleaved_no_fallback_counter():
    s = _pipelined()
    progs = [compile_step(TINY, s, MCM_TINY, schedule="interleaved"),
             compile_step(TINY, s, MCM_TINY, schedule="1f1b")]
    with metrics.scope() as m:
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            out = replay_batch(progs)
    assert out["scalar_fallback"].tolist() == [False, False]
    assert m.counters["batch_replay.records"] == 2
    assert "batch_replay.scalar_fallback" not in m.counters
    assert not [w for w in caught
                if issubclass(w.category, RuntimeWarning)
                and "scalar event engine" in str(w.message)]


def test_batch_replay_vectorized_has_no_fallback():
    s = _pipelined()
    progs = [compile_step(TINY, s, MCM_TINY, schedule="1f1b")] * 3
    with metrics.scope() as m:
        out = replay_batch(progs)
    assert not out["scalar_fallback"].any()
    assert "batch_replay.scalar_fallback" not in m.counters


def test_validation_summary_reports_fallback():
    from repro.api import Study
    res = Study(_tiny_scenario(validate_top=2)).run()
    val = res.provenance["validate"]
    assert val["n_scalar_fallback"] >= 0
    assert 0.0 <= val["scalar_fallback_frac"] <= 1.0


# ---------------------------------------------------------------------------
# Satellite: public cache/retrace stats — repeated same-bucket sweeps
# must not retrace
# ---------------------------------------------------------------------------
def test_evaluator_stats_public():
    from itertools import islice
    from repro.dse.search import BatchedEvaluator
    from repro.dse.space import StrategyBatch
    ev = BatchedEvaluator(TINY, MCM_TINY, backend="numpy")
    grid = StrategyBatch.from_strategies(
        list(islice(enumerate_strategies(TINY, MCM_TINY), 32)))
    ev.evaluate(grid)
    ev.evaluate(grid)                              # cache-served
    st = ev.stats()
    assert st["dse.cache.sim"] == len(grid.keys())
    assert st["dse.cache.hits"] == len(grid.keys())
    assert st["dse.cache.fallback_rows"] >= 0


def test_repeated_same_bucket_sweep_zero_new_retraces():
    jax = pytest.importorskip("jax")
    del jax
    from repro.dse.batched_sim import jax_stats
    from repro.dse.search import sweep_design_space
    sc = _tiny_scenario()
    space = sc.design_space()
    sweep_design_space(space, backend="jax")           # warm the trace
    before = jax_stats()["traces"]
    with metrics.scope() as m:
        sweep_design_space(space, backend="jax")       # same bucket
    assert jax_stats()["traces"] == before
    assert m.counters.get("batched_sim.jax_retraces", 0) == 0
