"""Tests for the event-driven timeline validator (repro.events) plus the
satellite work that rode along: vectorized traffic matrices and the
reuse-decision provenance in simulate() logs."""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.core.mcm import mcm_from_compute
from repro.core.optimizer import enumerate_strategies
from repro.core.simulator import map_intra, simulate
from repro.core.traffic import (PARALLELISMS, Strategy, _traffic_matrix_loop,
                                traffic_matrix, traffic_volumes)
from repro.core.workload import Workload
from repro.events import compile_step, replay, replay_batch
from repro.events.dag import SCHEDULES, device_op_order

TINY = Workload(model=get_config("tinyllama_1_1b"), seq_len=4096,
                global_batch=256)
MOE = Workload(model=get_config("qwen3_moe_235b_a22b"), seq_len=10240,
               global_batch=512)
HYBRID = Workload(model=get_config("zamba2_7b"), seq_len=4096,
                  global_batch=256)

MCM_TINY = mcm_from_compute(1e6, 16, 6)
MCM_MOE = mcm_from_compute(4e6, 16, 6)
MCM_HYB = mcm_from_compute(1e6, 16, 6)

_CASES = [("tiny", TINY, MCM_TINY), ("moe", MOE, MCM_MOE),
          ("hybrid", HYBRID, MCM_HYB)]
_GRIDS = {}


def _feasible(name, w, mcm):
    if name not in _GRIDS:
        out = []
        for s in enumerate_strategies(w, mcm):
            r = simulate(w, s, mcm)
            if r.feasible:
                out.append((s, r))
        out.sort(key=lambda t: -t[1].throughput)
        _GRIDS[name] = out
    return _GRIDS[name]


# ---------------------------------------------------------------------------
# Satellite: vectorized traffic_matrix parity vs the loop reference
# ---------------------------------------------------------------------------
@settings(max_examples=12)
@given(st.sampled_from([TINY, MOE]), st.integers(0, 10 ** 6),
       st.booleans())
def test_traffic_matrix_parity(w, pick, ep_fc):
    name, mcm = ("tiny", MCM_TINY) if w is TINY else ("moe", MCM_MOE)
    grid = _feasible(name, w, mcm)
    s = grid[pick % len(grid)][0]
    if s.n_devices > 2048:          # keep the O(n^2) reference cheap
        s = Strategy(tp=s.tp, dp=max(s.dp // 4, 1), pp=s.pp, cp=s.cp,
                     ep=s.ep, n_micro=s.n_micro)
    got = traffic_matrix(w, s, ep_fc=ep_fc)
    want = _traffic_matrix_loop(w, s, ep_fc=ep_fc)
    assert np.allclose(got, want, rtol=1e-12, atol=0.0)


def test_traffic_matrix_row_conservation():
    s = Strategy(tp=4, dp=4, pp=2, cp=2, ep=4, n_micro=8)
    vols = traffic_volumes(MOE, s)
    total = sum(v for p, v in vols.items() if s.degree(p) > 1)
    for ep_fc in (False, True):
        mat = traffic_matrix(MOE, s, ep_fc=ep_fc)
        assert np.allclose(mat.sum(1), total, rtol=1e-9)


# ---------------------------------------------------------------------------
# Satellite: reuse-decision provenance in simulate() logs
# ---------------------------------------------------------------------------
REUSE_S = Strategy(tp=1, dp=128, pp=2, cp=2, ep=8, n_micro=4)


def test_simulate_logs_reuse_gated():
    r = simulate(MOE, REUSE_S, MCM_MOE)
    logs = r.logs
    assert logs["reuse_cand_a"] >= 0 and logs["reuse_cand_b"] >= 0
    assert logs["reuse_gated"] == 1.0          # banked MEMS gate fired
    assert logs["reuse_active"] == 0.0
    assert logs["reuse_pair_a"] == -1.0 and logs["reuse_pair_b"] == -1.0
    assert logs["reuse_paper_mode"] == 0.0


def test_simulate_logs_reuse_paper_mode():
    hw = dataclasses.replace(MCM_MOE.hw, ocs_reuse_mode="paper")
    r = simulate(MOE, REUSE_S, MCM_MOE, hw=hw)
    logs = r.logs
    assert logs["reuse_paper_mode"] == 1.0
    assert logs["reuse_active"] == 1.0
    assert logs["reuse_gated"] == 0.0
    assert (logs["reuse_pair_a"], logs["reuse_pair_b"]) == \
           (logs["reuse_cand_a"], logs["reuse_cand_b"])
    a, b = int(logs["reuse_pair_a"]), int(logs["reuse_pair_b"])
    assert PARALLELISMS[a] != PARALLELISMS[b]


def test_simulate_logs_no_candidate():
    s, _ = _feasible("tiny", TINY, MCM_TINY)[0]
    r = simulate(TINY, s, MCM_TINY, fabric="ib")
    assert r.logs["reuse_cand_a"] == -1.0
    assert r.logs["reuse_gated"] == 0.0


# ---------------------------------------------------------------------------
# Tentpole: byte conservation (hypothesis) — dense, MoE, hybrid
# ---------------------------------------------------------------------------
@settings(max_examples=10)
@given(st.sampled_from(_CASES), st.integers(0, 10 ** 6))
def test_event_byte_conservation(case, pick):
    name, w, mcm = case
    grid = _feasible(name, w, mcm)
    s = grid[pick % len(grid)][0]
    prog = compile_step(w, s, mcm, schedule="gpipe")
    r = replay(prog)
    intra, inter = map_intra(w, s, mcm)
    vols = traffic_volumes(w, s)
    for p in PARALLELISMS:
        segs = (1 if intra.get(p, 1) > 1 else 0) \
            + (1 if inter.get(p, 1) > 1 else 0)
        want = vols[p] * segs
        got = r.bytes_moved.get(p, 0.0)
        if want == 0.0:
            assert got == 0.0
        else:
            assert got == pytest.approx(want, rel=1e-6), p
            assert prog.bytes_expected[p] == pytest.approx(want, rel=1e-12)


def test_event_replay_deterministic():
    s = next(s for s, _ in _feasible("tiny", TINY, MCM_TINY) if s.pp > 1)
    a = replay(compile_step(TINY, s, MCM_TINY, schedule="1f1b"),
               record_timeline=True)
    b = replay(compile_step(TINY, s, MCM_TINY, schedule="1f1b"),
               record_timeline=True)
    assert a.step_time == b.step_time
    assert a.n_events == b.n_events
    assert a.timeline == b.timeline
    assert a.bytes_moved == b.bytes_moved


# ---------------------------------------------------------------------------
# Tentpole: fidelity vs the analytic model (gpipe / 1f1b asserted)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("case", _CASES, ids=[c[0] for c in _CASES])
def test_event_fidelity_top_points(case):
    name, w, mcm = case
    picks = _feasible(name, w, mcm)[:3]
    picks += [t for t in _feasible(name, w, mcm) if t[0].pp > 1][:2]
    for s, sim in picks:
        for sched in ("gpipe", "1f1b"):
            r = replay(compile_step(w, s, mcm, schedule=sched))
            assert r.analytic_step_time == pytest.approx(sim.step_time,
                                                         rel=1e-9)
            assert abs(r.err) <= 0.15, (name, s, sched, r.err)


def test_event_fidelity_with_derived_topology():
    from repro.core.optimizer import evaluate_point
    found = 0
    for s, _ in _feasible("moe", MOE, MCM_MOE)[:20]:
        pt = evaluate_point(MOE, s, MCM_MOE)
        if pt is None or pt.topo is None or not pt.topo.dims:
            continue
        r = replay(compile_step(MOE, s, MCM_MOE, topo=pt.topo,
                                schedule="gpipe"))
        assert r.analytic_step_time == pytest.approx(pt.sim.step_time,
                                                     rel=1e-9)
        assert abs(r.err) <= 0.15
        found += 1
        if found >= 3:
            break
    assert found > 0


# ---------------------------------------------------------------------------
# Tentpole: schedules — bubble ordering and memory behaviour
# ---------------------------------------------------------------------------
def _pipelined(name, w, mcm, min_nm=8):
    for s, _ in _feasible(name, w, mcm):
        if s.pp > 1 and s.n_micro >= max(min_nm, s.pp):
            return s
    pytest.skip("no pipelined strategy in grid")


def test_schedule_bubble_ordering():
    s = _pipelined("tiny", TINY, MCM_TINY)
    res = {sched: replay(compile_step(TINY, s, MCM_TINY, schedule=sched))
           for sched in SCHEDULES}
    # gpipe and (non-interleaved) 1f1b share the same bubble ratio;
    # interleaving over v chunks divides it
    assert res["1f1b"].bubble == pytest.approx(res["gpipe"].bubble,
                                               rel=0.05, abs=0.01)
    assert res["interleaved"].bubble < 0.75 * res["gpipe"].bubble
    assert res["interleaved"].step_time < res["gpipe"].step_time
    # the analytic model assumes a gpipe-style bubble
    an_bubble = simulate(TINY, s, MCM_TINY).logs["bubble"]
    assert res["gpipe"].bubble == pytest.approx(an_bubble, rel=0.05,
                                                abs=0.01)
    # 1F1B's win is activation residency, not the bubble
    assert res["1f1b"].peak_inflight <= res["gpipe"].peak_inflight
    assert res["1f1b"].peak_inflight <= s.pp
    assert res["gpipe"].peak_inflight == s.n_micro


def test_schedule_op_orders_complete():
    for sched in SCHEDULES:
        for pp, v, nm in ((1, 1, 1), (2, 1, 8), (4, 2, 8), (8, 2, 16)):
            if sched != "interleaved":
                v = 1
            for s in range(pp):
                ops = device_op_order(sched, pp, v, nm, s)
                assert len(ops) == 2 * nm * v
                assert len(set(ops)) == 2 * nm * v    # each op exactly once


# ---------------------------------------------------------------------------
# Tentpole: batch replay parity vs the scalar engine
# ---------------------------------------------------------------------------
def test_batch_replay_matches_scalar():
    progs = []
    for name, w, mcm in _CASES:
        picks = _feasible(name, w, mcm)[:2]
        picks += [t for t in _feasible(name, w, mcm) if t[0].pp > 1][:1]
        for s, _ in picks:
            for sched in ("gpipe", "1f1b"):
                progs.append(compile_step(w, s, mcm, schedule=sched))
    out = replay_batch(progs)
    for j, p in enumerate(progs):
        r = replay(p)
        assert out["step_time"][j] == pytest.approx(r.step_time, rel=0.05)
        assert out["analytic_step_time"][j] == \
            pytest.approx(r.analytic_step_time, rel=1e-12)


def test_batch_replay_interleaved_vectorized():
    """Interleaved runs through the SAME vectorized wavefront as
    gpipe/1f1b — the level-table recurrence resolves its chunk-wrap
    dependencies, so there is no scalar fallback to hide behind."""
    s = _pipelined("tiny", TINY, MCM_TINY)
    prog = compile_step(TINY, s, MCM_TINY, schedule="interleaved")
    out = replay_batch([prog] * 3)
    assert not out["scalar_fallback"].any()
    r = replay(prog)
    assert out["step_time"][0] == pytest.approx(r.step_time, rel=0.05)
    assert out["bubble"][0] == pytest.approx(r.bubble, rel=0.05, abs=0.01)


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([c[0] for c in _CASES]), st.integers(0, 10 ** 6))
def test_batch_replay_interleaved_parity(name, pick):
    """Batch-vs-scalar parity for interleaved schedules across the
    feasible pipelined grid — previously vacuous (the fallback WAS the
    scalar engine), now a real recurrence-parity pin."""
    _, w, mcm = next(c for c in _CASES if c[0] == name)
    grid = [t for t in _feasible(name, w, mcm) if t[0].pp > 1]
    if not grid:
        return
    s = grid[pick % len(grid)][0]
    prog = compile_step(w, s, mcm, schedule="interleaved")
    out = replay_batch([prog])
    assert not out["scalar_fallback"].any()
    r = replay(prog)
    assert out["step_time"][0] == pytest.approx(r.step_time, rel=0.05)


# ---------------------------------------------------------------------------
# Tentpole: jax wavefront backend — parity, bucketing, auto resolution
# ---------------------------------------------------------------------------
def _jax_ok() -> bool:
    from repro.dse.batched_sim import _jax_available
    return _jax_available()


@pytest.mark.skipif(not _jax_ok(), reason="jax not installed")
def test_batch_replay_jax_matches_numpy():
    progs = []
    s = _pipelined("tiny", TINY, MCM_TINY)
    for sched in SCHEDULES:
        progs.append(compile_step(TINY, s, MCM_TINY, schedule=sched))
    progs += [compile_step(TINY, t[0], MCM_TINY, schedule="gpipe")
              for t in _feasible("tiny", TINY, MCM_TINY)[:3]]
    rn = replay_batch(progs, backend="numpy")
    rj = replay_batch(progs, backend="jax")
    for k in ("step_time", "makespan_body", "bubble", "dp_exposed"):
        np.testing.assert_allclose(rj[k], rn[k], rtol=1e-6, atol=0.0,
                                   err_msg=k)
    np.testing.assert_allclose(rj["err"], rn["err"], rtol=1e-6)


@pytest.mark.skipif(not _jax_ok(), reason="jax not installed")
def test_batch_replay_jax_same_bucket_no_retrace():
    from repro.events import batch as eb
    s = _pipelined("tiny", TINY, MCM_TINY)
    progs = [compile_step(TINY, s, MCM_TINY, schedule="1f1b")] * 40
    replay_batch(progs, backend="jax")
    before = eb._JAX_TRACES["count"]
    for n in range(33, 41):           # same power-of-two bucket (64)
        replay_batch(progs[:n], backend="jax")
    assert eb._JAX_TRACES["count"] == before


def test_batch_replay_backend_resolution():
    from repro.events.batch import JAX_AUTO_MIN_RECORDS, resolve_backend
    assert resolve_backend("numpy", 10 ** 9) == "numpy"
    assert resolve_backend("jax", 1) == "jax"
    assert resolve_backend("auto", JAX_AUTO_MIN_RECORDS - 1) == "numpy"
    if _jax_ok():
        assert resolve_backend("auto", JAX_AUTO_MIN_RECORDS) == "jax"
    with pytest.raises(ValueError, match="backend"):
        resolve_backend("zigzag", 4)


# ---------------------------------------------------------------------------
# Wiring: Study.run(validate_top=K), Scenario fields, CLI subcommand
# ---------------------------------------------------------------------------
def _tiny_scenario(**kw):
    from repro.api import Scenario
    return Scenario(model="tinyllama_1_1b", total_tflops=1e6, seq_len=4096,
                    global_batch=256, dies_per_mcm=(16,), m=(6,),
                    cpo_ratio=(0.6,), fabrics=("oi",), refine_top=3,
                    keep_top=16, **kw)


def test_study_validate_top_stamps_records():
    from repro.api import Study
    sc = _tiny_scenario(validate_top=3, schedule="1f1b")
    res = Study(sc).run()
    stamped = [r for r in res.records
               if "validated_step_time" in r.metrics]
    assert len(stamped) == 3
    for r in stamped:
        assert r.metrics["validated_step_time"] > 0
        assert abs(r.metrics["fidelity_err"]) <= 0.15
    val = res.provenance["validate"]
    assert val["n_validated"] == 3 and val["schedule"] == "1f1b"
    assert val["backend"] == sc.backend
    assert res.timings["validate_s"] > 0
    # argument overrides the scenario field
    res2 = Study(_tiny_scenario()).run(validate_top=2)
    assert sum("validated_step_time" in r.metrics
               for r in res2.records) == 2


def test_outer_event_replay_hook():
    from repro.api import Study
    sc = _tiny_scenario(driver="chiplight-outer",
                        driver_kw={"rounds": 1, "walkers": 2,
                                   "event_replay": 2})
    res = Study(sc).run()
    assert res.provenance["n_event_replayed"] > 0
    assert res.provenance["metrics"]["counters"][
        "outer.event_replayed"] == res.provenance["n_event_replayed"]
    w = res.traces[-1]["walkers"][0]
    assert w["event_thpt"] > 0 and w["event_step_time"] > 0
    # default off: legacy trace schema, no replays
    r0 = Study(sc.replace(driver_kw={"rounds": 1, "walkers": 2})).run()
    assert "event_thpt" not in r0.traces[-1]["walkers"][0]
    assert r0.provenance["n_event_replayed"] == 0


def test_outer_event_replay_rejects_scalar():
    from repro.dse.outer import outer_search
    with pytest.raises(ValueError, match="event_replay"):
        outer_search(TINY, 1e6, method="scalar", walkers=1,
                     event_replay=2)
    with pytest.raises(ValueError, match="event_schedule"):
        outer_search(TINY, 1e6, event_replay=2, event_schedule="zigzag")


def test_study_validate_roundtrips_artifact(tmp_path):
    from repro.api import Study, StudyResult
    res = Study(_tiny_scenario(validate_top=2)).run()
    path = res.save(tmp_path / "res.json")
    loaded = StudyResult.load(path)
    assert loaded.scenario.validate_top == 2
    stamped = [r for r in loaded.records
               if "validated_step_time" in r.metrics]
    assert len(stamped) == 2


def test_scenario_rejects_bad_schedule():
    with pytest.raises(ValueError, match="schedule"):
        _tiny_scenario(schedule="zigzag")
    with pytest.raises(ValueError, match="validate_top"):
        _tiny_scenario(validate_top=-1)


def test_validate_scenario_harness():
    from repro.events.validate import validate_scenario
    block = validate_scenario(_tiny_scenario(), top=2,
                              schedules=("gpipe", "1f1b"))
    assert block["n_points"] == 2
    assert len(block["rows"]) == 4
    assert all(r["ok"] for r in block["rows"])
    for r in block["rows"]:
        assert abs(r["err"]) <= 0.15


def test_cli_validate_smoke(tmp_path):
    from repro.cli import main
    out = tmp_path / "fidelity.json"
    rc = main(["validate", "scenarios/tinyllama_quick.json", "--quick",
               "--out", str(out)])
    assert rc == 0
    import json
    report = json.loads(out.read_text())
    assert report["schema"] == 1
    assert report["n_violations"] == 0
    assert report["n_asserted"] > 0


def test_cli_validate_top_flag(capsys):
    from repro.cli import main
    rc = main(["scenarios/tinyllama_quick.json", "--validate-top", "2",
               "--quick", "--out", "artifacts/studies"])
    assert rc == 0
    assert "event-validated 2 records" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# Tentpole: vectorized record->program compilation (events.compile_batch)
# ---------------------------------------------------------------------------
def _program_row(p):
    """The (6,) _ROW_KEYS row the per-record path derives from one
    compiled StepProgram — the reference compile_batch is pinned to."""
    return np.array(p.spans() + (p.n_micro * p.v,
                                 p.analytic.step_time))


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([c[0] for c in _CASES]),
       st.sampled_from(SCHEDULES), st.integers(0, 10 ** 6))
def test_compile_batch_parity(name, sched, pick):
    """Batched compilation == K compile_step walks at 1e-9: spans,
    DP cost, overlap credit, nmv and the embedded analytic step."""
    from repro.events.compile_batch import compile_batch
    _, w, mcm = next(c for c in _CASES if c[0] == name)
    grid = _feasible(name, w, mcm)
    ss = [grid[(pick + i) % len(grid)][0] for i in range(5)]
    cb = compile_batch(w, ss, mcm, schedule=sched)
    assert cb.feasible.all()
    for j, s in enumerate(ss):
        p = compile_step(w, s, mcm, schedule=sched)
        np.testing.assert_allclose(cb.rows[:, j], _program_row(p),
                                   rtol=1e-9, err_msg=f"{sched} {s}")
        assert int(cb.v[j]) == p.v
        assert cb.shape_keys[cb.key_rows[j]] == \
            (sched, p.n_stages, p.v, p.n_micro)


def test_compile_batch_topo_rows_parity():
    """Per-row derived OITopology overrides the allocation exactly like
    compile_step's topo branch (mixed with derive-it-yourself rows)."""
    from repro.core.optimizer import evaluate_point
    from repro.events.compile_batch import compile_batch
    rows = []
    for s, _ in _feasible("moe", MOE, MCM_MOE)[:20]:
        pt = evaluate_point(MOE, s, MCM_MOE)
        if pt is None or pt.topo is None or not pt.topo.dims:
            continue
        rows.append((s, pt.topo))
        if len(rows) >= 3:
            break
    assert rows
    rows.append((_feasible("moe", MOE, MCM_MOE)[0][0], None))
    ss = [s for s, _ in rows]
    topos = [t for _, t in rows]
    cb = compile_batch(MOE, ss, MCM_MOE, topos=topos, schedule="1f1b")
    assert cb.feasible.all()
    for j, (s, topo) in enumerate(rows):
        p = compile_step(MOE, s, MCM_MOE, topo=topo, schedule="1f1b")
        np.testing.assert_allclose(cb.rows[:, j], _program_row(p),
                                   rtol=1e-9)


def test_compile_batch_marks_infeasible():
    """compile_step raises on an infeasible point; the batch marks the
    row and replay() scatters inf back instead."""
    from repro.events.compile_batch import compile_batch
    good = _feasible("tiny", TINY, MCM_TINY)[0][0]
    bad = Strategy(tp=3, dp=1, pp=1, cp=1, ep=1, n_micro=1)
    cb = compile_batch(TINY, [good, bad], MCM_TINY)
    assert cb.feasible.tolist() == [True, False]
    assert np.isnan(cb.rows[:, 1]).all()
    assert cb.key_rows[1] == -1
    out = cb.replay(backend="numpy")
    assert np.isfinite(out["step_time"][0])
    assert out["step_time"][1] == np.inf
    with pytest.raises(ValueError, match="schedule"):
        compile_batch(TINY, [good], MCM_TINY, schedule="zigzag")


@pytest.mark.parametrize("case", _CASES, ids=[c[0] for c in _CASES])
def test_compile_batch_ranking_matches_per_record(case):
    """Fixed-schedule event ranking through the fused path == the
    per-record compile_step + replay_batch ranking."""
    from repro.events.compile_batch import compile_batch
    name, w, mcm = case
    grid = _feasible(name, w, mcm)
    ss = [t[0] for t in grid[:8]]
    ss += [t[0] for t in grid if t[0].pp > 1][:4]
    cb = compile_batch(w, ss, mcm, schedule="1f1b")
    got = cb.replay(backend="numpy")["step_time"]
    progs = [compile_step(w, s, mcm, schedule="1f1b") for s in ss]
    want = replay_batch(progs, backend="numpy")["step_time"]
    np.testing.assert_allclose(got, want, rtol=1e-9)
    assert np.array_equal(np.argsort(got, kind="stable"),
                          np.argsort(want, kind="stable"))


# ---------------------------------------------------------------------------
# Tentpole: schedule search — scenario axis, study re-rank, outer hook
# ---------------------------------------------------------------------------
def test_scenario_schedule_list():
    assert _tiny_scenario().schedule_list() == ("gpipe",)
    assert _tiny_scenario(schedule="search").schedule_list() == \
        tuple(SCHEDULES)
    assert _tiny_scenario(schedule="1f1b,interleaved").schedule_list() \
        == ("1f1b", "interleaved")
    with pytest.raises(ValueError, match="schedule"):
        _tiny_scenario(schedule="1f1b,zigzag")


def test_schedule_axis():
    from repro.dse.space import schedule_axis
    assert schedule_axis(("gpipe",)) == (("gpipe", 1),)
    assert schedule_axis(("1f1b", "interleaved")) == \
        (("1f1b", 1), ("interleaved", 2), ("interleaved", 4))


def test_event_rerank_rows_fixed_schedule_matches_replay_ranking():
    from repro.dse.search import event_rerank_rows, sweep_design_space
    sc = _tiny_scenario()
    sweep = sweep_design_space(sc.design_space(), backend=sc.backend)
    feas = np.nonzero(sweep.metrics["feasible"])[0]
    rows = feas[np.argsort(-sweep.metrics["throughput"][feas])][:12]
    rr = event_rerank_rows(sweep, rows, [("1f1b", 1)], backend="numpy")
    progs = []
    for i in rows:
        s = sweep.batch.take(np.array([int(i)])).to_strategies()[0]
        mcm = sweep.space.mcms[int(sweep.mcm_idx[i])]
        progs.append(compile_step(sweep.space.workload, s, mcm,
                                  fabric=str(sweep.fabric[i]),
                                  reuse=sweep.space.reuse,
                                  schedule="1f1b"))
    want = replay_batch(progs, backend="numpy")["step_time"]
    np.testing.assert_allclose(rr["step_time"], want, rtol=1e-9)
    assert np.array_equal(rr["order"], np.argsort(want, kind="stable"))
    assert set(rr["schedule"]) == {"1f1b"} and (rr["v"] == 1).all()


def test_study_schedule_search_reranks_and_stamps():
    from repro.api import Study
    res = Study(_tiny_scenario(schedule="search")).run()
    rr = res.provenance["event_rerank"]
    assert rr["n_reranked"] > 0
    assert rr["schedules"] == list(SCHEDULES)
    assert sum(rr["winners"].values()) == rr["n_reranked"]
    assert res.timings["rerank_s"] > 0
    best = res.records[res.best]
    assert best.metrics["event_schedule"] in SCHEDULES
    assert best.metrics["event_v"] >= 1
    assert best.metrics["event_step_time"] > 0
    assert best.metrics["event_throughput"] > 0
    # a single-schedule scenario skips the stage entirely
    r1 = Study(_tiny_scenario(schedule="1f1b")).run()
    assert "event_rerank" not in r1.provenance
    assert "rerank_s" not in r1.timings


def test_outer_event_replay_schedule_search():
    from repro.api import Study
    sc = _tiny_scenario(schedule="search", driver="chiplight-outer",
                        driver_kw={"rounds": 1, "walkers": 2,
                                   "event_replay": 2})
    res = Study(sc).run()
    assert res.provenance["n_event_replayed"] > 0
    w = res.traces[-1]["walkers"][0]
    assert w["event_thpt"] > 0 and w["event_step_time"] > 0


def test_outer_event_schedule_driver_kw_deprecated():
    import warnings
    from repro.api import Study
    sc = _tiny_scenario(driver="chiplight-outer",
                        driver_kw={"rounds": 1, "walkers": 2,
                                   "event_replay": 2,
                                   "event_schedule": "1f1b"})
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        res = Study(sc).run()
    assert sum(issubclass(r.category, DeprecationWarning)
               for r in rec) == 1
    assert res.provenance["n_event_replayed"] > 0
