"""Suite bootstrap.

Registers the in-repo hypothesis shim (tests/_hypothesis_shim.py) when
the real ``hypothesis`` package is not installed, so the property tests
run (with plain random sampling) instead of erroring at collection.
"""
import importlib.util
import pathlib
import sys

try:
    import hypothesis  # noqa: F401
except ImportError:
    _path = pathlib.Path(__file__).with_name("_hypothesis_shim.py")
    _spec = importlib.util.spec_from_file_location("hypothesis", _path)
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies
