"""chiplint (repro.analysis) — golden fixture tests per rule family,
baseline semantics, suppressions, and the repo-wide baseline-exact gate.

Fixture snippets live in tests/fixtures/chiplint/; each family has one
firing and one clean snippet, and the firing ones pin exact line
numbers so a finding that drifts off its source line fails here.
"""
import shutil
from pathlib import Path

import pytest

from repro.analysis import (DEFAULT_PARITY_PAIRS, LintConfig, ParityPair,
                            ParitySide, diff_baseline, load_baseline,
                            run_lint, save_baseline)
from repro.analysis.findings import Finding
from repro.analysis.jax_hygiene import JaxEntry
from repro.cli import main as cli_main

REPO_ROOT = Path(__file__).resolve().parents[1]
FIXTURES = Path(__file__).resolve().parent / "fixtures" / "chiplint"

# a config that disables every family; tests switch on one at a time
_OFF = dict(parity_pairs=(), jax_entries=(), units_paths=(),
            scan_glob="no_such_dir/**/*.py",
            metrics_decl_path="no_such_file.py")


def _tree(tmp_path, mapping):
    """Materialize {relpath: fixture-name-or-text} under tmp_path."""
    for rel, src in mapping.items():
        dst = tmp_path / rel
        dst.parent.mkdir(parents=True, exist_ok=True)
        if (FIXTURES / src).is_file():
            shutil.copy(FIXTURES / src, dst)
        else:
            dst.write_text(src)
    return tmp_path


def _findings(report, rule=None):
    return [f for f in report.findings
            if rule is None or f.rule == rule]


# ---------------------------------------------------------------------------
# parity-drift
# ---------------------------------------------------------------------------
def _parity_cfg(a_file):
    pair = ParityPair(
        name="fixture",
        a=ParitySide(path=a_file, functions=("cost",),
                     roles=(("w", "workload"), ("hw", "hw"))),
        b=ParitySide(path="b.py", functions=("cost_batch",),
                     roles=(("w", "workload"), ("hw", "hw"))))
    return LintConfig(**{**_OFF, "parity_pairs": (pair,)})


def test_parity_clean(tmp_path):
    root = _tree(tmp_path, {"a.py": "parity_a_clean.py",
                            "b.py": "parity_b.py"})
    report = run_lint(root, _parity_cfg("a.py"))
    assert report.findings == []


def test_parity_drift_fires_at_line(tmp_path):
    root = _tree(tmp_path, {"a.py": "parity_a_drift.py",
                            "b.py": "parity_b.py"})
    report = run_lint(root, _parity_cfg("a.py"))
    got = {(f.path, f.line) for f in _findings(report, "parity-drift")}
    # extra attr read on the drifted side, at its occurrence line
    assert ("a.py", 8) in got
    # 13.0 has no mirror (a side), and b's 12.0 is now unmatched
    assert ("a.py", 7) in got
    assert ("b.py", 6) in got
    msgs = " ".join(f.message for f in report.findings)
    assert "hw.derate" in msgs and "13" in msgs and "12" in msgs


def test_parity_missing_function_is_reported(tmp_path):
    root = _tree(tmp_path, {"a.py": "def other():\n    pass\n",
                            "b.py": "parity_b.py"})
    report = run_lint(root, _parity_cfg("a.py"))
    assert any("not found" in f.message for f in report.findings)


def test_seeded_drift_in_real_registered_pair(tmp_path):
    """The acceptance scenario: a one-token constant edit to a REAL
    registered pair (traffic_volumes) is a finding at that file:line."""
    files = ("src/repro/core/traffic.py", "src/repro/dse/batched_sim.py")
    for rel in files:
        dst = tmp_path / rel
        dst.parent.mkdir(parents=True, exist_ok=True)
        shutil.copy(REPO_ROOT / rel, dst)
    traffic = tmp_path / files[0]
    src = traffic.read_text()
    needle = "8.0 * layers_per_stage"
    assert needle in src
    edit_line = next(i for i, ln in enumerate(src.splitlines(), 1)
                     if needle in ln)
    traffic.write_text(src.replace(needle, "9.0 * layers_per_stage"))

    pair = next(p for p in DEFAULT_PARITY_PAIRS
                if p.name == "traffic_volumes")
    report = run_lint(tmp_path, LintConfig(**{**_OFF,
                                              "parity_pairs": (pair,)}))
    assert any(f.path == files[0] and f.line == edit_line
               and "9" in f.message for f in report.findings), \
        [f.render() for f in report.findings]
    # and the batched side's 8.0 is now unmatched too
    assert any(f.path == files[1] and "8" in f.message
               for f in report.findings)


# ---------------------------------------------------------------------------
# jax-hygiene
# ---------------------------------------------------------------------------
def _jax_cfg(path):
    entry = JaxEntry(path=path, qualname="terms",
                     static_params=("xp", "hw"))
    return LintConfig(**{**_OFF, "jax_entries": (entry,)})


def test_jax_clean(tmp_path):
    root = _tree(tmp_path, {"k.py": "jax_clean.py"})
    report = run_lint(root, _jax_cfg("k.py"))
    assert report.findings == []


def test_jax_firing_all_subchecks_at_lines(tmp_path):
    root = _tree(tmp_path, {"k.py": "jax_firing.py"})
    report = run_lint(root, _jax_cfg("k.py"))
    by_line = {f.line: f.message for f in _findings(report, "jax-hygiene")}
    assert 10 in by_line and "branch-on-tracer" in by_line[10]
    assert 11 in by_line and "tracer-escape" in by_line[11]
    assert 12 in by_line and "np-in-jit" in by_line[12]
    # helper() is reachable from the entry, so its mutable default fires
    assert 16 in by_line and "unhashable-default" in by_line[16]


def test_jax_missing_entry_is_reported(tmp_path):
    root = _tree(tmp_path, {"k.py": "def other(x):\n    return x\n"})
    report = run_lint(root, _jax_cfg("k.py"))
    assert any("entry point not found" in f.message
               for f in report.findings)


# ---------------------------------------------------------------------------
# units
# ---------------------------------------------------------------------------
def _units_cfg(*paths):
    return LintConfig(**{**_OFF, "units_paths": tuple(paths)})


def test_units_clean(tmp_path):
    root = _tree(tmp_path, {"u.py": "units_clean.py"})
    report = run_lint(root, _units_cfg("u.py"))
    assert report.findings == []


def test_units_firing_all_subchecks_at_lines(tmp_path):
    root = _tree(tmp_path, {"u.py": "units_firing.py"})
    report = run_lint(root, _units_cfg("u.py"))
    by_line = {f.line: f.message for f in _findings(report, "units")}
    assert 8 in by_line and "`+`" in by_line[8] \
        and "bytes" in by_line[8] and "`s`" in by_line[8]
    assert 9 in by_line and "comparison" in by_line[9]
    assert 11 in by_line and "assignment" in by_line[11] \
        and "GB" in by_line[11]


def test_units_propagates_through_assignment(tmp_path):
    src = ("def f(n_bytes, lat_s):\n"
           "    total = n_bytes * 2.0\n"
           "    return total + lat_s\n")
    root = _tree(tmp_path, {"u.py": src})
    report = run_lint(root, _units_cfg("u.py"))
    # total inherits no unit from a * expression: must NOT fire
    assert report.findings == []
    src2 = ("def f(n_bytes, lat_s):\n"
            "    total = n_bytes\n"
            "    return total + lat_s\n")
    root2 = _tree(tmp_path / "t2", {"u.py": src2})
    report2 = run_lint(root2, _units_cfg("u.py"))
    assert len(_findings(report2, "units")) == 1
    assert report2.findings[0].line == 3


# ---------------------------------------------------------------------------
# determinism / schema
# ---------------------------------------------------------------------------
def _det_tree(tmp_path, snippet):
    return _tree(tmp_path, {
        "src/repro/obs/metrics.py": "metrics_decl.py",
        "src/repro/mod.py": snippet,
    })


_DET_CFG = LintConfig(**{**_OFF, "scan_glob": "src/repro/**/*.py",
                         "metrics_decl_path": "src/repro/obs/metrics.py"})


def test_determinism_clean(tmp_path):
    root = _det_tree(tmp_path, "determinism_clean.py")
    report = run_lint(root, _DET_CFG)
    assert report.findings == []


def test_determinism_firing_all_subchecks_at_lines(tmp_path):
    root = _det_tree(tmp_path, "determinism_firing.py")
    report = run_lint(root, _DET_CFG)
    by_line = {f.line: f.message
               for f in _findings(report, "determinism")}
    assert 19 in by_line and "global-rng" in by_line[19] \
        and "random.random" in by_line[19]
    assert 20 in by_line and "np.random.rand" in by_line[20]
    assert 21 in by_line and "unknown-metric" in by_line[21] \
        and "not.declared" in by_line[21]
    assert 23 in by_line and "frozen-mutation" in by_line[23]


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------
def test_inline_suppression_named_and_bare(tmp_path):
    src = ("def mix(total_bytes, lat_s):\n"
           "    a = total_bytes + lat_s  # chiplint: ignore[units]\n"
           "    b = total_bytes - lat_s  # chiplint: ignore\n"
           "    c = total_bytes + lat_s  # chiplint: ignore[parity-drift]\n"
           "    return a, b, c\n")
    root = _tree(tmp_path, {"u.py": src})
    report = run_lint(root, _units_cfg("u.py"))
    # lines 2 and 3 suppressed (named match + bare); line 4 names a
    # different rule, so the units finding survives
    assert report.n_suppressed == 2
    assert [f.line for f in report.findings] == [4]


# ---------------------------------------------------------------------------
# baseline semantics
# ---------------------------------------------------------------------------
def _f(path="x.py", line=3, rule="units", message="m", symbol="f"):
    return Finding(path=path, line=line, rule=rule, message=message,
                   symbol=symbol)


def test_baseline_roundtrip_and_multiset_diff(tmp_path):
    f1, f2 = _f(line=3), _f(line=9)      # same fingerprint, two sites
    g = _f(rule="determinism", message="other")
    p = save_baseline(tmp_path / "b.json", [f1, g])
    base = load_baseline(p)

    # exact: one of the duplicate pair is new, g is covered
    new, stale = diff_baseline([f1, f2, g], base)
    assert new == [f2] and stale == []
    # both fixed: baseline entries go stale
    new, stale = diff_baseline([], base)
    assert new == [] and sorted(stale) == sorted(
        [f1.fingerprint, g.fingerprint])
    # line moves don't count as new (fingerprint excludes line)
    new, stale = diff_baseline([_f(line=77), g], base)
    assert new == [] and stale == []


def test_load_baseline_missing_and_bad_schema(tmp_path):
    assert load_baseline(tmp_path / "absent.json") == {}
    bad = tmp_path / "bad.json"
    bad.write_text('{"schema": 99, "findings": []}')
    with pytest.raises(ValueError):
        load_baseline(bad)


# ---------------------------------------------------------------------------
# the repo-wide gate (tier-1): current tree must be baseline-exact
# ---------------------------------------------------------------------------
def test_repo_is_baseline_exact():
    report = run_lint(REPO_ROOT)
    base = load_baseline(REPO_ROOT / "chiplint_baseline.json")
    new, stale = diff_baseline(report.findings, base)
    assert new == [], "chiplint found NEW findings:\n" + "\n".join(
        f.render() for f in new)
    assert stale == [], ("baseline entries with no matching finding "
                         "(fix shipped? update the baseline):\n"
                         + "\n".join(stale))
    assert report.n_files > 80     # the scan actually covered the tree


def test_cli_lint_exit_codes(tmp_path, capsys):
    assert cli_main(["lint", "--root", str(REPO_ROOT),
                     "--json", str(tmp_path / "r.json")]) == 0
    out = capsys.readouterr().out
    assert "chiplint:" in out
    assert (tmp_path / "r.json").is_file()
    # a tree with findings and no baseline exits 1 (the default config
    # scans src/repro/**, so the firing determinism fixture is covered;
    # the registered-but-absent parity/jax functions also report)
    root = _tree(tmp_path / "t", {
        "src/repro/obs/metrics.py": "metrics_decl.py",
        "src/repro/mod.py": "determinism_firing.py",
    })
    assert cli_main(["lint", "--root", str(root)]) == 1
    capsys.readouterr()
    # ...--update-baseline grandfathers them, then lint exits 0
    assert cli_main(["lint", "--root", str(root),
                     "--update-baseline"]) == 0
    assert cli_main(["lint", "--root", str(root)]) == 0
    # fixing the findings makes the baseline stale -> exit 1 again
    (root / "src/repro/mod.py").write_text("def ok():\n    return 0\n")
    assert cli_main(["lint", "--root", str(root)]) == 1
    capsys.readouterr()
