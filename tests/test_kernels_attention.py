"""Flash-attention kernel sweeps: pallas(interpret) and xla-blockwise vs
the dense oracle, across shapes, dtypes, GQA ratios, windows, softcaps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_fwd


def _mk(b, hq, hkv, s, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(42), 3)
    q = jax.random.normal(ks[0], (b, hq, s, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), dtype)
    return q, k, v


SWEEP = [
    # b, hq, hkv, s, d, window, softcap, causal
    (1, 2, 2, 128, 32, None, 0.0, True),
    (2, 4, 2, 128, 16, None, 0.0, True),
    (1, 8, 1, 256, 32, None, 0.0, True),     # MQA
    (2, 4, 4, 128, 64, 32, 0.0, True),       # SWA
    (1, 2, 2, 128, 32, None, 50.0, True),    # softcap (gemma2)
    (1, 2, 2, 128, 32, 64, 30.0, True),      # SWA + softcap
    (1, 4, 2, 128, 32, None, 0.0, False),    # encoder (non-causal)
]


@pytest.mark.parametrize("b,hq,hkv,s,d,win,cap,causal", SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_interpret_vs_ref(b, hq, hkv, s, d, win, cap, causal, dtype):
    q, k, v = _mk(b, hq, hkv, s, d, dtype)
    o, lse = flash_attention_fwd(q, k, v, win, causal=causal, softcap=cap,
                                 block_q=64, block_k=64, interpret=True)
    r = ref.attention_ref(q, k, v, causal=causal, window=win, softcap=cap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               rtol=tol, atol=tol)
    assert bool(jnp.isfinite(lse).all())


@pytest.mark.parametrize("b,hq,hkv,s,d,win,cap,causal", SWEEP)
def test_xla_blockwise_vs_ref(b, hq, hkv, s, d, win, cap, causal):
    q, k, v = _mk(b, hq, hkv, s, d, jnp.float32)
    o = ops.flash_attention(q, k, v, window=win, causal=causal, softcap=cap,
                            block=32, backend="xla")
    r = ref.attention_ref(q, k, v, causal=causal, window=win, softcap=cap)
    np.testing.assert_allclose(o, r, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("win,cap", [(None, 0.0), (32, 0.0), (None, 20.0)])
def test_gradients_vs_dense(win, cap):
    q, k, v = _mk(1, 4, 2, 64, 16, jnp.float32)
    gb = jax.grad(lambda q_, k_, v_: (ops.flash_attention(
        q_, k_, v_, window=win, softcap=cap, block=16,
        backend="xla") ** 2).sum(), argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lambda q_, k_, v_: (ref.attention_ref(
        q_, k_, v_, window=win, softcap=cap) ** 2).sum(),
        argnums=(0, 1, 2))(q, k, v)
    for a, b_, nm in zip(gb, gr, "qkv"):
        np.testing.assert_allclose(a, b_, rtol=3e-4, atol=3e-4,
                                   err_msg=f"d{nm}")


def test_dynamic_window_matches_static():
    q, k, v = _mk(1, 2, 2, 128, 16, jnp.float32)
    stat = ops.flash_attention(q, k, v, window=48, block=32, backend="xla")
    dyn = jax.jit(lambda w: ops.flash_attention(q, k, v, window=w, block=32,
                                                backend="xla"))(
                                                    jnp.int32(48))
    np.testing.assert_allclose(stat, dyn, rtol=1e-6, atol=1e-6)


def test_decode_matches_prefill_row():
    """decode_attention(pos) == last row of full attention over pos+1 keys."""
    q, k, v = _mk(2, 4, 2, 64, 16, jnp.float32)
    pos = 37
    full = ref.attention_ref(q[:, :, :pos + 1], k[:, :, :pos + 1],
                             v[:, :, :pos + 1], causal=True)
    dec = ops.decode_attention(q[:, :, pos:pos + 1], k, v, jnp.int32(pos))
    np.testing.assert_allclose(dec[:, :, 0], full[:, :, -1], rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("b,hq,hkv,s,d,win,cap,causal", SWEEP)
def test_xla_blocked_vs_ref(b, hq, hkv, s, d, win, cap, causal):
    """Statically-skipped 2D-block path == dense oracle."""
    q, k, v = _mk(b, hq, hkv, s, d, jnp.float32)
    o = ops.flash_attention(q, k, v, window=win, causal=causal, softcap=cap,
                            block=32, backend="xla_blocked")
    r = ref.attention_ref(q, k, v, causal=causal, window=win, softcap=cap)
    np.testing.assert_allclose(o, r, rtol=2e-5, atol=2e-5)


def test_xla_blocked_grads_match_scan():
    q, k, v = _mk(1, 4, 2, 64, 16, jnp.float32)
    for win in (None, 32):
        gb = jax.grad(lambda q_: (ops.flash_attention(
            q_, k, v, window=win, block=16,
            backend="xla_blocked") ** 2).sum())(q)
        gr = jax.grad(lambda q_: (ref.attention_ref(
            q_, k, v, window=win) ** 2).sum())(q)
        np.testing.assert_allclose(gb, gr, rtol=3e-4, atol=3e-4)


def test_blocked_cross_attention_mismatched_lengths():
    """sq != sk (whisper cross-attn): independent block sizes."""
    q, _, _ = _mk(1, 4, 2, 64, 16, jnp.float32)
    _, k, v = _mk(1, 4, 2, 96, 16, jnp.float32)
    o = ops.flash_attention(q, k, v, causal=False, block=32,
                            backend="xla_blocked")
    r = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(o, r, rtol=2e-5, atol=2e-5)


def test_nondivisible_seq_block():
    """S=1500-style non-power-of-two lengths pick a divisor block."""
    q, k, v = _mk(1, 2, 2, 100, 16, jnp.float32)
    for backend in ("xla", "xla_blocked"):
        o = ops.flash_attention(q, k, v, causal=False, block=32,
                                backend=backend)
        r = ref.attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(o, r, rtol=2e-5, atol=2e-5)
